#!/usr/bin/env bash
# Unsafe-audit gate: every `unsafe` site in the crate must carry an
# adjacent SAFETY justification (tests/unsafe_audit.rs), and the model
# checker must still vouch for the slot & refcount protocols when the
# `model` feature is requested.
#
# Usage: scripts/check_unsafe.sh [--with-model]
set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== unsafe audit (SAFETY-comment lint)"
cargo test -q --test unsafe_audit -- --nocapture

if [[ "${1:-}" == "--with-model" ]]; then
    echo "== model checker self-tests"
    cargo test -q --features model --lib model::
    echo "== slot & refcount protocol models"
    cargo test -q --features model --test model_slot --test model_refcount -- --nocapture
fi
