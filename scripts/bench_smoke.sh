#!/usr/bin/env bash
# Bench smoke: run the three JSON-mode benches with small, CI-sized
# parameters and write BENCH_<name>.json next to the repo root (or into
# $1 if given). These files are the cross-PR perf baseline: record one
# set before a perf change and one after, then compare the MOPs/kOPs and
# allocs-per-op fields. CI uploads them as a workflow artifact.
#
# Absolute numbers from a 1-2 vCPU container are noisy; ratios within
# one file (trustee/MCS, adaptive/eager, trust/mutex) and the
# allocs_per_op field are the stable signals.
set -euo pipefail

cd "$(dirname "$0")/../rust"
OUT_DIR="${1:-$(cd .. && pwd)}"
mkdir -p "$OUT_DIR"

echo "bench smoke -> $OUT_DIR" >&2

cargo bench --bench channel_micro -- --json --ops 4000 --threads 2 \
    > "$OUT_DIR/BENCH_channel_micro.json"
echo "wrote BENCH_channel_micro.json" >&2

cargo bench --bench fig9_kv_write_pct -- --json --quick --dist uniform --ops 1500 \
    > "$OUT_DIR/BENCH_fig9_kv_write_pct.json"
echo "wrote BENCH_fig9_kv_write_pct.json" >&2

cargo bench --bench resp_throughput -- --json --quick --ops 1500 \
    > "$OUT_DIR/BENCH_resp_throughput.json"
echo "wrote BENCH_resp_throughput.json" >&2

cargo bench --bench eviction_pressure -- --json --quick --ops 1500 \
    > "$OUT_DIR/BENCH_eviction_pressure.json"
echo "wrote BENCH_eviction_pressure.json" >&2

# E22 degradation curve, CI-sized: goodput + shed rate at two offered-
# concurrency levels, watermarked vs unlimited admission.
cargo bench --bench overload_degradation -- --json --quick --ops 800 \
    > "$OUT_DIR/BENCH_overload_degradation.json"
echo "wrote BENCH_overload_degradation.json" >&2

# E21 connection-scale sweep, CI-sized rungs (the full ladder is
# 1000,10000,100000 — see EXPERIMENTS.md E21). Cells where io_uring is
# unavailable fall back to epoll with a logged reason and still emit
# valid JSON, so this works on any kernel.
cargo bench --bench net_idle_conns -- --sweep --json \
    --conns 64,256 --ops 400 --active-pct 5 \
    > "$OUT_DIR/BENCH_net_idle_conns.json"
echo "wrote BENCH_net_idle_conns.json" >&2

# E23 readiness-vs-data-plane A/B, CI-sized. Emits a skip object on
# kernels without io_uring and a readiness-only cell list without
# PBUF_RING, so it is valid JSON everywhere.
cargo bench --bench uring_dataplane -- --json --ops 4000 --conns 2 --pipeline 8 \
    > "$OUT_DIR/BENCH_uring_dataplane.json"
echo "wrote BENCH_uring_dataplane.json" >&2

# Sanity: every file must be non-empty JSON (first byte '{').
for f in BENCH_channel_micro.json BENCH_fig9_kv_write_pct.json BENCH_resp_throughput.json BENCH_eviction_pressure.json BENCH_overload_degradation.json BENCH_net_idle_conns.json BENCH_uring_dataplane.json; do
    head -c 1 "$OUT_DIR/$f" | grep -q '{' || { echo "bad JSON in $f" >&2; exit 1; }
done
echo "bench smoke OK" >&2
