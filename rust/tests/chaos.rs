//! Chaos harness (`--features faults` only, see `[[test]]` gate in
//! Cargo.toml): seeded randomized fault-injection runs over every
//! NetPolicy × backend cell of the memcached server, with tight shed
//! watermarks so overload control engages under the same storm.
//!
//! Invariants asserted per cell:
//! - the server keeps accepting: after the storm a fresh connection
//!   completes a clean round trip;
//! - surviving connections got *correct* responses (the loader's strict
//!   parsers treat any desync as an error; injected resets/EOFs are the
//!   only tolerated failures);
//! - loader stats stay coherent (`done == hits + misses + shed`);
//! - shutdown completes within the drain-grace bound;
//! - no leaked fds (`/proc/self/fd` returns to its pre-server count);
//! - across a test's pinned-seed matrix, every injection site the
//!   environment can reach actually fired (`faultsim::injected`).
//!
//! The fault plan is process-global, so every test serializes on
//! [`PLAN_LOCK`]. Each pinned seed is replayable: run the same seed via
//! `TRUSTEE_FAULTS=seed:rate:mask` (the randomized test logs its seed in
//! exactly that spec form).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};
use trustee::kvstore::BackendKind;
use trustee::memcache::{run_memtier, McdServer, McdServerConfig, MemtierConfig};
use trustee::server::{NetPolicy, ServerTuning};
use trustee::util::faultsim::{self, Site};

/// Serializes every chaos test: the fault plan and its counters are
/// process-global.
static PLAN_LOCK: Mutex<()> = Mutex::new(());

/// Injection probability per probe, in basis points (5%): high enough
/// that a short storm exercises every site, low enough that most
/// connections make progress.
const RATE_BP: u32 = 500;

/// Pinned seeds: the deterministic regression matrix.
const PINNED_SEEDS: [u64; 2] = [0xC4A0_5EED, 0x7357_BEEF];

fn lock() -> std::sync::MutexGuard<'static, ()> {
    PLAN_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn fd_count() -> usize {
    std::fs::read_dir("/proc/self/fd").unwrap().count()
}

fn backends() -> [BackendKind; 4] {
    [
        BackendKind::Trust { shards: 2 },
        BackendKind::Mutex,
        BackendKind::RwLock,
        BackendKind::Swift,
    ]
}

/// Clean health probe (run with faults cleared): one SET + GET round
/// trip on a fresh connection.
fn assert_accepting(addr: std::net::SocketAddr) {
    let mut c = TcpStream::connect(addr).expect("server stopped accepting after the storm");
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    c.write_all(b"set chaos-health 0 0 2\r\nok\r\nget chaos-health\r\n").unwrap();
    let want = b"STORED\r\nVALUE chaos-health 0 2\r\nok\r\nEND\r\n";
    let mut got = Vec::new();
    let mut chunk = [0u8; 256];
    while got.len() < want.len() {
        let n = c.read(&mut chunk).expect("health read timed out");
        assert!(n > 0, "server closed the health connection");
        got.extend_from_slice(&chunk[..n]);
    }
    assert_eq!(&got[..], &want[..], "post-storm responses must be byte-correct");
}

/// One chaos cell: start the server under an installed plan, storm it
/// with the strict in-crate loader, verify every invariant, and return
/// the per-site injected counts this cell produced (sampled before
/// `clear`, since `install` resets the counters).
fn chaos_cell(policy: NetPolicy, backend: BackendKind, seed: u64) -> [u64; faultsim::NSITES] {
    let fds_before = fd_count();
    faultsim::install(seed, RATE_BP, faultsim::MASK_ALL);
    let server = McdServer::start(McdServerConfig {
        workers: 2,
        backend,
        net: policy,
        tuning: ServerTuning { shed_high: 64, shed_low: 32, ..ServerTuning::default() },
        ..Default::default()
    });
    // Backend-direct prefill (no socket in that path): loader GETs that
    // reach the store must hit, so a miss storm would flag corruption.
    server.prefill(64, 8);

    let stats = run_memtier(&MemtierConfig {
        addr: server.addr(),
        threads: 2,
        pipeline: 8,
        ops_per_thread: 150,
        keys: 64,
        dist: "uniform".into(),
        write_pct: 20,
        ttl_pct: 0,
        val_len: 8,
        seed,
        retry_shed: false,
    });
    // Injected resets/EOFs legitimately kill client threads mid-run;
    // anything else (a desync, an unexpected reply) is a real bug.
    for e in &stats.errors {
        assert!(
            e.contains("server closed")
                || e.contains("read:")
                || e.contains("write:")
                || e.contains("connect"),
            "client failure is not a tolerated fault under {policy:?}/{backend:?} seed {seed}: {e}"
        );
    }
    assert_eq!(
        stats.ops,
        stats.hits + stats.misses + stats.shed,
        "loader accounting must stay coherent under faults"
    );
    let injected = [
        faultsim::injected(Site::Read),
        faultsim::injected(Site::Write),
        faultsim::injected(Site::Accept),
        faultsim::injected(Site::EpollWait),
        faultsim::injected(Site::UringEnter),
        faultsim::injected(Site::UringRecv),
    ];

    // The storm is over: stop injecting, then check the invariants.
    faultsim::clear();
    assert_accepting(server.addr());
    assert!(
        server.metrics().totals().requests > 0,
        "the storm must have reached the server"
    );

    let t0 = Instant::now();
    server.stop();
    let stop_elapsed = t0.elapsed();
    assert!(
        stop_elapsed < Duration::from_secs(5),
        "shutdown took {stop_elapsed:?} — drain grace is 250ms plus teardown"
    );
    assert_eq!(fd_count(), fds_before, "fd leak under {policy:?}/{backend:?} seed {seed}");
    injected
}

/// Run the pinned-seed × backend matrix for one policy, summing per-site
/// injected counts across cells.
fn run_matrix(policy: NetPolicy) -> [u64; faultsim::NSITES] {
    let mut sum = [0u64; faultsim::NSITES];
    for seed in PINNED_SEEDS {
        for backend in backends() {
            let cell = chaos_cell(policy, backend, seed);
            for (s, c) in sum.iter_mut().zip(cell) {
                *s += c;
            }
        }
    }
    sum
}

#[test]
fn chaos_epoll_matrix_survives_and_covers_sites() {
    let _g = lock();
    let sum = run_matrix(NetPolicy::Epoll);
    assert!(sum[Site::Read.index()] > 0, "no read faults fired: {sum:?}");
    assert!(sum[Site::Write.index()] > 0, "no write faults fired: {sum:?}");
    assert!(sum[Site::Accept.index()] > 0, "no accept faults fired: {sum:?}");
    assert!(sum[Site::EpollWait.index()] > 0, "no epoll_wait faults fired: {sum:?}");
}

#[test]
fn chaos_busypoll_matrix_survives() {
    let _g = lock();
    let sum = run_matrix(NetPolicy::BusyPoll);
    assert!(sum[Site::Read.index()] > 0, "no read faults fired: {sum:?}");
    assert!(sum[Site::Write.index()] > 0, "no write faults fired: {sum:?}");
    assert!(sum[Site::Accept.index()] > 0, "no accept faults fired: {sum:?}");
}

#[test]
fn chaos_uring_matrix_survives_and_covers_enter_site() {
    let _g = lock();
    if let Err(e) = trustee::runtime::uring::probe() {
        eprintln!("SKIP chaos under uring: io_uring unavailable ({e})");
        return;
    }
    // On a PBUF_RING-capable kernel the storm runs over the data plane:
    // registered connections make no read/write syscalls, so the
    // read/write sites cannot fire — the RECV-CQE site must instead.
    // Readiness-plane kernels keep the PR 8 coverage expectations.
    let dataplane = trustee::runtime::uring::dataplane_enabled()
        && trustee::runtime::uring::probe_pbuf().is_ok();
    let sum = run_matrix(NetPolicy::IoUring);
    assert!(
        sum[Site::UringEnter.index()] > 0,
        "no io_uring_enter faults fired: {sum:?}"
    );
    if dataplane {
        assert!(
            sum[Site::UringRecv.index()] > 0,
            "no data-plane RECV faults (ENOBUFS / short CQE) fired: {sum:?}"
        );
    } else {
        assert!(sum[Site::Read.index()] > 0, "no read faults fired: {sum:?}");
        assert!(sum[Site::Write.index()] > 0, "no write faults fired: {sum:?}");
    }
}

#[test]
fn chaos_randomized_seed_logs_replay_spec() {
    let _g = lock();
    // One randomized cell per run widens coverage beyond the pinned
    // seeds; the seed is logged in TRUSTEE_FAULTS spec form so a CI
    // failure is replayable. TRUSTEE_CHAOS_SEED pins it for replay.
    let seed = match std::env::var("TRUSTEE_CHAOS_SEED") {
        Ok(s) => s.parse().expect("TRUSTEE_CHAOS_SEED must be a u64"),
        Err(_) => std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos() as u64,
    };
    eprintln!(
        "chaos: randomized seed {seed} \
         (replay: TRUSTEE_CHAOS_SEED={seed}, plan spec {seed}:{RATE_BP}:0x{:x})",
        faultsim::MASK_ALL
    );
    chaos_cell(NetPolicy::Epoll, BackendKind::Trust { shards: 2 }, seed);
}
