//! Accept-loop fd-exhaustion regression test: when `accept(2)` hits the
//! process's `RLIMIT_NOFILE` ceiling (EMFILE), the acceptor must count
//! the event in `accept_throttled`, back off instead of spinning, and —
//! once descriptors free up — accept the connection that sat in the
//! listen backlog the whole time.
//!
//! This is the only test in this binary on purpose: it clamps the
//! process-wide fd limit, which would race any concurrently-running test
//! that opens sockets or files.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};
use trustee::kvstore::{proto, BackendKind, KvServer, KvServerConfig};
use trustee::util::sys;

/// One PUT + GET round trip over an already-connected stream.
fn round_trip(c: &mut TcpStream, key: &[u8]) {
    let mut buf = Vec::new();
    proto::write_request(&mut buf, 1, proto::OP_PUT, key, b"alive");
    proto::write_request(&mut buf, 2, proto::OP_GET, key, &[]);
    c.write_all(&buf).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut cursor = proto::FrameCursor::new();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut got = 0;
    while got < 2 {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            match got {
                0 => assert_eq!((r.id, r.status), (1, proto::ST_OK)),
                _ => assert_eq!((r.id, r.val.as_slice()), (2, &b"alive"[..])),
            }
            got += 1;
            continue;
        }
        let n = c.read(&mut chunk).expect("read timed out");
        assert!(n > 0, "server closed during round trip");
        rbuf.extend_from_slice(&chunk[..n]);
    }
}

#[test]
fn accept_recovers_from_fd_exhaustion_with_backoff() {
    let mut saved = sys::rlimit { rlim_cur: 0, rlim_max: 0 };
    // SAFETY: plain getrlimit into a properly-sized, owned struct.
    let rc = unsafe { sys::getrlimit(sys::RLIMIT_NOFILE, &mut saved) };
    assert_eq!(rc, 0, "getrlimit failed");

    let server = KvServer::start(KvServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        ..Default::default()
    });
    // Baseline health check (also warms every lazily-created fd —
    // reactors, wake eventfds — so the clamp below can't starve startup).
    let mut warm = TcpStream::connect(server.addr()).unwrap();
    round_trip(&mut warm, b"warmup-k");

    // Clamp the soft limit just above the current fd population, then
    // burn every remaining descriptor so the next accept must EMFILE.
    let max_fd = std::fs::read_dir("/proc/self/fd")
        .unwrap()
        .filter_map(|e| e.ok()?.file_name().to_str()?.parse::<u64>().ok())
        .max()
        .unwrap();
    let clamp = sys::rlimit { rlim_cur: max_fd + 8, rlim_max: saved.rlim_max };
    // SAFETY: lowering the soft fd limit; restored before the test ends.
    let rc = unsafe { sys::setrlimit(sys::RLIMIT_NOFILE, &clamp) };
    assert_eq!(rc, 0, "setrlimit(clamp) failed");
    let mut fillers = Vec::new();
    loop {
        match std::fs::File::open("/dev/null") {
            Ok(f) => fillers.push(f),
            Err(_) => break, // EMFILE: the table is full
        }
    }
    // Free exactly one slot: the client's connect() takes it, the TCP
    // handshake completes in the kernel backlog, and the server's
    // accept() — needing a second descriptor — hits EMFILE.
    fillers.pop();
    let mut pending = TcpStream::connect(server.addr()).expect("backlog connect");

    let deadline = Instant::now() + Duration::from_secs(5);
    while server.metrics().totals().accept_throttled == 0 {
        assert!(
            Instant::now() < deadline,
            "acceptor never reported EMFILE throttling"
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    // Recovery: free the descriptors and restore the limit; the backed-
    // off acceptor must pick the pending connection up and serve it.
    drop(fillers);
    // SAFETY: restoring the limit saved above.
    let rc = unsafe { sys::setrlimit(sys::RLIMIT_NOFILE, &saved) };
    assert_eq!(rc, 0, "setrlimit(restore) failed");
    round_trip(&mut pending, b"post-emfile-k");

    let totals = server.metrics().totals();
    assert!(totals.accept_throttled >= 1, "throttle metric must have fired");
    server.stop();
}
