//! The unified item store's cache semantics, pinned **identically across
//! all four backends** (trust / mutex / rwlock / swift):
//!
//! - deterministic LRU victim order under a byte budget (seeded: one
//!   shard, a manual clock, a scripted access sequence);
//! - lazy-on-access expiry vs sweep expiry equivalence (same misses,
//!   same final counters, whichever path reclaims);
//! - the TTL surface end to end over both wire protocols: memcached
//!   `set <exptime>` and RESP `SET EX/PX` / `EXPIRE` / `TTL` / `PTTL` /
//!   `PERSIST`.

use std::cell::Cell;
use std::rc::Rc;
use std::sync::{Arc, Mutex, RwLock};
use trustee::fiber;
use trustee::kvstore::backend::{AckCb, AsyncKv, GetItemCb, TtlCb};
use trustee::kvstore::store::{entry_cost, StoreClock, StoreConfig, TTL_MISSING, TTL_NO_EXPIRY};
use trustee::kvstore::{ItemShard, LockedItemKv, StoreStats, TrustKv};
use trustee::runtime::Runtime;

// ---------------------------------------------------------------------
// Synchronous op helpers (run inside a runtime fiber so Trust
// completions can flow; lock backends complete inline).
// ---------------------------------------------------------------------

fn set_sync(kv: &Arc<dyn AsyncKv>, key: &[u8], val: &[u8], flags: u32, ttl_ms: u64) -> bool {
    let r: Rc<Cell<Option<bool>>> = Rc::new(Cell::new(None));
    let r2 = r.clone();
    kv.set_item(key, val, flags, ttl_ms, AckCb::new(move |e| r2.set(Some(e))));
    while r.get().is_none() {
        fiber::yield_now();
    }
    r.get().unwrap()
}

fn get_sync(kv: &Arc<dyn AsyncKv>, key: &[u8]) -> Option<(u32, Vec<u8>)> {
    let r: Rc<Cell<bool>> = Rc::new(Cell::new(false));
    let out: Rc<std::cell::RefCell<Option<(u32, Vec<u8>)>>> =
        Rc::new(std::cell::RefCell::new(None));
    let (r2, o2) = (r.clone(), out.clone());
    kv.get_item(
        key,
        GetItemCb::new(move |_k: &[u8], item: Option<(u32, &[u8])>| {
            *o2.borrow_mut() = item.map(|(f, v)| (f, v.to_vec()));
            r2.set(true);
        }),
    );
    while !r.get() {
        fiber::yield_now();
    }
    out.borrow_mut().take()
}

fn ttl_sync(kv: &Arc<dyn AsyncKv>, key: &[u8]) -> i64 {
    let r: Rc<Cell<Option<i64>>> = Rc::new(Cell::new(None));
    let r2 = r.clone();
    kv.ttl(key, TtlCb::new(move |ms| r2.set(Some(ms))));
    while r.get().is_none() {
        fiber::yield_now();
    }
    r.get().unwrap()
}

/// Build each backend flavor with one shard (so every key contends for
/// the same budget) over the given store config.
fn backends_one_shard(rt: &Runtime, cfg: &StoreConfig) -> Vec<(&'static str, Arc<dyn AsyncKv>)> {
    vec![
        ("trust", TrustKv::with_config(rt, &[0], 1, cfg) as Arc<dyn AsyncKv>),
        (
            "mutex",
            Arc::new(LockedItemKv::<Mutex<ItemShard>>::new(1, "mutex", cfg)),
        ),
        (
            "rwlock",
            Arc::new(LockedItemKv::<RwLock<ItemShard>>::new(1, "rwlock", cfg)),
        ),
        (
            "swift",
            Arc::new(LockedItemKv::<RwLock<ItemShard>>::new(1, "swift", cfg)),
        ),
    ]
}

#[test]
fn lru_victim_order_is_deterministic_across_backends() {
    // One shard, budget for exactly 4 entries of this shape.
    let per_entry = entry_cost(2, 100); // "k0" + a 100-byte (class-120) value
    let val = vec![b'x'; 100];
    let rt = Runtime::builder().workers(2).build();
    let mut outcomes: Vec<(&'static str, Vec<bool>, StoreStats)> = Vec::new();
    for (name, kv) in backends_one_shard(&rt, &StoreConfig::with_budget(4 * per_entry)) {
        let kv2 = kv.clone();
        let val = val.clone();
        let hits = rt.block_on(1, move || {
            for k in [b"k0", b"k1", b"k2", b"k3"] {
                assert!(!set_sync(&kv2, k, &val, 0, 0));
            }
            // Recency script: bump k0 and k2, leaving k1 then k3 as the
            // LRU victims for the next two inserts.
            assert!(get_sync(&kv2, b"k0").is_some());
            assert!(get_sync(&kv2, b"k2").is_some());
            assert!(!set_sync(&kv2, b"k4", &val, 0, 0)); // evicts k1
            assert!(!set_sync(&kv2, b"k5", &val, 0, 0)); // evicts k3
            [b"k0", b"k1", b"k2", b"k3", b"k4", b"k5"]
                .iter()
                .map(|k| get_sync(&kv2, *k).is_some())
                .collect::<Vec<bool>>()
        });
        outcomes.push((name, hits, kv.store_stats()));
    }
    let want = vec![true, false, true, false, true, true];
    for (name, hits, stats) in &outcomes {
        assert_eq!(hits, &want, "{name}: LRU victim order diverged");
        assert_eq!(stats.evictions, 2, "{name}: eviction count");
        assert_eq!(stats.items, 4, "{name}: live items");
        assert!(
            stats.store_bytes <= 4 * per_entry,
            "{name}: budget exceeded ({} > {})",
            stats.store_bytes,
            4 * per_entry
        );
    }
    rt.shutdown();
}

#[test]
fn lazy_and_sweep_expiry_agree_across_backends() {
    // Three keys: `a` expires and is reclaimed lazily (a GET touches
    // it), `c` expires and is reclaimed by the sweep (nobody touches
    // it), `b` never expires. Every backend must report the same misses
    // and converge to the same counters.
    let rt = Runtime::builder().workers(2).build();
    let clock = StoreClock::manual();
    let cfg = StoreConfig { budget_bytes: 0, clock: clock.clone() };
    for (name, kv) in backends_one_shard(&rt, &cfg) {
        let kv2 = kv.clone();
        let clock2 = clock.clone();
        rt.block_on(1, move || {
            set_sync(&kv2, b"a", b"v", 1, 100);
            set_sync(&kv2, b"b", b"v", 2, 0);
            set_sync(&kv2, b"c", b"v", 3, 100);
            assert_eq!(ttl_sync(&kv2, b"a"), 100, "{name}");
            assert_eq!(ttl_sync(&kv2, b"b"), TTL_NO_EXPIRY, "{name}");
            clock2.advance(100);
            // Lazy path: the GET discovers and reclaims `a`.
            assert!(get_sync(&kv2, b"a").is_none(), "{name}: a must expire");
            assert_eq!(ttl_sync(&kv2, b"a"), TTL_MISSING, "{name}");
            // `c` is expired but untouched: invisible, not yet reclaimed.
            assert_eq!(ttl_sync(&kv2, b"c"), TTL_MISSING, "{name}");
            // `b` lives on.
            assert_eq!(get_sync(&kv2, b"b"), Some((2, b"v".to_vec())), "{name}");
        });
        // Sweep path: reclaim `c` without any access.
        let swept = kv.sweep_now(1 << 16);
        assert_eq!(swept, 1, "{name}: sweep must reclaim exactly c");
        let stats = kv.store_stats();
        assert_eq!(stats.items, 1, "{name}: only b survives");
        assert_eq!(stats.expired_keys, 2, "{name}: a (lazy) + c (sweep)");
        assert_eq!(stats.evictions, 0, "{name}");
        assert_eq!(stats.store_bytes, entry_cost(1, 1), "{name}");
        // The clock is shared across backends in this loop; rewind is
        // impossible, so later backends just see a larger `now` — the
        // relative script stays identical.
    }
    rt.shutdown();
}

// ---------------------------------------------------------------------
// Wire-level TTL coverage
// ---------------------------------------------------------------------

mod wire {
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;
    use trustee::kvstore::BackendKind;
    use trustee::memcache::{McdServer, McdServerConfig};
    use trustee::server::{RespServer, RespServerConfig};

    fn read_line(r: &mut impl BufRead) -> String {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        line
    }

    #[test]
    fn mcd_exptime_expires_over_the_socket() {
        let server = McdServer::start(McdServerConfig {
            workers: 2,
            backend: BackendKind::Trust { shards: 2 },
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        // exptime 1: relative seconds.
        c.write_all(b"set ttl-key 9 1 5\r\nhello\r\n").unwrap();
        assert_eq!(read_line(&mut reader), "STORED\r\n");
        c.write_all(b"get ttl-key\r\n").unwrap();
        assert_eq!(read_line(&mut reader), "VALUE ttl-key 9 5\r\n");
        let mut data = vec![0u8; 7];
        reader.read_exact(&mut data).unwrap(); // "hello\r\n"
        assert_eq!(read_line(&mut reader), "END\r\n");
        // A key without exptime survives alongside.
        c.write_all(b"set keeper 0 0 2\r\nok\r\n").unwrap();
        assert_eq!(read_line(&mut reader), "STORED\r\n");
        // Negative exptime: memcached's "expire immediately".
        c.write_all(b"set gone 0 -1 2\r\nxx\r\n").unwrap();
        assert_eq!(read_line(&mut reader), "STORED\r\n");
        std::thread::sleep(std::time::Duration::from_millis(1200));
        c.write_all(b"get gone\r\n").unwrap();
        assert_eq!(read_line(&mut reader), "END\r\n", "negative exptime misses");
        c.write_all(b"get ttl-key\r\n").unwrap();
        assert_eq!(read_line(&mut reader), "END\r\n", "expired key must miss");
        c.write_all(b"get keeper\r\n").unwrap();
        assert_eq!(read_line(&mut reader), "VALUE keeper 0 2\r\n");
        let mut data = vec![0u8; 4];
        reader.read_exact(&mut data).unwrap(); // "ok\r\n"
        assert_eq!(read_line(&mut reader), "END\r\n");
        let stats = server.store_stats();
        assert!(
            stats.expired_keys >= 1,
            "lazy/sweep expiry must have reclaimed: {stats:?}"
        );
        drop((c, reader));
        server.stop();
    }

    #[test]
    fn resp_ttl_command_surface() {
        let server = RespServer::start(RespServerConfig {
            workers: 2,
            backend: BackendKind::Trust { shards: 2 },
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut ask = |cmd: &str| -> String {
            c.write_all(cmd.as_bytes()).unwrap();
            read_line(&mut reader)
        };
        assert_eq!(ask("SET k v PX 60000\r\n"), "+OK\r\n");
        // PTTL: remaining ms in (0, 60000]; TTL rounds up to seconds.
        let pttl: i64 = ask("PTTL k\r\n").trim_start_matches(':').trim().parse().unwrap();
        assert!((1..=60_000).contains(&pttl), "pttl {pttl}");
        let ttl: i64 = ask("TTL k\r\n").trim_start_matches(':').trim().parse().unwrap();
        assert!((1..=60).contains(&ttl), "ttl {ttl}");
        assert_eq!(ask("PERSIST k\r\n"), ":1\r\n");
        assert_eq!(ask("TTL k\r\n"), ":-1\r\n");
        assert_eq!(ask("PERSIST k\r\n"), ":0\r\n", "no deadline left to clear");
        assert_eq!(ask("EXPIRE k 30\r\n"), ":1\r\n");
        let ttl: i64 = ask("TTL k\r\n").trim_start_matches(':').trim().parse().unwrap();
        assert!((1..=30).contains(&ttl));
        // Expire it for real.
        assert_eq!(ask("PEXPIRE k 60\r\n"), ":1\r\n");
        std::thread::sleep(std::time::Duration::from_millis(150));
        assert_eq!(ask("GET k\r\n"), "$-1\r\n", "expired key must be gone");
        assert_eq!(ask("TTL k\r\n"), ":-2\r\n");
        assert_eq!(ask("EXPIRE k 10\r\n"), ":0\r\n", "expire on missing key");
        assert_eq!(ask("EXPIRE missing 10\r\n"), ":0\r\n");
        // SET EX sets a deadline too; bad options are syntax errors.
        assert_eq!(ask("SET e v EX 40\r\n"), "+OK\r\n");
        let ttl: i64 = ask("TTL e\r\n").trim_start_matches(':').trim().parse().unwrap();
        assert!((1..=40).contains(&ttl));
        // A plain SET clears the deadline (Redis semantics).
        assert_eq!(ask("SET e v2\r\n"), "+OK\r\n");
        assert_eq!(ask("TTL e\r\n"), ":-1\r\n");
        assert!(ask("SET b v BOGUS 1\r\n").starts_with("-ERR syntax error"));
        assert!(ask("SET b v EX 0\r\n").starts_with("-ERR invalid expire"));
        assert!(ask("EXPIRE e abc\r\n").starts_with("-ERR invalid expire"));
        drop((c, reader));
        server.stop();
    }

    #[test]
    fn eviction_under_budget_over_the_wire() {
        // A tiny budget: pipelined sets must keep the server under it,
        // with evictions visible in the stats and the survivors the
        // most recently written keys.
        let budget = 16 * 1024;
        let server = RespServer::start(RespServerConfig {
            workers: 2,
            backend: BackendKind::Trust { shards: 1 },
            budget_bytes: budget,
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let val = "v".repeat(512);
        for i in 0..128 {
            c.write_all(format!("SET evict:{i} {val}\r\n").as_bytes()).unwrap();
            assert_eq!(read_line(&mut reader), "+OK\r\n");
        }
        let stats = server.store_stats();
        assert!(stats.evictions > 0, "budget must have evicted: {stats:?}");
        assert!(
            stats.store_bytes <= budget,
            "store over budget: {} > {budget}",
            stats.store_bytes
        );
        // The most recent key must have survived; the very first is gone.
        c.write_all(b"EXISTS evict:127\r\n").unwrap();
        assert_eq!(read_line(&mut reader), ":1\r\n");
        c.write_all(b"EXISTS evict:0\r\n").unwrap();
        assert_eq!(read_line(&mut reader), ":0\r\n");
        drop((c, reader));
        server.stop();
    }
}
