//! Failure injection: the runtime's behavior at the edges — assertion
//! failures for illegal blocking (§3.4), client disconnects mid-pipeline,
//! panics in fibers, runtime teardown with outstanding handles.

use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use trustee::kvstore::{proto, BackendKind, KvServer, KvServerConfig};
use trustee::runtime::Runtime;

#[test]
fn fiber_panic_does_not_kill_other_fibers() {
    let rt = Runtime::builder().workers(2).build();
    // A panicking fiber on worker 1...
    let survived = Arc::new(AtomicU64::new(0));
    let s = survived.clone();
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        rt.block_on(1, || panic!("injected fiber failure"));
    }));
    assert!(caught.is_err(), "panic must propagate to block_on caller");
    // ...must not prevent later fibers on the same worker from running.
    rt.block_on(1, move || s.store(42, Ordering::Release));
    assert_eq!(survived.load(Ordering::Acquire), 42);
    rt.shutdown();
}

#[test]
fn client_disconnect_mid_pipeline_leaves_server_healthy() {
    let server = KvServer::start(KvServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        ..Default::default()
    });
    server.prefill(100, 16);
    // Open a connection, fire pipelined requests, slam it shut without
    // reading responses.
    {
        let mut c = std::net::TcpStream::connect(server.addr()).unwrap();
        let mut buf = Vec::new();
        for i in 0..200u64 {
            proto::write_request(
                &mut buf,
                i,
                proto::OP_GET,
                &trustee::kvstore::key_bytes(i % 100),
                &[],
            );
        }
        c.write_all(&buf).unwrap();
        // Drop without reading: the connection fiber must drain inflight
        // callbacks and exit without wedging the worker.
    }
    // A fresh connection still works.
    let mut c = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut buf = Vec::new();
    proto::write_request(&mut buf, 1, proto::OP_GET, &trustee::kvstore::key_bytes(5), &[]);
    c.write_all(&buf).unwrap();
    use std::io::Read;
    let mut rbuf = Vec::new();
    let mut cursor = proto::FrameCursor::new();
    let mut chunk = [0u8; 4096];
    let resp = loop {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            break r;
        }
        let n = c.read(&mut chunk).unwrap();
        assert!(n > 0, "server died after client abort");
        rbuf.extend_from_slice(&chunk[..n]);
    };
    assert_eq!(resp.status, proto::ST_OK);
    server.stop();
}

#[test]
fn truncated_request_is_simply_ignored_until_complete() {
    // A partial frame must not crash the parser or produce garbage ops.
    let server = KvServer::start(KvServerConfig {
        workers: 2,
        backend: BackendKind::Mutex,
        ..Default::default()
    });
    let mut c = std::net::TcpStream::connect(server.addr()).unwrap();
    let mut buf = Vec::new();
    proto::write_request(&mut buf, 9, proto::OP_PUT, b"kk", b"vv");
    // Send only half the frame, wait, then the rest.
    let half = buf.len() / 2;
    c.write_all(&buf[..half]).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(50));
    c.write_all(&buf[half..]).unwrap();
    use std::io::Read;
    let mut rbuf = Vec::new();
    let mut cursor = proto::FrameCursor::new();
    let mut chunk = [0u8; 1024];
    let resp = loop {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            break r;
        }
        let n = c.read(&mut chunk).unwrap();
        assert!(n > 0);
        rbuf.extend_from_slice(&chunk[..n]);
    };
    assert_eq!((resp.id, resp.status), (9, proto::ST_OK));
    server.stop();
}

#[test]
fn trust_outliving_runtime_is_inert() {
    // Dropping a Trust after shutdown must not crash (property was
    // reclaimed at worker teardown; drop becomes a no-op).
    let rt = Runtime::builder().workers(2).build();
    let ct = rt.trustee(0).entrust(123u64);
    rt.shutdown();
    drop(ct); // must not panic or touch freed memory
}

#[test]
fn shutdown_with_parked_fibers_is_clean() {
    // Fibers parked on a never-opened gate at shutdown: the runtime drains
    // quiescent workers; parked-forever fibers would hang shutdown, so the
    // test instead checks that *completed* work shuts down promptly even
    // after heavy suspension traffic.
    let rt = Runtime::builder().workers(3).build();
    let ct = rt.trustee(0).entrust(0u64);
    let done = Arc::new(AtomicU64::new(0));
    for w in 1..3 {
        let ct = ct.clone();
        let d = done.clone();
        rt.spawn_on(w, move || {
            for _ in 0..500 {
                ct.apply(|v| *v += 1);
            }
            d.fetch_add(1, Ordering::AcqRel);
        });
    }
    while done.load(Ordering::Acquire) != 2 {
        std::thread::yield_now();
    }
    drop(ct);
    let t0 = std::time::Instant::now();
    rt.shutdown();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(10),
        "shutdown took {:?}",
        t0.elapsed()
    );
}

#[test]
fn zero_sized_and_unit_properties() {
    // Degenerate property types must work (zero-size env, zero-size T).
    let rt = Runtime::builder().workers(2).build();
    let unit = rt.trustee(0).entrust(());
    let c2 = unit.clone();
    let out = rt.block_on(1, move || c2.apply(|_| 7u64));
    assert_eq!(out, 7);
    drop(unit);
    rt.shutdown();
}
