//! Cross-module integration: Trust\<T\> + fibers + channel + runtime under
//! realistic composition — many properties, many workers, mixed blocking /
//! non-blocking traffic, nested structures, refcount churn.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use trustee::runtime::Runtime;
use trustee::trust::{local_trustee, Latch, Trust};

#[test]
fn many_properties_many_workers_exact_counts() {
    let rt = Runtime::builder().workers(4).build();
    // 32 counters spread over all workers.
    let counters: Vec<Trust<u64>> = (0..32)
        .map(|i| rt.trustee(i % 4).entrust(0u64))
        .collect();
    let counters = Arc::new(counters);
    let done = Arc::new(AtomicU64::new(0));
    for w in 0..4 {
        let counters = counters.clone();
        let done = done.clone();
        rt.spawn_on(w, move || {
            // Each worker increments every counter 50 times.
            for _round in 0..50 {
                for c in counters.iter() {
                    c.apply(|v| *v += 1);
                }
            }
            done.fetch_add(1, Ordering::AcqRel);
        });
    }
    while done.load(Ordering::Acquire) != 4 {
        std::thread::yield_now();
    }
    let counters2 = counters.clone();
    let totals: Vec<u64> = rt.block_on(0, move || {
        counters2.iter().map(|c| c.apply(|v| *v)).collect()
    });
    assert!(totals.iter().all(|&t| t == 200), "{totals:?}");
    drop(counters);
    rt.shutdown();
}

#[test]
fn mixed_blocking_and_async_on_same_property() {
    let rt = Runtime::builder().workers(3).build();
    let acc = rt.block_on(0, || local_trustee().entrust(Vec::<u32>::new()));
    let a2 = acc.clone();
    rt.block_on(1, move || {
        // Interleave apply and apply_then; per-pair ordering guarantees the
        // final blocking apply sees everything this worker sent.
        for i in 0..100u32 {
            if i % 3 == 0 {
                a2.apply(move |v| v.push(i));
            } else {
                a2.apply_then(move |v| v.push(i), |_| {});
            }
        }
        let len = a2.apply(|v| v.len() as u64);
        assert_eq!(len, 100);
        // Per-pair in-order execution: the vector must be sorted.
        let sorted = a2.apply(|v| v.windows(2).all(|w| w[0] < w[1]));
        assert!(sorted, "per-pair requests must execute in order");
    });
    drop(acc);
    rt.shutdown();
}

#[test]
fn trust_inside_trust_composes() {
    // A directory property holding Trusts to leaf properties: delegation
    // requests routed through a delegated lookup (apply_then from within
    // delegated context).
    let rt = Runtime::builder().workers(3).build();
    let leaf_a = rt.trustee(1).entrust(0u64);
    let leaf_b = rt.trustee(2).entrust(0u64);
    let dir = rt.trustee(0).entrust(vec![leaf_a.clone(), leaf_b.clone()]);

    let d2 = dir.clone();
    rt.block_on(1, move || {
        for i in 0..20u64 {
            let which = (i % 2) as usize;
            // Look up the leaf inside the directory's trustee, then issue a
            // non-blocking nested delegation from delegated context (§4.2).
            d2.apply(move |leaves| {
                leaves[which].apply_then(|v| *v += 1, |_| {});
            });
        }
    });
    // Poll until both leaves absorbed their increments.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        let la = leaf_a.clone();
        let lb = leaf_b.clone();
        let (a, b) = rt.block_on(1, move || (la.apply(|v| *v), lb.apply(|v| *v)));
        if a == 10 && b == 10 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "stuck at {a},{b}");
    }
    drop((dir, leaf_a, leaf_b));
    rt.shutdown();
}

#[test]
fn refcount_churn_many_clones() {
    let rt = Runtime::builder().workers(3).build();
    let ct = rt.trustee(0).entrust(String::from("x"));
    // Clone/drop storm across threads.
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let ct = ct.clone();
            std::thread::spawn(move || {
                let mut clones = Vec::new();
                for _ in 0..50 {
                    clones.push(ct.clone());
                }
                drop(clones);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // Still alive and correct.
    let v = ct.apply(|s| s.clone());
    assert_eq!(v, "x");
    drop(ct);
    // Property reclaimed after the last drop.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        let live = rt.block_on(0, || trustee::runtime::with_worker(|w| w.registry.live));
        if live == 0 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "{live} props leaked");
    }
    rt.shutdown();
}

#[test]
fn latch_serializes_launches() {
    let rt = Runtime::builder().workers(2).build();
    let prop = rt.trustee(0).entrust(Latch::new(Vec::<usize>::new()));
    let done = Arc::new(AtomicU64::new(0));
    for tag in 0..4usize {
        let p = prop.clone();
        let d = done.clone();
        rt.spawn_on(1, move || {
            p.launch(move |v| {
                v.push(tag);
                trustee::fiber::yield_now();
                v.push(tag);
            });
            d.fetch_add(1, Ordering::AcqRel);
        });
    }
    while done.load(Ordering::Acquire) != 4 {
        std::thread::yield_now();
    }
    let p = prop.clone();
    let v = rt.block_on(1, move || p.apply(|l| l.with_lock(|v| v.clone())));
    assert_eq!(v.len(), 8);
    for pair in v.chunks(2) {
        assert_eq!(pair[0], pair[1], "critical sections interleaved: {v:?}");
    }
    drop(prop);
    rt.shutdown();
}

#[test]
fn large_values_through_channel() {
    let rt = Runtime::builder().workers(2).build();
    let store = rt.trustee(0).entrust(Vec::<Vec<u8>>::new());
    let s2 = store.clone();
    rt.block_on(1, move || {
        // 8 KiB values exercise the heap/spill paths both directions.
        let big = vec![0xCDu8; 8192];
        s2.apply_with(|v, data: Vec<u8>| v.push(data), big.clone());
        let back = s2.apply(|v| v[0].clone());
        assert_eq!(back.len(), 8192);
        assert!(back.iter().all(|&b| b == 0xCD));
    });
    drop(store);
    rt.shutdown();
}

#[test]
fn throughput_sanity_batching_wins() {
    // Async (windowed) delegation must beat sequential blocking round
    // trips between two workers — the transparent-batching claim (§1).
    use trustee::bench::fadd::{run_async, run_trust, FaddConfig};
    let cfg = FaddConfig {
        threads: 1,
        objects: 1,
        ops_per_thread: 4_000,
        dedicated: 1,
        fibers: 1, // sequential blocking
        window: 64,
        ..Default::default()
    };
    let sync1 = run_trust(&cfg);
    let asyncw = run_async(&cfg);
    assert!(
        asyncw.mops() > sync1.mops() * 2.0,
        "windowed async {:.3} MOPs should dwarf sequential sync {:.3} MOPs",
        asyncw.mops(),
        sync1.mops()
    );
}
