//! The allocation-free hot-path contract, enforced (DESIGN.md,
//! "Allocation discipline").
//!
//! This binary installs the counting global allocator and asserts **zero
//! allocation events** across thousands of steady-state delegated
//! operations — for windowed async fetch-add delegation (the paper's
//! §6.1 microworkload), for a KV GET/PUT round trip over the Trust
//! backend (the §6.3 data path), for the memcached-shaped
//! `set_item`/`get_item` round trip (flags + TTL + LRU relinking on the
//! unified item store, the §7 data path), for sustained **over-budget
//! SET churn** (every op a fresh key: miss-insert + LRU-tail eviction,
//! recycled end to end through the item slab's free list, the key-buffer
//! pool, and the size-classed value pools), and for a **one-directional
//! PUT-only stream** (no GET back-traffic to cross-feed free lists —
//! the closed store-side caveat from the pre-slab design). Warmup rounds
//! let every recycled buffer
//! (outbox arena, completion deques, response scratch, table entry)
//! reach its high-water mark first; after that, a single allocation
//! anywhere in the measured window — any worker thread, any layer — is
//! a regression and fails the test.
//!
//! The counters are process-wide, so these tests also keep the
//! *scheduler's* idle paths honest: the serve/poll/reactor/inject/flush
//! phases of both workers run concurrently with the measured fiber and
//! must not allocate either.
//!
//! Two network phases extend the contract to the wire (DESIGN.md,
//! "Kernel-boundary batching"): with connections parked on fd readiness
//! the **idle window is exactly zero** under epoll and io_uring — the
//! reactor poll and uring CQE-harvest scratch vectors are taken, filled,
//! and handed back, never reallocated — and an **active GET/PUT window**
//! over live TCP stays under a documented generous per-op bound for
//! every net policy, guarding against O(idle connections)-per-op blowups.

use std::cell::Cell;
use std::rc::Rc;
use std::sync::Arc;
use trustee::kvstore::backend::{AckCb, AsyncKv, GetCb, GetItemCb, TrustKv};
use trustee::kvstore::store::entry_cost;
use trustee::runtime::Runtime;
use trustee::trust::local_trustee;
use trustee::util::count_alloc::{snapshot, CountingAlloc};
use trustee::{fiber, Trust};

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Windowed async fetch-add driver (the run_async shape): issue
/// `apply_then` increments with `window` outstanding, parking the fiber
/// while the window is full. Returns completions observed.
fn fadd_rounds(ct: &Trust<u64>, ops: u64, window: u64) -> u64 {
    let completed = Rc::new(Cell::new(0u64));
    let parked: Rc<Cell<Option<fiber::FiberId>>> = Rc::new(Cell::new(None));
    let mut issued = 0u64;
    while completed.get() < ops {
        while issued < ops && issued - completed.get() < window {
            let comp = completed.clone();
            let parked2 = parked.clone();
            ct.apply_then(
                |c| {
                    *c += 1;
                    *c
                },
                move |_v| {
                    comp.set(comp.get() + 1);
                    if let Some(id) = parked2.take() {
                        fiber::with_executor(|e| e.resume(id));
                    }
                },
            );
            issued += 1;
        }
        if completed.get() < ops {
            fiber::suspend(|id| parked.set(Some(id)));
        }
    }
    completed.get()
}

/// One test, five phases. The counters are process-wide and the default
/// test harness runs `#[test]` fns concurrently, so separate tests would
/// see each other's setup allocations inside their measured windows;
/// sequential phases in a single test keep every window quiet.
#[test]
fn hot_paths_are_allocation_free_at_steady_state() {
    counting_allocator_counts();
    fetch_add_phase();
    kv_get_put_phase();
    mcd_item_phase();
    eviction_churn_phase();
    one_directional_put_phase();
    net_phases();
}

fn fetch_add_phase() {
    let rt = Runtime::builder().workers(2).build();
    let ct = rt.block_on(0, || local_trustee().entrust(0u64));
    let ct2 = ct.clone();
    let delta = rt.block_on(1, move || {
        // Warmup: grow every recycled buffer to its high-water mark. The
        // warmup window is deliberately *wider* than the measured one so
        // every window-proportional buffer (outbox arena, completion
        // deques) reaches a ceiling the measured phase cannot exceed,
        // regardless of scheduling jitter.
        fadd_rounds(&ct2, 2_000, 128);
        let before = snapshot();
        let done = fadd_rounds(&ct2, 4_000, 64);
        let after = snapshot();
        assert_eq!(done, 4_000);
        after.since(&before)
    });
    assert_eq!(
        delta.allocs, 0,
        "steady-state fetch-add delegation must not allocate \
         ({} allocs / {} bytes across 4000 ops)",
        delta.allocs, delta.bytes
    );
    drop(ct);
    rt.shutdown();
}

/// One GET + one overwriting PUT against a fixed key, window 1 (each op
/// parks the fiber until its completion lands). Returns ops completed.
fn kv_rounds(kv: &TrustKv, rounds: u64) -> u64 {
    let key: &[u8] = b"alloc-regression-key";
    let val = [b'v'; 16];
    let done = Rc::new(Cell::new(0u64));
    let parked: Rc<Cell<Option<fiber::FiberId>>> = Rc::new(Cell::new(None));
    let mut completed = 0u64;
    for i in 0..rounds {
        let d = done.clone();
        let p = parked.clone();
        if i % 2 == 0 {
            kv.put(
                key,
                &val,
                AckCb::new(move |_existed| {
                    d.set(d.get() + 1);
                    if let Some(id) = p.take() {
                        fiber::with_executor(|e| e.resume(id));
                    }
                }),
            );
        } else {
            kv.get(
                key,
                GetCb::new(move |v: Option<&[u8]>| {
                    assert_eq!(v.map(|v| v.len()), Some(16));
                    d.set(d.get() + 1);
                    if let Some(id) = p.take() {
                        fiber::with_executor(|e| e.resume(id));
                    }
                }),
            );
        }
        completed += 1;
        while done.get() < completed {
            fiber::suspend(|id| parked.set(Some(id)));
        }
    }
    done.get()
}

fn kv_get_put_phase() {
    let rt = Runtime::builder().workers(2).build();
    // Shards on worker 0; the measuring fiber runs as a client on 1.
    let kv = TrustKv::new(&rt, &[0], 2);
    let kv2 = kv.clone();
    let delta = rt.block_on(1, move || {
        // Warmup inserts the key (the one productive allocation) and
        // grows every recycled buffer.
        kv_rounds(&kv2, 500);
        let before = snapshot();
        let done = kv_rounds(&kv2, 1_000);
        let after = snapshot();
        assert_eq!(done, 1_000);
        after.since(&before)
    });
    assert_eq!(
        delta.allocs, 0,
        "steady-state KV GET/PUT round trips must not allocate \
         ({} allocs / {} bytes across 1000 ops)",
        delta.allocs, delta.bytes
    );
    drop(kv);
    rt.shutdown();
}

/// The memcached-shaped round trip on the unified item store: one
/// `set_item` (flags + TTL) + one `get_item` (key echo, flags, borrowed
/// value) against a fixed key, window 1. The TTL is far enough out that
/// this key never expires mid-test; each overwrite re-stamps the
/// deadline, relinks the item to the LRU head, and updates the byte
/// accounting — all of which must stay allocation-free.
fn mcd_rounds(kv: &Arc<dyn AsyncKv>, rounds: u64) -> u64 {
    const TTL_MS: u64 = 60 * 60 * 1000;
    let key: &[u8] = b"alloc-regression-mcd-key";
    let val = [b'm'; 16];
    let done = Rc::new(Cell::new(0u64));
    let parked: Rc<Cell<Option<fiber::FiberId>>> = Rc::new(Cell::new(None));
    let mut completed = 0u64;
    for i in 0..rounds {
        let d = done.clone();
        let p = parked.clone();
        if i % 2 == 0 {
            kv.set_item(
                key,
                &val,
                7,
                TTL_MS,
                AckCb::new(move |_existed| {
                    d.set(d.get() + 1);
                    if let Some(id) = p.take() {
                        fiber::with_executor(|e| e.resume(id));
                    }
                }),
            );
        } else {
            kv.get_item(
                key,
                GetItemCb::new(move |k: &[u8], item: Option<(u32, &[u8])>| {
                    assert_eq!(k.len(), 24);
                    let (flags, v) = item.expect("live item");
                    assert_eq!((flags, v.len()), (7, 16));
                    d.set(d.get() + 1);
                    if let Some(id) = p.take() {
                        fiber::with_executor(|e| e.resume(id));
                    }
                }),
            );
        }
        completed += 1;
        while done.get() < completed {
            fiber::suspend(|id| parked.set(Some(id)));
        }
    }
    done.get()
}

fn mcd_item_phase() {
    use trustee::kvstore::BackendKind;
    let rt = Runtime::builder().workers(2).build();
    // Shards on worker 0; the measuring fiber runs as a client on 1.
    // build_with (unlike the bare TrustKv constructor) also installs the
    // maintenance-hook sweep on every worker, so the incremental expiry
    // sweep runs *inside* the measured window and is held to the same
    // zero-alloc bar.
    let kv = BackendKind::Trust { shards: 2 }.build_with(
        &rt,
        &[0],
        &trustee::kvstore::StoreConfig::default(),
    );
    let kv2 = kv.clone();
    let delta = rt.block_on(1, move || {
        // Warmup inserts the measured key and grows every recycled
        // buffer — plus a batch of short-TTL keys that expire under the
        // measured window, so the sweep does real reclamation work in
        // it (reclamation frees; it must never allocate).
        for i in 0..64u64 {
            let done = Rc::new(Cell::new(false));
            let d = done.clone();
            kv2.set_item(
                &[b'x', i as u8],
                b"short-ttl",
                0,
                30, // expires while the measured rounds run
                AckCb::new(move |_| d.set(true)),
            );
            while !done.get() {
                fiber::yield_now();
            }
        }
        mcd_rounds(&kv2, 500);
        let before = snapshot();
        let done = mcd_rounds(&kv2, 1_000);
        let after = snapshot();
        assert_eq!(done, 1_000);
        after.since(&before)
    });
    assert_eq!(
        delta.allocs, 0,
        "steady-state mcd set_item/get_item round trips (with the \
         maintenance sweep active) must not allocate \
         ({} allocs / {} bytes across 1000 ops)",
        delta.allocs, delta.bytes
    );
    drop(kv);
    rt.shutdown();
}

/// Over-budget SET churn, window 1: every op writes a *fresh* 8-byte
/// key (a little-endian counter), so at steady state every SET is a
/// miss-insert that evicts the LRU tail on the owning shard. Insert and
/// evict must recycle end to end through the item slab's free list, the
/// pooled key buffers, and the size-classed value pools.
fn churn_rounds(kv: &Arc<dyn AsyncKv>, start: u64, rounds: u64) -> u64 {
    let val = [b'c'; 16];
    let done = Rc::new(Cell::new(0u64));
    let parked: Rc<Cell<Option<fiber::FiberId>>> = Rc::new(Cell::new(None));
    let mut completed = 0u64;
    for i in 0..rounds {
        let d = done.clone();
        let p = parked.clone();
        let key = (start + i).to_le_bytes();
        kv.set_item(
            &key,
            &val,
            0,
            0,
            AckCb::new(move |_existed| {
                d.set(d.get() + 1);
                if let Some(id) = p.take() {
                    fiber::with_executor(|e| e.resume(id));
                }
            }),
        );
        completed += 1;
        while done.get() < completed {
            fiber::suspend(|id| parked.set(Some(id)));
        }
    }
    done.get()
}

fn eviction_churn_phase() {
    use trustee::kvstore::BackendKind;
    let rt = Runtime::builder().workers(2).build();
    // Budget sized to 40 entries per shard: each 8-byte key + class-16
    // value charges entry_cost(8, 16) bytes, and the total splits evenly
    // over the two shards.
    let per_entry = entry_cost(8, 16);
    let kv = BackendKind::Trust { shards: 2 }.build_with(
        &rt,
        &[0],
        &trustee::kvstore::StoreConfig::with_budget(2 * 40 * per_entry),
    );
    // Warmup fills both shards to their budget and brings every free
    // list (slab slots, key pool, class-16 value pool) to steady state.
    let kv2 = kv.clone();
    rt.block_on(1, move || churn_rounds(&kv2, 0, 1_500));
    let before_stats = kv.store_stats();
    let kv2 = kv.clone();
    let delta = rt.block_on(1, move || {
        let before = snapshot();
        let done = churn_rounds(&kv2, 1_500, 3_000);
        let after = snapshot();
        assert_eq!(done, 3_000);
        after.since(&before)
    });
    let stats = kv.store_stats();
    assert_eq!(
        delta.allocs, 0,
        "steady-state over-budget SET churn must not allocate \
         ({} allocs / {} bytes across 3000 insert+evict ops)",
        delta.allocs, delta.bytes
    );
    // The window must actually churn: with both shards at budget and
    // every key fresh, each measured SET inserts and evicts exactly one
    // LRU tail, served entirely from the value pools.
    let evicted = stats.evictions - before_stats.evictions;
    assert_eq!(
        evicted, 3_000,
        "every measured SET must evict ({before_stats:?} -> {stats:?})"
    );
    assert_eq!(
        stats.slab_misses, before_stats.slab_misses,
        "measured churn must be pool-served ({before_stats:?} -> {stats:?})"
    );
    drop(kv);
    rt.shutdown();
}

/// PUT-only stream, window 1: overwrite a small rotating key set with a
/// class-72 value and never issue a GET. One-directional traffic like
/// this has no response payloads flowing back, so nothing cross-feeds
/// the old heap free lists — the overwrite must recycle the store-side
/// value buffer in place instead.
fn oneway_rounds(kv: &Arc<dyn AsyncKv>, rounds: u64) -> u64 {
    let val = [b'p'; 64];
    let done = Rc::new(Cell::new(0u64));
    let parked: Rc<Cell<Option<fiber::FiberId>>> = Rc::new(Cell::new(None));
    let mut completed = 0u64;
    for i in 0..rounds {
        let d = done.clone();
        let p = parked.clone();
        let key = [b'w', (i % 8) as u8];
        kv.set_item(
            &key,
            &val,
            3,
            0,
            AckCb::new(move |_existed| {
                d.set(d.get() + 1);
                if let Some(id) = p.take() {
                    fiber::with_executor(|e| e.resume(id));
                }
            }),
        );
        completed += 1;
        while done.get() < completed {
            fiber::suspend(|id| parked.set(Some(id)));
        }
    }
    done.get()
}

fn one_directional_put_phase() {
    use trustee::kvstore::BackendKind;
    let rt = Runtime::builder().workers(2).build();
    let kv = BackendKind::Trust { shards: 2 }.build_with(
        &rt,
        &[0],
        &trustee::kvstore::StoreConfig::default(),
    );
    let kv2 = kv.clone();
    let delta = rt.block_on(1, move || {
        // Warmup inserts the 8 keys (the productive allocations) and
        // grows the outbox arena to its PUT-heavy high-water mark.
        oneway_rounds(&kv2, 500);
        let before = snapshot();
        let done = oneway_rounds(&kv2, 1_000);
        let after = snapshot();
        assert_eq!(done, 1_000);
        after.since(&before)
    });
    assert_eq!(
        delta.allocs, 0,
        "a one-directional PUT-heavy stream must not allocate \
         ({} allocs / {} bytes across 1000 ops)",
        delta.allocs, delta.bytes
    );
    drop(kv);
    rt.shutdown();
}

/// The wire-path phases, per policy. The idle-window zero applies to the
/// fd-parking policies (epoll, io_uring); busy-poll idle connections spin
/// by design and are measured by E15, not held to an allocation bar.
fn net_phases() {
    use trustee::kvstore::NetPolicy;
    net_roundtrip_window(NetPolicy::BusyPoll);
    net_idle_window(NetPolicy::Epoll);
    net_roundtrip_window(NetPolicy::Epoll);
    match trustee::runtime::uring::probe() {
        Ok(()) => {
            net_idle_window(NetPolicy::IoUring);
            net_roundtrip_window(NetPolicy::IoUring);
            if !trustee::runtime::uring::dataplane_enabled() {
                eprintln!("SKIP data-plane alloc phase: disabled via TRUSTEE_URING_NO_PBUF");
            } else if let Err(e) = trustee::runtime::uring::probe_pbuf() {
                assert!(
                    std::env::var_os("TRUSTEE_REQUIRE_URING_PBUF").is_none(),
                    "TRUSTEE_REQUIRE_URING_PBUF set but PBUF_RING unavailable: {e}"
                );
                eprintln!("SKIP data-plane alloc phase: PBUF_RING unavailable ({e})");
            } else {
                net_dataplane_window();
            }
        }
        Err(e) => eprintln!("SKIP net alloc phases under uring: io_uring unavailable ({e})"),
    }
}

/// One pipelined burst of 16 PUTs and their acks. PUT-only on purpose:
/// ack frames carry an empty `val`, and `to_vec()` on an empty slice
/// does not allocate, so the *client* half of the measured window is
/// silent too and the bar can be exact zero rather than a per-op bound.
fn tcp_put_burst(
    c: &mut std::net::TcpStream,
    wbuf: &mut Vec<u8>,
    rbuf: &mut Vec<u8>,
    chunk: &mut [u8],
    id: u64,
) {
    use std::io::{Read, Write};
    use trustee::kvstore::proto;
    const BURST: u64 = 16;
    wbuf.clear();
    for k in 0..BURST {
        proto::write_request(wbuf, id + k, proto::OP_PUT, b"dp-alloc-key", b"value-16-bytes!!");
    }
    c.write_all(wbuf).unwrap();
    rbuf.clear();
    let mut cursor = proto::FrameCursor::new();
    let mut got = 0;
    while got < BURST {
        if let Some(r) = cursor.next_response(rbuf).unwrap() {
            assert_eq!((r.status, r.val.len()), (proto::ST_OK, 0));
            got += 1;
            continue;
        }
        let n = c.read(chunk).unwrap();
        assert!(n > 0, "server closed during data-plane alloc window");
        rbuf.extend_from_slice(&chunk[..n]);
    }
}

/// Steady-state provided-buffer RECV + ring SEND, exact zero: once the
/// reactor's `send_active`/`send_next` vectors, the per-connection CQE
/// queue, the engine inbuf, and the spool have hit their high-water
/// marks, a registered connection's ingest→parse→dispatch→egress loop
/// touches only recycled storage — kernel-filled pool buffers in,
/// frozen reactor-owned send buffers out. The window also proves the
/// plane is *engaged*: RECV CQEs and SEND SQEs advance while the
/// server-side `read()`/`write()` counters do not.
fn net_dataplane_window() {
    use trustee::kvstore::NetPolicy;
    use trustee::server::netfiber;
    const BURSTS: u64 = 300;
    let server = net_server(NetPolicy::IoUring);
    let mut c = std::net::TcpStream::connect(server.addr()).unwrap();
    c.set_nodelay(true).ok();
    std::thread::sleep(std::time::Duration::from_millis(100));
    let mut wbuf = Vec::new();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 4096];
    for i in 0..150u64 {
        tcp_put_burst(&mut c, &mut wbuf, &mut rbuf, &mut chunk, 1 + i * 16);
    }
    let stats0 = server.uring_stats();
    let reads0 = netfiber::read_syscalls();
    let writes0 = netfiber::write_syscalls();
    let before = snapshot();
    for i in 0..BURSTS {
        tcp_put_burst(&mut c, &mut wbuf, &mut rbuf, &mut chunk, 10_000 + i * 16);
    }
    let after = snapshot();
    let d = after.since(&before);
    let stats = server.uring_stats();
    assert_eq!(
        d.allocs,
        0,
        "steady-state data-plane RECV/SEND must not allocate \
         ({} allocs / {} bytes across {} pipelined PUTs)",
        d.allocs,
        d.bytes,
        BURSTS * 16
    );
    assert!(
        stats.recv_cqes > stats0.recv_cqes && stats.send_sqes > stats0.send_sqes,
        "measured window must ride the data plane ({stats0:?} -> {stats:?})"
    );
    assert!(
        stats.pbuf_recycled > stats0.pbuf_recycled,
        "consumed pool buffers must be republished ({stats0:?} -> {stats:?})"
    );
    assert_eq!(
        (netfiber::read_syscalls() - reads0, netfiber::write_syscalls() - writes0),
        (0, 0),
        "a registered data-plane connection makes no read/write syscalls"
    );
    drop(c);
    server.stop();
}

fn net_server(net: trustee::kvstore::NetPolicy) -> trustee::kvstore::KvServer {
    use trustee::kvstore::{BackendKind, KvServer, KvServerConfig};
    KvServer::start(KvServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net,
        ..Default::default()
    })
}

/// One pipelined PUT+GET round trip reusing caller-owned buffers, so the
/// *client* side of the measured window allocates only what the protocol
/// cursor itself does.
fn tcp_get_put(
    c: &mut std::net::TcpStream,
    wbuf: &mut Vec<u8>,
    rbuf: &mut Vec<u8>,
    chunk: &mut [u8],
    id: u64,
) {
    use std::io::{Read, Write};
    use trustee::kvstore::proto;
    wbuf.clear();
    proto::write_request(wbuf, id, proto::OP_PUT, b"net-alloc-key", b"value-16-bytes!!");
    proto::write_request(wbuf, id + 1, proto::OP_GET, b"net-alloc-key", &[]);
    c.write_all(wbuf).unwrap();
    rbuf.clear();
    let mut cursor = proto::FrameCursor::new();
    let mut got = 0;
    while got < 2 {
        if let Some(r) = cursor.next_response(rbuf).unwrap() {
            if r.id == id + 1 {
                assert_eq!(r.val.len(), 16);
            }
            got += 1;
            continue;
        }
        let n = c.read(chunk).unwrap();
        assert!(n > 0, "server closed during alloc window");
        rbuf.extend_from_slice(&chunk[..n]);
    }
}

/// Idle network window, exact zero: with every connection fiber parked on
/// fd readiness, both workers keep looping (serve, reactor poll, uring
/// flush/harvest, idle block) and must not allocate — the readiness
/// scratch vectors are recycled through `mem::take`/hand-back, and a CQE
/// or epoll-event batch lands in capacity grown during warmup.
fn net_idle_window(net: trustee::kvstore::NetPolicy) {
    let server = net_server(net);
    let conns: Vec<std::net::TcpStream> = (0..16)
        .map(|_| std::net::TcpStream::connect(server.addr()).unwrap())
        .collect();
    // Let every connection fiber reach its first park and every scratch
    // vector its high-water mark.
    std::thread::sleep(std::time::Duration::from_millis(300));
    let before = snapshot();
    std::thread::sleep(std::time::Duration::from_millis(300));
    let after = snapshot();
    let d = after.since(&before);
    assert_eq!(
        d.allocs,
        0,
        "idle {} network window must not allocate \
         ({} allocs / {} bytes with 16 parked connections)",
        net.label(),
        d.allocs,
        d.bytes
    );
    drop(conns);
    server.stop();
}

/// Active GET/PUT window over live TCP with 64 parked bystanders. The
/// wire path hands owned key/value buffers through the protocol layer,
/// so the bar is a generous per-op bound rather than exact zero: wide
/// enough for the cursor's per-frame buffers on both ends, far below the
/// ≥64-allocs-per-op signature of an O(idle connections) regression.
fn net_roundtrip_window(net: trustee::kvstore::NetPolicy) {
    const OPS: u64 = 400;
    let server = net_server(net);
    let idle: Vec<std::net::TcpStream> = (0..64)
        .map(|_| std::net::TcpStream::connect(server.addr()).unwrap())
        .collect();
    let mut c = std::net::TcpStream::connect(server.addr()).unwrap();
    c.set_nodelay(true).ok();
    std::thread::sleep(std::time::Duration::from_millis(200));
    let mut wbuf = Vec::new();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 4096];
    for i in 0..200u64 {
        tcp_get_put(&mut c, &mut wbuf, &mut rbuf, &mut chunk, i * 2 + 1);
    }
    let before = snapshot();
    for i in 0..OPS {
        tcp_get_put(&mut c, &mut wbuf, &mut rbuf, &mut chunk, 1_000 + i * 2 + 1);
    }
    let after = snapshot();
    let d = after.since(&before);
    let bound = OPS * 16 + 256;
    assert!(
        d.allocs <= bound,
        "GET/PUT window under {} allocated {} times / {} bytes across {OPS} ops \
         (bound {bound}; an O(idle conns)-per-op regression would be >={})",
        net.label(),
        d.allocs,
        d.bytes,
        OPS * 64
    );
    drop((c, idle));
    server.stop();
}

fn counting_allocator_counts() {
    // Sanity for the harness itself: an intentional allocation is seen.
    let before = snapshot();
    let v: Vec<u8> = Vec::with_capacity(4096);
    let after = snapshot();
    std::hint::black_box(&v);
    let d = after.since(&before);
    assert!(d.allocs >= 1, "allocator wrapper must count");
    assert!(d.bytes >= 4096);
}
