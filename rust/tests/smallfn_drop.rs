//! Drop-discipline tests for the inline-storage one-shot closures
//! (`define_inline_fn_once!`, ISSUE 6 satellite). The erased type manages
//! captures through raw storage and manual drop glue, so the contract —
//! captures dropped **exactly once**, whether the closure is called,
//! dropped uncalled, spilled to the heap, or unwound out of — is pinned
//! here with a counting guard. Everything is pure in-memory work, so the
//! whole file runs under Miri.

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::rc::Rc;

trustee::define_inline_fn_once! {
    /// Test subject: erased `FnOnce(u64)` with 24 bytes of inline storage.
    pub struct Cb(v: u64);
    inline_bytes = 24;
}

/// Counting guard: bumps its counter exactly once, from `Drop`.
struct Canary(Rc<Cell<u32>>);

impl Drop for Canary {
    fn drop(&mut self) {
        self.0.set(self.0.get() + 1);
    }
}

fn canary() -> (Rc<Cell<u32>>, Canary) {
    let n = Rc::new(Cell::new(0));
    (Rc::clone(&n), Canary(Rc::clone(&n)))
}

#[test]
fn call_runs_the_closure_and_consumes_captures_once() {
    let (drops, guard) = canary();
    let seen = Rc::new(Cell::new(0u64));
    let seen2 = Rc::clone(&seen);
    let cb = Cb::new(move |v| {
        let _hold = &guard;
        seen2.set(v);
    });
    assert!(cb.is_some());
    assert!(!cb.was_boxed(), "two Rcs must fit the inline buffer");
    assert_eq!(drops.get(), 0, "captures live until the call");
    cb.call(7);
    assert_eq!(seen.get(), 7, "closure body must run with its argument");
    assert_eq!(drops.get(), 1, "captures dropped exactly once by the call");
}

#[test]
fn drop_without_call_drops_captures_once_and_never_runs() {
    let (drops, guard) = canary();
    let ran = Rc::new(Cell::new(false));
    let ran2 = Rc::clone(&ran);
    let cb = Cb::new(move |_| {
        let _hold = &guard;
        ran2.set(true);
    });
    drop(cb);
    assert!(!ran.get(), "an uncalled closure must never run");
    assert_eq!(drops.get(), 1, "uncalled captures dropped exactly once");
}

#[test]
fn oversized_captures_take_the_heap_fallback() {
    // 64 bytes of payload cannot fit 24 inline bytes.
    let (drops, guard) = canary();
    let big = [5u64; 8];
    let seen = Rc::new(Cell::new(0u64));
    let seen2 = Rc::clone(&seen);
    let cb = Cb::new(move |v| {
        let _hold = &guard;
        seen2.set(v + big.iter().sum::<u64>());
    });
    assert!(cb.was_boxed(), "64-byte captures must spill to the heap");
    cb.call(2);
    assert_eq!(seen.get(), 42, "heap-spilled captures must survive intact");
    assert_eq!(drops.get(), 1);

    // And the uncalled heap representation frees its box (Miri's leak
    // checker would flag a lost Box) and drops captures exactly once.
    let (drops, guard) = canary();
    let big = [0u64; 8];
    let cb = Cb::new(move |_| {
        let _hold = (&guard, &big);
    });
    assert!(cb.was_boxed());
    drop(cb);
    assert_eq!(drops.get(), 1, "heap captures dropped exactly once");
}

#[test]
fn over_aligned_captures_take_the_heap_fallback() {
    #[repr(align(16))]
    struct Wide([u8; 16]);
    let (drops, guard) = canary();
    let wide = Wide([3; 16]);
    let cb = Cb::new(move |_| {
        let _hold = (&guard, &wide);
    });
    assert!(cb.was_boxed(), "align > 8 must spill regardless of size");
    cb.call(0);
    assert_eq!(drops.get(), 1);
}

#[test]
fn panic_during_call_drops_captures_exactly_once() {
    // Inline representation.
    let (drops, guard) = canary();
    let cb = Cb::new(move |_| {
        let _hold = &guard;
        panic!("boom");
    });
    let r = catch_unwind(AssertUnwindSafe(|| cb.call(1)));
    assert!(r.is_err(), "the panic must propagate");
    assert_eq!(
        drops.get(),
        1,
        "unwinding out of the call drops captures exactly once"
    );

    // Heap representation.
    let (drops, guard) = canary();
    let big = [0u64; 8];
    let cb = Cb::new(move |_| {
        let _hold = (&guard, &big);
        panic!("boom");
    });
    assert!(cb.was_boxed());
    let r = catch_unwind(AssertUnwindSafe(|| cb.call(1)));
    assert!(r.is_err());
    assert_eq!(drops.get(), 1, "heap captures dropped exactly once on unwind");
}

#[test]
fn none_is_inert() {
    let cb = Cb::none();
    assert!(cb.is_none());
    assert!(!cb.was_boxed());
    cb.call(9); // no-op, must not touch uninitialized storage
    drop(Cb::none()); // dropping the empty value is a no-op too
}
