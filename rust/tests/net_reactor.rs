//! Integration tests for the epoll readiness reactor (DESIGN.md "Network
//! reactor"): servers running with `NetPolicy::Epoll` park idle connection
//! fibers on fd readiness instead of re-polling every scheduler tick, the
//! acceptor is a fiber on the same epoll instance (no sleep-poll thread),
//! and teardown wakes every parked fiber. The E15 bench
//! (`benches/net_idle_conns.rs`) measures the latency effect; these tests
//! pin down the functional contract on any hardware.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use trustee::kvstore::{proto, BackendKind, KvServer, KvServerConfig, NetPolicy};
use trustee::memcache::{McdServer, McdServerConfig};
use trustee::server::{RespServer, RespServerConfig};

fn kv_server(net: NetPolicy, workers: usize, dedicated: usize) -> KvServer {
    KvServer::start(KvServerConfig {
        workers,
        dedicated,
        backend: BackendKind::Trust { shards: 2 },
        net,
        ..Default::default()
    })
}

fn kv_roundtrip(c: &mut TcpStream, id: u64, key: &[u8], val: &[u8]) {
    let mut buf = Vec::new();
    proto::write_request(&mut buf, id, proto::OP_PUT, key, val);
    proto::write_request(&mut buf, id + 1, proto::OP_GET, key, &[]);
    c.write_all(&buf).unwrap();
    let mut cursor = proto::FrameCursor::new();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut got = 0;
    while got < 2 {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            if r.id == id + 1 {
                assert_eq!(r.val, val);
            }
            got += 1;
            continue;
        }
        let n = c.read(&mut chunk).unwrap();
        assert!(n > 0, "server closed early");
        rbuf.extend_from_slice(&chunk[..n]);
    }
}

#[test]
fn epoll_server_serves_and_stops_cleanly() {
    let server = kv_server(NetPolicy::Epoll, 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    for i in 0..20u64 {
        kv_roundtrip(&mut c, i * 2 + 1, format!("k{i}").as_bytes(), b"value");
    }
    assert_eq!(server.ops_served.load(Ordering::Relaxed), 40);
    drop(c);
    // Stop must wake the fd-parked acceptor fiber and exit promptly.
    let t0 = std::time::Instant::now();
    server.stop();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(10),
        "shutdown took {:?} — fd-parked fibers not woken?",
        t0.elapsed()
    );
}

#[test]
fn epoll_acceptor_handles_connection_churn() {
    // The acceptor fiber parks on listener readability between accepts;
    // every new connection must wake it, including bursts.
    let server = kv_server(NetPolicy::Epoll, 2, 0);
    for round in 0..10u64 {
        let mut conns: Vec<TcpStream> = (0..5)
            .map(|_| TcpStream::connect(server.addr()).unwrap())
            .collect();
        for (i, c) in conns.iter_mut().enumerate() {
            kv_roundtrip(c, 1, format!("r{round}c{i}").as_bytes(), b"x");
        }
        // All dropped: connection fibers must drain and exit.
    }
    server.stop();
}

#[test]
fn idle_connections_park_instead_of_spinning() {
    let server = kv_server(NetPolicy::Epoll, 2, 0);
    // 32 connections sit idle; one keeps working. If the idle ones were
    // busy-polled they would each be re-read every tick — with the
    // reactor they park, and traffic on the active one still flows.
    let idle: Vec<TcpStream> = (0..32)
        .map(|_| TcpStream::connect(server.addr()).unwrap())
        .collect();
    let mut active = TcpStream::connect(server.addr()).unwrap();
    // Let the idle fibers reach their first park.
    std::thread::sleep(std::time::Duration::from_millis(100));
    for i in 0..50u64 {
        kv_roundtrip(&mut active, i * 2 + 1, b"hot", b"value");
    }
    // Idle connections are still usable afterwards (wake on readiness).
    for (i, mut c) in idle.into_iter().enumerate() {
        if i % 8 == 0 {
            kv_roundtrip(&mut c, 1, format!("idle{i}").as_bytes(), b"woke");
        }
    }
    drop(active);
    server.stop();
}

#[test]
fn epoll_with_dedicated_trustees() {
    let server = kv_server(NetPolicy::Epoll, 3, 1);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    kv_roundtrip(&mut c, 1, b"a", b"b");
    drop(c);
    server.stop();
}

#[test]
fn busy_poll_policy_still_works() {
    // The A/B baseline stays functional behind the flag.
    let server = kv_server(NetPolicy::BusyPoll, 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    kv_roundtrip(&mut c, 1, b"bp", b"val");
    drop(c);
    server.stop();
}

#[test]
fn memcache_under_epoll_roundtrips() {
    let server = McdServer::start(McdServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net: NetPolicy::Epoll,
        ..Default::default()
    });
    let mut c = TcpStream::connect(server.addr()).unwrap();
    // Idle a moment first: the fiber parks, then must wake on our bytes.
    std::thread::sleep(std::time::Duration::from_millis(50));
    c.write_all(b"set greeting 5 0 5\r\nhello\r\n").unwrap();
    let mut reader = BufReader::new(c.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line, "STORED\r\n");
    c.write_all(b"get greeting\r\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line, "VALUE greeting 5 5\r\n");
    drop((c, reader));
    server.stop();
}

#[test]
fn resp_under_epoll_roundtrips() {
    // Third protocol on the shared core: the RESP front end must obey the
    // same park/wake contract as the KV and memcached servers.
    let server = RespServer::start(RespServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net: NetPolicy::Epoll,
        ..Default::default()
    });
    let mut c = TcpStream::connect(server.addr()).unwrap();
    // Idle a moment first: the fiber parks, then must wake on our bytes.
    std::thread::sleep(std::time::Duration::from_millis(50));
    c.write_all(b"SET greeting hello\r\n").unwrap();
    let mut got = vec![0u8; 5];
    c.read_exact(&mut got).unwrap();
    assert_eq!(&got, b"+OK\r\n");
    c.write_all(b"GET greeting\r\n").unwrap();
    let mut got = vec![0u8; 11];
    c.read_exact(&mut got).unwrap();
    assert_eq!(&got[..], &b"$5\r\nhello\r\n"[..]);
    drop(c);
    server.stop();
}

/// Gate a uring test on kernel capability, with a visible skip reason.
/// CI sets `TRUSTEE_REQUIRE_URING=1` on kernels known to support it so a
/// probe regression fails loudly instead of silently skipping.
fn uring_or_skip(test: &str) -> bool {
    match trustee::runtime::uring::probe() {
        Ok(()) => true,
        Err(e) => {
            assert!(
                std::env::var_os("TRUSTEE_REQUIRE_URING").is_none(),
                "TRUSTEE_REQUIRE_URING set but io_uring unavailable: {e}"
            );
            eprintln!("SKIP {test}: io_uring unavailable ({e})");
            false
        }
    }
}

#[test]
fn uring_server_serves_and_stops_cleanly() {
    if !uring_or_skip("uring_server_serves_and_stops_cleanly") {
        return;
    }
    let server = kv_server(NetPolicy::IoUring, 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    for i in 0..20u64 {
        kv_roundtrip(&mut c, i * 2 + 1, format!("k{i}").as_bytes(), b"value");
    }
    assert_eq!(server.ops_served.load(Ordering::Relaxed), 40);
    // The traffic really rode the ring: parks staged SQEs and the
    // scheduler submitted them (batched — flushes, not per-park enters).
    let stats = server.uring_stats();
    assert!(stats.enters > 0, "no io_uring_enter recorded: {stats:?}");
    assert!(stats.sqes_submitted > 0, "no SQEs submitted: {stats:?}");
    assert!(stats.cqes_harvested > 0, "no CQEs harvested: {stats:?}");
    drop(c);
    let t0 = std::time::Instant::now();
    server.stop();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(10),
        "shutdown took {:?} — uring-parked fibers not swept?",
        t0.elapsed()
    );
}

#[test]
fn uring_acceptor_handles_connection_churn() {
    if !uring_or_skip("uring_acceptor_handles_connection_churn") {
        return;
    }
    // One multishot-accept SQE must serve connection bursts and survive
    // churn (kernel re-arms internally; the fiber re-arms after !F_MORE).
    let server = kv_server(NetPolicy::IoUring, 2, 0);
    for round in 0..10u64 {
        let mut conns: Vec<TcpStream> = (0..5)
            .map(|_| TcpStream::connect(server.addr()).unwrap())
            .collect();
        for (i, c) in conns.iter_mut().enumerate() {
            kv_roundtrip(c, 1, format!("r{round}c{i}").as_bytes(), b"x");
        }
    }
    server.stop();
}

#[test]
fn uring_idle_connections_park_instead_of_spinning() {
    if !uring_or_skip("uring_idle_connections_park_instead_of_spinning") {
        return;
    }
    let server = kv_server(NetPolicy::IoUring, 2, 0);
    let idle: Vec<TcpStream> = (0..32)
        .map(|_| TcpStream::connect(server.addr()).unwrap())
        .collect();
    let mut active = TcpStream::connect(server.addr()).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(100));
    for i in 0..50u64 {
        kv_roundtrip(&mut active, i * 2 + 1, b"hot", b"value");
    }
    // Parked-idle connections must still wake on readiness afterwards.
    for (i, mut c) in idle.into_iter().enumerate() {
        if i % 8 == 0 {
            kv_roundtrip(&mut c, 1, format!("idle{i}").as_bytes(), b"woke");
        }
    }
    drop(active);
    server.stop();
}

#[test]
fn memcache_under_uring_roundtrips() {
    if !uring_or_skip("memcache_under_uring_roundtrips") {
        return;
    }
    let server = McdServer::start(McdServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net: NetPolicy::IoUring,
        ..Default::default()
    });
    let mut c = TcpStream::connect(server.addr()).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(50));
    c.write_all(b"set greeting 5 0 5\r\nhello\r\n").unwrap();
    let mut reader = BufReader::new(c.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line, "STORED\r\n");
    c.write_all(b"get greeting\r\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line, "VALUE greeting 5 5\r\n");
    drop((c, reader));
    server.stop();
}

#[test]
fn resp_under_uring_roundtrips() {
    if !uring_or_skip("resp_under_uring_roundtrips") {
        return;
    }
    let server = RespServer::start(RespServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net: NetPolicy::IoUring,
        ..Default::default()
    });
    let mut c = TcpStream::connect(server.addr()).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(50));
    c.write_all(b"SET greeting hello\r\n").unwrap();
    let mut got = vec![0u8; 5];
    c.read_exact(&mut got).unwrap();
    assert_eq!(&got, b"+OK\r\n");
    c.write_all(b"GET greeting\r\n").unwrap();
    let mut got = vec![0u8; 11];
    c.read_exact(&mut got).unwrap();
    assert_eq!(&got[..], &b"$5\r\nhello\r\n"[..]);
    drop(c);
    server.stop();
}

#[test]
fn uring_trickled_bytes_wake_the_parked_fiber_each_time() {
    if !uring_or_skip("uring_trickled_bytes_wake_the_parked_fiber_each_time") {
        return;
    }
    // Every byte arrival must complete the oneshot poll, wake the fiber,
    // and the next park must stage (and batch-submit) a fresh SQE.
    let server = kv_server(NetPolicy::IoUring, 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    c.set_nodelay(true).unwrap();
    let mut buf = Vec::new();
    proto::write_request(&mut buf, 42, proto::OP_PUT, b"slow", b"drip");
    for b in &buf {
        c.write_all(std::slice::from_ref(b)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let mut cursor = proto::FrameCursor::new();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 1024];
    let resp = loop {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            break r;
        }
        let n = c.read(&mut chunk).unwrap();
        assert!(n > 0);
        rbuf.extend_from_slice(&chunk[..n]);
    };
    assert_eq!((resp.id, resp.status), (42, proto::ST_OK));
    drop(c);
    server.stop();
}

#[test]
fn slow_trickled_bytes_wake_the_parked_fiber_each_time() {
    // A request delivered one byte at a time: the fiber parks between
    // bytes and must be woken by each arrival until the frame completes.
    let server = kv_server(NetPolicy::Epoll, 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    c.set_nodelay(true).unwrap();
    let mut buf = Vec::new();
    proto::write_request(&mut buf, 42, proto::OP_PUT, b"slow", b"drip");
    for b in &buf {
        c.write_all(std::slice::from_ref(b)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let mut cursor = proto::FrameCursor::new();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 1024];
    let resp = loop {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            break r;
        }
        let n = c.read(&mut chunk).unwrap();
        assert!(n > 0);
        rbuf.extend_from_slice(&chunk[..n]);
    };
    assert_eq!((resp.id, resp.status), (42, proto::ST_OK));
    drop(c);
    server.stop();
}
