//! Integration tests for the epoll readiness reactor (DESIGN.md "Network
//! reactor"): servers running with `NetPolicy::Epoll` park idle connection
//! fibers on fd readiness instead of re-polling every scheduler tick, the
//! acceptor is a fiber on the same epoll instance (no sleep-poll thread),
//! and teardown wakes every parked fiber. The E15 bench
//! (`benches/net_idle_conns.rs`) measures the latency effect; these tests
//! pin down the functional contract on any hardware.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use trustee::kvstore::{proto, BackendKind, KvServer, KvServerConfig, NetPolicy};
use trustee::memcache::{McdServer, McdServerConfig};
use trustee::server::{RespServer, RespServerConfig};

fn kv_server(net: NetPolicy, workers: usize, dedicated: usize) -> KvServer {
    KvServer::start(KvServerConfig {
        workers,
        dedicated,
        backend: BackendKind::Trust { shards: 2 },
        net,
        ..Default::default()
    })
}

fn kv_roundtrip(c: &mut TcpStream, id: u64, key: &[u8], val: &[u8]) {
    let mut buf = Vec::new();
    proto::write_request(&mut buf, id, proto::OP_PUT, key, val);
    proto::write_request(&mut buf, id + 1, proto::OP_GET, key, &[]);
    c.write_all(&buf).unwrap();
    let mut cursor = proto::FrameCursor::new();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut got = 0;
    while got < 2 {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            if r.id == id + 1 {
                assert_eq!(r.val, val);
            }
            got += 1;
            continue;
        }
        let n = c.read(&mut chunk).unwrap();
        assert!(n > 0, "server closed early");
        rbuf.extend_from_slice(&chunk[..n]);
    }
}

#[test]
fn epoll_server_serves_and_stops_cleanly() {
    let server = kv_server(NetPolicy::Epoll, 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    for i in 0..20u64 {
        kv_roundtrip(&mut c, i * 2 + 1, format!("k{i}").as_bytes(), b"value");
    }
    assert_eq!(server.ops_served.load(Ordering::Relaxed), 40);
    drop(c);
    // Stop must wake the fd-parked acceptor fiber and exit promptly.
    let t0 = std::time::Instant::now();
    server.stop();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(10),
        "shutdown took {:?} — fd-parked fibers not woken?",
        t0.elapsed()
    );
}

#[test]
fn epoll_acceptor_handles_connection_churn() {
    // The acceptor fiber parks on listener readability between accepts;
    // every new connection must wake it, including bursts.
    let server = kv_server(NetPolicy::Epoll, 2, 0);
    for round in 0..10u64 {
        let mut conns: Vec<TcpStream> = (0..5)
            .map(|_| TcpStream::connect(server.addr()).unwrap())
            .collect();
        for (i, c) in conns.iter_mut().enumerate() {
            kv_roundtrip(c, 1, format!("r{round}c{i}").as_bytes(), b"x");
        }
        // All dropped: connection fibers must drain and exit.
    }
    server.stop();
}

#[test]
fn idle_connections_park_instead_of_spinning() {
    let server = kv_server(NetPolicy::Epoll, 2, 0);
    // 32 connections sit idle; one keeps working. If the idle ones were
    // busy-polled they would each be re-read every tick — with the
    // reactor they park, and traffic on the active one still flows.
    let idle: Vec<TcpStream> = (0..32)
        .map(|_| TcpStream::connect(server.addr()).unwrap())
        .collect();
    let mut active = TcpStream::connect(server.addr()).unwrap();
    // Let the idle fibers reach their first park.
    std::thread::sleep(std::time::Duration::from_millis(100));
    for i in 0..50u64 {
        kv_roundtrip(&mut active, i * 2 + 1, b"hot", b"value");
    }
    // Idle connections are still usable afterwards (wake on readiness).
    for (i, mut c) in idle.into_iter().enumerate() {
        if i % 8 == 0 {
            kv_roundtrip(&mut c, 1, format!("idle{i}").as_bytes(), b"woke");
        }
    }
    drop(active);
    server.stop();
}

#[test]
fn epoll_with_dedicated_trustees() {
    let server = kv_server(NetPolicy::Epoll, 3, 1);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    kv_roundtrip(&mut c, 1, b"a", b"b");
    drop(c);
    server.stop();
}

#[test]
fn busy_poll_policy_still_works() {
    // The A/B baseline stays functional behind the flag.
    let server = kv_server(NetPolicy::BusyPoll, 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    kv_roundtrip(&mut c, 1, b"bp", b"val");
    drop(c);
    server.stop();
}

#[test]
fn memcache_under_epoll_roundtrips() {
    let server = McdServer::start(McdServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net: NetPolicy::Epoll,
        ..Default::default()
    });
    let mut c = TcpStream::connect(server.addr()).unwrap();
    // Idle a moment first: the fiber parks, then must wake on our bytes.
    std::thread::sleep(std::time::Duration::from_millis(50));
    c.write_all(b"set greeting 5 0 5\r\nhello\r\n").unwrap();
    let mut reader = BufReader::new(c.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line, "STORED\r\n");
    c.write_all(b"get greeting\r\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line, "VALUE greeting 5 5\r\n");
    drop((c, reader));
    server.stop();
}

#[test]
fn resp_under_epoll_roundtrips() {
    // Third protocol on the shared core: the RESP front end must obey the
    // same park/wake contract as the KV and memcached servers.
    let server = RespServer::start(RespServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net: NetPolicy::Epoll,
        ..Default::default()
    });
    let mut c = TcpStream::connect(server.addr()).unwrap();
    // Idle a moment first: the fiber parks, then must wake on our bytes.
    std::thread::sleep(std::time::Duration::from_millis(50));
    c.write_all(b"SET greeting hello\r\n").unwrap();
    let mut got = vec![0u8; 5];
    c.read_exact(&mut got).unwrap();
    assert_eq!(&got, b"+OK\r\n");
    c.write_all(b"GET greeting\r\n").unwrap();
    let mut got = vec![0u8; 11];
    c.read_exact(&mut got).unwrap();
    assert_eq!(&got[..], &b"$5\r\nhello\r\n"[..]);
    drop(c);
    server.stop();
}

/// Gate a uring test on kernel capability, with a visible skip reason.
/// CI sets `TRUSTEE_REQUIRE_URING=1` on kernels known to support it so a
/// probe regression fails loudly instead of silently skipping.
fn uring_or_skip(test: &str) -> bool {
    match trustee::runtime::uring::probe() {
        Ok(()) => true,
        Err(e) => {
            assert!(
                std::env::var_os("TRUSTEE_REQUIRE_URING").is_none(),
                "TRUSTEE_REQUIRE_URING set but io_uring unavailable: {e}"
            );
            eprintln!("SKIP {test}: io_uring unavailable ({e})");
            false
        }
    }
}

#[test]
fn uring_server_serves_and_stops_cleanly() {
    if !uring_or_skip("uring_server_serves_and_stops_cleanly") {
        return;
    }
    let server = kv_server(NetPolicy::IoUring, 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    for i in 0..20u64 {
        kv_roundtrip(&mut c, i * 2 + 1, format!("k{i}").as_bytes(), b"value");
    }
    assert_eq!(server.ops_served.load(Ordering::Relaxed), 40);
    // The traffic really rode the ring: parks staged SQEs and the
    // scheduler submitted them (batched — flushes, not per-park enters).
    let stats = server.uring_stats();
    assert!(stats.enters > 0, "no io_uring_enter recorded: {stats:?}");
    assert!(stats.sqes_submitted > 0, "no SQEs submitted: {stats:?}");
    assert!(stats.cqes_harvested > 0, "no CQEs harvested: {stats:?}");
    drop(c);
    let t0 = std::time::Instant::now();
    server.stop();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(10),
        "shutdown took {:?} — uring-parked fibers not swept?",
        t0.elapsed()
    );
}

#[test]
fn uring_acceptor_handles_connection_churn() {
    if !uring_or_skip("uring_acceptor_handles_connection_churn") {
        return;
    }
    // One multishot-accept SQE must serve connection bursts and survive
    // churn (kernel re-arms internally; the fiber re-arms after !F_MORE).
    let server = kv_server(NetPolicy::IoUring, 2, 0);
    for round in 0..10u64 {
        let mut conns: Vec<TcpStream> = (0..5)
            .map(|_| TcpStream::connect(server.addr()).unwrap())
            .collect();
        for (i, c) in conns.iter_mut().enumerate() {
            kv_roundtrip(c, 1, format!("r{round}c{i}").as_bytes(), b"x");
        }
    }
    server.stop();
}

#[test]
fn uring_idle_connections_park_instead_of_spinning() {
    if !uring_or_skip("uring_idle_connections_park_instead_of_spinning") {
        return;
    }
    let server = kv_server(NetPolicy::IoUring, 2, 0);
    let idle: Vec<TcpStream> = (0..32)
        .map(|_| TcpStream::connect(server.addr()).unwrap())
        .collect();
    let mut active = TcpStream::connect(server.addr()).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(100));
    for i in 0..50u64 {
        kv_roundtrip(&mut active, i * 2 + 1, b"hot", b"value");
    }
    // Parked-idle connections must still wake on readiness afterwards.
    for (i, mut c) in idle.into_iter().enumerate() {
        if i % 8 == 0 {
            kv_roundtrip(&mut c, 1, format!("idle{i}").as_bytes(), b"woke");
        }
    }
    drop(active);
    server.stop();
}

#[test]
fn memcache_under_uring_roundtrips() {
    if !uring_or_skip("memcache_under_uring_roundtrips") {
        return;
    }
    let server = McdServer::start(McdServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net: NetPolicy::IoUring,
        ..Default::default()
    });
    let mut c = TcpStream::connect(server.addr()).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(50));
    c.write_all(b"set greeting 5 0 5\r\nhello\r\n").unwrap();
    let mut reader = BufReader::new(c.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line, "STORED\r\n");
    c.write_all(b"get greeting\r\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(line, "VALUE greeting 5 5\r\n");
    drop((c, reader));
    server.stop();
}

#[test]
fn resp_under_uring_roundtrips() {
    if !uring_or_skip("resp_under_uring_roundtrips") {
        return;
    }
    let server = RespServer::start(RespServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net: NetPolicy::IoUring,
        ..Default::default()
    });
    let mut c = TcpStream::connect(server.addr()).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(50));
    c.write_all(b"SET greeting hello\r\n").unwrap();
    let mut got = vec![0u8; 5];
    c.read_exact(&mut got).unwrap();
    assert_eq!(&got, b"+OK\r\n");
    c.write_all(b"GET greeting\r\n").unwrap();
    let mut got = vec![0u8; 11];
    c.read_exact(&mut got).unwrap();
    assert_eq!(&got[..], &b"$5\r\nhello\r\n"[..]);
    drop(c);
    server.stop();
}

#[test]
fn uring_trickled_bytes_wake_the_parked_fiber_each_time() {
    if !uring_or_skip("uring_trickled_bytes_wake_the_parked_fiber_each_time") {
        return;
    }
    // Every byte arrival must complete the oneshot poll, wake the fiber,
    // and the next park must stage (and batch-submit) a fresh SQE.
    let server = kv_server(NetPolicy::IoUring, 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    c.set_nodelay(true).unwrap();
    let mut buf = Vec::new();
    proto::write_request(&mut buf, 42, proto::OP_PUT, b"slow", b"drip");
    for b in &buf {
        c.write_all(std::slice::from_ref(b)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let mut cursor = proto::FrameCursor::new();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 1024];
    let resp = loop {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            break r;
        }
        let n = c.read(&mut chunk).unwrap();
        assert!(n > 0);
        rbuf.extend_from_slice(&chunk[..n]);
    };
    assert_eq!((resp.id, resp.status), (42, proto::ST_OK));
    drop(c);
    server.stop();
}

/// Gate a *data-plane* test on PBUF_RING capability (and the
/// `TRUSTEE_URING_NO_PBUF` kill switch), with a visible skip reason.
/// `TRUSTEE_REQUIRE_URING_PBUF=1` (CI on capable kernels) turns the skip
/// into a failure so a probe regression cannot silently hide the plane.
fn pbuf_or_skip(test: &str) -> bool {
    if !uring_or_skip(test) {
        return false;
    }
    if !trustee::runtime::uring::dataplane_enabled() {
        eprintln!("SKIP {test}: data plane disabled via TRUSTEE_URING_NO_PBUF");
        return false;
    }
    match trustee::runtime::uring::probe_pbuf() {
        Ok(()) => true,
        Err(e) => {
            assert!(
                std::env::var_os("TRUSTEE_REQUIRE_URING_PBUF").is_none(),
                "TRUSTEE_REQUIRE_URING_PBUF set but PBUF_RING unavailable: {e}"
            );
            eprintln!("SKIP {test}: io_uring provided buffers unavailable ({e})");
            false
        }
    }
}

#[test]
fn dataplane_pipelined_whole_frames_ride_provided_buffers() {
    if !pbuf_or_skip("dataplane_pipelined_whole_frames_ride_provided_buffers") {
        return;
    }
    // Pipelined complete frames arrive in kernel-filled provided buffers
    // and parse in place (the whole-frame fast path): the server's RECV
    // CQE and ring-SEND counters must move, and every consumed buffer
    // must be recycled back to the pool while connections are alive.
    let server = kv_server(NetPolicy::IoUring, 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    // Batches of pipelined PUT+GET pairs — multiple frames per segment.
    for round in 0..10u64 {
        let mut buf = Vec::new();
        for i in 0..8u64 {
            let id = round * 100 + i * 2 + 1;
            proto::write_request(&mut buf, id, proto::OP_PUT, format!("k{i}").as_bytes(), b"v");
            proto::write_request(&mut buf, id + 1, proto::OP_GET, format!("k{i}").as_bytes(), &[]);
        }
        c.write_all(&buf).unwrap();
        let mut cursor = proto::FrameCursor::new();
        let mut rbuf = Vec::new();
        let mut chunk = [0u8; 4096];
        let mut got = 0;
        while got < 16 {
            if let Some(_r) = cursor.next_response(&rbuf).unwrap() {
                got += 1;
                continue;
            }
            let n = c.read(&mut chunk).unwrap();
            assert!(n > 0, "server closed early");
            rbuf.extend_from_slice(&chunk[..n]);
        }
    }
    assert_eq!(server.ops_served.load(Ordering::Relaxed), 160);
    let stats = server.uring_stats();
    assert!(stats.recv_cqes > 0, "ingest did not ride the data plane: {stats:?}");
    assert!(stats.send_sqes > 0, "egress did not ride ring SENDs: {stats:?}");
    assert!(stats.pbuf_recycled > 0, "no provided buffers recycled: {stats:?}");
    assert!(
        stats.pbuf_recycled <= stats.recv_cqes,
        "recycled more buffers than RECV CQEs delivered: {stats:?}"
    );
    drop(c);
    server.stop();
}

#[test]
fn dataplane_partial_frames_take_the_copy_once_path() {
    if !pbuf_or_skip("dataplane_partial_frames_take_the_copy_once_path") {
        return;
    }
    // A frame split across provided-buffer segments: the engine copies
    // the partial tail into the owned buffer exactly once per detach and
    // completes the parse when the rest arrives.
    let server = kv_server(NetPolicy::IoUring, 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    c.set_nodelay(true).unwrap();
    let mut buf = Vec::new();
    proto::write_request(&mut buf, 7, proto::OP_PUT, b"split", &vec![b'p'; 600]);
    // Three chunks with pauses: each lands as its own RECV CQE, so the
    // first two leave a partial frame behind (detach → copy-once).
    for part in buf.chunks(buf.len() / 3 + 1) {
        c.write_all(part).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    let mut cursor = proto::FrameCursor::new();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 1024];
    let resp = loop {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            break r;
        }
        let n = c.read(&mut chunk).unwrap();
        assert!(n > 0);
        rbuf.extend_from_slice(&chunk[..n]);
    };
    assert_eq!((resp.id, resp.status), (7, proto::ST_OK));
    // Readback proves the reassembled body was stored intact.
    kv_roundtrip(&mut c, 100, b"check", b"after-split");
    let stats = server.uring_stats();
    assert!(stats.recv_cqes >= 3, "split delivery should take >= 3 RECV CQEs: {stats:?}");
    drop(c);
    server.stop();
}

#[test]
fn dataplane_enobufs_starvation_recovers_at_the_wire() {
    if !pbuf_or_skip("dataplane_enobufs_starvation_recovers_at_the_wire") {
        return;
    }
    // Replenish-withheld backpressure, proven at the wire: a client that
    // pipelines large-response GETs while never reading closes the
    // dispatch gate (spool + in-flight SEND bytes at MAX_OUTBUF), then
    // keeps writing until the unparsed backlog passes MAX_INBUF — the
    // fiber stops taking (and so stops recycling) provided buffers, the
    // pool drains, and RECV terminates with ENOBUFS. When the client
    // finally reads, settles reopen the cascade and the starved RECV is
    // re-armed from the recycle path: every response must come back
    // byte-correct and the counters must show the starvation.
    let server = kv_server(NetPolicy::IoUring, 1, 0);
    {
        // Prefill one 256 KiB value through a throwaway connection.
        let mut p = TcpStream::connect(server.addr()).unwrap();
        kv_roundtrip(&mut p, 1, b"big", &vec![b'B'; 256 * 1024]);
    }
    let mut c = TcpStream::connect(server.addr()).unwrap();
    c.set_nonblocking(true).unwrap();
    // Phase 1: request ~16 MiB of responses without reading any (64 GETs
    // x 256 KiB floods spool + reactor past MAX_OUTBUF on both sides).
    let mut reqs = Vec::new();
    for i in 0..64u64 {
        proto::write_request(&mut reqs, 1000 + i, proto::OP_GET, b"big", &[]);
    }
    // Phase 2: filler the server must *buffer unparsed* while the gate
    // is closed — large PUTs (tiny ACK responses) totalling well past
    // MAX_INBUF plus the whole provided pool plus any plausible socket
    // buffer autotuning, so the pool must drain.
    for i in 0..16u64 {
        proto::write_request(
            &mut reqs,
            2000 + i,
            proto::OP_PUT,
            b"fill",
            &vec![b'f'; (1 << 20) - 64],
        );
    }
    // Nonblocking writes until the kernel refuses: the server has by
    // then absorbed MAX_INBUF + the pool and stopped taking.
    let mut written = 0;
    let mut stalled = 0;
    while written < reqs.len() && stalled < 200 {
        match c.write(&reqs[written..]) {
            Ok(n) => {
                written += n;
                stalled = 0;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                stalled += 1;
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => panic!("write failed mid-flood: {e}"),
        }
    }
    // Phase 3: drain everything. Every GET must return the exact value,
    // every PUT must ack — a single corrupted or dropped response means
    // the starvation path lost data.
    c.set_nonblocking(false).unwrap();
    c.set_read_timeout(Some(std::time::Duration::from_secs(30))).unwrap();
    let writer = std::thread::spawn({
        let mut c2 = c.try_clone().unwrap();
        let rest = reqs[written..].to_vec();
        move || {
            // Finish the flood (blocking) while the reader drains.
            c2.write_all(&rest).unwrap();
        }
    });
    let mut cursor = proto::FrameCursor::new();
    let mut rbuf = Vec::new();
    let mut chunk = vec![0u8; 64 * 1024];
    let mut gets = 0u64;
    let mut puts = 0u64;
    while gets + puts < 80 {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            if (1000..2000).contains(&r.id) {
                assert_eq!(r.status, proto::ST_OK, "GET {} failed", r.id);
                assert_eq!(r.val.len(), 256 * 1024, "GET {} returned a torn value", r.id);
                assert!(r.val.iter().all(|&b| b == b'B'), "GET {} corrupted", r.id);
                gets += 1;
            } else {
                assert_eq!(r.status, proto::ST_OK, "PUT {} failed", r.id);
                puts += 1;
            }
            continue;
        }
        proto::compact(&mut rbuf, &mut cursor);
        let n = c.read(&mut chunk).expect("drain read timed out");
        assert!(n > 0, "server closed during drain");
        rbuf.extend_from_slice(&chunk[..n]);
    }
    writer.join().unwrap();
    assert_eq!((gets, puts), (64, 16));
    let stats = server.uring_stats();
    assert!(
        stats.enobufs > 0,
        "the flood never starved the provided pool (ENOBUFS): {stats:?}"
    );
    assert!(stats.recv_cqes > 0 && stats.pbuf_recycled > 0, "{stats:?}");
    drop(c);
    server.stop();
}

#[test]
fn slow_trickled_bytes_wake_the_parked_fiber_each_time() {
    // A request delivered one byte at a time: the fiber parks between
    // bytes and must be woken by each arrival until the frame completes.
    let server = kv_server(NetPolicy::Epoll, 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    c.set_nodelay(true).unwrap();
    let mut buf = Vec::new();
    proto::write_request(&mut buf, 42, proto::OP_PUT, b"slow", b"drip");
    for b in &buf {
        c.write_all(std::slice::from_ref(b)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let mut cursor = proto::FrameCursor::new();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 1024];
    let resp = loop {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            break r;
        }
        let n = c.read(&mut chunk).unwrap();
        assert!(n > 0);
        rbuf.extend_from_slice(&chunk[..n]);
    };
    assert_eq!((resp.id, resp.status), (42, proto::ST_OK));
    drop(c);
    server.stop();
}
