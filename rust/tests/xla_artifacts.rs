//! Integration: the AOT bridge end to end — load `artifacts/*.hlo.txt`
//! (produced by `make artifacts` from the JAX/Pallas layers), compile on
//! the PJRT CPU client, and verify numerics against a Rust-side oracle.
//!
//! Skips (with a loud message) when artifacts have not been built, so
//! `cargo test` works standalone; `make test` always builds them first.
//! The whole file is gated on the `xla` feature (the PJRT bridge needs the
//! externally-vendored `xla` crate — see DESIGN.md).

#![cfg(feature = "xla")]

use trustee::runtime::xla_exec::{BatchEngine, XlaExec};

fn artifact(name: &str) -> Option<std::path::PathBuf> {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts")
        .join(name);
    if p.exists() {
        Some(p)
    } else {
        eprintln!("SKIP: {p:?} missing — run `make artifacts` first");
        None
    }
}

#[test]
fn load_and_run_small_engine() {
    let Some(path) = artifact("batch_engine_small.hlo.txt") else { return };
    let exec = XlaExec::load(&path).expect("load + compile HLO text");
    assert!(exec.platform().to_lowercase().contains("host") || !exec.platform().is_empty());

    // table = zeros(1024); ops: keys [5, 5, 9], deltas [2, 3, 7].
    let table = xla::Literal::vec1(&vec![0i32; 1024]);
    let mut keys = vec![0i32; 32];
    let mut deltas = vec![0i32; 32];
    keys[0] = 5;
    keys[1] = 5;
    keys[2] = 9;
    deltas[0] = 2;
    deltas[1] = 3;
    deltas[2] = 7;
    let out = exec
        .run(&[table, xla::Literal::vec1(&keys), xla::Literal::vec1(&deltas)])
        .expect("execute");
    assert_eq!(out.len(), 3, "(new_table, old, shard)");
    let new_table = out[0].to_vec::<i32>().unwrap();
    let old = out[1].to_vec::<i32>().unwrap();
    // In-order fetch-and-add semantics: second op on key 5 sees the first.
    assert_eq!(old[0], 0);
    assert_eq!(old[1], 2);
    assert_eq!(old[2], 0);
    assert_eq!(new_table[5], 5);
    assert_eq!(new_table[9], 7);
    let shard = out[2].to_vec::<i32>().unwrap();
    assert!(shard.iter().all(|&s| (0..64).contains(&s)));
}

#[test]
fn batch_engine_stateful_roundtrip() {
    let Some(path) = artifact("batch_engine_small.hlo.txt") else { return };
    let mut eng = BatchEngine::new(&path, 1024, 32).expect("engine");
    // Apply three batches; mirror with a Rust-side oracle.
    let mut oracle = vec![0i32; 1024];
    let mut rng = 0x1234_5678_u64;
    for _ in 0..3 {
        let mut keys = Vec::new();
        let mut deltas = Vec::new();
        let mut want_old = Vec::new();
        for _ in 0..20 {
            rng = rng.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let key = ((rng >> 33) % 1024) as i32;
            let delta = ((rng >> 13) % 7) as i32;
            want_old.push(oracle[key as usize]);
            oracle[key as usize] += delta;
            keys.push(key);
            deltas.push(delta);
        }
        let old = eng.apply_batch(&keys, &deltas).expect("apply");
        assert_eq!(old, want_old);
    }
    let table = eng.table().expect("table");
    assert_eq!(&table[..], &oracle[..]);
    assert_eq!(eng.batches, 3);
    assert_eq!(eng.ops, 60);
}

#[test]
fn large_engine_compiles_and_runs() {
    let Some(path) = artifact("batch_engine.hlo.txt") else { return };
    let mut eng = BatchEngine::new(&path, 65536, 256).expect("engine");
    let keys: Vec<i32> = (0..256).collect();
    let deltas = vec![1i32; 256];
    let old = eng.apply_batch(&keys, &deltas).expect("apply");
    assert!(old.iter().all(|&o| o == 0));
    let old2 = eng.apply_batch(&keys, &deltas).expect("apply 2");
    assert!(old2.iter().all(|&o| o == 1), "second round sees first");
}
