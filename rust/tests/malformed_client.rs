//! Hostile-client integration tests: feeding arbitrary bytes to a live
//! `KvServer` connection must never panic a worker. A panicking fiber
//! unwinds onto the worker's scheduler stack and kills the thread, so a
//! single bad client would wedge the whole runtime — the ROADMAP's
//! "heavy traffic from millions of users" north star makes wire-path
//! totality a hard requirement, not a nicety.
//!
//! Each scenario runs under every net policy (io_uring included when the
//! kernel probe passes — otherwise skipped with a visible message), then
//! proves the server is still healthy by completing a well-formed round
//! trip on a fresh connection.

use std::io::{Read, Write};
use std::net::TcpStream;
use trustee::kvstore::{proto, BackendKind, KvServer, KvServerConfig, NetPolicy};
use trustee::util::Rng;

/// Every policy to harden against; IoUring only where the kernel has it.
fn policies(test: &str) -> Vec<NetPolicy> {
    let mut v = vec![NetPolicy::BusyPoll, NetPolicy::Epoll];
    match trustee::runtime::uring::probe() {
        Ok(()) => v.push(NetPolicy::IoUring),
        Err(e) => eprintln!("SKIP {test} under uring: io_uring unavailable ({e})"),
    }
    v
}

fn start(net: NetPolicy) -> KvServer {
    KvServer::start(KvServerConfig {
        workers: 2,
        backend: BackendKind::Trust { shards: 2 },
        net,
        ..Default::default()
    })
}

/// One valid PUT + GET round trip: the liveness probe.
fn assert_healthy(server: &KvServer, key: &[u8]) {
    let mut c = TcpStream::connect(server.addr()).unwrap();
    let mut buf = Vec::new();
    proto::write_request(&mut buf, 1, proto::OP_PUT, key, b"alive");
    proto::write_request(&mut buf, 2, proto::OP_GET, key, &[]);
    c.write_all(&buf).unwrap();
    let mut cursor = proto::FrameCursor::new();
    let mut rbuf = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut got = 0;
    while got < 2 {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            match got {
                0 => assert_eq!((r.id, r.status), (1, proto::ST_OK)),
                _ => assert_eq!((r.id, r.val.as_slice()), (2, &b"alive"[..])),
            }
            got += 1;
            continue;
        }
        let n = c.read(&mut chunk).unwrap();
        assert!(n > 0, "server closed during health check");
        rbuf.extend_from_slice(&chunk[..n]);
    }
}

/// Write `bytes` to a fresh connection and wait for the server to close it
/// (or ignore it); either is fine as long as no worker dies.
fn throw_garbage(server: &KvServer, bytes: &[u8]) {
    let mut c = TcpStream::connect(server.addr()).unwrap();
    // The server may close mid-write (RST): broken pipes here are expected.
    let _ = c.write_all(bytes);
    let _ = c.flush();
    c.set_read_timeout(Some(std::time::Duration::from_millis(500))).unwrap();
    let mut sink = [0u8; 4096];
    loop {
        match c.read(&mut sink) {
            Ok(0) => break,          // server closed: the hardened path
            Ok(_) => continue,       // an error/normal response: also fine
            Err(_) => break,         // timeout: server ignored the bytes
        }
    }
}

#[test]
fn hostile_frame_len_is_rejected_without_ballooning() {
    for net in policies("hostile_frame_len_is_rejected_without_ballooning") {
        let server = start(net);
        // A 4 GiB frame_len announcement, then silence.
        throw_garbage(&server, &u32::MAX.to_le_bytes());
        // An exactly-MAX+1 announcement with some body.
        let mut buf = ((proto::MAX_FRAME_LEN + 1) as u32).to_le_bytes().to_vec();
        buf.extend_from_slice(&[0u8; 64]);
        throw_garbage(&server, &buf);
        assert_healthy(&server, format!("k-{}", net.label()).as_bytes());
        server.stop();
    }
}

#[test]
fn truncated_and_corrupt_frames_never_panic_workers() {
    for net in policies("truncated_and_corrupt_frames_never_panic_workers") {
        let server = start(net);
        // Truncated valid frame.
        let mut buf = Vec::new();
        proto::write_request(&mut buf, 9, proto::OP_PUT, b"kk", b"vv");
        throw_garbage(&server, &buf[..buf.len() / 2]);
        // Length fields that lie about the body.
        let mut buf = Vec::new();
        proto::write_request(&mut buf, 10, proto::OP_PUT, b"kk", b"vv");
        buf[13] = 0xEE; // corrupt key_len
        throw_garbage(&server, &buf);
        // Unknown op mid-pipeline.
        let mut buf = Vec::new();
        proto::write_request(&mut buf, 11, proto::OP_GET, b"kk", &[]);
        proto::write_request(&mut buf, 12, 0xAB, b"kk", &[]);
        proto::write_request(&mut buf, 13, proto::OP_GET, b"kk", &[]);
        throw_garbage(&server, &buf);
        assert_healthy(&server, format!("t-{}", net.label()).as_bytes());
        server.stop();
    }
}

#[test]
fn hostile_bytes_via_provided_buffers_are_answered_not_panicked() {
    // Data-plane variant: hostile bytes arrive in kernel-filled provided
    // buffers and are parsed *in place* (the borrowed-slice fast path),
    // so the protocol's totality contract is exercised against kernel
    // memory, not a copied Vec. Skips (visibly) when the kernel has no
    // PBUF_RING; TRUSTEE_REQUIRE_URING_PBUF turns the skip into a
    // failure.
    if trustee::runtime::uring::probe().is_err() || !trustee::runtime::uring::dataplane_enabled() {
        eprintln!("SKIP hostile_bytes_via_provided_buffers: io_uring data plane unavailable");
        return;
    }
    if let Err(e) = trustee::runtime::uring::probe_pbuf() {
        assert!(
            std::env::var_os("TRUSTEE_REQUIRE_URING_PBUF").is_none(),
            "TRUSTEE_REQUIRE_URING_PBUF set but PBUF_RING unavailable: {e}"
        );
        eprintln!("SKIP hostile_bytes_via_provided_buffers: provided buffers unavailable ({e})");
        return;
    }
    let server = start(NetPolicy::IoUring);
    let mut rng = Rng::new(0x9B0F_BEEF);
    for round in 0..24u64 {
        let len = 1 + (rng.next_u64() % 4096) as usize;
        let mut bytes = Vec::with_capacity(len);
        for _ in 0..len {
            bytes.push(rng.next_u64() as u8);
        }
        match round % 3 {
            // Corruption at byte zero of a fresh provided buffer.
            0 => {}
            // Valid frame first: the corruption lands mid-slice, after
            // the in-place parser has already consumed a real request.
            1 => {
                let mut framed = Vec::new();
                proto::write_request(&mut framed, round, proto::OP_GET, b"seed", &[]);
                framed.extend_from_slice(&bytes);
                bytes = framed;
            }
            // Oversized frame announcement: poisons via the length check
            // while the slice is attached.
            _ => {
                let mut framed = ((proto::MAX_FRAME_LEN + 1) as u32).to_le_bytes().to_vec();
                framed.extend_from_slice(&bytes);
                bytes = framed;
            }
        }
        throw_garbage(&server, &bytes);
    }
    // The storm really rode the data plane, and the server is healthy.
    let stats = server.uring_stats();
    assert!(stats.recv_cqes > 0, "hostile bytes did not arrive via RECV CQEs: {stats:?}");
    assert_healthy(&server, b"hostile-pbuf");
    server.stop();
}

#[test]
fn random_byte_storms_never_panic_workers() {
    for net in policies("random_byte_storms_never_panic_workers") {
        let server = start(net);
        let mut rng = Rng::new(0xBAD_BEEF ^ net.label().len() as u64);
        for round in 0..16u64 {
            let len = 1 + (rng.next_u64() % 2048) as usize;
            let mut bytes = Vec::with_capacity(len);
            for _ in 0..len {
                bytes.push(rng.next_u64() as u8);
            }
            if round % 4 == 0 {
                // Sometimes lead with valid framing so the corruption
                // lands mid-stream rather than at byte zero.
                let mut framed = Vec::new();
                proto::write_request(&mut framed, round, proto::OP_GET, b"seed", &[]);
                framed.extend_from_slice(&bytes);
                bytes = framed;
            }
            throw_garbage(&server, &bytes);
        }
        assert_healthy(&server, format!("r-{}", net.label()).as_bytes());
        server.stop();
    }
}
