//! E12 — Figure 4 behaviour: `launch()` supports blocking calls, including
//! nested blocking delegation, while `apply()` in delegated context is a
//! runtime assertion failure (§3.4, §4.3).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use trustee::runtime::{in_delegated_context, Runtime};
use trustee::trust::Latch;

#[test]
fn launch_blocks_on_nested_delegation_chain() {
    // launch -> apply(inner) -> apply(inner2): a two-deep blocking chain
    // from a trustee-side fiber.
    let rt = Runtime::builder().workers(3).build();
    let inner2 = rt.trustee(2).entrust(4u64);
    let inner = rt.trustee(1).entrust(3u64);
    let outer = rt.trustee(0).entrust(Latch::new(0u64));

    let o = outer.clone();
    let i1 = inner.clone();
    let i2 = inner2.clone();
    let v = rt.block_on(1, move || {
        o.launch(move |x| {
            // Two sequential blocking hops from the trustee-side fiber —
            // each would assert under plain apply() (delegated context).
            let a = i1.apply(|v| *v);
            let b = i2.apply(|v| *v);
            *x += a + b;
            *x
        })
    });
    assert_eq!(v, 7);
    drop((inner, inner2, outer));
    rt.shutdown();
}

#[test]
fn launched_closure_runs_outside_delegated_context() {
    // The launched fiber is NOT delegated context: blocking is legal there.
    let rt = Runtime::builder().workers(2).build();
    let outer = rt.trustee(0).entrust(Latch::new(0u64));
    let o = outer.clone();
    let flag = rt.block_on(1, move || o.launch(|_| in_delegated_context()));
    assert!(!flag, "launched fibers must not be delegated context");
    drop(outer);
    rt.shutdown();
}

#[test]
fn plain_apply_closure_is_delegated_context() {
    let rt = Runtime::builder().workers(2).build();
    let ct = rt.trustee(0).entrust(0u64);
    let c2 = ct.clone();
    let flag = rt.block_on(1, move || c2.apply(|_| in_delegated_context()));
    assert!(flag, "apply closures run in delegated context");
    drop(ct);
    rt.shutdown();
}

#[test]
fn concurrent_launches_make_progress_while_one_blocks() {
    // Fig 4's point: when a launched fiber suspends, the trustee continues
    // serving; a second launch completes while the first is still parked.
    let rt = Runtime::builder().workers(3).build();
    let gate = rt.trustee(1).entrust(false); // the first launch waits on this
    let prop = rt.trustee(0).entrust(Latch::new(Vec::<&'static str>::new()));

    let order = Arc::new(std::sync::Mutex::new(Vec::new()));
    let done = Arc::new(AtomicU64::new(0));

    // Launch A: records "a-start", then blocks until the gate opens.
    {
        let p = prop.clone();
        let g = gate.clone();
        let ord = order.clone();
        let d = done.clone();
        rt.spawn_on(1, move || {
            p.launch(move |_v| {
                // Blocking poll of a remote property from inside launch.
                loop {
                    let open = g.apply(|b| *b);
                    if open {
                        break;
                    }
                    trustee::fiber::yield_now();
                }
            });
            ord.lock().unwrap().push("a-done");
            d.fetch_add(1, Ordering::AcqRel);
        });
    }
    // Launch B: should complete even though A is parked inside the trustee.
    // NOTE: B does not touch the latch while A holds it — A locks only the
    // latch property itself, so we use apply on the *same trustee* to show
    // the trustee stays live.
    {
        let p = prop.clone();
        let ord = order.clone();
        let d = done.clone();
        let g = gate.clone();
        rt.spawn_on(2, move || {
            // The trustee (worker 0) must still serve plain applies while
            // launch A's fiber is parked.
            p.apply(|_l| ());
            ord.lock().unwrap().push("b-done");
            // Open the gate so A can finish.
            g.apply(|b| *b = true);
            d.fetch_add(1, Ordering::AcqRel);
        });
    }
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while done.load(Ordering::Acquire) != 2 {
        assert!(std::time::Instant::now() < deadline, "deadlock: trustee blocked by launch");
        std::thread::yield_now();
    }
    let ord = order.lock().unwrap().clone();
    assert_eq!(ord, vec!["b-done", "a-done"], "B must finish while A is parked");
    drop((gate, prop));
    rt.shutdown();
}

#[test]
fn launch_returns_move_only_values() {
    // launch's result travels by move (no Wire bound): verify with a
    // heap-owning type.
    let rt = Runtime::builder().workers(2).build();
    let prop = rt.trustee(0).entrust(Latch::new(vec![1u64, 2, 3]));
    let p = prop.clone();
    let v: Vec<u64> = rt.block_on(1, move || p.launch(|v| v.clone()));
    assert_eq!(v, vec![1, 2, 3]);
    drop(prop);
    rt.shutdown();
}
