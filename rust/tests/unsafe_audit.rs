//! Unsafe-audit lint (ISSUE 6 tentpole part 3): every `unsafe` site in
//! `src/`, `tests/` and `benches/` must carry an adjacent safety
//! justification.
//!
//! A site is justified when one of the following holds:
//!
//! - the same line carries a `// SAFETY: ...` (or `/* SAFETY ... */`)
//!   comment;
//! - the contiguous comment/attribute block immediately above it (doc
//!   comments included, attributes and blank lines skipped, up to 40
//!   lines) contains `SAFETY:` or a `# Safety` doc section.
//!
//! `unsafe fn(...)` in *type position* (a function-pointer type) is not
//! an unsafe site and is exempt. The scanner is comment/string aware: it
//! strips block comments, string/raw-string/char literals before
//! matching, so `"unsafe"` inside a string never counts.
//!
//! Together with the crate-wide `#![deny(unsafe_op_in_unsafe_fn)]` in
//! `lib.rs` this closes the audit gap the issue measured (216 unsafe
//! sites, only 60 annotated). The allowlist below is **shrink-only**: it
//! starts empty and must never grow.

use std::fs;
use std::path::{Path, PathBuf};

/// Shrink-only allowlist of repo-relative files permitted to contain
/// unannotated `unsafe`. Empty, and it must stay that way: fix the site
/// or annotate it, do not add entries.
const ALLOWLIST: &[&str] = &[];

/// How far above an unsafe site the justification may sit (comment /
/// attribute lines only).
const LOOKBACK_LINES: usize = 40;

/// Per-line scan result: code with comments removed and literals
/// blanked, plus the comment text of that line.
struct Stripped {
    code: String,
    comment: String,
}

/// Cross-line scanner state: block-comment nesting and string kinds.
#[derive(Default)]
struct Stripper {
    in_block: u32,
    in_str: bool,
    in_raw: bool,
    raw_hashes: usize,
}

impl Stripper {
    fn strip_line(&mut self, raw: &str) -> Stripped {
        let chars: Vec<char> = raw.chars().collect();
        let n = chars.len();
        let mut code = String::new();
        let mut comment = String::new();
        let starts = |i: usize, pat: &str| -> bool {
            raw_starts_with(&chars, i, pat)
        };
        let mut i = 0;
        while i < n {
            let c = chars[i];
            if self.in_block > 0 {
                if starts(i, "*/") {
                    self.in_block -= 1;
                    i += 2;
                    continue;
                }
                if starts(i, "/*") {
                    self.in_block += 1;
                    i += 2;
                    continue;
                }
                comment.push(c);
                i += 1;
                continue;
            }
            if self.in_str {
                if c == '\\' && i + 1 < n {
                    code.push_str("  ");
                    i += 2;
                    continue;
                }
                if c == '"' {
                    self.in_str = false;
                }
                code.push(' ');
                i += 1;
                continue;
            }
            if self.in_raw {
                let end_len = 1 + self.raw_hashes;
                if c == '"'
                    && i + end_len <= n
                    && chars[i + 1..i + end_len].iter().all(|&h| h == '#')
                {
                    self.in_raw = false;
                    for _ in 0..end_len {
                        code.push(' ');
                    }
                    i += end_len;
                    continue;
                }
                code.push(' ');
                i += 1;
                continue;
            }
            if starts(i, "//") {
                comment.extend(chars[i..].iter());
                break;
            }
            if starts(i, "/*") {
                self.in_block += 1;
                i += 2;
                continue;
            }
            if c == 'r' {
                // Possible raw string r"..." / r#"..."#.
                let mut j = i + 1;
                while j < n && chars[j] == '#' {
                    j += 1;
                }
                if j < n && chars[j] == '"' {
                    self.raw_hashes = j - i - 1;
                    self.in_raw = true;
                    for _ in i..=j {
                        code.push(' ');
                    }
                    i = j + 1;
                    continue;
                }
            }
            if c == '"' {
                self.in_str = true;
                code.push(' ');
                i += 1;
                continue;
            }
            if c == '\'' {
                // Char literal ('x' / '\x'); otherwise a lifetime tick.
                let lit_len = if i + 3 < n && chars[i + 1] == '\\' && chars[i + 3] == '\'' {
                    Some(4)
                } else if i + 2 < n
                    && chars[i + 1] != '\''
                    && chars[i + 1] != '\\'
                    && chars[i + 2] == '\''
                {
                    Some(3)
                } else {
                    None
                };
                if let Some(l) = lit_len {
                    for _ in 0..l {
                        code.push(' ');
                    }
                    i += l;
                    continue;
                }
                code.push('\'');
                i += 1;
                continue;
            }
            code.push(c);
            i += 1;
        }
        Stripped { code, comment }
    }
}

fn raw_starts_with(chars: &[char], i: usize, pat: &str) -> bool {
    let mut k = i;
    for p in pat.chars() {
        if chars.get(k) != Some(&p) {
            return false;
        }
        k += 1;
    }
    true
}

fn is_word(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// First `\bunsafe\b` in code text that is a real unsafe site (skips
/// `unsafe fn(` function-pointer types). Returns true when one exists.
fn line_has_unsafe_site(code: &str) -> bool {
    for (pos, _) in code.match_indices("unsafe") {
        let before_ok = match code[..pos].chars().next_back() {
            Some(c) => !is_word(c),
            None => true,
        };
        let tail = &code[pos + "unsafe".len()..];
        let after_ok = match tail.chars().next() {
            Some(c) => !is_word(c),
            None => true,
        };
        if !(before_ok && after_ok) {
            continue;
        }
        if tail.trim_start().starts_with("fn(") {
            continue; // fn-pointer type position
        }
        return true;
    }
    false
}

struct Violation {
    file: PathBuf,
    line: usize,
    text: String,
}

fn check_file(path: &Path, violations: &mut Vec<Violation>, sites: &mut usize) {
    let src = fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
    let lines: Vec<&str> = src.lines().collect();
    let mut stripper = Stripper::default();
    let stripped: Vec<Stripped> =
        lines.iter().map(|l| stripper.strip_line(l)).collect();

    for (idx, s) in stripped.iter().enumerate() {
        if !line_has_unsafe_site(&s.code) {
            continue;
        }
        *sites += 1;
        // Same-line marker?
        if s.comment.contains("SAFETY") || s.comment.contains("Safety") {
            continue;
        }
        // Contiguous comment/attribute block above.
        let mut ok = false;
        let mut j = idx;
        let mut budget = LOOKBACK_LINES;
        while j > 0 && budget > 0 {
            j -= 1;
            budget -= 1;
            let above = &stripped[j];
            let t = above.code.trim();
            if !t.is_empty() && !t.starts_with("#[") {
                break; // real code ends the block
            }
            if above.comment.contains("SAFETY:") || above.comment.contains("# Safety") {
                ok = true;
                break;
            }
            if t.is_empty() && above.comment.is_empty() && !lines[j].trim().is_empty() {
                break; // e.g. the body of a multi-line string literal
            }
        }
        if !ok {
            violations.push(Violation {
                file: path.to_path_buf(),
                line: idx + 1,
                text: lines[idx].trim().chars().take(100).collect(),
            });
        }
    }
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(rd) = fs::read_dir(dir) else { return };
    let mut entries: Vec<PathBuf> = rd.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

#[test]
fn every_unsafe_site_has_a_safety_comment() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut files = Vec::new();
    for root in ["src", "tests", "benches"] {
        walk(&manifest.join(root), &mut files);
    }
    assert!(
        files.len() > 20,
        "walker found only {} source files — wrong root?",
        files.len()
    );

    let mut violations = Vec::new();
    let mut sites = 0usize;
    for f in &files {
        let rel = f.strip_prefix(manifest).unwrap_or(f);
        if ALLOWLIST.iter().any(|a| Path::new(a) == rel) {
            continue;
        }
        check_file(f, &mut violations, &mut sites);
    }

    println!(
        "unsafe audit: {} files scanned, {} unsafe sites, {} unannotated",
        files.len(),
        sites,
        violations.len()
    );
    if !violations.is_empty() {
        let mut msg = format!(
            "{} unsafe site(s) without an adjacent SAFETY justification:\n",
            violations.len()
        );
        for v in &violations {
            let rel = v.file.strip_prefix(manifest).unwrap_or(&v.file);
            msg.push_str(&format!("  {}:{}  {}\n", rel.display(), v.line, v.text));
        }
        msg.push_str(
            "add a `// SAFETY: ...` comment (or `# Safety` doc section) adjacent to each site",
        );
        panic!("{msg}");
    }
    // The audit is only meaningful if it actually sees the crate's
    // unsafe code (216 sites at the time this lint landed).
    assert!(
        sites > 100,
        "only {sites} unsafe sites found — scanner regression?"
    );
}
