//! The full cache state machine — set / get / del / touch, lazy expiry,
//! budget eviction — replayed from seeded random scripts against a
//! naive Vec-backed LRU reference model, on **every backend flavor**.
//!
//! The reference model is the obviously-correct implementation: an
//! MRU-first `Vec` scanned linearly, with the same published semantics
//! (tail victims, expired-vs-evicted victim counting, class-rounded
//! byte charges via the real [`entry_cost`]). Any divergence in an op
//! result, a counter, or the surviving contents — on any backend —
//! means the slab-handle + intrusive-LRU store broke the contract the
//! old stamp-scan store pinned. The per-backend stats are also compared
//! across backends at the end of each script: victim order, expiry
//! accounting, and even the value-pool gauges must be identical because
//! all four flavors drive the same `ItemShard` with the same ops.

use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::{Arc, Mutex, RwLock};
use trustee::fiber;
use trustee::kvstore::backend::{AckCb, AsyncKv, GetItemCb};
use trustee::kvstore::store::{entry_cost, StoreClock, StoreConfig};
use trustee::kvstore::{ItemShard, LockedItemKv, StoreStats, TrustKv};
use trustee::runtime::Runtime;

// ---------------------------------------------------------------------
// Synchronous op helpers (run inside a runtime fiber so Trust
// completions can flow; lock backends complete inline).
// ---------------------------------------------------------------------

fn set_sync(kv: &Arc<dyn AsyncKv>, key: &[u8], val: &[u8], flags: u32, ttl_ms: u64) -> bool {
    let r: Rc<Cell<Option<bool>>> = Rc::new(Cell::new(None));
    let r2 = r.clone();
    kv.set_item(key, val, flags, ttl_ms, AckCb::new(move |e| r2.set(Some(e))));
    while r.get().is_none() {
        fiber::yield_now();
    }
    r.get().unwrap()
}

fn get_sync(kv: &Arc<dyn AsyncKv>, key: &[u8]) -> Option<(u32, Vec<u8>)> {
    let r: Rc<Cell<bool>> = Rc::new(Cell::new(false));
    let out: Rc<RefCell<Option<(u32, Vec<u8>)>>> = Rc::new(RefCell::new(None));
    let (r2, o2) = (r.clone(), out.clone());
    kv.get_item(
        key,
        GetItemCb::new(move |_k: &[u8], item: Option<(u32, &[u8])>| {
            *o2.borrow_mut() = item.map(|(f, v)| (f, v.to_vec()));
            r2.set(true);
        }),
    );
    while !r.get() {
        fiber::yield_now();
    }
    out.borrow_mut().take()
}

fn del_sync(kv: &Arc<dyn AsyncKv>, key: &[u8]) -> bool {
    let r: Rc<Cell<Option<bool>>> = Rc::new(Cell::new(None));
    let r2 = r.clone();
    kv.del(key, AckCb::new(move |e| r2.set(Some(e))));
    while r.get().is_none() {
        fiber::yield_now();
    }
    r.get().unwrap()
}

fn touch_sync(kv: &Arc<dyn AsyncKv>, key: &[u8], ttl_ms: u64) -> bool {
    let r: Rc<Cell<Option<bool>>> = Rc::new(Cell::new(None));
    let r2 = r.clone();
    kv.touch(key, ttl_ms, AckCb::new(move |e| r2.set(Some(e))));
    while r.get().is_none() {
        fiber::yield_now();
    }
    r.get().unwrap()
}

// ---------------------------------------------------------------------
// The reference model: MRU-first Vec, linear scans, naive eviction.
// ---------------------------------------------------------------------

struct MEntry {
    key: Vec<u8>,
    flags: u32,
    val: Vec<u8>,
    expires_at_ms: u64,
}

impl MEntry {
    fn is_expired(&self, now: u64) -> bool {
        self.expires_at_ms != 0 && self.expires_at_ms <= now
    }
}

struct Model {
    /// MRU first; the victim is always the last element.
    entries: Vec<MEntry>,
    now: u64,
    budget: u64,
    evictions: u64,
    expired: u64,
}

impl Model {
    /// `now` starts wherever the (shared, rewind-free) manual clock
    /// currently reads, so one clock can serve every backend in turn.
    fn new(budget: u64, now: u64) -> Model {
        Model { entries: Vec::new(), now, budget, evictions: 0, expired: 0 }
    }

    fn bytes(&self) -> u64 {
        self.entries.iter().map(|e| entry_cost(e.key.len(), e.val.len())).sum()
    }

    fn find(&self, key: &[u8]) -> Option<usize> {
        self.entries.iter().position(|e| e.key == key)
    }

    fn bump(&mut self, pos: usize) {
        let e = self.entries.remove(pos);
        self.entries.insert(0, e);
    }

    fn get(&mut self, key: &[u8]) -> Option<(u32, Vec<u8>)> {
        let pos = self.find(key)?;
        if self.entries[pos].is_expired(self.now) {
            self.entries.remove(pos);
            self.expired += 1;
            return None;
        }
        self.bump(pos);
        Some((self.entries[0].flags, self.entries[0].val.clone()))
    }

    fn set(&mut self, key: &[u8], val: &[u8], flags: u32, ttl_ms: u64) -> bool {
        let expires = if ttl_ms == 0 { 0 } else { self.now.saturating_add(ttl_ms) };
        let existed = match self.find(key) {
            Some(pos) => {
                let was_expired = self.entries[pos].is_expired(self.now);
                if was_expired {
                    self.expired += 1;
                }
                let e = &mut self.entries[pos];
                e.flags = flags;
                e.val = val.to_vec();
                e.expires_at_ms = expires;
                self.bump(pos);
                !was_expired
            }
            None => {
                self.entries.insert(
                    0,
                    MEntry { key: key.to_vec(), flags, val: val.to_vec(), expires_at_ms: expires },
                );
                false
            }
        };
        while self.budget > 0 && self.bytes() > self.budget {
            let victim = self.entries.pop().expect("over budget implies non-empty");
            if victim.is_expired(self.now) {
                self.expired += 1;
            } else {
                self.evictions += 1;
            }
        }
        existed
    }

    fn del(&mut self, key: &[u8]) -> bool {
        let Some(pos) = self.find(key) else {
            return false;
        };
        let was_expired = self.entries[pos].is_expired(self.now);
        self.entries.remove(pos);
        if was_expired {
            self.expired += 1;
            false
        } else {
            true
        }
    }

    fn touch(&mut self, key: &[u8], ttl_ms: u64) -> bool {
        let Some(pos) = self.find(key) else {
            return false;
        };
        if self.entries[pos].is_expired(self.now) {
            self.entries.remove(pos);
            self.expired += 1;
            return false;
        }
        self.entries[pos].expires_at_ms =
            if ttl_ms == 0 { 0 } else { self.now.saturating_add(ttl_ms) };
        self.bump(pos);
        true
    }
}

// ---------------------------------------------------------------------
// Seeded script generation (SplitMix64 — no external crates).
// ---------------------------------------------------------------------

fn next_rand(s: &mut u64) -> u64 {
    *s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *s;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[derive(Clone, Copy, Debug)]
enum Op {
    Set { k: u8, len: usize, flags: u32, ttl_ms: u64 },
    Get { k: u8 },
    Del { k: u8 },
    Touch { k: u8, ttl_ms: u64 },
    Advance { ms: u64 },
}

/// 8 keys, value lengths spanning several size classes, a mix of
/// no-expiry and short TTLs, and clock advances that expire them
/// mid-script. Set-heavy so the budget keeps evicting.
fn script(seed: u64, len: usize) -> Vec<Op> {
    let mut s = seed;
    (0..len)
        .map(|_| {
            let r = next_rand(&mut s);
            let k = ((r >> 8) % 8) as u8;
            match r % 8 {
                0..=2 => Op::Set {
                    k,
                    len: ((r >> 16) % 96 + 1) as usize,
                    flags: ((r >> 24) % 100) as u32,
                    ttl_ms: if (r >> 32) % 3 == 0 { 0 } else { (r >> 32) % 40 + 1 },
                },
                3 | 4 => Op::Get { k },
                5 => Op::Del { k },
                6 => Op::Touch {
                    k,
                    ttl_ms: if (r >> 16) % 2 == 0 { 0 } else { (r >> 16) % 40 + 1 },
                },
                _ => Op::Advance { ms: (r >> 16) % 16 },
            }
        })
        .collect()
}

// ---------------------------------------------------------------------
// The harness
// ---------------------------------------------------------------------

/// Build each backend flavor with one shard (so every key contends for
/// the same budget) over the given store config.
fn backends_one_shard(rt: &Runtime, cfg: &StoreConfig) -> Vec<(&'static str, Arc<dyn AsyncKv>)> {
    vec![
        ("trust", TrustKv::with_config(rt, &[0], 1, cfg) as Arc<dyn AsyncKv>),
        (
            "mutex",
            Arc::new(LockedItemKv::<Mutex<ItemShard>>::new(1, "mutex", cfg)),
        ),
        (
            "rwlock",
            Arc::new(LockedItemKv::<RwLock<ItemShard>>::new(1, "rwlock", cfg)),
        ),
        (
            "swift",
            Arc::new(LockedItemKv::<RwLock<ItemShard>>::new(1, "swift", cfg)),
        ),
    ]
}

#[test]
fn random_scripts_match_the_naive_lru_model_on_every_backend() {
    // Budget for ~5 of the largest entries this script writes ("kN" +
    // a 96-byte value), so eviction stays busy over the 8-key space.
    let budget = 5 * entry_cost(2, 96);
    let rt = Runtime::builder().workers(2).build();
    // One manual clock shared by every backend (rewind is impossible, so
    // later backends just see a larger `now`; the model resyncs).
    let clock = StoreClock::manual();
    let cfg = StoreConfig { budget_bytes: budget, clock: clock.clone() };
    for seed in [0xA5A5_u64, 0x5EED, 0xC0FFEE] {
        let mut all_stats: Vec<(&'static str, StoreStats)> = Vec::new();
        for (name, kv) in backends_one_shard(&rt, &cfg) {
            let kv2 = kv.clone();
            let clock2 = clock.clone();
            let model_start = clock.now_ms();
            let model_end = rt.block_on(1, move || {
                let mut model = Model::new(budget, model_start);
                for (i, op) in script(seed, 400).into_iter().enumerate() {
                    match op {
                        Op::Set { k, len, flags, ttl_ms } => {
                            let key = [b'k', k];
                            let val = vec![k.wrapping_mul(31).wrapping_add(len as u8); len];
                            let got = set_sync(&kv2, &key, &val, flags, ttl_ms);
                            let want = model.set(&key, &val, flags, ttl_ms);
                            assert_eq!(got, want, "{name} seed {seed:#x} op {i}: {op:?}");
                        }
                        Op::Get { k } => {
                            let got = get_sync(&kv2, &[b'k', k]);
                            let want = model.get(&[b'k', k]);
                            assert_eq!(got, want, "{name} seed {seed:#x} op {i}: {op:?}");
                        }
                        Op::Del { k } => {
                            let got = del_sync(&kv2, &[b'k', k]);
                            let want = model.del(&[b'k', k]);
                            assert_eq!(got, want, "{name} seed {seed:#x} op {i}: {op:?}");
                        }
                        Op::Touch { k, ttl_ms } => {
                            let got = touch_sync(&kv2, &[b'k', k], ttl_ms);
                            let want = model.touch(&[b'k', k], ttl_ms);
                            assert_eq!(got, want, "{name} seed {seed:#x} op {i}: {op:?}");
                        }
                        Op::Advance { ms } => {
                            clock2.advance(ms);
                            model.now += ms;
                        }
                    }
                }
                // Final contents: one GET per possible key is both a
                // value/flags comparison and a last victim-order probe
                // (a divergent eviction would have dropped a different
                // survivor set).
                for k in 0..8u8 {
                    let got = get_sync(&kv2, &[b'k', k]);
                    let want = model.get(&[b'k', k]);
                    assert_eq!(got, want, "{name} seed {seed:#x}: final contents of key {k}");
                }
                (model.entries.len() as u64, model.bytes(), model.evictions, model.expired)
            });
            let stats = kv.store_stats();
            let (items, bytes, evictions, expired) = model_end;
            assert_eq!(stats.items, items, "{name} seed {seed:#x}: live items");
            assert_eq!(stats.store_bytes, bytes, "{name} seed {seed:#x}: charged bytes");
            assert_eq!(stats.evictions, evictions, "{name} seed {seed:#x}: evictions");
            assert_eq!(stats.expired_keys, expired, "{name} seed {seed:#x}: expired keys");
            all_stats.push((name, stats));
        }
        // Same ops on the same shard code: every backend must land on
        // byte-identical stats, value-pool gauges included.
        let (first_name, first) = &all_stats[0];
        for (name, stats) in &all_stats[1..] {
            assert_eq!(stats, first, "seed {seed:#x}: {name} diverged from {first_name}");
        }
    }
    rt.shutdown();
}
