//! Stress tests for the delegated-refcount ordering contract.
//!
//! The bug these provoke (fixed by the acked-clone protocol): `clone`'s
//! `+1` used to travel fire-and-forget on the *cloner's* client→trustee
//! slot pair, while the receiving thread's eventual `-1` travels on *its
//! own* pair. Nothing ordered the two, so the `-1` could be served first,
//! drive the count to zero, and reclaim the property while the cloned
//! handle was alive — a use-after-free the moment the receiver touched it.
//! The window was widest exactly when the cloner's edge already had a
//! batch in flight (the `+1` then waited in the outbox), which the first
//! test sets up on every round; under the adaptive flush policy a lazy
//! `+1` would make it wider still. With acked clones the `+1` is applied
//! before the handle can cross threads, so these runs are deterministic.

use std::sync::mpsc;
use trustee::channel::FlushPolicy;
use trustee::runtime::{with_worker, Runtime};

/// Receive from an mpsc channel inside a fiber without blocking the
/// worker thread (yield lets the scheduler serve/poll between probes).
fn fiber_recv<T>(rx: &mpsc::Receiver<T>) -> T {
    loop {
        match rx.try_recv() {
            Ok(v) => return v,
            Err(mpsc::TryRecvError::Empty) => trustee::fiber::yield_now(),
            Err(mpsc::TryRecvError::Disconnected) => panic!("sender dropped"),
        }
    }
}

/// Wait until worker 0's registry is empty (decrements are asynchronous).
fn wait_reclaimed(rt: &Runtime) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        let live = rt.block_on(0, || with_worker(|w| w.registry.live));
        if live == 0 {
            return;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "{live} properties leaked — a decrement overtook an increment"
        );
        std::thread::yield_now();
    }
}

#[test]
fn increment_cannot_be_overtaken_by_remote_decrement() {
    // The exact old interleaving, provoked every round:
    //   worker 1: occupy the (1→0) edge, clone, hand off, drop original
    //   worker 2: receive the clone, USE it, drop it
    // Pre-fix, worker 2's -1 could be served while the +1 still sat
    // behind the in-flight batch on worker 1's edge → count hit zero →
    // reclaim → worker 2's apply touched freed memory.
    let rt = Runtime::builder()
        .workers(3)
        .flush_policy(FlushPolicy::Adaptive)
        .build();
    for round in 0..200u64 {
        let prop = rt.trustee(0).entrust(round);
        let (tx, rx) = mpsc::channel();
        let h1 = rt.spawn_on_handle(1, move || {
            // Put a batch in flight on the (1→0) edge so an unacked +1
            // would have to queue behind it.
            prop.apply_forget(|_| {});
            let handle = prop.clone(); // must be acked before the send
            tx.send(handle).unwrap();
            drop(prop); // -1 rides a later batch on this edge
        });
        let h2 = rt.spawn_on_handle(2, move || {
            let handle = fiber_recv(&rx);
            // Use-after-free detector: pre-fix this read raced reclaim.
            let v = handle.apply(|x| *x);
            assert_eq!(v, round);
            drop(handle); // the final -1; the property reclaims cleanly
        });
        h1.join();
        h2.join();
    }
    wait_reclaimed(&rt);
    rt.shutdown();
}

#[test]
fn clone_storm_across_workers_balances_exactly() {
    // Many concurrent cloners and droppers of one property: every clone
    // acked, every drop asynchronous, final count must return to zero
    // exactly once the root handle drops.
    let rt = Runtime::builder().workers(4).build();
    let root = rt.trustee(0).entrust(0u64);
    let mut handles = Vec::new();
    for w in 1..4 {
        let r = root.clone();
        handles.push(rt.spawn_on_handle(w, move || {
            for i in 0..100u64 {
                let c = r.clone();
                if i % 3 == 0 {
                    c.apply(|x| *x += 1);
                }
                drop(c);
            }
            drop(r);
        }));
    }
    for h in handles {
        h.join();
    }
    let total = {
        let r = root.clone();
        rt.block_on(1, move || r.apply(|x| *x))
    };
    assert_eq!(total, 3 * 34, "every third clone incremented (i = 0,3,..,99)");
    drop(root);
    wait_reclaimed(&rt);
    rt.shutdown();
}

#[test]
fn handoff_chain_through_every_worker() {
    // A single handle relayed 1 → 2 → 3 → 1 ... with the previous holder
    // dropping right after each send: at every hop the acked +1 must beat
    // the previous holder's -1, whatever edges they ride.
    let rt = Runtime::builder().workers(4).build();
    let prop = rt.trustee(0).entrust(7u64);
    let mut current = prop.clone();
    drop(prop);
    for hop in 0..30usize {
        let w = 1 + (hop % 3);
        let (tx, rx) = mpsc::channel();
        let moved = current;
        let h = rt.spawn_on_handle(w, move || {
            let mine = moved.clone();
            drop(moved);
            let v = mine.apply(|x| *x);
            assert_eq!(v, 7);
            tx.send(mine).unwrap();
        });
        h.join();
        current = rx.recv().unwrap();
    }
    drop(current);
    wait_reclaimed(&rt);
    rt.shutdown();
}
