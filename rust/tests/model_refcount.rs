//! Model-checked Trust clone/drop refcount-ack protocol (`trust/mod.rs`,
//! ISSUE 6 tentpole part 2b).
//!
//! Two closed-world models:
//!
//! 1. **Acked clone vs the PR 1 use-after-free.** The object's refcount
//!    is a plain [`VCell`] mutated only by its trustee (delegated
//!    refcounting, paper §4.3). Client P1 clones its handle and passes
//!    the clone to client P2 over a mailbox; both eventually drop. The
//!    `+1` and the `-1`s travel on *different* slot edges, so nothing
//!    orders them — unless the clone waits for the trustee's ack before
//!    the handle escapes (`rc_inc_acked`). The seeded bug skips the ack
//!    (the historical fire-and-forget clone): the explorer must find the
//!    premature free and the use-after-free, with a replayable schedule.
//!
//! 2. **Spin-ack vs the PR 2 clone-cycle deadlock.** Two trustee threads
//!    each clone a handle to the *other's* object and spin-wait for the
//!    inc-ack ([`VBool`], mirroring `rc_inc_spin_ack_thunk`). The fixed
//!    protocol serves incoming rc-increment batches while spinning
//!    (`serve_rc_increment_batches`); the seeded bug spins without
//!    serving — the explorer must report the ack deadlock.

#![cfg(feature = "model")]

use std::sync::atomic::Ordering::{Acquire, Release};
use std::sync::Arc;
use trustee::model::{self, Opts};
use trustee::util::vatomic::{VAtomicU64, VBool, VCell};

/// Preemption bound every test explores exhaustively to (see
/// `model_slot.rs` for the rationale).
const BOUND: usize = 2;

fn opts() -> Opts {
    Opts { preemptions: BOUND, ..Opts::default() }
}

// ---------------------------------------------------------------------------
// Model 1: acked clone vs premature free (PR 1 UAF class)
// ---------------------------------------------------------------------------

const OP_INC: u64 = 1;
const OP_DEC: u64 = 2;

/// One single-slot request edge to the trustee: toggle bit 0, op code in
/// bits 1..3; `ack` echoes the toggle when the op has been applied.
struct Edge {
    req: VAtomicU64,
    ack: VAtomicU64,
}

impl Edge {
    fn new() -> Edge {
        Edge { req: VAtomicU64::new(0), ack: VAtomicU64::new(0) }
    }

    /// Post `op` with the given toggle (producer side).
    fn post(&self, toggle: bool, op: u64) {
        self.req.store(toggle as u64 | (op << 1), Release);
    }
}

/// Block until the trustee acked `toggle` on `edge`.
fn wait_ack(edge: &Arc<Edge>, toggle: bool) {
    let e = Arc::clone(edge);
    model::block_until(move || e.ack.raw_load() & 1 == toggle as u64);
    let _ = edge.ack.load(Acquire);
}

struct RcWorld {
    /// Refcount of the one shared object — mutated *only* by the trustee
    /// (delegated refcounting), so a plain cell is correct by protocol.
    rc: VCell<i64>,
    /// The object's storage, stood in by a tracked allocation.
    obj: usize,
    /// P1's edge (carries the clone `+1`, then P1's drop `-1`).
    edge_a: Arc<Edge>,
    /// P2's edge (carries P2's drop `-1`).
    edge_b: Arc<Edge>,
    /// Handle handoff from P1 to P2.
    mailbox: VAtomicU64,
}

fn rc_trustee(w: Arc<RcWorld>) {
    // Serve three ops total (one +1, two -1), scanning edge A then B —
    // the fixed scan order means only *publication* order can save or
    // doom us, exactly like the real outbox-flush timing.
    let mut tog_a = false;
    let mut tog_b = false;
    for _ in 0..3 {
        let (wa, wb) = (!tog_a, !tog_b);
        let (ea, eb) = (Arc::clone(&w.edge_a), Arc::clone(&w.edge_b));
        model::block_until(move || {
            ea.req.raw_load() & 1 == wa as u64 || eb.req.raw_load() & 1 == wb as u64
        });
        let (edge, toggle) = if w.edge_a.req.load(Acquire) & 1 == wa as u64 {
            tog_a = wa;
            (&w.edge_a, wa)
        } else {
            tog_b = wb;
            (&w.edge_b, wb)
        };
        let op = (edge.req.load(Acquire) >> 1) & 3;
        // Applying any rc op touches the object's header.
        model::track_access(w.obj);
        match op {
            OP_INC => w.rc.set(w.rc.get() + 1),
            OP_DEC => {
                let rc = w.rc.get() - 1;
                w.rc.set(rc);
                if rc == 0 {
                    model::track_free(w.obj);
                }
            }
            _ => panic!("bogus op {op}"),
        }
        edge.ack.store(toggle as u64, Release);
    }
    assert_eq!(w.rc.get(), 0, "refcount must end at zero");
    assert!(!model::tracked_alive(w.obj), "object must be reclaimed exactly once");
}

/// P1 starts with the only handle (rc = 1): clones it for P2, hands the
/// clone over, then drops its own handle. `acked_clone` is the protocol
/// under test: +1 applied (acked) *before* the handle escapes.
fn rc_p1(w: Arc<RcWorld>, acked_clone: bool) {
    if acked_clone {
        w.edge_a.post(true, OP_INC);
        wait_ack(&w.edge_a, true); // rc_inc_acked: +1 is in before clone returns
        w.mailbox.store(1, Release); // the clone escapes to P2
    } else {
        // Seeded PR 1 bug: fire-and-forget clone — the handle escapes
        // while the +1 still sits unflushed in the outbox.
        w.mailbox.store(1, Release);
        w.edge_a.post(true, OP_INC);
        wait_ack(&w.edge_a, true); // slot-reuse wait only; too late to help
    }
    // Drop P1's own handle.
    w.edge_a.post(false, OP_DEC);
}

fn rc_p2(w: Arc<RcWorld>) {
    let wm = Arc::clone(&w);
    model::block_until(move || wm.mailbox.raw_load() == 1);
    let _ = w.mailbox.load(Acquire); // receive the cloned handle
    // ... use it, then drop it.
    w.edge_b.post(true, OP_DEC);
}

fn rc_body(acked_clone: bool) -> impl FnMut() {
    move || {
        let w = Arc::new(RcWorld {
            rc: VCell::new(1),
            obj: model::track_alloc("trust-object"),
            edge_a: Arc::new(Edge::new()),
            edge_b: Arc::new(Edge::new()),
            mailbox: VAtomicU64::new(0),
        });
        let (w1, w2) = (Arc::clone(&w), Arc::clone(&w));
        model::spawn(move || rc_p1(w1, acked_clone));
        model::spawn(move || rc_p2(w2));
        model::spawn(move || rc_trustee(w));
    }
}

/// The acked-clone protocol: across every schedule up to the bound the
/// object is freed exactly once, after all three ops, with no ack
/// deadlock (a deadlock would be reported as a violation).
#[test]
fn acked_clone_has_no_premature_free() {
    let report = model::explore(opts(), rc_body(true));
    report.assert_ok();
    assert!(
        report.completed,
        "exploration must exhaust the schedule space at preemption bound {BOUND}"
    );
    assert!(
        report.schedules > 50,
        "suspiciously few schedules ({})",
        report.schedules
    );
    println!(
        "refcount-ack model: {} schedules explored exhaustively at preemption bound {BOUND} (max depth {})",
        report.schedules, report.max_depth
    );
}

/// Seeded bug: skipping the clone ack lets a `-1` from the cloned
/// handle's new owner reach the trustee before the `+1` is even
/// published — premature free, then use-after-free when the `+1` lands.
#[test]
fn seeded_skipped_clone_ack_is_caught_with_replayable_schedule() {
    let report = model::explore(opts(), rc_body(false));
    let v = report
        .violation
        .expect("explorer must catch the skipped clone ack");
    assert!(
        v.message.contains("use-after-free") || v.message.contains("refcount"),
        "expected a use-after-free from the premature free, got: {}",
        v.message
    );
    let replayed = model::replay(opts(), &v.schedule, rc_body(false))
        .expect("replaying the reported schedule must reproduce a violation");
    assert_eq!(
        replayed.message, v.message,
        "replay must reproduce the same violation deterministically"
    );
}

// ---------------------------------------------------------------------------
// Model 2: spin-ack vs the clone-cycle deadlock (PR 2)
// ---------------------------------------------------------------------------

/// Requests *for one object*: posted by the peer, served by the owner;
/// the spin-ack flag mirrors `rc_inc_spin_ack_thunk`'s `AtomicBool`.
struct SpinSide {
    req: VAtomicU64,
    ack: VBool,
    rc: VCell<u64>,
}

impl SpinSide {
    fn new() -> SpinSide {
        SpinSide { req: VAtomicU64::new(0), ack: VBool::new(false), rc: VCell::new(1) }
    }
}

/// One trustee thread of the clone cycle: post an inc for the peer's
/// object, then wait for the ack. `serve_while_spinning` is PR 2's fix
/// (`serve_rc_increment_batches`): while waiting, admit and apply
/// incoming rc-increment batches for *our* object.
fn spin_trustee(mine: Arc<SpinSide>, peers: Arc<SpinSide>, serve_while_spinning: bool) {
    peers.req.store(1, Release);
    if serve_while_spinning {
        let mut served = false;
        loop {
            let (m, p) = (Arc::clone(&mine), Arc::clone(&peers));
            let done_serve = served;
            model::block_until(move || {
                p.ack.raw_load() || (!done_serve && m.req.raw_load() == 1)
            });
            if !served && mine.req.load(Acquire) == 1 {
                mine.rc.set(mine.rc.get() + 1);
                mine.ack.store(true, Release);
                served = true;
            }
            if peers.ack.load(Acquire) {
                break;
            }
        }
        // Our ack arrived, and the peer always posts its request before
        // acking ours, so we must have served it: both objects end at 2.
        assert_eq!(mine.rc.get(), 2, "peer's inc was not admitted while spinning");
    } else {
        // Seeded PR 2 bug: spin on our ack without serving anything.
        let p = Arc::clone(&peers);
        model::block_until(move || p.ack.raw_load());
        let _ = peers.ack.load(Acquire);
    }
}

fn spin_body(serve_while_spinning: bool) -> impl FnMut() {
    move || {
        let a = Arc::new(SpinSide::new());
        let b = Arc::new(SpinSide::new());
        let (a1, b1) = (Arc::clone(&a), Arc::clone(&b));
        model::spawn(move || spin_trustee(a1, b1, serve_while_spinning));
        model::spawn(move || spin_trustee(b, a, serve_while_spinning));
    }
}

/// PR 2's fix model-checked: serving rc-increment batches while
/// spinning breaks the cycle in every schedule, and both refcounts end
/// at 2.
#[test]
fn spin_ack_with_serving_never_deadlocks() {
    let report = model::explore(opts(), spin_body(true));
    report.assert_ok();
    assert!(report.completed, "must exhaust schedules at bound {BOUND}");
    println!(
        "clone-cycle model: {} schedules explored exhaustively at preemption bound {BOUND} (max depth {})",
        report.schedules, report.max_depth
    );
}

/// Seeded bug: both sides spinning without serving is the PR 2 ack
/// deadlock — detected (not hung) and replayable.
#[test]
fn seeded_spin_without_serving_deadlocks() {
    let report = model::explore(opts(), spin_body(false));
    let v = report.violation.expect("explorer must catch the ack deadlock");
    assert!(
        v.message.contains("deadlock"),
        "expected a deadlock violation, got: {}",
        v.message
    );
    let replayed = model::replay(opts(), &v.schedule, spin_body(false))
        .expect("replay must reproduce the deadlock");
    assert!(replayed.message.contains("deadlock"), "got: {}", replayed.message);
}
