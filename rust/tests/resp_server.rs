//! Integration tests for the RESP (Redis-protocol) front end on the
//! shared delegated server core: the acceptance smoke (PING/SET/GET/
//! MGET/DEL through both `NetPolicy` variants on the Trust backend),
//! every backend behind the same wire format, strict in-order pipelined
//! responses through the reorder spool, and hostile-input totality
//! (garbage, truncation, bit-flips must never panic a worker —
//! `tests/malformed_client.rs` for the KV protocol, this file for RESP).

use std::io::{Read, Write};
use std::net::TcpStream;
use trustee::kvstore::BackendKind;
use trustee::server::{NetPolicy, RespServer, RespServerConfig};
use trustee::util::Rng;

fn start(backend: BackendKind, net: NetPolicy, workers: usize, dedicated: usize) -> RespServer {
    RespServer::start(RespServerConfig {
        workers,
        dedicated,
        backend,
        budget_bytes: 0,
        net,
        addr: "127.0.0.1:0".into(),
        ..Default::default()
    })
}

/// Send `cmd`, read exactly the expected reply bytes (the client socket
/// stays blocking, so read_exact waits for the full reply).
fn roundtrip(c: &mut TcpStream, cmd: &[u8], want: &[u8]) {
    c.write_all(cmd).unwrap();
    let mut got = vec![0u8; want.len()];
    c.read_exact(&mut got).unwrap();
    assert_eq!(
        got,
        want,
        "cmd {:?}: got {:?} want {:?}",
        String::from_utf8_lossy(cmd),
        String::from_utf8_lossy(&got),
        String::from_utf8_lossy(want)
    );
}

#[test]
fn resp_smoke_trust_backend_both_policies() {
    // The acceptance smoke: PING/SET/GET/MGET/DEL (and the rest of the
    // command set) through BusyPoll and Epoll on the Trust backend.
    for net in [NetPolicy::BusyPoll, NetPolicy::Epoll] {
        let server = start(BackendKind::Trust { shards: 2 }, net, 2, 0);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        // Inline and multibulk forms both parse.
        roundtrip(&mut c, b"PING\r\n", b"+PONG\r\n");
        roundtrip(&mut c, b"*1\r\n$4\r\nPING\r\n", b"+PONG\r\n");
        roundtrip(&mut c, b"PING hello\r\n", b"$5\r\nhello\r\n");
        roundtrip(&mut c, b"*3\r\n$3\r\nSET\r\n$1\r\na\r\n$5\r\nhello\r\n", b"+OK\r\n");
        roundtrip(&mut c, b"*2\r\n$3\r\nGET\r\n$1\r\na\r\n", b"$5\r\nhello\r\n");
        roundtrip(&mut c, b"SET b world\r\n", b"+OK\r\n");
        roundtrip(
            &mut c,
            b"MGET a b nope\r\n",
            b"*3\r\n$5\r\nhello\r\n$5\r\nworld\r\n$-1\r\n",
        );
        roundtrip(&mut c, b"EXISTS a b nope\r\n", b":2\r\n");
        roundtrip(&mut c, b"DEL a nope\r\n", b":1\r\n");
        roundtrip(&mut c, b"GET a\r\n", b"$-1\r\n");
        roundtrip(&mut c, b"INCR ctr\r\n", b":1\r\n");
        roundtrip(&mut c, b"INCR ctr\r\n", b":2\r\n");
        roundtrip(&mut c, b"GET ctr\r\n", b"$1\r\n2\r\n");
        roundtrip(&mut c, b"FLUSHALL\r\n", b"+OK\r\n");
        roundtrip(&mut c, b"GET b\r\n", b"$-1\r\n");
        drop(c);
        server.stop();
    }
}

#[test]
fn resp_all_backends_roundtrip() {
    // `--backend trust|mutex|rwlock|swift` all speak Redis now.
    for backend in [
        BackendKind::Trust { shards: 2 },
        BackendKind::Mutex,
        BackendKind::RwLock,
        BackendKind::Swift,
    ] {
        let server = start(backend, NetPolicy::default(), 2, 0);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        roundtrip(&mut c, b"SET k v\r\n", b"+OK\r\n");
        roundtrip(&mut c, b"GET k\r\n", b"$1\r\nv\r\n");
        roundtrip(&mut c, b"INCR n\r\n", b":1\r\n");
        roundtrip(&mut c, b"INCR n\r\n", b":2\r\n");
        roundtrip(&mut c, b"SET s abc\r\n", b"+OK\r\n");
        roundtrip(
            &mut c,
            b"INCR s\r\n",
            b"-ERR value is not an integer or out of range\r\n",
        );
        roundtrip(&mut c, b"DEL k s\r\n", b":2\r\n");
        roundtrip(&mut c, b"EXISTS n\r\n", b":1\r\n");
        roundtrip(&mut c, b"FLUSHALL\r\n", b"+OK\r\n");
        roundtrip(&mut c, b"EXISTS n\r\n", b":0\r\n");
        drop(c);
        server.stop();
    }
}

#[test]
fn pipelined_resp_responses_stay_ordered() {
    // The delegated backend completes out of order across shards; RESP
    // demands in-order replies — the engine's reorder spool must hold
    // completed responses until their turn.
    let server = start(BackendKind::Trust { shards: 8 }, NetPolicy::default(), 3, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    let n = 64u64;
    let mut req = Vec::new();
    for i in 0..n {
        req.extend_from_slice(format!("SET key:{i} v{i}\r\n").as_bytes());
    }
    c.write_all(&req).unwrap();
    let mut acks = vec![0u8; 5 * n as usize];
    c.read_exact(&mut acks).unwrap();
    assert_eq!(acks, b"+OK\r\n".repeat(n as usize));

    let mut req = Vec::new();
    let mut want = Vec::new();
    for i in 0..n {
        req.extend_from_slice(format!("GET key:{i}\r\n").as_bytes());
        let v = format!("v{i}");
        want.extend_from_slice(format!("${}\r\n{v}\r\n", v.len()).as_bytes());
    }
    c.write_all(&req).unwrap();
    let mut got = vec![0u8; want.len()];
    c.read_exact(&mut got).unwrap();
    assert_eq!(
        got,
        want,
        "replies out of order: got {:?}",
        String::from_utf8_lossy(&got)
    );
    drop(c);
    server.stop();
}

#[test]
fn unknown_command_and_wrong_arity_answer_errors_without_closing() {
    // Dispatch-level errors are normal replies (the connection lives on),
    // unlike parse errors which poison the stream.
    let server = start(BackendKind::Trust { shards: 2 }, NetPolicy::default(), 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    roundtrip(&mut c, b"BLAH\r\n", b"-ERR unknown command 'BLAH'\r\n");
    roundtrip(
        &mut c,
        b"GET\r\n",
        b"-ERR wrong number of arguments for 'get' command\r\n",
    );
    roundtrip(
        &mut c,
        b"SET onlykey\r\n",
        b"-ERR wrong number of arguments for 'set' command\r\n",
    );
    // Same connection still works.
    roundtrip(&mut c, b"PING\r\n", b"+PONG\r\n");
    drop(c);
    server.stop();
}

#[test]
fn parse_error_is_answered_in_order_then_closes() {
    // A valid command followed by garbage: the -ERR line must arrive
    // *after* the +OK (sequenced through the reorder spool), then the
    // server closes — mirroring the memcached ERROR-line contract.
    let server = start(BackendKind::Trust { shards: 2 }, NetPolicy::default(), 2, 0);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    c.write_all(b"SET k v\r\n*zzz\r\n").unwrap();
    let want = b"+OK\r\n-ERR Protocol error: invalid multibulk length\r\n";
    let mut got = vec![0u8; want.len()];
    c.read_exact(&mut got).unwrap();
    assert_eq!(got, &want[..], "got {:?}", String::from_utf8_lossy(&got));
    // Connection must drain to EOF after the error.
    let mut rest = Vec::new();
    c.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "unexpected bytes after protocol error: {rest:?}");
    // The worker survived: a fresh connection works.
    let mut c2 = TcpStream::connect(server.addr()).unwrap();
    roundtrip(&mut c2, b"GET k\r\n", b"$1\r\nv\r\n");
    drop(c2);
    server.stop();
}

/// One valid SET + GET round trip: the liveness probe.
fn assert_healthy(server: &RespServer, key: &str) {
    let mut c = TcpStream::connect(server.addr()).unwrap();
    roundtrip(&mut c, format!("SET {key} alive\r\n").as_bytes(), b"+OK\r\n");
    roundtrip(&mut c, format!("GET {key}\r\n").as_bytes(), b"$5\r\nalive\r\n");
}

/// Write `bytes` to a fresh connection and wait for the server to close
/// it (or ignore it); either is fine as long as no worker dies.
fn throw_garbage(server: &RespServer, bytes: &[u8]) {
    let mut c = TcpStream::connect(server.addr()).unwrap();
    // The server may close mid-write (RST): broken pipes are expected.
    let _ = c.write_all(bytes);
    let _ = c.flush();
    c.set_read_timeout(Some(std::time::Duration::from_millis(500))).unwrap();
    let mut sink = [0u8; 4096];
    loop {
        match c.read(&mut sink) {
            Ok(0) => break,    // server closed: the hardened path
            Ok(_) => continue, // an error/normal reply: also fine
            Err(_) => break,   // timeout: server ignored the bytes
        }
    }
}

#[test]
fn resp_hostile_streams_never_panic_workers() {
    for net in [NetPolicy::BusyPoll, NetPolicy::Epoll] {
        let server = start(BackendKind::Trust { shards: 2 }, net, 2, 0);
        // Hostile multibulk/bulk length announcements.
        throw_garbage(&server, b"*99999999999999999999\r\n");
        throw_garbage(&server, b"*2\r\n$99999999\r\nx\r\n");
        throw_garbage(&server, b"*1\r\n$-5\r\n\r\n");
        // Truncated valid command (half a SET), then close.
        throw_garbage(&server, b"*3\r\n$3\r\nSET\r\n$1\r\na\r\n$5\r\nhel");
        // Bulk data not CRLF-terminated where declared.
        throw_garbage(&server, b"*1\r\n$3\r\nfooXY");
        // Endless inline line.
        throw_garbage(&server, &vec![b'q'; 16 * 1024]);
        assert_healthy(&server, &format!("h-{}", net.label()));
        server.stop();
    }
}

#[test]
fn resp_random_byte_storms_never_panic_workers() {
    for net in [NetPolicy::BusyPoll, NetPolicy::Epoll] {
        let server = start(BackendKind::Trust { shards: 2 }, net, 2, 0);
        let mut rng = Rng::new(0x4E59 ^ net.label().len() as u64);
        for round in 0..16u64 {
            let len = 1 + (rng.next_u64() % 2048) as usize;
            let mut bytes = Vec::with_capacity(len);
            for _ in 0..len {
                bytes.push(rng.next_u64() as u8);
            }
            if round % 4 == 0 {
                // Sometimes lead with a valid command so the corruption
                // lands mid-stream rather than at byte zero.
                let mut framed = b"SET seed 1\r\n".to_vec();
                framed.extend_from_slice(&bytes);
                bytes = framed;
            }
            throw_garbage(&server, &bytes);
        }
        assert_healthy(&server, &format!("r-{}", net.label()));
        server.stop();
    }
}

#[test]
fn resp_with_dedicated_trustees_and_prefill() {
    let server = start(BackendKind::Trust { shards: 4 }, NetPolicy::default(), 3, 1);
    server.prefill(32, 8);
    let mut c = TcpStream::connect(server.addr()).unwrap();
    // Prefilled keys (key:<n>, 8 x 'r') are visible over the wire.
    roundtrip(&mut c, b"GET key:7\r\n", b"$8\r\nrrrrrrrr\r\n");
    roundtrip(&mut c, b"EXISTS key:0 key:31 key:32\r\n", b":2\r\n");
    drop(c);
    server.stop();
}
