//! Regression test for the mutual-clone spin cycle that DESIGN.md used to
//! carry as a *Known caveat*: two trustees that clone each other's
//! properties inside delegated closures at the same instant both take the
//! clone-ack spin path and wait on each other — each one's `+1` can only
//! be applied by the other, and neither is serving.
//!
//! The fix: while spinning for its own ack, a trustee also serves incoming
//! batches that consist solely of refcount-*increment* records
//! (`TrusteeEndpoint::serve_filtered` + `serve_rc_increment_batches`).
//! Those records touch only the property header — no user code, no
//! reclamation — so applying them re-entrantly under the in-progress
//! delegated closure is sound, and it is exactly what breaks the cycle.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use trustee::runtime::{with_worker, Runtime};
use trustee::trust::local_trustee;

#[test]
fn mutual_clone_in_delegated_contexts_resolves() {
    let rt = Runtime::builder().workers(2).build();
    let a = rt.block_on(0, || local_trustee().entrust(1u64));
    let b = rt.block_on(1, || local_trustee().entrust(2u64));

    // Rendezvous gate: both closures wait until the *other* trustee is
    // also inside its delegated closure before cloning, so the two spin
    // paths reliably overlap (the deadline keeps a broken build from
    // turning into a silent non-test).
    let gate = Arc::new(AtomicU64::new(0));

    let a1 = a.clone();
    let b1 = b.clone();
    let g1 = gate.clone();
    let h1 = rt.spawn_on_handle(0, move || {
        // Local apply on trustee 0: the closure runs in delegated context.
        a1.apply(move |x| {
            g1.fetch_add(1, Ordering::AcqRel);
            let entered = Instant::now();
            while g1.load(Ordering::Acquire) < 2
                && entered.elapsed() < Duration::from_secs(5)
            {
                // OS yield: on a 1-CPU container the peer worker needs the
                // core to reach its side of the rendezvous.
                std::thread::yield_now();
            }
            // Clone a property trusteed by worker 1 → spin-ack path.
            let extra = b1.clone();
            drop(extra);
            *x
        })
    });

    let a2 = a.clone();
    let b2 = b.clone();
    let g2 = gate.clone();
    let h2 = rt.spawn_on_handle(1, move || {
        b2.apply(move |y| {
            g2.fetch_add(1, Ordering::AcqRel);
            let entered = Instant::now();
            while g2.load(Ordering::Acquire) < 2
                && entered.elapsed() < Duration::from_secs(5)
            {
                // OS yield: on a 1-CPU container the peer worker needs the
                // core to reach its side of the rendezvous.
                std::thread::yield_now();
            }
            // Clone a property trusteed by worker 0 → spin-ack path.
            let extra = a2.clone();
            drop(extra);
            *y
        })
    });

    // A regression here deadlocks; fail loudly instead of hanging the
    // whole suite.
    let deadline = Instant::now() + Duration::from_secs(30);
    while !(h1.is_finished() && h2.is_finished()) {
        assert!(
            Instant::now() < deadline,
            "mutual-clone spin cycle did not resolve: both trustees are \
             waiting for each other's refcount ack"
        );
        std::thread::yield_now();
    }
    assert_eq!(h1.join(), 1);
    assert_eq!(h2.join(), 2);

    // Both properties survived with coherent counts: the in-closure
    // clones were acked (+1) and their drops (-1) balance out.
    let a3 = a.clone();
    let b3 = b.clone();
    assert_eq!(rt.block_on(1, move || a3.apply(|x| *x)), 1);
    assert_eq!(rt.block_on(0, move || b3.apply(|y| *y)), 2);

    // Dropping the last handles reclaims both properties (no leaked or
    // double-freed refcounts after the cycle dance).
    drop((a, b));
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let live0 = rt.block_on(0, || with_worker(|w| w.registry.live));
        let live1 = rt.block_on(1, || with_worker(|w| w.registry.live));
        if live0 == 0 && live1 == 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "properties leaked after mutual-clone cycle: {live0} on w0, {live1} on w1"
        );
    }
    rt.shutdown();
}

#[test]
fn repeated_mutual_clones_stay_coherent() {
    // Hammer the cycle breaker: many rounds of simultaneous cross-clones,
    // each round re-entering the spin path, must neither deadlock nor
    // corrupt a refcount.
    let rt = Runtime::builder().workers(2).build();
    let a = rt.block_on(0, || local_trustee().entrust(0u64));
    let b = rt.block_on(1, || local_trustee().entrust(0u64));

    for _round in 0..25 {
        let gate = Arc::new(AtomicU64::new(0));
        let (a1, b1, g1) = (a.clone(), b.clone(), gate.clone());
        let h1 = rt.spawn_on_handle(0, move || {
            a1.apply(move |x| {
                g1.fetch_add(1, Ordering::AcqRel);
                let t0 = Instant::now();
                while g1.load(Ordering::Acquire) < 2 && t0.elapsed() < Duration::from_secs(2) {
                    std::thread::yield_now();
                }
                drop(b1.clone());
                *x += 1;
            })
        });
        let (a2, b2, g2) = (a.clone(), b.clone(), gate.clone());
        let h2 = rt.spawn_on_handle(1, move || {
            b2.apply(move |y| {
                g2.fetch_add(1, Ordering::AcqRel);
                let t0 = Instant::now();
                while g2.load(Ordering::Acquire) < 2 && t0.elapsed() < Duration::from_secs(2) {
                    std::thread::yield_now();
                }
                drop(a2.clone());
                *y += 1;
            })
        });
        let deadline = Instant::now() + Duration::from_secs(30);
        while !(h1.is_finished() && h2.is_finished()) {
            assert!(Instant::now() < deadline, "cycle breaker wedged mid-round");
            std::thread::yield_now();
        }
        h1.join();
        h2.join();
    }

    let a4 = a.clone();
    let b4 = b.clone();
    assert_eq!(rt.block_on(1, move || a4.apply(|x| *x)), 25);
    assert_eq!(rt.block_on(0, move || b4.apply(|y| *y)), 25);
    drop((a, b));
    rt.shutdown();
}
