//! Deterministic interleaving tests for the adaptive-batching refactor:
//! the ordering contract every layer above the slots relies on.
//!
//! The contract (DESIGN.md, "Flush policy and ordering contract"):
//! *enqueued* is decoupled from *visible to the trustee*, but per-pair
//! FIFO survives — the outbox is FIFO, `try_flush` packs front to back,
//! the trustee applies records in batch order, and responses dispatch in
//! the same order. The channel-level tests below drive client and trustee
//! endpoints by hand on one thread, so every interleaving is exact and
//! repeatable; the runtime-level tests check the same contract end to end
//! under both flush policies.

use std::rc::Rc;
use trustee::channel::{
    read_response, ClientEndpoint, Completion, FlushPolicy, ResponseWriter, SlotPair,
    TrusteeEndpoint, FLUSH_RECORDS, HEAP_BACKPRESSURE_BYTES, MAX_INLINE_PAYLOAD,
};
use trustee::codec::{Wire, WireReader};
use trustee::runtime::Runtime;
use trustee::trust::local_trustee;

/// Fetch-and-add thunk: add the env u64 to the property, respond with the
/// pre-increment value (exposes service order on the response stream).
///
/// # Safety
/// `env` holds a framed `u64` delta; `prop` points at the live `u64`
/// property on the trustee.
unsafe fn fadd_thunk(env: *const u8, prop: *mut u8, _args: &[u8], out: &mut ResponseWriter) {
    // SAFETY: env is the framed u64 delta.
    let delta = unsafe { env.cast::<u64>().read_unaligned() };
    let p = prop.cast::<u64>();
    // SAFETY: prop is the live u64 property; thunks run serially.
    let old = unsafe { *p };
    // SAFETY: same pointer as the read above.
    unsafe { *p = old + delta };
    out.write_value(&old);
}

/// Thunk with serialized args (drives the heap path when args are large).
///
/// # Safety
/// `prop` points at the live `u64` property; `args` carry a wire vec.
unsafe fn arg_len_thunk(_env: *const u8, prop: *mut u8, args: &[u8], out: &mut ResponseWriter) {
    let mut r = WireReader::new(args);
    let v = Vec::<u8>::read(&mut r).unwrap();
    // SAFETY: prop is the live u64 property.
    unsafe { *prop.cast::<u64>() += v.len() as u64 };
    out.write_value(&(v.len() as u64));
}

fn enqueue_fadd(ep: &mut ClientEndpoint, prop: *mut u64, delta: u64, completion: Completion) {
    ep.enqueue_framed(
        fadd_thunk,
        prop as *mut u8,
        &delta.to_le_bytes(),
        completion,
        |_| {},
    );
}

#[test]
fn enqueued_is_not_visible_until_flush() {
    // Interleaving: enqueue N -> serve (nothing) -> flush -> serve (all N).
    let pair = SlotPair::default();
    let mut client = ClientEndpoint::default();
    let mut trustee = TrusteeEndpoint::default();
    let mut counter: u64 = 0;

    for _ in 0..5 {
        enqueue_fadd(
            &mut client,
            &mut counter,
            1,
            Completion::new(|r| {
                read_response::<u64>(r);
            }),
        );
    }
    assert_eq!(client.queued(), 5, "all five sit in the outbox");
    // The trustee sees nothing before the flush: enqueued != visible.
    // SAFETY: every record was framed above with matching thunk/env/prop.
    assert_eq!(unsafe { trustee.serve(&pair) }, 0);
    assert_eq!(counter, 0);

    assert_eq!(client.try_flush(&pair), 5);
    // SAFETY: every record was framed above with matching thunk/env/prop.
    assert_eq!(unsafe { trustee.serve(&pair) }, 5);
    assert_eq!(counter, 5);
    assert_eq!(client.poll(&pair), 5);
    assert_eq!(client.pending(), 0);
}

#[test]
fn watermark_requests_flush_before_record_cap() {
    // 32-byte fadd records hit the byte watermark (one slot's worth)
    // before the record-count cap.
    let mut client = ClientEndpoint::default();
    let mut counter: u64 = 0;
    let mut n = 0usize;
    while !client.wants_flush() {
        enqueue_fadd(
            &mut client,
            &mut counter,
            1,
            Completion::new(|r| {
                read_response::<u64>(r);
            }),
        );
        n += 1;
        assert!(n <= FLUSH_RECORDS, "watermark never tripped");
    }
    assert!(n > 4, "watermark should allow meaningful accumulation, got {n}");
    assert_eq!(client.backpressure_hits, 0, "byte watermark is not backpressure");

    // Drain so the endpoint drops cleanly (completions are never run in
    // this test; serve everything through a local pair).
    let pair = SlotPair::default();
    let mut trustee = TrusteeEndpoint::default();
    while client.pending() > 0 {
        client.try_flush(&pair);
        // SAFETY: every record was framed above with matching thunk/env/prop.
        unsafe { trustee.serve(&pair) };
        client.poll(&pair);
    }
}

#[test]
fn heap_records_trigger_backpressure() {
    // Records whose args exceed MAX_INLINE_PAYLOAD travel out-of-line;
    // their in-slot footprint is fixed, so only the heap accounting can
    // bound them.
    let mut client = ClientEndpoint::default();
    let mut acc: u64 = 0;
    let big = vec![0xCDu8; MAX_INLINE_PAYLOAD + 1024];
    let mut n = 0usize;
    while !client.wants_flush() {
        client.enqueue_framed(
            arg_len_thunk,
            &mut acc as *mut u64 as *mut u8,
            &[],
            Completion::new(|r| {
                read_response::<u64>(r);
            }),
            |w| big.write(w),
        );
        n += 1;
        assert!(n < 100_000, "backpressure never tripped");
    }
    assert!(client.over_heap_bound(), "only the heap bound can trip here");
    assert_eq!(
        client.backpressure_hits, 0,
        "hits count forced publishes, not enqueues over the bound"
    );
    assert!(
        n <= HEAP_BACKPRESSURE_BYTES / MAX_INLINE_PAYLOAD + 2,
        "tripped far too late: {n} records"
    );

    let pair = SlotPair::default();
    let mut trustee = TrusteeEndpoint::default();
    while client.pending() > 0 {
        client.try_flush(&pair);
        // SAFETY: every record was framed above with matching thunk/env/prop.
        unsafe { trustee.serve(&pair) };
        client.poll(&pair);
    }
    assert!(
        client.backpressure_hits >= 1,
        "publishing while over the bound must count a backpressure hit"
    );
    assert_eq!(acc, (n as u64) * (MAX_INLINE_PAYLOAD as u64 + 1024));
}

#[test]
fn fifo_preserved_across_lazy_batches() {
    // 100 increments enqueued up front, published across several batches:
    // responses (pre-increment values) must arrive in submission order —
    // exactly 0,1,2,...,99 — proving both service order and dispatch
    // order survive the decoupled flush.
    let pair = SlotPair::default();
    let mut client = ClientEndpoint::default();
    let mut trustee = TrusteeEndpoint::default();
    let mut counter: u64 = 0;

    let order: Rc<std::cell::RefCell<Vec<u64>>> = Rc::new(std::cell::RefCell::new(Vec::new()));
    for _ in 0..100 {
        let o = order.clone();
        enqueue_fadd(
            &mut client,
            &mut counter,
            1,
            Completion::new(move |r| o.borrow_mut().push(read_response::<u64>(r))),
        );
    }
    let mut batches = 0;
    while client.pending() > 0 {
        if client.try_flush(&pair) > 0 {
            batches += 1;
        }
        // SAFETY: every record was framed above with matching thunk/env/prop.
        unsafe { trustee.serve(&pair) };
        client.poll(&pair);
        assert!(batches < 1000, "no progress");
    }
    assert!(batches > 1, "100 records cannot fit one slot batch");
    assert_eq!(*order.borrow(), (0..100).collect::<Vec<u64>>());
    assert_eq!(counter, 100);
}

#[test]
fn runtime_fifo_order_under_both_policies() {
    // End-to-end: one client worker issues 300 apply_then increments with
    // interleaved blocking applies; callback order must equal submission
    // order under both the eager and the adaptive policy.
    for policy in [FlushPolicy::Eager, FlushPolicy::Adaptive] {
        let rt = Runtime::builder().workers(2).flush_policy(policy).build();
        let prop = rt.block_on(0, || local_trustee().entrust(0u64));
        let p2 = prop.clone();
        let ordered = rt.block_on(1, move || {
            let order: Rc<std::cell::RefCell<Vec<u64>>> =
                Rc::new(std::cell::RefCell::new(Vec::new()));
            for i in 0..300u64 {
                let o = order.clone();
                p2.apply_then(
                    |c| {
                        *c += 1;
                        *c - 1 // pre-increment value == submission index
                    },
                    move |v| o.borrow_mut().push(v),
                );
                if i % 50 == 49 {
                    // A blocking apply is a flush barrier: per-pair FIFO
                    // means every response before it has dispatched.
                    let seen = p2.apply(|c| *c);
                    assert_eq!(seen, i + 1, "policy {policy:?}");
                    assert_eq!(order.borrow().len() as u64, i + 1, "policy {policy:?}");
                }
            }
            let final_order = order.borrow().clone();
            final_order == (0..300).collect::<Vec<u64>>()
        });
        assert!(ordered, "responses out of order under {policy:?}");
        drop(prop);
        rt.shutdown();
    }
}

#[test]
fn adaptive_policy_batches_more_than_eager() {
    // Deterministic single-thread model of one worker's scheduler: each
    // "client phase" enqueues 8 requests; eager flushes (and the trustee,
    // modelled as keeping up, serves) after every enqueue, adaptive
    // flushes once at phase end. Every interleaving is explicit, so the
    // occupancy numbers are exact: eager degenerates to 1 request/batch,
    // adaptive packs the whole phase.
    fn occupancy(eager: bool) -> f64 {
        let pair = SlotPair::default();
        let mut client = ClientEndpoint::default();
        let mut trustee = TrusteeEndpoint::default();
        let mut counter: u64 = 0;
        let total = 256u64;
        let mut enqueued = 0u64;
        while enqueued < total || client.pending() > 0 {
            for _ in 0..8 {
                if enqueued == total {
                    break;
                }
                enqueue_fadd(
                    &mut client,
                    &mut counter,
                    1,
                    Completion::new(|r| {
                        read_response::<u64>(r);
                    }),
                );
                enqueued += 1;
                if eager {
                    client.try_flush(&pair);
                    // SAFETY: every record was framed above with matching thunk/env/prop.
                    unsafe { trustee.serve(&pair) };
                    client.poll(&pair);
                }
            }
            client.try_flush(&pair); // the end-of-client-phase flush hook
            // SAFETY: every record was framed above with matching thunk/env/prop.
            unsafe { trustee.serve(&pair) };
            client.poll(&pair);
        }
        assert_eq!(counter, total);
        client.flushed_requests as f64 / client.batches as f64
    }
    let eager = occupancy(true);
    let adaptive = occupancy(false);
    assert!((eager - 1.0).abs() < f64::EPSILON, "eager occupancy {eager}");
    assert!((adaptive - 8.0).abs() < f64::EPSILON, "adaptive occupancy {adaptive}");
}
