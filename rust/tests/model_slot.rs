//! Model-checked slot-pair toggle handoff (`channel/slot.rs` protocol,
//! ISSUE 6 tentpole part 2a).
//!
//! A closed-world model of the request/response slot pair: one client,
//! one trustee, `BATCHES` batches over a single pair. Headers use the
//! *real* [`Header`] bit packing on a [`VAtomicU64`]; payload bytes are
//! modelled by [`VCell`] words (race-checked by the explorer), and the
//! heap-spill escape hatch by a tracked allocation (use-after-free /
//! double-free checked).
//!
//! Checked across **every** schedule up to the stated preemption bound:
//!
//! - no lost batch and no double-serve (the count field carries a
//!   sequence number the trustee asserts);
//! - no stale-header read (toggle must match what the waiter expects);
//! - no torn payload read (publish/consume must be release/acquire
//!   ordered);
//! - the spill buffer is consumed exactly once.
//!
//! Two seeded bugs demonstrate the explorer catches real protocol
//! weakenings, each with a replayable schedule:
//!
//! - the client's publish store downgraded from `Release` to `Relaxed`;
//! - the client skipping the response-complete wait before reusing the
//!   slot.

#![cfg(feature = "model")]

use std::sync::atomic::Ordering::{Acquire, Relaxed, Release};
use std::sync::Arc;
use trustee::channel::slot::Header;
use trustee::model::{self, Opts};
use trustee::util::vatomic::{VAtomicU64, VCell};

/// Preemption bound every test explores exhaustively to. 2 preemptive
/// switches (plus unlimited forced switches at blocks/exits) is the
/// standard sweet spot: it covers every seeded bug here while keeping
/// the schedule space in the low thousands.
const BOUND: usize = 2;

const BATCHES: usize = 3;

fn opts() -> Opts {
    Opts { preemptions: BOUND, ..Opts::default() }
}

/// One direction of the modelled slot: the real packed header word on
/// the shim atomic, one `VCell` word standing in for each payload block,
/// and a tracked-allocation id standing in for the spill `Vec`.
struct MSlot {
    header: VAtomicU64,
    primary: VCell<u64>,
    overflow: VCell<u64>,
    spill: VCell<usize>,
}

impl MSlot {
    fn new() -> MSlot {
        MSlot {
            header: VAtomicU64::new(Header::new(false, false, 0, 0, 0).0),
            primary: VCell::new(0),
            overflow: VCell::new(0),
            spill: VCell::new(usize::MAX),
        }
    }
}

struct MPair {
    request: MSlot,
    response: MSlot,
}

/// What the client deliberately gets wrong, if anything.
#[derive(Clone, Copy, PartialEq)]
enum Seed {
    None,
    /// Publish the request header with `Relaxed` instead of `Release`.
    RelaxedPublish,
    /// Reuse the slot without waiting for response-complete.
    SkipResponseWait,
}

fn client(pair: Arc<MPair>, seed: Seed) {
    let mut toggle = false;
    for i in 1..=BATCHES {
        toggle = !toggle;
        // Fill the payload blocks *before* the publish store.
        pair.request.primary.set(100 + i as u64);
        let olen = if i % 2 == 0 { 8 } else { 0 };
        if olen > 0 {
            pair.request.overflow.set(200 + i as u64);
        }
        // Last batch exercises the heap-spill escape hatch.
        let spill = i == BATCHES;
        if spill {
            let id = model::track_alloc("spill-buffer");
            pair.request.spill.set(id);
        }
        let h = Header::new(toggle, spill, i, 8, olen);
        let order = if seed == Seed::RelaxedPublish { Relaxed } else { Release };
        pair.request.header.store(h.0, order);

        if seed != Seed::SkipResponseWait {
            // Response-complete: response toggle == published toggle.
            let want = toggle;
            let p = Arc::clone(&pair);
            model::block_until(move || Header(p.response.header.raw_load()).toggle() == want);
            let rh = Header(pair.response.header.load(Acquire));
            assert_eq!(rh.toggle(), toggle, "stale response header");
            assert_eq!(rh.count(), i, "response for the wrong batch");
            assert_eq!(
                pair.response.primary.get(),
                1000 + i as u64,
                "response payload mismatch"
            );
        }
    }
}

fn trustee(pair: Arc<MPair>) {
    let mut served = false;
    for expect in 1..=BATCHES {
        let want = !served;
        let p = Arc::clone(&pair);
        model::block_until(move || Header(p.request.header.raw_load()).toggle() == want);
        let h = Header(pair.request.header.load(Acquire));
        assert_eq!(h.toggle(), want, "stale header read");
        // The count field carries the batch sequence number: a skipped
        // or repeated batch is a lost batch / double-serve.
        assert_eq!(
            h.count(),
            expect,
            "lost batch or double-serve (expected batch {expect})"
        );
        let v = pair.request.primary.get();
        assert_eq!(v, 100 + expect as u64, "stale primary payload");
        if h.overflow_len() > 0 {
            assert_eq!(
                pair.request.overflow.get(),
                200 + expect as u64,
                "stale overflow payload"
            );
        }
        if h.spill() {
            let id = pair.request.spill.get();
            model::track_access(id); // read the spilled bytes
            model::track_free(id); // consume the buffer exactly once
        }
        // Serve: write the response payload, then publish.
        pair.response.primary.set(1000 + expect as u64);
        pair.response.header.store(Header::new(want, false, expect, 8, 0).0, Release);
        served = want;
    }
}

fn body(seed: Seed) -> impl FnMut() {
    move || {
        let pair = Arc::new(MPair { request: MSlot::new(), response: MSlot::new() });
        let p = Arc::clone(&pair);
        model::spawn(move || client(p, seed));
        model::spawn(move || trustee(pair));
    }
}

/// The real protocol is correct across every schedule up to the bound:
/// no lost batch, no double-serve, no stale header, no torn payload, and
/// the spill buffer is freed exactly once.
#[test]
fn slot_handoff_correct_under_exhaustive_exploration() {
    let report = model::explore(opts(), body(Seed::None));
    report.assert_ok();
    assert!(
        report.completed,
        "exploration must exhaust the schedule space at preemption bound {BOUND}"
    );
    assert!(
        report.schedules > 50,
        "suspiciously few schedules ({}) — yield points missing?",
        report.schedules
    );
    println!(
        "slot model: {} schedules explored exhaustively at preemption bound {BOUND} (max depth {})",
        report.schedules, report.max_depth
    );
}

/// Seeded bug 1: weakening the publish store to `Relaxed` removes the
/// happens-before edge between the payload writes and the trustee's
/// reads — the explorer must report a torn read, and the failing
/// schedule must replay to the same violation.
#[test]
fn seeded_relaxed_publish_is_caught_with_replayable_schedule() {
    let report = model::explore(opts(), body(Seed::RelaxedPublish));
    let v = report
        .violation
        .expect("explorer must catch the Relaxed-downgraded publish");
    assert!(
        v.message.contains("torn read") || v.message.contains("data race"),
        "expected a torn-read/race violation, got: {}",
        v.message
    );
    let replayed = model::replay(opts(), &v.schedule, body(Seed::RelaxedPublish))
        .expect("replaying the reported schedule must reproduce a violation");
    assert!(
        replayed.message.contains("torn read") || replayed.message.contains("data race"),
        "replay reproduced a different violation: {}",
        replayed.message
    );
}

/// Seeded bug 2: a client that reuses the slot without waiting for
/// response-complete overwrites an unserved batch — caught as a lost
/// batch, a payload race, or (if the trustee starves) a deadlock.
#[test]
fn seeded_skipped_response_wait_is_caught_with_replayable_schedule() {
    let report = model::explore(opts(), body(Seed::SkipResponseWait));
    let v = report
        .violation
        .expect("explorer must catch slot reuse before response-complete");
    let replayed = model::replay(opts(), &v.schedule, body(Seed::SkipResponseWait))
        .expect("replaying the reported schedule must reproduce a violation");
    assert_eq!(
        replayed.message, v.message,
        "replay must reproduce the same violation deterministically"
    );
}
