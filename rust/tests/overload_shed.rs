//! Wire-level overload-shedding tests: a pipelined client that bursts
//! past the shed watermark must receive its protocol's overload error —
//! `-BUSY` (RESP), `SERVER_ERROR busy` (memcached), `ST_OVERLOADED`
//! (binary KV) — on the still-open connection, never a silent close, and
//! the in-order protocols must keep request/response sequence integrity
//! through the admit/shed mix.
//!
//! Determinism: `dedicated: 1` puts the shard trustee on worker 0 and the
//! connection fiber on worker 1, so every dispatch crosses a delegation
//! channel and its completion can only land between scheduler phases —
//! a single pipelined burst therefore drives the server-wide inflight
//! gauge through the (tiny) watermark before the first completion
//! returns.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;
use trustee::kvstore::{proto, BackendKind, KvServer, KvServerConfig};
use trustee::memcache::{McdServer, McdServerConfig};
use trustee::server::{RespServer, RespServerConfig, ServerTuning};

/// A watermark low enough that one pipelined burst must cross it: two
/// cost units admitted, the third concurrent request sheds.
fn tight_tuning() -> ServerTuning {
    ServerTuning { shed_high: 2, shed_low: 2, ..ServerTuning::default() }
}

const BURST: usize = 100;

/// Read until `buf` satisfies `done`, with a deadline (avoids hanging the
/// suite if the server stops answering).
fn read_until(c: &mut TcpStream, buf: &mut Vec<u8>, mut done: impl FnMut(&[u8]) -> bool) {
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut chunk = [0u8; 16 * 1024];
    while !done(buf) {
        let n = c.read(&mut chunk).expect("read timed out waiting for replies");
        assert!(n > 0, "server closed the connection mid-burst (shed must answer, not drop)");
        buf.extend_from_slice(&chunk[..n]);
    }
}

#[test]
fn kv_burst_past_watermark_answers_every_id_with_ok_or_overloaded() {
    let server = KvServer::start(KvServerConfig {
        workers: 2,
        dedicated: 1,
        backend: BackendKind::Trust { shards: 1 },
        tuning: tight_tuning(),
        ..Default::default()
    });
    server.prefill(8, 16);
    let mut buf = Vec::new();
    for id in 0..BURST as u64 {
        proto::write_request(&mut buf, id, proto::OP_GET, &(id % 8).to_le_bytes(), &[]);
    }
    let mut c = TcpStream::connect(server.addr()).unwrap();
    c.write_all(&buf).unwrap();

    let mut cursor = proto::FrameCursor::new();
    let mut rbuf = Vec::new();
    let mut seen = vec![false; BURST];
    let (mut ok, mut shed) = (0u64, 0u64);
    let mut got = 0usize;
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut chunk = [0u8; 16 * 1024];
    while got < BURST {
        if let Some(r) = cursor.next_response(&rbuf).unwrap() {
            assert!(!seen[r.id as usize], "duplicate response for id {}", r.id);
            seen[r.id as usize] = true;
            match r.status {
                proto::ST_OK => ok += 1,
                proto::ST_OVERLOADED => shed += 1,
                s => panic!("unexpected status {s} for id {}", r.id),
            }
            got += 1;
            continue;
        }
        let n = c.read(&mut chunk).expect("read timed out");
        assert!(n > 0, "server closed mid-burst (shed must answer, not drop)");
        rbuf.extend_from_slice(&chunk[..n]);
    }
    assert_eq!(ok + shed, BURST as u64);
    assert!(ok >= 2, "the first requests under the watermark must be served (ok={ok})");
    assert!(shed >= 1, "a {BURST}-deep burst over shed_high=2 must shed");
    assert_eq!(server.metrics().totals().shed, shed, "shed metric must match wire replies");
    server.stop();
}

#[test]
fn mcd_burst_keeps_reply_order_through_the_shed_mix() {
    let server = McdServer::start(McdServerConfig {
        workers: 2,
        dedicated: 1,
        backend: BackendKind::Trust { shards: 1 },
        tuning: tight_tuning(),
        ..Default::default()
    });
    server.prefill(8, 8);
    let mut buf = Vec::new();
    for i in 0..BURST {
        buf.extend_from_slice(format!("get memtier-{}\r\n", i % 8).as_bytes());
    }
    let mut c = TcpStream::connect(server.addr()).unwrap();
    c.write_all(&buf).unwrap();

    // Each reply is VALUE <echoed key> … END (served) or the busy line
    // (shed). The echoed key pins every served reply to its position in
    // the request pipeline: sequence integrity, not just totality.
    let mut rbuf = Vec::new();
    let (mut ok, mut shed) = (0u64, 0u64);
    let mut pos = 0usize;
    for i in 0..BURST {
        let line_end = loop {
            if let Some(nl) = rbuf[pos..].windows(2).position(|w| w == b"\r\n") {
                break pos + nl;
            }
            read_until(&mut c, &mut rbuf, |b| b[pos..].windows(2).any(|w| w == b"\r\n"));
        };
        let line = rbuf[pos..line_end].to_vec();
        if line == b"SERVER_ERROR busy" {
            shed += 1;
            pos = line_end + 2;
            continue;
        }
        let want = format!("VALUE memtier-{} 0 8", i % 8);
        assert_eq!(
            String::from_utf8_lossy(&line),
            want,
            "reply {i} out of sequence (shed mix must not reorder)"
        );
        // data block + END\r\n
        let need = line_end + 2 + 8 + 2 + 5;
        read_until(&mut c, &mut rbuf, |b| b.len() >= need);
        assert_eq!(&rbuf[need - 5..need], b"END\r\n");
        pos = need;
        ok += 1;
    }
    assert_eq!(ok + shed, BURST as u64);
    assert!(ok >= 2, "requests under the watermark must be served (ok={ok})");
    assert!(shed >= 1, "a {BURST}-deep burst over shed_high=2 must shed");
    assert_eq!(server.metrics().totals().shed, shed);
    server.stop();
}

#[test]
fn resp_incr_burst_sheds_with_busy_and_preserves_sequence() {
    let server = RespServer::start(RespServerConfig {
        workers: 2,
        dedicated: 1,
        backend: BackendKind::Trust { shards: 1 },
        tuning: tight_tuning(),
        ..Default::default()
    });
    let mut buf = Vec::new();
    for _ in 0..BURST {
        buf.extend_from_slice(b"*2\r\n$4\r\nINCR\r\n$3\r\nctr\r\n");
    }
    let mut c = TcpStream::connect(server.addr()).unwrap();
    c.write_all(&buf).unwrap();

    // Served INCRs return :1, :2, :3, … — shed ones return -BUSY and do
    // NOT advance the counter, so the integer subsequence must be exactly
    // 1..=ok in order. Any reordering or double-execution breaks it.
    let mut rbuf = Vec::new();
    let (mut ok, mut shed) = (0u64, 0u64);
    let mut pos = 0usize;
    for i in 0..BURST {
        read_until(&mut c, &mut rbuf, |b| b[pos..].windows(2).any(|w| w == b"\r\n"));
        let nl = pos + rbuf[pos..].windows(2).position(|w| w == b"\r\n").unwrap();
        let line = &rbuf[pos..nl];
        match line.first().copied() {
            Some(b':') => {
                let n: u64 = std::str::from_utf8(&line[1..]).unwrap().parse().unwrap();
                assert_eq!(n, ok + 1, "reply {i}: counter out of sequence");
                ok += 1;
            }
            Some(b'-') => {
                assert!(
                    line.starts_with(b"-BUSY"),
                    "reply {i}: unexpected error {:?}",
                    String::from_utf8_lossy(line)
                );
                shed += 1;
            }
            other => panic!("reply {i}: unexpected type byte {other:?}"),
        }
        pos = nl + 2;
    }
    assert_eq!(ok + shed, BURST as u64);
    assert!(ok >= 2, "requests under the watermark must be served (ok={ok})");
    assert!(shed >= 1, "a {BURST}-deep burst over shed_high=2 must shed");
    assert_eq!(server.metrics().totals().shed, shed);
    server.stop();
}
