//! A miniature property-testing harness (proptest/quickcheck are
//! unavailable offline — DESIGN.md substitution #6).
//!
//! Provides [`Arbitrary`] generation from the crate PRNG, a [`check`]
//! driver that runs N random cases, and greedy shrinking on failure so
//! counterexamples are reported minimally. Used by the channel, codec,
//! trust, and cmap test suites for their invariant properties.

use super::rng::Rng;

/// Types that can be generated randomly and shrunk toward smaller values.
pub trait Arbitrary: Clone + std::fmt::Debug {
    fn arbitrary(rng: &mut Rng, size: usize) -> Self;
    /// Candidate strictly-smaller values to try when shrinking.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

macro_rules! arb_uint {
    ($($t:ty),*) => {$(
        impl Arbitrary for $t {
            fn arbitrary(rng: &mut Rng, size: usize) -> Self {
                // Mix small values (edge-case rich) with full-range ones.
                match rng.below(4) {
                    0 => (rng.below((size as u64).max(1) + 1)) as $t,
                    1 => match rng.below(5) {
                        0 => 0,
                        1 => 1,
                        2 => <$t>::MAX,
                        3 => <$t>::MAX - 1,
                        _ => (<$t>::MAX >> 1),
                    },
                    _ => rng.next_u64() as $t,
                }
            }
            fn shrink(&self) -> Vec<Self> {
                let mut out = Vec::new();
                if *self > 0 { out.push(0); }
                if *self > 1 { out.push(*self / 2); out.push(*self - 1); }
                out
            }
        }
    )*};
}
arb_uint!(u8, u16, u32, u64, usize);

macro_rules! arb_int {
    ($($t:ty),*) => {$(
        impl Arbitrary for $t {
            fn arbitrary(rng: &mut Rng, size: usize) -> Self {
                let mag = <u64 as Arbitrary>::arbitrary(rng, size) as $t;
                if rng.chance(0.5) { mag } else { mag.wrapping_neg() }
            }
            fn shrink(&self) -> Vec<Self> {
                let mut out = Vec::new();
                if *self != 0 { out.push(0); out.push(*self / 2); }
                if *self < 0 { out.push(-*self); }
                out
            }
        }
    )*};
}
arb_int!(i8, i16, i32, i64);

impl Arbitrary for bool {
    fn arbitrary(rng: &mut Rng, _size: usize) -> Self {
        rng.chance(0.5)
    }
    fn shrink(&self) -> Vec<Self> {
        if *self {
            vec![false]
        } else {
            vec![]
        }
    }
}

impl Arbitrary for f64 {
    fn arbitrary(rng: &mut Rng, _size: usize) -> Self {
        match rng.below(6) {
            0 => 0.0,
            1 => 1.0,
            2 => -1.0,
            3 => f64::from_bits(rng.next_u64() & !(0x7ff << 52)), // finite-ish subnormal mix
            _ => (rng.unit_f64() - 0.5) * 1e12,
        }
    }
}

impl Arbitrary for String {
    fn arbitrary(rng: &mut Rng, size: usize) -> Self {
        let len = rng.below(size as u64 + 1) as usize;
        (0..len)
            .map(|_| {
                // Mostly ASCII, sometimes multi-byte.
                if rng.chance(0.9) {
                    (b' ' + rng.below(95) as u8) as char
                } else {
                    char::from_u32(0x100 + rng.next_u32() % 0x500).unwrap_or('x')
                }
            })
            .collect()
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(String::new());
            let mid: String = self.chars().take(self.chars().count() / 2).collect();
            out.push(mid);
        }
        out
    }
}

impl<T: Arbitrary> Arbitrary for Vec<T> {
    fn arbitrary(rng: &mut Rng, size: usize) -> Self {
        let len = rng.below(size as u64 + 1) as usize;
        (0..len).map(|_| T::arbitrary(rng, size)).collect()
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.is_empty() {
            out.push(Vec::new());
            out.push(self[..self.len() / 2].to_vec());
            let mut tail = self.clone();
            tail.remove(0);
            out.push(tail);
            // Also shrink one element.
            if let Some(smaller) = self[0].shrink().into_iter().next() {
                let mut v = self.clone();
                v[0] = smaller;
                out.push(v);
            }
        }
        out
    }
}

impl<T: Arbitrary> Arbitrary for Option<T> {
    fn arbitrary(rng: &mut Rng, size: usize) -> Self {
        if rng.chance(0.2) {
            None
        } else {
            Some(T::arbitrary(rng, size))
        }
    }
    fn shrink(&self) -> Vec<Self> {
        match self {
            None => vec![],
            Some(x) => {
                let mut out = vec![None];
                out.extend(x.shrink().into_iter().map(Some));
                out
            }
        }
    }
}

// Tuple shrinking needs per-field access; implement the common arities by
// hand rather than through a macro.
impl<A: Arbitrary, B: Arbitrary> Arbitrary for (A, B) {
    fn arbitrary(rng: &mut Rng, size: usize) -> Self {
        (A::arbitrary(rng, size), B::arbitrary(rng, size))
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Arbitrary, B: Arbitrary, C: Arbitrary> Arbitrary for (A, B, C) {
    fn arbitrary(rng: &mut Rng, size: usize) -> Self {
        (
            A::arbitrary(rng, size),
            B::arbitrary(rng, size),
            C::arbitrary(rng, size),
        )
    }
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrink()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2
                .shrink()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

impl<A: Arbitrary, B: Arbitrary, C: Arbitrary, D: Arbitrary> Arbitrary for (A, B, C, D) {
    fn arbitrary(rng: &mut Rng, size: usize) -> Self {
        (
            A::arbitrary(rng, size),
            B::arbitrary(rng, size),
            C::arbitrary(rng, size),
            D::arbitrary(rng, size),
        )
    }
    fn shrink(&self) -> Vec<Self> {
        let (a, b, c, d) = self;
        let mut out: Vec<Self> = a
            .shrink()
            .into_iter()
            .map(|a| (a, b.clone(), c.clone(), d.clone()))
            .collect();
        out.extend(b.shrink().into_iter().map(|b| (a.clone(), b, c.clone(), d.clone())));
        out.extend(c.shrink().into_iter().map(|c| (a.clone(), b.clone(), c, d.clone())));
        out.extend(d.shrink().into_iter().map(|d| (a.clone(), b.clone(), c.clone(), d)));
        out
    }
}

impl<A: Arbitrary, B: Arbitrary, C: Arbitrary, D: Arbitrary, E: Arbitrary> Arbitrary
    for (A, B, C, D, E)
{
    fn arbitrary(rng: &mut Rng, size: usize) -> Self {
        (
            A::arbitrary(rng, size),
            B::arbitrary(rng, size),
            C::arbitrary(rng, size),
            D::arbitrary(rng, size),
            E::arbitrary(rng, size),
        )
    }
    fn shrink(&self) -> Vec<Self> {
        let (a, b, c, d, e) = self;
        let mut out: Vec<Self> = a
            .shrink()
            .into_iter()
            .map(|a| (a, b.clone(), c.clone(), d.clone(), e.clone()))
            .collect();
        out.extend(
            e.shrink()
                .into_iter()
                .map(|e| (a.clone(), b.clone(), c.clone(), d.clone(), e)),
        );
        out
    }
}

/// Run `cases` random checks of `prop`; on failure, shrink greedily and
/// panic with the minimal counterexample found.
pub fn check<T: Arbitrary>(name: &str, cases: usize, prop: impl Fn(&T) -> bool) {
    check_seeded(name, 0xC0FFEE ^ name.len() as u64, cases, prop)
}

/// Like [`check`] with an explicit seed (for reproducing failures).
pub fn check_seeded<T: Arbitrary>(
    name: &str,
    seed: u64,
    cases: usize,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        // Grow the size budget over the run: early cases are tiny.
        let size = 1 + case * 64 / cases.max(1);
        let input = T::arbitrary(&mut rng, size);
        if !prop(&input) {
            let minimal = shrink_loop(input, &prop);
            panic!(
                "property {name:?} failed (seed={seed:#x}, case={case});\n\
                 minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<T: Arbitrary>(mut failing: T, prop: &impl Fn(&T) -> bool) -> T {
    // Greedy descent, bounded to avoid pathological shrink graphs.
    'outer: for _ in 0..1000 {
        for cand in failing.shrink() {
            if !prop(&cand) {
                failing = cand;
                continue 'outer;
            }
        }
        break;
    }
    failing
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check::<u64>("reflexive-eq", 200, |x| x == x);
        check::<(u32, u32)>("add-comm", 200, |(a, b)| {
            a.wrapping_add(*b) == b.wrapping_add(*a)
        });
        check::<Vec<u8>>("rev-rev", 100, |v| {
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            r == *v
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let caught = std::panic::catch_unwind(|| {
            check::<u64>("always-small", 500, |&x| x < 10);
        });
        let msg = match caught {
            Ok(_) => panic!("property should have failed"),
            Err(e) => *e.downcast::<String>().expect("panic payload"),
        };
        // Greedy shrink of (x >= 10) should land on exactly 10.
        assert!(msg.contains("counterexample: 10"), "got: {msg}");
    }

    #[test]
    fn vec_shrinks_toward_empty() {
        let caught = std::panic::catch_unwind(|| {
            check::<Vec<u8>>("always-empty", 500, |v| v.is_empty());
        });
        let msg = match caught {
            Ok(_) => panic!("property should have failed"),
            Err(e) => *e.downcast::<String>().expect("panic payload"),
        };
        assert!(msg.contains("counterexample: [0]"), "got: {msg}");
    }

    #[test]
    fn string_arbitrary_valid_utf8() {
        check::<String>("string-len", 200, |s| s.chars().count() <= s.len());
    }
}
