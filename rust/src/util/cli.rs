//! A small `--key value` command-line parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean flags (`--flag`), and
//! typed access with defaults. Bench binaries receive extra arguments from
//! `cargo bench -- ...`; unknown keys starting with `--` that cargo's
//! libtest harness would add (`--bench`) are tolerated.

use std::collections::BTreeMap;
use std::str::FromStr;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse from an explicit iterator of tokens.
    pub fn parse<I, S>(it: I) -> Args
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Args::default();
        let toks: Vec<String> = it.into_iter().map(Into::into).collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(body) = t.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.kv.insert(body[..eq].to_string(), body[eq + 1..].to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.kv.insert(body.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        out
    }

    /// Typed lookup with a default.
    pub fn get<T: FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        match self.kv.get(key) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key} {v:?}: parse error {e:?}")),
            None => default,
        }
    }

    /// String lookup with a default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional lookup.
    pub fn opt<T: FromStr>(&self, key: &str) -> Option<T>
    where
        T::Err: std::fmt::Debug,
    {
        self.kv.get(key).map(|v| {
            v.parse()
                .unwrap_or_else(|e| panic!("--{key} {v:?}: parse error {e:?}"))
        })
    }

    /// Is a bare `--flag` present?
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Comma-separated list of T (`--sizes 1,4,16`).
    pub fn get_list<T: FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
        T::Err: std::fmt::Debug,
    {
        match self.kv.get(key) {
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|e| panic!("--{key} item {s:?}: {e:?}"))
                })
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_kv_and_flags() {
        // NB: bare flags must come last or use `--flag` followed by another
        // `--` token — a bare flag followed by a positional is ambiguous and
        // parses as key/value.
        let a = Args::parse(["pos1", "--threads", "8", "--dist=zipf", "--verbose"]);
        assert_eq!(a.get::<usize>("threads", 1), 8);
        assert_eq!(a.get_str("dist", "uniform"), "zipf");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(Vec::<String>::new());
        assert_eq!(a.get::<u64>("ops", 1000), 1000);
        assert_eq!(a.get_str("dist", "uniform"), "uniform");
        assert!(!a.flag("quick"));
        assert_eq!(a.opt::<u64>("seed"), None);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = Args::parse(["--quick", "--threads", "4"]);
        assert!(a.flag("quick"));
        assert_eq!(a.get::<usize>("threads", 1), 4);
    }

    #[test]
    fn lists() {
        let a = Args::parse(["--sizes", "1,4,16"]);
        assert_eq!(a.get_list::<u64>("sizes", &[]), vec![1, 4, 16]);
        assert_eq!(a.get_list::<u64>("other", &[7]), vec![7]);
    }

    #[test]
    #[should_panic]
    fn bad_parse_panics() {
        let a = Args::parse(["--threads", "abc"]);
        let _: usize = a.get("threads", 1);
    }
}
