//! Deterministic fault injection at the syscall boundary.
//!
//! Behind the `faults` cargo feature (off by default, like `model`), this
//! module interposes on the handful of places the crate touches the
//! kernel for network I/O — the reactor's `epoll_wait`, the ring's
//! `io_uring_enter`, and the `read`/`write`/`accept` paths in
//! `server::netfiber` — and injects the failures a production deployment
//! will eventually see: `EAGAIN`, `EINTR`, `ECONNRESET`, `EMFILE`, short
//! reads, short writes, and failed ring submissions.
//!
//! Decisions are **deterministic given a seed**: each injection site owns
//! an attempt counter, and the (seed, site, attempt-index) triple is
//! hashed through SplitMix64 to a fault/no-fault decision. Two runs with
//! the same seed and the same per-site call sequences inject the same
//! faults, regardless of thread scheduling across sites — which is what
//! makes a chaos failure replayable from its logged seed.
//!
//! Configuration is either programmatic ([`install`]) or via the
//! `TRUSTEE_FAULTS=seed:rate:mask` environment variable, where `rate` is
//! the injection probability in basis points (1/10,000ths; `100` = 1%)
//! and `mask` is a bitwise OR of the `MASK_*` fault-kind bits (`0` or a
//! missing variable disables injection). Per-site counters record how
//! many faults actually fired so tests can assert a plan exercised every
//! site ([`injected`]).
//!
//! With the feature disabled every probe in this module compiles to an
//! inline `None`/`false` constant — the production hot path pays nothing
//! (enforced by `tests/alloc_regression.rs` and the bench suite running
//! without the feature).

/// Injection sites, one per interposed syscall boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Site {
    /// Socket reads (`server::netfiber::read_burst` / `read_available`).
    Read,
    /// Socket writes (`server::netfiber::write_pending`).
    Write,
    /// Accept paths (fiber, busy-poll thread, and uring acceptor).
    Accept,
    /// The reactor's `epoll_wait` (simulated `EINTR`).
    EpollWait,
    /// The ring's `io_uring_enter` (simulated submission failure).
    UringEnter,
    /// Data-plane RECV completions (simulated `ENOBUFS` pool exhaustion
    /// and split segment delivery). Injections here are **lossless**:
    /// the chaos loaders treat any desync as corruption, so both kinds
    /// deliver every byte and only perturb *how* it arrives.
    UringRecv,
}

/// Number of [`Site`] variants (sizes the per-site counter arrays).
pub const NSITES: usize = 6;

impl Site {
    /// Stable per-site array index (counter slots; also used by tests to
    /// index per-site tallies).
    pub fn index(self) -> usize {
        match self {
            Site::Read => 0,
            Site::Write => 1,
            Site::Accept => 2,
            Site::EpollWait => 3,
            Site::UringEnter => 4,
            Site::UringRecv => 5,
        }
    }

    /// Human label for logs ("replay with TRUSTEE_FAULTS=…").
    pub fn label(self) -> &'static str {
        match self {
            Site::Read => "read",
            Site::Write => "write",
            Site::Accept => "accept",
            Site::EpollWait => "epoll_wait",
            Site::UringEnter => "io_uring_enter",
            Site::UringRecv => "uring_recv",
        }
    }
}

/// What a read-site injection tells the caller to pretend happened.
#[cfg_attr(not(feature = "faults"), allow(dead_code))]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReadFault {
    /// Pretend the socket returned `EAGAIN` (no bytes this pass).
    Eagain,
    /// Pretend the peer reset the connection (`ECONNRESET`).
    ConnReset,
    /// Deliver at most this many bytes this pass (short read).
    Short(usize),
}

/// What a write-site injection tells the caller to pretend happened.
#[cfg_attr(not(feature = "faults"), allow(dead_code))]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteFault {
    /// Pretend the socket returned `EAGAIN` (nothing written this pass).
    Eagain,
    /// Pretend the peer reset the connection (`ECONNRESET`).
    ConnReset,
    /// Write at most one byte this pass (short write).
    Short,
}

/// What a data-plane RECV injection tells the reactor to pretend
/// happened (both kinds deliver every byte — see [`Site::UringRecv`]).
#[cfg_attr(not(feature = "faults"), allow(dead_code))]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UringRecvFault {
    /// Deliver the data, then pretend the pool ran dry: disarm the
    /// multishot RECV as `-ENOBUFS` would, exercising the starved
    /// re-arm-on-recycle machinery.
    Enobufs,
    /// Split the delivered segment in two queue entries so the frame
    /// parser sees a mid-frame boundary (partial-frame copy path).
    Short,
}

/// Fault-kind mask bits for [`install`] / `TRUSTEE_FAULTS`.
pub const MASK_EAGAIN: u32 = 1 << 0;
pub const MASK_EINTR: u32 = 1 << 1;
pub const MASK_CONNRESET: u32 = 1 << 2;
pub const MASK_EMFILE: u32 = 1 << 3;
pub const MASK_SHORT_READ: u32 = 1 << 4;
pub const MASK_SHORT_WRITE: u32 = 1 << 5;
pub const MASK_URING_ENTER: u32 = 1 << 6;
pub const MASK_URING_ENOBUFS: u32 = 1 << 7;
pub const MASK_URING_SHORT_RECV: u32 = 1 << 8;
/// Every fault kind.
pub const MASK_ALL: u32 = (1 << 9) - 1;

#[cfg(feature = "faults")]
mod imp {
    use super::*;
    use crate::util::rng::splitmix64;
    use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
    use std::sync::Once;

    /// Fast-path gate: a single relaxed load on every probe while no plan
    /// is installed.
    static ENABLED: AtomicBool = AtomicBool::new(false);
    static SEED: AtomicU64 = AtomicU64::new(0);
    /// Injection probability in basis points (1/10,000ths).
    static RATE_BP: AtomicU32 = AtomicU32::new(0);
    static MASK: AtomicU32 = AtomicU32::new(0);
    /// Per-site attempt counters (the deterministic decision index).
    static ATTEMPTS: [AtomicU64; NSITES] = [
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
    ];
    /// Per-site counters of faults that actually fired.
    static INJECTED: [AtomicU64; NSITES] = [
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
        AtomicU64::new(0),
    ];
    static ENV_INIT: Once = Once::new();

    /// Install a fault plan: `rate_bp` is the per-probe injection
    /// probability in basis points, `mask` selects fault kinds
    /// (`MASK_*`). Resets the attempt and injected counters so a test's
    /// assertions see only its own plan.
    pub fn install(seed: u64, rate_bp: u32, mask: u32) {
        SEED.store(seed, Ordering::Relaxed);
        RATE_BP.store(rate_bp.min(10_000), Ordering::Relaxed);
        MASK.store(mask, Ordering::Relaxed);
        for i in 0..NSITES {
            ATTEMPTS[i].store(0, Ordering::Relaxed);
            INJECTED[i].store(0, Ordering::Relaxed);
        }
        ENABLED.store(rate_bp > 0 && mask != 0, Ordering::Release);
    }

    /// Disable injection (counters are left readable for assertions).
    pub fn clear() {
        ENABLED.store(false, Ordering::Release);
    }

    /// Parse `TRUSTEE_FAULTS=seed:rate:mask` and install it. Returns
    /// whether a plan was installed. Numbers accept a `0x` hex prefix.
    pub fn install_from_env() -> bool {
        let spec = match std::env::var("TRUSTEE_FAULTS") {
            Ok(s) if !s.is_empty() => s,
            _ => return false,
        };
        let mut parts = spec.splitn(3, ':');
        let num = |s: Option<&str>| -> Option<u64> {
            let s = s?.trim();
            if let Some(hex) = s.strip_prefix("0x") {
                u64::from_str_radix(hex, 16).ok()
            } else {
                s.parse().ok()
            }
        };
        match (num(parts.next()), num(parts.next()), num(parts.next())) {
            (Some(seed), Some(rate), Some(mask)) => {
                install(seed, rate as u32, mask as u32);
                true
            }
            _ => {
                eprintln!("TRUSTEE_FAULTS: expected seed:rate:mask, got {spec:?}; ignored");
                false
            }
        }
    }

    /// Faults that actually fired at `site` under the current plan.
    pub fn injected(site: Site) -> u64 {
        INJECTED[site.index()].load(Ordering::Relaxed)
    }

    /// The installed plan as a replay spec (`seed:rate:mask`), if any.
    pub fn plan_spec() -> Option<String> {
        if !ENABLED.load(Ordering::Acquire) {
            return None;
        }
        Some(format!(
            "{}:{}:0x{:x}",
            SEED.load(Ordering::Relaxed),
            RATE_BP.load(Ordering::Relaxed),
            MASK.load(Ordering::Relaxed)
        ))
    }

    /// Deterministic per-(site, attempt) decision: returns the subset of
    /// `candidates` the plan picked, or 0 for "no fault".
    fn decide(site: Site, candidates: u32) -> u32 {
        ENV_INIT.call_once(|| {
            install_from_env();
        });
        if !ENABLED.load(Ordering::Relaxed) {
            return 0;
        }
        let candidates = candidates & MASK.load(Ordering::Relaxed);
        if candidates == 0 {
            return 0;
        }
        let attempt = ATTEMPTS[site.index()].fetch_add(1, Ordering::Relaxed);
        // Hash (seed, site, attempt) so decisions are independent of the
        // interleaving of *other* sites' probes.
        let mut s = SEED
            .load(Ordering::Relaxed)
            .wrapping_add((site.index() as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(attempt.wrapping_mul(0xBF58_476D_1CE4_E5B9));
        let r = splitmix64(&mut s);
        if (r % 10_000) as u32 >= RATE_BP.load(Ordering::Relaxed) {
            return 0;
        }
        // Pick one of the candidate kinds with a second draw.
        let n = candidates.count_ones();
        let pick = (splitmix64(&mut s) % n as u64) as u32;
        let mut rem = candidates;
        for _ in 0..pick {
            rem &= rem - 1; // drop lowest set bit
        }
        let kind = rem & rem.wrapping_neg(); // isolate lowest set bit
        INJECTED[site.index()].fetch_add(1, Ordering::Relaxed);
        kind
    }

    /// Probe the read site. `Some` overrides the real socket read.
    #[inline]
    pub fn read_fault() -> Option<ReadFault> {
        match decide(Site::Read, MASK_EAGAIN | MASK_CONNRESET | MASK_SHORT_READ) {
            MASK_EAGAIN => Some(ReadFault::Eagain),
            MASK_CONNRESET => Some(ReadFault::ConnReset),
            MASK_SHORT_READ => Some(ReadFault::Short(1)),
            _ => None,
        }
    }

    /// Probe the write site. `Some` overrides the real socket write.
    #[inline]
    pub fn write_fault() -> Option<WriteFault> {
        match decide(Site::Write, MASK_EAGAIN | MASK_CONNRESET | MASK_SHORT_WRITE) {
            MASK_EAGAIN => Some(WriteFault::Eagain),
            MASK_CONNRESET => Some(WriteFault::ConnReset),
            MASK_SHORT_WRITE => Some(WriteFault::Short),
            _ => None,
        }
    }

    /// Probe the accept site: `true` simulates `EMFILE` (the acceptor
    /// must take its backoff path instead of retrying hot).
    #[inline]
    pub fn accept_fault() -> bool {
        decide(Site::Accept, MASK_EMFILE) != 0
    }

    /// Probe the `epoll_wait` site: `true` simulates `EINTR` (the poll
    /// returns no events; the caller's next tick retries).
    #[inline]
    pub fn epoll_fault() -> bool {
        decide(Site::EpollWait, MASK_EINTR) != 0
    }

    /// Probe the `io_uring_enter` site: `true` simulates a failed enter
    /// (staged SQEs stay staged; the next flush resubmits them).
    #[inline]
    pub fn uring_enter_fault() -> bool {
        decide(Site::UringEnter, MASK_URING_ENTER) != 0
    }

    /// Probe the data-plane RECV site. `Some` perturbs (losslessly) how
    /// a delivered segment surfaces to the engine.
    #[inline]
    pub fn uring_recv_fault() -> Option<UringRecvFault> {
        match decide(Site::UringRecv, MASK_URING_ENOBUFS | MASK_URING_SHORT_RECV) {
            MASK_URING_ENOBUFS => Some(UringRecvFault::Enobufs),
            MASK_URING_SHORT_RECV => Some(UringRecvFault::Short),
            _ => None,
        }
    }
}

#[cfg(not(feature = "faults"))]
mod imp {
    use super::*;

    /// No-op without the `faults` feature (plan ignored).
    #[inline(always)]
    pub fn install(_seed: u64, _rate_bp: u32, _mask: u32) {}

    /// No-op without the `faults` feature.
    #[inline(always)]
    pub fn clear() {}

    /// Always `false` without the `faults` feature.
    #[inline(always)]
    pub fn install_from_env() -> bool {
        false
    }

    /// Always 0 without the `faults` feature.
    #[inline(always)]
    pub fn injected(_site: Site) -> u64 {
        0
    }

    /// Always `None` without the `faults` feature.
    #[inline(always)]
    pub fn plan_spec() -> Option<String> {
        None
    }

    #[inline(always)]
    pub fn read_fault() -> Option<ReadFault> {
        None
    }

    #[inline(always)]
    pub fn write_fault() -> Option<WriteFault> {
        None
    }

    #[inline(always)]
    pub fn accept_fault() -> bool {
        false
    }

    #[inline(always)]
    pub fn epoll_fault() -> bool {
        false
    }

    #[inline(always)]
    pub fn uring_enter_fault() -> bool {
        false
    }

    #[inline(always)]
    pub fn uring_recv_fault() -> Option<UringRecvFault> {
        None
    }
}

pub use imp::*;

#[cfg(all(test, feature = "faults"))]
mod tests {
    use super::*;
    use std::sync::Mutex;

    /// The plan is process-global state; tests that install one must not
    /// interleave. Shared with `tests/chaos.rs` conceptually (that file
    /// is a separate binary, so only in-file serialization is needed).
    static LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_plan_injects_nothing() {
        let _g = LOCK.lock().unwrap();
        install(1, 0, MASK_ALL);
        for _ in 0..100 {
            assert_eq!(read_fault(), None);
            assert!(!accept_fault());
        }
        assert_eq!(injected(Site::Read), 0);
        clear();
    }

    #[test]
    fn same_seed_same_decisions() {
        let _g = LOCK.lock().unwrap();
        let run = || {
            install(0xDEAD_BEEF, 2_500, MASK_ALL);
            let seq: Vec<Option<ReadFault>> = (0..64).map(|_| read_fault()).collect();
            let fired = injected(Site::Read);
            clear();
            (seq, fired)
        };
        let (a, fa) = run();
        let (b, fb) = run();
        assert_eq!(a, b, "decisions must replay given the seed");
        assert_eq!(fa, fb);
        assert!(fa > 0, "25% over 64 attempts must fire at least once");
    }

    #[test]
    fn sites_decide_independently() {
        let _g = LOCK.lock().unwrap();
        // Interleaving another site's probes must not perturb read-site
        // decisions: the decision index is per-site.
        install(42, 5_000, MASK_ALL);
        let plain: Vec<Option<ReadFault>> = (0..32).map(|_| read_fault()).collect();
        install(42, 5_000, MASK_ALL);
        let interleaved: Vec<Option<ReadFault>> = (0..32)
            .map(|_| {
                accept_fault();
                epoll_fault();
                read_fault()
            })
            .collect();
        assert_eq!(plain, interleaved);
        clear();
    }

    #[test]
    fn mask_restricts_kinds() {
        let _g = LOCK.lock().unwrap();
        install(7, 10_000, MASK_CONNRESET);
        for _ in 0..32 {
            assert_eq!(read_fault(), Some(ReadFault::ConnReset));
            // Accept has no candidate under this mask: never fires.
            assert!(!accept_fault());
        }
        assert_eq!(injected(Site::Read), 32);
        assert_eq!(injected(Site::Accept), 0);
        clear();
    }

    #[test]
    fn plan_spec_round_trips() {
        let _g = LOCK.lock().unwrap();
        install(9, 100, MASK_EAGAIN | MASK_EMFILE);
        assert_eq!(plan_spec().as_deref(), Some("9:100:0x9"));
        clear();
        assert_eq!(plan_spec(), None);
    }
}
