//! Measurement substrates: latency histograms, running statistics, and
//! human-readable throughput formatting for the benchmark harnesses.
//!
//! The latency histogram is an HDR-style log-bucketed design: values are
//! bucketed by (exponent, 5-bit mantissa), giving ~3% relative error across
//! the full u64 range in 64×32 fixed buckets — enough resolution for the
//! paper's mean / p99.9 reporting (§6.2) without per-sample storage.

/// Log-bucketed histogram of u64 samples (e.g. nanoseconds).
#[derive(Clone)]
pub struct LatencyHist {
    /// buckets[exp][mantissa-top-5-bits]
    buckets: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

const MANTISSA_BITS: u32 = 5;
const SUB: usize = 1 << MANTISSA_BITS; // 32 sub-buckets per power of two

impl Default for LatencyHist {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHist {
    pub fn new() -> Self {
        LatencyHist {
            buckets: vec![0; 64 * SUB],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    #[inline]
    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize; // exact for tiny values
        }
        let exp = 63 - v.leading_zeros();
        let mant = ((v >> (exp - MANTISSA_BITS)) & (SUB as u64 - 1)) as usize;
        (exp as usize) * SUB + mant
    }

    /// Representative (upper-bound) value for a bucket index.
    fn value(idx: usize) -> u64 {
        if idx < SUB {
            return idx as u64;
        }
        let exp = (idx / SUB) as u32;
        let mant = (idx % SUB) as u64;
        (1u64 << exp) + ((mant + 1) << (exp - MANTISSA_BITS)) - 1
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum as f64 / self.count as f64
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Value at quantile `q` in [0, 1] (e.g. 0.999 for p99.9), with ~3%
    /// relative bucket error.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::value(i).min(self.max).max(self.min);
            }
        }
        self.max
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += *b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Welford online mean/variance accumulator.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Half-width of a ~95% confidence interval (normal approximation).
    pub fn ci95(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        1.96 * self.stddev() / (self.n as f64).sqrt()
    }
}

/// Format an operations-per-second figure the way the paper's plots do
/// (MOPs with 2–3 significant digits).
pub fn fmt_mops(ops_per_sec: f64) -> String {
    if ops_per_sec >= 1e6 {
        format!("{:.2} MOPs", ops_per_sec / 1e6)
    } else if ops_per_sec >= 1e3 {
        format!("{:.1} kOPs", ops_per_sec / 1e3)
    } else {
        format!("{ops_per_sec:.0} OPs")
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} us", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn hist_exact_for_small_values() {
        let mut h = LatencyHist::new();
        for v in 0..32u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 32);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 31);
        // target rank ceil(0.5*32)=16 -> 16th smallest value is 15
        assert_eq!(h.quantile(0.5), 15);
    }

    #[test]
    fn hist_quantiles_within_relative_error() {
        let mut h = LatencyHist::new();
        let mut r = Rng::new(42);
        let mut vals: Vec<u64> = (0..10_000).map(|_| r.below(1_000_000) + 1).collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort_unstable();
        for q in [0.5, 0.9, 0.99, 0.999] {
            let want = vals[((q * vals.len() as f64) as usize).min(vals.len() - 1)];
            let got = h.quantile(q);
            let rel = (got as f64 - want as f64).abs() / want as f64;
            assert!(rel < 0.05, "q={q}: got {got}, want {want}, rel {rel}");
        }
    }

    #[test]
    fn hist_mean_exact() {
        let mut h = LatencyHist::new();
        for v in [10u64, 20, 30, 40] {
            h.record(v);
        }
        assert!((h.mean() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn hist_merge() {
        let mut a = LatencyHist::new();
        let mut b = LatencyHist::new();
        a.record(100);
        b.record(1_000_000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 100);
        assert_eq!(a.max(), 1_000_000);
    }

    #[test]
    fn hist_empty_is_sane() {
        let h = LatencyHist::new();
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
    }

    #[test]
    fn welford_matches_closed_form() {
        let mut w = Welford::default();
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of the set is 32/7
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_mops(25_000_000.0), "25.00 MOPs");
        assert_eq!(fmt_mops(2_500.0), "2.5 kOPs");
        assert_eq!(fmt_ns(1_500.0), "1.50 us");
    }
}
