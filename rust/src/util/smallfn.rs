//! Inline-storage one-shot closures — the allocation-free alternative to
//! `Box<dyn FnOnce(..)>` on the delegation hot path.
//!
//! A boxed completion costs one heap allocation per response-bearing
//! request; the paper's channel is allocation-free by construction (fixed
//! slot pairs, pass-by-value records), so per-op boxes were the single
//! largest remaining allocation source. [`define_inline_fn_once!`]
//! generates a concrete erased-`FnOnce` type that stores the closure's
//! captures **inline** in a fixed buffer when they fit (the common case:
//! a couple of pointers/`Rc`s) and falls back to a heap box only for
//! oversized or over-aligned captures. Callers can observe the fallback
//! (`was_boxed()`) so endpoints can count hot-path allocations.
//!
//! Layout per generated type (`N` = inline capacity in bytes):
//!
//! ```text
//! data      [u8; N] storage, 8-byte aligned (inline captures, or the
//!           thin `*mut C` of the heap fallback in its first 8 bytes)
//! call      Option<unsafe fn(*mut u8, bool, args..)> — None when empty
//!           (a fire-and-forget marker) or already consumed
//! drop_fn   unsafe fn(*mut u8, bool) — drops an uncalled closure
//! heap      bool — which representation `data` holds
//! ```
//!
//! The generated type is deliberately **not** `Send`/`Sync` (it may hold
//! `Rc`s and raw pointers); completions only ever run on the worker that
//! created them, matching the old `Box<dyn FnOnce>` (also non-`Send`).

/// Fixed inline backing store. 8-byte alignment covers every capture the
/// hot paths use (pointers, `Rc`/`Arc`, `u64` ids); closures with larger
/// alignment (`u128`, SIMD) take the heap fallback. Deliberately **not**
/// 16-aligned: `repr(align(16))` would round every buffer size up to a
/// multiple of 16, bloating the generated structs past the nesting
/// budget (a 40-byte-inline callback must be exactly 64 bytes so that a
/// channel `Completion` capturing one still stores inline).
#[repr(align(8))]
pub struct InlineData<const N: usize>(pub [std::mem::MaybeUninit<u8>; N]);

impl<const N: usize> InlineData<N> {
    pub const fn uninit() -> Self {
        InlineData([std::mem::MaybeUninit::uninit(); N])
    }

    #[inline]
    pub fn as_mut_ptr(&mut self) -> *mut u8 {
        self.0.as_mut_ptr() as *mut u8
    }
}

/// Generate an inline-storage erased `FnOnce($($argty),*)` named `$name`
/// with `$bytes` bytes of inline capture storage.
///
/// The argument types may use elided lifetimes (e.g. `Option<&[u8]>`,
/// `&mut WireReader<'_>`): both the stored bound and the internal fn
/// pointers become higher-ranked over them, exactly like
/// `Box<dyn FnOnce(Option<&[u8]>)>` would.
#[macro_export]
macro_rules! define_inline_fn_once {
    (
        $(#[$meta:meta])*
        $vis:vis struct $name:ident($($arg:ident: $argty:ty),* $(,)?);
        inline_bytes = $bytes:expr;
    ) => {
        $(#[$meta])*
        $vis struct $name {
            data: $crate::util::smallfn::InlineData<{ $bytes }>,
            call_fn: Option<unsafe fn(*mut u8, bool $(, $argty)*)>,
            drop_fn: unsafe fn(*mut u8, bool),
            heap: bool,
        }

        impl $name {
            /// Bytes of inline capture storage before the heap fallback.
            pub const INLINE_BYTES: usize = $bytes;

            /// The empty value (a fire-and-forget marker): calling it is
            /// a no-op, dropping it is a no-op.
            pub const fn none() -> $name {
                // SAFETY: touches nothing; unsafe only to match the drop-fn pointer type.
                unsafe fn drop_nothing(_p: *mut u8, _heap: bool) {}
                $name {
                    data: $crate::util::smallfn::InlineData::uninit(),
                    call_fn: None,
                    drop_fn: drop_nothing,
                    heap: false,
                }
            }

            /// Erase `c`, storing its captures inline when they fit.
            pub fn new<C>(c: C) -> $name
            where
                C: FnOnce($($argty),*) + 'static,
            {
                // SAFETY: caller passes `p` pointing at a live `C` (inline buffer or
                // heap box per `heap`), moved out exactly once.
                unsafe fn call_c<C: FnOnce($($argty),*)>(
                    p: *mut u8,
                    heap: bool
                    $(, $arg: $argty)*
                ) {
                    if heap {
                        // SAFETY: `p` holds the thin pointer of a leaked
                        // `Box<C>`; ownership returns here exactly once.
                        let c = unsafe { Box::from_raw(p.cast::<*mut C>().read()) };
                        (*c)($($arg),*);
                    } else {
                        // SAFETY: `p` is 8-byte-aligned storage holding a
                        // by-value `C`; ownership moves out exactly once.
                        let c = unsafe { p.cast::<C>().read() };
                        c($($arg),*);
                    }
                }
                // SAFETY: caller passes `p` pointing at a live `C` not yet consumed;
                // drops it in place (or frees the heap box).
                unsafe fn drop_c<C>(p: *mut u8, heap: bool) {
                    if heap {
                        // SAFETY: as in `call_c`'s heap arm.
                        drop(unsafe { Box::from_raw(p.cast::<*mut C>().read()) });
                    } else {
                        // SAFETY: as in `call_c`'s inline arm.
                        unsafe { p.cast::<C>().drop_in_place() };
                    }
                }
                let mut data = $crate::util::smallfn::InlineData::uninit();
                let p = data.as_mut_ptr();
                let heap = std::mem::size_of::<C>() > $bytes
                    || std::mem::align_of::<C>() > 8;
                if heap {
                    let boxed = Box::into_raw(Box::new(c));
                    // SAFETY: first 8 bytes of 8-aligned storage hold the
                    // thin pointer.
                    unsafe { p.cast::<*mut C>().write(boxed) };
                } else {
                    // SAFETY: size/align checked above; storage is fresh.
                    unsafe { p.cast::<C>().write(c) };
                }
                $name { data, call_fn: Some(call_c::<C>), drop_fn: drop_c::<C>, heap }
            }

            /// Is this the empty ([`Self::none`]) value?
            pub fn is_none(&self) -> bool {
                self.call_fn.is_none()
            }

            pub fn is_some(&self) -> bool {
                self.call_fn.is_some()
            }

            /// Did construction fall back to a heap box (metrics)?
            pub fn was_boxed(&self) -> bool {
                self.heap
            }

            /// Consume and invoke the closure; a no-op for
            /// [`Self::none`].
            #[inline]
            pub fn call(mut self $(, $arg: $argty)*) {
                if let Some(f) = self.call_fn.take() {
                    // SAFETY: `call` was Some, so the storage holds a live
                    // closure; taking it first makes Drop a no-op.
                    unsafe { f(self.data.as_mut_ptr(), self.heap $(, $arg)*) };
                }
            }
        }

        impl Drop for $name {
            fn drop(&mut self) {
                if self.call_fn.take().is_some() {
                    // SAFETY: an uncalled closure still lives in `data`.
                    unsafe { (self.drop_fn)(self.data.as_mut_ptr(), self.heap) };
                }
            }
        }

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                f.debug_struct(stringify!($name))
                    .field("some", &self.is_some())
                    .field("boxed", &self.heap)
                    .finish()
            }
        }
    };
}

#[cfg(test)]
mod tests {
    use std::cell::Cell;
    use std::rc::Rc;

    define_inline_fn_once! {
        /// Test subject: FnOnce(u64).
        pub struct TestCb(v: u64);
        inline_bytes = 24;
    }

    define_inline_fn_once! {
        /// Borrowed-argument subject: elided lifetimes must be accepted.
        pub struct SliceCb(v: Option<&[u8]>);
        inline_bytes = 24;
    }

    #[test]
    fn inline_closure_runs_once() {
        let hit = Rc::new(Cell::new(0u64));
        let h = hit.clone();
        let cb = TestCb::new(move |v| h.set(h.get() + v));
        assert!(cb.is_some());
        assert!(!cb.was_boxed(), "one Rc must fit inline");
        cb.call(41);
        assert_eq!(hit.get(), 41);
    }

    #[test]
    fn oversized_capture_falls_back_to_heap_and_still_runs() {
        let big = [7u8; 200];
        let hit = Rc::new(Cell::new(0u64));
        let h = hit.clone();
        let cb = TestCb::new(move |v| {
            h.set(v + big.iter().map(|&b| b as u64).sum::<u64>())
        });
        assert!(cb.was_boxed(), "200-byte capture cannot fit inline");
        cb.call(1);
        assert_eq!(hit.get(), 1 + 200 * 7);
    }

    #[test]
    fn none_is_inert() {
        let cb = TestCb::none();
        assert!(cb.is_none());
        cb.call(9); // no-op
        let cb2 = TestCb::none();
        drop(cb2); // no-op
    }

    #[test]
    fn dropping_uncalled_closure_drops_captures_exactly_once() {
        struct Canary(Rc<Cell<u32>>);
        impl Drop for Canary {
            fn drop(&mut self) {
                self.0.set(self.0.get() + 1);
            }
        }
        let drops = Rc::new(Cell::new(0u32));
        // Inline representation.
        let c = Canary(drops.clone());
        let cb = TestCb::new(move |_| {
            let _keep = &c;
        });
        drop(cb);
        assert_eq!(drops.get(), 1);
        // Heap representation.
        let c = Canary(drops.clone());
        let pad = [0u8; 100];
        let cb = TestCb::new(move |_| {
            let _keep = (&c, &pad);
        });
        assert!(cb.was_boxed());
        drop(cb);
        assert_eq!(drops.get(), 2);
        // Calling also consumes exactly once.
        let c = Canary(drops.clone());
        let cb = TestCb::new(move |_| drop(c));
        cb.call(0);
        assert_eq!(drops.get(), 3);
    }

    #[test]
    fn borrowed_arguments_work_with_any_lifetime() {
        let got = Rc::new(Cell::new(0usize));
        let g = got.clone();
        let cb = SliceCb::new(move |v: Option<&[u8]>| g.set(v.map_or(0, |s| s.len())));
        {
            let local = vec![1u8, 2, 3];
            cb.call(Some(&local));
        }
        assert_eq!(got.get(), 3);
    }
}
