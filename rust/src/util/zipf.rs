//! Zipfian and uniform key-distribution samplers.
//!
//! The paper's workloads (§6.1, §6.3, §7) draw objects/keys either uniformly
//! or from a zipfian distribution with α = 1 over up to 10⁸ keys. Sampling
//! zipf at that scale needs care: the textbook inverse-CDF over a harmonic
//! table is O(N) memory, and Gray's YCSB generator is specific to θ < 1.
//!
//! We use a hybrid that is exact where it matters and analytic where it
//! doesn't: an exact cumulative table over the first `HEAD` ranks (where the
//! bulk of the probability mass lives and the continuous approximation is
//! worst), and a continuous inverse-CDF over the tail, valid for any α > 0
//! including α = 1.
//!
//! A scrambled variant (à la YCSB `ScrambledZipfianGenerator`) hashes ranks
//! into the key space so "popular" keys are spread across a table rather
//! than clustered at low indices.

use super::rng::{mix64, Rng};

/// Number of head ranks sampled from an exact CDF table.
const HEAD: usize = 4096;

/// Zipfian sampler over ranks `0..n` with exponent `alpha`.
///
/// `sample()` returns a 0-based *rank*: rank 0 is the most popular item with
/// probability ∝ 1, rank k with probability ∝ 1/(k+1)^α.
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    alpha: f64,
    /// Exact normalized CDF over ranks `0..head` (head = min(n, HEAD)).
    head_cdf: Vec<f64>,
    /// Total probability mass of the head region.
    head_mass: f64,
    /// Generalized harmonic H(n, alpha) — total unnormalized mass.
    total: f64,
    /// Unnormalized mass of head (= H(head, alpha)).
    head_total: f64,
}

#[cfg_attr(not(test), allow(dead_code))]
/// Generalized harmonic number H(n, a) = sum_{i=1..n} i^-a, computed exactly
/// up to `HEAD` and by Euler–Maclaurin beyond.
fn harmonic(n: u64, a: f64) -> f64 {
    let exact_upto = (HEAD as u64).min(n);
    let mut h = 0.0;
    for i in 1..=exact_upto {
        h += (i as f64).powf(-a);
    }
    if n > exact_upto {
        h += harmonic_range(exact_upto as f64 + 0.5, n as f64 + 0.5, a);
    }
    h
}

/// Continuous approximation of sum_{i in (lo, hi]} i^-a via the integral of
/// x^-a (midpoint-corrected: bounds at k±0.5 make this accurate to ~1e-6 for
/// the tail ranks we use it on).
fn harmonic_range(lo: f64, hi: f64, a: f64) -> f64 {
    if (a - 1.0).abs() < 1e-9 {
        (hi / lo).ln()
    } else {
        (hi.powf(1.0 - a) - lo.powf(1.0 - a)) / (1.0 - a)
    }
}

/// Inverse of `harmonic_range(lo, ., a) = m`: returns `hi`.
fn inv_harmonic_range(lo: f64, m: f64, a: f64) -> f64 {
    if (a - 1.0).abs() < 1e-9 {
        lo * m.exp()
    } else {
        (lo.powf(1.0 - a) + (1.0 - a) * m).powf(1.0 / (1.0 - a))
    }
}

impl Zipf {
    /// Build a sampler over `n` ranks with exponent `alpha` (paper: α = 1).
    pub fn new(n: u64, alpha: f64) -> Self {
        assert!(n > 0, "Zipf over empty domain");
        assert!(alpha > 0.0, "alpha must be positive");
        let head = (HEAD as u64).min(n) as usize;
        let mut head_cdf = Vec::with_capacity(head);
        let mut acc = 0.0;
        for i in 1..=head {
            acc += (i as f64).powf(-alpha);
            head_cdf.push(acc);
        }
        let head_total = acc;
        let total = if n > head as u64 {
            head_total + harmonic_range(head as f64 + 0.5, n as f64 + 0.5, alpha)
        } else {
            head_total
        };
        let head_mass = head_total / total;
        // Normalize head CDF to [0, head_mass].
        for c in &mut head_cdf {
            *c /= total;
        }
        Zipf { n, alpha, head_cdf, head_mass, total, head_total }
    }

    /// Number of ranks.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// Probability of a given 0-based rank.
    pub fn prob(&self, rank: u64) -> f64 {
        assert!(rank < self.n);
        ((rank + 1) as f64).powf(-self.alpha) / self.total
    }

    /// Draw a 0-based rank.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        let u = rng.unit_f64();
        if u < self.head_mass {
            // Binary search the exact head CDF.
            let mut lo = 0usize;
            let mut hi = self.head_cdf.len();
            while lo < hi {
                let mid = (lo + hi) / 2;
                if self.head_cdf[mid] < u {
                    lo = mid + 1;
                } else {
                    hi = mid;
                }
            }
            lo as u64
        } else {
            // Invert the continuous tail CDF.
            let m = u * self.total - self.head_total;
            let lo = self.head_cdf.len() as f64 + 0.5;
            let x = inv_harmonic_range(lo, m, self.alpha);
            // x is a continuous "rank + 0.5" position; round and clamp.
            let r = (x - 0.5).floor() as u64;
            r.min(self.n - 1).max(self.head_cdf.len() as u64)
        }
    }
}

/// A key distribution over `0..n`: uniform, zipfian (rank order), or
/// scrambled zipfian (popular ranks hashed across the key space).
#[derive(Clone, Debug)]
pub enum KeyDist {
    Uniform { n: u64 },
    Zipfian(Zipf),
    ScrambledZipfian(Zipf),
}

impl KeyDist {
    /// Parse from bench CLI notation: `uniform` or `zipf` / `zipfian`
    /// (optionally `zipf:ALPHA`).
    pub fn from_spec(spec: &str, n: u64) -> KeyDist {
        let spec = spec.trim();
        if spec.eq_ignore_ascii_case("uniform") {
            KeyDist::Uniform { n }
        } else if let Some(rest) = spec
            .strip_prefix("zipf")
            .map(|r| r.trim_start_matches("ian"))
        {
            let alpha = rest
                .strip_prefix(':')
                .map(|a| a.parse::<f64>().expect("bad zipf alpha"))
                .unwrap_or(1.0);
            KeyDist::ScrambledZipfian(Zipf::new(n, alpha))
        } else {
            panic!("unknown distribution spec {spec:?} (want uniform|zipf[:alpha])");
        }
    }

    /// Domain size.
    pub fn n(&self) -> u64 {
        match self {
            KeyDist::Uniform { n } => *n,
            KeyDist::Zipfian(z) | KeyDist::ScrambledZipfian(z) => z.n(),
        }
    }

    /// Draw a key in `0..n`.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        match self {
            KeyDist::Uniform { n } => rng.below(*n),
            KeyDist::Zipfian(z) => z.sample(rng),
            KeyDist::ScrambledZipfian(z) => {
                let rank = z.sample(rng);
                // Spread ranks across the key space with a fixed bijective
                // mix, reduced to the domain. Collisions merely merge the
                // popularity of two ranks, as in YCSB.
                mix64(rank) % z.n()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_frequencies_match_theory() {
        let z = Zipf::new(1000, 1.0);
        let mut rng = Rng::new(123);
        let draws = 200_000;
        let mut counts = vec![0u64; 1000];
        for _ in 0..draws {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        for rank in [0usize, 1, 2, 9, 99] {
            let want = z.prob(rank as u64);
            let got = counts[rank] as f64 / draws as f64;
            let tol = 0.15 * want + 2.0 / draws as f64;
            assert!(
                (got - want).abs() < tol,
                "rank {rank}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn tail_mass_roughly_correct() {
        // For n=1e6, alpha=1: P(rank >= 4096) = (H_n - H_4096)/H_n.
        let n = 1_000_000u64;
        let z = Zipf::new(n, 1.0);
        let mut rng = Rng::new(77);
        let draws = 100_000;
        let tail = (0..draws)
            .filter(|_| z.sample(&mut rng) >= HEAD as u64)
            .count() as f64
            / draws as f64;
        let want = 1.0 - z.head_mass;
        assert!(
            (tail - want).abs() < 0.02,
            "tail mass got {tail}, want {want}"
        );
    }

    #[test]
    fn samples_in_domain_various_n() {
        let mut rng = Rng::new(5);
        for n in [1u64, 2, 3, 100, 5000, 1_000_000] {
            let z = Zipf::new(n, 1.0);
            for _ in 0..2000 {
                assert!(z.sample(&mut rng) < n);
            }
        }
    }

    #[test]
    fn alpha_sharper_concentrates_more() {
        let mut rng = Rng::new(6);
        let n = 100_000;
        let draws = 50_000;
        let top_share = |alpha: f64, rng: &mut Rng| {
            let z = Zipf::new(n, alpha);
            (0..draws).filter(|_| z.sample(rng) < 10).count() as f64 / draws as f64
        };
        let a1 = top_share(0.8, &mut rng);
        let a2 = top_share(1.5, &mut rng);
        assert!(a2 > a1 + 0.2, "alpha=1.5 share {a2} vs alpha=0.8 share {a1}");
    }

    #[test]
    fn harmonic_exact_vs_approx_agree() {
        // exact sum vs our hybrid for a mid-size n
        let n = 20_000u64;
        let exact: f64 = (1..=n).map(|i| 1.0 / i as f64).sum();
        let approx = harmonic(n, 1.0);
        assert!((exact - approx).abs() / exact < 1e-4);
    }

    #[test]
    fn uniform_dist_covers() {
        let d = KeyDist::Uniform { n: 10 };
        let mut rng = Rng::new(8);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[d.sample(&mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn scrambled_zipf_spreads_hot_keys() {
        let d = KeyDist::from_spec("zipf", 1_000_000);
        let mut rng = Rng::new(9);
        // Hot keys should NOT all be < HEAD after scrambling.
        let low = (0..10_000)
            .filter(|_| d.sample(&mut rng) < HEAD as u64)
            .count();
        assert!(low < 1000, "scrambling failed: {low} of 10000 in head range");
    }

    #[test]
    fn from_spec_parses() {
        assert!(matches!(
            KeyDist::from_spec("uniform", 5),
            KeyDist::Uniform { n: 5 }
        ));
        assert!(matches!(
            KeyDist::from_spec("zipf", 5),
            KeyDist::ScrambledZipfian(_)
        ));
        assert!(matches!(
            KeyDist::from_spec("zipfian:0.99", 5),
            KeyDist::ScrambledZipfian(_)
        ));
    }

    #[test]
    #[should_panic]
    fn from_spec_rejects_garbage() {
        KeyDist::from_spec("pareto", 5);
    }
}
