//! CPU topology discovery and thread pinning.
//!
//! The paper's experiments pin memcached/worker threads to hardware threads
//! (§7.1) and distinguish *dedicated* trustee cores from *shared* ones
//! (§6.1). On the single-core container this reproduction runs in, pinning
//! degenerates to a no-op, but the module keeps the same code path the
//! paper's testbed would use (`sched_setaffinity`), so the benches behave
//! identically on a real multicore box.

/// Number of CPUs available to this process.
pub fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Pin the calling thread to a CPU (modulo the available count).
/// Returns false if pinning was unavailable or failed (non-fatal).
pub fn pin_to_cpu(cpu: usize) -> bool {
    #[cfg(target_os = "linux")]
    {
        use crate::util::sys as libc;
        let ncpu = num_cpus();
        let target = cpu % ncpu;
        // SAFETY: set is a live cpu_set_t; CPU_ZERO/CPU_SET only write within
        // it, and sched_setaffinity reads exactly cpusetsize bytes.
        unsafe {
            let mut set: libc::cpu_set_t = std::mem::zeroed();
            libc::CPU_ZERO(&mut set);
            libc::CPU_SET(target, &mut set);
            libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set) == 0
        }
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = cpu;
        false
    }
}

/// Plan a worker→CPU assignment: first `dedicated` workers get the lowest
/// CPUs (the paper's dedicated-trustee cores); remaining workers spread
/// round-robin over the rest (or over everything if CPUs are scarce).
pub fn plan_pinning(workers: usize, dedicated: usize) -> Vec<usize> {
    let ncpu = num_cpus();
    (0..workers)
        .map(|w| {
            if w < dedicated && ncpu > dedicated {
                w % ncpu
            } else if ncpu > dedicated {
                dedicated + (w - dedicated.min(w)) % (ncpu - dedicated)
            } else {
                w % ncpu
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn num_cpus_positive() {
        assert!(num_cpus() >= 1);
    }

    #[test]
    fn pin_does_not_crash() {
        // On a 1-CPU box this pins to CPU 0; either way it must not panic.
        let _ = pin_to_cpu(0);
        let _ = pin_to_cpu(1000);
    }

    #[test]
    fn plan_covers_all_workers() {
        for (w, d) in [(1, 0), (8, 2), (4, 4), (16, 0)] {
            let plan = plan_pinning(w, d);
            assert_eq!(plan.len(), w);
            let ncpu = num_cpus();
            assert!(plan.iter().all(|&c| c < ncpu));
        }
    }
}
