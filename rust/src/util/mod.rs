//! Substrates built from scratch for the offline environment.
//!
//! The paper's artifact leans on crates.io (`rand`, `zipf`, `clap`,
//! `criterion`, `proptest`, `serde`/`bincode`, `hdrhistogram`). None of
//! those are available in this build environment, so this module provides
//! the equivalents the rest of the crate needs:
//!
//! - [`rng`] — xoshiro256** PRNG + splitmix64 seeding
//! - [`zipf`] — exact-head/analytic-tail zipfian sampler (YCSB-style
//!   scrambled variant included)
//! - [`stats`] — log-bucketed latency histogram with percentiles, Welford
//!   mean/variance, throughput formatting
//! - [`cli`] — a small `--key value` argument parser
//! - [`affinity`] — CPU pinning via `sched_setaffinity` (no-op fallback)
//! - [`sys`] — raw C-library bindings (`mmap`, `sched_setaffinity`) so the
//!   crate needs no external `libc` dependency
//! - [`quickcheck`] — a miniature property-testing harness with shrinking
//! - [`cache`] — cache-line padding, `pause`, prefetch helpers
//! - [`smallfn`] — inline-storage erased `FnOnce` types (the
//!   allocation-free replacement for boxed completions/callbacks)
//! - [`count_alloc`] — opt-in counting global allocator behind the
//!   zero-allocation hot-path regression test
//! - [`faultsim`] — deterministic syscall-boundary fault injection
//!   behind the `faults` feature (compiled to no-ops by default)
//! - [`vatomic`] — virtual atomics: `std::sync::atomic` newtypes that the
//!   `model` feature reroutes through the interleaving explorer

pub mod affinity;
pub mod cache;
pub mod cli;
pub mod count_alloc;
pub mod faultsim;
pub mod quickcheck;
pub mod rng;
pub mod smallfn;
pub mod stats;
pub mod sys;
pub mod vatomic;
pub mod zipf;

pub use cache::{pause, pause_n, CachePadded};
pub use rng::Rng;
pub use zipf::{KeyDist, Zipf};
