//! xoshiro256** pseudo-random number generator.
//!
//! The `rand` crate is unavailable offline; this is a from-scratch
//! implementation of Blackman & Vigna's xoshiro256** 1.0 with splitmix64
//! seeding, plus the handful of distribution helpers the workloads need
//! (Lemire bounded integers, unit floats, shuffling).

/// xoshiro256** PRNG. Not cryptographically secure; intended for workload
/// generation and property testing.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// splitmix64 step — used to expand a 64-bit seed into xoshiro state, and
/// useful on its own as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateless 64-bit mix (fmix64 from MurmurHash3). Used for key scrambling.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z ^= z >> 33;
    z = z.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    z ^= z >> 33;
    z = z.wrapping_mul(0xC4CE_B9FE_1A85_EC53);
    z ^= z >> 33;
    z
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // xoshiro state must not be all-zero; splitmix64 of any seed
        // cannot produce four zeros, but be defensive anyway.
        let s = if s == [0; 4] { [1, 2, 3, 4] } else { s };
        Rng { s }
    }

    /// Seed from the OS monotonic clock + thread id; convenient for benches
    /// where reproducibility is controlled by an explicit `--seed` instead.
    pub fn from_time() -> Self {
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x5EED);
        Rng::new(t ^ (std::process::id() as u64) << 32)
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Next raw 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, bound)` using Lemire's multiply-shift with
    /// rejection; panics if `bound == 0`.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "Rng::below(0)");
        // Lemire 2018: unbiased bounded generation without division in the
        // common case.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial: true with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.unit_f64() < p
    }

    /// Percentage trial: true with probability `pct`/100 (integers, exact).
    #[inline]
    pub fn pct(&mut self, pct: u32) -> bool {
        debug_assert!(pct <= 100);
        self.below(100) < pct as u64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a byte slice with random data.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn below_one_is_zero() {
        let mut r = Rng::new(3);
        for _ in 0..10 {
            assert_eq!(r.below(1), 0);
        }
    }

    #[test]
    fn unit_f64_in_range_and_mean_near_half() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.unit_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn pct_extremes() {
        let mut r = Rng::new(11);
        for _ in 0..100 {
            assert!(!r.pct(0));
            assert!(r.pct(100));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn fill_bytes_varied() {
        let mut r = Rng::new(13);
        let mut buf = [0u8; 37];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(17);
        let mut a = root.fork();
        let mut b = root.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn mix64_bijective_spotcheck() {
        // distinct inputs -> distinct outputs for a sample
        let mut outs: Vec<u64> = (0..1000u64).map(mix64).collect();
        outs.sort_unstable();
        outs.dedup();
        assert_eq!(outs.len(), 1000);
    }
}
