//! A counting `GlobalAlloc` wrapper — the measurement side of the
//! allocation-free hot-path contract (DESIGN.md, "Allocation discipline").
//!
//! The library never installs it; a test or bench binary opts in with
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: trustee::util::count_alloc::CountingAlloc =
//!     trustee::util::count_alloc::CountingAlloc;
//! ```
//!
//! and then brackets a measured region with [`snapshot`] — the
//! steady-state regression test (`tests/alloc_regression.rs`) asserts a
//! **zero** delta across thousands of delegated ops, and
//! `benches/channel_micro --json` reports allocs/op alongside MOPs.
//!
//! Counting is two relaxed atomic adds per allocation on top of the
//! system allocator. That overhead is irrelevant precisely when the
//! assertion holds (the hot path performs no allocations to count), and
//! the wrapper is never linked into builds that do not opt in.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// Pass-through to [`System`] that counts every `alloc`/`realloc`
/// (process-wide, all threads).
pub struct CountingAlloc;

// SAFETY: defers entirely to `System`; the counters do not affect layout
// or pointer validity.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: the caller upholds GlobalAlloc's contract; we only count.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        // SAFETY: layout forwarded verbatim from our caller.
        unsafe { System.alloc(layout) }
    }

    // SAFETY: the caller upholds GlobalAlloc's contract.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: ptr/layout forwarded verbatim from our caller.
        unsafe { System.dealloc(ptr, layout) }
    }

    // SAFETY: the caller upholds GlobalAlloc's contract; we only count.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // A growth realloc is an allocation event for the contract: the
        // hot path must not grow buffers at steady state either.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        // SAFETY: ptr/layout/new_size forwarded verbatim from our caller.
        unsafe { System.realloc(ptr, layout, new_size) }
    }
}

/// Counter snapshot: allocation events and bytes requested since process
/// start. Subtract two snapshots to measure a region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AllocSnapshot {
    pub allocs: u64,
    pub bytes: u64,
}

impl AllocSnapshot {
    /// Events/bytes between `earlier` and `self`.
    pub fn since(&self, earlier: &AllocSnapshot) -> AllocSnapshot {
        AllocSnapshot {
            allocs: self.allocs - earlier.allocs,
            bytes: self.bytes - earlier.bytes,
        }
    }
}

/// Read the process-wide counters. Zeros (trivially) unless the binary
/// installed [`CountingAlloc`] as its `#[global_allocator]`.
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot {
        allocs: ALLOCS.load(Ordering::Relaxed),
        bytes: ALLOC_BYTES.load(Ordering::Relaxed),
    }
}
