//! Cache-conscious primitives: padding, `pause`, prefetch.
//!
//! The paper's microbenchmarks insert a single `pause` instruction in every
//! critical section / delegated closure (§6.1, following FFWD), and the
//! channel layout is explicitly designed around 64-byte cache lines and the
//! cost of scanning ready flags (§5.3.1).

/// Pads and aligns a value to 128 bytes (two cache lines, covering the
/// adjacent-line prefetcher), so neighbouring values in an array never
/// false-share. In-tree stand-in for `crossbeam_utils::CachePadded`,
/// which is unavailable in the offline build environment.
#[derive(Clone, Copy, Default, Debug)]
#[repr(align(128))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    pub const fn new(value: T) -> CachePadded<T> {
        CachePadded { value }
    }

    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

impl<T> From<T> for CachePadded<T> {
    fn from(value: T) -> CachePadded<T> {
        CachePadded::new(value)
    }
}

/// One `pause` (x86) / spin-loop hint — the paper's stand-in for critical
/// section work in the fetch-and-add benchmarks.
#[inline(always)]
pub fn pause() {
    core::hint::spin_loop();
}

/// `n` back-to-back pause hints.
#[inline(always)]
pub fn pause_n(n: u32) {
    for _ in 0..n {
        core::hint::spin_loop();
    }
}

/// Best-effort prefetch of the cache line containing `p` into all levels.
#[inline(always)]
pub fn prefetch_read<T>(p: *const T) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: _mm_prefetch is a hint — it dereferences nothing and any
    // address, valid or not, is permitted.
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(p as *const i8);
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = p;
    }
}

/// Spin with exponential backoff, yielding to the OS scheduler once the
/// budget is exhausted. **Single-core substitution:** on the paper's 128-way
/// testbed a spinning waiter burns a hardware thread; on this 1-CPU
/// container it would *prevent the holder from running at all*, so every
/// spin-wait in the crate funnels through this helper, which escalates
/// `pause` → `yield_now`.
#[derive(Default)]
pub struct Backoff {
    step: u32,
}

impl Backoff {
    pub const YIELD_THRESHOLD: u32 = 7;

    #[inline]
    pub fn new() -> Self {
        Backoff { step: 0 }
    }

    /// One wait step: 2^step pauses, then OS yield beyond the threshold.
    #[inline]
    pub fn snooze(&mut self) {
        if self.step <= Self::YIELD_THRESHOLD {
            pause_n(1 << self.step);
            self.step += 1;
        } else {
            std::thread::yield_now();
        }
    }

    /// Has this backoff escalated to OS yields?
    #[inline]
    pub fn is_yielding(&self) -> bool {
        self.step > Self::YIELD_THRESHOLD
    }

    #[inline]
    pub fn reset(&mut self) {
        self.step = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_escalates() {
        let mut b = Backoff::new();
        assert!(!b.is_yielding());
        for _ in 0..=Backoff::YIELD_THRESHOLD {
            b.snooze();
        }
        assert!(b.is_yielding());
        b.reset();
        assert!(!b.is_yielding());
    }

    #[test]
    fn padding_is_cache_line() {
        assert!(std::mem::size_of::<CachePadded<u8>>() >= 64);
    }

    #[test]
    fn pause_helpers_run() {
        pause();
        pause_n(10);
        let x = 42u64;
        prefetch_read(&x);
    }
}
