//! Minimal raw bindings to the platform C library for the few syscalls the
//! crate needs (`mmap` fiber stacks, `sched_setaffinity` pinning, and the
//! `epoll`/`eventfd` readiness primitives behind the network reactor).
//!
//! The offline build environment has no crates.io access, so instead of the
//! `libc` crate we declare exactly the symbols we use. `std` already links
//! against the C library, so these `extern "C"` declarations resolve with
//! no extra build configuration. Linux-only, matching the fiber context
//! switch (sysv64) this crate targets.

#![allow(non_camel_case_types)]
#![cfg(target_os = "linux")]

pub use std::ffi::{c_int, c_long, c_uint, c_void};

pub type size_t = usize;
pub type off_t = i64;
pub type pid_t = i32;

pub const PROT_NONE: c_int = 0x0;
pub const PROT_READ: c_int = 0x1;
pub const PROT_WRITE: c_int = 0x2;

pub const MAP_PRIVATE: c_int = 0x02;
pub const MAP_ANONYMOUS: c_int = 0x20;
pub const MAP_STACK: c_int = 0x20000;

pub const MAP_FAILED: *mut c_void = !0usize as *mut c_void;

pub const _SC_PAGESIZE: c_int = 30;

/// Linux `cpu_set_t`: a 1024-bit CPU mask.
#[repr(C)]
#[derive(Clone, Copy)]
pub struct cpu_set_t {
    bits: [u64; 16],
}

/// Clear every CPU in the set.
#[allow(non_snake_case)]
pub fn CPU_ZERO(set: &mut cpu_set_t) {
    set.bits = [0; 16];
}

/// Add `cpu` to the set (out-of-range bits are ignored, like glibc).
#[allow(non_snake_case)]
pub fn CPU_SET(cpu: usize, set: &mut cpu_set_t) {
    if cpu < 1024 {
        set.bits[cpu / 64] |= 1u64 << (cpu % 64);
    }
}

// ---------------------------------------------------------------------
// epoll / eventfd (the readiness reactor)
// ---------------------------------------------------------------------

pub const EPOLL_CLOEXEC: c_int = 0x80000;

pub const EPOLL_CTL_ADD: c_int = 1;
pub const EPOLL_CTL_DEL: c_int = 2;
pub const EPOLL_CTL_MOD: c_int = 3;

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;
pub const EPOLLONESHOT: u32 = 1 << 30;

pub const EFD_CLOEXEC: c_int = 0x80000;
pub const EFD_NONBLOCK: c_int = 0x800;

/// Linux `struct epoll_event`. The kernel packs it **only on x86-64**
/// (`__EPOLL_PACKED`: 12 bytes, data at offset 4); other architectures
/// use natural alignment (16 bytes, data at offset 8). Mirror that with a
/// conditional repr — the rest of the crate is x86-64-only today (sysv64
/// fiber assembly), but the binding must not silently corrupt the stack
/// if that ever changes. Read fields by copy, never by reference.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
pub struct epoll_event {
    pub events: u32,
    pub data: u64,
}

extern "C" {
    pub fn mmap(
        addr: *mut c_void,
        len: size_t,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        offset: off_t,
    ) -> *mut c_void;
    pub fn munmap(addr: *mut c_void, len: size_t) -> c_int;
    pub fn mprotect(addr: *mut c_void, len: size_t, prot: c_int) -> c_int;
    pub fn sysconf(name: c_int) -> c_long;
    pub fn sched_setaffinity(pid: pid_t, cpusetsize: size_t, cpuset: *const cpu_set_t) -> c_int;

    pub fn epoll_create1(flags: c_int) -> c_int;
    pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut epoll_event) -> c_int;
    pub fn epoll_wait(
        epfd: c_int,
        events: *mut epoll_event,
        maxevents: c_int,
        timeout: c_int,
    ) -> c_int;
    pub fn eventfd(initval: c_uint, flags: c_int) -> c_int;
    pub fn read(fd: c_int, buf: *mut c_void, count: size_t) -> isize;
    pub fn write(fd: c_int, buf: *const c_void, count: size_t) -> isize;
    pub fn close(fd: c_int) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_size_sane() {
        // SAFETY: sysconf has no memory preconditions.
        let sz = unsafe { sysconf(_SC_PAGESIZE) };
        assert!(sz >= 4096, "page size {sz}");
    }

    #[test]
    fn cpu_set_ops() {
        // SAFETY: cpu_set_t is a plain bitmask; all-zeroes is a valid value.
        let mut set: cpu_set_t = unsafe { std::mem::zeroed() };
        CPU_ZERO(&mut set);
        CPU_SET(0, &mut set);
        CPU_SET(70, &mut set);
        CPU_SET(4096, &mut set); // ignored, no panic
        assert_eq!(set.bits[0], 1);
        assert_eq!(set.bits[1], 1 << 6);
    }

    #[test]
    fn epoll_eventfd_roundtrip() {
        // SAFETY: raw syscall roundtrip — every pointer passed is a live local
        // (event buffer, u64 word), and fds are checked right after creation.
        unsafe {
            let ep = epoll_create1(EPOLL_CLOEXEC);
            assert!(ep >= 0, "epoll_create1 failed");
            let efd = eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC);
            assert!(efd >= 0, "eventfd failed");
            let mut ev = epoll_event { events: EPOLLIN, data: 0xABCD };
            assert_eq!(epoll_ctl(ep, EPOLL_CTL_ADD, efd, &mut ev), 0);

            // Nothing written yet: zero-timeout wait sees nothing.
            let mut out = [epoll_event { events: 0, data: 0 }; 4];
            assert_eq!(epoll_wait(ep, out.as_mut_ptr(), 4, 0), 0);

            // After a write, the eventfd is readable and carries our token.
            let one: u64 = 1;
            assert_eq!(write(efd, &one as *const u64 as *const c_void, 8), 8);
            let n = epoll_wait(ep, out.as_mut_ptr(), 4, 100);
            assert_eq!(n, 1);
            let data = out[0].data;
            assert_eq!(data, 0xABCD);

            // Draining the counter clears readiness (level-triggered).
            let mut val: u64 = 0;
            assert_eq!(read(efd, &mut val as *mut u64 as *mut c_void, 8), 8);
            assert_eq!(val, 1);
            assert_eq!(epoll_wait(ep, out.as_mut_ptr(), 4, 0), 0);

            assert_eq!(close(efd), 0);
            assert_eq!(close(ep), 0);
        }
    }

    #[test]
    fn mmap_roundtrip() {
        // SAFETY: fresh anonymous mapping; checked against MAP_FAILED before
        // any access, unmapped exactly once.
        unsafe {
            let p = mmap(
                std::ptr::null_mut(),
                8192,
                PROT_READ | PROT_WRITE,
                MAP_PRIVATE | MAP_ANONYMOUS,
                -1,
                0,
            );
            assert!(p != MAP_FAILED);
            *(p as *mut u8) = 0x5A;
            assert_eq!(*(p as *const u8), 0x5A);
            assert_eq!(munmap(p, 8192), 0);
        }
    }
}
