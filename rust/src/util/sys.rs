//! Minimal raw bindings to the platform C library for the few syscalls the
//! crate needs (`mmap` fiber stacks, `sched_setaffinity` pinning, and the
//! `epoll`/`eventfd` readiness primitives behind the network reactor).
//!
//! The offline build environment has no crates.io access, so instead of the
//! `libc` crate we declare exactly the symbols we use. `std` already links
//! against the C library, so these `extern "C"` declarations resolve with
//! no extra build configuration. Linux-only, matching the fiber context
//! switch (sysv64) this crate targets.

#![allow(non_camel_case_types)]
#![cfg(target_os = "linux")]

pub use std::ffi::{c_int, c_long, c_uint, c_void};

pub type size_t = usize;
pub type off_t = i64;
pub type pid_t = i32;

pub const PROT_NONE: c_int = 0x0;
pub const PROT_READ: c_int = 0x1;
pub const PROT_WRITE: c_int = 0x2;

pub const MAP_PRIVATE: c_int = 0x02;
pub const MAP_ANONYMOUS: c_int = 0x20;
pub const MAP_STACK: c_int = 0x20000;

pub const MAP_FAILED: *mut c_void = !0usize as *mut c_void;

pub const _SC_PAGESIZE: c_int = 30;

/// Linux `cpu_set_t`: a 1024-bit CPU mask.
#[repr(C)]
#[derive(Clone, Copy)]
pub struct cpu_set_t {
    bits: [u64; 16],
}

/// Clear every CPU in the set.
#[allow(non_snake_case)]
pub fn CPU_ZERO(set: &mut cpu_set_t) {
    set.bits = [0; 16];
}

/// Add `cpu` to the set (out-of-range bits are ignored, like glibc).
#[allow(non_snake_case)]
pub fn CPU_SET(cpu: usize, set: &mut cpu_set_t) {
    if cpu < 1024 {
        set.bits[cpu / 64] |= 1u64 << (cpu % 64);
    }
}

// ---------------------------------------------------------------------
// epoll / eventfd (the readiness reactor)
// ---------------------------------------------------------------------

pub const EPOLL_CLOEXEC: c_int = 0x80000;

pub const EPOLL_CTL_ADD: c_int = 1;
pub const EPOLL_CTL_DEL: c_int = 2;
pub const EPOLL_CTL_MOD: c_int = 3;

pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
pub const EPOLLRDHUP: u32 = 0x2000;
pub const EPOLLONESHOT: u32 = 1 << 30;

pub const EFD_CLOEXEC: c_int = 0x80000;
pub const EFD_NONBLOCK: c_int = 0x800;

/// Linux `struct epoll_event`. The kernel packs it **only on x86-64**
/// (`__EPOLL_PACKED`: 12 bytes, data at offset 4); other architectures
/// use natural alignment (16 bytes, data at offset 8). Mirror that with a
/// conditional repr — the rest of the crate is x86-64-only today (sysv64
/// fiber assembly), but the binding must not silently corrupt the stack
/// if that ever changes. Read fields by copy, never by reference.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
pub struct epoll_event {
    pub events: u32,
    pub data: u64,
}

/// `getrlimit`/`setrlimit` resource id for the open-file-descriptor cap.
pub const RLIMIT_NOFILE: c_int = 7;

/// Linux `struct rlimit` (64-bit fields on x86-64).
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct rlimit {
    pub rlim_cur: u64,
    pub rlim_max: u64,
}

extern "C" {
    pub fn getrlimit(resource: c_int, rlim: *mut rlimit) -> c_int;
    pub fn setrlimit(resource: c_int, rlim: *const rlimit) -> c_int;

    pub fn mmap(
        addr: *mut c_void,
        len: size_t,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        offset: off_t,
    ) -> *mut c_void;
    pub fn munmap(addr: *mut c_void, len: size_t) -> c_int;
    pub fn mprotect(addr: *mut c_void, len: size_t, prot: c_int) -> c_int;
    pub fn sysconf(name: c_int) -> c_long;
    pub fn sched_setaffinity(pid: pid_t, cpusetsize: size_t, cpuset: *const cpu_set_t) -> c_int;

    pub fn epoll_create1(flags: c_int) -> c_int;
    pub fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut epoll_event) -> c_int;
    pub fn epoll_wait(
        epfd: c_int,
        events: *mut epoll_event,
        maxevents: c_int,
        timeout: c_int,
    ) -> c_int;
    pub fn eventfd(initval: c_uint, flags: c_int) -> c_int;
    pub fn read(fd: c_int, buf: *mut c_void, count: size_t) -> isize;
    pub fn write(fd: c_int, buf: *const c_void, count: size_t) -> isize;
    pub fn close(fd: c_int) -> c_int;
}

// ---------------------------------------------------------------------
// io_uring (batched submission/completion networking)
// ---------------------------------------------------------------------
//
// glibc exposes no wrappers for the io_uring family, so these go through
// the raw variadic `syscall(2)` entry point with the x86-64 syscall
// numbers — consistent with the crate's existing x86-64-only assumption
// (see the `epoll_event` packing note above). The SQ/CQ rings are shared
// memory mapped from the ring fd at the fixed `IORING_OFF_*` offsets;
// the head/tail memory-ordering contract on those mappings lives with
// the reactor (`runtime::uring`), not here.

pub const SYS_IO_URING_SETUP: c_long = 425;
pub const SYS_IO_URING_ENTER: c_long = 426;
pub const SYS_IO_URING_REGISTER: c_long = 427;

/// `mmap` offsets selecting which ring region a mapping covers.
pub const IORING_OFF_SQ_RING: off_t = 0;
pub const IORING_OFF_CQ_RING: off_t = 0x800_0000;
pub const IORING_OFF_SQES: off_t = 0x1000_0000;

/// `io_uring_params.features` bits the reactor depends on.
pub const IORING_FEAT_SINGLE_MMAP: u32 = 1 << 0;
pub const IORING_FEAT_NODROP: u32 = 1 << 1;
pub const IORING_FEAT_EXT_ARG: u32 = 1 << 8;

/// SQE opcodes (the subset the reactor submits).
pub const IORING_OP_NOP: u8 = 0;
pub const IORING_OP_POLL_ADD: u8 = 6;
pub const IORING_OP_ACCEPT: u8 = 13;
pub const IORING_OP_SEND: u8 = 26;
pub const IORING_OP_RECV: u8 = 27;

/// `io_uring_sqe.len` flag for `IORING_OP_POLL_ADD`: re-arm after every
/// completion (multishot) instead of one CQE per SQE.
pub const IORING_POLL_ADD_MULTI: u32 = 1 << 0;
/// `io_uring_sqe.ioprio` flag for `IORING_OP_ACCEPT`: one SQE keeps
/// producing a CQE per accepted connection.
pub const IORING_ACCEPT_MULTISHOT: u16 = 1 << 0;

/// `io_uring_sqe.ioprio` flags for `IORING_OP_RECV`/`IORING_OP_SEND`.
/// `POLL_FIRST` skips the speculative first attempt and arms readiness
/// directly (the data-plane default: the fiber only posts a RECV when no
/// bytes are queued); `MULTISHOT` keeps one RECV SQE producing a CQE per
/// arriving burst until a terminal completion or `!F_MORE`.
pub const IORING_RECVSEND_POLL_FIRST: u16 = 1 << 0;
pub const IORING_RECV_MULTISHOT: u16 = 1 << 1;

/// `io_uring_sqe.flags` bit: pick the destination buffer from the
/// provided-buffer group named by `sqe.buf_index` instead of `sqe.addr`.
pub const IOSQE_BUFFER_SELECT: u8 = 1 << 2;

/// `io_uring_enter` flags.
pub const IORING_ENTER_GETEVENTS: u32 = 1 << 0;
pub const IORING_ENTER_EXT_ARG: u32 = 1 << 3;

/// CQE flag: the completion carries a provided buffer; the buffer id is
/// in the upper 16 bits of `cqe.flags` (see [`IORING_CQE_BUFFER_SHIFT`]).
pub const IORING_CQE_F_BUFFER: u32 = 1 << 0;
/// CQE flag: this multishot SQE is still armed and will produce more.
pub const IORING_CQE_F_MORE: u32 = 1 << 1;
/// Shift extracting the provided-buffer id from `cqe.flags`.
pub const IORING_CQE_BUFFER_SHIFT: u32 = 16;

/// SQ-ring `flags` bit (kernel → us): completions were dropped into the
/// internal overflow list (`IORING_FEAT_NODROP`); flushing them into the
/// CQ requires an `io_uring_enter` with `IORING_ENTER_GETEVENTS`.
pub const IORING_SQ_CQ_OVERFLOW: u32 = 1 << 1;

/// `io_uring_register` opcode for registering a wakeup eventfd.
pub const IORING_REGISTER_EVENTFD: c_uint = 4;
/// `io_uring_register` opcodes for attaching/detaching a provided-buffer
/// ring (`struct io_uring_buf_reg` argument, nr_args = 1).
pub const IORING_REGISTER_PBUF_RING: c_uint = 22;
pub const IORING_UNREGISTER_PBUF_RING: c_uint = 23;

/// Classic `poll(2)` event bits (what `POLL_ADD` takes in
/// `io_uring_sqe.op_flags`; numerically the same low bits as `EPOLL*`).
pub const POLLIN: u32 = 0x001;
pub const POLLOUT: u32 = 0x004;
pub const POLLERR: u32 = 0x008;
pub const POLLHUP: u32 = 0x010;
pub const POLLRDHUP: u32 = 0x2000;

/// `accept4(2)` flag, passed through the ACCEPT SQE's `op_flags`.
pub const SOCK_CLOEXEC: u32 = 0x80000;

pub const MAP_SHARED: c_int = 0x01;
pub const MAP_POPULATE: c_int = 0x8000;

/// Field offsets (relative to the SQ ring mapping) published by
/// `io_uring_setup`.
#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct io_sqring_offsets {
    pub head: u32,
    pub tail: u32,
    pub ring_mask: u32,
    pub ring_entries: u32,
    pub flags: u32,
    pub dropped: u32,
    pub array: u32,
    pub resv1: u32,
    pub user_addr: u64,
}

/// Field offsets (relative to the CQ ring mapping) published by
/// `io_uring_setup`.
#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct io_cqring_offsets {
    pub head: u32,
    pub tail: u32,
    pub ring_mask: u32,
    pub ring_entries: u32,
    pub overflow: u32,
    pub cqes: u32,
    pub flags: u32,
    pub resv1: u32,
    pub user_addr: u64,
}

/// In/out parameter block of `io_uring_setup`.
#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct io_uring_params {
    pub sq_entries: u32,
    pub cq_entries: u32,
    pub flags: u32,
    pub sq_thread_cpu: u32,
    pub sq_thread_idle: u32,
    pub features: u32,
    pub wq_fd: u32,
    pub resv: [u32; 3],
    pub sq_off: io_sqring_offsets,
    pub cq_off: io_cqring_offsets,
}

/// One submission-queue entry (64 bytes). The kernel's struct is a pile
/// of unions; this mirrors the fields the reactor uses, with `op_flags`
/// standing in for the `rw_flags`/`poll32_events`/`accept_flags` union
/// and `off` for `off`/`addr2`.
#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct io_uring_sqe {
    pub opcode: u8,
    pub flags: u8,
    pub ioprio: u16,
    pub fd: i32,
    pub off: u64,
    pub addr: u64,
    pub len: u32,
    pub op_flags: u32,
    pub user_data: u64,
    pub buf_index: u16,
    pub personality: u16,
    pub splice_fd_in: i32,
    pub addr3: u64,
    pub __pad2: u64,
}

/// One completion-queue entry (16 bytes).
#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct io_uring_cqe {
    pub user_data: u64,
    pub res: i32,
    pub flags: u32,
}

/// `IORING_ENTER_EXT_ARG` payload: lets a GETEVENTS wait carry a timeout
/// (`ts` points at a [`kernel_timespec`]) without an extra TIMEOUT SQE.
#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct io_uring_getevents_arg {
    pub sigmask: u64,
    pub sigmask_sz: u32,
    pub pad: u32,
    pub ts: u64,
}

/// `struct __kernel_timespec` (64-bit fields on every ABI).
#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct kernel_timespec {
    pub tv_sec: i64,
    pub tv_nsec: i64,
}

/// One entry of a provided-buffer ring (`struct io_uring_buf`, 16 bytes):
/// the userspace side publishes `{addr, len, bid}` triples at the ring
/// tail and the kernel consumes them for BUFFER_SELECT ops. Also the
/// head-of-ring shared layout (`struct io_uring_buf_ring` is a union
/// whose first entry's `resv`/tail word doubles as the ring tail), so a
/// pbuf ring mapping is just `ring_entries` of these.
#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct io_uring_buf {
    pub addr: u64,
    pub len: u32,
    pub bid: u16,
    /// In entry 0 of the ring this field *is* the ring tail
    /// (`io_uring_buf_ring.tail` in the kernel's union layout).
    pub resv: u16,
}

/// `IORING_REGISTER_PBUF_RING` argument (`struct io_uring_buf_reg`,
/// 40 bytes): where the [`io_uring_buf`] ring lives, how many entries it
/// has, and which buffer-group id (`sqe.buf_index` under
/// `IOSQE_BUFFER_SELECT`) selects it.
#[repr(C)]
#[derive(Clone, Copy, Debug, Default)]
pub struct io_uring_buf_reg {
    pub ring_addr: u64,
    pub ring_entries: u32,
    pub bgid: u16,
    pub flags: u16,
    pub resv: [u64; 3],
}

extern "C" {
    /// The raw variadic syscall trampoline (io_uring has no libc wrappers).
    fn syscall(num: c_long, ...) -> c_long;
}

/// `io_uring_setup(2)`: create a ring of (at least) `entries` SQEs and
/// return its fd, filling `p` with ring geometry and feature bits.
///
/// # Safety
/// `p` must point at a live, zero-initialized `io_uring_params`.
pub unsafe fn io_uring_setup(entries: u32, p: *mut io_uring_params) -> c_int {
    // SAFETY: forwarded per the function contract; the kernel writes only
    // within *p.
    unsafe { syscall(SYS_IO_URING_SETUP, entries as c_long, p) as c_int }
}

/// `io_uring_enter(2)`: submit `to_submit` staged SQEs and/or wait for
/// `min_complete` completions. `arg`/`argsz` carry the
/// [`io_uring_getevents_arg`] when `IORING_ENTER_EXT_ARG` is set, else
/// a sigset (null here).
///
/// # Safety
/// `fd` must be a live io_uring fd whose rings are mapped and whose
/// published SQ tail covers `to_submit` fully-written SQEs; `arg` must
/// match `flags`/`argsz`.
pub unsafe fn io_uring_enter(
    fd: c_int,
    to_submit: u32,
    min_complete: u32,
    flags: u32,
    arg: *const c_void,
    argsz: size_t,
) -> c_int {
    // SAFETY: forwarded per the function contract.
    unsafe {
        syscall(
            SYS_IO_URING_ENTER,
            fd as c_long,
            to_submit as c_long,
            min_complete as c_long,
            flags as c_long,
            arg,
            argsz as c_long,
        ) as c_int
    }
}

/// `io_uring_register(2)`: attach resources (e.g. a wakeup eventfd) to a
/// ring.
///
/// # Safety
/// `fd` must be a live io_uring fd and `arg`/`nr_args` must match what
/// `opcode` expects.
pub unsafe fn io_uring_register(
    fd: c_int,
    opcode: c_uint,
    arg: *const c_void,
    nr_args: c_uint,
) -> c_int {
    // SAFETY: forwarded per the function contract.
    unsafe {
        syscall(SYS_IO_URING_REGISTER, fd as c_long, opcode as c_long, arg, nr_args as c_long)
            as c_int
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_size_sane() {
        // SAFETY: sysconf has no memory preconditions.
        let sz = unsafe { sysconf(_SC_PAGESIZE) };
        assert!(sz >= 4096, "page size {sz}");
    }

    #[test]
    fn cpu_set_ops() {
        // SAFETY: cpu_set_t is a plain bitmask; all-zeroes is a valid value.
        let mut set: cpu_set_t = unsafe { std::mem::zeroed() };
        CPU_ZERO(&mut set);
        CPU_SET(0, &mut set);
        CPU_SET(70, &mut set);
        CPU_SET(4096, &mut set); // ignored, no panic
        assert_eq!(set.bits[0], 1);
        assert_eq!(set.bits[1], 1 << 6);
    }

    #[test]
    fn epoll_eventfd_roundtrip() {
        // SAFETY: raw syscall roundtrip — every pointer passed is a live local
        // (event buffer, u64 word), and fds are checked right after creation.
        unsafe {
            let ep = epoll_create1(EPOLL_CLOEXEC);
            assert!(ep >= 0, "epoll_create1 failed");
            let efd = eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC);
            assert!(efd >= 0, "eventfd failed");
            let mut ev = epoll_event { events: EPOLLIN, data: 0xABCD };
            assert_eq!(epoll_ctl(ep, EPOLL_CTL_ADD, efd, &mut ev), 0);

            // Nothing written yet: zero-timeout wait sees nothing.
            let mut out = [epoll_event { events: 0, data: 0 }; 4];
            assert_eq!(epoll_wait(ep, out.as_mut_ptr(), 4, 0), 0);

            // After a write, the eventfd is readable and carries our token.
            let one: u64 = 1;
            assert_eq!(write(efd, &one as *const u64 as *const c_void, 8), 8);
            let n = epoll_wait(ep, out.as_mut_ptr(), 4, 100);
            assert_eq!(n, 1);
            let data = out[0].data;
            assert_eq!(data, 0xABCD);

            // Draining the counter clears readiness (level-triggered).
            let mut val: u64 = 0;
            assert_eq!(read(efd, &mut val as *mut u64 as *mut c_void, 8), 8);
            assert_eq!(val, 1);
            assert_eq!(epoll_wait(ep, out.as_mut_ptr(), 4, 0), 0);

            assert_eq!(close(efd), 0);
            assert_eq!(close(ep), 0);
        }
    }

    #[test]
    fn mmap_roundtrip() {
        // SAFETY: fresh anonymous mapping; checked against MAP_FAILED before
        // any access, unmapped exactly once.
        unsafe {
            let p = mmap(
                std::ptr::null_mut(),
                8192,
                PROT_READ | PROT_WRITE,
                MAP_PRIVATE | MAP_ANONYMOUS,
                -1,
                0,
            );
            assert!(p != MAP_FAILED);
            *(p as *mut u8) = 0x5A;
            assert_eq!(*(p as *const u8), 0x5A);
            assert_eq!(munmap(p, 8192), 0);
        }
    }

    #[test]
    fn struct_layouts_match_the_abi() {
        assert_eq!(std::mem::size_of::<io_uring_sqe>(), 64);
        assert_eq!(std::mem::size_of::<io_uring_cqe>(), 16);
        assert_eq!(std::mem::size_of::<io_sqring_offsets>(), 40);
        assert_eq!(std::mem::size_of::<io_cqring_offsets>(), 40);
        assert_eq!(std::mem::size_of::<io_uring_params>(), 40 + 40 + 40);
        assert_eq!(std::mem::size_of::<io_uring_getevents_arg>(), 24);
        assert_eq!(std::mem::size_of::<kernel_timespec>(), 16);
        assert_eq!(std::mem::size_of::<io_uring_buf>(), 16);
        assert_eq!(std::mem::size_of::<io_uring_buf_reg>(), 40);
        // The bid sits at offset 12 — the kernel reads it from the shared
        // ring, so a silent field reorder would corrupt buffer accounting.
        let b = io_uring_buf { addr: 0, len: 0, bid: 0xBEEF, resv: 0 };
        // SAFETY: io_uring_buf is a 16-byte repr(C) POD (asserted above);
        // viewing it as raw bytes has no validity requirements.
        let raw: [u8; 16] = unsafe { std::mem::transmute(b) };
        assert_eq!(u16::from_ne_bytes([raw[12], raw[13]]), 0xBEEF);
    }

    #[test]
    fn io_uring_setup_reports_geometry_or_skips() {
        let mut p = io_uring_params::default();
        // SAFETY: p is a live zeroed params block; the fd is checked before
        // any use and closed exactly once.
        let fd = unsafe { io_uring_setup(8, &mut p) };
        if fd < 0 {
            eprintln!(
                "SKIP io_uring_setup_reports_geometry_or_skips: io_uring unavailable ({})",
                std::io::Error::last_os_error()
            );
            return;
        }
        assert!(p.sq_entries >= 8);
        assert!(p.cq_entries >= p.sq_entries);
        assert_eq!(p.sq_off.ring_entries > 0, true);
        // A NOP pushed through the raw ring protocol completes: maps the
        // rings, writes one SQE, publishes the tail, enters, reaps the CQE.
        let has_single_mmap = p.features & IORING_FEAT_SINGLE_MMAP != 0;
        if has_single_mmap {
            let sq_sz = (p.sq_off.array as usize) + p.sq_entries as usize * 4;
            let cq_sz = (p.cq_off.cqes as usize)
                + p.cq_entries as usize * std::mem::size_of::<io_uring_cqe>();
            let ring_sz = sq_sz.max(cq_sz);
            // SAFETY: mapping the ring fd at the documented offsets; every
            // result is checked against MAP_FAILED before use, and derived
            // pointers stay inside the mapping (offsets come from the kernel).
            unsafe {
                let ring = mmap(
                    std::ptr::null_mut(),
                    ring_sz,
                    PROT_READ | PROT_WRITE,
                    MAP_SHARED | MAP_POPULATE,
                    fd,
                    IORING_OFF_SQ_RING,
                );
                assert!(ring != MAP_FAILED);
                let sqes_sz = p.sq_entries as usize * std::mem::size_of::<io_uring_sqe>();
                let sqes = mmap(
                    std::ptr::null_mut(),
                    sqes_sz,
                    PROT_READ | PROT_WRITE,
                    MAP_SHARED | MAP_POPULATE,
                    fd,
                    IORING_OFF_SQES,
                );
                assert!(sqes != MAP_FAILED);
                let base = ring as *mut u8;
                let sq_tail = base.add(p.sq_off.tail as usize) as *mut u32;
                let sq_array = base.add(p.sq_off.array as usize) as *mut u32;
                let sqe = &mut *(sqes as *mut io_uring_sqe);
                *sqe = io_uring_sqe {
                    opcode: IORING_OP_NOP,
                    user_data: 0xC0FFEE,
                    ..Default::default()
                };
                *sq_array = 0;
                std::sync::atomic::fence(std::sync::atomic::Ordering::Release);
                sq_tail.write_volatile(sq_tail.read_volatile().wrapping_add(1));
                let rc = io_uring_enter(fd, 1, 1, IORING_ENTER_GETEVENTS, std::ptr::null(), 0);
                assert_eq!(rc, 1, "one SQE submitted");
                let cq_head = base.add(p.cq_off.head as usize) as *mut u32;
                let cq_tail = base.add(p.cq_off.tail as usize) as *const u32;
                assert_eq!(cq_tail.read_volatile().wrapping_sub(cq_head.read_volatile()), 1);
                let cqe = &*(base.add(p.cq_off.cqes as usize) as *const io_uring_cqe);
                assert_eq!(cqe.user_data, 0xC0FFEE);
                assert_eq!(cqe.res, 0);
                cq_head.write_volatile(cq_head.read_volatile().wrapping_add(1));
                assert_eq!(munmap(sqes as *mut c_void, sqes_sz), 0);
                assert_eq!(munmap(ring, ring_sz), 0);
            }
        }
        // SAFETY: fd was created by this test; closed exactly once.
        unsafe { close(fd) };
    }
}
