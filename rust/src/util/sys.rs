//! Minimal raw bindings to the platform C library for the few syscalls the
//! crate needs (`mmap` fiber stacks, `sched_setaffinity` pinning).
//!
//! The offline build environment has no crates.io access, so instead of the
//! `libc` crate we declare exactly the symbols we use. `std` already links
//! against the C library, so these `extern "C"` declarations resolve with
//! no extra build configuration. Linux-only, matching the fiber context
//! switch (sysv64) this crate targets.

#![allow(non_camel_case_types)]
#![cfg(target_os = "linux")]

pub use std::ffi::{c_int, c_long, c_void};

pub type size_t = usize;
pub type off_t = i64;
pub type pid_t = i32;

pub const PROT_NONE: c_int = 0x0;
pub const PROT_READ: c_int = 0x1;
pub const PROT_WRITE: c_int = 0x2;

pub const MAP_PRIVATE: c_int = 0x02;
pub const MAP_ANONYMOUS: c_int = 0x20;
pub const MAP_STACK: c_int = 0x20000;

pub const MAP_FAILED: *mut c_void = !0usize as *mut c_void;

pub const _SC_PAGESIZE: c_int = 30;

/// Linux `cpu_set_t`: a 1024-bit CPU mask.
#[repr(C)]
#[derive(Clone, Copy)]
pub struct cpu_set_t {
    bits: [u64; 16],
}

/// Clear every CPU in the set.
#[allow(non_snake_case)]
pub fn CPU_ZERO(set: &mut cpu_set_t) {
    set.bits = [0; 16];
}

/// Add `cpu` to the set (out-of-range bits are ignored, like glibc).
#[allow(non_snake_case)]
pub fn CPU_SET(cpu: usize, set: &mut cpu_set_t) {
    if cpu < 1024 {
        set.bits[cpu / 64] |= 1u64 << (cpu % 64);
    }
}

extern "C" {
    pub fn mmap(
        addr: *mut c_void,
        len: size_t,
        prot: c_int,
        flags: c_int,
        fd: c_int,
        offset: off_t,
    ) -> *mut c_void;
    pub fn munmap(addr: *mut c_void, len: size_t) -> c_int;
    pub fn mprotect(addr: *mut c_void, len: size_t, prot: c_int) -> c_int;
    pub fn sysconf(name: c_int) -> c_long;
    pub fn sched_setaffinity(pid: pid_t, cpusetsize: size_t, cpuset: *const cpu_set_t) -> c_int;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn page_size_sane() {
        let sz = unsafe { sysconf(_SC_PAGESIZE) };
        assert!(sz >= 4096, "page size {sz}");
    }

    #[test]
    fn cpu_set_ops() {
        let mut set: cpu_set_t = unsafe { std::mem::zeroed() };
        CPU_ZERO(&mut set);
        CPU_SET(0, &mut set);
        CPU_SET(70, &mut set);
        CPU_SET(4096, &mut set); // ignored, no panic
        assert_eq!(set.bits[0], 1);
        assert_eq!(set.bits[1], 1 << 6);
    }

    #[test]
    fn mmap_roundtrip() {
        unsafe {
            let p = mmap(
                std::ptr::null_mut(),
                8192,
                PROT_READ | PROT_WRITE,
                MAP_PRIVATE | MAP_ANONYMOUS,
                -1,
                0,
            );
            assert!(p != MAP_FAILED);
            *(p as *mut u8) = 0x5A;
            assert_eq!(*(p as *const u8), 0x5A);
            assert_eq!(munmap(p, 8192), 0);
        }
    }
}
