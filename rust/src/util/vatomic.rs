//! Virtual atomics — the shim between the crate's lock-free protocols and
//! the [`crate::model`] interleaving explorer.
//!
//! In a normal build every type here is a zero-cost `#[inline]` newtype
//! over `std::sync::atomic` (or `UnsafeCell` for [`VCell`]): same codegen
//! as using the std types directly. Under `--features model` every
//! load/store/cell access first consults a thread-local model context;
//! inside [`crate::model::explore`] the access becomes a scheduling yield
//! point with happens-before bookkeeping, outside one it falls back to
//! the plain operation. This is what lets `channel/slot.rs` run its real
//! header protocol under the explorer without a test-only fork of the
//! code.

use std::sync::atomic::{AtomicU64, Ordering};

#[cfg(feature = "model")]
use crate::model::VarId;

/// A `u64` atomic routed through the model explorer when one is active.
#[derive(Debug)]
pub struct VAtomicU64 {
    inner: AtomicU64,
    #[cfg(feature = "model")]
    vid: VarId,
}

impl VAtomicU64 {
    pub const fn new(v: u64) -> VAtomicU64 {
        VAtomicU64 {
            inner: AtomicU64::new(v),
            #[cfg(feature = "model")]
            vid: VarId::unregistered(),
        }
    }

    #[inline]
    pub fn load(&self, order: Ordering) -> u64 {
        #[cfg(feature = "model")]
        {
            crate::model::atomic_load(&self.vid, &self.inner, order)
        }
        #[cfg(not(feature = "model"))]
        {
            self.inner.load(order)
        }
    }

    #[inline]
    pub fn store(&self, val: u64, order: Ordering) {
        #[cfg(feature = "model")]
        {
            crate::model::atomic_store(&self.vid, &self.inner, val, order)
        }
        #[cfg(not(feature = "model"))]
        {
            self.inner.store(val, order)
        }
    }

    /// Raw value read with **no** scheduling yield point and **no**
    /// happens-before effect (the moral equivalent of peeking at memory).
    /// For [`crate::model::block_until`] predicates, which run outside
    /// the scheduled thread; production code should use [`Self::load`].
    #[inline]
    pub fn raw_load(&self) -> u64 {
        self.inner.load(Ordering::SeqCst)
    }
}

impl Default for VAtomicU64 {
    fn default() -> Self {
        VAtomicU64::new(0)
    }
}

/// A `bool` flavour of [`VAtomicU64`] (stored as 0/1), for ack flags like
/// the refcount spin-ack in `trust`.
#[derive(Debug, Default)]
pub struct VBool(VAtomicU64);

impl VBool {
    pub const fn new(v: bool) -> VBool {
        VBool(VAtomicU64::new(v as u64))
    }

    #[inline]
    pub fn load(&self, order: Ordering) -> bool {
        self.0.load(order) != 0
    }

    #[inline]
    pub fn store(&self, val: bool, order: Ordering) {
        self.0.store(val as u64, order)
    }

    /// See [`VAtomicU64::raw_load`].
    #[inline]
    pub fn raw_load(&self) -> bool {
        self.0.raw_load() != 0
    }
}

/// Non-atomic shared data whose accesses are *race-checked* by the model
/// explorer: a read or write with no happens-before edge to the last
/// conflicting access is reported as a torn read / data race.
///
/// This type exists for protocol **models** (the payload bytes a slot
/// header publishes, a refcount only the trustee may touch). It is
/// deliberately unusable for cross-thread sharing in normal builds:
///
/// - without the `model` feature it is `!Sync` (it wraps an
///   `UnsafeCell`), so safe code cannot share it across threads at all;
/// - with the `model` feature it is `Sync`, but any access outside a
///   model context panics, so the only concurrent accesses that can
///   happen are the serialized, race-checked ones inside
///   [`crate::model::explore`].
#[derive(Debug)]
pub struct VCell<T> {
    inner: std::cell::UnsafeCell<T>,
    #[cfg(feature = "model")]
    vid: VarId,
}

// SAFETY: with the `model` feature, every access (get/set) either runs
// inside the explorer — which runs exactly one virtual thread at a time
// under a global lock, making accesses data-race-free in the Rust sense
// even when the *modelled* protocol races (that is reported as a
// violation instead of executed as UB) — or panics before touching the
// cell. There is no Sync impl without the feature.
#[cfg(feature = "model")]
unsafe impl<T: Send> Sync for VCell<T> {}

impl<T: Copy> VCell<T> {
    pub const fn new(v: T) -> VCell<T> {
        VCell {
            inner: std::cell::UnsafeCell::new(v),
            #[cfg(feature = "model")]
            vid: VarId::unregistered(),
        }
    }

    /// Read the value (a race-checked model event).
    #[inline]
    pub fn get(&self) -> T {
        #[cfg(feature = "model")]
        crate::model::cell_read(&self.vid);
        // SAFETY: in a normal build the missing Sync impl confines us to
        // one thread; under the model feature `cell_read` has either
        // panicked or serialized us (explorer grants one thread at a
        // time, and the grant persists until our next yield point).
        unsafe { *self.inner.get() }
    }

    /// Write the value (a race-checked model event).
    #[inline]
    pub fn set(&self, v: T) {
        #[cfg(feature = "model")]
        crate::model::cell_write(&self.vid);
        // SAFETY: as in `get`.
        unsafe { *self.inner.get() = v }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering::{Acquire, Relaxed, Release};

    /// Outside a model (or without the feature) the shim is just an
    /// atomic.
    #[test]
    fn passthrough_semantics() {
        let a = VAtomicU64::new(7);
        assert_eq!(a.load(Relaxed), 7);
        a.store(9, Release);
        assert_eq!(a.load(Acquire), 9);
        assert_eq!(a.raw_load(), 9);

        let b = VBool::new(false);
        assert!(!b.load(Relaxed));
        b.store(true, Release);
        assert!(b.load(Acquire));
    }

    /// `VCell` passthrough — only without the model feature: with it,
    /// access outside a model context is a deliberate panic.
    #[cfg(not(feature = "model"))]
    #[test]
    fn vcell_passthrough() {
        let c = VCell::new(3u64);
        assert_eq!(c.get(), 3);
        c.set(4);
        assert_eq!(c.get(), 4);
    }
}
