//! The protocol-agnostic delegated connection engine.
//!
//! Before this module existed, `kvstore::server` and `memcache::server`
//! were two hand-rolled copies of the same connection-fiber loop
//! (read_burst → parse → delegate → spool responses → write_pending →
//! net_wait → drain-on-stop). Every new wire protocol cost a third copy,
//! and every hot-path improvement had to land twice. The engine owns that
//! loop once, parameterised by a [`Protocol`]:
//!
//! - **Ingest**: per-connection [`Inbuf`] with [`netfiber::MAX_INBUF`]
//!   backpressure and the `read_burst` fairness bound.
//! - **Parse + dispatch**: the protocol turns bytes into requests and
//!   hands each one to its backend with a [`Completion`] ticket; parse
//!   failures are *answered* (via [`Protocol::render_error`] —
//!   `ST_BAD_REQUEST`, `CLIENT_ERROR …`, `-ERR …`) before the connection
//!   winds down, never silently dropped and never a worker panic.
//! - **Response spooling** ([`Spool`]): both ordering disciplines —
//!   [`ResponseOrder::OutOfOrder`] for id-tagged protocols (the binary KV
//!   proto) appends each response as its delegation completes;
//!   [`ResponseOrder::InOrder`] for id-less protocols (memcached text,
//!   RESP) sequences completions through a reorder buffer so the wire
//!   sees request order even though shard completions arrive out of
//!   order. Response buffers are pooled and recycled per connection
//!   instead of allocated per response.
//! - **Egress** with partial-write cursors, the bounded stop-drain grace
//!   period (acked work reaches the wire; a never-reading peer cannot
//!   hold shutdown hostage), and [`NetPolicy`]-driven waiting (fd-park
//!   under epoll, yield under busy-poll).
//! - **Metrics**: per-worker connection counters ([`ConnMetrics`]).
//!
//! [`ServerCore`] wraps the engine with everything a TCP front end needs:
//! runtime construction, trustee topology, acceptor startup (fiber or
//! thread per [`NetPolicy`]), prefill, and teardown.

use super::netfiber::{self, net_wait, read_burst, write_pending, NetInfo, NetPolicy, ReadOutcome};
use crate::fiber;
use crate::runtime::uring;
use crate::runtime::Runtime;
use crate::util::cache::CachePadded;
use std::cell::RefCell;
use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, IntoRawFd};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

// ---------------------------------------------------------------------
// Protocol trait
// ---------------------------------------------------------------------

/// How a protocol's responses must hit the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResponseOrder {
    /// Responses carry a request id; the client matches them, so each one
    /// is transmitted as soon as its delegation completes (paper §6.3:
    /// "the client accepts responses out-of-order").
    OutOfOrder,
    /// The protocol has no request ids; responses to one connection must
    /// be transmitted in request order even though shard completions
    /// arrive out of order (paper §7: "the memcached socket worker thread
    /// must order the responses before they are transmitted").
    InOrder,
}

/// One wire protocol on top of the connection engine. Implementations are
/// per-connection (created by the factory passed to
/// [`ServerCore::try_start`]) and may keep parse state across calls.
///
/// Contract: `parse` must be **total** — arbitrary client bytes yield
/// `Err`, never a panic (a panicking fiber unwinds onto the worker's
/// scheduler stack and kills the thread). `dispatch` must eventually call
/// [`Completion::complete`] exactly once per request, from the same
/// worker (backend completion callbacks satisfy this).
pub trait Protocol: 'static {
    /// One parsed request.
    type Request;
    /// Why a byte stream failed to parse (protocol-specific).
    type Error;

    /// This protocol's response ordering discipline.
    const ORDER: ResponseOrder;

    /// Parse the next complete request out of `inbuf.unparsed()`,
    /// advancing the buffer past consumed bytes. `Ok(None)` means "wait
    /// for more bytes"; `Err` poisons the connection (it is answered via
    /// [`Protocol::render_error`], drained, and closed).
    fn parse(&mut self, inbuf: &mut Inbuf) -> Result<Option<Self::Request>, Self::Error>;

    /// Render the on-wire answer to a parse failure (e.g.
    /// `ST_BAD_REQUEST`, `CLIENT_ERROR bad command line format\r\n`,
    /// `-ERR Protocol error…\r\n`). May leave `out` empty to close
    /// without answering.
    fn render_error(&mut self, err: &Self::Error, out: &mut Vec<u8>);

    /// Render the on-wire answer for a request the engine **sheds** under
    /// overload (admission control past the [`ServerTuning::shed_high`]
    /// watermark, or deadline pressure): RESP `-BUSY`, memcached
    /// `SERVER_ERROR busy`, KV `ST_OVERLOADED`. Returning `false` (the
    /// default) means the protocol has no overload representation; the
    /// engine then dispatches the request normally instead of shedding.
    /// The shed answer rides the ordinary response spool, so in-order
    /// protocols keep sequence integrity across shed responses.
    fn render_overload(&mut self, _req: &Self::Request, _out: &mut Vec<u8>) -> bool {
        false
    }

    /// How many units of the connection's [`MAX_CONN_INFLIGHT`] budget
    /// this request consumes while outstanding. Default 1; protocols
    /// whose single request fans out into many backend operations (RESP
    /// `MGET k k k …`) report the fan-out so one compound request cannot
    /// amplify its way past the egress bound.
    fn cost(&self, _req: &Self::Request) -> u64 {
        1
    }

    /// Dispatch a parsed request toward the backend. The rendered
    /// response is handed back through `done` (see [`Completion`]).
    fn dispatch(&mut self, req: Self::Request, done: Completion);
}

// ---------------------------------------------------------------------
// Inbuf
// ---------------------------------------------------------------------

/// Per-connection receive buffer with a consumed cursor. The engine
/// appends socket bytes; the protocol consumes whole requests via
/// [`Inbuf::advance`]; the engine compacts once per loop.
///
/// Under the io_uring data plane the engine can also *attach* a borrowed
/// kernel-filled provided-buffer slice ([`Inbuf::attach_borrowed`]) in
/// place of the owned buffer: the protocol then parses straight out of
/// the kernel's memory (the whole-frame fast path, zero copies), and
/// only the unconsumed tail of a partial frame is copied once into the
/// owned buffer at [`Inbuf::detach_borrowed`]. The two modes are
/// exclusive — a slice is only attached while the owned backlog is
/// empty, so `unparsed()` is always one contiguous slice either way.
pub struct Inbuf {
    buf: Vec<u8>,
    consumed: usize,
    /// Borrowed kernel-filled slice (data plane); null when detached.
    /// Valid for `ext_len` bytes from attach until detach — the engine
    /// recycles the provided buffer only after `detach_borrowed`.
    ext: *const u8,
    ext_len: usize,
}

impl Inbuf {
    pub fn with_capacity(n: usize) -> Inbuf {
        Inbuf { buf: Vec::with_capacity(n), consumed: 0, ext: std::ptr::null(), ext_len: 0 }
    }

    fn attached(&self) -> bool {
        !self.ext.is_null()
    }

    /// The not-yet-consumed bytes.
    pub fn unparsed(&self) -> &[u8] {
        if self.attached() {
            // SAFETY: `attach_borrowed`'s contract keeps `ext` pointing
            // at `ext_len` readable bytes for the whole attachment (the
            // provided buffer stays engine-owned until the engine
            // recycles it, which happens only after detach), and
            // `advance` bounds `consumed <= ext_len`.
            unsafe {
                let left = self.ext_len - self.consumed;
                std::slice::from_raw_parts(self.ext.add(self.consumed), left)
            }
        } else {
            &self.buf[self.consumed..]
        }
    }

    /// Mark `n` bytes of [`Inbuf::unparsed`] as consumed.
    pub fn advance(&mut self, n: usize) {
        let limit = if self.attached() { self.ext_len } else { self.buf.len() };
        debug_assert!(self.consumed + n <= limit);
        self.consumed += n;
    }

    /// Unparsed backlog in bytes (what [`netfiber::MAX_INBUF`] bounds).
    pub fn backlog(&self) -> usize {
        if self.attached() {
            self.ext_len - self.consumed
        } else {
            self.buf.len() - self.consumed
        }
    }

    pub(crate) fn buf_mut(&mut self) -> &mut Vec<u8> {
        debug_assert!(!self.attached(), "owned buffer is inaccessible while a slice is attached");
        &mut self.buf
    }

    /// Data plane: parse directly out of a kernel-filled provided buffer.
    /// Caller contract: the owned backlog is empty, and `ptr` stays
    /// valid for `len` bytes until [`Inbuf::detach_borrowed`] returns
    /// (i.e. the provided buffer is recycled only after detach).
    pub(crate) fn attach_borrowed(&mut self, ptr: *const u8, len: usize) {
        debug_assert!(!self.attached());
        debug_assert_eq!(self.backlog(), 0);
        self.buf.clear();
        self.consumed = 0;
        self.ext = ptr;
        self.ext_len = len;
    }

    /// Detach the borrowed slice, copying any unconsumed tail into the
    /// owned buffer (the copy-once partial-frame path). After this
    /// returns the caller may recycle the provided buffer.
    pub(crate) fn detach_borrowed(&mut self) {
        if !self.attached() {
            return;
        }
        let (ptr, len, consumed) = (self.ext, self.ext_len, self.consumed);
        self.ext = std::ptr::null();
        self.ext_len = 0;
        self.consumed = 0;
        if consumed < len {
            // SAFETY: the provided buffer is still engine-owned here
            // (recycling happens only after this method returns), and
            // `consumed <= len` is maintained by `advance`.
            let tail = unsafe { std::slice::from_raw_parts(ptr.add(consumed), len - consumed) };
            self.buf.extend_from_slice(tail);
        }
    }

    fn compact(&mut self) {
        debug_assert!(!self.attached(), "compact only runs on the owned buffer");
        if self.consumed > 0 {
            self.buf.drain(..self.consumed);
            self.consumed = 0;
        }
    }
}

// ---------------------------------------------------------------------
// Spool
// ---------------------------------------------------------------------

/// Response buffers kept for reuse per connection (beyond this, excess
/// buffers are dropped).
const POOL_MAX: usize = 32;
/// A pooled buffer that grew past this capacity is dropped instead of
/// recycled, so one huge response cannot pin memory forever.
const POOL_BUF_MAX: usize = 64 * 1024;

/// Egress backpressure: most *cost units* ([`Protocol::cost`] — backend
/// operations, not just requests) one connection may have dispatched but
/// uncompleted. Together with [`MAX_OUTBUF`] this bounds what a client
/// that pipelines requests while never reading responses can make the
/// server buffer (`MAX_INBUF` alone only bounds *input* — parsed
/// requests would otherwise fan out into unboundedly many buffered
/// responses). Comfortably above every load generator's pipeline depth.
pub const MAX_CONN_INFLIGHT: u64 = 128;
/// Egress backpressure: once this many response bytes sit rendered or
/// reorder-parked but unsent, the connection stops parsing (and therefore
/// dispatching) until the peer drains its socket.
pub const MAX_OUTBUF: usize = 4 << 20;

/// Optional per-request deadline bookkeeping (only allocated when a
/// server configures [`ServerTuning::deadline_ms`]; the default path
/// carries a `None` and pays one branch per begin/complete).
///
/// Two structures because the two checkpoints need different access:
/// completion-delivery looks an arbitrary `seq` up (completions arrive
/// out of order), while dispatch asks "is the *oldest* outstanding
/// request past its deadline?" — a front-of-queue peek with lazy
/// dropping of entries that already completed.
struct DeadlineTracker {
    deadline: std::time::Duration,
    /// Outstanding seq → issue instant (completion-delivery checkpoint).
    issued: HashMap<u64, std::time::Instant>,
    /// Issue order (dispatch checkpoint); entries whose seq has left
    /// `issued` are dropped lazily on the next peek.
    order: std::collections::VecDeque<(u64, std::time::Instant)>,
    /// Completions delivered after their deadline (still delivered — the
    /// in-order spool needs every slot — but counted).
    misses: u64,
}

impl DeadlineTracker {
    fn on_begin(&mut self, seq: u64) {
        let now = std::time::Instant::now();
        self.issued.insert(seq, now);
        self.order.push_back((seq, now));
    }

    fn on_complete(&mut self, seq: u64) {
        if let Some(t0) = self.issued.remove(&seq) {
            if t0.elapsed() > self.deadline {
                self.misses += 1;
            }
        }
    }

    /// Is the oldest still-outstanding request past its deadline?
    fn pressure(&mut self) -> bool {
        while let Some(&(seq, t0)) = self.order.front() {
            if !self.issued.contains_key(&seq) {
                self.order.pop_front();
                continue;
            }
            return t0.elapsed() > self.deadline;
        }
        false
    }
}

/// Per-connection response spool: sequence allocation, completion
/// buffering under either [`ResponseOrder`], the wire-out buffer with its
/// partial-write cursor, and the response-buffer pool.
pub struct Spool {
    order: ResponseOrder,
    /// Next sequence number to hand out ([`Spool::begin`]).
    next_seq: u64,
    /// Completions received so far (either order).
    completed: u64,
    /// Outstanding [`Protocol::cost`] units (what [`MAX_CONN_INFLIGHT`]
    /// bounds).
    inflight_cost: u64,
    /// In-order only: next sequence to emit onto the wire.
    next_emit: u64,
    /// In-order only: completed-but-not-yet-emittable responses.
    pending: HashMap<u64, Vec<u8>>,
    /// Total bytes parked in `pending` (kept in sync for O(1) egress
    /// accounting).
    pending_bytes: usize,
    /// Bytes ready for (or partially on) the wire.
    out: Vec<u8>,
    /// How much of `out` is already written.
    wcursor: usize,
    pool: Vec<Vec<u8>>,
    pub pool_hits: u64,
    pub pool_misses: u64,
    /// Response bytes rendered through this spool (bytes-copied metric:
    /// one response-buffer → wire-buffer copy per completion).
    pub resp_bytes: u64,
    /// Per-request deadline bookkeeping; `None` (the default) when the
    /// server has no deadline configured.
    deadline: Option<DeadlineTracker>,
}

impl Spool {
    pub fn new(order: ResponseOrder) -> Spool {
        Spool {
            order,
            next_seq: 0,
            completed: 0,
            inflight_cost: 0,
            next_emit: 0,
            pending: HashMap::new(),
            pending_bytes: 0,
            out: Vec::with_capacity(32 * 1024),
            wcursor: 0,
            pool: Vec::new(),
            pool_hits: 0,
            pool_misses: 0,
            resp_bytes: 0,
            deadline: None,
        }
    }

    /// Arm per-request deadline tracking ([`ServerTuning::deadline_ms`]).
    pub fn set_deadline(&mut self, deadline: std::time::Duration) {
        self.deadline = Some(DeadlineTracker {
            deadline,
            issued: HashMap::new(),
            order: std::collections::VecDeque::new(),
            misses: 0,
        });
    }

    /// Completions delivered after their deadline so far.
    pub fn deadline_misses(&self) -> u64 {
        self.deadline.as_ref().map_or(0, |t| t.misses)
    }

    /// Dispatch checkpoint: is the oldest outstanding request already
    /// past its deadline? (Always false with no deadline configured.)
    pub fn deadline_pressure(&mut self) -> bool {
        self.deadline.as_mut().is_some_and(|t| t.pressure())
    }

    /// Allocate the next response slot, charging `cost` units against the
    /// [`MAX_CONN_INFLIGHT`] budget until completion. Under
    /// [`ResponseOrder::InOrder`] the wire emits slots strictly in
    /// `begin` order.
    pub fn begin(&mut self, cost: u64) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        self.inflight_cost += cost;
        if let Some(t) = &mut self.deadline {
            t.on_begin(s);
        }
        s
    }

    /// Check a (cleared) response buffer out of the pool.
    pub fn checkout(&mut self) -> Vec<u8> {
        match self.pool.pop() {
            Some(b) => {
                self.pool_hits += 1;
                b
            }
            None => {
                self.pool_misses += 1;
                Vec::with_capacity(256)
            }
        }
    }

    /// Hand back the rendered response for slot `seq`, releasing its
    /// `cost` charge. Out-of-order mode emits immediately; in-order mode
    /// emits the contiguous prefix of completed slots.
    pub fn complete(&mut self, seq: u64, cost: u64, buf: Vec<u8>) {
        self.completed += 1;
        self.inflight_cost -= cost;
        self.resp_bytes += buf.len() as u64;
        if let Some(t) = &mut self.deadline {
            t.on_complete(seq);
        }
        match self.order {
            ResponseOrder::OutOfOrder => self.emit(buf),
            ResponseOrder::InOrder => {
                if seq == self.next_emit {
                    self.emit(buf);
                    self.next_emit += 1;
                    while let Some(b) = self.pending.remove(&self.next_emit) {
                        self.pending_bytes -= b.len();
                        self.emit(b);
                        self.next_emit += 1;
                    }
                } else {
                    self.pending_bytes += buf.len();
                    self.pending.insert(seq, buf);
                }
            }
        }
    }

    fn emit(&mut self, b: Vec<u8>) {
        self.out.extend_from_slice(&b);
        self.recycle(b);
    }

    fn recycle(&mut self, mut b: Vec<u8>) {
        if self.pool.len() < POOL_MAX && b.capacity() <= POOL_BUF_MAX {
            b.clear();
            self.pool.push(b);
        }
    }

    /// Return an unused checked-out buffer to the pool (a shed attempt
    /// whose protocol declined to render an overload answer).
    pub fn give_back(&mut self, b: Vec<u8>) {
        self.recycle(b);
    }

    /// Requests dispatched but not yet completed.
    pub fn inflight(&self) -> u64 {
        self.next_seq - self.completed
    }

    /// Bytes rendered (or sequenced) but not yet on the wire.
    pub fn unsent(&self) -> usize {
        self.out.len() - self.wcursor
    }

    /// Everything buffered on the response side: unsent wire bytes plus
    /// reorder-parked completions (what [`MAX_OUTBUF`] bounds).
    pub fn egress_bytes(&self) -> usize {
        self.unsent() + self.pending_bytes
    }

    /// Whether the engine may parse + dispatch another request on this
    /// connection, or must let the peer drain responses first.
    pub fn admits_dispatch(&self) -> bool {
        self.inflight_cost < MAX_CONN_INFLIGHT && self.egress_bytes() < MAX_OUTBUF
    }

    /// In-order only: completed responses still waiting behind an
    /// incomplete earlier slot.
    pub fn reordering(&self) -> usize {
        self.pending.len()
    }

    /// Flush as much of the out-buffer as the socket accepts; false if
    /// the connection died.
    pub fn write_to(&mut self, stream: &mut TcpStream) -> bool {
        write_pending(stream, &mut self.out, &mut self.wcursor)
    }

    /// Data-plane egress: hand the unsent wire bytes to `submit` (the
    /// ring SEND path). When `submit` accepts them — copies them into
    /// the reactor's send buffers — the spool forgets them; delivery,
    /// short-write continuation SQEs, and failure detection belong to
    /// the reactor from then on. Returns the bytes handed off (0 when
    /// nothing was pending or `submit` refused).
    pub(crate) fn drain_into(&mut self, submit: impl FnOnce(&[u8]) -> bool) -> usize {
        let n = self.out.len() - self.wcursor;
        if n == 0 {
            return 0;
        }
        if submit(&self.out[self.wcursor..]) {
            self.out.clear();
            self.wcursor = 0;
            n
        } else {
            0
        }
    }
}

// ---------------------------------------------------------------------
// Tuning + engine-wide shared state
// ---------------------------------------------------------------------

/// Overload-control and graceful-degradation knobs shared by every front
/// end. Defaults reproduce the pre-overload-control behaviour except for
/// the shed watermarks, which sit far above anything a well-behaved
/// client mix reaches (the per-connection [`MAX_CONN_INFLIGHT`] gate
/// engages long before the server-wide watermark does).
#[derive(Clone, Copy, Debug)]
pub struct ServerTuning {
    /// Engage admission control once the server-wide sum of outstanding
    /// [`Protocol::cost`] units across the trustees' queues reaches this
    /// watermark: new requests get the protocol's overload answer
    /// ([`Protocol::render_overload`]) instead of queueing. `0` disables
    /// shedding entirely.
    pub shed_high: u64,
    /// Hysteresis: once shedding, keep shedding until the outstanding
    /// load drains below this (must be <= `shed_high`; the gap is what
    /// keeps a watermark-riding burst from flapping admit/shed per
    /// request).
    pub shed_low: u64,
    /// Per-request deadline in milliseconds, checked at dispatch (oldest
    /// outstanding request past its deadline ⇒ shed new arrivals) and at
    /// completion delivery (late completions are counted, still
    /// delivered). `0` disables deadlines — and keeps the steady-state
    /// path allocation-free.
    pub deadline_ms: u64,
    /// Slow-consumer defense: a connection with unsent response bytes
    /// whose peer makes no egress progress for this long is reaped. `0`
    /// disables reaping (and lets egress-blocked fibers park instead of
    /// polling the stall clock).
    pub conn_stall_ms: u64,
    /// How long a stopping server keeps draining acked-but-unsent
    /// responses before giving up on a peer that never reads
    /// (historically a hardcoded 250 ms).
    pub stop_drain_grace_ms: u64,
    /// Scheduler ticks with zero progress before an idle worker blocks in
    /// `epoll_wait`/`io_uring_enter` instead of spinning (historically
    /// the hardcoded `IDLE_EPOLL_TICKS = 256`).
    pub idle_ticks: u32,
}

impl Default for ServerTuning {
    fn default() -> Self {
        ServerTuning {
            shed_high: 4096,
            shed_low: 3072,
            deadline_ms: 0,
            conn_stall_ms: 0,
            stop_drain_grace_ms: 250,
            idle_ticks: 256,
        }
    }
}

impl ServerTuning {
    /// Validate knob coherence (reported before any worker spawns, like
    /// `validate_topology`).
    pub fn validate(&self) -> Result<(), String> {
        if self.shed_high > 0 && self.shed_low > self.shed_high {
            return Err(format!(
                "shed_low ({}) must be <= shed_high ({}): the hysteresis band \
                 disengages shedding below shed_low",
                self.shed_low, self.shed_high
            ));
        }
        if self.idle_ticks == 0 {
            return Err("idle_ticks must be >= 1 (0 would block workers on every idle tick)".into());
        }
        Ok(())
    }
}

/// State shared by every connection of one server: the ops counter, the
/// server-wide outstanding-cost gauge the shed watermarks act on, and the
/// hysteresis latch. One `Arc` per server, cloned into each
/// [`Completion`] (keeping `Completion` at 32 bytes — small enough that
/// the backends' 40-byte inline callbacks never spill to the heap).
pub(crate) struct EngineShared {
    /// Completed requests (the public `ops_served` counter).
    ops: Arc<AtomicU64>,
    /// Outstanding dispatched-but-uncompleted [`Protocol::cost`] units
    /// across all connections — the aggregate depth of the trustees'
    /// delegation queues as seen from the socket side.
    inflight: AtomicU64,
    /// Hysteresis latch: engaged at `shed_high`, released below
    /// `shed_low`.
    shedding: AtomicBool,
    tuning: ServerTuning,
}

impl EngineShared {
    fn new(ops: Arc<AtomicU64>, tuning: ServerTuning) -> Arc<EngineShared> {
        Arc::new(EngineShared {
            ops,
            inflight: AtomicU64::new(0),
            shedding: AtomicBool::new(false),
            tuning,
        })
    }

    /// Admission decision for a request of weight `cost`, advancing the
    /// hysteresis latch. Races between connection fibers on different
    /// workers are benign: the watermark is a load-shedding heuristic,
    /// not an exact bound.
    fn should_shed(&self, cost: u64) -> bool {
        let high = self.tuning.shed_high;
        if high == 0 {
            return false;
        }
        let q = self.inflight.load(Ordering::Relaxed);
        if self.shedding.load(Ordering::Relaxed) {
            if q < self.tuning.shed_low {
                self.shedding.store(false, Ordering::Relaxed);
                false
            } else {
                true
            }
        } else if q.saturating_add(cost) > high {
            self.shedding.store(true, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    fn admit(&self, cost: u64) {
        self.inflight.fetch_add(cost, Ordering::Relaxed);
    }

    fn release(&self, cost: u64) {
        self.inflight.fetch_sub(cost, Ordering::Relaxed);
    }

    /// Outstanding cost units right now (tests/diagnostics).
    #[cfg(test)]
    fn inflight_now(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------
// Completion
// ---------------------------------------------------------------------

/// The ticket a [`Protocol::dispatch`] implementation threads through its
/// backend callback: check a pooled buffer out, render the response into
/// it, and [`Completion::complete`]. Dropping a `Completion` without
/// completing it would wedge the in-order spool — always complete, even
/// for error responses.
pub struct Completion {
    spool: Rc<RefCell<Spool>>,
    seq: u64,
    cost: u64,
    shared: Arc<EngineShared>,
}

impl Completion {
    /// Check a (cleared, pooled) response buffer out of the connection's
    /// spool.
    pub fn checkout(&self) -> Vec<u8> {
        self.spool.borrow_mut().checkout()
    }

    /// Deliver the rendered response, release the request's overload
    /// charge, and count the op served.
    pub fn complete(self, buf: Vec<u8>) {
        self.spool.borrow_mut().complete(self.seq, self.cost, buf);
        self.shared.release(self.cost);
        self.shared.ops.fetch_add(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------
// Metrics
// ---------------------------------------------------------------------

/// Per-worker connection counters (one cache-padded slot per worker, no
/// cross-worker contention on the hot path).
#[derive(Default)]
pub struct WorkerConnStats {
    /// Connection fibers started on this worker.
    pub accepted: AtomicU64,
    /// Connection fibers exited on this worker.
    pub closed: AtomicU64,
    /// Requests parsed + dispatched.
    pub requests: AtomicU64,
    /// Connections poisoned by a parse error.
    pub parse_errors: AtomicU64,
    /// Response buffers served from the spool pool vs freshly allocated.
    pub pool_hits: AtomicU64,
    pub pool_misses: AtomicU64,
    /// Response bytes rendered into wire buffers (bytes-copied metric).
    pub resp_bytes: AtomicU64,
    /// Requests answered with the protocol's overload error instead of
    /// being dispatched (admission control past the shed watermark or
    /// under deadline pressure).
    pub shed: AtomicU64,
    /// Completions delivered after their request deadline (late but
    /// still delivered).
    pub deadline_misses: AtomicU64,
    /// Accept attempts that hit fd exhaustion (or an injected EMFILE)
    /// and took the exponential-backoff path.
    pub accept_throttled: AtomicU64,
    /// Connections reaped by the `conn_stall_ms` slow-consumer defense.
    pub stalled_reaped: AtomicU64,
}

pub struct ConnMetrics {
    per_worker: Vec<CachePadded<WorkerConnStats>>,
}

/// Aggregated [`ConnMetrics`] snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ConnTotals {
    pub accepted: u64,
    pub closed: u64,
    pub requests: u64,
    pub parse_errors: u64,
    pub pool_hits: u64,
    pub pool_misses: u64,
    pub resp_bytes: u64,
    pub shed: u64,
    pub deadline_misses: u64,
    pub accept_throttled: u64,
    pub stalled_reaped: u64,
}

impl ConnMetrics {
    pub fn new(workers: usize) -> Arc<ConnMetrics> {
        let mut per_worker = Vec::with_capacity(workers.max(1));
        per_worker.resize_with(workers.max(1), || CachePadded::new(WorkerConnStats::default()));
        Arc::new(ConnMetrics { per_worker })
    }

    /// The calling worker's slot (slot 0 off-runtime — accept thread).
    pub fn slot(&self) -> &WorkerConnStats {
        let w = crate::runtime::try_worker_id().unwrap_or(0);
        &self.per_worker[w % self.per_worker.len()]
    }

    pub fn worker(&self, w: usize) -> &WorkerConnStats {
        &self.per_worker[w % self.per_worker.len()]
    }

    pub fn totals(&self) -> ConnTotals {
        let mut t = ConnTotals::default();
        for s in &self.per_worker {
            t.accepted += s.accepted.load(Ordering::Relaxed);
            t.closed += s.closed.load(Ordering::Relaxed);
            t.requests += s.requests.load(Ordering::Relaxed);
            t.parse_errors += s.parse_errors.load(Ordering::Relaxed);
            t.pool_hits += s.pool_hits.load(Ordering::Relaxed);
            t.pool_misses += s.pool_misses.load(Ordering::Relaxed);
            t.resp_bytes += s.resp_bytes.load(Ordering::Relaxed);
            t.shed += s.shed.load(Ordering::Relaxed);
            t.deadline_misses += s.deadline_misses.load(Ordering::Relaxed);
            t.accept_throttled += s.accept_throttled.load(Ordering::Relaxed);
            t.stalled_reaped += s.stalled_reaped.load(Ordering::Relaxed);
        }
        t
    }
}

// ---------------------------------------------------------------------
// The connection fiber
// ---------------------------------------------------------------------

/// Step 2 of both connection loops: parse + dispatch every complete
/// request in `inbuf`, bounded by the egress gate, with overload
/// admission and parse-error poisoning. Sets `progress` when anything
/// parsed and `poisoned` on a parse failure (answered, never a panic).
fn parse_and_dispatch<P: Protocol>(
    proto: &mut P,
    inbuf: &mut Inbuf,
    spool: &Rc<RefCell<Spool>>,
    shared: &Arc<EngineShared>,
    metrics: &ConnMetrics,
    progress: &mut bool,
    poisoned: &mut bool,
) {
    while !*poisoned && spool.borrow().admits_dispatch() {
        match proto.parse(inbuf) {
            Ok(Some(req)) => {
                *progress = true;
                metrics.slot().requests.fetch_add(1, Ordering::Relaxed);
                let cost = proto.cost(&req).max(1);
                // Overload admission: past the shed watermark (or with
                // the oldest outstanding request already over its
                // deadline), answer with the protocol's overload error
                // instead of queueing more work onto the trustees. The
                // shed answer takes an ordinary spool slot, so in-order
                // protocols keep request/response sequence integrity.
                let overloaded = shared.should_shed(cost) || spool.borrow_mut().deadline_pressure();
                let mut shed = false;
                if overloaded {
                    let mut b = spool.borrow_mut().checkout();
                    if proto.render_overload(&req, &mut b) {
                        let seq = spool.borrow_mut().begin(1);
                        spool.borrow_mut().complete(seq, 1, b);
                        metrics.slot().shed.fetch_add(1, Ordering::Relaxed);
                        shed = true;
                    } else {
                        // Protocol cannot shed: dispatch normally.
                        spool.borrow_mut().give_back(b);
                    }
                }
                if !shed {
                    shared.admit(cost);
                    let seq = spool.borrow_mut().begin(cost);
                    let done =
                        Completion { spool: spool.clone(), seq, cost, shared: shared.clone() };
                    proto.dispatch(req, done);
                }
            }
            Ok(None) => break,
            Err(e) => {
                // Answer the failure (sequenced behind every earlier
                // command, like any other response), then wind down.
                *progress = true;
                metrics.slot().parse_errors.fetch_add(1, Ordering::Relaxed);
                let (seq, mut b) = {
                    let mut sp = spool.borrow_mut();
                    let seq = sp.begin(1);
                    let b = sp.checkout();
                    (seq, b)
                };
                proto.render_error(&e, &mut b);
                spool.borrow_mut().complete(seq, 1, b);
                *poisoned = true;
                break;
            }
        }
    }
}

/// Flush per-connection spool counters into the worker's metrics slot
/// (the shared tail of both connection loops).
fn flush_conn_stats(metrics: &ConnMetrics, spool: &Rc<RefCell<Spool>>) {
    let stats = metrics.slot();
    stats.closed.fetch_add(1, Ordering::Relaxed);
    let sp = spool.borrow();
    stats.pool_hits.fetch_add(sp.pool_hits, Ordering::Relaxed);
    stats.pool_misses.fetch_add(sp.pool_misses, Ordering::Relaxed);
    stats.resp_bytes.fetch_add(sp.resp_bytes, Ordering::Relaxed);
    stats.deadline_misses.fetch_add(sp.deadline_misses(), Ordering::Relaxed);
}

/// The shared connection loop: ingest → parse/dispatch → spool → egress →
/// exit checks → wait. One fiber per accepted connection.
fn connection_fiber<P: Protocol>(
    mut stream: TcpStream,
    mut proto: P,
    shared: Arc<EngineShared>,
    stop: Arc<AtomicBool>,
    policy: NetPolicy,
    metrics: Arc<ConnMetrics>,
) {
    if stream.set_nonblocking(true).is_err() {
        return;
    }
    stream.set_nodelay(true).ok();
    let stats = metrics.slot();
    stats.accepted.fetch_add(1, Ordering::Relaxed);
    // Data plane: under IoUring on a PBUF_RING-capable kernel, hand the
    // fd to this worker's reactor (multishot RECV + ring-batched SEND)
    // and run the data-plane loop instead. `conn_register` returning
    // `None` — no ring, no PBUF_RING support, the kill switch, or a full
    // conn slab — keeps this connection on the readiness plane below:
    // same engine semantics, read/write syscalls instead of provided
    // buffers.
    if policy == NetPolicy::IoUring {
        if let Some(token) = uring::conn_register(stream.as_raw_fd()) {
            // fd ownership moved to the reactor (it closes the fd after
            // in-flight SENDs settle); release the stream's claim so the
            // fd is not double-closed.
            let _ = stream.into_raw_fd();
            dataplane_fiber(token, proto, shared, stop, metrics);
            return;
        }
    }
    let fd = stream.as_raw_fd();
    let tuning = shared.tuning;
    let spool = Rc::new(RefCell::new(Spool::new(P::ORDER)));
    if tuning.deadline_ms > 0 {
        spool
            .borrow_mut()
            .set_deadline(std::time::Duration::from_millis(tuning.deadline_ms));
    }
    let grace = std::time::Duration::from_millis(tuning.stop_drain_grace_ms);
    // Slow-consumer defense: when enabled, a connection sitting on unsent
    // response bytes whose peer drains nothing for `conn_stall_ms` is
    // reaped instead of pinning its buffers forever.
    let stall = (tuning.conn_stall_ms > 0)
        .then(|| std::time::Duration::from_millis(tuning.conn_stall_ms));
    let mut last_egress_progress = std::time::Instant::now();
    let mut inbuf = Inbuf::with_capacity(32 * 1024);
    let mut peer_gone = false;
    // Malformed stream: answer (render_error), stop reading/parsing,
    // drain what's owed, close — never panic the worker.
    let mut poisoned = false;
    // On server stop, drain buffered responses for a bounded grace period
    // (acked work should reach the wire) without letting a peer that
    // never reads hold shutdown hostage.
    let mut stop_deadline: Option<std::time::Instant> = None;

    loop {
        let mut progress = false;
        let mut egress_progress = false;
        // 1. Ingest ("reading requests is done in batches"): drain the
        //    socket up to a fairness bound, and stop reading while the
        //    unparsed backlog is past MAX_INBUF (TCP backpressure instead
        //    of unbounded buffering).
        if !peer_gone && !poisoned && inbuf.backlog() < netfiber::MAX_INBUF {
            match read_burst(&mut stream, inbuf.buf_mut(), 64 * 1024) {
                ReadOutcome::Data(_) => progress = true,
                ReadOutcome::Closed => peer_gone = true,
                ReadOutcome::WouldBlock => {}
            }
        }
        // 2. Parse + dispatch every complete request — bounded by the
        //    egress gate: a client that pipelines requests while never
        //    reading responses must stall here (its inbuf then fills to
        //    MAX_INBUF and TCP backpressure takes over) instead of
        //    ballooning the response spool without bound.
        parse_and_dispatch(
            &mut proto,
            &mut inbuf,
            &spool,
            &shared,
            &metrics,
            &mut progress,
            &mut poisoned,
        );
        inbuf.compact();
        // 3. Egress ("sending results is done in batches").
        {
            let mut sp = spool.borrow_mut();
            let before = sp.unsent();
            if !sp.write_to(&mut stream) {
                break;
            }
            if sp.unsent() < before {
                progress = true;
                egress_progress = true;
            }
        }
        // 4. Exit conditions.
        let (inflight, unsent) = {
            let sp = spool.borrow();
            (sp.inflight(), sp.unsent())
        };
        if (peer_gone || poisoned) && inflight == 0 && unsent == 0 {
            break;
        }
        // Slow-consumer defense: reap a connection whose peer accepts no
        // response bytes for conn_stall_ms while we have bytes to send.
        if let Some(stall_after) = stall {
            if unsent == 0 || egress_progress {
                last_egress_progress = std::time::Instant::now();
            } else if last_egress_progress.elapsed() > stall_after {
                metrics.slot().stalled_reaped.fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
        if stop.load(Ordering::Acquire) && inflight == 0 {
            if unsent == 0 {
                break;
            }
            let deadline =
                *stop_deadline.get_or_insert_with(|| std::time::Instant::now() + grace);
            if std::time::Instant::now() >= deadline {
                break;
            }
        }
        // 5. Wait for more work. With responses in flight the wake comes
        //    from the scheduler (backend completions), so yield; otherwise
        //    the only possible wake is the socket — park on it (Epoll)
        //    instead of re-polling every tick (BusyPoll). With the stall
        //    clock armed and bytes unsent, an fd park could outlive the
        //    stall bound (the only fd signal would be peer progress —
        //    exactly what a stalled peer never produces), so stay in the
        //    bounded yield loop instead.
        if progress
            || inflight > 0
            || stop.load(Ordering::Acquire)
            || (stall.is_some() && unsent > 0)
        {
            fiber::yield_now();
        } else {
            let want_read = !peer_gone && !poisoned && inbuf.backlog() < netfiber::MAX_INBUF;
            let want_write = unsent > 0;
            net_wait(policy, fd, want_read, want_write);
        }
    }
    flush_conn_stats(&metrics, &spool);
}

/// The data-plane connection loop (io_uring provided buffers): the same
/// five steps as [`connection_fiber`], but ingest takes kernel-filled
/// slices queued by the worker's multishot RECV ([`uring::recv_take`] —
/// no read syscalls) and egress hands spooled bytes to ring-submitted
/// SENDs ([`uring::send_enqueue`] — no write syscalls). The reactor owns
/// the fd; it closes it after in-flight SENDs settle
/// ([`uring::conn_close`]), so a final response always gets its shot at
/// the wire.
///
/// `MAX_INBUF` backpressure works by *withholding replenishment*: past
/// the bound the fiber stops taking (and therefore recycling) provided
/// buffers, the pool drains, RECV hits `ENOBUFS`, and the kernel stalls
/// the peer at the wire — no reads, no syscalls, no committed
/// per-connection buffer while idle.
fn dataplane_fiber<P: Protocol>(
    token: usize,
    mut proto: P,
    shared: Arc<EngineShared>,
    stop: Arc<AtomicBool>,
    metrics: Arc<ConnMetrics>,
) {
    let tuning = shared.tuning;
    let spool = Rc::new(RefCell::new(Spool::new(P::ORDER)));
    if tuning.deadline_ms > 0 {
        spool
            .borrow_mut()
            .set_deadline(std::time::Duration::from_millis(tuning.deadline_ms));
    }
    let grace = std::time::Duration::from_millis(tuning.stop_drain_grace_ms);
    let stall = (tuning.conn_stall_ms > 0)
        .then(|| std::time::Duration::from_millis(tuning.conn_stall_ms));
    let mut last_egress_progress = std::time::Instant::now();
    let mut inbuf = Inbuf::with_capacity(32 * 1024);
    let mut peer_gone = false;
    let mut poisoned = false;
    // Ring RECV/SEND errored: responses can no longer reach this peer —
    // wind down without draining (mirrors `write_to` returning false).
    let mut conn_dead = false;
    let mut stop_deadline: Option<std::time::Instant> = None;
    // SEND bytes the reactor still holds, sampled each loop so a settle
    // counts as egress progress for the stall clock.
    let mut last_send_pending = 0usize;

    loop {
        let mut progress = false;
        let mut egress_progress = false;
        // 1. Ingest: take kernel-filled segments. At most one borrowed
        //    slice is attached per iteration (the whole-frame fast path,
        //    parsed in place); continuation segments of a partial frame
        //    are copied once into the owned buffer, bounded by the same
        //    fairness budget as `read_burst`.
        let mut borrowed: Option<(u16, bool)> = None;
        if !peer_gone && !poisoned && !conn_dead {
            let mut copied = 0usize;
            while inbuf.backlog() < netfiber::MAX_INBUF && copied < 64 * 1024 {
                match uring::recv_take(token) {
                    uring::RecvTake::Data { ptr, len, bid, owns } => {
                        progress = true;
                        if inbuf.backlog() == 0 {
                            inbuf.attach_borrowed(ptr, len as usize);
                            borrowed = Some((bid, owns));
                            break;
                        }
                        // SAFETY: the reactor guarantees `ptr` names
                        // `len` readable bytes of a provided-buffer
                        // segment that stays engine-owned until the
                        // `recv_recycle` call right below.
                        let seg = unsafe { std::slice::from_raw_parts(ptr, len as usize) };
                        inbuf.buf_mut().extend_from_slice(seg);
                        copied += seg.len();
                        uring::recv_recycle(bid, owns);
                    }
                    uring::RecvTake::Empty => break,
                    uring::RecvTake::Eof => {
                        peer_gone = true;
                        break;
                    }
                    uring::RecvTake::Err(_) => {
                        peer_gone = true;
                        conn_dead = true;
                        break;
                    }
                }
            }
        }
        // 2. Parse + dispatch (identical to the readiness plane; when a
        //    slice is attached the protocol parses kernel memory in
        //    place).
        parse_and_dispatch(
            &mut proto,
            &mut inbuf,
            &spool,
            &shared,
            &metrics,
            &mut progress,
            &mut poisoned,
        );
        // Detach before compaction/egress: any unconsumed tail is copied
        // once into the owned buffer and the provided buffer goes back
        // to the pool (replenishing the ring tail — the recycle half of
        // the backpressure contract).
        if let Some((bid, owns)) = borrowed.take() {
            inbuf.detach_borrowed();
            uring::recv_recycle(bid, owns);
        }
        inbuf.compact();
        // 3. Egress: hand the spooled bytes to the ring SEND path. The
        //    reactor copies them and owns delivery + short-write
        //    continuation SQEs from here. The handoff is bounded: past
        //    MAX_OUTBUF of unsettled SEND bytes the spool keeps the
        //    overflow, so `egress_bytes` grows and the dispatch gate
        //    closes — a client that pipelines requests while never
        //    reading responses cannot make the reactor buffer without
        //    bound (the data-plane analog of the readiness plane's
        //    partial-write cursor).
        {
            let mut sp = spool.borrow_mut();
            if uring::send_pending(token) < MAX_OUTBUF {
                let handed = sp.drain_into(|bytes| uring::send_enqueue(token, bytes));
                if handed > 0 {
                    progress = true;
                } else if sp.unsent() > 0 && uring::send_failed(token) {
                    conn_dead = true;
                }
            }
        }
        // 4. Exit conditions: as on the readiness plane, with "unsent"
        //    covering both the spool and the reactor's in-flight SENDs.
        let (inflight, spool_unsent) = {
            let sp = spool.borrow();
            (sp.inflight(), sp.unsent())
        };
        let send_pending = uring::send_pending(token);
        if send_pending < last_send_pending {
            progress = true;
            egress_progress = true;
        }
        last_send_pending = send_pending;
        let unsent = spool_unsent + send_pending;
        if conn_dead {
            break;
        }
        if (peer_gone || poisoned) && inflight == 0 && unsent == 0 {
            break;
        }
        // Slow-consumer defense, driven by SEND settles instead of
        // write() progress.
        if let Some(stall_after) = stall {
            if unsent == 0 || egress_progress {
                last_egress_progress = std::time::Instant::now();
            } else if last_egress_progress.elapsed() > stall_after {
                metrics.slot().stalled_reaped.fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
        if stop.load(Ordering::Acquire) && inflight == 0 {
            if unsent == 0 {
                break;
            }
            let deadline =
                *stop_deadline.get_or_insert_with(|| std::time::Instant::now() + grace);
            if std::time::Instant::now() >= deadline {
                break;
            }
        }
        // 5. Wait: with work in flight the wake comes from the scheduler
        //    (backend completions), so yield; otherwise park on the
        //    reactor's data-plane CQEs (RECV delivery, SEND settle).
        if progress
            || inflight > 0
            || stop.load(Ordering::Acquire)
            || (stall.is_some() && unsent > 0)
        {
            fiber::yield_now();
        } else {
            let want_read = !peer_gone && !poisoned && inbuf.backlog() < netfiber::MAX_INBUF;
            uring::conn_park(token, want_read);
        }
    }
    // Return the fd to the reactor for deferred close: in-flight SENDs
    // settle first, so the last response reaches the wire.
    uring::conn_close(token);
    flush_conn_stats(&metrics, &spool);
}

// ---------------------------------------------------------------------
// ServerCore
// ---------------------------------------------------------------------

/// Topology + socket configuration shared by every front end.
#[derive(Clone, Debug)]
pub struct CoreConfig {
    pub workers: usize,
    /// Dedicated trustee workers (shards live there; no socket fibers).
    pub dedicated: usize,
    pub addr: String,
    /// How connection fibers wait for socket progress.
    pub net: NetPolicy,
    /// Overload-control and degradation knobs.
    pub tuning: ServerTuning,
}

impl Default for CoreConfig {
    fn default() -> Self {
        CoreConfig {
            workers: 4,
            dedicated: 0,
            addr: "127.0.0.1:0".into(),
            net: NetPolicy::default(),
            tuning: ServerTuning::default(),
        }
    }
}

/// A running delegated TCP server: runtime, acceptor, connection engine.
/// Front ends ([`crate::kvstore::KvServer`], [`crate::memcache::McdServer`],
/// [`crate::server::resp::RespServer`]) wrap one of these plus their
/// backend handle.
pub struct ServerCore {
    rt: Option<Runtime>,
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    ops_served: Arc<AtomicU64>,
    metrics: Arc<ConnMetrics>,
    net: NetInfo,
}

impl ServerCore {
    /// Start the engine. `build` runs once after the runtime exists —
    /// with the runtime and the trustee worker ids — and returns the
    /// per-connection protocol factory (where front ends construct their
    /// backend and close over it). Configuration and bind problems are
    /// reported as descriptive errors *before* any worker thread spawns.
    pub fn try_start<P, F, B>(
        cfg: CoreConfig,
        accept_name: &str,
        build: B,
    ) -> Result<ServerCore, String>
    where
        P: Protocol + Send,
        F: FnMut() -> P + Send + 'static,
        B: FnOnce(&Runtime, &[usize]) -> F,
    {
        netfiber::validate_topology(cfg.workers, cfg.dedicated)?;
        cfg.tuning.validate()?;
        let listener =
            TcpListener::bind(&cfg.addr).map_err(|e| format!("bind {}: {e}", cfg.addr))?;
        let local_addr = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("nonblocking listener: {e}"))?;

        let rt = Runtime::builder()
            .workers(cfg.workers)
            .dedicated_trustees(cfg.dedicated)
            .idle_ticks(cfg.tuning.idle_ticks)
            .build();
        // Shard trustees: the dedicated workers if any, else all workers.
        let trustees: Vec<usize> = if cfg.dedicated > 0 {
            (0..cfg.dedicated).collect()
        } else {
            (0..cfg.workers).collect()
        };
        let mut factory = build(&rt, &trustees);

        let stop = Arc::new(AtomicBool::new(false));
        let ops_served = Arc::new(AtomicU64::new(0));
        let metrics = ConnMetrics::new(cfg.workers);
        let engine = EngineShared::new(ops_served.clone(), cfg.tuning);

        // Socket workers: the non-dedicated ones (validate_topology
        // guarantees at least one).
        let socket_workers: Vec<usize> = (cfg.dedicated..cfg.workers).collect();
        // Settle the policy against kernel capabilities once, here:
        // IoUring on a kernel without io_uring degrades to Epoll with a
        // reason logged once per server start, and every connection
        // fiber sees the result (including the data-plane capability).
        let net = cfg.net.settle();
        let policy = net.resolved;

        // Round-robin dispatch of accepted streams onto socket workers.
        let dispatch = {
            let engine = engine.clone();
            let stop = stop.clone();
            let metrics = metrics.clone();
            netfiber::round_robin_dispatch(
                rt.shared().clone(),
                socket_workers.clone(),
                move |stream| {
                    let proto = factory();
                    let engine = engine.clone();
                    let stop = stop.clone();
                    let metrics = metrics.clone();
                    Box::new(move || {
                        connection_fiber(stream, proto, engine, stop, policy, metrics)
                    })
                },
            )
        };

        // Epoll: the acceptor is a fiber parked on listener readability in
        // the first socket worker's reactor — no sleep-poll thread.
        // BusyPoll: the legacy 200 µs accept thread (A/B baseline).
        let accept_handle = netfiber::start_acceptor(
            policy,
            listener,
            stop.clone(),
            rt.shared(),
            socket_workers[0],
            dispatch,
            accept_name,
            metrics.clone(),
        )?;

        Ok(ServerCore {
            rt: Some(rt),
            local_addr,
            stop,
            accept_handle,
            ops_served,
            metrics,
            net,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    /// The settled network plane: requested vs resolved policy, whether
    /// the io_uring data plane (provided buffers) engaged, and the
    /// fallback reason when a degradation happened. Startup lines and
    /// stats introspection surface this so operators can tell which
    /// plane actually ran.
    pub fn net_info(&self) -> &NetInfo {
        &self.net
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt.as_ref().unwrap()
    }

    /// Completed requests across all connections (bumped by
    /// [`Completion::complete`]).
    pub fn ops_served(&self) -> &Arc<AtomicU64> {
        &self.ops_served
    }

    pub fn metrics(&self) -> &Arc<ConnMetrics> {
        &self.metrics
    }

    /// Channel-layer hot-path allocation/copy counters aggregated across
    /// the runtime's workers — surfaced next to [`ConnMetrics`] so a
    /// server driver can report delegation-layer allocations (inline-
    /// completion spills, heap records, slot bytes) alongside connection
    /// counters. Diagnostic: runs a short fiber per worker.
    pub fn hot_path_stats(&self) -> crate::runtime::HotPathStats {
        self.runtime().hot_path_totals()
    }

    /// io_uring submission/completion counters aggregated across the
    /// runtime's workers (zeros unless connections ran under
    /// `NetPolicy::IoUring`). The batching contract lives here: `enters`
    /// stays at ~one per scheduler loop no matter how many connections
    /// had pending I/O. Diagnostic: runs a short fiber per worker.
    pub fn uring_stats(&self) -> crate::runtime::uring::UringStats {
        self.runtime().uring_totals()
    }

    /// Issue `n` backend operations from a worker fiber with a bounded
    /// in-flight window ("Prior to each run, we pre-fill the table").
    /// `issue(i, on_done)` must arrange for `on_done()` when operation
    /// `i` completes.
    pub fn prefill(
        &self,
        n: u64,
        issue: impl Fn(u64, Box<dyn FnOnce() + 'static>) + Send + 'static,
    ) {
        let worker = self.runtime().workers() - 1;
        self.runtime().block_on(worker, move || {
            let done = Arc::new(AtomicU64::new(0));
            let mut issued = 0u64;
            while issued < n || done.load(Ordering::Relaxed) < n {
                // Keep a bounded window in flight so outboxes stay small.
                while issued < n && issued - done.load(Ordering::Relaxed) < 256 {
                    let d = done.clone();
                    issue(
                        issued,
                        Box::new(move || {
                            d.fetch_add(1, Ordering::Relaxed);
                        }),
                    );
                    issued += 1;
                }
                fiber::yield_now();
            }
        });
    }

    /// Stop accepting, drain connections (bounded), tear the runtime
    /// down. Idempotent; also runs on drop.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(rt) = self.rt.take() {
            rt.shutdown();
        }
    }
}

impl Drop for ServerCore {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rendered(bytes: &[u8], sp: &mut Spool) -> Vec<u8> {
        let mut b = sp.checkout();
        b.extend_from_slice(bytes);
        b
    }

    #[test]
    fn in_order_spool_delivers_in_sequence_despite_out_of_order_completions() {
        // Three requests dispatched in order A, B, C; shard completions
        // arrive C, A, B. The wire must still see A B C.
        let mut sp = Spool::new(ResponseOrder::InOrder);
        let (a, b, c) = (sp.begin(1), sp.begin(1), sp.begin(1));
        assert_eq!((a, b, c), (0, 1, 2));
        assert_eq!(sp.inflight(), 3);

        let buf = rendered(b"C;", &mut sp);
        sp.complete(c, 1, buf);
        assert_eq!(sp.unsent(), 0, "C must wait for A and B");
        assert_eq!(sp.reordering(), 1);

        let buf = rendered(b"A;", &mut sp);
        sp.complete(a, 1, buf);
        assert_eq!(&sp.out[..], b"A;", "A emits alone; B still missing");

        let buf = rendered(b"B;", &mut sp);
        sp.complete(b, 1, buf);
        assert_eq!(&sp.out[..], b"A;B;C;", "B unlocks the parked C");
        assert_eq!(sp.inflight(), 0);
        assert_eq!(sp.reordering(), 0);
    }

    #[test]
    fn out_of_order_spool_emits_on_completion() {
        let mut sp = Spool::new(ResponseOrder::OutOfOrder);
        let (a, b) = (sp.begin(1), sp.begin(1));
        let buf = rendered(b"B;", &mut sp);
        sp.complete(b, 1, buf);
        assert_eq!(&sp.out[..], b"B;", "no reordering for id-tagged protocols");
        let buf = rendered(b"A;", &mut sp);
        sp.complete(a, 1, buf);
        assert_eq!(&sp.out[..], b"B;A;");
        assert_eq!(sp.inflight(), 0);
    }

    #[test]
    fn spool_pools_and_reuses_response_buffers() {
        let mut sp = Spool::new(ResponseOrder::InOrder);
        for round in 0..10u64 {
            let seq = sp.begin(1);
            let mut b = sp.checkout();
            b.extend_from_slice(b"xxxxxxxx");
            sp.complete(seq, 1, b);
            if round == 0 {
                assert_eq!(sp.pool_misses, 1, "first checkout allocates");
            }
        }
        // After the first allocation every checkout was served by reuse.
        assert_eq!(sp.pool_misses, 1);
        assert_eq!(sp.pool_hits, 9);
        assert_eq!(sp.resp_bytes, 80, "10 responses x 8 bytes counted");
        // Oversized buffers are not retained: the single pooled buffer is
        // checked out, grown past the cap, and dropped on recycle.
        assert_eq!(sp.pool.len(), 1);
        let seq = sp.begin(1);
        let mut b = sp.checkout();
        b.reserve(POOL_BUF_MAX + 1);
        sp.complete(seq, 1, b);
        assert_eq!(sp.pool.len(), 0, "grown buffer must not be retained");
    }

    #[test]
    fn egress_gate_closes_on_inflight_and_buffered_bytes() {
        // Inflight cap: a pipelining client stalls at MAX_CONN_INFLIGHT
        // outstanding requests.
        let mut sp = Spool::new(ResponseOrder::InOrder);
        for _ in 0..MAX_CONN_INFLIGHT {
            sp.begin(1);
        }
        assert!(!sp.admits_dispatch(), "inflight cap must close the gate");

        // Unsent-bytes cap: rendered responses the peer never reads.
        let mut sp = Spool::new(ResponseOrder::OutOfOrder);
        let seq = sp.begin(1);
        let mut b = sp.checkout();
        b.resize(MAX_OUTBUF + 1, 0);
        sp.complete(seq, 1, b);
        assert_eq!(sp.egress_bytes(), MAX_OUTBUF + 1);
        assert!(!sp.admits_dispatch(), "unsent bytes must close the gate");

        // In-order: reorder-parked completions count toward the cap too.
        let mut sp = Spool::new(ResponseOrder::InOrder);
        let _head = sp.begin(1);
        let tail = sp.begin(1);
        let mut b = sp.checkout();
        b.resize(MAX_OUTBUF + 1, 0);
        sp.complete(tail, 1, b);
        assert_eq!(sp.unsent(), 0, "tail must be parked behind the head");
        assert!(!sp.admits_dispatch(), "parked bytes must close the gate");

        // Cost weighting: one compound request (e.g. a many-key MGET) can
        // consume the whole budget, and releases it on completion.
        let mut sp = Spool::new(ResponseOrder::InOrder);
        let seq = sp.begin(MAX_CONN_INFLIGHT);
        assert!(!sp.admits_dispatch(), "one expensive request fills the budget");
        let b = sp.checkout();
        sp.complete(seq, MAX_CONN_INFLIGHT, b);
        assert!(sp.admits_dispatch(), "completion releases the charge");
    }

    #[test]
    fn in_order_spool_handles_interleaved_begin_complete() {
        // begin/complete interleavings (a pipeline that keeps flowing):
        // emit order must match begin order at every step.
        let mut sp = Spool::new(ResponseOrder::InOrder);
        let a = sp.begin(1);
        let b = sp.begin(1);
        let buf = rendered(b"b", &mut sp);
        sp.complete(b, 1, buf);
        let c = sp.begin(1);
        let buf = rendered(b"c", &mut sp);
        sp.complete(c, 1, buf);
        assert_eq!(sp.unsent(), 0);
        let buf = rendered(b"a", &mut sp);
        sp.complete(a, 1, buf);
        assert_eq!(&sp.out[..], b"abc");
        assert_eq!(sp.inflight(), 0);
    }

    #[test]
    fn shed_hysteresis_engages_at_high_and_releases_below_low() {
        let tuning = ServerTuning { shed_high: 10, shed_low: 4, ..ServerTuning::default() };
        let s = EngineShared::new(Arc::new(AtomicU64::new(0)), tuning);
        assert!(!s.should_shed(10), "exactly at the watermark still admits");
        s.admit(10);
        assert_eq!(s.inflight_now(), 10);
        assert!(s.should_shed(1), "past the watermark sheds");
        s.release(5);
        assert!(s.should_shed(1), "hysteresis holds until load drops below shed_low");
        s.release(2); // inflight 3 < shed_low 4
        assert!(!s.should_shed(1), "below shed_low the latch releases");
        assert!(!s.should_shed(1), "and stays released while under the high watermark");
    }

    #[test]
    fn shed_high_zero_disables_admission_control() {
        let tuning = ServerTuning { shed_high: 0, ..ServerTuning::default() };
        let s = EngineShared::new(Arc::new(AtomicU64::new(0)), tuning);
        s.admit(u64::MAX / 2);
        assert!(!s.should_shed(u64::MAX / 2));
    }

    #[test]
    fn tuning_validation_rejects_inverted_band_and_zero_idle_ticks() {
        assert!(ServerTuning::default().validate().is_ok());
        let bad = ServerTuning { shed_high: 10, shed_low: 11, ..ServerTuning::default() };
        assert!(bad.validate().is_err(), "shed_low above shed_high must be rejected");
        let bad = ServerTuning { idle_ticks: 0, ..ServerTuning::default() };
        assert!(bad.validate().is_err(), "idle_ticks 0 must be rejected");
        // shed_high == 0 disables shedding; shed_low is then irrelevant.
        let ok = ServerTuning { shed_high: 0, shed_low: 11, ..ServerTuning::default() };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn deadline_tracker_counts_late_completions_and_reports_pressure() {
        // Fast path: generous deadline, nothing is late.
        let mut sp = Spool::new(ResponseOrder::InOrder);
        sp.set_deadline(std::time::Duration::from_secs(10));
        let a = sp.begin(1);
        assert!(!sp.deadline_pressure());
        let b = sp.checkout();
        sp.complete(a, 1, b);
        assert_eq!(sp.deadline_misses(), 0);

        // Slow path: the oldest outstanding request ages past its
        // deadline (dispatch checkpoint), and its eventual completion is
        // counted late but still delivered (completion checkpoint).
        let mut sp = Spool::new(ResponseOrder::InOrder);
        sp.set_deadline(std::time::Duration::from_millis(1));
        let a = sp.begin(1);
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(sp.deadline_pressure(), "oldest outstanding request is past deadline");
        let b = sp.checkout();
        sp.complete(a, 1, b);
        assert_eq!(sp.deadline_misses(), 1);
        assert!(!sp.deadline_pressure(), "nothing outstanding anymore");
    }
}
