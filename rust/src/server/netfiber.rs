//! Non-blocking socket helpers for fibers, shared by every front end of
//! the delegated server core ([`crate::server::engine`]): a connection
//! fiber reads and writes without ever blocking its worker thread. What
//! happens when the socket has no progress to offer is the [`NetPolicy`]:
//!
//! - [`NetPolicy::BusyPoll`] — the original yield loop: the fiber yields
//!   to the scheduler and is re-run every tick, re-`read()`ing its socket
//!   each time. Idle connections cost O(connections) per tick.
//! - [`NetPolicy::Epoll`] — the fiber parks on its fd in the worker's
//!   readiness reactor ([`crate::runtime::reactor`]) and is woken only
//!   when the fd becomes readable/writable. Idle connections cost
//!   O(ready fds) per tick, so they no longer steal serve-phase capacity
//!   from the trustees (paper §6.3/§7's saturation assumption).

use crate::fiber;
use crate::runtime::reactor;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Cap on unparsed receive-buffer backlog: a connection stops reading
/// (applies TCP backpressure) rather than buffering a hostile or runaway
/// pipeline without bound. Must exceed `proto::MAX_FRAME_LEN` + one frame
/// header so any single legal frame can always complete.
pub const MAX_INBUF: usize = (1 << 20) + (1 << 16);

/// How a connection fiber waits for socket progress.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum NetPolicy {
    /// Re-poll the socket every scheduler tick (pre-reactor behaviour,
    /// kept for A/B comparison — bench E15).
    BusyPoll,
    /// Park on fd readiness in the per-worker epoll reactor.
    #[default]
    Epoll,
}

impl NetPolicy {
    /// Parse a CLI spec (`busy` | `epoll`).
    pub fn from_spec(s: &str) -> NetPolicy {
        match s {
            "busy" | "busypoll" | "busy-poll" => NetPolicy::BusyPoll,
            "epoll" => NetPolicy::Epoll,
            other => panic!("unknown net policy {other:?} (want busy|epoll)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            NetPolicy::BusyPoll => "busy-poll",
            NetPolicy::Epoll => "epoll",
        }
    }
}

/// Wait until `fd` may have progress to offer: one scheduler yield under
/// [`NetPolicy::BusyPoll`], a park on fd readiness (readable when
/// `want_read`, writable when `want_write`) under [`NetPolicy::Epoll`].
/// Wake-ups may be spurious either way — callers re-check their socket and
/// loop. A connection that will no longer read (poisoned / half-closed)
/// must pass `want_read: false` so stale inbound bytes cannot wake-storm
/// it.
pub fn net_wait(policy: NetPolicy, fd: i32, want_read: bool, want_write: bool) {
    match policy {
        NetPolicy::BusyPoll => fiber::yield_now(),
        NetPolicy::Epoll => reactor::wait_fd(fd, want_read, want_write),
    }
}

/// Outcome of one read attempt.
pub enum ReadOutcome {
    /// `n` bytes appended to the buffer.
    Data(usize),
    /// Socket has nothing right now (caller should yield).
    WouldBlock,
    /// Peer closed or connection errored.
    Closed,
}

/// Read whatever is available into `buf` (append), one chunk.
pub fn read_available(stream: &mut TcpStream, buf: &mut Vec<u8>) -> ReadOutcome {
    let mut chunk = [0u8; 16 * 1024];
    match stream.read(&mut chunk) {
        Ok(0) => ReadOutcome::Closed,
        Ok(n) => {
            buf.extend_from_slice(&chunk[..n]);
            ReadOutcome::Data(n)
        }
        Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted => {
            ReadOutcome::WouldBlock
        }
        Err(_) => ReadOutcome::Closed,
    }
}

/// Drain the socket into `buf` until it would block, the peer closes, or
/// roughly `max_bytes` were read this burst (fairness bound: a firehose
/// peer must not monopolize the fiber's worker). EOF/error after some data
/// reports the data first; the sticky condition resurfaces on the next
/// call.
pub fn read_burst(stream: &mut TcpStream, buf: &mut Vec<u8>, max_bytes: usize) -> ReadOutcome {
    let mut total = 0usize;
    let mut chunk = [0u8; 16 * 1024];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => {
                return if total > 0 { ReadOutcome::Data(total) } else { ReadOutcome::Closed };
            }
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                total += n;
                if total >= max_bytes {
                    return ReadOutcome::Data(total);
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted => {
                return if total > 0 { ReadOutcome::Data(total) } else { ReadOutcome::WouldBlock };
            }
            Err(_) => {
                return if total > 0 { ReadOutcome::Data(total) } else { ReadOutcome::Closed };
            }
        }
    }
}

/// Write as much of `buf[*cursor..]` as the socket accepts; advances
/// `cursor`. Returns false if the connection died. When the whole buffer
/// drains, both buffer and cursor reset.
pub fn write_pending(stream: &mut TcpStream, buf: &mut Vec<u8>, cursor: &mut usize) -> bool {
    while *cursor < buf.len() {
        match stream.write(&buf[*cursor..]) {
            Ok(0) => return false,
            Ok(n) => *cursor += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted => {
                break;
            }
            Err(_) => return false,
        }
    }
    if *cursor == buf.len() && !buf.is_empty() {
        buf.clear();
        *cursor = 0;
    }
    true
}

/// Validate a server's worker topology before any runtime is built:
/// misconfigurations that used to die on internal asserts after worker
/// threads were already spawned report here as descriptive errors.
pub fn validate_topology(workers: usize, dedicated: usize) -> Result<(), String> {
    if workers == 0 {
        return Err("workers must be >= 1".into());
    }
    if dedicated >= workers {
        return Err(format!(
            "dedicated trustees ({dedicated}) must be fewer than workers ({workers}): \
             at least one non-dedicated socket worker is required to host connection fibers"
        ));
    }
    Ok(())
}

/// Build the accepted-stream dispatcher shared by the KV and memcached
/// servers: round-robin each new connection onto a socket worker and
/// inject a job that spawns its connection fiber there. `make_fiber`
/// turns the stream into the per-connection fiber body (where each server
/// closes over its backend/engine, counters, stop flag, and net policy).
pub fn round_robin_dispatch(
    shared: Arc<crate::runtime::Shared>,
    socket_workers: Vec<usize>,
    mut make_fiber: impl FnMut(TcpStream) -> Box<dyn FnOnce() + Send + 'static> + Send + 'static,
) -> impl FnMut(TcpStream) + Send + 'static {
    let mut next = 0usize;
    move |stream: TcpStream| {
        let worker = socket_workers[next % socket_workers.len()];
        next += 1;
        let fiber_body = make_fiber(stream);
        shared.inject(
            worker,
            Box::new(move || {
                fiber::with_executor(|e| {
                    e.spawn(fiber_body);
                });
            }),
        );
    }
}

/// Accept-loop *fiber* body (the [`NetPolicy::Epoll`] replacement for the
/// dedicated 200 µs sleep-poll accept thread): accepts until the listener
/// would block, hands each stream to `dispatch`, then parks on listener
/// readability. Exits only when `stop` is set — the runtime's shutdown
/// sweep wakes the park, so setting `stop` before `Runtime::shutdown()`
/// is enough to terminate it. Transient accept errors (ECONNABORTED, fd
/// exhaustion under a connection flood, EINTR) must NOT kill the
/// acceptor: the listener would be dead forever once the flood passed, so
/// every error path yields and retries.
pub fn accept_fiber(
    listener: TcpListener,
    policy: NetPolicy,
    stop: Arc<AtomicBool>,
    mut dispatch: impl FnMut(TcpStream),
) {
    let fd = listener.as_raw_fd();
    loop {
        if stop.load(Ordering::Acquire) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => dispatch(stream),
            Err(e) if e.kind() == ErrorKind::WouldBlock => net_wait(policy, fd, true, false),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            // EMFILE/ENFILE/ECONNABORTED/…: back off a fiber slice and
            // retry. The pending backlog keeps the fd readable, so under
            // Epoll a park would wake right back — yield instead.
            Err(_) => fiber::yield_now(),
        }
    }
}

/// Start the accept loop for `policy`: an fd-parked fiber on `worker`
/// under [`NetPolicy::Epoll`] (no thread), or the legacy dedicated
/// 200 µs sleep-poll thread under [`NetPolicy::BusyPoll`] (returned for
/// joining at stop). Shared by the KV and memcached servers.
pub fn start_acceptor(
    policy: NetPolicy,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    shared: &Arc<crate::runtime::Shared>,
    worker: usize,
    mut dispatch: impl FnMut(TcpStream) + Send + 'static,
    thread_name: &str,
) -> Result<Option<std::thread::JoinHandle<()>>, String> {
    match policy {
        NetPolicy::Epoll => {
            shared.inject(
                worker,
                Box::new(move || {
                    fiber::with_executor(|e| {
                        e.spawn(move || accept_fiber(listener, policy, stop, dispatch));
                    });
                }),
            );
            Ok(None)
        }
        NetPolicy::BusyPoll => {
            let handle = std::thread::Builder::new()
                .name(thread_name.into())
                .spawn(move || {
                    while !stop.load(Ordering::Acquire) {
                        match listener.accept() {
                            Ok((stream, _peer)) => dispatch(stream),
                            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                                std::thread::sleep(std::time::Duration::from_micros(200));
                            }
                            Err(e) if e.kind() == ErrorKind::Interrupted => {}
                            // Transient (fd exhaustion, aborted handshake):
                            // never kill the acceptor; retry after a pause.
                            Err(_) => {
                                std::thread::sleep(std::time::Duration::from_millis(1));
                            }
                        }
                    }
                })
                .map_err(|e| format!("spawn acceptor: {e}"))?;
            Ok(Some(handle))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn echo_over_nonblocking_pair() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            s.set_nonblocking(true).unwrap();
            let mut inbuf = Vec::new();
            let mut out = Vec::new();
            let mut cur = 0usize;
            loop {
                match read_available(&mut s, &mut inbuf) {
                    ReadOutcome::Data(_) => {
                        out.extend_from_slice(&inbuf);
                        inbuf.clear();
                    }
                    ReadOutcome::WouldBlock => std::thread::yield_now(),
                    ReadOutcome::Closed => break,
                }
                if !write_pending(&mut s, &mut out, &mut cur) {
                    break;
                }
            }
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"hello fiber net").unwrap();
        let mut back = [0u8; 15];
        c.read_exact(&mut back).unwrap();
        assert_eq!(&back, b"hello fiber net");
        drop(c);
        t.join().unwrap();
    }

    #[test]
    fn read_burst_drains_until_wouldblock() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut c = TcpStream::connect(addr).unwrap();
        let (mut s, _) = listener.accept().unwrap();
        s.set_nonblocking(true).unwrap();

        let payload = vec![0x5Au8; 100_000];
        c.write_all(&payload).unwrap();
        c.flush().unwrap();
        // Give loopback delivery a moment, then burst-read with a bound.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let mut buf = Vec::new();
        let mut got = 0usize;
        loop {
            match read_burst(&mut s, &mut buf, 32 * 1024) {
                ReadOutcome::Data(n) => {
                    assert!(n >= 1);
                    got += n;
                    if got >= payload.len() {
                        break;
                    }
                }
                ReadOutcome::WouldBlock => std::thread::sleep(std::time::Duration::from_millis(1)),
                ReadOutcome::Closed => panic!("peer still open"),
            }
        }
        assert_eq!(buf, payload);
        // Peer closes: burst now reports Closed.
        drop(c);
        loop {
            match read_burst(&mut s, &mut buf, 1024) {
                ReadOutcome::Closed => break,
                ReadOutcome::WouldBlock => std::thread::sleep(std::time::Duration::from_millis(1)),
                ReadOutcome::Data(_) => panic!("no more data expected"),
            }
        }
    }

    #[test]
    fn net_policy_specs_parse() {
        assert_eq!(NetPolicy::from_spec("busy"), NetPolicy::BusyPoll);
        assert_eq!(NetPolicy::from_spec("epoll"), NetPolicy::Epoll);
        assert_eq!(NetPolicy::default(), NetPolicy::Epoll);
        assert_eq!(NetPolicy::BusyPoll.label(), "busy-poll");
    }
}
