//! Non-blocking socket helpers for fibers, shared by every front end of
//! the delegated server core ([`crate::server::engine`]): a connection
//! fiber reads and writes without ever blocking its worker thread. What
//! happens when the socket has no progress to offer is the [`NetPolicy`]:
//!
//! - [`NetPolicy::BusyPoll`] — the original yield loop: the fiber yields
//!   to the scheduler and is re-run every tick, re-`read()`ing its socket
//!   each time. Idle connections cost O(connections) per tick.
//! - [`NetPolicy::Epoll`] — the fiber parks on its fd in the worker's
//!   readiness reactor ([`crate::runtime::reactor`]) and is woken only
//!   when the fd becomes readable/writable. Idle connections cost
//!   O(ready fds) per tick, so they no longer steal serve-phase capacity
//!   from the trustees (paper §6.3/§7's saturation assumption).
//! - [`NetPolicy::IoUring`] — the fiber parks by *staging* a poll SQE
//!   into the worker's io_uring submission ring
//!   ([`crate::runtime::uring`]); the scheduler publishes the whole
//!   loop's parks with one `io_uring_enter` and harvests readiness from
//!   the completion ring with no syscall, and the listener runs on a
//!   single multishot-accept SQE. Same delegation philosophy as the slot
//!   matrix, applied to the kernel boundary (DESIGN.md,
//!   "Kernel-boundary batching"). Requires kernel support — resolve via
//!   [`NetPolicy::resolve`], which falls back to Epoll with a logged
//!   reason instead of failing.

use super::engine::ConnMetrics;
use crate::fiber;
use crate::runtime::{reactor, uring};
use crate::util::faultsim;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Process-wide tallies of the socket syscalls the fallback (readiness)
/// data path issues. The E23 bench divides these by ops to show the data
/// plane's read-syscalls/op ≈ 0; cheap relaxed increments next to an
/// actual syscall.
static READ_SYSCALLS: AtomicU64 = AtomicU64::new(0);
static WRITE_SYSCALLS: AtomicU64 = AtomicU64::new(0);

/// `read(2)`-family calls issued by [`read_available`]/[`read_burst`]
/// since process start.
pub fn read_syscalls() -> u64 {
    READ_SYSCALLS.load(Ordering::Relaxed)
}

/// `write(2)`-family calls issued by [`write_pending`] since process
/// start.
pub fn write_syscalls() -> u64 {
    WRITE_SYSCALLS.load(Ordering::Relaxed)
}

/// Cap on unparsed receive-buffer backlog: a connection stops reading
/// (applies TCP backpressure) rather than buffering a hostile or runaway
/// pipeline without bound. Must exceed `proto::MAX_FRAME_LEN` + one frame
/// header so any single legal frame can always complete.
pub const MAX_INBUF: usize = (1 << 20) + (1 << 16);

/// How a connection fiber waits for socket progress.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum NetPolicy {
    /// Re-poll the socket every scheduler tick (pre-reactor behaviour,
    /// kept for A/B comparison — bench E15).
    BusyPoll,
    /// Park on fd readiness in the per-worker epoll reactor.
    #[default]
    Epoll,
    /// Park via a poll SQE in the per-worker io_uring; submissions are
    /// batched one-`io_uring_enter`-per-scheduler-loop and completions
    /// harvested syscall-free.
    IoUring,
}

impl NetPolicy {
    /// Parse a CLI spec (`busy` | `epoll` | `uring`). Unknown specs are a
    /// descriptive `Err`, surfaced through the server configs' `validate()`
    /// like every other config check.
    pub fn from_spec(s: &str) -> Result<NetPolicy, String> {
        match s {
            "busy" | "busypoll" | "busy-poll" => Ok(NetPolicy::BusyPoll),
            "epoll" => Ok(NetPolicy::Epoll),
            "uring" | "io_uring" | "iouring" | "io-uring" => Ok(NetPolicy::IoUring),
            other => Err(format!("unknown net policy {other:?} (want busy|epoll|uring)")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            NetPolicy::BusyPoll => "busy-poll",
            NetPolicy::Epoll => "epoll",
            NetPolicy::IoUring => "uring",
        }
    }

    /// Resolve the policy against kernel capabilities: [`NetPolicy::IoUring`]
    /// degrades to [`NetPolicy::Epoll`] — never a panic — when the
    /// io_uring probe fails (old kernel, seccomp, `io_uring_disabled`
    /// sysctl). Silent: logging belongs to [`NetPolicy::settle`], which
    /// each server calls exactly once at start-up (so a fallback is
    /// reported once per server start, not once per probe call).
    pub fn resolve(self) -> NetPolicy {
        self.settle_quietly().resolved
    }

    /// Resolve and report: returns the full [`NetInfo`] (requested vs
    /// resolved policy, data-plane capability, fallback reason) and logs
    /// a fallback to stderr. Servers call this once per start; every
    /// other caller uses the silent [`NetPolicy::resolve`].
    pub fn settle(self) -> NetInfo {
        let info = self.settle_quietly();
        if let Some(reason) = &info.fallback_reason {
            eprintln!("net policy uring unavailable ({reason}); falling back to epoll");
        }
        info
    }

    fn settle_quietly(self) -> NetInfo {
        let (resolved, fallback_reason) = match self {
            NetPolicy::IoUring => match uring::probe() {
                Ok(()) => (NetPolicy::IoUring, None),
                Err(e) => (NetPolicy::Epoll, Some(e)),
            },
            p => (p, None),
        };
        let dataplane = resolved == NetPolicy::IoUring
            && uring::dataplane_enabled()
            && uring::probe_pbuf().is_ok();
        NetInfo { requested: self, resolved, dataplane, fallback_reason }
    }
}

/// The settled network plane of a running server: which policy was asked
/// for, which one actually runs, and whether the io_uring *data* plane
/// (provided-buffer RECV/SEND) is engaged — surfaced in startup lines
/// and introspection so operators can tell which plane ran.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetInfo {
    pub requested: NetPolicy,
    pub resolved: NetPolicy,
    /// Provided-buffer data plane engaged (pbuf-capable kernel and the
    /// `TRUSTEE_URING_NO_PBUF` kill switch not set).
    pub dataplane: bool,
    /// Why an [`NetPolicy::IoUring`] request degraded, when it did.
    pub fallback_reason: Option<String>,
}

impl NetInfo {
    /// Short plane label: `busy-poll`, `epoll`, `uring` (readiness
    /// plane), or `uring+pbuf` (data plane).
    pub fn label(&self) -> &'static str {
        if self.dataplane {
            "uring+pbuf"
        } else {
            self.resolved.label()
        }
    }

    /// One-line summary for startup logs, including the degradation when
    /// the resolved policy differs from the requested one.
    pub fn summary(&self) -> String {
        if self.requested == self.resolved {
            format!("net={}", self.label())
        } else {
            format!("net={} (requested {})", self.label(), self.requested.label())
        }
    }
}

/// Wait until `fd` may have progress to offer: one scheduler yield under
/// [`NetPolicy::BusyPoll`], a park on fd readiness (readable when
/// `want_read`, writable when `want_write`) under [`NetPolicy::Epoll`].
/// Wake-ups may be spurious either way — callers re-check their socket and
/// loop. A connection that will no longer read (poisoned / half-closed)
/// must pass `want_read: false` so stale inbound bytes cannot wake-storm
/// it.
pub fn net_wait(policy: NetPolicy, fd: i32, want_read: bool, want_write: bool) {
    match policy {
        NetPolicy::BusyPoll => fiber::yield_now(),
        NetPolicy::Epoll => reactor::wait_fd(fd, want_read, want_write),
        NetPolicy::IoUring => uring::wait_fd(fd, want_read, want_write),
    }
}

/// Outcome of one read attempt.
pub enum ReadOutcome {
    /// `n` bytes appended to the buffer.
    Data(usize),
    /// Socket has nothing right now (caller should yield).
    WouldBlock,
    /// Peer closed or connection errored.
    Closed,
}

/// Read whatever is available into `buf` (append), one chunk.
pub fn read_available(stream: &mut TcpStream, buf: &mut Vec<u8>) -> ReadOutcome {
    let mut chunk = [0u8; 16 * 1024];
    let mut want = chunk.len();
    // Fault injection (`faults` feature only; inline no-op otherwise):
    // simulate EAGAIN / ECONNRESET / a short read before touching the
    // socket. Callers already handle each outcome.
    match faultsim::read_fault() {
        Some(faultsim::ReadFault::Eagain) => return ReadOutcome::WouldBlock,
        Some(faultsim::ReadFault::ConnReset) => return ReadOutcome::Closed,
        Some(faultsim::ReadFault::Short(n)) => want = n.max(1).min(chunk.len()),
        None => {}
    }
    READ_SYSCALLS.fetch_add(1, Ordering::Relaxed);
    match stream.read(&mut chunk[..want]) {
        Ok(0) => ReadOutcome::Closed,
        Ok(n) => {
            buf.extend_from_slice(&chunk[..n]);
            ReadOutcome::Data(n)
        }
        Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted => {
            ReadOutcome::WouldBlock
        }
        Err(_) => ReadOutcome::Closed,
    }
}

/// Drain the socket into `buf` until it would block, the peer closes, or
/// roughly `max_bytes` were read this burst (fairness bound: a firehose
/// peer must not monopolize the fiber's worker). EOF/error after some data
/// reports the data first; the sticky condition resurfaces on the next
/// call.
pub fn read_burst(stream: &mut TcpStream, buf: &mut Vec<u8>, max_bytes: usize) -> ReadOutcome {
    let mut total = 0usize;
    let mut chunk = [0u8; 16 * 1024];
    let mut max_bytes = max_bytes;
    // Fault injection (`faults` feature only; inline no-op otherwise):
    // EAGAIN ends the burst empty, ECONNRESET kills it, a short read
    // clamps the burst to a byte — the caller's loop must make progress
    // on the leftovers either way.
    match faultsim::read_fault() {
        Some(faultsim::ReadFault::Eagain) => return ReadOutcome::WouldBlock,
        Some(faultsim::ReadFault::ConnReset) => return ReadOutcome::Closed,
        Some(faultsim::ReadFault::Short(n)) => max_bytes = n.max(1),
        None => {}
    }
    loop {
        let want = chunk.len().min(max_bytes - total);
        READ_SYSCALLS.fetch_add(1, Ordering::Relaxed);
        match stream.read(&mut chunk[..want]) {
            Ok(0) => {
                return if total > 0 { ReadOutcome::Data(total) } else { ReadOutcome::Closed };
            }
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                total += n;
                if total >= max_bytes {
                    return ReadOutcome::Data(total);
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted => {
                return if total > 0 { ReadOutcome::Data(total) } else { ReadOutcome::WouldBlock };
            }
            Err(_) => {
                return if total > 0 { ReadOutcome::Data(total) } else { ReadOutcome::Closed };
            }
        }
    }
}

/// Write as much of `buf[*cursor..]` as the socket accepts; advances
/// `cursor`. Returns false if the connection died. When the whole buffer
/// drains, both buffer and cursor reset.
pub fn write_pending(stream: &mut TcpStream, buf: &mut Vec<u8>, cursor: &mut usize) -> bool {
    // Fault injection (`faults` feature only; inline no-op otherwise):
    // simulate EAGAIN (nothing leaves this pass), ECONNRESET (connection
    // dies), or a short write (at most one byte leaves). Probed only when
    // there is something to write so an idle egress path never counts as
    // an attempt.
    let mut cap = usize::MAX;
    if *cursor < buf.len() {
        match faultsim::write_fault() {
            Some(faultsim::WriteFault::Eagain) => cap = 0,
            Some(faultsim::WriteFault::ConnReset) => return false,
            Some(faultsim::WriteFault::Short) => cap = 1,
            None => {}
        }
    }
    while *cursor < buf.len() && cap > 0 {
        let end = buf.len().min(cursor.saturating_add(cap));
        WRITE_SYSCALLS.fetch_add(1, Ordering::Relaxed);
        match stream.write(&buf[*cursor..end]) {
            Ok(0) => return false,
            Ok(n) => {
                *cursor += n;
                cap -= n.min(cap);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted => {
                break;
            }
            Err(_) => return false,
        }
    }
    if *cursor == buf.len() && !buf.is_empty() {
        buf.clear();
        *cursor = 0;
    }
    true
}

/// Validate a server's worker topology before any runtime is built:
/// misconfigurations that used to die on internal asserts after worker
/// threads were already spawned report here as descriptive errors.
pub fn validate_topology(workers: usize, dedicated: usize) -> Result<(), String> {
    if workers == 0 {
        return Err("workers must be >= 1".into());
    }
    if dedicated >= workers {
        return Err(format!(
            "dedicated trustees ({dedicated}) must be fewer than workers ({workers}): \
             at least one non-dedicated socket worker is required to host connection fibers"
        ));
    }
    Ok(())
}

/// Build the accepted-stream dispatcher shared by the KV and memcached
/// servers: round-robin each new connection onto a socket worker and
/// inject a job that spawns its connection fiber there. `make_fiber`
/// turns the stream into the per-connection fiber body (where each server
/// closes over its backend/engine, counters, stop flag, and net policy).
pub fn round_robin_dispatch(
    shared: Arc<crate::runtime::Shared>,
    socket_workers: Vec<usize>,
    mut make_fiber: impl FnMut(TcpStream) -> Box<dyn FnOnce() + Send + 'static> + Send + 'static,
) -> impl FnMut(TcpStream) + Send + 'static {
    let mut next = 0usize;
    move |stream: TcpStream| {
        let worker = socket_workers[next % socket_workers.len()];
        next += 1;
        let fiber_body = make_fiber(stream);
        shared.inject(
            worker,
            Box::new(move || {
                fiber::with_executor(|e| {
                    e.spawn(fiber_body);
                });
            }),
        );
    }
}

/// Exponential accept backoff with jitter: 1 ms doubling to a 100 ms cap,
/// the actual delay jittered within ±25% so a fleet of acceptors (or an
/// acceptor racing a connection flood) does not retry in lockstep. Reset
/// on any successful accept.
pub(crate) struct AcceptBackoff {
    delay_ms: u64,
    jitter: u64,
}

impl AcceptBackoff {
    const MAX_DELAY_MS: u64 = 100;

    pub(crate) fn new() -> AcceptBackoff {
        AcceptBackoff { delay_ms: 0, jitter: 0x9E37_79B9_7F4A_7C15 }
    }

    pub(crate) fn reset(&mut self) {
        self.delay_ms = 0;
    }

    /// The next (jittered) delay in the exponential schedule.
    pub(crate) fn next_delay(&mut self) -> std::time::Duration {
        self.delay_ms = if self.delay_ms == 0 {
            1
        } else {
            (self.delay_ms * 2).min(Self::MAX_DELAY_MS)
        };
        // xorshift64: cheap jitter, no global RNG state.
        self.jitter ^= self.jitter << 13;
        self.jitter ^= self.jitter >> 7;
        self.jitter ^= self.jitter << 17;
        let base_us = self.delay_ms * 1000;
        let jitter_us = self.jitter % (base_us / 2 + 1);
        std::time::Duration::from_micros(base_us * 3 / 4 + jitter_us)
    }
}

/// Wait out one backoff delay from fiber context: yield-loop until the
/// deadline (a fiber must never block its worker thread), bailing early
/// on `stop`. Bounded by [`AcceptBackoff::MAX_DELAY_MS`].
fn backoff_yield(backoff: &mut AcceptBackoff, stop: &AtomicBool) {
    let deadline = std::time::Instant::now() + backoff.next_delay();
    while std::time::Instant::now() < deadline && !stop.load(Ordering::Acquire) {
        fiber::yield_now();
    }
}

/// Accept-loop *fiber* body (the [`NetPolicy::Epoll`] replacement for the
/// dedicated 200 µs sleep-poll accept thread): accepts until the listener
/// would block, hands each stream to `dispatch`, then parks on listener
/// readability. Exits only when `stop` is set — the runtime's shutdown
/// sweep wakes the park, so setting `stop` before `Runtime::shutdown()`
/// is enough to terminate it. Transient accept errors (ECONNABORTED, fd
/// exhaustion under a connection flood, EINTR) must NOT kill the
/// acceptor: the listener would be dead forever once the flood passed.
/// EMFILE-class errors take a bounded exponential backoff (counted in
/// `accept_throttled`) instead of a hot retry loop — under fd exhaustion
/// the pending backlog keeps the listener readable, so an immediate retry
/// would spin a worker at 100% while starving the process of the very
/// closes that would free descriptors.
pub fn accept_fiber(
    listener: TcpListener,
    policy: NetPolicy,
    stop: Arc<AtomicBool>,
    mut dispatch: impl FnMut(TcpStream),
    metrics: Arc<ConnMetrics>,
) {
    let fd = listener.as_raw_fd();
    let mut backoff = AcceptBackoff::new();
    loop {
        if stop.load(Ordering::Acquire) {
            break;
        }
        // Fault injection (`faults` feature only): simulated EMFILE —
        // must take the same throttled backoff as the real thing.
        if faultsim::accept_fault() {
            metrics.slot().accept_throttled.fetch_add(1, Ordering::Relaxed);
            backoff_yield(&mut backoff, &stop);
            continue;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                backoff.reset();
                dispatch(stream);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                backoff.reset();
                net_wait(policy, fd, true, false);
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => {
                metrics.slot().accept_throttled.fetch_add(1, Ordering::Relaxed);
                backoff_yield(&mut backoff, &stop);
            }
        }
    }
}

/// Accept-loop fiber body for [`NetPolicy::IoUring`]: one **multishot
/// ACCEPT** SQE serves every incoming connection — the kernel re-arms it
/// internally, so a wave of N connections costs zero accept syscalls here
/// (the completions ride the worker's ordinary CQ harvest). The fiber
/// drains queued accepted fds, dispatches them, and parks until the next
/// completion; the runtime's shutdown sweep (and `stop`) wake the park.
/// If the worker cannot create a ring, this degrades to the epoll
/// [`accept_fiber`] — which serves connections of any policy — so a
/// partially-capable host still accepts.
pub fn uring_accept_fiber(
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    mut dispatch: impl FnMut(TcpStream),
    metrics: Arc<ConnMetrics>,
) {
    let Some(token) = uring::accept_register(listener.as_raw_fd()) else {
        eprintln!("uring acceptor: ring unavailable on this worker; using epoll accept loop");
        return accept_fiber(listener, NetPolicy::Epoll, stop, dispatch, metrics);
    };
    let mut backoff = AcceptBackoff::new();
    loop {
        if stop.load(Ordering::Acquire) {
            break;
        }
        // Fault injection (`faults` feature only): simulated EMFILE on
        // the uring accept path — throttle instead of spinning on the
        // queued fds.
        if faultsim::accept_fault() {
            metrics.slot().accept_throttled.fetch_add(1, Ordering::Relaxed);
            backoff_yield(&mut backoff, &stop);
            continue;
        }
        match uring::accept_take(token) {
            Some(fd) => {
                backoff.reset();
                // SAFETY: the accept CQE handed this fiber sole ownership
                // of the connection fd; wrapping transfers it to the
                // TcpStream (the engine sets non-blocking itself).
                let stream = unsafe { <TcpStream as std::os::fd::FromRawFd>::from_raw_fd(fd) };
                dispatch(stream);
            }
            None => uring::accept_park(token),
        }
    }
    uring::accept_close(token);
}

/// Start the accept loop for `policy`: an fd-parked fiber on `worker`
/// under [`NetPolicy::Epoll`] (no thread), a multishot-accept fiber under
/// [`NetPolicy::IoUring`], or the legacy dedicated 200 µs sleep-poll
/// thread under [`NetPolicy::BusyPoll`] (returned for joining at stop).
/// Shared by the KV and memcached servers.
pub fn start_acceptor(
    policy: NetPolicy,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
    shared: &Arc<crate::runtime::Shared>,
    worker: usize,
    mut dispatch: impl FnMut(TcpStream) + Send + 'static,
    thread_name: &str,
    metrics: Arc<ConnMetrics>,
) -> Result<Option<std::thread::JoinHandle<()>>, String> {
    match policy {
        NetPolicy::Epoll => {
            shared.inject(
                worker,
                Box::new(move || {
                    fiber::with_executor(|e| {
                        e.spawn(move || accept_fiber(listener, policy, stop, dispatch, metrics));
                    });
                }),
            );
            Ok(None)
        }
        NetPolicy::IoUring => {
            shared.inject(
                worker,
                Box::new(move || {
                    fiber::with_executor(|e| {
                        e.spawn(move || uring_accept_fiber(listener, stop, dispatch, metrics));
                    });
                }),
            );
            Ok(None)
        }
        NetPolicy::BusyPoll => {
            let handle = std::thread::Builder::new()
                .name(thread_name.into())
                .spawn(move || {
                    let mut backoff = AcceptBackoff::new();
                    while !stop.load(Ordering::Acquire) {
                        // Fault injection (`faults` feature only):
                        // simulated EMFILE takes the throttled path.
                        if faultsim::accept_fault() {
                            metrics.slot().accept_throttled.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(backoff.next_delay());
                            continue;
                        }
                        match listener.accept() {
                            Ok((stream, _peer)) => {
                                backoff.reset();
                                dispatch(stream);
                            }
                            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                                backoff.reset();
                                std::thread::sleep(std::time::Duration::from_micros(200));
                            }
                            Err(e) if e.kind() == ErrorKind::Interrupted => {}
                            // Transient (fd exhaustion, aborted handshake):
                            // never kill the acceptor; bounded exponential
                            // backoff instead of a hot 1 ms retry.
                            Err(_) => {
                                metrics.slot().accept_throttled.fetch_add(1, Ordering::Relaxed);
                                std::thread::sleep(backoff.next_delay());
                            }
                        }
                    }
                })
                .map_err(|e| format!("spawn acceptor: {e}"))?;
            Ok(Some(handle))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn echo_over_nonblocking_pair() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            s.set_nonblocking(true).unwrap();
            let mut inbuf = Vec::new();
            let mut out = Vec::new();
            let mut cur = 0usize;
            loop {
                match read_available(&mut s, &mut inbuf) {
                    ReadOutcome::Data(_) => {
                        out.extend_from_slice(&inbuf);
                        inbuf.clear();
                    }
                    ReadOutcome::WouldBlock => std::thread::yield_now(),
                    ReadOutcome::Closed => break,
                }
                if !write_pending(&mut s, &mut out, &mut cur) {
                    break;
                }
            }
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"hello fiber net").unwrap();
        let mut back = [0u8; 15];
        c.read_exact(&mut back).unwrap();
        assert_eq!(&back, b"hello fiber net");
        drop(c);
        t.join().unwrap();
    }

    #[test]
    fn read_burst_drains_until_wouldblock() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut c = TcpStream::connect(addr).unwrap();
        let (mut s, _) = listener.accept().unwrap();
        s.set_nonblocking(true).unwrap();

        let payload = vec![0x5Au8; 100_000];
        c.write_all(&payload).unwrap();
        c.flush().unwrap();
        // Give loopback delivery a moment, then burst-read with a bound.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let mut buf = Vec::new();
        let mut got = 0usize;
        loop {
            match read_burst(&mut s, &mut buf, 32 * 1024) {
                ReadOutcome::Data(n) => {
                    assert!(n >= 1);
                    got += n;
                    if got >= payload.len() {
                        break;
                    }
                }
                ReadOutcome::WouldBlock => std::thread::sleep(std::time::Duration::from_millis(1)),
                ReadOutcome::Closed => panic!("peer still open"),
            }
        }
        assert_eq!(buf, payload);
        // Peer closes: burst now reports Closed.
        drop(c);
        loop {
            match read_burst(&mut s, &mut buf, 1024) {
                ReadOutcome::Closed => break,
                ReadOutcome::WouldBlock => std::thread::sleep(std::time::Duration::from_millis(1)),
                ReadOutcome::Data(_) => panic!("no more data expected"),
            }
        }
    }

    #[test]
    fn net_policy_specs_parse() {
        assert_eq!(NetPolicy::from_spec("busy"), Ok(NetPolicy::BusyPoll));
        assert_eq!(NetPolicy::from_spec("epoll"), Ok(NetPolicy::Epoll));
        assert_eq!(NetPolicy::from_spec("uring"), Ok(NetPolicy::IoUring));
        assert_eq!(NetPolicy::from_spec("io_uring"), Ok(NetPolicy::IoUring));
        assert_eq!(NetPolicy::default(), NetPolicy::Epoll);
        assert_eq!(NetPolicy::BusyPoll.label(), "busy-poll");
        assert_eq!(NetPolicy::IoUring.label(), "uring");
        let err = NetPolicy::from_spec("nope").unwrap_err();
        assert!(err.contains("nope") && err.contains("uring"), "descriptive: {err}");
    }

    #[test]
    fn settle_reports_the_plane() {
        let info = NetPolicy::Epoll.settle();
        assert_eq!(info.resolved, NetPolicy::Epoll);
        assert!(!info.dataplane, "epoll never engages the data plane");
        assert_eq!(info.label(), "epoll");
        assert_eq!(info.summary(), "net=epoll");

        let info = NetPolicy::IoUring.settle();
        match info.resolved {
            NetPolicy::IoUring => {
                assert!(info.fallback_reason.is_none());
                assert!(matches!(info.label(), "uring" | "uring+pbuf"));
                if info.dataplane {
                    assert_eq!(info.label(), "uring+pbuf");
                }
            }
            NetPolicy::Epoll => {
                assert!(info.fallback_reason.is_some(), "a degrade must carry its reason");
                assert!(!info.dataplane);
                assert!(info.summary().contains("requested uring"), "{}", info.summary());
            }
            NetPolicy::BusyPoll => unreachable!("uring never degrades to busy-poll"),
        }
    }

    #[test]
    fn resolve_never_panics() {
        // IoUring resolves to itself (capable kernel) or Epoll (with the
        // reason logged) — never a panic; other policies are identity.
        let r = NetPolicy::IoUring.resolve();
        assert!(matches!(r, NetPolicy::IoUring | NetPolicy::Epoll));
        assert_eq!(NetPolicy::Epoll.resolve(), NetPolicy::Epoll);
        assert_eq!(NetPolicy::BusyPoll.resolve(), NetPolicy::BusyPoll);
    }
}
