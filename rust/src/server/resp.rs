//! RESP2 (the Redis wire format) front end for the delegated server core.
//!
//! The paper's claim is that delegation scales *any* service whose
//! critical sections become delegated closures; this module is the third
//! protocol ported onto the shared engine (after the binary KV proto and
//! memcached text), mapping a Redis command subset onto the existing
//! [`AsyncKv`] backends so stock Redis clients can drive
//! `--backend trust|mutex|rwlock|swift`.
//!
//! Commands: `PING`, `GET`, `SET` (with `EX`/`PX` expiry options),
//! `DEL`, `EXISTS`, `MGET`, `INCR`, `EXPIRE`, `PEXPIRE`, `TTL`, `PTTL`,
//! `PERSIST`, `FLUSHALL` — accepted both as RESP arrays
//! (`*2\r\n$3\r\nGET\r\n…`) and as inline commands (`GET key\r\n`). The
//! expiry commands ride the unified item store's TTL machinery (lazy
//! expiry + incremental sweep), shared with the memcached front end.
//! RESP has no request ids, so the
//! engine runs the [`ResponseOrder::InOrder`] reorder spool: responses
//! hit the wire in request order even though shard completions arrive
//! out of order. Parsing is **total**: hostile bytes answer
//! `-ERR Protocol error: …` and close, never a worker panic.

use super::engine::{
    Completion, CoreConfig, Inbuf, Protocol, ResponseOrder, ServerCore, ServerTuning,
};
use super::netfiber::{self, NetPolicy};
use crate::kvstore::backend::{AckCb, AsyncKv, BackendKind, FlushCb, GetCb, IncrCb, TtlCb};
use crate::kvstore::store::{StoreConfig, StoreStats, TTL_MISSING, TTL_NO_EXPIRY};
use crate::runtime::Runtime;
use std::cell::RefCell;
use std::rc::Rc;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

/// Longest inline command line accepted (mirrors redis'
/// `PROTO_INLINE_MAX_SIZE` spirit at a smaller bound).
pub const MAX_INLINE: usize = 8192;
/// Largest single bulk string (keys and values) accepted.
pub const MAX_BULK: usize = 1 << 20;
/// Most arguments per command.
pub const MAX_MULTIBULK: usize = 1024;
/// Total on-wire size of one command (headers + every bulk). Must stay
/// **below** [`netfiber::MAX_INBUF`]: the engine stops reading once the
/// unparsed backlog reaches `MAX_INBUF`, so a command that legally needed
/// more bytes than that could never finish parsing and would wedge its
/// connection (the invariant `MAX_INBUF`'s own docs demand of every
/// protocol). Leaves a [`MAX_BULK`]-sized value plus framing inside the
/// bound; anything larger is rejected *before* waiting for its bytes.
pub const MAX_COMMAND: usize = netfiber::MAX_INBUF - (1 << 15);

/// Why a byte stream failed to parse as RESP. Answered on the wire as
/// `-ERR Protocol error: <message>` and the connection closes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RespParseError {
    Protocol(&'static str),
}

impl RespParseError {
    pub fn message(&self) -> &'static str {
        match self {
            RespParseError::Protocol(m) => m,
        }
    }
}

/// One client command; `args[0]` is the case-insensitive command name.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RespRequest {
    pub args: Vec<Vec<u8>>,
}

// ---------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------

/// Find the `\r\n` terminating the line starting at `from`: `Ok(Some)` =
/// offset of the `\r`, `Ok(None)` = wait for more bytes, `Err(())` = no
/// terminator within `limit` bytes (the stream is hostile).
fn line_end(buf: &[u8], from: usize, limit: usize) -> Result<Option<usize>, ()> {
    let window = &buf[from..buf.len().min(from + limit + 2)];
    match window.windows(2).position(|w| w == b"\r\n") {
        Some(p) => Ok(Some(from + p)),
        None if window.len() >= limit + 2 => Err(()),
        None => Ok(None),
    }
}

fn parse_i64(b: &[u8]) -> Option<i64> {
    if b.is_empty() {
        return None;
    }
    std::str::from_utf8(b).ok()?.parse().ok()
}

/// Incremental RESP2 request parser over a receive buffer:
/// `Ok(Some((args, bytes_consumed)))` for a complete command (`args` may
/// be empty for a whitespace-only inline line, which callers skip),
/// `Ok(None)` to wait for more bytes, `Err` for a stream that can never
/// become valid. Total on arbitrary input — never panics.
pub fn parse_request(buf: &[u8]) -> Result<Option<(Vec<Vec<u8>>, usize)>, RespParseError> {
    if buf.is_empty() {
        return Ok(None);
    }
    if buf[0] == b'*' {
        parse_multibulk(buf)
    } else {
        parse_inline(buf)
    }
}

fn parse_multibulk(buf: &[u8]) -> Result<Option<(Vec<Vec<u8>>, usize)>, RespParseError> {
    const E_MB: RespParseError = RespParseError::Protocol("invalid multibulk length");
    const E_BULK: RespParseError = RespParseError::Protocol("invalid bulk length");
    const E_SIZE: RespParseError = RespParseError::Protocol("multibulk command too large");
    let end = match line_end(buf, 1, 32) {
        Ok(Some(e)) => e,
        Ok(None) => return Ok(None),
        Err(()) => return Err(E_MB),
    };
    let n = match parse_i64(&buf[1..end]) {
        Some(n) => n,
        None => return Err(E_MB),
    };
    // A hostile count must be rejected before any buffering is committed;
    // *0 / *-1 carry no command to answer, so they are protocol errors
    // here rather than silent skips.
    if n < 1 || n as usize > MAX_MULTIBULK {
        return Err(E_MB);
    }
    let n = n as usize;
    // Size-only pre-scan: locate every bulk (range, not copy) first. A
    // partially-arrived command is re-scanned on the next read burst
    // without re-allocating or re-copying completed args, so large
    // commands ingest linearly rather than quadratically.
    let mut pos = end + 2;
    let mut ranges: Vec<(usize, usize)> = Vec::with_capacity(n);
    for _ in 0..n {
        if pos >= buf.len() {
            return Ok(None);
        }
        if buf[pos] != b'$' {
            return Err(RespParseError::Protocol("expected '$' bulk header"));
        }
        let hend = match line_end(buf, pos + 1, 32) {
            Ok(Some(e)) => e,
            Ok(None) => return Ok(None),
            Err(()) => return Err(E_BULK),
        };
        let len = match parse_i64(&buf[pos + 1..hend]) {
            Some(l) => l,
            None => return Err(E_BULK),
        };
        if len < 0 || len as usize > MAX_BULK {
            return Err(E_BULK);
        }
        let len = len as usize;
        let data_start = hend + 2;
        let next = data_start + len + 2;
        // Reject an over-MAX_COMMAND command *before* waiting for bytes
        // the engine's MAX_INBUF read gate would never let arrive.
        if next > MAX_COMMAND {
            return Err(E_SIZE);
        }
        if buf.len() < next {
            return Ok(None);
        }
        if &buf[data_start + len..next] != b"\r\n" {
            return Err(RespParseError::Protocol("bulk string not CRLF-terminated"));
        }
        ranges.push((data_start, len));
        pos = next;
    }
    // The whole command is present: materialize the args exactly once.
    let args = ranges
        .into_iter()
        .map(|(start, len)| buf[start..start + len].to_vec())
        .collect();
    Ok(Some((args, pos)))
}

fn parse_inline(buf: &[u8]) -> Result<Option<(Vec<Vec<u8>>, usize)>, RespParseError> {
    // Inline commands terminate on LF (redis accepts a bare LF here).
    let window = buf.len().min(MAX_INLINE + 2);
    let Some(nl) = buf[..window].iter().position(|&b| b == b'\n') else {
        // +1: a maximal legal line may momentarily sit in the buffer with
        // its '\r' but not yet its '\n' (TCP segmentation must not change
        // accept/reject).
        return if buf.len() > MAX_INLINE + 1 {
            Err(RespParseError::Protocol("too big inline request"))
        } else {
            Ok(None)
        };
    };
    let mut line = &buf[..nl];
    if line.last() == Some(&b'\r') {
        line = &line[..line.len() - 1];
    }
    let args: Vec<Vec<u8>> = line
        .split(|&b| b == b' ' || b == b'\t')
        .filter(|p| !p.is_empty())
        .map(|p| p.to_vec())
        .collect();
    Ok(Some((args, nl + 1)))
}

// ---------------------------------------------------------------------
// Reply serialisation
// ---------------------------------------------------------------------

pub fn write_simple(out: &mut Vec<u8>, s: &str) {
    out.push(b'+');
    out.extend_from_slice(s.as_bytes());
    out.extend_from_slice(b"\r\n");
}

pub fn write_error(out: &mut Vec<u8>, msg: &str) {
    out.push(b'-');
    out.extend_from_slice(msg.as_bytes());
    out.extend_from_slice(b"\r\n");
}

pub fn write_int(out: &mut Vec<u8>, n: i64) {
    out.push(b':');
    out.extend_from_slice(n.to_string().as_bytes());
    out.extend_from_slice(b"\r\n");
}

pub fn write_bulk(out: &mut Vec<u8>, v: &[u8]) {
    out.push(b'$');
    out.extend_from_slice(v.len().to_string().as_bytes());
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(v);
    out.extend_from_slice(b"\r\n");
}

/// The RESP2 null bulk string (a missing key).
pub fn write_null(out: &mut Vec<u8>) {
    out.extend_from_slice(b"$-1\r\n");
}

pub fn write_array_header(out: &mut Vec<u8>, n: usize) {
    out.push(b'*');
    out.extend_from_slice(n.to_string().as_bytes());
    out.extend_from_slice(b"\r\n");
}

// ---------------------------------------------------------------------
// Protocol impl
// ---------------------------------------------------------------------

/// RESP2 on the shared engine, over any [`AsyncKv`] backend.
pub struct RespProtocol {
    backend: Arc<dyn AsyncKv>,
}

impl RespProtocol {
    pub fn new(backend: Arc<dyn AsyncKv>) -> RespProtocol {
        RespProtocol { backend }
    }
}

impl Protocol for RespProtocol {
    type Request = RespRequest;
    type Error = RespParseError;

    /// RESP has no request ids: strict in-order responses.
    const ORDER: ResponseOrder = ResponseOrder::InOrder;

    fn parse(&mut self, inbuf: &mut Inbuf) -> Result<Option<RespRequest>, RespParseError> {
        loop {
            // Skip stray newlines between commands (redis tolerates them);
            // each iteration below consumes at least one byte, so this
            // loop terminates.
            let skip = inbuf
                .unparsed()
                .iter()
                .take_while(|&&b| b == b'\r' || b == b'\n')
                .count();
            if skip > 0 {
                inbuf.advance(skip);
            }
            match parse_request(inbuf.unparsed())? {
                Some((args, used)) => {
                    inbuf.advance(used);
                    if args.is_empty() {
                        continue; // whitespace-only inline line
                    }
                    return Ok(Some(RespRequest { args }));
                }
                None => return Ok(None),
            }
        }
    }

    fn render_error(&mut self, err: &RespParseError, out: &mut Vec<u8>) {
        write_error(out, &format!("ERR Protocol error: {}", err.message()));
    }

    /// Shed replies use the memcached-era `-BUSY` convention: a normal
    /// error reply on an open connection, so pipelined clients keep their
    /// request/response pairing and may retry.
    fn render_overload(&mut self, _req: &RespRequest, out: &mut Vec<u8>) -> bool {
        write_error(out, "BUSY server overloaded, try again later");
        true
    }

    /// Multi-key commands fan out into one backend operation per key and
    /// can render arbitrarily large replies, so they charge the inflight
    /// budget per key — `MGET k k k …` cannot amplify past the engine's
    /// egress bound the way a cost-1 accounting would allow.
    fn cost(&self, req: &RespRequest) -> u64 {
        let name = &req.args[0];
        if name.eq_ignore_ascii_case(b"MGET")
            || name.eq_ignore_ascii_case(b"DEL")
            || name.eq_ignore_ascii_case(b"EXISTS")
        {
            (req.args.len() as u64).saturating_sub(1).max(1)
        } else {
            1
        }
    }

    fn dispatch(&mut self, req: RespRequest, done: Completion) {
        dispatch_command(&self.backend, req.args, done);
    }
}

fn reply_now(done: Completion, render: impl FnOnce(&mut Vec<u8>)) {
    let mut b = done.checkout();
    render(&mut b);
    done.complete(b);
}

fn wrong_arity(done: Completion, cmd: &str) {
    let msg = format!("ERR wrong number of arguments for '{cmd}' command");
    reply_now(done, |b| write_error(b, &msg));
}

#[derive(Clone, Copy)]
enum CountOp {
    Del,
    Exists,
}

/// `DEL`/`EXISTS` over N keys: issue every backend op at once, count the
/// hits, answer one integer when the last completion lands (all
/// completions run on this connection's worker, so plain `Rc` state).
fn gather_count(backend: &Arc<dyn AsyncKv>, mut args: Vec<Vec<u8>>, done: Completion, op: CountOp) {
    let keys = args.split_off(1);
    let n = keys.len();
    let state = Rc::new(RefCell::new((0i64, n, Some(done))));
    for key in keys {
        let st = state.clone();
        let cb = AckCb::new(move |hit| {
            let mut s = st.borrow_mut();
            if hit {
                s.0 += 1;
            }
            s.1 -= 1;
            if s.1 == 0 {
                let done = s.2.take().unwrap();
                let count = s.0;
                drop(s);
                let mut b = done.checkout();
                write_int(&mut b, count);
                done.complete(b);
            }
        });
        match op {
            CountOp::Del => backend.del(&key, cb),
            CountOp::Exists => backend.exists(&key, cb),
        }
    }
}

/// `MGET`: one array reply holding every value (null for misses) in key
/// order, assembled as the per-key delegations complete in any order.
fn mget(backend: &Arc<dyn AsyncKv>, mut args: Vec<Vec<u8>>, done: Completion) {
    let keys = args.split_off(1);
    let n = keys.len();
    struct Gather {
        slots: Vec<Option<Option<Vec<u8>>>>,
        remaining: usize,
        done: Option<Completion>,
    }
    let g = Rc::new(RefCell::new(Gather { slots: vec![None; n], remaining: n, done: Some(done) }));
    for (i, key) in keys.into_iter().enumerate() {
        let g = g.clone();
        backend.get(
            &key,
            // MGET assembles values arriving in any order into one array
            // reply, so each value is copied into its slot here (the
            // multi-key gather is the one place that buffers values;
            // single-key GET stays one-copy).
            GetCb::new(move |v: Option<&[u8]>| {
                let mut st = g.borrow_mut();
                st.slots[i] = Some(v.map(|val| val.to_vec()));
                st.remaining -= 1;
                if st.remaining == 0 {
                    let done = st.done.take().unwrap();
                    let mut b = done.checkout();
                    write_array_header(&mut b, st.slots.len());
                    for s in &st.slots {
                        match s.as_ref().unwrap() {
                            Some(val) => write_bulk(&mut b, val),
                            None => write_null(&mut b),
                        }
                    }
                    drop(st);
                    done.complete(b);
                }
            }),
        );
    }
}

fn dispatch_command(backend: &Arc<dyn AsyncKv>, mut args: Vec<Vec<u8>>, done: Completion) {
    let name = args[0].to_ascii_uppercase();
    match name.as_slice() {
        b"PING" => match args.len() {
            1 => reply_now(done, |b| write_simple(b, "PONG")),
            2 => {
                let msg = args.pop().unwrap();
                reply_now(done, |b| write_bulk(b, &msg));
            }
            _ => wrong_arity(done, "ping"),
        },
        b"GET" => {
            if args.len() != 2 {
                return wrong_arity(done, "get");
            }
            let key = args.swap_remove(1);
            backend.get(
                &key,
                // One-copy GET: the borrowed value is written straight
                // into the pooled wire buffer.
                GetCb::new(move |v: Option<&[u8]>| {
                    let mut b = done.checkout();
                    match v {
                        Some(val) => write_bulk(&mut b, val),
                        None => write_null(&mut b),
                    }
                    done.complete(b);
                }),
            );
        }
        b"SET" => {
            // SET key value [EX seconds | PX milliseconds]; a plain SET
            // clears any existing deadline (Redis semantics).
            if args.len() != 3 && args.len() != 5 {
                return wrong_arity(done, "set");
            }
            let mut ttl_ms = 0u64;
            if args.len() == 5 {
                let amount = match parse_i64(&args[4]) {
                    Some(n) if n > 0 => n as u64,
                    _ => {
                        return reply_now(done, |b| {
                            write_error(b, "ERR invalid expire time in 'set' command")
                        })
                    }
                };
                let opt = args[3].to_ascii_uppercase();
                ttl_ms = match opt.as_slice() {
                    b"EX" => amount.saturating_mul(1000),
                    b"PX" => amount,
                    _ => return reply_now(done, |b| write_error(b, "ERR syntax error")),
                };
            }
            let val = std::mem::take(&mut args[2]);
            let key = std::mem::take(&mut args[1]);
            backend.set_item(
                &key,
                &val,
                0,
                ttl_ms,
                AckCb::new(move |_| {
                    let mut b = done.checkout();
                    write_simple(&mut b, "OK");
                    done.complete(b);
                }),
            );
        }
        b"DEL" => {
            if args.len() < 2 {
                return wrong_arity(done, "del");
            }
            gather_count(backend, args, done, CountOp::Del);
        }
        b"EXISTS" => {
            if args.len() < 2 {
                return wrong_arity(done, "exists");
            }
            gather_count(backend, args, done, CountOp::Exists);
        }
        b"MGET" => {
            if args.len() < 2 {
                return wrong_arity(done, "mget");
            }
            mget(backend, args, done);
        }
        b"INCR" => {
            if args.len() != 2 {
                return wrong_arity(done, "incr");
            }
            let key = args.swap_remove(1);
            backend.incr(
                &key,
                1,
                IncrCb::new(move |r| {
                    let mut b = done.checkout();
                    match r {
                        Ok(n) => write_int(&mut b, n),
                        Err(()) => {
                            write_error(&mut b, "ERR value is not an integer or out of range")
                        }
                    }
                    done.complete(b);
                }),
            );
        }
        b"EXPIRE" | b"PEXPIRE" => {
            // EXPIRE key seconds / PEXPIRE key ms → :1 (deadline set) or
            // :0 (no such live key). Rides AsyncKv::touch.
            if args.len() != 3 {
                return wrong_arity(done, if name == b"EXPIRE" { "expire" } else { "pexpire" });
            }
            let amount = match parse_i64(&args[2]) {
                Some(n) if n > 0 => n as u64,
                // Redis deletes on a non-positive expire; we keep the
                // subset simple and reject it like a bad argument.
                _ => {
                    return reply_now(done, |b| {
                        write_error(b, "ERR invalid expire time in 'expire' command")
                    })
                }
            };
            let ttl_ms = if name == b"EXPIRE" {
                amount.saturating_mul(1000)
            } else {
                amount
            };
            let key = args.swap_remove(1);
            backend.touch(
                &key,
                ttl_ms,
                AckCb::new(move |live| {
                    let mut b = done.checkout();
                    write_int(&mut b, i64::from(live));
                    done.complete(b);
                }),
            );
        }
        b"TTL" | b"PTTL" => {
            if args.len() != 2 {
                return wrong_arity(done, if name == b"TTL" { "ttl" } else { "pttl" });
            }
            let seconds = name == b"TTL";
            let key = args.swap_remove(1);
            backend.ttl(
                &key,
                TtlCb::new(move |ms| {
                    let mut b = done.checkout();
                    let v = match ms {
                        TTL_MISSING | TTL_NO_EXPIRY => ms,
                        // Remaining time; TTL rounds up like Redis (a key
                        // with 1 ms left still reports 1 s).
                        ms if seconds => ms.div_ceil(1000),
                        ms => ms,
                    };
                    write_int(&mut b, v);
                    done.complete(b);
                }),
            );
        }
        b"PERSIST" => {
            if args.len() != 2 {
                return wrong_arity(done, "persist");
            }
            let key = args.swap_remove(1);
            backend.persist(
                &key,
                AckCb::new(move |cleared| {
                    let mut b = done.checkout();
                    write_int(&mut b, i64::from(cleared));
                    done.complete(b);
                }),
            );
        }
        b"FLUSHALL" => {
            if args.len() != 1 {
                return wrong_arity(done, "flushall");
            }
            backend.flush_all(FlushCb::new(move || {
                let mut b = done.checkout();
                write_simple(&mut b, "OK");
                done.complete(b);
            }));
        }
        _ => {
            let msg = format!(
                "ERR unknown command '{}'",
                String::from_utf8_lossy(&args[0]).escape_default()
            );
            reply_now(done, |b| write_error(b, &msg));
        }
    }
}

// ---------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------

/// RESP server configuration (same shape as the KV/memcached configs).
#[derive(Clone, Debug)]
pub struct RespServerConfig {
    pub workers: usize,
    /// Dedicated trustee workers (shards live there; no socket fibers).
    pub dedicated: usize,
    pub backend: BackendKind,
    /// Total store byte budget (split per shard; 0 = unlimited). Going
    /// over evicts per-shard LRU victims.
    pub budget_bytes: u64,
    pub addr: String,
    /// How connection fibers wait for socket progress.
    pub net: NetPolicy,
    /// Overload-control and degradation knobs (shed watermarks, request
    /// deadline, stalled-connection reaping, stop-drain grace).
    pub tuning: ServerTuning,
}

impl Default for RespServerConfig {
    fn default() -> Self {
        RespServerConfig {
            workers: 4,
            dedicated: 0,
            backend: BackendKind::Trust { shards: 0 },
            budget_bytes: 0,
            addr: "127.0.0.1:0".into(),
            net: NetPolicy::default(),
            tuning: ServerTuning::default(),
        }
    }
}

impl RespServerConfig {
    /// Topology + budget sanity checks, before any runtime is built.
    pub fn validate(&self) -> Result<(), String> {
        netfiber::validate_topology(self.workers, self.dedicated)?;
        self.backend.validate_budget(self.budget_bytes)?;
        self.tuning.validate()
    }
}

/// A running RESP (Redis-protocol) server over a delegated or lock-based
/// [`AsyncKv`] backend.
pub struct RespServer {
    core: ServerCore,
    backend: Arc<dyn AsyncKv>,
    pub ops_served: Arc<AtomicU64>,
}

impl RespServer {
    /// Start a server, panicking on an invalid configuration (see
    /// [`RespServer::try_start`] for the fallible form).
    pub fn start(cfg: RespServerConfig) -> RespServer {
        Self::try_start(cfg).unwrap_or_else(|e| panic!("invalid RespServerConfig: {e}"))
    }

    /// Start a server, reporting configuration/bind problems as a
    /// descriptive error *before* any worker thread is spawned.
    pub fn try_start(cfg: RespServerConfig) -> Result<RespServer, String> {
        cfg.backend.validate_budget(cfg.budget_bytes)?;
        let mut backend_out: Option<Arc<dyn AsyncKv>> = None;
        let store_cfg = StoreConfig::with_budget(cfg.budget_bytes);
        let core = ServerCore::try_start(
            CoreConfig {
                workers: cfg.workers,
                dedicated: cfg.dedicated,
                addr: cfg.addr.clone(),
                net: cfg.net,
                tuning: cfg.tuning,
            },
            "resp-accept",
            |rt, trustees| {
                let backend = cfg.backend.build_with(rt, trustees, &store_cfg);
                backend_out = Some(backend.clone());
                move || RespProtocol::new(backend.clone())
            },
        )?;
        let ops_served = core.ops_served().clone();
        Ok(RespServer { core, backend: backend_out.unwrap(), ops_served })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.core.addr()
    }

    pub fn backend(&self) -> &Arc<dyn AsyncKv> {
        &self.backend
    }

    pub fn runtime(&self) -> &Runtime {
        self.core.runtime()
    }

    pub fn metrics(&self) -> &Arc<super::engine::ConnMetrics> {
        self.core.metrics()
    }

    /// Delegation-layer hot-path allocation/copy counters (diagnostic).
    pub fn hot_path_stats(&self) -> crate::runtime::HotPathStats {
        self.core.hot_path_stats()
    }

    /// io_uring submission/completion counters across all workers
    /// (zeros unless running under `NetPolicy::IoUring`; diagnostic).
    pub fn uring_stats(&self) -> crate::runtime::uring::UringStats {
        self.core.uring_stats()
    }

    /// The settled network plane (requested vs resolved policy, data-
    /// plane capability, fallback reason).
    pub fn net_info(&self) -> &crate::server::netfiber::NetInfo {
        self.core.net_info()
    }

    /// Item-store counters (items, bytes, evictions, expirations, plus
    /// the value-slab pool hit/miss and fragmentation gauges).
    pub fn store_stats(&self) -> StoreStats {
        self.backend.store_stats()
    }

    /// Pre-fill the store with `n` keys in the load generator's format.
    pub fn prefill(&self, n: u64, val_len: usize) {
        let backend = self.backend.clone();
        self.core.prefill(n, move |i, on_done| {
            backend.put(
                &super::resp_load::key_bytes(i),
                &vec![b'r'; val_len],
                AckCb::new(move |_| on_done()),
            );
        });
    }

    pub fn stop(mut self) {
        self.core.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_one(bytes: &[u8]) -> Option<(Vec<Vec<u8>>, usize)> {
        parse_request(bytes).unwrap()
    }

    #[test]
    fn multibulk_roundtrip() {
        let (args, used) = parse_one(b"*2\r\n$3\r\nGET\r\n$5\r\nhello\r\n").unwrap();
        assert_eq!(args, vec![b"GET".to_vec(), b"hello".to_vec()]);
        assert_eq!(used, 24);
        // Empty bulk strings are legal.
        let (args, _) = parse_one(b"*2\r\n$3\r\nSET\r\n$0\r\n\r\n").unwrap();
        assert_eq!(args[1], b"");
    }

    #[test]
    fn inline_commands_parse() {
        let (args, used) = parse_one(b"PING\r\n").unwrap();
        assert_eq!(args, vec![b"PING".to_vec()]);
        assert_eq!(used, 6);
        // Bare LF and extra whitespace are tolerated.
        let (args, used) = parse_one(b"SET  key   value\n").unwrap();
        assert_eq!(args, vec![b"SET".to_vec(), b"key".to_vec(), b"value".to_vec()]);
        assert_eq!(used, 17);
        // Whitespace-only line: consumed, no args (caller skips).
        let (args, used) = parse_one(b"   \r\n").unwrap();
        assert!(args.is_empty());
        assert_eq!(used, 5);
    }

    #[test]
    fn partial_frames_wait() {
        let full = b"*2\r\n$3\r\nGET\r\n$5\r\nhello\r\n";
        for cut in 0..full.len() {
            assert!(
                parse_request(&full[..cut]).unwrap().is_none(),
                "cut={cut} must wait for more bytes"
            );
        }
        assert!(parse_request(b"GET key").unwrap().is_none(), "no LF yet");
        // Boundary: a maximal inline line whose CRLF is split across reads
        // must wait, then parse — TCP segmentation must not flip the
        // verdict on identical bytes.
        let mut line = vec![b'p'; MAX_INLINE];
        assert!(parse_request(&line).unwrap().is_none());
        line.push(b'\r');
        assert!(parse_request(&line).unwrap().is_none(), "awaiting the LF");
        line.push(b'\n');
        let (args, used) = parse_request(&line).unwrap().unwrap();
        assert_eq!((args.len(), args[0].len(), used), (1, MAX_INLINE, MAX_INLINE + 2));
    }

    #[test]
    fn hostile_streams_error_instead_of_panicking_or_wedging() {
        // Bad multibulk counts.
        assert!(parse_request(b"*0\r\n").is_err());
        assert!(parse_request(b"*-1\r\n").is_err());
        assert!(parse_request(b"*abc\r\n").is_err());
        assert!(parse_request(format!("*{}\r\n", MAX_MULTIBULK + 1).as_bytes()).is_err());
        // Bad bulk headers.
        assert!(parse_request(b"*1\r\n:3\r\nfoo\r\n").is_err());
        assert!(parse_request(b"*1\r\n$-2\r\n\r\n").is_err());
        assert!(parse_request(format!("*1\r\n${}\r\n", MAX_BULK + 1).as_bytes()).is_err());
        // Data block not CRLF-terminated where declared.
        assert!(parse_request(b"*1\r\n$3\r\nfooXY").is_err());
        // Endless inline line.
        let long = vec![b'a'; MAX_INLINE + 16];
        assert!(parse_request(&long).is_err());
        // A command whose *total* size exceeds MAX_COMMAND is rejected at
        // header time — waiting for its bytes would wedge the connection,
        // because the engine stops reading at MAX_INBUF backlog.
        let mut big = Vec::new();
        big.extend_from_slice(b"*3\r\n$3\r\nSET\r\n$600000\r\n");
        big.extend_from_slice(&vec![b'k'; 600_000]);
        big.extend_from_slice(b"\r\n$600000\r\n");
        assert_eq!(
            parse_request(&big),
            Err(RespParseError::Protocol("multibulk command too large"))
        );
        // ...while a single maximal bulk still fits within the gate.
        let mut maximal = Vec::new();
        maximal.extend_from_slice(format!("*1\r\n${MAX_BULK}\r\n").as_bytes());
        maximal.extend_from_slice(&vec![b'v'; MAX_BULK]);
        maximal.extend_from_slice(b"\r\n");
        let (args, used) = parse_request(&maximal).unwrap().unwrap();
        assert_eq!((args.len(), args[0].len(), used), (1, MAX_BULK, maximal.len()));
        // A multibulk count line that never terminates.
        let mut evil = b"*1".to_vec();
        evil.extend_from_slice(&[b'9'; 64]);
        assert!(parse_request(&evil).is_err());
        // Arbitrary bytes never panic.
        crate::util::quickcheck::check::<Vec<u8>>("resp-parse-garbage", 300, |bytes| {
            let _ = parse_request(bytes);
            true
        });
    }

    #[test]
    fn bitflipped_valid_streams_never_panic() {
        crate::util::quickcheck::check::<(Vec<u8>, Vec<u8>, usize, usize)>(
            "resp-parse-bitflip",
            300,
            |(key, val, flip_at, cut)| {
                if key.len() > 4096 || val.len() > 4096 {
                    return true;
                }
                let mut buf = Vec::new();
                write_array_header(&mut buf, 3);
                write_bulk(&mut buf, b"SET");
                write_bulk(&mut buf, key);
                write_bulk(&mut buf, val);
                let i = flip_at % buf.len();
                buf[i] ^= ((flip_at >> 8) as u8) | 1;
                buf.truncate(cut % (buf.len() + 1));
                // Parse to exhaustion: every outcome is fine except panic.
                let mut off = 0usize;
                loop {
                    match parse_request(&buf[off..]) {
                        Ok(Some((_, used))) => {
                            off += used.max(1);
                            if off >= buf.len() {
                                break;
                            }
                        }
                        Ok(None) | Err(_) => break,
                    }
                }
                true
            },
        );
    }

    #[test]
    fn reply_writers_encode_resp2() {
        let mut b = Vec::new();
        write_simple(&mut b, "OK");
        write_error(&mut b, "ERR nope");
        write_int(&mut b, -7);
        write_bulk(&mut b, b"hi");
        write_null(&mut b);
        write_array_header(&mut b, 2);
        assert_eq!(&b[..], &b"+OK\r\n-ERR nope\r\n:-7\r\n$2\r\nhi\r\n$-1\r\n*2\r\n"[..]);
    }
}
