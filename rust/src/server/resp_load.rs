//! Load-generating RESP client for the E16 bench and the `resp-load`
//! subcommand: multi-threaded, per-thread connections, configurable
//! pipelining, key distribution and write percentage — the same knobs as
//! the KV and memtier loaders, speaking the Redis wire format.
//!
//! The connection loop is the shared [`crate::loadgen`] skeleton; this
//! module contributes only the RESP [`LoadDriver`] (in-order replies,
//! null bulk = miss). I/O failures are surfaced in
//! [`RespLoadStats::errors`] (a server dropping a connection mid-run
//! fails the run descriptively) instead of panicking the client thread.

use super::resp::{write_array_header, write_bulk};
use crate::loadgen::{run_pipelined_loader_opts, LoadDriver, Reply};
use crate::util::{KeyDist, Rng};
use std::collections::VecDeque;
use std::time::Instant;

/// Key encoding shared by prefill and load (`key:<n>`).
pub fn key_bytes(k: u64) -> Vec<u8> {
    format!("key:{k}").into_bytes()
}

#[derive(Clone, Debug)]
pub struct RespLoadConfig {
    pub addr: std::net::SocketAddr,
    /// Concurrent client threads (each with its own connection).
    pub threads: usize,
    /// Outstanding requests per connection.
    pub pipeline: usize,
    /// Total operations per thread.
    pub ops_per_thread: u64,
    /// Key space size and distribution spec ("uniform" | "zipf[:a]").
    pub keys: u64,
    pub dist: String,
    /// Percentage of SETs (rest are GETs).
    pub write_pct: u32,
    /// Percentage of SETs that carry `EX 1` (the TTL-mix knob driving
    /// the store's expiry machinery; GETs of expired keys then count as
    /// misses).
    pub ttl_pct: u32,
    pub val_len: usize,
    pub seed: u64,
    /// Re-issue requests the server shed with `-BUSY` (bounded; off =
    /// count them as valueless completions).
    pub retry_shed: bool,
}

/// Aggregated results. `errors` holds one descriptive entry per client
/// thread that failed; completed operations from failed threads still
/// count toward `ops`.
pub struct RespLoadStats {
    pub ops: u64,
    pub elapsed: std::time::Duration,
    pub hits: u64,
    pub misses: u64,
    /// Requests the server answered with `-BUSY`.
    pub shed: u64,
    pub errors: Vec<String>,
}

impl RespLoadStats {
    pub fn throughput(&self) -> f64 {
        self.ops as f64 / self.elapsed.as_secs_f64()
    }

    pub fn ok(&self) -> bool {
        self.errors.is_empty()
    }
}

/// Run the workload; returns aggregate stats (never panics on I/O).
pub fn run_resp_load(cfg: &RespLoadConfig) -> RespLoadStats {
    let start = Instant::now();
    let handles: Vec<_> = (0..cfg.threads)
        .map(|t| {
            let cfg = cfg.clone();
            std::thread::spawn(move || run_connection(&cfg, t as u64))
        })
        .collect();
    let mut ops = 0;
    let mut hits = 0;
    let mut misses = 0;
    let mut shed = 0;
    let mut errors = Vec::new();
    for (t, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok((o, hi, mi, sh, err)) => {
                ops += o;
                hits += hi;
                misses += mi;
                shed += sh;
                if let Some(e) = err {
                    errors.push(format!("client thread {t}: {e}"));
                }
            }
            Err(_) => errors.push(format!("client thread {t} panicked")),
        }
    }
    RespLoadStats { ops, elapsed: start.elapsed(), hits, misses, shed, errors }
}

/// One parsed wire reply (see [`parse_reply`]).
#[derive(Debug, PartialEq, Eq)]
enum Parsed {
    /// Ordinary reply; `hit` is false only for a null bulk (missing key).
    Done { used: usize, hit: bool },
    /// The server shed the request with `-BUSY …` (not a desync: the
    /// connection is still good and the request may be retried).
    Shed { used: usize },
}

/// Parse one complete RESP reply: `Ok(Some(parsed))`, `Ok(None)` = wait
/// for more bytes, `Err` = the server answered a (non-BUSY) error or the
/// stream is broken.
fn parse_reply(buf: &[u8]) -> Result<Option<Parsed>, String> {
    if buf.is_empty() {
        return Ok(None);
    }
    let Some(le) = buf.windows(2).position(|w| w == b"\r\n") else {
        if buf.len() > 64 * 1024 {
            return Err("reply line longer than 64 KiB".into());
        }
        return Ok(None);
    };
    match buf[0] {
        b'+' | b':' => Ok(Some(Parsed::Done { used: le + 2, hit: true })),
        b'-' => {
            if buf[1..le].starts_with(b"BUSY") {
                return Ok(Some(Parsed::Shed { used: le + 2 }));
            }
            Err(format!(
                "server error reply: {}",
                String::from_utf8_lossy(&buf[1..le])
            ))
        }
        b'$' => {
            let n: i64 = std::str::from_utf8(&buf[1..le])
                .ok()
                .and_then(|s| s.parse().ok())
                .ok_or("malformed bulk length in reply")?;
            if n < 0 {
                return Ok(Some(Parsed::Done { used: le + 2, hit: false }));
            }
            // A length past the server's own bulk cap means the stream is
            // desynced: fail descriptively instead of waiting forever for
            // bytes that will never come.
            if n as usize > super::resp::MAX_BULK {
                return Err(format!("bulk length {n} in reply exceeds MAX_BULK (desync?)"));
            }
            let need = le + 2 + n as usize + 2;
            if buf.len() < need {
                return Ok(None);
            }
            Ok(Some(Parsed::Done { used: need, hit: true }))
        }
        other => Err(format!("unexpected reply type byte {other:#04x}")),
    }
}

fn encode_get(out: &mut Vec<u8>, key: &[u8]) {
    write_array_header(out, 2);
    write_bulk(out, b"GET");
    write_bulk(out, key);
}

fn encode_set(out: &mut Vec<u8>, key: &[u8], val: &[u8], ttl_secs: u64) {
    if ttl_secs == 0 {
        write_array_header(out, 3);
        write_bulk(out, b"SET");
        write_bulk(out, key);
        write_bulk(out, val);
    } else {
        write_array_header(out, 5);
        write_bulk(out, b"SET");
        write_bulk(out, key);
        write_bulk(out, val);
        write_bulk(out, b"EX");
        write_bulk(out, ttl_secs.to_string().as_bytes());
    }
}

/// Whether a pipelined slot was a GET (miss accounting applies).
enum Expect {
    Set,
    Get,
}

/// The RESP wire format plugged into the shared loader skeleton: replies
/// arrive strictly in request order; only a GET answered with a null
/// bulk counts as a miss.
struct RespDriver {
    rng: Rng,
    dist: KeyDist,
    write_pct: u32,
    ttl_pct: u32,
    val: Vec<u8>,
    expect: VecDeque<Expect>,
}

impl LoadDriver for RespDriver {
    fn encode_next(&mut self, out: &mut Vec<u8>) {
        let key = key_bytes(self.dist.sample(&mut self.rng));
        if self.rng.pct(self.write_pct) {
            let ttl = if self.ttl_pct > 0 && self.rng.pct(self.ttl_pct) {
                crate::memcache::memtier::LOAD_TTL_SECS
            } else {
                0
            };
            encode_set(out, &key, &self.val, ttl);
            self.expect.push_back(Expect::Set);
        } else {
            encode_get(out, &key);
            self.expect.push_back(Expect::Get);
        }
    }

    fn parse_reply(&mut self, buf: &[u8]) -> Result<Option<Reply>, String> {
        if self.expect.is_empty() {
            return Ok(None);
        }
        match parse_reply(buf)? {
            Some(Parsed::Done { used, hit }) => {
                let was_get = matches!(self.expect.pop_front(), Some(Expect::Get));
                Ok(Some(Reply::ok(used, hit || !was_get)))
            }
            Some(Parsed::Shed { used }) => {
                self.expect.pop_front();
                Ok(Some(Reply::shed(used)))
            }
            None => Ok(None),
        }
    }
}

fn run_connection(cfg: &RespLoadConfig, tid: u64) -> (u64, u64, u64, u64, Option<String>) {
    let mut driver = RespDriver {
        rng: Rng::new(cfg.seed ^ (tid.wrapping_mul(0xC2B2_AE35))),
        dist: KeyDist::from_spec(&cfg.dist, cfg.keys),
        write_pct: cfg.write_pct,
        ttl_pct: cfg.ttl_pct,
        val: vec![b'r'; cfg.val_len],
        expect: VecDeque::with_capacity(cfg.pipeline),
    };
    let r = run_pipelined_loader_opts(
        cfg.addr,
        cfg.pipeline,
        cfg.ops_per_thread,
        &mut driver,
        cfg.retry_shed,
    );
    (r.done, r.hits, r.misses, r.shed, r.error)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reply_parser_handles_each_type_and_partials() {
        assert_eq!(
            parse_reply(b"+OK\r\n").unwrap(),
            Some(Parsed::Done { used: 5, hit: true })
        );
        assert_eq!(
            parse_reply(b":42\r\n").unwrap(),
            Some(Parsed::Done { used: 5, hit: true })
        );
        assert_eq!(
            parse_reply(b"$-1\r\n").unwrap(),
            Some(Parsed::Done { used: 5, hit: false })
        );
        assert_eq!(
            parse_reply(b"$5\r\nhello\r\nrest").unwrap(),
            Some(Parsed::Done { used: 11, hit: true })
        );
        let full = b"$5\r\nhello\r\n";
        for cut in 0..full.len() {
            assert_eq!(parse_reply(&full[..cut]).unwrap(), None, "cut={cut}");
        }
        assert!(parse_reply(b"-ERR nope\r\n").is_err());
        assert!(parse_reply(b"?junk\r\n").is_err());
        // A -BUSY error is a shed marker, not a desync.
        assert_eq!(
            parse_reply(b"-BUSY server overloaded, try again later\r\n").unwrap(),
            Some(Parsed::Shed { used: 42 })
        );
        // Desync guard: absurd declared lengths error instead of hanging.
        assert!(parse_reply(b"$99999999\r\n").is_err());
        assert!(parse_reply(b"$999999999999999999999\r\n").is_err());
    }
}
