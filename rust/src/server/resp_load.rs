//! Load-generating RESP client for the E16 bench and the `resp-load`
//! subcommand: multi-threaded, per-thread connections, configurable
//! pipelining, key distribution and write percentage — the same knobs as
//! the KV and memtier loaders, speaking the Redis wire format.
//!
//! I/O failures are surfaced in [`RespLoadStats::errors`] (a server
//! dropping a connection mid-run fails the run descriptively) instead of
//! panicking the client thread.

use super::resp::{write_array_header, write_bulk};
use crate::util::{KeyDist, Rng};
use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::Instant;

/// Key encoding shared by prefill and load (`key:<n>`).
pub fn key_bytes(k: u64) -> Vec<u8> {
    format!("key:{k}").into_bytes()
}

#[derive(Clone, Debug)]
pub struct RespLoadConfig {
    pub addr: std::net::SocketAddr,
    /// Concurrent client threads (each with its own connection).
    pub threads: usize,
    /// Outstanding requests per connection.
    pub pipeline: usize,
    /// Total operations per thread.
    pub ops_per_thread: u64,
    /// Key space size and distribution spec ("uniform" | "zipf[:a]").
    pub keys: u64,
    pub dist: String,
    /// Percentage of SETs (rest are GETs).
    pub write_pct: u32,
    pub val_len: usize,
    pub seed: u64,
}

/// Aggregated results. `errors` holds one descriptive entry per client
/// thread that failed; completed operations from failed threads still
/// count toward `ops`.
pub struct RespLoadStats {
    pub ops: u64,
    pub elapsed: std::time::Duration,
    pub hits: u64,
    pub misses: u64,
    pub errors: Vec<String>,
}

impl RespLoadStats {
    pub fn throughput(&self) -> f64 {
        self.ops as f64 / self.elapsed.as_secs_f64()
    }

    pub fn ok(&self) -> bool {
        self.errors.is_empty()
    }
}

/// Run the workload; returns aggregate stats (never panics on I/O).
pub fn run_resp_load(cfg: &RespLoadConfig) -> RespLoadStats {
    let start = Instant::now();
    let handles: Vec<_> = (0..cfg.threads)
        .map(|t| {
            let cfg = cfg.clone();
            std::thread::spawn(move || run_connection(&cfg, t as u64))
        })
        .collect();
    let mut ops = 0;
    let mut hits = 0;
    let mut misses = 0;
    let mut errors = Vec::new();
    for (t, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok((o, hi, mi, err)) => {
                ops += o;
                hits += hi;
                misses += mi;
                if let Some(e) = err {
                    errors.push(format!("client thread {t}: {e}"));
                }
            }
            Err(_) => errors.push(format!("client thread {t} panicked")),
        }
    }
    RespLoadStats { ops, elapsed: start.elapsed(), hits, misses, errors }
}

/// Parse one complete RESP reply: `Ok(Some((bytes_used, is_hit)))` where
/// `is_hit` is false only for a null bulk (missing key), `Ok(None)` =
/// wait for more bytes, `Err` = the server answered an error or the
/// stream is broken.
fn parse_reply(buf: &[u8]) -> Result<Option<(usize, bool)>, String> {
    if buf.is_empty() {
        return Ok(None);
    }
    let Some(le) = buf.windows(2).position(|w| w == b"\r\n") else {
        if buf.len() > 64 * 1024 {
            return Err("reply line longer than 64 KiB".into());
        }
        return Ok(None);
    };
    match buf[0] {
        b'+' | b':' => Ok(Some((le + 2, true))),
        b'-' => Err(format!(
            "server error reply: {}",
            String::from_utf8_lossy(&buf[1..le])
        )),
        b'$' => {
            let n: i64 = std::str::from_utf8(&buf[1..le])
                .ok()
                .and_then(|s| s.parse().ok())
                .ok_or("malformed bulk length in reply")?;
            if n < 0 {
                return Ok(Some((le + 2, false)));
            }
            // A length past the server's own bulk cap means the stream is
            // desynced: fail descriptively instead of waiting forever for
            // bytes that will never come.
            if n as usize > super::resp::MAX_BULK {
                return Err(format!("bulk length {n} in reply exceeds MAX_BULK (desync?)"));
            }
            let need = le + 2 + n as usize + 2;
            if buf.len() < need {
                return Ok(None);
            }
            Ok(Some((need, true)))
        }
        other => Err(format!("unexpected reply type byte {other:#04x}")),
    }
}

fn encode_get(out: &mut Vec<u8>, key: &[u8]) {
    write_array_header(out, 2);
    write_bulk(out, b"GET");
    write_bulk(out, key);
}

fn encode_set(out: &mut Vec<u8>, key: &[u8], val: &[u8]) {
    write_array_header(out, 3);
    write_bulk(out, b"SET");
    write_bulk(out, key);
    write_bulk(out, val);
}

/// Whether a pipelined slot was a GET (miss accounting applies).
enum Expect {
    Set,
    Get,
}

fn run_connection(cfg: &RespLoadConfig, tid: u64) -> (u64, u64, u64, Option<String>) {
    let mut rng = Rng::new(cfg.seed ^ (tid.wrapping_mul(0xC2B2_AE35)));
    let dist = KeyDist::from_spec(&cfg.dist, cfg.keys);
    let mut stream = match TcpStream::connect(cfg.addr) {
        Ok(s) => s,
        Err(e) => return (0, 0, 0, Some(format!("connect {}: {e}", cfg.addr))),
    };
    stream.set_nodelay(true).ok();
    if let Err(e) = stream.set_nonblocking(true) {
        return (0, 0, 0, Some(format!("nonblocking: {e}")));
    }

    let val = vec![b'r'; cfg.val_len];
    let mut expect: VecDeque<Expect> = VecDeque::with_capacity(cfg.pipeline);
    let mut out: Vec<u8> = Vec::with_capacity(64 * 1024);
    let mut wcur = 0usize;
    let mut inbuf: Vec<u8> = Vec::with_capacity(64 * 1024);
    let mut parsed = 0usize;
    let (mut sent, mut done, mut hits, mut misses) = (0u64, 0u64, 0u64, 0u64);

    macro_rules! fail {
        ($($arg:tt)*) => {
            return (
                done,
                hits,
                misses,
                Some(format!(
                    "after {done}/{} ops: {}",
                    cfg.ops_per_thread,
                    format!($($arg)*)
                )),
            )
        };
    }

    while done < cfg.ops_per_thread {
        while sent < cfg.ops_per_thread && expect.len() < cfg.pipeline {
            let key = key_bytes(dist.sample(&mut rng));
            if rng.pct(cfg.write_pct) {
                encode_set(&mut out, &key, &val);
                expect.push_back(Expect::Set);
            } else {
                encode_get(&mut out, &key);
                expect.push_back(Expect::Get);
            }
            sent += 1;
        }
        // Flush writes (partial ok).
        loop {
            if wcur >= out.len() {
                out.clear();
                wcur = 0;
                break;
            }
            match stream.write(&out[wcur..]) {
                Ok(0) => fail!("server closed connection mid-write"),
                Ok(n) => wcur += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => fail!("write: {e}"),
            }
        }
        // Drain replies.
        let mut chunk = [0u8; 32 * 1024];
        match stream.read(&mut chunk) {
            Ok(0) => fail!("server closed connection mid-run"),
            Ok(n) => inbuf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock => {}
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => fail!("read: {e}"),
        }
        loop {
            if expect.is_empty() {
                break;
            }
            match parse_reply(&inbuf[parsed..]) {
                Ok(Some((used, hit))) => {
                    parsed += used;
                    let was_get = matches!(expect.pop_front(), Some(Expect::Get));
                    done += 1;
                    if hit || !was_get {
                        hits += 1;
                    } else {
                        misses += 1;
                    }
                }
                Ok(None) => break,
                Err(e) => fail!("{e}"),
            }
        }
        if parsed > 0 {
            inbuf.drain(..parsed);
            parsed = 0;
        }
    }
    (done, hits, misses, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reply_parser_handles_each_type_and_partials() {
        assert_eq!(parse_reply(b"+OK\r\n").unwrap(), Some((5, true)));
        assert_eq!(parse_reply(b":42\r\n").unwrap(), Some((5, true)));
        assert_eq!(parse_reply(b"$-1\r\n").unwrap(), Some((5, false)));
        assert_eq!(parse_reply(b"$5\r\nhello\r\nrest").unwrap(), Some((11, true)));
        let full = b"$5\r\nhello\r\n";
        for cut in 0..full.len() {
            assert_eq!(parse_reply(&full[..cut]).unwrap(), None, "cut={cut}");
        }
        assert!(parse_reply(b"-ERR nope\r\n").is_err());
        assert!(parse_reply(b"?junk\r\n").is_err());
        // Desync guard: absurd declared lengths error instead of hanging.
        assert!(parse_reply(b"$99999999\r\n").is_err());
        assert!(parse_reply(b"$999999999999999999999\r\n").is_err());
    }
}
