//! The protocol-agnostic delegated server core.
//!
//! One connection engine ([`engine`]) owns the accept path, per-connection
//! buffers, backpressure, response spooling under both ordering
//! disciplines, stop/drain semantics, and per-worker metrics — so a wire
//! protocol is just a [`Protocol`] implementation (parse / error-render /
//! dispatch) plus backend wiring. Three front ends ride it:
//!
//! - the binary KV protocol (paper §6.3) — [`crate::kvstore::KvServer`],
//!   out-of-order completion (id-tagged frames);
//! - the memcached text protocol (paper §7) —
//!   [`crate::memcache::McdServer`], in-order via the reorder spool;
//! - RESP2, the Redis wire format — [`resp::RespServer`], in-order, so
//!   stock Redis clients can drive the delegated backends.
//!
//! [`netfiber`] carries the shared non-blocking socket helpers and the
//! [`netfiber::NetPolicy`] waiting disciplines (busy-poll vs epoll park).

pub mod engine;
pub mod netfiber;
pub mod resp;
pub mod resp_load;

pub use engine::{
    Completion, ConnMetrics, ConnTotals, CoreConfig, Inbuf, Protocol, ResponseOrder, ServerCore,
    ServerTuning, Spool,
};
pub use netfiber::NetPolicy;
pub use resp::{RespParseError, RespProtocol, RespRequest, RespServer, RespServerConfig};
pub use resp_load::{run_resp_load, RespLoadConfig, RespLoadStats};
