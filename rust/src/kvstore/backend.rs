//! KV-store backends (§6.3, §7): the delegated Trust\<T\> design vs. the
//! lock baselines, behind one callback-style interface so every front
//! end (binary KV, memcached text, RESP) is identical over all of them.
//!
//! Since the storage unification all four backends share **one shard
//! type** — [`ItemShard`](super::store::ItemShard), the unified item
//! store with flags, TTL, a per-shard byte budget and LRU eviction:
//!
//! - [`TrustKv`] entrusts one shard per trustee ("16 and 24 cores to run
//!   trustees, each hosting a shard of the table"); socket workers
//!   *delegate* all accesses — including the LRU bump, expiry check and
//!   eviction every access implies — and never touch the table. Clients
//!   receive a **copy** of the value, exactly like the paper's memcached
//!   port (§7).
//! - [`LockedItemKv`] puts the same shard behind `Mutex`/`RwLock` locks
//!   (the `mutex`/`rwlock`/`swift` baselines). Because a cache GET
//!   mutates (LRU relink, lazy expiry), even the readers-writer variants
//!   take the exclusive lock on the read path — stock memcached's
//!   synchronization profile ("memory allocation, LRU updates as well as
//!   table writes, all of which involve synchronization in a lock-based
//!   design"). Only genuinely read-only probes (EXISTS, TTL) stay on the
//!   read lock.
//!
//! ## Allocation discipline (the one-copy GET contract)
//!
//! The interface is built so the steady state performs **zero per-op
//! allocations** and each value is copied exactly once per channel hop
//! (DESIGN.md, "Allocation discipline"):
//!
//! - Keys travel **borrowed** (`&[u8]`): the Trust backend serializes
//!   them straight into the delegation slot ([`Trust::apply_raw_then`])
//!   and the trustee looks them up as a borrowed slice; the lock
//!   backends probe their shards in place under the lock.
//! - GET completions ([`GetCb`]/[`GetItemCb`]) receive the value
//!   **borrowed** — from the delegation response stream (Trust) or in
//!   place under the shard lock (locks) — so the front end copies it
//!   once, directly into its pooled wire buffer. [`GetItemCb`]
//!   additionally receives the **key echoed borrowed** (from the
//!   delegation slot / the caller's slice), so the memcached front end
//!   renders `VALUE <key> …` without owning a key copy in its
//!   completion.
//! - Callbacks store their captures inline (40 bytes) instead of one
//!   `Box<dyn FnOnce>` per op.
//! - Overwriting SETs reuse the entry's `Vec` allocation in place.
//!
//! Every Trust delegation here is **non-urgent**, so the request paths
//! inherit the adaptive flush policy: all the ops a socket fiber parses
//! out of one TCP read accumulate in the per-(worker, trustee) outbox
//! and travel as one batch.

use super::store::{ItemShard, ShardLock, StoreConfig, StoreStats, SWEEP_SLOTS};
use crate::channel::{read_opt_bytes, read_response, ResponseWriter};
use crate::cmap::fxhash;
use crate::runtime::Runtime;
use crate::trust::Trust;
use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::{Arc, Mutex, RwLock};

crate::define_inline_fn_once! {
    /// Completion callback for a get. The value arrives **borrowed**
    /// (from the response stream or the shard) and only for the duration
    /// of the call — copy it where it needs to go, typically straight
    /// into a pooled wire buffer (the one-copy GET). `None` for a miss.
    pub struct GetCb(v: Option<&[u8]>);
    inline_bytes = 40;
}

crate::define_inline_fn_once! {
    /// Completion callback for an item-aware get: the key (echoed
    /// borrowed, so a line-protocol front end can render `VALUE <key>`
    /// without owning a copy) and, on a hit, the item's flags plus the
    /// value borrowed.
    pub struct GetItemCb(key: &[u8], item: Option<(u32, &[u8])>);
    inline_bytes = 40;
}

crate::define_inline_fn_once! {
    /// Completion callback for put/del/exists/touch/persist
    /// (true = the key existed / the operation applied).
    pub struct AckCb(existed: bool);
    inline_bytes = 40;
}

crate::define_inline_fn_once! {
    /// Completion for incr: `Ok(new_value)` or `Err(())` when the stored
    /// value is not an ASCII integer (or the increment overflows).
    pub struct IncrCb(r: Result<i64, ()>);
    inline_bytes = 40;
}

crate::define_inline_fn_once! {
    /// Completion for a TTL query: [`super::store::TTL_MISSING`],
    /// [`super::store::TTL_NO_EXPIRY`], or the remaining milliseconds.
    pub struct TtlCb(r: i64);
    inline_bytes = 40;
}

crate::define_inline_fn_once! {
    /// Completion for flush_all.
    pub struct FlushCb();
    inline_bytes = 40;
}

/// Callback-style KV interface over the unified item store. Lock
/// backends complete inline; the Trust backend completes when the
/// delegation response arrives. Keys are borrowed (`&[u8]`) — backends
/// copy them only where ownership is truly needed (into the delegation
/// slot, or into the table on a fresh insert).
pub trait AsyncKv: Send + Sync + 'static {
    /// Look `key` up; `cb` receives the value borrowed (one-copy GET).
    /// A GET carries full cache semantics: it relinks the item to the
    /// LRU head and lazily reclaims an expired entry (reported as a
    /// miss).
    ///
    /// **Contract:** `cb` must only *render* — it must not call back
    /// into this backend synchronously. Lock backends run it while
    /// holding the shard lock (that is what makes the borrowed value
    /// possible without a copy), so a re-entrant `get`/`put` from inside
    /// `cb` can self-deadlock on the same shard. The engine's completion
    /// callbacks comply by construction (they render into a
    /// connection-local spool); chained follow-up operations belong
    /// after the callback returns, not inside it.
    fn get(&self, key: &[u8], cb: GetCb);

    /// Item-aware GET: like [`AsyncKv::get`] but the callback also
    /// receives the item's flags and the key echoed borrowed (the
    /// memcached `VALUE <key> <flags> <bytes>` shape). The default goes
    /// through [`AsyncKv::get`] with flags 0 and an owned key copy —
    /// cold/experimental backends only; the real backends override.
    fn get_item(&self, key: &[u8], cb: GetItemCb) {
        let k = key.to_vec();
        self.get(
            key,
            GetCb::new(move |v: Option<&[u8]>| cb.call(&k, v.map(|v| (0, v)))),
        );
    }

    /// Plain store: flags 0, no expiry (clears any previous deadline,
    /// like Redis `SET`).
    fn put(&self, key: &[u8], val: &[u8], cb: AckCb) {
        self.set_item(key, val, 0, 0, cb);
    }

    /// Full item store: value plus flags and a relative TTL in ms
    /// (0 = no expiry). `cb` receives whether a live entry was
    /// overwritten. May evict LRU items to honor the shard's byte
    /// budget before completing.
    fn set_item(&self, key: &[u8], val: &[u8], flags: u32, ttl_ms: u64, cb: AckCb);

    fn del(&self, key: &[u8], cb: AckCb);

    /// Key-presence check (RESP `EXISTS`). Read-only: no LRU bump, no
    /// lazy reclamation — the read-lock-scaling path on the RwLock
    /// baselines. The default goes through the borrowed [`GetCb`]
    /// (which *does* bump); hot backends override with a true peek.
    fn exists(&self, key: &[u8], cb: AckCb) {
        self.get(key, GetCb::new(move |v: Option<&[u8]>| cb.call(v.is_some())));
    }

    /// Reset (or, with `ttl_ms` 0, clear) a live entry's deadline —
    /// memcached `touch` / Redis `EXPIRE`. `cb(true)` when the key was
    /// live. Default: TTLs unsupported, always false.
    fn touch(&self, key: &[u8], ttl_ms: u64, cb: AckCb) {
        let _ = (key, ttl_ms);
        cb.call(false);
    }

    /// Clear a live entry's deadline (Redis `PERSIST`): `cb(true)` only
    /// when the entry existed and had a deadline. Default: false.
    fn persist(&self, key: &[u8], cb: AckCb) {
        let _ = key;
        cb.call(false);
    }

    /// Remaining lifetime in ms ([`TtlCb`] semantics). The default
    /// answers through `exists` (no TTL support: live keys never
    /// expire).
    fn ttl(&self, key: &[u8], cb: TtlCb) {
        self.exists(
            key,
            AckCb::new(move |e| {
                cb.call(if e {
                    super::store::TTL_NO_EXPIRY
                } else {
                    super::store::TTL_MISSING
                })
            }),
        );
    }

    /// Atomic ASCII-decimal increment with Redis `INCR` semantics: a
    /// missing (or expired) key counts as 0, a non-integer value (or
    /// overflow) is an error and leaves the entry untouched. Atomic per
    /// key — delegated to the owning trustee for Trust, under the shard
    /// lock for the lock backends.
    fn incr(&self, key: &[u8], delta: i64, cb: IncrCb);

    /// Remove every entry (RESP `FLUSHALL`).
    fn flush_all(&self, cb: FlushCb);

    /// Total entries (diagnostic; may take locks). Expired-but-unswept
    /// entries still count — they occupy memory until reclaimed.
    fn len(&self) -> usize;

    /// Run a bounded expiry sweep over every shard *now* (`max_slots`
    /// slab slots per shard), returning entries reclaimed. Diagnostic /
    /// test entry point; production reclamation runs incrementally via
    /// [`AsyncKv::maintenance_tick`].
    fn sweep_now(&self, max_slots: usize) -> u64 {
        let _ = max_slots;
        0
    }

    /// Aggregated store counters (items, bytes, evictions, expirations,
    /// plus the value-slab pool hit/miss/fragmentation gauges).
    /// Diagnostic; may take locks / delegate per shard.
    fn store_stats(&self) -> StoreStats {
        StoreStats { items: self.len() as u64, ..Default::default() }
    }

    /// One bounded maintenance quantum, called from worker `worker`'s
    /// scheduler loop every few ticks (see
    /// [`install_store_maintenance`]). `workers` is the runtime size and
    /// `tick` a per-worker call counter, so implementations can stripe
    /// their shards. Returns entries reclaimed (useful-work signal for
    /// the scheduler's backoff).
    fn maintenance_tick(&self, worker: usize, workers: usize, tick: u64) -> u64 {
        let _ = (worker, workers, tick);
        0
    }

    fn name(&self) -> &'static str;
}

/// Register the store's incremental expiry sweep with every worker's
/// scheduler maintenance hook: each worker calls
/// [`AsyncKv::maintenance_tick`] every few scheduler ticks. On the Trust
/// backend each trustee sweeps **its own shards** through the local
/// delegation shortcut — expiry reclamation stays synchronization-free;
/// the lock backends stripe their shards over the workers and sweep
/// lock-scoped. Called by [`BackendKind::build_with`]; harmless to call
/// more than once (the sweep is idempotent).
pub fn install_store_maintenance(rt: &Runtime, kv: &Arc<dyn AsyncKv>) {
    let workers = rt.workers();
    for w in 0..workers {
        let kv = kv.clone();
        rt.shared().inject(
            w,
            Box::new(move || {
                let mut tick = 0u64;
                crate::runtime::with_worker(|wk| {
                    wk.register_maintenance(Box::new(move || {
                        tick = tick.wrapping_add(1);
                        kv.maintenance_tick(w, workers, tick) as usize
                    }));
                });
            }),
        );
    }
}

// ---------------------------------------------------------------------
// Lock baselines
// ---------------------------------------------------------------------

/// The unified item store behind per-shard locks — the `mutex`,
/// `rwlock` and `swift` baselines (the latter is the Dashmap-style
/// fixed-64-shard RwLock layout). See the module docs for why GETs take
/// the write side.
pub struct LockedItemKv<L> {
    shards: Vec<L>,
    name: &'static str,
}

impl<L: ShardLock> LockedItemKv<L> {
    /// `n_shards` is rounded up to a power of two (512 for the sharded
    /// baselines, 64 for the Dashmap-like layout).
    pub fn new(n_shards: usize, name: &'static str, cfg: &StoreConfig) -> LockedItemKv<L> {
        let n = n_shards.next_power_of_two().max(1);
        let budget = cfg.shard_budget(n);
        let mut shards = Vec::with_capacity(n);
        for _ in 0..n {
            shards.push(L::new(ItemShard::with_budget(cfg.clock.clone(), budget)));
        }
        LockedItemKv { shards, name }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn shard(&self, key: &[u8]) -> &L {
        &self.shards[(fxhash(key) as usize >> 7) & (self.shards.len() - 1)]
    }
}

impl<L: ShardLock> AsyncKv for LockedItemKv<L> {
    fn get(&self, key: &[u8], cb: GetCb) {
        // The callback renders under the shard lock, so the value is
        // copied exactly once, shard → wire buffer. Write side: the LRU
        // bump and lazy expiry are mutations (module docs).
        self.shard(key).write(|s| cb.call(s.get(key).map(|(_, v)| v)));
    }

    fn get_item(&self, key: &[u8], cb: GetItemCb) {
        self.shard(key).write(|s| cb.call(key, s.get(key)));
    }

    fn set_item(&self, key: &[u8], val: &[u8], flags: u32, ttl_ms: u64, cb: AckCb) {
        cb.call(self.shard(key).write(|s| s.set(key, val, flags, ttl_ms)));
    }

    fn del(&self, key: &[u8], cb: AckCb) {
        cb.call(self.shard(key).write(|s| s.del(key)));
    }

    fn exists(&self, key: &[u8], cb: AckCb) {
        // True peek: read lock, no LRU bump, no reclamation — EXISTS is
        // read-only and must scale like the read it is.
        cb.call(self.shard(key).read(|s| s.peek(key).is_some()));
    }

    fn touch(&self, key: &[u8], ttl_ms: u64, cb: AckCb) {
        cb.call(self.shard(key).write(|s| s.touch(key, ttl_ms)));
    }

    fn persist(&self, key: &[u8], cb: AckCb) {
        cb.call(self.shard(key).write(|s| s.persist(key)));
    }

    fn ttl(&self, key: &[u8], cb: TtlCb) {
        cb.call(self.shard(key).read(|s| s.ttl_ms(key)));
    }

    fn incr(&self, key: &[u8], delta: i64, cb: IncrCb) {
        cb.call(self.shard(key).write(|s| s.incr(key, delta)));
    }

    fn flush_all(&self, cb: FlushCb) {
        for s in &self.shards {
            s.write(|s| s.clear());
        }
        cb.call();
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read(|s| s.len())).sum()
    }

    fn sweep_now(&self, max_slots: usize) -> u64 {
        self.shards
            .iter()
            .map(|s| s.write(|s| s.sweep(max_slots)))
            .sum()
    }

    fn store_stats(&self) -> StoreStats {
        let mut total = StoreStats::default();
        for s in &self.shards {
            let st = s.read(|s| s.stats());
            total.merge(&st);
        }
        total
    }

    fn maintenance_tick(&self, worker: usize, workers: usize, tick: u64) -> u64 {
        // Stripe shards over workers, sweep a few per tick round-robin:
        // bounded, lock-scoped work per quantum.
        const SHARDS_PER_TICK: u64 = 4;
        let n = self.shards.len() as u64;
        let workers = workers.max(1) as u64;
        let stripe_len = n.div_ceil(workers).max(1);
        let mut reclaimed = 0;
        for j in 0..SHARDS_PER_TICK {
            let pos = (tick.wrapping_mul(SHARDS_PER_TICK) + j) % stripe_len;
            let idx = worker as u64 + pos * workers;
            if idx < n {
                reclaimed += self.shards[idx as usize].write(|s| s.sweep(SWEEP_SLOTS));
            }
        }
        reclaimed
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

// ---------------------------------------------------------------------
// Delegated backend (Trust<T>)
// ---------------------------------------------------------------------

/// The Trust\<T\>-backed store: one entrusted [`ItemShard`] per trustee.
/// Every cache mutation — table write, LRU relink, expiry reclamation,
/// budget eviction — is trustee-local, with zero synchronization.
pub struct TrustKv {
    shards: Vec<Trust<ItemShard>>,
}

impl TrustKv {
    /// Entrust `n_shards` shards round-robin over `trustees` with the
    /// default (unbudgeted, real-clock) store config.
    pub fn new(rt: &Runtime, trustees: &[usize], n_shards: usize) -> Arc<TrustKv> {
        Self::with_config(rt, trustees, n_shards, &StoreConfig::default())
    }

    pub fn with_config(
        rt: &Runtime,
        trustees: &[usize],
        n_shards: usize,
        cfg: &StoreConfig,
    ) -> Arc<TrustKv> {
        assert!(!trustees.is_empty());
        let budget = cfg.shard_budget(n_shards);
        let mut shards = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let tr = rt.trustee(trustees[s % trustees.len()]);
            // Entrust from this (non-worker) thread via the injected path.
            shards.push(tr.entrust(ItemShard::with_budget(cfg.clock.clone(), budget)));
        }
        Arc::new(TrustKv { shards })
    }

    #[inline]
    fn shard(&self, key: &[u8]) -> &Trust<ItemShard> {
        let h = fxhash(key) as usize;
        &self.shards[(h >> 8) % self.shards.len()]
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }
}

impl AsyncKv for TrustKv {
    fn get(&self, key: &[u8], cb: GetCb) {
        // One-copy GET: the key is copied once (caller → delegation
        // slot), looked up borrowed on the trustee — LRU bump and lazy
        // expiry applied right there — and the value is written borrowed
        // into the response stream; `cb` sees it borrowed from that
        // stream and copies it straight into the wire buffer.
        self.shard(key).apply_raw_then(
            |t: &mut ItemShard, k: &[u8], out: &mut ResponseWriter| {
                out.write_opt_bytes(t.get(k).map(|(_, v)| v))
            },
            key,
            move |r| cb.call(read_opt_bytes(r)),
        );
    }

    fn get_item(&self, key: &[u8], cb: GetItemCb) {
        self.shard(key).apply_raw_then(
            |t: &mut ItemShard, k: &[u8], out: &mut ResponseWriter| {
                // Echo the key first (borrowed from the delegation slot →
                // one copy into the response stream) so the completion can
                // render `VALUE <key> …` without owning a key.
                out.write_opt_bytes(Some(k));
                match t.get(k) {
                    Some((f, v)) => {
                        out.write_value(&true);
                        out.write_value(&f);
                        out.write_opt_bytes(Some(v));
                    }
                    None => out.write_value(&false),
                }
            },
            key,
            move |r| {
                let k = read_opt_bytes(r).expect("key echo");
                if read_response::<bool>(r) {
                    let f = read_response::<u32>(r);
                    let v = read_opt_bytes(r).expect("item value");
                    cb.call(k, Some((f, v)));
                } else {
                    cb.call(k, None);
                }
            },
        );
    }

    fn set_item(&self, key: &[u8], val: &[u8], flags: u32, ttl_ms: u64, cb: AckCb) {
        // Key and value travel as adjacent raw parts (one copy into the
        // slot, no concatenation buffer); the closure re-splits at the
        // captured key length. Overwrites reuse the entry's existing
        // allocation — steady-state SET traffic allocates nothing.
        let klen = key.len();
        self.shard(key).apply_raw_parts_then(
            move |t: &mut ItemShard, args: &[u8], out: &mut ResponseWriter| {
                let (k, v) = args.split_at(klen);
                out.write_value(&t.set(k, v, flags, ttl_ms));
            },
            &[key, val],
            move |r| cb.call(read_response::<bool>(r)),
        );
    }

    fn del(&self, key: &[u8], cb: AckCb) {
        self.shard(key).apply_raw_then(
            |t: &mut ItemShard, k: &[u8], out: &mut ResponseWriter| {
                out.write_value(&t.del(k))
            },
            key,
            move |r| cb.call(read_response::<bool>(r)),
        );
    }

    fn exists(&self, key: &[u8], cb: AckCb) {
        // Trustee-local read-only peek: no value copy travels back, no
        // LRU bump.
        self.shard(key).apply_raw_then(
            |t: &mut ItemShard, k: &[u8], out: &mut ResponseWriter| {
                out.write_value(&t.peek(k).is_some())
            },
            key,
            move |r| cb.call(read_response::<bool>(r)),
        );
    }

    fn touch(&self, key: &[u8], ttl_ms: u64, cb: AckCb) {
        self.shard(key).apply_raw_then(
            move |t: &mut ItemShard, k: &[u8], out: &mut ResponseWriter| {
                out.write_value(&t.touch(k, ttl_ms))
            },
            key,
            move |r| cb.call(read_response::<bool>(r)),
        );
    }

    fn persist(&self, key: &[u8], cb: AckCb) {
        self.shard(key).apply_raw_then(
            |t: &mut ItemShard, k: &[u8], out: &mut ResponseWriter| {
                out.write_value(&t.persist(k))
            },
            key,
            move |r| cb.call(read_response::<bool>(r)),
        );
    }

    fn ttl(&self, key: &[u8], cb: TtlCb) {
        self.shard(key).apply_raw_then(
            |t: &mut ItemShard, k: &[u8], out: &mut ResponseWriter| {
                out.write_value(&t.ttl_ms(k))
            },
            key,
            move |r| cb.call(read_response::<i64>(r)),
        );
    }

    fn incr(&self, key: &[u8], delta: i64, cb: IncrCb) {
        // The read-modify-write runs entirely on the owning trustee, so
        // it is atomic per key with zero synchronization (the paper's
        // core claim applied to a compound operation).
        self.shard(key).apply_raw_then(
            move |t: &mut ItemShard, k: &[u8], out: &mut ResponseWriter| {
                out.write_value(&t.incr(k, delta))
            },
            key,
            move |r| cb.call(read_response::<Result<i64, ()>>(r)),
        );
    }

    fn flush_all(&self, cb: FlushCb) {
        // Fan one clear out to every shard's trustee; answer when the
        // last completion lands (all completions run on this worker).
        let remaining = Rc::new(Cell::new(self.shards.len()));
        let done = Rc::new(RefCell::new(Some(cb)));
        for s in &self.shards {
            let remaining = remaining.clone();
            let done = done.clone();
            s.apply_then(
                |t| t.clear(),
                move |_| {
                    remaining.set(remaining.get() - 1);
                    if remaining.get() == 0 {
                        if let Some(cb) = done.borrow_mut().take() {
                            cb.call();
                        }
                    }
                },
            );
        }
    }

    fn len(&self) -> usize {
        // Diagnostic: blocking sum over shards (from a non-worker thread
        // this takes the injected path).
        self.shards.iter().map(|s| s.apply(|t| t.len() as u64) as usize).sum()
    }

    fn sweep_now(&self, max_slots: usize) -> u64 {
        self.shards
            .iter()
            .map(|s| s.apply(move |t| t.sweep(max_slots)))
            .sum()
    }

    fn store_stats(&self) -> StoreStats {
        let mut total = StoreStats::default();
        for s in &self.shards {
            let a = s.apply(|t| t.stats().to_array());
            total.merge(&StoreStats::from_array(a));
        }
        total
    }

    fn maintenance_tick(&self, worker: usize, _workers: usize, _tick: u64) -> u64 {
        // Sweep only the shards entrusted to *this* worker, through the
        // local delegation shortcut: plain single-threaded mutation, no
        // channel traffic, no locks — expiry stays synchronization-free.
        let mut reclaimed = 0;
        for s in &self.shards {
            if s.trustee_id() == worker {
                reclaimed += s.apply(|t| t.sweep(SWEEP_SLOTS));
            }
        }
        reclaimed
    }

    fn name(&self) -> &'static str {
        "trust"
    }
}

// ---------------------------------------------------------------------
// Backend selector
// ---------------------------------------------------------------------

/// Backend selector used by the server configs and the benches.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Trust<T>-delegated shards; `shards` tables spread over the
    /// runtime's trustee workers.
    Trust { shards: usize },
    /// The unified item store behind 512 `Mutex` shards.
    Mutex,
    /// The unified item store behind 512 `RwLock` shards.
    RwLock,
    /// The unified item store in the Dashmap-like layout (64 `RwLock`
    /// shards).
    Swift,
}

impl BackendKind {
    pub fn from_spec(s: &str) -> BackendKind {
        match s {
            "mutex" => BackendKind::Mutex,
            "rwlock" => BackendKind::RwLock,
            "swift" | "dashmap" => BackendKind::Swift,
            other => {
                if let Some(rest) = other.strip_prefix("trust") {
                    let shards = rest.trim_start_matches(':').parse().unwrap_or(0);
                    BackendKind::Trust { shards }
                } else {
                    panic!("unknown backend {other:?} (want trust[:N]|mutex|rwlock|swift)")
                }
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            BackendKind::Trust { shards } => format!("Trust{shards}"),
            BackendKind::Mutex => "Mutex".into(),
            BackendKind::RwLock => "RwLock".into(),
            BackendKind::Swift => "Dashmap-like".into(),
        }
    }

    /// Shard count this kind will split a store budget over (lower
    /// bound for `Trust { shards: 0 }`, which resolves to the trustee
    /// count at build time).
    pub fn shard_count(&self) -> usize {
        match self {
            BackendKind::Trust { shards } => (*shards).max(1),
            BackendKind::Mutex | BackendKind::RwLock => 512,
            BackendKind::Swift => 64,
        }
    }

    /// Reject a byte budget that is degenerate for this backend's shard
    /// granularity: the budget splits per shard, and a slice that cannot
    /// hold even two entries' fixed overhead means every SET evicts its
    /// own key — the server would answer STORED/+OK while retaining
    /// nothing. Entry *values* make real entries bigger still, so this
    /// floor only catches configs that are wrong for every workload.
    pub fn validate_budget(&self, budget_bytes: u64) -> Result<(), String> {
        let n = self.shard_count() as u64;
        let floor = n * 2 * super::store::ITEM_OVERHEAD;
        if budget_bytes > 0 && budget_bytes < floor {
            return Err(format!(
                "budget_bytes {budget_bytes} splits to {} B over {n} {} shards — \
                 below two entries' fixed overhead ({}B each); every SET would \
                 immediately evict its own key. Use at least {floor} bytes (or 0 \
                 for unlimited)",
                budget_bytes / n,
                self.label(),
                super::store::ITEM_OVERHEAD,
            ));
        }
        Ok(())
    }

    /// Instantiate with the default store config. `trustees` lists
    /// worker ids hosting shards (Trust only).
    pub fn build(&self, rt: &Runtime, trustees: &[usize]) -> Arc<dyn AsyncKv> {
        self.build_with(rt, trustees, &StoreConfig::default())
    }

    /// Instantiate with an explicit store config (byte budget, clock)
    /// and register the incremental expiry sweep with the runtime's
    /// maintenance hook.
    pub fn build_with(
        &self,
        rt: &Runtime,
        trustees: &[usize],
        cfg: &StoreConfig,
    ) -> Arc<dyn AsyncKv> {
        let kv: Arc<dyn AsyncKv> = match self {
            BackendKind::Trust { shards } => {
                let n = if *shards == 0 { trustees.len() } else { *shards };
                TrustKv::with_config(rt, trustees, n, cfg)
            }
            BackendKind::Mutex => {
                Arc::new(LockedItemKv::<Mutex<ItemShard>>::new(512, "mutex", cfg))
            }
            BackendKind::RwLock => {
                Arc::new(LockedItemKv::<RwLock<ItemShard>>::new(512, "rwlock", cfg))
            }
            BackendKind::Swift => {
                Arc::new(LockedItemKv::<RwLock<ItemShard>>::new(64, "swift", cfg))
            }
        };
        install_store_maintenance(rt, &kv);
        kv
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::store::{StoreClock, TTL_MISSING, TTL_NO_EXPIRY};
    use std::sync::atomic::{AtomicI64, AtomicUsize, Ordering};

    fn exercise_backend(kv: Arc<dyn AsyncKv>, rt: &Runtime) {
        // Run ops from a worker fiber so Trust completions can flow.
        let kv2 = kv.clone();
        let worker = rt.workers() - 1;
        rt.block_on(worker, move || {
            let done = Arc::new(AtomicUsize::new(0));
            for i in 0..50u64 {
                let d = done.clone();
                kv2.put(
                    &format!("k{i}").into_bytes(),
                    &format!("v{i}").into_bytes(),
                    AckCb::new(move |existed| {
                        assert!(!existed);
                        d.fetch_add(1, Ordering::Relaxed);
                    }),
                );
            }
            // Drain: wait until all callbacks ran (yield lets poll run).
            while done.load(Ordering::Relaxed) != 50 {
                crate::fiber::yield_now();
            }
            // Overwrites must report the existing key (and, on Trust,
            // reuse the entry in place).
            let over = Arc::new(AtomicUsize::new(0));
            for i in 0..10u64 {
                let o = over.clone();
                kv2.put(
                    &format!("k{i}").into_bytes(),
                    &format!("V{i}").into_bytes(),
                    AckCb::new(move |existed| {
                        assert!(existed);
                        o.fetch_add(1, Ordering::Relaxed);
                    }),
                );
            }
            while over.load(Ordering::Relaxed) != 10 {
                crate::fiber::yield_now();
            }
            let got = Arc::new(AtomicUsize::new(0));
            for i in 0..50u64 {
                let g = got.clone();
                let want = if i < 10 {
                    format!("V{i}").into_bytes()
                } else {
                    format!("v{i}").into_bytes()
                };
                kv2.get(
                    &format!("k{i}").into_bytes(),
                    GetCb::new(move |v: Option<&[u8]>| {
                        assert_eq!(v, Some(&want[..]));
                        g.fetch_add(1, Ordering::Relaxed);
                    }),
                );
            }
            while got.load(Ordering::Relaxed) != 50 {
                crate::fiber::yield_now();
            }
            let deleted = Arc::new(AtomicUsize::new(0));
            for i in 0..25u64 {
                let d = deleted.clone();
                kv2.del(
                    &format!("k{i}").into_bytes(),
                    AckCb::new(move |e| {
                        assert!(e);
                        d.fetch_add(1, Ordering::Relaxed);
                    }),
                );
            }
            while deleted.load(Ordering::Relaxed) != 25 {
                crate::fiber::yield_now();
            }
        });
        assert_eq!(kv.len(), 25);
    }

    #[test]
    fn trust_backend_end_to_end() {
        let rt = Runtime::builder().workers(3).build();
        let kv = BackendKind::Trust { shards: 4 }.build(&rt, &[0, 1]);
        assert_eq!(kv.name(), "trust");
        exercise_backend(kv, &rt);
        rt.shutdown();
    }

    #[test]
    fn lock_backends_end_to_end() {
        let rt = Runtime::builder().workers(2).build();
        for kind in [BackendKind::Mutex, BackendKind::RwLock, BackendKind::Swift] {
            let kv = kind.build(&rt, &[]);
            exercise_backend(kv, &rt);
        }
        rt.shutdown();
    }

    fn exercise_redis_ops(kv: Arc<dyn AsyncKv>, rt: &Runtime) {
        let kv2 = kv.clone();
        let worker = rt.workers() - 1;
        rt.block_on(worker, move || {
            let steps = Arc::new(AtomicUsize::new(0));
            // INCR on a missing key starts from 0.
            let s = steps.clone();
            kv2.incr(
                b"ctr",
                5,
                IncrCb::new(move |r| {
                    assert_eq!(r, Ok(5));
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 1 {
                crate::fiber::yield_now();
            }
            // INCR again: reads the stored ASCII value back.
            let s = steps.clone();
            kv2.incr(
                b"ctr",
                2,
                IncrCb::new(move |r| {
                    assert_eq!(r, Ok(7));
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 2 {
                crate::fiber::yield_now();
            }
            // Non-integer value: an error, and the entry is untouched.
            let s = steps.clone();
            kv2.put(
                b"text",
                b"not-a-number",
                AckCb::new(move |_| {
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 3 {
                crate::fiber::yield_now();
            }
            let s = steps.clone();
            kv2.incr(
                b"text",
                1,
                IncrCb::new(move |r| {
                    assert_eq!(r, Err(()));
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 4 {
                crate::fiber::yield_now();
            }
            let s = steps.clone();
            kv2.get(
                b"text",
                GetCb::new(move |v: Option<&[u8]>| {
                    assert_eq!(v, Some(&b"not-a-number"[..]));
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 5 {
                crate::fiber::yield_now();
            }
            // EXISTS without copying: hit then miss.
            let s = steps.clone();
            kv2.exists(
                b"ctr",
                AckCb::new(move |e| {
                    assert!(e);
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            let s = steps.clone();
            kv2.exists(
                b"nope",
                AckCb::new(move |e| {
                    assert!(!e);
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 7 {
                crate::fiber::yield_now();
            }
            // FLUSHALL empties every shard.
            let s = steps.clone();
            kv2.flush_all(FlushCb::new(move || {
                s.fetch_add(1, Ordering::Relaxed);
            }));
            while steps.load(Ordering::Relaxed) != 8 {
                crate::fiber::yield_now();
            }
        });
        assert_eq!(kv.len(), 0, "flush_all must empty the store");
    }

    #[test]
    fn trust_backend_redis_ops() {
        let rt = Runtime::builder().workers(3).build();
        let kv = BackendKind::Trust { shards: 4 }.build(&rt, &[0, 1]);
        exercise_redis_ops(kv, &rt);
        rt.shutdown();
    }

    #[test]
    fn lock_backends_redis_ops() {
        let rt = Runtime::builder().workers(2).build();
        for kind in [BackendKind::Mutex, BackendKind::RwLock, BackendKind::Swift] {
            let kv = kind.build(&rt, &[]);
            exercise_redis_ops(kv, &rt);
        }
        rt.shutdown();
    }

    fn exercise_item_ops(kv: Arc<dyn AsyncKv>, rt: &Runtime, clock: Arc<StoreClock>) {
        let kv2 = kv.clone();
        let worker = rt.workers() - 1;
        rt.block_on(worker, move || {
            let steps = Arc::new(AtomicUsize::new(0));
            // set_item with flags + TTL; get_item echoes key and flags.
            let s = steps.clone();
            kv2.set_item(
                b"it",
                b"payload",
                42,
                500,
                AckCb::new(move |existed| {
                    assert!(!existed);
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 1 {
                crate::fiber::yield_now();
            }
            let s = steps.clone();
            kv2.get_item(
                b"it",
                GetItemCb::new(move |k: &[u8], item: Option<(u32, &[u8])>| {
                    assert_eq!(k, b"it", "key must be echoed");
                    let (flags, v) = item.expect("live item");
                    assert_eq!(flags, 42);
                    assert_eq!(v, b"payload");
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 2 {
                crate::fiber::yield_now();
            }
            // TTL is visible, EXPIRE-style touch resets it, PERSIST
            // clears it.
            let remaining = Arc::new(AtomicI64::new(0));
            let s = steps.clone();
            let r2 = remaining.clone();
            kv2.ttl(
                b"it",
                TtlCb::new(move |ms| {
                    r2.store(ms, Ordering::Relaxed);
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 3 {
                crate::fiber::yield_now();
            }
            let ms = remaining.load(Ordering::Relaxed);
            assert!((1..=500).contains(&ms), "remaining ttl {ms}");
            let s = steps.clone();
            kv2.touch(
                b"it",
                10_000,
                AckCb::new(move |live| {
                    assert!(live);
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            let s = steps.clone();
            kv2.persist(
                b"it",
                AckCb::new(move |had_ttl| {
                    assert!(had_ttl);
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            let s = steps.clone();
            kv2.ttl(
                b"it",
                TtlCb::new(move |ms| {
                    assert_eq!(ms, TTL_NO_EXPIRY);
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 6 {
                crate::fiber::yield_now();
            }
            // Expire it for real (manual clock) and observe the miss.
            let s = steps.clone();
            kv2.touch(
                b"it",
                100,
                AckCb::new(move |live| {
                    assert!(live);
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 7 {
                crate::fiber::yield_now();
            }
            clock.advance(100);
            let s = steps.clone();
            kv2.get_item(
                b"it",
                GetItemCb::new(move |k: &[u8], item: Option<(u32, &[u8])>| {
                    assert_eq!(k, b"it");
                    assert!(item.is_none(), "expired item must miss");
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            let s = steps.clone();
            kv2.ttl(
                b"it",
                TtlCb::new(move |ms| {
                    assert_eq!(ms, TTL_MISSING);
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 9 {
                crate::fiber::yield_now();
            }
        });
        let stats = kv.store_stats();
        assert_eq!(stats.items, 0, "lazy expiry reclaimed on access");
        assert_eq!(stats.expired_keys, 1);
        assert_eq!(stats.store_bytes, 0);
    }

    #[test]
    fn item_ops_across_all_backends() {
        for kind in [
            BackendKind::Trust { shards: 2 },
            BackendKind::Mutex,
            BackendKind::RwLock,
            BackendKind::Swift,
        ] {
            let rt = Runtime::builder().workers(2).build();
            let clock = StoreClock::manual();
            let cfg = StoreConfig { budget_bytes: 0, clock: clock.clone() };
            let kv = kind.build_with(&rt, &[0], &cfg);
            exercise_item_ops(kv, &rt, clock);
            rt.shutdown();
        }
    }

    #[test]
    fn default_item_ops_work_through_plain_get() {
        // A backend that only implements the plain ops still answers the
        // item-aware entry points through the defaults (flags lost, TTLs
        // unsupported).
        struct GetOnly(LockedItemKv<Mutex<ItemShard>>);
        impl AsyncKv for GetOnly {
            fn get(&self, key: &[u8], cb: GetCb) {
                self.0.get(key, cb)
            }
            fn set_item(&self, key: &[u8], val: &[u8], _f: u32, _ttl: u64, cb: AckCb) {
                self.0.set_item(key, val, 0, 0, cb)
            }
            fn del(&self, key: &[u8], cb: AckCb) {
                self.0.del(key, cb)
            }
            fn incr(&self, key: &[u8], delta: i64, cb: IncrCb) {
                self.0.incr(key, delta, cb)
            }
            fn flush_all(&self, cb: FlushCb) {
                self.0.flush_all(cb)
            }
            fn len(&self) -> usize {
                self.0.len()
            }
            fn name(&self) -> &'static str {
                "get-only"
            }
        }
        let kv = GetOnly(LockedItemKv::new(4, "inner", &StoreConfig::default()));
        kv.put(b"k", b"v", AckCb::new(|_| {}));
        let hit = Rc::new(Cell::new(false));
        let h = hit.clone();
        kv.exists(b"k", AckCb::new(move |e| h.set(e)));
        assert!(hit.get());
        let h = hit.clone();
        kv.exists(b"missing", AckCb::new(move |e| h.set(e)));
        assert!(!hit.get());
        // Default get_item echoes the key and reports flags 0.
        let seen = Rc::new(Cell::new(false));
        let s = seen.clone();
        kv.get_item(
            b"k",
            GetItemCb::new(move |k: &[u8], item: Option<(u32, &[u8])>| {
                assert_eq!(k, b"k");
                assert_eq!(item, Some((0, &b"v"[..])));
                s.set(true);
            }),
        );
        assert!(seen.get());
        // Default TTL: live keys report no expiry, missing report missing.
        let ttl = Rc::new(Cell::new(0i64));
        let t = ttl.clone();
        kv.ttl(b"k", TtlCb::new(move |ms| t.set(ms)));
        assert_eq!(ttl.get(), TTL_NO_EXPIRY);
        let t = ttl.clone();
        kv.ttl(b"missing", TtlCb::new(move |ms| t.set(ms)));
        assert_eq!(ttl.get(), TTL_MISSING);
        // Default touch/persist: unsupported, false.
        let ack = Rc::new(Cell::new(true));
        let a = ack.clone();
        kv.touch(b"k", 100, AckCb::new(move |r| a.set(r)));
        assert!(!ack.get());
        let a = ack.clone();
        kv.persist(b"k", AckCb::new(move |r| a.set(r)));
        assert!(!ack.get());
    }

    #[test]
    fn callback_sizes_nest_inside_channel_completions() {
        use crate::channel::{read_opt_bytes, Completion, COMPLETION_INLINE_BYTES};
        // The allocation-free chain depends on sizes nesting: a backend
        // callback (40-byte inline) must be exactly 64 bytes so the
        // channel completion that captures one (64-byte inline) still
        // stores it inline. If a field is added to the generated structs,
        // this test catches the silent heap fallback it would cause.
        assert_eq!(std::mem::size_of::<GetCb>(), 64);
        assert_eq!(std::mem::size_of::<GetItemCb>(), 64);
        assert_eq!(std::mem::size_of::<AckCb>(), 64);
        assert_eq!(std::mem::size_of::<IncrCb>(), 64);
        assert_eq!(std::mem::size_of::<TtlCb>(), 64);
        assert!(std::mem::size_of::<GetCb>() <= COMPLETION_INLINE_BYTES);
        let cb = GetCb::new(|_: Option<&[u8]>| {});
        assert!(!cb.was_boxed());
        let c = Completion::new(move |r: &mut crate::codec::WireReader<'_>| {
            cb.call(read_opt_bytes(r))
        });
        assert!(
            !c.was_boxed(),
            "a completion capturing one backend callback must store inline"
        );
        drop(c);
        // Same for the item-aware GET (the mcd hot path).
        let icb = GetItemCb::new(|_: &[u8], _: Option<(u32, &[u8])>| {});
        assert!(!icb.was_boxed());
        let c = Completion::new(move |r: &mut crate::codec::WireReader<'_>| {
            let k = read_opt_bytes(r).unwrap();
            icb.call(k, None);
        });
        assert!(!c.was_boxed());
        drop(c);
    }

    #[test]
    fn degenerate_budgets_are_rejected_per_shard_granularity() {
        // 10 KB over 512 Mutex shards is < 2 entries' overhead per
        // shard: every SET would self-evict. The same budget over one
        // Trust shard is fine, and 0 always means unlimited.
        assert!(BackendKind::Mutex.validate_budget(10_000).is_err());
        assert!(BackendKind::RwLock.validate_budget(10_000).is_err());
        assert!(BackendKind::Swift.validate_budget(2_000).is_err());
        assert!(BackendKind::Mutex.validate_budget(0).is_ok());
        assert!(BackendKind::Mutex.validate_budget(1 << 20).is_ok());
        assert!(BackendKind::Trust { shards: 1 }.validate_budget(10_000).is_ok());
        assert!(BackendKind::Trust { shards: 256 }.validate_budget(10_000).is_err());
    }

    #[test]
    fn backend_spec_parsing() {
        assert_eq!(BackendKind::from_spec("mutex"), BackendKind::Mutex);
        assert_eq!(BackendKind::from_spec("rwlock"), BackendKind::RwLock);
        assert_eq!(BackendKind::from_spec("swift"), BackendKind::Swift);
        assert_eq!(BackendKind::from_spec("trust:16"), BackendKind::Trust { shards: 16 });
        assert_eq!(BackendKind::from_spec("trust"), BackendKind::Trust { shards: 0 });
    }

    #[test]
    fn maintenance_sweep_reclaims_without_access() {
        // Items with a short real TTL must disappear via the runtime's
        // maintenance hook alone — nobody touches the keys after the
        // writes, so only the incremental trustee-side sweep can reclaim
        // them.
        let rt = Runtime::builder().workers(2).build();
        let kv = BackendKind::Trust { shards: 2 }.build(&rt, &[0]);
        let kv2 = kv.clone();
        rt.block_on(1, move || {
            let done = Arc::new(AtomicUsize::new(0));
            for i in 0..64u64 {
                let d = done.clone();
                kv2.set_item(
                    &format!("s{i}").into_bytes(),
                    b"v",
                    0,
                    40, // 40 ms
                    AckCb::new(move |_| {
                        d.fetch_add(1, Ordering::Relaxed);
                    }),
                );
            }
            while done.load(Ordering::Relaxed) != 64 {
                crate::fiber::yield_now();
            }
        });
        assert_eq!(kv.store_stats().items, 64);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
        loop {
            let stats = kv.store_stats();
            if stats.items == 0 {
                assert_eq!(stats.expired_keys, 64);
                assert_eq!(stats.store_bytes, 0);
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "sweep never reclaimed: {stats:?}"
            );
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        rt.shutdown();
    }
}
