//! KV-store backends (§6.3): the delegated Trust\<T\> design vs. the lock
//! baselines, behind one callback-style interface so the server code is
//! identical for all of them.
//!
//! The Trust backend shards the table across trustees ("16 and 24 cores to
//! run trustees, each hosting a shard of the table"); socket workers
//! *delegate* all accesses and never touch the table — clients receive a
//! **copy** of the value, exactly like the paper's memcached port (§7:
//! "instead of a pointer to a value in the table, clients receive a
//! copy").
//!
//! ## Allocation discipline (the one-copy GET contract)
//!
//! The interface is built so the steady state performs **zero per-op
//! allocations** and each value is copied exactly once per channel hop
//! (DESIGN.md, "Allocation discipline"):
//!
//! - Keys travel **borrowed** (`&[u8]`): the Trust backend serializes
//!   them straight into the delegation slot ([`Trust::apply_raw_then`])
//!   and the trustee looks them up as a borrowed slice; the lock
//!   backends probe their maps through the borrow-keyed
//!   [`ConcurrentMap`] entry points. No owned key is ever built.
//! - GET completions ([`GetCb`]) receive the value **borrowed** — from
//!   the delegation response stream (Trust) or in place under the shard
//!   read lock (locks) — so the front end copies it once, directly into
//!   its pooled wire buffer.
//! - Callbacks ([`GetCb`]/[`AckCb`]/[`IncrCb`]/[`FlushCb`]) store their
//!   captures inline (40 bytes) instead of one `Box<dyn FnOnce>` per op.
//! - Trust PUTs that overwrite an existing key reuse the entry's `Vec`
//!   allocation in place.
//!
//! Every Trust delegation here is **non-urgent**, so the Fig. 8/9 request
//! paths inherit the adaptive flush policy for free: all the gets/puts a
//! socket fiber parses out of one TCP read accumulate in the
//! per-(worker, trustee) outbox and travel as one batch at the
//! scheduler's phase-end flush (or earlier at the slot watermark).

use crate::channel::{read_opt_bytes, read_response, ResponseWriter};
use crate::cmap::{fxhash, ConcurrentMap, OaTable, ShardedMutexMap, ShardedRwMap, SwiftMap};
use crate::runtime::Runtime;
use crate::trust::{Trust, TrusteeRef};
use std::cell::{Cell, RefCell};
use std::rc::Rc;
use std::sync::Arc;

crate::define_inline_fn_once! {
    /// Completion callback for a get. The value arrives **borrowed**
    /// (from the response stream or the shard) and only for the duration
    /// of the call — copy it where it needs to go, typically straight
    /// into a pooled wire buffer (the one-copy GET). `None` for a miss.
    pub struct GetCb(v: Option<&[u8]>);
    inline_bytes = 40;
}

crate::define_inline_fn_once! {
    /// Completion callback for put/del/exists (true = key existed before).
    pub struct AckCb(existed: bool);
    inline_bytes = 40;
}

crate::define_inline_fn_once! {
    /// Completion for incr: `Ok(new_value)` or `Err(())` when the stored
    /// value is not an ASCII integer (or the increment overflows).
    pub struct IncrCb(r: Result<i64, ()>);
    inline_bytes = 40;
}

crate::define_inline_fn_once! {
    /// Completion for flush_all.
    pub struct FlushCb();
    inline_bytes = 40;
}

/// Callback-style KV interface. Lock backends complete inline; the Trust
/// backend completes when the delegation response arrives. Keys are
/// borrowed (`&[u8]`) — backends copy them only where ownership is truly
/// needed (into the delegation slot, or into the table on a fresh
/// insert).
pub trait AsyncKv: Send + Sync + 'static {
    /// Look `key` up; `cb` receives the value borrowed (one-copy GET).
    ///
    /// **Contract:** `cb` must only *render* — it must not call back
    /// into this backend synchronously. Lock backends run it while
    /// holding the shard's read lock (that is what makes the borrowed
    /// value possible without a copy), so a re-entrant `get`/`put` from
    /// inside `cb` can self-deadlock on the same shard. The engine's
    /// completion callbacks comply by construction (they render into a
    /// connection-local spool); chained follow-up operations belong
    /// after the callback returns, not inside it.
    fn get(&self, key: &[u8], cb: GetCb);
    fn put(&self, key: &[u8], val: &[u8], cb: AckCb);
    fn del(&self, key: &[u8], cb: AckCb);
    /// Key-presence check (RESP `EXISTS`). With the borrowed [`GetCb`]
    /// the default no longer copies the value anywhere. It does still
    /// pay one heap box per call (the wrapper closure captures the
    /// 64-byte `AckCb`, which exceeds `GetCb`'s 40-byte inline budget),
    /// so hot-path backends override it — both to skip shipping value
    /// bytes and to stay allocation-free; this default is a convenience
    /// for cold or experimental backends only.
    fn exists(&self, key: &[u8], cb: AckCb) {
        self.get(key, GetCb::new(move |v: Option<&[u8]>| cb.call(v.is_some())));
    }
    /// Atomic ASCII-decimal increment with Redis `INCR` semantics: a
    /// missing key counts as 0, a non-integer value (or overflow) is an
    /// error and leaves the entry untouched. Atomic per key — delegated
    /// to the owning trustee for Trust, under the shard's write lock for
    /// the lock backends.
    fn incr(&self, key: &[u8], delta: i64, cb: IncrCb);
    /// Remove every entry (RESP `FLUSHALL`).
    fn flush_all(&self, cb: FlushCb);
    /// Total entries (diagnostic; may take locks).
    fn len(&self) -> usize;
    fn name(&self) -> &'static str;
}

/// Redis `INCR` semantics on an entry slot: missing = 0, value must be
/// an ASCII `i64`, overflow errors out. On success the slot holds the
/// new value's decimal encoding; on error it is left untouched.
fn incr_slot(slot: &mut Option<Vec<u8>>, delta: i64) -> Result<i64, ()> {
    let cur: i64 = match slot {
        None => 0,
        Some(v) => std::str::from_utf8(v).map_err(|_| ())?.parse().map_err(|_| ())?,
    };
    let next = cur.checked_add(delta).ok_or(())?;
    *slot = Some(next.to_string().into_bytes());
    Ok(next)
}

/// Any [`ConcurrentMap`] is an inline-completing [`AsyncKv`].
pub struct LockedKv<M> {
    map: M,
    name: &'static str,
}

impl<M: ConcurrentMap<Vec<u8>, Vec<u8>> + 'static> LockedKv<M> {
    pub fn new(map: M, name: &'static str) -> Self {
        LockedKv { map, name }
    }
}

impl<M: ConcurrentMap<Vec<u8>, Vec<u8>> + 'static> AsyncKv for LockedKv<M> {
    fn get(&self, key: &[u8], cb: GetCb) {
        // Borrow-based: the callback renders under the shard's read lock,
        // so the value is copied exactly once, shard → wire buffer, with
        // no owned intermediate. The callback must not touch the map
        // (engine completions render into a connection-local spool).
        self.map.with_get::<[u8], _, _>(key, |v| cb.call(v.map(|v| &v[..])));
    }

    fn put(&self, key: &[u8], val: &[u8], cb: AckCb) {
        cb.call(self.map.insert(key.to_vec(), val.to_vec()).is_some());
    }

    fn del(&self, key: &[u8], cb: AckCb) {
        cb.call(self.map.remove::<[u8]>(key).is_some());
    }

    fn exists(&self, key: &[u8], cb: AckCb) {
        // Presence check without cloning the value out and — on the
        // RwLock-based baselines — without the write lock a read-modify-
        // write path would take (EXISTS is read-only and must scale like
        // the read it is).
        cb.call(self.map.contains::<[u8]>(key));
    }

    fn incr(&self, key: &[u8], delta: i64, cb: IncrCb) {
        cb.call(self.map.entry_update(key.to_vec(), &mut |slot| incr_slot(slot, delta)));
    }

    fn flush_all(&self, cb: FlushCb) {
        self.map.clear();
        cb.call();
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

/// One shard of the delegated table.
pub type KvShard = OaTable<Vec<u8>, Vec<u8>>;

/// The Trust\<T\>-backed store: one entrusted [`KvShard`] per trustee.
pub struct TrustKv {
    shards: Vec<Trust<KvShard>>,
}

impl TrustKv {
    /// Entrust `n_shards` table shards round-robin over `trustees`.
    pub fn new(rt: &Runtime, trustees: &[usize], n_shards: usize) -> Arc<TrustKv> {
        assert!(!trustees.is_empty());
        let mut shards = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let w = trustees[s % trustees.len()];
            let tr = rt.trustee(w);
            // Entrust from this (non-worker) thread via the injected path.
            shards.push(entrust_shard(&tr));
        }
        Arc::new(TrustKv { shards })
    }

    #[inline]
    fn shard(&self, key: &[u8]) -> &Trust<KvShard> {
        let h = fxhash(key) as usize;
        &self.shards[(h >> 8) % self.shards.len()]
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }
}

fn entrust_shard(tr: &TrusteeRef) -> Trust<KvShard> {
    tr.entrust(OaTable::with_capacity(1024))
}

impl AsyncKv for TrustKv {
    fn get(&self, key: &[u8], cb: GetCb) {
        // One-copy GET: the key is copied once (caller → delegation
        // slot), looked up borrowed on the trustee, and the value is
        // written borrowed into the response stream; `cb` sees it
        // borrowed from that stream and copies it straight into the wire
        // buffer. No owned key, no owned value, no per-op allocation.
        self.shard(key).apply_raw_then(
            |t: &mut KvShard, k: &[u8], out: &mut ResponseWriter| {
                out.write_opt_bytes(t.get(k).map(|v| &v[..]))
            },
            key,
            move |r| cb.call(read_opt_bytes(r)),
        );
    }

    fn put(&self, key: &[u8], val: &[u8], cb: AckCb) {
        // Key and value travel as adjacent raw parts (one copy into the
        // slot, no concatenation buffer); the closure re-splits at the
        // captured key length. Overwrites reuse the entry's existing
        // allocation — steady-state PUT traffic allocates nothing.
        let klen = key.len();
        self.shard(key).apply_raw_parts_then(
            move |t: &mut KvShard, args: &[u8], out: &mut ResponseWriter| {
                let (k, v) = args.split_at(klen);
                let existed = match t.get_mut(k) {
                    Some(slot) => {
                        slot.clear();
                        slot.extend_from_slice(v);
                        true
                    }
                    None => {
                        t.insert(k.to_vec(), v.to_vec());
                        false
                    }
                };
                out.write_value(&existed);
            },
            &[key, val],
            move |r| cb.call(read_response::<bool>(r)),
        );
    }

    fn del(&self, key: &[u8], cb: AckCb) {
        self.shard(key).apply_raw_then(
            |t: &mut KvShard, k: &[u8], out: &mut ResponseWriter| {
                out.write_value(&t.remove(k).is_some())
            },
            key,
            move |r| cb.call(read_response::<bool>(r)),
        );
    }

    fn exists(&self, key: &[u8], cb: AckCb) {
        // Trustee-local presence check: no value copy travels back.
        self.shard(key).apply_raw_then(
            |t: &mut KvShard, k: &[u8], out: &mut ResponseWriter| {
                out.write_value(&t.contains_key(k))
            },
            key,
            move |r| cb.call(read_response::<bool>(r)),
        );
    }

    fn incr(&self, key: &[u8], delta: i64, cb: IncrCb) {
        // The read-modify-write runs entirely on the owning trustee, so
        // it is atomic per key with zero synchronization (the paper's
        // core claim applied to a compound operation). INCR rewrites the
        // stored value, so the re-insert owns fresh bytes by design.
        self.shard(key).apply_raw_then(
            move |t: &mut KvShard, k: &[u8], out: &mut ResponseWriter| {
                let mut slot = t.remove(k);
                let r = incr_slot(&mut slot, delta);
                if let Some(v) = slot {
                    t.insert(k.to_vec(), v);
                }
                out.write_value(&r);
            },
            key,
            move |r| cb.call(read_response::<Result<i64, ()>>(r)),
        );
    }

    fn flush_all(&self, cb: FlushCb) {
        // Fan one clear out to every shard's trustee; answer when the
        // last completion lands (all completions run on this worker).
        let remaining = Rc::new(Cell::new(self.shards.len()));
        let done = Rc::new(RefCell::new(Some(cb)));
        for s in &self.shards {
            let remaining = remaining.clone();
            let done = done.clone();
            s.apply_then(
                |t| t.clear(),
                move |_| {
                    remaining.set(remaining.get() - 1);
                    if remaining.get() == 0 {
                        if let Some(cb) = done.borrow_mut().take() {
                            cb.call();
                        }
                    }
                },
            );
        }
    }

    fn len(&self) -> usize {
        // Diagnostic: blocking sum over shards (from a non-worker thread
        // this takes the injected path).
        self.shards.iter().map(|s| s.apply(|t| t.len() as u64) as usize).sum()
    }

    fn name(&self) -> &'static str {
        "trust"
    }
}

/// Backend selector used by the server config and the benches.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Trust<T>-delegated shards; `shards` tables spread over the
    /// runtime's trustee workers.
    Trust { shards: usize },
    /// Sharded HashMap + Mutex (512 shards).
    Mutex,
    /// Sharded HashMap + RwLock (512 shards).
    RwLock,
    /// SwiftMap (the Dashmap stand-in).
    Swift,
}

impl BackendKind {
    pub fn from_spec(s: &str) -> BackendKind {
        match s {
            "mutex" => BackendKind::Mutex,
            "rwlock" => BackendKind::RwLock,
            "swift" | "dashmap" => BackendKind::Swift,
            other => {
                if let Some(rest) = other.strip_prefix("trust") {
                    let shards = rest.trim_start_matches(':').parse().unwrap_or(0);
                    BackendKind::Trust { shards }
                } else {
                    panic!("unknown backend {other:?} (want trust[:N]|mutex|rwlock|swift)")
                }
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            BackendKind::Trust { shards } => format!("Trust{shards}"),
            BackendKind::Mutex => "Mutex".into(),
            BackendKind::RwLock => "RwLock".into(),
            BackendKind::Swift => "Dashmap-like".into(),
        }
    }

    /// Instantiate. `trustees` lists worker ids hosting shards (Trust only).
    pub fn build(&self, rt: &Runtime, trustees: &[usize]) -> Arc<dyn AsyncKv> {
        match self {
            BackendKind::Trust { shards } => {
                let n = if *shards == 0 { trustees.len() } else { *shards };
                TrustKv::new(rt, trustees, n)
            }
            BackendKind::Mutex => Arc::new(LockedKv::new(ShardedMutexMap::new(512), "mutex")),
            BackendKind::RwLock => Arc::new(LockedKv::new(ShardedRwMap::new(512), "rwlock")),
            BackendKind::Swift => Arc::new(LockedKv::new(SwiftMap::new(64), "swift")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn exercise_backend(kv: Arc<dyn AsyncKv>, rt: &Runtime) {
        // Run ops from a worker fiber so Trust completions can flow.
        let kv2 = kv.clone();
        let worker = rt.workers() - 1;
        rt.block_on(worker, move || {
            let done = Arc::new(AtomicUsize::new(0));
            for i in 0..50u64 {
                let d = done.clone();
                kv2.put(
                    &format!("k{i}").into_bytes(),
                    &format!("v{i}").into_bytes(),
                    AckCb::new(move |existed| {
                        assert!(!existed);
                        d.fetch_add(1, Ordering::Relaxed);
                    }),
                );
            }
            // Drain: wait until all callbacks ran (yield lets poll run).
            while done.load(Ordering::Relaxed) != 50 {
                crate::fiber::yield_now();
            }
            // Overwrites must report the existing key (and, on Trust,
            // reuse the entry in place).
            let over = Arc::new(AtomicUsize::new(0));
            for i in 0..10u64 {
                let o = over.clone();
                kv2.put(
                    &format!("k{i}").into_bytes(),
                    &format!("V{i}").into_bytes(),
                    AckCb::new(move |existed| {
                        assert!(existed);
                        o.fetch_add(1, Ordering::Relaxed);
                    }),
                );
            }
            while over.load(Ordering::Relaxed) != 10 {
                crate::fiber::yield_now();
            }
            let got = Arc::new(AtomicUsize::new(0));
            for i in 0..50u64 {
                let g = got.clone();
                let want = if i < 10 {
                    format!("V{i}").into_bytes()
                } else {
                    format!("v{i}").into_bytes()
                };
                kv2.get(
                    &format!("k{i}").into_bytes(),
                    GetCb::new(move |v: Option<&[u8]>| {
                        assert_eq!(v, Some(&want[..]));
                        g.fetch_add(1, Ordering::Relaxed);
                    }),
                );
            }
            while got.load(Ordering::Relaxed) != 50 {
                crate::fiber::yield_now();
            }
            let deleted = Arc::new(AtomicUsize::new(0));
            for i in 0..25u64 {
                let d = deleted.clone();
                kv2.del(
                    &format!("k{i}").into_bytes(),
                    AckCb::new(move |e| {
                        assert!(e);
                        d.fetch_add(1, Ordering::Relaxed);
                    }),
                );
            }
            while deleted.load(Ordering::Relaxed) != 25 {
                crate::fiber::yield_now();
            }
        });
        assert_eq!(kv.len(), 25);
    }

    #[test]
    fn trust_backend_end_to_end() {
        let rt = Runtime::builder().workers(3).build();
        let kv = BackendKind::Trust { shards: 4 }.build(&rt, &[0, 1]);
        assert_eq!(kv.name(), "trust");
        exercise_backend(kv, &rt);
        rt.shutdown();
    }

    #[test]
    fn lock_backends_end_to_end() {
        let rt = Runtime::builder().workers(2).build();
        for kind in [BackendKind::Mutex, BackendKind::RwLock, BackendKind::Swift] {
            let kv = kind.build(&rt, &[]);
            exercise_backend(kv, &rt);
        }
        rt.shutdown();
    }

    fn exercise_redis_ops(kv: Arc<dyn AsyncKv>, rt: &Runtime) {
        let kv2 = kv.clone();
        let worker = rt.workers() - 1;
        rt.block_on(worker, move || {
            let steps = Arc::new(AtomicUsize::new(0));
            // INCR on a missing key starts from 0.
            let s = steps.clone();
            kv2.incr(
                b"ctr",
                5,
                IncrCb::new(move |r| {
                    assert_eq!(r, Ok(5));
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 1 {
                crate::fiber::yield_now();
            }
            // INCR again: reads the stored ASCII value back.
            let s = steps.clone();
            kv2.incr(
                b"ctr",
                2,
                IncrCb::new(move |r| {
                    assert_eq!(r, Ok(7));
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 2 {
                crate::fiber::yield_now();
            }
            // Non-integer value: an error, and the entry is untouched.
            let s = steps.clone();
            kv2.put(
                b"text",
                b"not-a-number",
                AckCb::new(move |_| {
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 3 {
                crate::fiber::yield_now();
            }
            let s = steps.clone();
            kv2.incr(
                b"text",
                1,
                IncrCb::new(move |r| {
                    assert_eq!(r, Err(()));
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 4 {
                crate::fiber::yield_now();
            }
            let s = steps.clone();
            kv2.get(
                b"text",
                GetCb::new(move |v: Option<&[u8]>| {
                    assert_eq!(v, Some(&b"not-a-number"[..]));
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 5 {
                crate::fiber::yield_now();
            }
            // EXISTS without copying: hit then miss.
            let s = steps.clone();
            kv2.exists(
                b"ctr",
                AckCb::new(move |e| {
                    assert!(e);
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            let s = steps.clone();
            kv2.exists(
                b"nope",
                AckCb::new(move |e| {
                    assert!(!e);
                    s.fetch_add(1, Ordering::Relaxed);
                }),
            );
            while steps.load(Ordering::Relaxed) != 7 {
                crate::fiber::yield_now();
            }
            // FLUSHALL empties every shard.
            let s = steps.clone();
            kv2.flush_all(FlushCb::new(move || {
                s.fetch_add(1, Ordering::Relaxed);
            }));
            while steps.load(Ordering::Relaxed) != 8 {
                crate::fiber::yield_now();
            }
        });
        assert_eq!(kv.len(), 0, "flush_all must empty the store");
    }

    #[test]
    fn trust_backend_redis_ops() {
        let rt = Runtime::builder().workers(3).build();
        let kv = BackendKind::Trust { shards: 4 }.build(&rt, &[0, 1]);
        exercise_redis_ops(kv, &rt);
        rt.shutdown();
    }

    #[test]
    fn lock_backends_redis_ops() {
        let rt = Runtime::builder().workers(2).build();
        for kind in [BackendKind::Mutex, BackendKind::RwLock, BackendKind::Swift] {
            let kv = kind.build(&rt, &[]);
            exercise_redis_ops(kv, &rt);
        }
        rt.shutdown();
    }

    #[test]
    fn default_exists_works_through_borrowed_get() {
        // A backend that does not override exists still answers presence
        // through the borrowed GetCb default (no value copy involved).
        struct GetOnly(LockedKv<SwiftMap<Vec<u8>, Vec<u8>>>);
        impl AsyncKv for GetOnly {
            fn get(&self, key: &[u8], cb: GetCb) {
                self.0.get(key, cb)
            }
            fn put(&self, key: &[u8], val: &[u8], cb: AckCb) {
                self.0.put(key, val, cb)
            }
            fn del(&self, key: &[u8], cb: AckCb) {
                self.0.del(key, cb)
            }
            fn incr(&self, key: &[u8], delta: i64, cb: IncrCb) {
                self.0.incr(key, delta, cb)
            }
            fn flush_all(&self, cb: FlushCb) {
                self.0.flush_all(cb)
            }
            fn len(&self) -> usize {
                self.0.len()
            }
            fn name(&self) -> &'static str {
                "get-only"
            }
        }
        let kv = GetOnly(LockedKv::new(SwiftMap::new(4), "inner"));
        kv.put(b"k", b"v", AckCb::new(|_| {}));
        let hit = std::rc::Rc::new(Cell::new(false));
        let h = hit.clone();
        kv.exists(b"k", AckCb::new(move |e| h.set(e)));
        assert!(hit.get());
        let h = hit.clone();
        kv.exists(b"missing", AckCb::new(move |e| h.set(e)));
        assert!(!hit.get());
    }

    #[test]
    fn callback_sizes_nest_inside_channel_completions() {
        use crate::channel::{read_opt_bytes, Completion, COMPLETION_INLINE_BYTES};
        // The allocation-free chain depends on sizes nesting: a backend
        // callback (40-byte inline) must be exactly 64 bytes so the
        // channel completion that captures one (64-byte inline) still
        // stores it inline. If a field is added to the generated structs,
        // this test catches the silent heap fallback it would cause.
        assert_eq!(std::mem::size_of::<GetCb>(), 64);
        assert_eq!(std::mem::size_of::<AckCb>(), 64);
        assert_eq!(std::mem::size_of::<IncrCb>(), 64);
        assert!(std::mem::size_of::<GetCb>() <= COMPLETION_INLINE_BYTES);
        let cb = GetCb::new(|_: Option<&[u8]>| {});
        assert!(!cb.was_boxed());
        let c = Completion::new(move |r: &mut crate::codec::WireReader<'_>| {
            cb.call(read_opt_bytes(r))
        });
        assert!(
            !c.was_boxed(),
            "a completion capturing one backend callback must store inline"
        );
        drop(c);
    }

    #[test]
    fn incr_slot_semantics() {
        let mut slot = None;
        assert_eq!(incr_slot(&mut slot, 1), Ok(1));
        assert_eq!(slot.as_deref(), Some(&b"1"[..]));
        assert_eq!(incr_slot(&mut slot, 41), Ok(42));
        assert_eq!(slot.as_deref(), Some(&b"42"[..]));
        let mut bad = Some(b"xyz".to_vec());
        assert_eq!(incr_slot(&mut bad, 1), Err(()));
        assert_eq!(bad.as_deref(), Some(&b"xyz"[..]), "error leaves slot untouched");
        let mut max = Some(i64::MAX.to_string().into_bytes());
        assert_eq!(incr_slot(&mut max, 1), Err(()), "overflow is an error");
    }

    #[test]
    fn backend_spec_parsing() {
        assert_eq!(BackendKind::from_spec("mutex"), BackendKind::Mutex);
        assert_eq!(BackendKind::from_spec("rwlock"), BackendKind::RwLock);
        assert_eq!(BackendKind::from_spec("swift"), BackendKind::Swift);
        assert_eq!(BackendKind::from_spec("trust:16"), BackendKind::Trust { shards: 16 });
        assert_eq!(BackendKind::from_spec("trust"), BackendKind::Trust { shards: 0 });
    }
}
