//! The TCP key-value store application (paper §6.3): a multi-threaded
//! server where socket workers parse pipelined GET/PUT/DEL requests and
//! dispatch them to a pluggable backend — Trust\<T\>-delegated shards or
//! the lock-based comparators — plus the load-generating client used by
//! the Fig. 8/9 benches.
//!
//! Testbed substitution (DESIGN.md #2): the paper runs client and server
//! on two machines over 100 Gbps Ethernet; here both sides share loopback
//! on one box. The code path (sockets, batching, pipelining, out-of-order
//! responses) is identical.

pub mod backend;
pub mod client;
pub mod proto;
pub mod server;
pub mod store;

/// The socket helpers moved into the protocol-agnostic server core; this
/// re-export keeps the historical `kvstore::netfiber` path working.
pub use crate::server::netfiber;

pub use backend::{install_store_maintenance, AsyncKv, BackendKind, LockedItemKv, TrustKv};
pub use client::{key_bytes, run_load, LoadConfig, LoadStats};
pub use netfiber::NetPolicy;
pub use server::{KvProtocol, KvServer, KvServerConfig};
pub use store::{ItemShard, StoreClock, StoreConfig, StoreStats};
