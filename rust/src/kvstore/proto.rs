//! Binary wire protocol for the TCP key-value store (§6.3).
//!
//! Requests and responses carry a 64-bit request id so the server can send
//! responses **out of order** and the client can match them ("the client
//! accepts responses out-of-order, to minimize waiting"). Frames:
//!
//! ```text
//! request:  [u32 frame_len][u64 id][u8 op][u16 key_len][key][u32 val_len][val]
//! response: [u32 frame_len][u64 id][u8 status][u32 val_len][val]
//! ```
//!
//! `frame_len` counts the bytes after itself. Parsing is incremental over a
//! growable buffer (sockets deliver partial frames).

pub const OP_GET: u8 = 0;
pub const OP_PUT: u8 = 1;
pub const OP_DEL: u8 = 2;

pub const ST_OK: u8 = 0;
pub const ST_NOT_FOUND: u8 = 1;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub op: u8,
    pub key: Vec<u8>,
    pub val: Vec<u8>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    pub id: u64,
    pub status: u8,
    pub val: Vec<u8>,
}

/// Append an encoded request to `out`.
pub fn write_request(out: &mut Vec<u8>, id: u64, op: u8, key: &[u8], val: &[u8]) {
    let frame_len = 8 + 1 + 2 + key.len() + 4 + val.len();
    out.extend_from_slice(&(frame_len as u32).to_le_bytes());
    out.extend_from_slice(&id.to_le_bytes());
    out.push(op);
    out.extend_from_slice(&(key.len() as u16).to_le_bytes());
    out.extend_from_slice(key);
    out.extend_from_slice(&(val.len() as u32).to_le_bytes());
    out.extend_from_slice(val);
}

/// Append an encoded response to `out`.
pub fn write_response(out: &mut Vec<u8>, id: u64, status: u8, val: &[u8]) {
    let frame_len = 8 + 1 + 4 + val.len();
    out.extend_from_slice(&(frame_len as u32).to_le_bytes());
    out.extend_from_slice(&id.to_le_bytes());
    out.push(status);
    out.extend_from_slice(&(val.len() as u32).to_le_bytes());
    out.extend_from_slice(val);
}

/// Incremental frame scanner over a receive buffer. `consumed` is advanced
/// past fully parsed frames; callers compact the buffer when convenient.
pub struct FrameCursor {
    pub consumed: usize,
}

impl FrameCursor {
    pub fn new() -> Self {
        FrameCursor { consumed: 0 }
    }

    fn next_frame<'a>(&mut self, buf: &'a [u8]) -> Option<&'a [u8]> {
        let rest = &buf[self.consumed..];
        if rest.len() < 4 {
            return None;
        }
        let frame_len = u32::from_le_bytes(rest[..4].try_into().unwrap()) as usize;
        if rest.len() < 4 + frame_len {
            return None;
        }
        let frame = &rest[4..4 + frame_len];
        self.consumed += 4 + frame_len;
        Some(frame)
    }

    /// Parse the next complete request, if any.
    pub fn next_request(&mut self, buf: &[u8]) -> Option<Request> {
        let f = self.next_frame(buf)?;
        assert!(f.len() >= 15, "malformed request frame");
        let id = u64::from_le_bytes(f[0..8].try_into().unwrap());
        let op = f[8];
        let key_len = u16::from_le_bytes(f[9..11].try_into().unwrap()) as usize;
        let key = f[11..11 + key_len].to_vec();
        let off = 11 + key_len;
        let val_len = u32::from_le_bytes(f[off..off + 4].try_into().unwrap()) as usize;
        let val = f[off + 4..off + 4 + val_len].to_vec();
        Some(Request { id, op, key, val })
    }

    /// Parse the next complete response, if any.
    pub fn next_response(&mut self, buf: &[u8]) -> Option<Response> {
        let f = self.next_frame(buf)?;
        assert!(f.len() >= 13, "malformed response frame");
        let id = u64::from_le_bytes(f[0..8].try_into().unwrap());
        let status = f[8];
        let val_len = u32::from_le_bytes(f[9..13].try_into().unwrap()) as usize;
        let val = f[13..13 + val_len].to_vec();
        Some(Response { id, status, val })
    }
}

impl Default for FrameCursor {
    fn default() -> Self {
        Self::new()
    }
}

/// Compact a receive buffer after parsing (drop consumed prefix).
pub fn compact(buf: &mut Vec<u8>, cursor: &mut FrameCursor) {
    if cursor.consumed > 0 {
        buf.drain(..cursor.consumed);
        cursor.consumed = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::check;

    #[test]
    fn request_roundtrip() {
        let mut buf = Vec::new();
        write_request(&mut buf, 7, OP_PUT, b"key1", b"value-bytes");
        let mut c = FrameCursor::new();
        let r = c.next_request(&buf).unwrap();
        assert_eq!(r, Request { id: 7, op: OP_PUT, key: b"key1".to_vec(), val: b"value-bytes".to_vec() });
        assert_eq!(c.consumed, buf.len());
        assert!(c.next_request(&buf).is_none());
    }

    #[test]
    fn response_roundtrip() {
        let mut buf = Vec::new();
        write_response(&mut buf, 9, ST_OK, b"v");
        write_response(&mut buf, 10, ST_NOT_FOUND, b"");
        let mut c = FrameCursor::new();
        assert_eq!(c.next_response(&buf).unwrap().id, 9);
        let r2 = c.next_response(&buf).unwrap();
        assert_eq!((r2.id, r2.status), (10, ST_NOT_FOUND));
    }

    #[test]
    fn partial_frames_wait() {
        let mut buf = Vec::new();
        write_request(&mut buf, 1, OP_GET, b"abc", b"");
        let full = buf.clone();
        for cut in 0..full.len() {
            let mut c = FrameCursor::new();
            assert!(c.next_request(&full[..cut]).is_none(), "cut={cut}");
        }
    }

    #[test]
    fn multiple_frames_in_one_buffer() {
        let mut buf = Vec::new();
        for i in 0..5u64 {
            write_request(&mut buf, i, OP_GET, format!("k{i}").as_bytes(), b"");
        }
        let mut c = FrameCursor::new();
        for i in 0..5u64 {
            assert_eq!(c.next_request(&buf).unwrap().id, i);
        }
        assert!(c.next_request(&buf).is_none());
    }

    #[test]
    fn compact_resets() {
        let mut buf = Vec::new();
        write_request(&mut buf, 1, OP_GET, b"k", b"");
        let tail_start = buf.len();
        write_request(&mut buf, 2, OP_GET, b"k2", b"");
        let mut c = FrameCursor::new();
        c.next_request(&buf).unwrap();
        compact(&mut buf, &mut c);
        assert_eq!(c.consumed, 0);
        assert_eq!(buf.len(), tail_start + 1 /*k2 longer*/ + 0);
        assert_eq!(c.next_request(&buf).unwrap().id, 2);
    }

    #[test]
    fn prop_roundtrip_random_payloads() {
        check::<(u64, Vec<u8>, Vec<u8>)>("kv-proto", 150, |(id, key, val)| {
            if key.len() > 60_000 {
                return true;
            }
            let mut buf = Vec::new();
            write_request(&mut buf, *id, OP_PUT, key, val);
            let mut c = FrameCursor::new();
            match c.next_request(&buf) {
                Some(r) => r.id == *id && &r.key == key && &r.val == val,
                None => false,
            }
        });
    }
}
