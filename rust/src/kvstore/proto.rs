//! Binary wire protocol for the TCP key-value store (§6.3).
//!
//! Requests and responses carry a 64-bit request id so the server can send
//! responses **out of order** and the client can match them ("the client
//! accepts responses out-of-order, to minimize waiting"). Frames:
//!
//! ```text
//! request:  [u32 frame_len][u64 id][u8 op][u16 key_len][key][u32 val_len][val]
//! response: [u32 frame_len][u64 id][u8 status][u32 val_len][val]
//! ```
//!
//! `frame_len` counts the bytes after itself. Parsing is incremental over a
//! growable buffer (sockets deliver partial frames), and **total**: a
//! malformed or hostile byte stream yields [`ProtoError`], never a panic —
//! the server must survive arbitrary client bytes (ROADMAP's
//! heavy-traffic north star). [`MAX_FRAME_LEN`] bounds the declared frame
//! length up front so a hostile 4 GiB `frame_len` cannot balloon the
//! receive buffer while the parser "waits" for the rest of the frame.

pub const OP_GET: u8 = 0;
pub const OP_PUT: u8 = 1;
pub const OP_DEL: u8 = 2;

pub const ST_OK: u8 = 0;
pub const ST_NOT_FOUND: u8 = 1;
/// The request was syntactically valid framing but semantically bad
/// (unknown op). The server answers with this status and closes.
pub const ST_BAD_REQUEST: u8 = 2;
/// The server is shedding load (queue depth over its watermark or
/// deadline pressure). The request was *not* executed; the connection
/// stays open and the client may retry later.
pub const ST_OVERLOADED: u8 = 3;

/// Hard ceiling on `frame_len`. Generous for the workloads here (64 KiB
/// keys + values up to ~1 MiB) while keeping a hostile length field from
/// committing the server to gigabytes of buffering.
pub const MAX_FRAME_LEN: usize = 1 << 20;

/// A malformed frame. The stream is not trustworthy past this point:
/// servers respond/close, clients bail out.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtoError {
    /// Declared `frame_len` exceeds [`MAX_FRAME_LEN`].
    FrameTooLarge { frame_len: usize },
    /// Frame body does not match its declared lengths.
    Malformed { reason: &'static str },
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::FrameTooLarge { frame_len } => {
                write!(f, "frame_len {frame_len} exceeds MAX_FRAME_LEN {MAX_FRAME_LEN}")
            }
            ProtoError::Malformed { reason } => write!(f, "malformed frame: {reason}"),
        }
    }
}

impl std::error::Error for ProtoError {}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub op: u8,
    pub key: Vec<u8>,
    pub val: Vec<u8>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Response {
    pub id: u64,
    pub status: u8,
    pub val: Vec<u8>,
}

/// Append an encoded request to `out`.
pub fn write_request(out: &mut Vec<u8>, id: u64, op: u8, key: &[u8], val: &[u8]) {
    let frame_len = 8 + 1 + 2 + key.len() + 4 + val.len();
    out.extend_from_slice(&(frame_len as u32).to_le_bytes());
    out.extend_from_slice(&id.to_le_bytes());
    out.push(op);
    out.extend_from_slice(&(key.len() as u16).to_le_bytes());
    out.extend_from_slice(key);
    out.extend_from_slice(&(val.len() as u32).to_le_bytes());
    out.extend_from_slice(val);
}

/// Append an encoded response to `out`.
pub fn write_response(out: &mut Vec<u8>, id: u64, status: u8, val: &[u8]) {
    let frame_len = 8 + 1 + 4 + val.len();
    out.extend_from_slice(&(frame_len as u32).to_le_bytes());
    out.extend_from_slice(&id.to_le_bytes());
    out.push(status);
    out.extend_from_slice(&(val.len() as u32).to_le_bytes());
    out.extend_from_slice(val);
}

/// Incremental frame scanner over a receive buffer. `consumed` is advanced
/// past fully parsed frames; callers compact the buffer when convenient.
///
/// Totality: `Ok(None)` means "wait for more bytes", `Err` means the
/// stream is malformed. A [`ProtoError::Malformed`] frame *was* consumed,
/// so a tolerant caller may keep scanning (re-sync at the next frame
/// boundary); [`ProtoError::FrameTooLarge`] consumes nothing and repeats —
/// the only safe continuation is closing the connection.
pub struct FrameCursor {
    pub consumed: usize,
}

impl FrameCursor {
    pub fn new() -> Self {
        FrameCursor { consumed: 0 }
    }

    fn next_frame<'a>(&mut self, buf: &'a [u8]) -> Result<Option<&'a [u8]>, ProtoError> {
        let rest = &buf[self.consumed..];
        if rest.len() < 4 {
            return Ok(None);
        }
        let frame_len = u32::from_le_bytes(rest[..4].try_into().unwrap()) as usize;
        if frame_len > MAX_FRAME_LEN {
            // Reject *before* waiting for the body: a hostile length must
            // not commit us to buffering it.
            return Err(ProtoError::FrameTooLarge { frame_len });
        }
        if rest.len() < 4 + frame_len {
            return Ok(None);
        }
        let frame = &rest[4..4 + frame_len];
        self.consumed += 4 + frame_len;
        Ok(Some(frame))
    }

    /// Parse the next complete request, if any.
    pub fn next_request(&mut self, buf: &[u8]) -> Result<Option<Request>, ProtoError> {
        let Some(f) = self.next_frame(buf)? else {
            return Ok(None);
        };
        if f.len() < 15 {
            return Err(ProtoError::Malformed { reason: "request frame shorter than header" });
        }
        let id = u64::from_le_bytes(f[0..8].try_into().unwrap());
        let op = f[8];
        let key_len = u16::from_le_bytes(f[9..11].try_into().unwrap()) as usize;
        let Some(body_len) = f.len().checked_sub(15 + key_len) else {
            return Err(ProtoError::Malformed { reason: "key_len exceeds frame body" });
        };
        let off = 11 + key_len;
        let val_len = u32::from_le_bytes(f[off..off + 4].try_into().unwrap()) as usize;
        if val_len != body_len {
            return Err(ProtoError::Malformed { reason: "val_len disagrees with frame_len" });
        }
        let key = f[11..off].to_vec();
        let val = f[off + 4..].to_vec();
        Ok(Some(Request { id, op, key, val }))
    }

    /// Parse the next complete response, if any.
    pub fn next_response(&mut self, buf: &[u8]) -> Result<Option<Response>, ProtoError> {
        let Some(f) = self.next_frame(buf)? else {
            return Ok(None);
        };
        if f.len() < 13 {
            return Err(ProtoError::Malformed { reason: "response frame shorter than header" });
        }
        let id = u64::from_le_bytes(f[0..8].try_into().unwrap());
        let status = f[8];
        let val_len = u32::from_le_bytes(f[9..13].try_into().unwrap()) as usize;
        if val_len != f.len() - 13 {
            return Err(ProtoError::Malformed { reason: "val_len disagrees with frame_len" });
        }
        let val = f[13..].to_vec();
        Ok(Some(Response { id, status, val }))
    }
}

impl Default for FrameCursor {
    fn default() -> Self {
        Self::new()
    }
}

/// Compact a receive buffer after parsing (drop consumed prefix).
pub fn compact(buf: &mut Vec<u8>, cursor: &mut FrameCursor) {
    if cursor.consumed > 0 {
        buf.drain(..cursor.consumed);
        cursor.consumed = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::check;

    #[test]
    fn request_roundtrip() {
        let mut buf = Vec::new();
        write_request(&mut buf, 7, OP_PUT, b"key1", b"value-bytes");
        let mut c = FrameCursor::new();
        let r = c.next_request(&buf).unwrap().unwrap();
        assert_eq!(r, Request { id: 7, op: OP_PUT, key: b"key1".to_vec(), val: b"value-bytes".to_vec() });
        assert_eq!(c.consumed, buf.len());
        assert!(c.next_request(&buf).unwrap().is_none());
    }

    #[test]
    fn response_roundtrip() {
        let mut buf = Vec::new();
        write_response(&mut buf, 9, ST_OK, b"v");
        write_response(&mut buf, 10, ST_NOT_FOUND, b"");
        let mut c = FrameCursor::new();
        assert_eq!(c.next_response(&buf).unwrap().unwrap().id, 9);
        let r2 = c.next_response(&buf).unwrap().unwrap();
        assert_eq!((r2.id, r2.status), (10, ST_NOT_FOUND));
    }

    #[test]
    fn partial_frames_wait() {
        let mut buf = Vec::new();
        write_request(&mut buf, 1, OP_GET, b"abc", b"");
        let full = buf.clone();
        for cut in 0..full.len() {
            let mut c = FrameCursor::new();
            assert!(c.next_request(&full[..cut]).unwrap().is_none(), "cut={cut}");
        }
    }

    #[test]
    fn multiple_frames_in_one_buffer() {
        let mut buf = Vec::new();
        for i in 0..5u64 {
            write_request(&mut buf, i, OP_GET, format!("k{i}").as_bytes(), b"");
        }
        let mut c = FrameCursor::new();
        for i in 0..5u64 {
            assert_eq!(c.next_request(&buf).unwrap().unwrap().id, i);
        }
        assert!(c.next_request(&buf).unwrap().is_none());
    }

    #[test]
    fn compact_resets() {
        let mut buf = Vec::new();
        write_request(&mut buf, 1, OP_GET, b"k", b"");
        let tail_start = buf.len();
        write_request(&mut buf, 2, OP_GET, b"k2", b"");
        let mut c = FrameCursor::new();
        c.next_request(&buf).unwrap().unwrap();
        compact(&mut buf, &mut c);
        assert_eq!(c.consumed, 0);
        assert_eq!(buf.len(), tail_start + 1 /*k2 longer*/ + 0);
        assert_eq!(c.next_request(&buf).unwrap().unwrap().id, 2);
    }

    #[test]
    fn oversized_frame_len_is_rejected_up_front() {
        // A hostile 4 GiB frame_len must be an error immediately — not an
        // Ok(None) that leaves the server buffering forever.
        let buf = u32::MAX.to_le_bytes().to_vec();
        let mut c = FrameCursor::new();
        match c.next_request(&buf) {
            Err(ProtoError::FrameTooLarge { frame_len }) => {
                assert_eq!(frame_len, u32::MAX as usize);
            }
            other => panic!("expected FrameTooLarge, got {other:?}"),
        }
        assert_eq!(c.consumed, 0, "nothing consumed: caller closes");
    }

    #[test]
    fn truncated_and_lying_length_fields_are_errors_not_panics() {
        // frame_len says 10 but the body is only a 9-byte header stub.
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 10]);
        let mut c = FrameCursor::new();
        assert!(matches!(c.next_request(&buf), Err(ProtoError::Malformed { .. })));

        // key_len pointing past the end of the frame.
        let mut buf = Vec::new();
        write_request(&mut buf, 1, OP_GET, b"abcd", b"");
        buf[13] = 0xFF; // key_len low byte: 4 -> 0xFF
        let mut c = FrameCursor::new();
        assert!(matches!(c.next_request(&buf), Err(ProtoError::Malformed { .. })));

        // val_len disagreeing with frame_len.
        let mut buf = Vec::new();
        write_request(&mut buf, 1, OP_PUT, b"k", b"vvvv");
        let val_len_off = 4 + 8 + 1 + 2 + 1; // frame_len + id + op + key_len + key
        buf[val_len_off] = 3; // claims 3, body carries 4
        let mut c = FrameCursor::new();
        assert!(matches!(c.next_request(&buf), Err(ProtoError::Malformed { .. })));
    }

    #[test]
    fn malformed_frame_is_consumed_so_scanning_resyncs() {
        // A bad frame followed by a good one: the error consumes the bad
        // frame, so a tolerant scanner picks up the good frame next.
        let mut buf = Vec::new();
        buf.extend_from_slice(&5u32.to_le_bytes());
        buf.extend_from_slice(&[0u8; 5]); // too short for a request header
        write_request(&mut buf, 77, OP_GET, b"k", b"");
        let mut c = FrameCursor::new();
        assert!(c.next_request(&buf).is_err());
        let r = c.next_request(&buf).unwrap().unwrap();
        assert_eq!(r.id, 77);
    }

    #[test]
    fn prop_roundtrip_random_payloads() {
        check::<(u64, Vec<u8>, Vec<u8>)>("kv-proto", 150, |(id, key, val)| {
            if key.len() > 60_000 {
                return true;
            }
            let mut buf = Vec::new();
            write_request(&mut buf, *id, OP_PUT, key, val);
            let mut c = FrameCursor::new();
            match c.next_request(&buf) {
                Ok(Some(r)) => r.id == *id && &r.key == key && &r.val == val,
                _ => false,
            }
        });
    }

    /// Drive a cursor over `buf` until it stalls, errors terminally, or
    /// parses everything; panics (the property under test) propagate.
    fn scan_to_exhaustion(buf: &[u8]) {
        let mut c = FrameCursor::new();
        loop {
            match c.next_request(buf) {
                Ok(Some(_)) => continue,
                Ok(None) => break,
                Err(ProtoError::Malformed { .. }) => continue, // re-sync
                Err(ProtoError::FrameTooLarge { .. }) => break, // reject
            }
        }
        let mut c = FrameCursor::new();
        loop {
            match c.next_response(buf) {
                Ok(Some(_)) => continue,
                Ok(None) => break,
                Err(ProtoError::Malformed { .. }) => continue,
                Err(ProtoError::FrameTooLarge { .. }) => break,
            }
        }
    }

    #[test]
    fn prop_cursor_total_on_arbitrary_bytes() {
        // Feeding any byte stream through the cursor never panics: it
        // parses, waits, re-syncs, or rejects.
        check::<Vec<u8>>("kv-proto-garbage", 300, |bytes| {
            scan_to_exhaustion(bytes);
            true
        });
    }

    #[test]
    fn prop_cursor_total_on_corrupted_valid_streams() {
        // Frame a few valid requests, then bit-flip one byte and truncate
        // at an arbitrary point: still no panic, and the cursor never
        // consumes past the end of the buffer.
        check::<(u64, Vec<u8>, Vec<u8>, usize, usize)>(
            "kv-proto-bitflip",
            300,
            |(id, key, val, flip_at, cut)| {
                if key.len() > 60_000 {
                    return true;
                }
                let mut buf = Vec::new();
                write_request(&mut buf, *id, OP_GET, key, &[]);
                write_request(&mut buf, id.wrapping_add(1), OP_PUT, key, val);
                if !buf.is_empty() {
                    let i = flip_at % buf.len();
                    buf[i] ^= ((flip_at >> 8) as u8) | 1; // flip >= one bit
                }
                buf.truncate(cut % (buf.len() + 1));
                scan_to_exhaustion(&buf);
                let mut c = FrameCursor::new();
                while let Ok(Some(_)) = c.next_request(&buf) {}
                c.consumed <= buf.len()
            },
        );
    }
}
