//! The unified item store: one shard type with real cache semantics —
//! item metadata (flags, expiry deadline, recency stamp), a per-shard
//! byte budget with LRU eviction, lazy-on-access expiry, and an
//! incremental expiry sweep — shared by **all four** KV backends.
//!
//! This is the storage half of the paper's memcached argument (§7):
//! "memory allocation, LRU updates as well as table writes, all of which
//! involve synchronization in a lock-based design" become trustee-local
//! when a shard is entrusted. [`ItemShard`] keeps every auxiliary
//! structure (recency clock, byte accounting, expiry bookkeeping) *next
//! to* the table it describes, so:
//!
//! - on the Trust backend each shard lives on its owning trustee and all
//!   of this is plain single-threaded mutation — zero synchronization,
//!   zero atomics;
//! - on the `mutex`/`rwlock`/`swift` baselines the same shard sits
//!   behind a lock, and every GET now pays the write-side lock for its
//!   LRU bump and lazy expiry — exactly the synchronization profile the
//!   paper ascribes to stock memcached.
//!
//! Recency is a **shard-local clock** (`access` counter stamped onto
//! items), not an intrusive list: the open-addressing table relocates
//! entries on insert/remove (robin hood + backward shift), so stable
//! links would need a separate slab. Eviction scans for the minimum
//! stamp — O(capacity) per victim, paid only when over budget (the E18
//! bench records that cost). Expiry is enforced three ways, all
//! deterministic: lazily on access (a hit on an expired item reclaims it
//! and reports a miss), on overwrite, and by [`ItemShard::sweep`] — a
//! cursor-carrying incremental scan driven from the runtime's
//! maintenance hook with bounded work per call.

use crate::cmap::OaTable;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Fixed per-entry accounting overhead (table slot + Item header +
/// allocator slack), charged against the shard budget alongside the key
/// and value bytes.
pub const ITEM_OVERHEAD: u64 = 64;

/// Table slots one [`ItemShard::sweep`] call examines — the bounded work
/// quantum of the incremental expiry sweep.
pub const SWEEP_SLOTS: usize = 64;

/// `ttl_ms` query result: the key does not exist (or is expired).
pub const TTL_MISSING: i64 = -2;
/// `ttl_ms` query result: the key exists but carries no expiry.
pub const TTL_NO_EXPIRY: i64 = -1;

// ---------------------------------------------------------------------
// Clock
// ---------------------------------------------------------------------

/// The store's time source, in milliseconds. Real stores measure elapsed
/// time from creation; tests freeze time with [`StoreClock::manual`] and
/// drive it with [`StoreClock::advance`] so expiry and eviction runs are
/// fully deterministic across backends.
pub struct StoreClock {
    epoch: Instant,
    /// `u64::MAX` = real (epoch-elapsed) time; anything else is the
    /// manual clock's current reading.
    manual: AtomicU64,
}

const REAL_CLOCK: u64 = u64::MAX;

impl StoreClock {
    /// Wall-clock store time (milliseconds since store creation).
    pub fn real() -> Arc<StoreClock> {
        Arc::new(StoreClock { epoch: Instant::now(), manual: AtomicU64::new(REAL_CLOCK) })
    }

    /// A frozen, manually-advanced clock (starts at 1 ms so `now + ttl`
    /// can never collide with the "no expiry" sentinel 0).
    pub fn manual() -> Arc<StoreClock> {
        Arc::new(StoreClock { epoch: Instant::now(), manual: AtomicU64::new(1) })
    }

    #[inline]
    pub fn now_ms(&self) -> u64 {
        let m = self.manual.load(Ordering::Relaxed);
        if m == REAL_CLOCK {
            self.epoch.elapsed().as_millis() as u64
        } else {
            m
        }
    }

    /// Advance a manual clock. Panics on a real clock.
    pub fn advance(&self, ms: u64) {
        let prev = self.manual.fetch_add(ms, Ordering::Relaxed);
        assert_ne!(prev, REAL_CLOCK, "StoreClock::advance on a real clock");
    }

    pub fn is_manual(&self) -> bool {
        self.manual.load(Ordering::Relaxed) != REAL_CLOCK
    }
}

// ---------------------------------------------------------------------
// Config + stats
// ---------------------------------------------------------------------

/// Store-wide knobs shared by every backend flavor.
#[derive(Clone)]
pub struct StoreConfig {
    /// Total byte budget for the store (key + value + [`ITEM_OVERHEAD`]
    /// per entry); 0 = unlimited. Backends split it evenly over their
    /// shards ([`StoreConfig::shard_budget`]); a shard exceeding its
    /// slice evicts least-recently-used items until back under.
    pub budget_bytes: u64,
    /// Time source (shared by every shard of the store).
    pub clock: Arc<StoreClock>,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig { budget_bytes: 0, clock: StoreClock::real() }
    }
}

impl StoreConfig {
    pub fn with_budget(budget_bytes: u64) -> StoreConfig {
        StoreConfig { budget_bytes, ..Default::default() }
    }

    /// This store's per-shard budget when split over `n_shards` (0 stays
    /// unlimited; a nonzero budget never rounds down to unlimited).
    pub fn shard_budget(&self, n_shards: usize) -> u64 {
        if self.budget_bytes == 0 {
            0
        } else {
            (self.budget_bytes / n_shards.max(1) as u64).max(1)
        }
    }
}

/// Aggregated store counters (per shard, summed by the backends).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Live entries (expired-but-unswept entries still count until
    /// reclaimed — they occupy memory).
    pub items: u64,
    /// Bytes charged against shard budgets.
    pub store_bytes: u64,
    /// Entries reclaimed to enforce a byte budget.
    pub evictions: u64,
    /// Entries reclaimed because their deadline passed (lazily on
    /// access/overwrite, or by the sweep).
    pub expired_keys: u64,
}

impl StoreStats {
    pub fn merge(&mut self, other: &StoreStats) {
        self.items += other.items;
        self.store_bytes += other.store_bytes;
        self.evictions += other.evictions;
        self.expired_keys += other.expired_keys;
    }

    /// Wire-friendly tuple (for delegated stat reads).
    pub fn to_tuple(self) -> (u64, u64, u64, u64) {
        (self.items, self.store_bytes, self.evictions, self.expired_keys)
    }

    pub fn from_tuple(t: (u64, u64, u64, u64)) -> StoreStats {
        StoreStats { items: t.0, store_bytes: t.1, evictions: t.2, expired_keys: t.3 }
    }
}

// ---------------------------------------------------------------------
// Item + shard
// ---------------------------------------------------------------------

/// One stored item: value bytes plus the metadata the cache semantics
/// need. Everything is plain data mutated under the shard's exclusive
/// access (trustee-local or lock-scoped) — no atomics.
#[derive(Debug)]
pub struct Item {
    pub flags: u32,
    /// Absolute deadline on the store clock (ms); 0 = never expires.
    expires_at_ms: u64,
    /// Recency stamp from the shard's access counter (higher = more
    /// recently used).
    stamp: u64,
    pub data: Vec<u8>,
}

impl Item {
    #[inline]
    fn is_expired(&self, now_ms: u64) -> bool {
        self.expires_at_ms != 0 && self.expires_at_ms <= now_ms
    }
}

/// One shard of the unified item store. All mutating entry points take
/// `&mut self`: the Trust backend entrusts a shard per trustee (plain
/// single-threaded mutation), the lock backends wrap one per lock shard.
pub struct ItemShard {
    table: OaTable<Vec<u8>, Item>,
    clock: Arc<StoreClock>,
    /// Byte budget (0 = unlimited).
    budget: u64,
    /// Shard-local access clock for LRU stamps.
    access: u64,
    bytes: u64,
    evictions: u64,
    expired: u64,
    sweep_cursor: usize,
}

impl ItemShard {
    /// A single shard carrying the whole config budget (single-shard
    /// stores and tests); multi-shard backends use
    /// [`ItemShard::with_budget`] with their [`StoreConfig::shard_budget`]
    /// slice.
    pub fn new(cfg: &StoreConfig) -> ItemShard {
        Self::with_budget(cfg.clock.clone(), cfg.budget_bytes)
    }

    pub fn with_budget(clock: Arc<StoreClock>, budget: u64) -> ItemShard {
        ItemShard {
            table: OaTable::with_capacity(1024),
            clock,
            budget,
            access: 0,
            bytes: 0,
            evictions: 0,
            expired: 0,
            sweep_cursor: 0,
        }
    }

    #[inline]
    fn now(&self) -> u64 {
        self.clock.now_ms()
    }

    #[inline]
    fn entry_cost(key_len: usize, val_len: usize) -> u64 {
        key_len as u64 + val_len as u64 + ITEM_OVERHEAD
    }

    /// Remove the entry in slot `idx` and release its budget charge.
    /// Callers account the *reason* (eviction / expiry / delete).
    fn remove_entry(&mut self, idx: usize) -> Option<(Vec<u8>, Item)> {
        let (k, it) = self.table.remove_at(idx)?;
        self.bytes = self
            .bytes
            .saturating_sub(Self::entry_cost(k.len(), it.data.len()));
        Some((k, it))
    }

    /// Lookup with full cache semantics: bump the LRU stamp on a hit;
    /// reclaim (and miss) on a lazily-discovered expired entry.
    pub fn get(&mut self, key: &[u8]) -> Option<(u32, &[u8])> {
        let now = self.now();
        let idx = self.table.index_of(key)?;
        if self.table.entry_at(idx).unwrap().1.is_expired(now) {
            self.remove_entry(idx);
            self.expired += 1;
            return None;
        }
        self.access += 1;
        let stamp = self.access;
        let (_, it) = self.table.entry_at_mut(idx).unwrap();
        it.stamp = stamp;
        Some((it.flags, &*it.data))
    }

    /// Read-only probe: no LRU bump, no reclamation (EXISTS / TTL — the
    /// read-scaling path on the RwLock baselines). Expired entries are
    /// invisible but stay until a mutating access or the sweep reclaims
    /// them.
    pub fn peek(&self, key: &[u8]) -> Option<(u32, &[u8])> {
        let now = self.now();
        let it = self.table.get(key)?;
        if it.is_expired(now) {
            return None;
        }
        Some((it.flags, &*it.data))
    }

    /// Store `key = val` with `flags` and a relative TTL (`0` = no
    /// expiry, which also *clears* any previous deadline — memcached
    /// `exptime 0` / Redis plain `SET`). Returns whether a live entry
    /// was overwritten. Overwrites reuse the entry's allocation in
    /// place; going over budget evicts LRU victims before returning.
    pub fn set(&mut self, key: &[u8], val: &[u8], flags: u32, ttl_ms: u64) -> bool {
        let now = self.now();
        // Saturating: a hostile wire-supplied TTL must not wrap past the
        // 0 = never sentinel (or panic a trustee in debug builds).
        let expires = if ttl_ms == 0 { 0 } else { now.saturating_add(ttl_ms) };
        self.access += 1;
        let stamp = self.access;
        let existed = match self.table.index_of(key) {
            Some(idx) => {
                let was_expired = self.table.entry_at(idx).unwrap().1.is_expired(now);
                if was_expired {
                    // The old value died of expiry, not replacement.
                    self.expired += 1;
                }
                let old_len;
                {
                    let (_, it) = self.table.entry_at_mut(idx).unwrap();
                    old_len = it.data.len();
                    it.data.clear();
                    it.data.extend_from_slice(val);
                    it.flags = flags;
                    it.expires_at_ms = expires;
                    it.stamp = stamp;
                }
                self.bytes = self.bytes - old_len as u64 + val.len() as u64;
                !was_expired
            }
            None => {
                self.bytes += Self::entry_cost(key.len(), val.len());
                self.table.insert(
                    key.to_vec(),
                    Item { flags, expires_at_ms: expires, stamp, data: val.to_vec() },
                );
                false
            }
        };
        self.evict_to_budget(now);
        existed
    }

    /// Remove `key`; true only when a *live* entry was removed (an
    /// expired one is reclaimed but reported missing, like a GET).
    pub fn del(&mut self, key: &[u8]) -> bool {
        let now = self.now();
        let Some(idx) = self.table.index_of(key) else {
            return false;
        };
        let was_expired = self.table.entry_at(idx).unwrap().1.is_expired(now);
        self.remove_entry(idx);
        if was_expired {
            self.expired += 1;
            false
        } else {
            true
        }
    }

    /// Reset the deadline of a live entry (`ttl_ms` 0 clears it —
    /// memcached `touch 0`). True when the key was live.
    pub fn touch(&mut self, key: &[u8], ttl_ms: u64) -> bool {
        let now = self.now();
        let Some(idx) = self.table.index_of(key) else {
            return false;
        };
        if self.table.entry_at(idx).unwrap().1.is_expired(now) {
            self.remove_entry(idx);
            self.expired += 1;
            return false;
        }
        self.access += 1;
        let stamp = self.access;
        let (_, it) = self.table.entry_at_mut(idx).unwrap();
        it.expires_at_ms = if ttl_ms == 0 { 0 } else { now.saturating_add(ttl_ms) };
        it.stamp = stamp;
        true
    }

    /// Clear the deadline of a live entry (Redis `PERSIST`): true only
    /// when the entry existed *and* had a deadline to clear.
    pub fn persist(&mut self, key: &[u8]) -> bool {
        let now = self.now();
        let Some(idx) = self.table.index_of(key) else {
            return false;
        };
        if self.table.entry_at(idx).unwrap().1.is_expired(now) {
            self.remove_entry(idx);
            self.expired += 1;
            return false;
        }
        let (_, it) = self.table.entry_at_mut(idx).unwrap();
        let had = it.expires_at_ms != 0;
        it.expires_at_ms = 0;
        had
    }

    /// Remaining lifetime in ms: [`TTL_MISSING`] (missing or expired),
    /// [`TTL_NO_EXPIRY`], or the remaining ms (> 0). Read-only.
    pub fn ttl_ms(&self, key: &[u8]) -> i64 {
        let now = self.now();
        match self.table.get(key) {
            None => TTL_MISSING,
            Some(it) if it.is_expired(now) => TTL_MISSING,
            Some(it) if it.expires_at_ms == 0 => TTL_NO_EXPIRY,
            // Clamp: an absurd-but-accepted deadline must not wrap into
            // the negative range (where the sentinels live).
            Some(it) => (it.expires_at_ms - now).min(i64::MAX as u64) as i64,
        }
    }

    /// Redis `INCR` semantics on the item's value: missing (or expired)
    /// counts as 0, a non-integer value or overflow is an error leaving
    /// the entry untouched. Preserves flags and deadline on success.
    pub fn incr(&mut self, key: &[u8], delta: i64) -> Result<i64, ()> {
        use std::io::Write;
        let now = self.now();
        self.access += 1;
        let stamp = self.access;
        let live_idx = match self.table.index_of(key) {
            Some(idx) if self.table.entry_at(idx).unwrap().1.is_expired(now) => {
                self.remove_entry(idx);
                self.expired += 1;
                None
            }
            other => other,
        };
        let next = match live_idx {
            Some(idx) => {
                let (_, it) = self.table.entry_at_mut(idx).unwrap();
                let cur: i64 = std::str::from_utf8(&it.data)
                    .map_err(|_| ())?
                    .parse()
                    .map_err(|_| ())?;
                let next = cur.checked_add(delta).ok_or(())?;
                let old_len = it.data.len();
                it.data.clear();
                write!(it.data, "{next}").expect("write into Vec");
                it.stamp = stamp;
                let new_len = it.data.len();
                self.bytes = self.bytes - old_len as u64 + new_len as u64;
                next
            }
            None => {
                let data = delta.to_string().into_bytes();
                self.bytes += Self::entry_cost(key.len(), data.len());
                self.table.insert(
                    key.to_vec(),
                    Item { flags: 0, expires_at_ms: 0, stamp, data },
                );
                delta
            }
        };
        self.evict_to_budget(now);
        Ok(next)
    }

    /// Enforce the byte budget: reclaim expired entries first, then
    /// least-recently-stamped live ones, until back under. The scan is
    /// O(capacity) per victim — eviction is the deliberate slow path
    /// (EXPERIMENTS.md E18 records its cost under memory pressure).
    fn evict_to_budget(&mut self, now: u64) {
        if self.budget == 0 {
            return;
        }
        while self.bytes > self.budget && !self.table.is_empty() {
            let mut victim: Option<(usize, bool, u64)> = None; // (slot, expired, stamp)
            for idx in 0..self.table.capacity() {
                if let Some((_, it)) = self.table.entry_at(idx) {
                    let expired = it.is_expired(now);
                    let better = match victim {
                        None => true,
                        Some((_, v_expired, v_stamp)) => {
                            (expired && !v_expired)
                                || (expired == v_expired && it.stamp < v_stamp)
                        }
                    };
                    if better {
                        victim = Some((idx, expired, it.stamp));
                    }
                }
            }
            let Some((idx, expired, _)) = victim else { break };
            self.remove_entry(idx);
            if expired {
                self.expired += 1;
            } else {
                self.evictions += 1;
            }
        }
    }

    /// Incremental expiry sweep: advance the shard's cursor over up to
    /// `max_slots` table slots, reclaiming expired entries along the
    /// way. Bounded work per call — the runtime maintenance hook calls
    /// this every few scheduler ticks so unaccessed items still get
    /// reclaimed. Removals re-examine their slot (backward shift may
    /// pull a successor in) and do **not** consume the advance budget,
    /// so `sweep(capacity())` is always one full pass over the table,
    /// however many entries it reclaims. Returns entries reclaimed.
    pub fn sweep(&mut self, max_slots: usize) -> u64 {
        if self.table.is_empty() {
            return 0;
        }
        let now = self.now();
        let cap = self.table.capacity();
        if self.sweep_cursor >= cap {
            self.sweep_cursor = 0;
        }
        let mut reclaimed = 0u64;
        let mut advanced = 0usize;
        while advanced < max_slots.min(cap) {
            let idx = self.sweep_cursor;
            let expired = matches!(
                self.table.entry_at(idx),
                Some((_, it)) if it.is_expired(now)
            );
            if expired {
                self.remove_entry(idx);
                self.expired += 1;
                reclaimed += 1;
                // Backward-shift deletion may have pulled a successor
                // into this slot: re-examine it before advancing.
            } else {
                self.sweep_cursor = (idx + 1) % cap;
                advanced += 1;
            }
        }
        reclaimed
    }

    pub fn clear(&mut self) {
        self.table.clear();
        self.bytes = 0;
    }

    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.len() == 0
    }

    pub fn stats(&self) -> StoreStats {
        StoreStats {
            items: self.table.len() as u64,
            store_bytes: self.bytes,
            evictions: self.evictions,
            expired_keys: self.expired,
        }
    }
}

// ---------------------------------------------------------------------
// Lock adapters (the baselines' shard wrapper)
// ---------------------------------------------------------------------

/// The lock discipline a baseline wraps around each [`ItemShard`]. GETs
/// go through [`ShardLock::write`]: the LRU bump and lazy expiry are
/// mutations, so even the readers-writer baselines pay the exclusive
/// lock on the read path — the synchronization the paper's delegated
/// design removes. Only genuinely read-only probes (EXISTS, TTL) use
/// [`ShardLock::read`].
pub trait ShardLock: Send + Sync + 'static {
    fn new(shard: ItemShard) -> Self;
    fn write<R>(&self, f: impl FnOnce(&mut ItemShard) -> R) -> R;
    fn read<R>(&self, f: impl FnOnce(&ItemShard) -> R) -> R;
}

impl ShardLock for Mutex<ItemShard> {
    fn new(shard: ItemShard) -> Self {
        Mutex::new(shard)
    }

    fn write<R>(&self, f: impl FnOnce(&mut ItemShard) -> R) -> R {
        f(&mut self.lock().unwrap())
    }

    fn read<R>(&self, f: impl FnOnce(&ItemShard) -> R) -> R {
        f(&self.lock().unwrap())
    }
}

impl ShardLock for RwLock<ItemShard> {
    fn new(shard: ItemShard) -> Self {
        RwLock::new(shard)
    }

    fn write<R>(&self, f: impl FnOnce(&mut ItemShard) -> R) -> R {
        f(&mut self.write().unwrap())
    }

    fn read<R>(&self, f: impl FnOnce(&ItemShard) -> R) -> R {
        f(&self.read().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manual_shard(budget: u64) -> (ItemShard, Arc<StoreClock>) {
        let clock = StoreClock::manual();
        let cfg = StoreConfig { budget_bytes: budget, clock: clock.clone() };
        (ItemShard::new(&cfg), clock)
    }

    #[test]
    fn set_get_del_roundtrip_with_flags() {
        let (mut s, _clock) = manual_shard(0);
        assert!(!s.set(b"k", b"hello", 7, 0));
        assert_eq!(s.get(b"k"), Some((7, &b"hello"[..])));
        assert!(s.set(b"k", b"world!", 9, 0), "overwrite reports existed");
        assert_eq!(s.get(b"k"), Some((9, &b"world!"[..])));
        assert!(s.del(b"k"));
        assert_eq!(s.get(b"k"), None);
        assert!(!s.del(b"k"));
        assert_eq!(s.stats().items, 0);
        assert_eq!(s.stats().store_bytes, 0, "bytes must return to zero");
    }

    #[test]
    fn lazy_expiry_on_access() {
        let (mut s, clock) = manual_shard(0);
        s.set(b"k", b"v", 0, 500);
        assert_eq!(s.get(b"k"), Some((0, &b"v"[..])));
        clock.advance(499);
        assert!(s.get(b"k").is_some(), "1 ms before the deadline");
        clock.advance(1);
        assert_eq!(s.get(b"k"), None, "deadline reached");
        assert_eq!(s.stats().expired_keys, 1);
        assert_eq!(s.stats().items, 0, "lazy access reclaims");
        assert_eq!(s.stats().store_bytes, 0);
    }

    #[test]
    fn peek_is_read_only() {
        let (mut s, clock) = manual_shard(0);
        s.set(b"k", b"v", 3, 100);
        assert_eq!(s.peek(b"k"), Some((3, &b"v"[..])));
        clock.advance(100);
        assert_eq!(s.peek(b"k"), None, "expired entries are invisible");
        assert_eq!(s.stats().items, 1, "peek must not reclaim");
        assert_eq!(s.sweep(SWEEP_SLOTS.max(2048)), 1, "sweep reclaims it");
        assert_eq!(s.stats().items, 0);
    }

    #[test]
    fn overwrite_of_expired_entry_counts_expiry_not_overwrite() {
        let (mut s, clock) = manual_shard(0);
        s.set(b"k", b"v", 0, 10);
        clock.advance(10);
        assert!(!s.set(b"k", b"w", 0, 0), "expired overwrite = fresh store");
        assert_eq!(s.stats().expired_keys, 1);
        assert_eq!(s.get(b"k"), Some((0, &b"w"[..])));
    }

    #[test]
    fn lru_eviction_in_stamp_order() {
        // Budget fits 4 entries of this shape; each entry costs
        // 1 (key) + 8 (val) + OVERHEAD.
        let cost = ITEM_OVERHEAD + 1 + 8;
        let (mut s, _clock) = manual_shard(4 * cost);
        for k in [b"a", b"b", b"c", b"d"] {
            s.set(k, b"00000000", 0, 0);
        }
        assert_eq!(s.stats().items, 4);
        assert_eq!(s.stats().evictions, 0);
        // Bump "a" so "b" becomes the LRU victim.
        assert!(s.get(b"a").is_some());
        s.set(b"e", b"00000000", 0, 0);
        assert_eq!(s.stats().evictions, 1);
        assert_eq!(s.get(b"b"), None, "b was least recently used");
        assert!(s.get(b"a").is_some());
        // Another insert evicts "c" (next oldest).
        s.set(b"f", b"00000000", 0, 0);
        assert_eq!(s.get(b"c"), None);
        assert!(s.get(b"d").is_some());
        assert!(s.get(b"e").is_some());
        assert!(s.get(b"f").is_some());
        assert_eq!(s.stats().evictions, 2);
        assert!(s.stats().store_bytes <= 4 * cost);
    }

    #[test]
    fn eviction_prefers_expired_over_live_lru() {
        let cost = ITEM_OVERHEAD + 1 + 8;
        let (mut s, clock) = manual_shard(3 * cost);
        s.set(b"x", b"00000000", 0, 5); // will be expired
        s.set(b"a", b"00000000", 0, 0);
        s.set(b"b", b"00000000", 0, 0);
        clock.advance(5);
        s.set(b"c", b"00000000", 0, 0);
        // "x" (expired) went first, counted as expiry, not eviction.
        assert_eq!(s.stats().expired_keys, 1);
        assert_eq!(s.stats().evictions, 0);
        assert!(s.get(b"a").is_some());
        assert!(s.get(b"b").is_some());
        assert!(s.get(b"c").is_some());
    }

    #[test]
    fn touch_persist_and_ttl() {
        let (mut s, clock) = manual_shard(0);
        assert_eq!(s.ttl_ms(b"k"), TTL_MISSING);
        s.set(b"k", b"v", 0, 0);
        assert_eq!(s.ttl_ms(b"k"), TTL_NO_EXPIRY);
        assert!(s.touch(b"k", 250));
        assert_eq!(s.ttl_ms(b"k"), 250);
        clock.advance(100);
        assert_eq!(s.ttl_ms(b"k"), 150);
        assert!(s.persist(b"k"), "persist clears a live deadline");
        assert_eq!(s.ttl_ms(b"k"), TTL_NO_EXPIRY);
        assert!(!s.persist(b"k"), "nothing left to clear");
        assert!(s.touch(b"k", 50));
        clock.advance(50);
        assert!(!s.touch(b"k", 50), "touching an expired key misses");
        assert_eq!(s.ttl_ms(b"k"), TTL_MISSING);
        assert!(!s.persist(b"missing"));
    }

    #[test]
    fn incr_semantics_with_expiry() {
        let (mut s, clock) = manual_shard(0);
        assert_eq!(s.incr(b"ctr", 5), Ok(5));
        assert_eq!(s.incr(b"ctr", 2), Ok(7));
        assert_eq!(s.get(b"ctr"), Some((0, &b"7"[..])));
        s.set(b"txt", b"abc", 0, 0);
        assert_eq!(s.incr(b"txt", 1), Err(()));
        assert_eq!(s.get(b"txt"), Some((0, &b"abc"[..])), "error leaves value");
        // INCR preserves an existing deadline...
        s.set(b"t", b"1", 0, 100);
        assert_eq!(s.incr(b"t", 1), Ok(2));
        assert_eq!(s.ttl_ms(b"t"), 100);
        // ...and an expired counter restarts from zero.
        clock.advance(100);
        assert_eq!(s.incr(b"t", 3), Ok(3));
        assert_eq!(s.ttl_ms(b"t"), TTL_NO_EXPIRY);
    }

    #[test]
    fn sweep_is_incremental_and_complete() {
        let (mut s, clock) = manual_shard(0);
        for i in 0..200u64 {
            let key = format!("k{i}");
            s.set(key.as_bytes(), b"v", 0, if i % 2 == 0 { 50 } else { 0 });
        }
        clock.advance(50);
        assert_eq!(s.stats().items, 200, "nothing reclaimed yet");
        // Bounded calls make progress and eventually reclaim every
        // expired entry; live entries survive.
        let mut reclaimed = 0;
        for _ in 0..1000 {
            reclaimed += s.sweep(16);
            if reclaimed == 100 {
                break;
            }
        }
        assert_eq!(reclaimed, 100);
        assert_eq!(s.stats().items, 100);
        assert_eq!(s.stats().expired_keys, 100);
        for i in (1..200u64).step_by(2) {
            let key = format!("k{i}");
            assert!(s.get(key.as_bytes()).is_some(), "live key {i} swept");
        }
    }

    #[test]
    fn hostile_ttls_neither_wrap_nor_panic() {
        // Wire-supplied TTLs are attacker-controlled (memcached exptime,
        // RESP EX/PX): the deadline math must saturate, not wrap past
        // the 0 = never sentinel (or overflow-panic a trustee in debug
        // builds), and the TTL query must clamp instead of going
        // negative into sentinel territory.
        let (mut s, clock) = manual_shard(0);
        s.set(b"k", b"v", 0, u64::MAX);
        assert!(s.get(b"k").is_some(), "saturated deadline is 'far future'");
        let ttl = s.ttl_ms(b"k");
        assert_eq!(ttl, i64::MAX, "clamped, not negative: {ttl}");
        clock.advance(10_000);
        assert!(s.get(b"k").is_some());
        assert!(s.touch(b"k", u64::MAX), "touch saturates too");
        assert!(s.ttl_ms(b"k") > 0);
    }

    #[test]
    fn sweep_budgeted_by_advances_is_a_full_pass_despite_removals() {
        // Removals re-examine their slot without consuming the advance
        // budget, so sweep(capacity) reclaims *every* expired entry in
        // one call no matter how many there are (the old iteration
        // budget fell short by one slot per removal).
        let (mut s, clock) = manual_shard(0);
        for i in 0..500u64 {
            s.set(format!("k{i}").as_bytes(), b"v", 0, 10);
        }
        clock.advance(10);
        let swept = s.sweep(1 << 16);
        assert_eq!(swept, 500, "one bounded call must finish the pass");
        assert_eq!(s.stats().items, 0);
    }

    #[test]
    fn manual_clock_is_deterministic() {
        let c = StoreClock::manual();
        assert!(c.is_manual());
        let t0 = c.now_ms();
        c.advance(41);
        assert_eq!(c.now_ms(), t0 + 41);
        let real = StoreClock::real();
        assert!(!real.is_manual());
    }
}
