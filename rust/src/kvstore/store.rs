//! The unified item store: one shard type with real cache semantics —
//! item metadata (flags, expiry deadline), an intrusive LRU list, a
//! per-shard byte budget with O(1) eviction, size-classed value slabs,
//! lazy-on-access expiry, and an incremental expiry sweep — shared by
//! **all four** KV backends.
//!
//! This is the storage half of the paper's memcached argument (§7):
//! "memory allocation, LRU updates as well as table writes, all of which
//! involve synchronization in a lock-based design" become trustee-local
//! when a shard is entrusted. [`ItemShard`] keeps every auxiliary
//! structure (LRU list, byte accounting, value pools, expiry
//! bookkeeping) *next to* the table it describes, so:
//!
//! - on the Trust backend each shard lives on its owning trustee and all
//!   of this is plain single-threaded mutation — zero synchronization,
//!   zero atomics;
//! - on the `mutex`/`rwlock`/`swift` baselines the same shard sits
//!   behind a lock, and every GET now pays the write-side lock for its
//!   LRU relink and lazy expiry — exactly the synchronization profile
//!   the paper ascribes to stock memcached.
//!
//! ## Layout: stable slab handles, intrusive LRU
//!
//! Entries live in a [`Slab`] at stable `u32` handles; the
//! open-addressing table maps key → handle. The table still relocates
//! its *slots* (robin hood + backward shift), but a slot now holds only
//! a handle, so the [`Item`] itself never moves — which makes intrusive
//! prev/next links legal. Recency is a doubly-linked LRU list threaded
//! through the slab: a hit unlinks and re-heads the item (O(1)), and
//! eviction pops the tail (O(1)) instead of the old O(capacity)
//! min-stamp scan. The victim finds its own table slot through the hash
//! it carries ([`OaTable::find_slot_by_hash`] — an expected-O(1) probe,
//! not a scan), so victim selection *and* removal are constant-time.
//!
//! ## Value storage: size-classed slabs
//!
//! Item data lives in buffers rounded up to a size class (×~1.25
//! growth from [`MIN_VALUE_CLASS`], 8-byte aligned — memcached's slab
//! classes); freed buffers park in bounded per-shard, per-class pools
//! and are handed back to the next store of that class. Together with
//! the key-buffer pool and the slab free list, sustained over-budget
//! SET churn (insert + evict per op) settles into a fixed footprint
//! with **zero allocations per op** — `tests/alloc_regression.rs`
//! enforces this. Budgets charge the *class* size, not the byte length;
//! the rounding waste is visible as [`StoreStats::slab_slack_bytes`].
//!
//! Expiry is enforced three ways, all deterministic: lazily on access (a
//! hit on an expired item reclaims it and reports a miss), on overwrite,
//! and by [`ItemShard::sweep`] — a cursor-carrying incremental walk of
//! the *slab* (slots never relocate, so one pass visits every entry
//! exactly once) driven from the runtime's maintenance hook with bounded
//! work per call.

use crate::cmap::{fxhash, OaTable, Slab, NIL};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Fixed per-entry accounting overhead (table slot + Item header +
/// allocator slack), charged against the shard budget alongside the key
/// and the value's class-rounded charge.
pub const ITEM_OVERHEAD: u64 = 64;

/// Slab slots one [`ItemShard::sweep`] call examines — the bounded work
/// quantum of the incremental expiry sweep.
pub const SWEEP_SLOTS: usize = 64;

/// `ttl_ms` query result: the key does not exist (or is expired).
pub const TTL_MISSING: i64 = -2;
/// `ttl_ms` query result: the key exists but carries no expiry.
pub const TTL_NO_EXPIRY: i64 = -1;

// ---------------------------------------------------------------------
// Value size classes
// ---------------------------------------------------------------------

/// Smallest value size class in bytes; classes grow by ~×1.25, rounded
/// up to 8 bytes, through [`MAX_POOLED_CLASS`].
pub const MIN_VALUE_CLASS: usize = 16;

/// Largest pooled class; longer values get an exact-capacity buffer
/// that is never pooled (memcached's "oversize" path).
pub const MAX_POOLED_CLASS: usize = 1 << 20;

/// Free buffers a single class pool may hold.
const PER_CLASS_FREE_CAP: usize = 32;

/// Total bytes a shard may park across all class pools.
const FREE_BYTES_CAP: u64 = 4 << 20;

#[inline]
const fn next_class(c: usize) -> usize {
    (c + c / 4 + 7) & !7
}

/// Number of pooled size classes (compile-time walk of the chain).
const NUM_CLASSES: usize = {
    let mut c = MIN_VALUE_CLASS;
    let mut n = 1;
    while c < MAX_POOLED_CLASS {
        c = next_class(c);
        n += 1;
    }
    n
};

/// `(class index, class size)` of the smallest class holding `len`;
/// `None` when `len` is oversize (exact-capacity, unpooled).
#[inline]
fn class_for(len: usize) -> Option<(usize, usize)> {
    if len > MAX_POOLED_CLASS {
        return None;
    }
    let mut c = MIN_VALUE_CLASS;
    let mut i = 0;
    while c < len {
        c = next_class(c);
        i += 1;
    }
    Some((i, c))
}

/// Bytes a value of `len` is charged against the shard budget: its size
/// class (≥ `len`), or exactly `len` for oversize values.
#[inline]
pub fn value_charge(len: usize) -> u64 {
    match class_for(len) {
        Some((_, c)) => c as u64,
        None => len as u64,
    }
}

/// Budget charge for one entry: key bytes + class-rounded value charge +
/// [`ITEM_OVERHEAD`]. Benches and tests compute expected `store_bytes`
/// with this, so accounting changes stay in one place.
#[inline]
pub fn entry_cost(key_len: usize, val_len: usize) -> u64 {
    key_len as u64 + value_charge(val_len) + ITEM_OVERHEAD
}

/// Per-shard pools of freed value buffers, one LIFO stack per size
/// class. Bounded two ways (buffers per class, total parked bytes) so a
/// burst of huge values cannot pin memory forever.
struct ValueSlabs {
    pools: Vec<Vec<Vec<u8>>>,
    free_bytes: u64,
    hits: u64,
    misses: u64,
}

impl ValueSlabs {
    fn new() -> ValueSlabs {
        ValueSlabs { pools: vec![Vec::new(); NUM_CLASSES], free_bytes: 0, hits: 0, misses: 0 }
    }

    /// An empty buffer with capacity ≥ `len`, plus its charge. Pool hit
    /// = zero allocation.
    fn acquire(&mut self, len: usize) -> (Vec<u8>, u32) {
        match class_for(len) {
            Some((i, c)) => {
                if let Some(buf) = self.pools[i].pop() {
                    self.free_bytes -= c as u64;
                    self.hits += 1;
                    (buf, c as u32)
                } else {
                    self.misses += 1;
                    (Vec::with_capacity(c), c as u32)
                }
            }
            None => {
                self.misses += 1;
                (Vec::with_capacity(len), len as u32)
            }
        }
    }

    /// Park a freed buffer in its class pool, or drop it (oversize, full
    /// pool, or past the parked-bytes cap).
    fn release(&mut self, mut buf: Vec<u8>, charged: u32) {
        if let Some((i, c)) = class_for(charged as usize) {
            if c == charged as usize
                && self.pools[i].len() < PER_CLASS_FREE_CAP
                && self.free_bytes + c as u64 <= FREE_BYTES_CAP
            {
                buf.clear();
                self.pools[i].push(buf);
                self.free_bytes += c as u64;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Clock
// ---------------------------------------------------------------------

/// The store's time source, in milliseconds. Real stores measure elapsed
/// time from creation; tests freeze time with [`StoreClock::manual`] and
/// drive it with [`StoreClock::advance`] so expiry and eviction runs are
/// fully deterministic across backends.
pub struct StoreClock {
    epoch: Instant,
    /// `u64::MAX` = real (epoch-elapsed) time; anything else is the
    /// manual clock's current reading.
    manual: AtomicU64,
}

const REAL_CLOCK: u64 = u64::MAX;

impl StoreClock {
    /// Wall-clock store time (milliseconds since store creation).
    pub fn real() -> Arc<StoreClock> {
        Arc::new(StoreClock { epoch: Instant::now(), manual: AtomicU64::new(REAL_CLOCK) })
    }

    /// A frozen, manually-advanced clock (starts at 1 ms so `now + ttl`
    /// can never collide with the "no expiry" sentinel 0).
    pub fn manual() -> Arc<StoreClock> {
        Arc::new(StoreClock { epoch: Instant::now(), manual: AtomicU64::new(1) })
    }

    #[inline]
    pub fn now_ms(&self) -> u64 {
        let m = self.manual.load(Ordering::Relaxed);
        if m == REAL_CLOCK {
            self.epoch.elapsed().as_millis() as u64
        } else {
            m
        }
    }

    /// Advance a manual clock. Panics on a real clock.
    pub fn advance(&self, ms: u64) {
        let prev = self.manual.fetch_add(ms, Ordering::Relaxed);
        assert_ne!(prev, REAL_CLOCK, "StoreClock::advance on a real clock");
    }

    pub fn is_manual(&self) -> bool {
        self.manual.load(Ordering::Relaxed) != REAL_CLOCK
    }
}

// ---------------------------------------------------------------------
// Config + stats
// ---------------------------------------------------------------------

/// Store-wide knobs shared by every backend flavor.
#[derive(Clone)]
pub struct StoreConfig {
    /// Total byte budget for the store (key + class-rounded value +
    /// [`ITEM_OVERHEAD`] per entry); 0 = unlimited. Backends split it
    /// evenly over their shards ([`StoreConfig::shard_budget`]); a shard
    /// exceeding its slice evicts least-recently-used items until back
    /// under.
    pub budget_bytes: u64,
    /// Time source (shared by every shard of the store).
    pub clock: Arc<StoreClock>,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig { budget_bytes: 0, clock: StoreClock::real() }
    }
}

impl StoreConfig {
    pub fn with_budget(budget_bytes: u64) -> StoreConfig {
        StoreConfig { budget_bytes, ..Default::default() }
    }

    /// This store's per-shard budget when split over `n_shards` (0 stays
    /// unlimited; a nonzero budget never rounds down to unlimited).
    pub fn shard_budget(&self, n_shards: usize) -> u64 {
        if self.budget_bytes == 0 {
            0
        } else {
            (self.budget_bytes / n_shards.max(1) as u64).max(1)
        }
    }
}

/// Aggregated store counters (per shard, summed by the backends).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Live entries (expired-but-unswept entries still count until
    /// reclaimed — they occupy memory).
    pub items: u64,
    /// Bytes charged against shard budgets (class-rounded).
    pub store_bytes: u64,
    /// Entries reclaimed to enforce a byte budget.
    pub evictions: u64,
    /// Entries reclaimed because their deadline passed (lazily on
    /// access/overwrite, at the LRU tail, or by the sweep).
    pub expired_keys: u64,
    /// Value-buffer acquisitions served from a class pool (no
    /// allocation).
    pub slab_hits: u64,
    /// Value-buffer acquisitions that had to allocate (cold class pool
    /// or oversize value).
    pub slab_misses: u64,
    /// Bytes currently parked in class pools awaiting reuse (gauge).
    pub slab_free_bytes: u64,
    /// Class-rounding waste across live items: Σ(charge − value length)
    /// — the store's internal fragmentation (gauge).
    pub slab_slack_bytes: u64,
}

impl StoreStats {
    pub fn merge(&mut self, other: &StoreStats) {
        self.items += other.items;
        self.store_bytes += other.store_bytes;
        self.evictions += other.evictions;
        self.expired_keys += other.expired_keys;
        self.slab_hits += other.slab_hits;
        self.slab_misses += other.slab_misses;
        self.slab_free_bytes += other.slab_free_bytes;
        self.slab_slack_bytes += other.slab_slack_bytes;
    }

    /// Wire-friendly array (for delegated stat reads).
    pub fn to_array(self) -> [u64; 8] {
        [
            self.items,
            self.store_bytes,
            self.evictions,
            self.expired_keys,
            self.slab_hits,
            self.slab_misses,
            self.slab_free_bytes,
            self.slab_slack_bytes,
        ]
    }

    pub fn from_array(a: [u64; 8]) -> StoreStats {
        StoreStats {
            items: a[0],
            store_bytes: a[1],
            evictions: a[2],
            expired_keys: a[3],
            slab_hits: a[4],
            slab_misses: a[5],
            slab_free_bytes: a[6],
            slab_slack_bytes: a[7],
        }
    }
}

// ---------------------------------------------------------------------
// Item + shard
// ---------------------------------------------------------------------

/// One stored item: value bytes plus the metadata the cache semantics
/// need, including its intrusive LRU links (slab handles of its list
/// neighbors) and the key hash that walks it back to its table slot.
/// Everything is plain data mutated under the shard's exclusive access
/// (trustee-local or lock-scoped) — no atomics.
#[derive(Debug)]
struct Item {
    flags: u32,
    /// Bytes charged for the value: its size class, or the exact length
    /// for oversize values. `data.capacity() >= charged >= data.len()`.
    charged: u32,
    /// Absolute deadline on the store clock (ms); 0 = never expires.
    expires_at_ms: u64,
    /// `fxhash` of the key — lets the LRU tail victim find its own
    /// table slot without an owned key ([`OaTable::find_slot_by_hash`]).
    hash: u64,
    /// LRU neighbor toward the head (more recent); [`NIL`] at the head.
    prev: u32,
    /// LRU neighbor toward the tail (less recent); [`NIL`] at the tail.
    next: u32,
    data: Vec<u8>,
}

impl Item {
    #[inline]
    fn is_expired(&self, now_ms: u64) -> bool {
        self.expires_at_ms != 0 && self.expires_at_ms <= now_ms
    }
}

/// Key buffers the pool will retain: enough for memcached's 250-byte
/// limit and typical RESP keys; oddball huge keys just drop.
const KEY_POOL_MAX_CAP: usize = 1024;
/// Freed key buffers a shard parks for reuse.
const KEY_POOL_CAP: usize = 64;

/// One shard of the unified item store. All mutating entry points take
/// `&mut self`: the Trust backend entrusts a shard per trustee (plain
/// single-threaded mutation), the lock backends wrap one per lock shard.
///
/// Invariants tying the three structures together:
/// - `table[key] = h` ⇔ `items[h]` is occupied with `hash == fxhash(key)`;
///   `table.len() == items.len()`.
/// - The LRU list visits exactly the occupied slab handles:
///   `lru_head` → `next` links → `lru_tail`, mirrored by `prev`.
/// - `bytes` = Σ over live entries of `entry_cost(key.len, data.len)`;
///   `slack` = Σ(`charged` − `data.len`).
pub struct ItemShard {
    table: OaTable<Vec<u8>, u32>,
    items: Slab<Item>,
    values: ValueSlabs,
    /// Freed key buffers (bounded LIFO) so churn reuses key allocations.
    key_pool: Vec<Vec<u8>>,
    clock: Arc<StoreClock>,
    /// Byte budget (0 = unlimited).
    budget: u64,
    bytes: u64,
    /// Class-rounding waste across live items (Σ charged − len).
    slack: u64,
    evictions: u64,
    expired: u64,
    /// Most recently used (NIL when empty).
    lru_head: u32,
    /// Least recently used — the next eviction victim (NIL when empty).
    lru_tail: u32,
    /// Slab-slot cursor of the incremental expiry sweep.
    sweep_cursor: usize,
}

impl ItemShard {
    /// A single shard carrying the whole config budget (single-shard
    /// stores and tests); multi-shard backends use
    /// [`ItemShard::with_budget`] with their [`StoreConfig::shard_budget`]
    /// slice.
    pub fn new(cfg: &StoreConfig) -> ItemShard {
        Self::with_budget(cfg.clock.clone(), cfg.budget_bytes)
    }

    pub fn with_budget(clock: Arc<StoreClock>, budget: u64) -> ItemShard {
        ItemShard {
            table: OaTable::with_capacity(1024),
            items: Slab::new(),
            values: ValueSlabs::new(),
            key_pool: Vec::new(),
            clock,
            budget,
            bytes: 0,
            slack: 0,
            evictions: 0,
            expired: 0,
            lru_head: NIL,
            lru_tail: NIL,
            sweep_cursor: 0,
        }
    }

    #[inline]
    fn now(&self) -> u64 {
        self.clock.now_ms()
    }

    // -- intrusive LRU list ------------------------------------------

    /// Detach `idx` from the LRU list, patching its neighbors. The
    /// item's own links are left stale; callers relink or remove it.
    fn lru_unlink(&mut self, idx: u32) {
        let (prev, next) = {
            let it = self.items.get(idx).expect("unlink of vacant slab slot");
            (it.prev, it.next)
        };
        match prev {
            NIL => self.lru_head = next,
            p => self.items.get_mut(p).expect("LRU prev link dangles").next = next,
        }
        match next {
            NIL => self.lru_tail = prev,
            n => self.items.get_mut(n).expect("LRU next link dangles").prev = prev,
        }
    }

    /// Attach a detached `idx` at the head (most recently used).
    fn lru_push_front(&mut self, idx: u32) {
        let old_head = self.lru_head;
        {
            let it = self.items.get_mut(idx).expect("push_front of vacant slab slot");
            it.prev = NIL;
            it.next = old_head;
        }
        match old_head {
            NIL => self.lru_tail = idx,
            h => self.items.get_mut(h).expect("LRU head dangles").prev = idx,
        }
        self.lru_head = idx;
    }

    /// Move `idx` to the head — the O(1) recency bump on every hit.
    fn lru_touch(&mut self, idx: u32) {
        if self.lru_head == idx {
            return;
        }
        self.lru_unlink(idx);
        self.lru_push_front(idx);
    }

    // -- key / value recycling ---------------------------------------

    /// An owned copy of `key`, reusing a pooled buffer when one exists.
    fn make_key(&mut self, key: &[u8]) -> Vec<u8> {
        match self.key_pool.pop() {
            Some(mut k) => {
                k.clear();
                k.extend_from_slice(key);
                k
            }
            None => key.to_vec(),
        }
    }

    fn pool_key(&mut self, mut k: Vec<u8>) {
        if self.key_pool.len() < KEY_POOL_CAP && k.capacity() <= KEY_POOL_MAX_CAP {
            k.clear();
            self.key_pool.push(k);
        }
    }

    // -- entry lifecycle ---------------------------------------------

    /// Table slot currently mapping to slab handle `idx` — the reverse
    /// lookup through the item's stored hash; expected O(1).
    fn table_slot_of(&self, idx: u32) -> usize {
        let hash = self.items.get(idx).expect("slot lookup of vacant handle").hash;
        self.table
            .find_slot_by_hash(hash, |&h| h == idx)
            .expect("slab handle missing from table")
    }

    /// Remove the entry at table slot `slot`: unmap it, unlink it from
    /// the LRU list, release its budget charge, and recycle its key and
    /// value buffers. Callers account the *reason* (eviction / expiry /
    /// delete).
    fn remove_entry_at_slot(&mut self, slot: usize) {
        let (key, idx) = self.table.remove_at(slot).expect("remove of empty table slot");
        self.lru_unlink(idx);
        let it = self.items.remove(idx).expect("table slot mapped to vacant handle");
        self.bytes -= key.len() as u64 + it.charged as u64 + ITEM_OVERHEAD;
        self.slack -= it.charged as u64 - it.data.len() as u64;
        self.values.release(it.data, it.charged);
        self.pool_key(key);
    }

    /// Insert a fresh entry (key known absent) at the LRU head.
    fn insert_new(&mut self, key: &[u8], val: &[u8], flags: u32, expires: u64) {
        let (mut data, charged) = self.values.acquire(val.len());
        data.extend_from_slice(val);
        let hash = fxhash(key);
        let idx = self.items.insert(Item {
            flags,
            charged,
            expires_at_ms: expires,
            hash,
            prev: NIL,
            next: NIL,
            data,
        });
        self.lru_push_front(idx);
        let owned = self.make_key(key);
        self.table.insert_hashed(hash, owned, idx);
        self.bytes += key.len() as u64 + charged as u64 + ITEM_OVERHEAD;
        self.slack += charged as u64 - val.len() as u64;
    }

    /// Replace the value at `idx`: in place when the new value shares
    /// the old one's size class, otherwise through the class pools (the
    /// old buffer parks, the new class's buffer is reused — still no
    /// allocation once the pools are warm). Flags/expiry untouched.
    fn rewrite_value(&mut self, idx: u32, val: &[u8]) {
        let (old_len, old_charged) = {
            let it = self.items.get(idx).expect("rewrite of vacant handle");
            (it.data.len() as u64, it.charged)
        };
        let new_charge = value_charge(val.len());
        if new_charge == old_charged as u64 {
            let it = self.items.get_mut(idx).expect("rewrite of vacant handle");
            it.data.clear();
            it.data.extend_from_slice(val);
        } else {
            let (mut buf, charged) = self.values.acquire(val.len());
            buf.extend_from_slice(val);
            let it = self.items.get_mut(idx).expect("rewrite of vacant handle");
            let old = std::mem::replace(&mut it.data, buf);
            it.charged = charged;
            self.values.release(old, old_charged);
            self.bytes = self.bytes - old_charged as u64 + charged as u64;
        }
        let charged_now = self.items.get(idx).expect("rewrite of vacant handle").charged as u64;
        self.slack = self.slack - (old_charged as u64 - old_len) + (charged_now - val.len() as u64);
    }

    // -- public cache semantics --------------------------------------

    /// Lookup with full cache semantics: relink to the LRU head on a
    /// hit; reclaim (and miss) on a lazily-discovered expired entry.
    pub fn get(&mut self, key: &[u8]) -> Option<(u32, &[u8])> {
        let now = self.now();
        let slot = self.table.index_of(key)?;
        let idx = *self.table.entry_at(slot).expect("index_of slot").1;
        if self.items.get(idx).expect("table handle").is_expired(now) {
            self.remove_entry_at_slot(slot);
            self.expired += 1;
            return None;
        }
        self.lru_touch(idx);
        let it = self.items.get(idx).expect("table handle");
        Some((it.flags, &*it.data))
    }

    /// Read-only probe: no LRU bump, no reclamation (EXISTS / TTL — the
    /// read-scaling path on the RwLock baselines). Expired entries are
    /// invisible but stay until a mutating access or the sweep reclaims
    /// them.
    pub fn peek(&self, key: &[u8]) -> Option<(u32, &[u8])> {
        let now = self.now();
        let idx = *self.table.get(key)?;
        let it = self.items.get(idx)?;
        if it.is_expired(now) {
            return None;
        }
        Some((it.flags, &*it.data))
    }

    /// Store `key = val` with `flags` and a relative TTL (`0` = no
    /// expiry, which also *clears* any previous deadline — memcached
    /// `exptime 0` / Redis plain `SET`). Returns whether a live entry
    /// was overwritten. Overwrites reuse the entry's buffer in place
    /// (same size class) or swap through the class pools; going over
    /// budget evicts LRU-tail victims before returning.
    pub fn set(&mut self, key: &[u8], val: &[u8], flags: u32, ttl_ms: u64) -> bool {
        let now = self.now();
        // Saturating: a hostile wire-supplied TTL must not wrap past the
        // 0 = never sentinel (or panic a trustee in debug builds).
        let expires = if ttl_ms == 0 { 0 } else { now.saturating_add(ttl_ms) };
        let existed = match self.table.index_of(key) {
            Some(slot) => {
                let idx = *self.table.entry_at(slot).expect("index_of slot").1;
                let was_expired = self.items.get(idx).expect("table handle").is_expired(now);
                if was_expired {
                    // The old value died of expiry, not replacement.
                    self.expired += 1;
                }
                self.rewrite_value(idx, val);
                let it = self.items.get_mut(idx).expect("table handle");
                it.flags = flags;
                it.expires_at_ms = expires;
                self.lru_touch(idx);
                !was_expired
            }
            None => {
                self.insert_new(key, val, flags, expires);
                false
            }
        };
        self.evict_to_budget(now);
        existed
    }

    /// Remove `key`; true only when a *live* entry was removed (an
    /// expired one is reclaimed but reported missing, like a GET).
    pub fn del(&mut self, key: &[u8]) -> bool {
        let now = self.now();
        let Some(slot) = self.table.index_of(key) else {
            return false;
        };
        let idx = *self.table.entry_at(slot).expect("index_of slot").1;
        let was_expired = self.items.get(idx).expect("table handle").is_expired(now);
        self.remove_entry_at_slot(slot);
        if was_expired {
            self.expired += 1;
            false
        } else {
            true
        }
    }

    /// Reset the deadline of a live entry (`ttl_ms` 0 clears it —
    /// memcached `touch 0`). True when the key was live.
    pub fn touch(&mut self, key: &[u8], ttl_ms: u64) -> bool {
        let now = self.now();
        let Some(slot) = self.table.index_of(key) else {
            return false;
        };
        let idx = *self.table.entry_at(slot).expect("index_of slot").1;
        if self.items.get(idx).expect("table handle").is_expired(now) {
            self.remove_entry_at_slot(slot);
            self.expired += 1;
            return false;
        }
        let it = self.items.get_mut(idx).expect("table handle");
        it.expires_at_ms = if ttl_ms == 0 { 0 } else { now.saturating_add(ttl_ms) };
        self.lru_touch(idx);
        true
    }

    /// Clear the deadline of a live entry (Redis `PERSIST`): true only
    /// when the entry existed *and* had a deadline to clear. No LRU
    /// bump — persistence is metadata, not access.
    pub fn persist(&mut self, key: &[u8]) -> bool {
        let now = self.now();
        let Some(slot) = self.table.index_of(key) else {
            return false;
        };
        let idx = *self.table.entry_at(slot).expect("index_of slot").1;
        if self.items.get(idx).expect("table handle").is_expired(now) {
            self.remove_entry_at_slot(slot);
            self.expired += 1;
            return false;
        }
        let it = self.items.get_mut(idx).expect("table handle");
        let had = it.expires_at_ms != 0;
        it.expires_at_ms = 0;
        had
    }

    /// Remaining lifetime in ms: [`TTL_MISSING`] (missing or expired),
    /// [`TTL_NO_EXPIRY`], or the remaining ms (> 0). Read-only.
    pub fn ttl_ms(&self, key: &[u8]) -> i64 {
        let now = self.now();
        match self.table.get(key).and_then(|&idx| self.items.get(idx)) {
            None => TTL_MISSING,
            Some(it) if it.is_expired(now) => TTL_MISSING,
            Some(it) if it.expires_at_ms == 0 => TTL_NO_EXPIRY,
            // Clamp: an absurd-but-accepted deadline must not wrap into
            // the negative range (where the sentinels live).
            Some(it) => (it.expires_at_ms - now).min(i64::MAX as u64) as i64,
        }
    }

    /// Redis `INCR` semantics on the item's value: missing (or expired)
    /// counts as 0, a non-integer value or overflow is an error leaving
    /// the entry untouched. Preserves flags and deadline on success.
    /// The decimal rendering goes through a stack buffer, then the
    /// normal value-rewrite path — no heap allocation.
    pub fn incr(&mut self, key: &[u8], delta: i64) -> Result<i64, ()> {
        let now = self.now();
        let live = match self.table.index_of(key) {
            Some(slot) => {
                let idx = *self.table.entry_at(slot).expect("index_of slot").1;
                if self.items.get(idx).expect("table handle").is_expired(now) {
                    self.remove_entry_at_slot(slot);
                    self.expired += 1;
                    None
                } else {
                    Some(idx)
                }
            }
            None => None,
        };
        let mut buf = [0u8; 20]; // i64::MIN is exactly 20 bytes
        let next = match live {
            Some(idx) => {
                let it = self.items.get(idx).expect("table handle");
                let cur: i64 = std::str::from_utf8(&it.data)
                    .map_err(|_| ())?
                    .parse()
                    .map_err(|_| ())?;
                let next = cur.checked_add(delta).ok_or(())?;
                let digits = format_i64(next, &mut buf);
                self.rewrite_value(idx, digits);
                self.lru_touch(idx);
                next
            }
            None => {
                let digits = format_i64(delta, &mut buf);
                self.insert_new(key, digits, 0, 0);
                delta
            }
        };
        self.evict_to_budget(now);
        Ok(next)
    }

    /// Enforce the byte budget: pop the LRU tail until back under —
    /// O(1) per victim (tail unlink + hash-guided table probe), never a
    /// shard scan. An expired tail counts as expiry, a live one as
    /// eviction; expired entries elsewhere in the shard are left for
    /// lazy access or the sweep, which keeps this path constant-time
    /// (EXPERIMENTS.md E18/E20 record the before/after).
    fn evict_to_budget(&mut self, now: u64) {
        if self.budget == 0 {
            return;
        }
        while self.bytes > self.budget && self.lru_tail != NIL {
            let victim = self.lru_tail;
            let expired = self.items.get(victim).expect("LRU tail dangles").is_expired(now);
            let slot = self.table_slot_of(victim);
            self.remove_entry_at_slot(slot);
            if expired {
                self.expired += 1;
            } else {
                self.evictions += 1;
            }
        }
    }

    /// Incremental expiry sweep: advance the shard's cursor over up to
    /// `max_slots` *slab* slots, reclaiming expired entries along the
    /// way. Bounded work per call — the runtime maintenance hook calls
    /// this every few scheduler ticks so unaccessed items still get
    /// reclaimed. Slab slots never relocate (unlike table slots, which
    /// backward-shift on removal), so each advance examines a distinct
    /// slot and [`ItemShard::sweep_span`] advances are exactly one full
    /// pass — every live entry visited once, none skipped or repeated,
    /// regardless of free-list reuse during the pass. Returns entries
    /// reclaimed.
    pub fn sweep(&mut self, max_slots: usize) -> u64 {
        if self.items.is_empty() {
            return 0;
        }
        let now = self.now();
        let span = self.items.slot_count();
        if self.sweep_cursor >= span {
            self.sweep_cursor = 0;
        }
        let mut reclaimed = 0u64;
        for _ in 0..max_slots.min(span) {
            let idx = self.sweep_cursor as u32;
            self.sweep_cursor = (self.sweep_cursor + 1) % span;
            let expired = matches!(
                self.items.get(idx),
                Some(it) if it.is_expired(now)
            );
            if expired {
                let slot = self.table_slot_of(idx);
                self.remove_entry_at_slot(slot);
                self.expired += 1;
                reclaimed += 1;
            }
        }
        reclaimed
    }

    /// Sweep advances that make one full pass over the shard (its slab
    /// slot count — ≥ `len()`, since freed slots stay until `clear`).
    pub fn sweep_span(&self) -> usize {
        self.items.slot_count()
    }

    pub fn clear(&mut self) {
        self.table.clear();
        self.items.clear();
        self.lru_head = NIL;
        self.lru_tail = NIL;
        self.bytes = 0;
        self.slack = 0;
        self.sweep_cursor = 0;
    }

    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }

    pub fn stats(&self) -> StoreStats {
        StoreStats {
            items: self.table.len() as u64,
            store_bytes: self.bytes,
            evictions: self.evictions,
            expired_keys: self.expired,
            slab_hits: self.values.hits,
            slab_misses: self.values.misses,
            slab_free_bytes: self.values.free_bytes,
            slab_slack_bytes: self.slack,
        }
    }
}

/// Render `n` into `buf`, returning the written digits. 20 bytes fit
/// every i64 (`i64::MIN` = "-9223372036854775808").
fn format_i64(n: i64, buf: &mut [u8; 20]) -> &[u8] {
    use std::io::Write;
    let mut cursor = &mut buf[..];
    write!(cursor, "{n}").expect("20 bytes fit any i64");
    let remaining = cursor.len();
    let used = buf.len() - remaining;
    &buf[..used]
}

// ---------------------------------------------------------------------
// Lock adapters (the baselines' shard wrapper)
// ---------------------------------------------------------------------

/// The lock discipline a baseline wraps around each [`ItemShard`]. GETs
/// go through [`ShardLock::write`]: the LRU relink and lazy expiry are
/// mutations, so even the readers-writer baselines pay the exclusive
/// lock on the read path — the synchronization the paper's delegated
/// design removes. Only genuinely read-only probes (EXISTS, TTL) use
/// [`ShardLock::read`].
pub trait ShardLock: Send + Sync + 'static {
    fn new(shard: ItemShard) -> Self;
    fn write<R>(&self, f: impl FnOnce(&mut ItemShard) -> R) -> R;
    fn read<R>(&self, f: impl FnOnce(&ItemShard) -> R) -> R;
}

impl ShardLock for Mutex<ItemShard> {
    fn new(shard: ItemShard) -> Self {
        Mutex::new(shard)
    }

    fn write<R>(&self, f: impl FnOnce(&mut ItemShard) -> R) -> R {
        f(&mut self.lock().unwrap())
    }

    fn read<R>(&self, f: impl FnOnce(&ItemShard) -> R) -> R {
        f(&self.lock().unwrap())
    }
}

impl ShardLock for RwLock<ItemShard> {
    fn new(shard: ItemShard) -> Self {
        RwLock::new(shard)
    }

    fn write<R>(&self, f: impl FnOnce(&mut ItemShard) -> R) -> R {
        f(&mut self.write().unwrap())
    }

    fn read<R>(&self, f: impl FnOnce(&ItemShard) -> R) -> R {
        f(&self.read().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manual_shard(budget: u64) -> (ItemShard, Arc<StoreClock>) {
        let clock = StoreClock::manual();
        let cfg = StoreConfig { budget_bytes: budget, clock: clock.clone() };
        (ItemShard::new(&cfg), clock)
    }

    #[test]
    fn size_classes_grow_geometrically_and_charge_the_class() {
        assert_eq!(value_charge(0), MIN_VALUE_CLASS as u64);
        assert_eq!(value_charge(1), 16);
        assert_eq!(value_charge(16), 16);
        assert_eq!(value_charge(17), 24);
        assert_eq!(value_charge(100), 120);
        // Classes are 8-byte aligned and grow by ≤ ×1.3.
        let mut c = MIN_VALUE_CLASS;
        let mut n = 1;
        while c < MAX_POOLED_CLASS {
            let next = next_class(c);
            assert_eq!(next % 8, 0, "class {next} not 8-byte aligned");
            assert!(next > c && next <= c + c / 4 + 7, "class step {c} -> {next}");
            c = next;
            n += 1;
        }
        assert_eq!(n, NUM_CLASSES, "compile-time class count drifted");
        // Oversize values are charged exactly.
        assert_eq!(value_charge(MAX_POOLED_CLASS + 1), MAX_POOLED_CLASS as u64 + 1);
        assert_eq!(entry_cost(3, 8), 3 + 16 + ITEM_OVERHEAD);
    }

    #[test]
    fn set_get_del_roundtrip_with_flags() {
        let (mut s, _clock) = manual_shard(0);
        assert!(!s.set(b"k", b"hello", 7, 0));
        assert_eq!(s.get(b"k"), Some((7, &b"hello"[..])));
        assert!(s.set(b"k", b"world!", 9, 0), "overwrite reports existed");
        assert_eq!(s.get(b"k"), Some((9, &b"world!"[..])));
        assert!(s.del(b"k"));
        assert_eq!(s.get(b"k"), None);
        assert!(!s.del(b"k"));
        assert_eq!(s.stats().items, 0);
        assert_eq!(s.stats().store_bytes, 0, "bytes must return to zero");
        assert_eq!(s.stats().slab_slack_bytes, 0, "slack returns to zero too");
    }

    #[test]
    fn store_bytes_charge_the_size_class_and_track_slack() {
        let (mut s, _clock) = manual_shard(0);
        s.set(b"k", &[7u8; 20], 0, 0); // class 24
        assert_eq!(s.stats().store_bytes, entry_cost(1, 20));
        assert_eq!(s.stats().slab_slack_bytes, 4, "24-byte class, 20-byte value");
        // Same-class overwrite stays in place: charge unchanged, slack
        // retracks the new length.
        s.set(b"k", &[7u8; 23], 0, 0);
        assert_eq!(s.stats().store_bytes, entry_cost(1, 23));
        assert_eq!(s.stats().slab_slack_bytes, 1);
        // Cross-class overwrite recharges.
        s.set(b"k", &[7u8; 100], 0, 0); // class 120
        assert_eq!(s.stats().store_bytes, entry_cost(1, 100));
        assert_eq!(s.stats().slab_slack_bytes, 20);
        s.del(b"k");
        assert_eq!(s.stats().store_bytes, 0);
        assert_eq!(s.stats().slab_slack_bytes, 0);
        // The freed buffers parked in their class pools.
        assert_eq!(s.stats().slab_free_bytes, 24 + 120);
    }

    #[test]
    fn value_pools_recycle_freed_buffers() {
        let (mut s, _clock) = manual_shard(0);
        s.set(b"a", &[1u8; 30], 0, 0); // class 32, cold: miss
        let miss0 = s.stats().slab_misses;
        s.del(b"a"); // 32-byte buffer parks
        assert_eq!(s.stats().slab_free_bytes, 32);
        s.set(b"b", &[2u8; 25], 0, 0); // class 32 again: pool hit
        assert_eq!(s.stats().slab_hits, 1);
        assert_eq!(s.stats().slab_misses, miss0, "no new allocation");
        assert_eq!(s.stats().slab_free_bytes, 0);
        assert_eq!(s.get(b"b"), Some((0, &[2u8; 25][..])));
    }

    #[test]
    fn lazy_expiry_on_access() {
        let (mut s, clock) = manual_shard(0);
        s.set(b"k", b"v", 0, 500);
        assert_eq!(s.get(b"k"), Some((0, &b"v"[..])));
        clock.advance(499);
        assert!(s.get(b"k").is_some(), "1 ms before the deadline");
        clock.advance(1);
        assert_eq!(s.get(b"k"), None, "deadline reached");
        assert_eq!(s.stats().expired_keys, 1);
        assert_eq!(s.stats().items, 0, "lazy access reclaims");
        assert_eq!(s.stats().store_bytes, 0);
    }

    #[test]
    fn peek_is_read_only() {
        let (mut s, clock) = manual_shard(0);
        s.set(b"k", b"v", 3, 100);
        assert_eq!(s.peek(b"k"), Some((3, &b"v"[..])));
        clock.advance(100);
        assert_eq!(s.peek(b"k"), None, "expired entries are invisible");
        assert_eq!(s.stats().items, 1, "peek must not reclaim");
        assert_eq!(s.sweep(SWEEP_SLOTS.max(2048)), 1, "sweep reclaims it");
        assert_eq!(s.stats().items, 0);
    }

    #[test]
    fn overwrite_of_expired_entry_counts_expiry_not_overwrite() {
        let (mut s, clock) = manual_shard(0);
        s.set(b"k", b"v", 0, 10);
        clock.advance(10);
        assert!(!s.set(b"k", b"w", 0, 0), "expired overwrite = fresh store");
        assert_eq!(s.stats().expired_keys, 1);
        assert_eq!(s.get(b"k"), Some((0, &b"w"[..])));
    }

    #[test]
    fn lru_eviction_in_recency_order() {
        // Budget fits 4 entries of this shape; each entry costs
        // 1 (key) + 16 (8-byte value's class) + OVERHEAD.
        let cost = entry_cost(1, 8);
        let (mut s, _clock) = manual_shard(4 * cost);
        for k in [b"a", b"b", b"c", b"d"] {
            s.set(k, b"00000000", 0, 0);
        }
        assert_eq!(s.stats().items, 4);
        assert_eq!(s.stats().evictions, 0);
        // Bump "a" so "b" becomes the LRU victim.
        assert!(s.get(b"a").is_some());
        s.set(b"e", b"00000000", 0, 0);
        assert_eq!(s.stats().evictions, 1);
        assert_eq!(s.get(b"b"), None, "b was least recently used");
        assert!(s.get(b"a").is_some());
        // Another insert evicts "c" (next oldest).
        s.set(b"f", b"00000000", 0, 0);
        assert_eq!(s.get(b"c"), None);
        assert!(s.get(b"d").is_some());
        assert!(s.get(b"e").is_some());
        assert!(s.get(b"f").is_some());
        assert_eq!(s.stats().evictions, 2);
        assert!(s.stats().store_bytes <= 4 * cost);
    }

    #[test]
    fn overwrite_and_touch_rescue_entries_from_the_tail() {
        let cost = entry_cost(1, 8);
        let (mut s, _clock) = manual_shard(3 * cost);
        s.set(b"a", b"00000000", 0, 0);
        s.set(b"b", b"00000000", 0, 0);
        s.set(b"c", b"00000000", 0, 0);
        // Overwriting "a" and touching "b" re-head them: "c" is now the
        // tail despite being the newest insert.
        s.set(b"a", b"11111111", 0, 0);
        assert!(s.touch(b"b", 0));
        s.set(b"d", b"00000000", 0, 0);
        assert_eq!(s.get(b"c"), None, "c was the relinked tail");
        assert!(s.get(b"a").is_some());
        assert!(s.get(b"b").is_some());
        assert!(s.get(b"d").is_some());
        assert_eq!(s.stats().evictions, 1);
    }

    #[test]
    fn eviction_prefers_expired_over_live_lru() {
        let cost = entry_cost(1, 8);
        let (mut s, clock) = manual_shard(3 * cost);
        s.set(b"x", b"00000000", 0, 5); // will be expired
        s.set(b"a", b"00000000", 0, 0);
        s.set(b"b", b"00000000", 0, 0);
        clock.advance(5);
        s.set(b"c", b"00000000", 0, 0);
        // "x" (expired, at the tail) went first, counted as expiry, not
        // eviction.
        assert_eq!(s.stats().expired_keys, 1);
        assert_eq!(s.stats().evictions, 0);
        assert!(s.get(b"a").is_some());
        assert!(s.get(b"b").is_some());
        assert!(s.get(b"c").is_some());
    }

    #[test]
    fn touch_persist_and_ttl() {
        let (mut s, clock) = manual_shard(0);
        assert_eq!(s.ttl_ms(b"k"), TTL_MISSING);
        s.set(b"k", b"v", 0, 0);
        assert_eq!(s.ttl_ms(b"k"), TTL_NO_EXPIRY);
        assert!(s.touch(b"k", 250));
        assert_eq!(s.ttl_ms(b"k"), 250);
        clock.advance(100);
        assert_eq!(s.ttl_ms(b"k"), 150);
        assert!(s.persist(b"k"), "persist clears a live deadline");
        assert_eq!(s.ttl_ms(b"k"), TTL_NO_EXPIRY);
        assert!(!s.persist(b"k"), "nothing left to clear");
        assert!(s.touch(b"k", 50));
        clock.advance(50);
        assert!(!s.touch(b"k", 50), "touching an expired key misses");
        assert_eq!(s.ttl_ms(b"k"), TTL_MISSING);
        assert!(!s.persist(b"missing"));
    }

    #[test]
    fn incr_semantics_with_expiry() {
        let (mut s, clock) = manual_shard(0);
        assert_eq!(s.incr(b"ctr", 5), Ok(5));
        assert_eq!(s.incr(b"ctr", 2), Ok(7));
        assert_eq!(s.get(b"ctr"), Some((0, &b"7"[..])));
        s.set(b"txt", b"abc", 0, 0);
        assert_eq!(s.incr(b"txt", 1), Err(()));
        assert_eq!(s.get(b"txt"), Some((0, &b"abc"[..])), "error leaves value");
        // INCR preserves an existing deadline...
        s.set(b"t", b"1", 0, 100);
        assert_eq!(s.incr(b"t", 1), Ok(2));
        assert_eq!(s.ttl_ms(b"t"), 100);
        // ...and an expired counter restarts from zero.
        clock.advance(100);
        assert_eq!(s.incr(b"t", 3), Ok(3));
        assert_eq!(s.ttl_ms(b"t"), TTL_NO_EXPIRY);
        // Extremes render through the stack buffer unharmed.
        s.set(b"big", i64::MIN.to_string().as_bytes(), 0, 0);
        assert_eq!(s.incr(b"big", 1), Ok(i64::MIN + 1));
        assert_eq!(s.incr(b"big", -1), Ok(i64::MIN));
        assert_eq!(s.incr(b"big", -1), Err(()), "overflow is an error");
        assert_eq!(
            s.get(b"big"),
            Some((0, i64::MIN.to_string().as_bytes())),
            "failed incr leaves the value"
        );
    }

    #[test]
    fn sweep_is_incremental_and_complete() {
        let (mut s, clock) = manual_shard(0);
        for i in 0..200u64 {
            let key = format!("k{i}");
            s.set(key.as_bytes(), b"v", 0, if i % 2 == 0 { 50 } else { 0 });
        }
        clock.advance(50);
        assert_eq!(s.stats().items, 200, "nothing reclaimed yet");
        // Bounded calls make progress and eventually reclaim every
        // expired entry; live entries survive.
        let mut reclaimed = 0;
        for _ in 0..1000 {
            reclaimed += s.sweep(16);
            if reclaimed == 100 {
                break;
            }
        }
        assert_eq!(reclaimed, 100);
        assert_eq!(s.stats().items, 100);
        assert_eq!(s.stats().expired_keys, 100);
        for i in (1..200u64).step_by(2) {
            let key = format!("k{i}");
            assert!(s.get(key.as_bytes()).is_some(), "live key {i} swept");
        }
    }

    #[test]
    fn hostile_ttls_neither_wrap_nor_panic() {
        // Wire-supplied TTLs are attacker-controlled (memcached exptime,
        // RESP EX/PX): the deadline math must saturate, not wrap past
        // the 0 = never sentinel (or overflow-panic a trustee in debug
        // builds), and the TTL query must clamp instead of going
        // negative into sentinel territory.
        let (mut s, clock) = manual_shard(0);
        s.set(b"k", b"v", 0, u64::MAX);
        assert!(s.get(b"k").is_some(), "saturated deadline is 'far future'");
        let ttl = s.ttl_ms(b"k");
        assert_eq!(ttl, i64::MAX, "clamped, not negative: {ttl}");
        clock.advance(10_000);
        assert!(s.get(b"k").is_some());
        assert!(s.touch(b"k", u64::MAX), "touch saturates too");
        assert!(s.ttl_ms(b"k") > 0);
    }

    #[test]
    fn sweep_span_budget_is_one_full_pass_despite_removals() {
        // The cursor walks slab slots, which never relocate: removals
        // consume their own advance, and sweep(sweep_span()) is exactly
        // one full pass however many entries it reclaims.
        let (mut s, clock) = manual_shard(0);
        for i in 0..500u64 {
            s.set(format!("k{i}").as_bytes(), b"v", 0, 10);
        }
        clock.advance(10);
        let swept = s.sweep(s.sweep_span());
        assert_eq!(swept, 500, "one bounded call must finish the pass");
        assert_eq!(s.stats().items, 0);
    }

    #[test]
    fn sweep_full_pass_is_exact_across_free_list_reuse() {
        // Satellite check: after deletes punch free-list holes and new
        // inserts reuse them, one full pass still reclaims every entry
        // that was expired when the pass began — no slot skipped (a
        // skip would strand an entry), none double-counted (reclaimed
        // can never exceed the expired population).
        let (mut s, clock) = manual_shard(0);
        for i in 0..300u64 {
            s.set(format!("k{i}").as_bytes(), b"v", 0, 10);
        }
        // Holes at every third slab slot...
        for i in (0..300u64).step_by(3) {
            assert!(s.del(format!("k{i}").as_bytes()));
        }
        // ...refilled (LIFO) by fresh keys with the same deadline.
        for i in 300..400u64 {
            s.set(format!("k{i}").as_bytes(), b"v", 0, 10);
        }
        let live = s.len() as u64;
        assert_eq!(live, 300, "200 survivors + 100 reused slots");
        assert_eq!(s.sweep_span(), 300, "reuse must not have grown the slab");
        clock.advance(10);
        // Drive the pass in ragged chunks that sum to exactly one span.
        let span = s.sweep_span();
        let mut budget = span;
        let mut reclaimed = 0;
        while budget > 0 {
            let chunk = budget.min(7);
            reclaimed += s.sweep(chunk);
            budget -= chunk;
        }
        assert_eq!(reclaimed, live, "full pass visits every live entry once");
        assert_eq!(s.stats().items, 0);
        assert_eq!(s.sweep(s.sweep_span().max(1)), 0, "second pass finds nothing");
    }

    #[test]
    fn manual_clock_is_deterministic() {
        let c = StoreClock::manual();
        assert!(c.is_manual());
        let t0 = c.now_ms();
        c.advance(41);
        assert_eq!(c.now_ms(), t0 + 41);
        let real = StoreClock::real();
        assert!(!real.is_manual());
    }

    // -- reference-model property test --------------------------------
    //
    // A naive Vec-backed LRU map with the same externally visible
    // semantics (lazy expiry, tail eviction, class-rounded accounting).
    // Every random op sequence must agree on results, victim order
    // (observed through misses), contents, and stats. Runs under Miri
    // via the `kvstore::store::` lib filter.

    struct ModelEntry {
        key: Vec<u8>,
        flags: u32,
        expires: u64,
        data: Vec<u8>,
    }

    /// MRU-first vector: index 0 is the head, the last entry the tail.
    struct ModelStore {
        entries: Vec<ModelEntry>,
        budget: u64,
        bytes: u64,
        evictions: u64,
        expired: u64,
        now: u64,
    }

    impl ModelStore {
        fn new(budget: u64, now: u64) -> ModelStore {
            ModelStore { entries: Vec::new(), budget, bytes: 0, evictions: 0, expired: 0, now }
        }

        fn cost(e: &ModelEntry) -> u64 {
            entry_cost(e.key.len(), e.data.len())
        }

        fn is_expired(e: &ModelEntry, now: u64) -> bool {
            e.expires != 0 && e.expires <= now
        }

        fn find(&self, key: &[u8]) -> Option<usize> {
            self.entries.iter().position(|e| e.key == key)
        }

        fn remove_idx(&mut self, i: usize) -> ModelEntry {
            let e = self.entries.remove(i);
            self.bytes -= Self::cost(&e);
            e
        }

        fn evict_to_budget(&mut self) {
            if self.budget == 0 {
                return;
            }
            while self.bytes > self.budget && !self.entries.is_empty() {
                let e = self.remove_idx(self.entries.len() - 1);
                if Self::is_expired(&e, self.now) {
                    self.expired += 1;
                } else {
                    self.evictions += 1;
                }
            }
        }

        fn get(&mut self, key: &[u8]) -> Option<(u32, Vec<u8>)> {
            let i = self.find(key)?;
            if Self::is_expired(&self.entries[i], self.now) {
                self.remove_idx(i);
                self.expired += 1;
                return None;
            }
            let e = self.entries.remove(i);
            let out = (e.flags, e.data.clone());
            self.entries.insert(0, e);
            Some(out)
        }

        fn set(&mut self, key: &[u8], val: &[u8], flags: u32, ttl: u64) -> bool {
            let expires = if ttl == 0 { 0 } else { self.now.saturating_add(ttl) };
            let existed = match self.find(key) {
                Some(i) => {
                    let was_expired = Self::is_expired(&self.entries[i], self.now);
                    if was_expired {
                        self.expired += 1;
                    }
                    let mut e = self.remove_idx(i);
                    e.data = val.to_vec();
                    e.flags = flags;
                    e.expires = expires;
                    self.bytes += Self::cost(&e);
                    self.entries.insert(0, e);
                    !was_expired
                }
                None => {
                    let e = ModelEntry { key: key.to_vec(), flags, expires, data: val.to_vec() };
                    self.bytes += Self::cost(&e);
                    self.entries.insert(0, e);
                    false
                }
            };
            self.evict_to_budget();
            existed
        }

        fn del(&mut self, key: &[u8]) -> bool {
            let Some(i) = self.find(key) else { return false };
            let e = self.remove_idx(i);
            if Self::is_expired(&e, self.now) {
                self.expired += 1;
                false
            } else {
                true
            }
        }

        fn touch(&mut self, key: &[u8], ttl: u64) -> bool {
            let Some(i) = self.find(key) else { return false };
            if Self::is_expired(&self.entries[i], self.now) {
                self.remove_idx(i);
                self.expired += 1;
                return false;
            }
            let mut e = self.entries.remove(i);
            e.expires = if ttl == 0 { 0 } else { self.now.saturating_add(ttl) };
            self.entries.insert(0, e);
            true
        }
    }

    #[test]
    fn prop_shard_matches_naive_lru_model() {
        use crate::util::quickcheck::check;
        // Budget fits ~5 small entries, so eviction fires constantly;
        // 8 possible keys force overwrite/reuse collisions.
        check::<Vec<(u8, u8, u8)>>("shard-vs-lru-model", 80, |ops| {
            let clock = StoreClock::manual();
            let budget = 5 * entry_cost(1, 8);
            let cfg = StoreConfig { budget_bytes: budget, clock: clock.clone() };
            let mut shard = ItemShard::new(&cfg);
            let mut model = ModelStore::new(budget, clock.now_ms());
            for &(op, k, v) in ops {
                let key = [k % 8];
                match op % 6 {
                    0 | 1 => {
                        let val = vec![v; (v as usize % 24) + 1];
                        let ttl = if v % 3 == 0 { 0 } else { v as u64 };
                        let a = shard.set(&key, &val, v as u32, ttl);
                        let b = model.set(&key, &val, v as u32, ttl);
                        if a != b {
                            return false;
                        }
                    }
                    2 => {
                        let a = shard.get(&key).map(|(f, d)| (f, d.to_vec()));
                        if a != model.get(&key) {
                            return false;
                        }
                    }
                    3 => {
                        if shard.del(&key) != model.del(&key) {
                            return false;
                        }
                    }
                    4 => {
                        if shard.touch(&key, v as u64) != model.touch(&key, v as u64) {
                            return false;
                        }
                    }
                    _ => {
                        clock.advance(v as u64 % 8);
                        model.now = clock.now_ms();
                    }
                }
                let s = shard.stats();
                if (s.items, s.store_bytes, s.evictions, s.expired_keys)
                    != (model.entries.len() as u64, model.bytes, model.evictions, model.expired)
                {
                    return false;
                }
            }
            // Final contents agree entry-for-entry (peek leaves LRU
            // order untouched), and internal accounting is consistent.
            let now = clock.now_ms();
            model.entries.iter().all(|e| {
                let live = !ModelStore::is_expired(e, now);
                match shard.peek(&e.key) {
                    Some((f, d)) => live && f == e.flags && d == &e.data[..],
                    None => !live,
                }
            }) && shard.len() == model.entries.len()
        });
    }
}
