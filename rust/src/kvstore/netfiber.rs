//! Non-blocking socket helpers for fibers: a connection fiber reads and
//! writes without ever blocking its worker thread, yielding to the fiber
//! scheduler (which runs trustee work and other connections) whenever the
//! socket has no progress to offer.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;

/// Outcome of one read attempt.
pub enum ReadOutcome {
    /// `n` bytes appended to the buffer.
    Data(usize),
    /// Socket has nothing right now (caller should yield).
    WouldBlock,
    /// Peer closed or connection errored.
    Closed,
}

/// Read whatever is available into `buf` (append).
pub fn read_available(stream: &mut TcpStream, buf: &mut Vec<u8>) -> ReadOutcome {
    let mut chunk = [0u8; 16 * 1024];
    match stream.read(&mut chunk) {
        Ok(0) => ReadOutcome::Closed,
        Ok(n) => {
            buf.extend_from_slice(&chunk[..n]);
            ReadOutcome::Data(n)
        }
        Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted => {
            ReadOutcome::WouldBlock
        }
        Err(_) => ReadOutcome::Closed,
    }
}

/// Write as much of `buf[*cursor..]` as the socket accepts; advances
/// `cursor`. Returns false if the connection died. When the whole buffer
/// drains, both buffer and cursor reset.
pub fn write_pending(stream: &mut TcpStream, buf: &mut Vec<u8>, cursor: &mut usize) -> bool {
    while *cursor < buf.len() {
        match stream.write(&buf[*cursor..]) {
            Ok(0) => return false,
            Ok(n) => *cursor += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::Interrupted => {
                break;
            }
            Err(_) => return false,
        }
    }
    if *cursor == buf.len() && !buf.is_empty() {
        buf.clear();
        *cursor = 0;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn echo_over_nonblocking_pair() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            s.set_nonblocking(true).unwrap();
            let mut inbuf = Vec::new();
            let mut out = Vec::new();
            let mut cur = 0usize;
            loop {
                match read_available(&mut s, &mut inbuf) {
                    ReadOutcome::Data(_) => {
                        out.extend_from_slice(&inbuf);
                        inbuf.clear();
                    }
                    ReadOutcome::WouldBlock => std::thread::yield_now(),
                    ReadOutcome::Closed => break,
                }
                if !write_pending(&mut s, &mut out, &mut cur) {
                    break;
                }
            }
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"hello fiber net").unwrap();
        let mut back = [0u8; 15];
        c.read_exact(&mut back).unwrap();
        assert_eq!(&back, b"hello fiber net");
        drop(c);
        t.join().unwrap();
    }
}
