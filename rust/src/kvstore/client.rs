//! Load-generating TCP client for the KV store benchmarks (§6.3): "The TCP
//! client continuously maintains a queue of parallel queries over the
//! socket, such that the server always has new requests to serve", with
//! out-of-order response acceptance and per-request latency tracking.
//!
//! The connection loop is the shared [`crate::loadgen`] skeleton; this
//! module contributes only the binary-KV [`LoadDriver`] (id-tagged frames
//! matched out of order, per-request latency recorded by id). I/O
//! failures (a server dropping the connection mid-run, malformed response
//! frames) are surfaced in [`LoadStats::errors`] with the thread and
//! progress context, instead of panicking the client thread.

use super::proto::{self, FrameCursor};
use crate::loadgen::{run_pipelined_loader_opts, LoadDriver, Reply};
use crate::util::stats::LatencyHist;
use crate::util::{KeyDist, Rng};
use std::collections::HashMap;
use std::time::Instant;

/// 8-byte key encoding shared by client and prefill (paper: "The key size
/// is 8 bytes and the value size is 16 bytes").
pub fn key_bytes(k: u64) -> Vec<u8> {
    k.to_le_bytes().to_vec()
}

/// Workload configuration for one run.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    pub addr: std::net::SocketAddr,
    /// Concurrent client threads (each with its own connection).
    pub threads: usize,
    /// Outstanding requests per connection.
    pub pipeline: usize,
    /// Total operations per thread.
    pub ops_per_thread: u64,
    /// Key space size and distribution spec ("uniform" | "zipf[:a]").
    pub keys: u64,
    pub dist: String,
    /// Percentage of writes (rest are reads).
    pub write_pct: u32,
    pub val_len: usize,
    pub seed: u64,
    /// Re-issue requests the server shed with `ST_OVERLOADED` (bounded;
    /// off = count them as valueless completions).
    pub retry_shed: bool,
}

/// Aggregated results. `errors` holds one descriptive entry per client
/// thread that failed; operations completed before the failure still
/// count toward `ops`/`hist`.
pub struct LoadStats {
    pub ops: u64,
    pub elapsed: std::time::Duration,
    pub hist: LatencyHist,
    pub hits: u64,
    pub misses: u64,
    /// Requests the server answered with `ST_OVERLOADED`.
    pub shed: u64,
    pub errors: Vec<String>,
}

impl LoadStats {
    pub fn throughput(&self) -> f64 {
        self.ops as f64 / self.elapsed.as_secs_f64()
    }

    /// True when every client thread ran to completion.
    pub fn ok(&self) -> bool {
        self.errors.is_empty()
    }
}

/// Per-thread result: stats so far plus the error that ended the run
/// early, if any.
struct ThreadResult {
    ops: u64,
    hist: LatencyHist,
    hits: u64,
    misses: u64,
    shed: u64,
    error: Option<String>,
}

/// Run the workload; returns aggregate stats (never panics on I/O).
pub fn run_load(cfg: &LoadConfig) -> LoadStats {
    let start = Instant::now();
    let handles: Vec<_> = (0..cfg.threads)
        .map(|t| {
            let cfg = cfg.clone();
            std::thread::spawn(move || run_one_connection(&cfg, t as u64))
        })
        .collect();
    let mut hist = LatencyHist::new();
    let mut ops = 0;
    let mut hits = 0;
    let mut misses = 0;
    let mut shed = 0;
    let mut errors = Vec::new();
    for (t, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok(r) => {
                ops += r.ops;
                hits += r.hits;
                misses += r.misses;
                shed += r.shed;
                hist.merge(&r.hist);
                if let Some(e) = r.error {
                    errors.push(format!("client thread {t}: {e}"));
                }
            }
            Err(_) => errors.push(format!("client thread {t} panicked")),
        }
    }
    LoadStats { ops, elapsed: start.elapsed(), hist, hits, misses, shed, errors }
}

/// The binary-KV wire format plugged into the shared loader skeleton:
/// id-tagged frames, responses matched (and latency recorded) by id in
/// whatever order the server answers.
struct KvDriver {
    rng: Rng,
    dist: KeyDist,
    write_pct: u32,
    val: Vec<u8>,
    next_id: u64,
    in_flight: HashMap<u64, Instant>,
    hist: LatencyHist,
}

impl LoadDriver for KvDriver {
    fn encode_next(&mut self, out: &mut Vec<u8>) {
        let key = key_bytes(self.dist.sample(&mut self.rng));
        let id = self.next_id;
        self.next_id += 1;
        if self.rng.pct(self.write_pct) {
            proto::write_request(out, id, proto::OP_PUT, &key, &self.val);
        } else {
            proto::write_request(out, id, proto::OP_GET, &key, &[]);
        }
        self.in_flight.insert(id, Instant::now());
    }

    fn parse_reply(&mut self, buf: &[u8]) -> Result<Option<Reply>, String> {
        let mut cursor = FrameCursor::new();
        let resp = match cursor.next_response(buf) {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(None),
            Err(e) => return Err(format!("malformed response from server: {e}")),
        };
        let Some(t0) = self.in_flight.remove(&resp.id) else {
            return Err(format!("response for unknown request id {}", resp.id));
        };
        self.hist.record(t0.elapsed().as_nanos() as u64);
        if resp.status == proto::ST_OVERLOADED {
            return Ok(Some(Reply::shed(cursor.consumed)));
        }
        Ok(Some(Reply::ok(cursor.consumed, resp.status == proto::ST_OK)))
    }
}

fn run_one_connection(cfg: &LoadConfig, tid: u64) -> ThreadResult {
    let mut driver = KvDriver {
        rng: Rng::new(cfg.seed ^ (tid.wrapping_mul(0x9E37_79B9))),
        dist: KeyDist::from_spec(&cfg.dist, cfg.keys),
        write_pct: cfg.write_pct,
        val: vec![b'x'; cfg.val_len],
        next_id: 0,
        in_flight: HashMap::new(),
        hist: LatencyHist::new(),
    };
    let r = run_pipelined_loader_opts(
        cfg.addr,
        cfg.pipeline,
        cfg.ops_per_thread,
        &mut driver,
        cfg.retry_shed,
    );
    ThreadResult {
        ops: r.done,
        hist: driver.hist,
        hits: r.hits,
        misses: r.misses,
        shed: r.shed,
        error: r.error,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::backend::BackendKind;
    use crate::kvstore::server::{KvServer, KvServerConfig};

    #[test]
    fn load_generator_end_to_end() {
        let server = KvServer::start(KvServerConfig {
            workers: 3,
            backend: BackendKind::Trust { shards: 3 },
            ..Default::default()
        });
        server.prefill(100, 16);
        let stats = run_load(&LoadConfig {
            addr: server.addr(),
            threads: 2,
            pipeline: 16,
            ops_per_thread: 500,
            keys: 100,
            dist: "uniform".into(),
            write_pct: 5,
            val_len: 16,
            seed: 42,
            retry_shed: false,
        });
        assert!(stats.ok(), "client errors: {:?}", stats.errors);
        assert_eq!(stats.ops, 1000);
        // Table was prefilled: reads must hit.
        assert_eq!(stats.misses, 0, "prefilled keys must not miss");
        assert!(stats.throughput() > 0.0);
        assert!(stats.hist.quantile(0.999) >= stats.hist.quantile(0.5));
        server.stop();
    }

    #[test]
    fn zipf_load_against_lock_backend() {
        let server = KvServer::start(KvServerConfig {
            workers: 2,
            backend: BackendKind::Swift,
            ..Default::default()
        });
        server.prefill(1000, 16);
        let stats = run_load(&LoadConfig {
            addr: server.addr(),
            threads: 2,
            pipeline: 8,
            ops_per_thread: 300,
            keys: 1000,
            dist: "zipf".into(),
            write_pct: 50,
            val_len: 16,
            seed: 7,
            retry_shed: false,
        });
        assert!(stats.ok(), "client errors: {:?}", stats.errors);
        assert_eq!(stats.ops, 600);
        assert_eq!(stats.misses, 0);
        server.stop();
    }

    #[test]
    fn connection_refused_is_an_error_not_a_panic() {
        // Nothing listens here: the run must come back with a descriptive
        // error for every thread instead of aborting the process.
        let stats = run_load(&LoadConfig {
            addr: "127.0.0.1:1".parse().unwrap(),
            threads: 2,
            pipeline: 4,
            ops_per_thread: 10,
            keys: 10,
            dist: "uniform".into(),
            write_pct: 0,
            val_len: 8,
            seed: 1,
            retry_shed: false,
        });
        assert_eq!(stats.ops, 0);
        assert_eq!(stats.errors.len(), 2);
        for e in &stats.errors {
            assert!(e.contains("connect"), "unhelpful error: {e}");
            assert!(e.contains("0/10 ops"), "missing progress context: {e}");
        }
    }

    #[test]
    fn server_dropping_mid_run_fails_descriptively() {
        // Start a real server, run a long load, stop the server under it:
        // threads must report the dropped connection, not abort.
        let server = KvServer::start(KvServerConfig {
            workers: 2,
            backend: BackendKind::Trust { shards: 2 },
            ..Default::default()
        });
        server.prefill(10, 16);
        let addr = server.addr();
        let loader = std::thread::spawn(move || {
            run_load(&LoadConfig {
                addr,
                threads: 1,
                pipeline: 8,
                ops_per_thread: u64::MAX / 2, // effectively endless
                keys: 10,
                dist: "uniform".into(),
                write_pct: 5,
                val_len: 16,
                seed: 3,
                retry_shed: false,
            })
        });
        // Let it get going, then yank the server.
        std::thread::sleep(std::time::Duration::from_millis(200));
        server.stop();
        let stats = loader.join().unwrap();
        assert_eq!(stats.errors.len(), 1, "expected one failed thread");
        assert!(
            stats.errors[0].contains("server closed")
                || stats.errors[0].contains("read:")
                || stats.errors[0].contains("write:"),
            "unhelpful error: {}",
            stats.errors[0]
        );
    }
}
