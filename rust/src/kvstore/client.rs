//! Load-generating TCP client for the KV store benchmarks (§6.3): "The TCP
//! client continuously maintains a queue of parallel queries over the
//! socket, such that the server always has new requests to serve", with
//! out-of-order response acceptance and per-request latency tracking.

use super::proto::{self, FrameCursor};
use crate::util::stats::LatencyHist;
use crate::util::{KeyDist, Rng};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Instant;

/// 8-byte key encoding shared by client and prefill (paper: "The key size
/// is 8 bytes and the value size is 16 bytes").
pub fn key_bytes(k: u64) -> Vec<u8> {
    k.to_le_bytes().to_vec()
}

/// Workload configuration for one run.
#[derive(Clone, Debug)]
pub struct LoadConfig {
    pub addr: std::net::SocketAddr,
    /// Concurrent client threads (each with its own connection).
    pub threads: usize,
    /// Outstanding requests per connection.
    pub pipeline: usize,
    /// Total operations per thread.
    pub ops_per_thread: u64,
    /// Key space size and distribution spec ("uniform" | "zipf[:a]").
    pub keys: u64,
    pub dist: String,
    /// Percentage of writes (rest are reads).
    pub write_pct: u32,
    pub val_len: usize,
    pub seed: u64,
}

/// Aggregated results.
pub struct LoadStats {
    pub ops: u64,
    pub elapsed: std::time::Duration,
    pub hist: LatencyHist,
    pub hits: u64,
    pub misses: u64,
}

impl LoadStats {
    pub fn throughput(&self) -> f64 {
        self.ops as f64 / self.elapsed.as_secs_f64()
    }
}

/// Run the workload; returns aggregate stats.
pub fn run_load(cfg: &LoadConfig) -> LoadStats {
    let start = Instant::now();
    let handles: Vec<_> = (0..cfg.threads)
        .map(|t| {
            let cfg = cfg.clone();
            std::thread::spawn(move || run_one_connection(&cfg, t as u64))
        })
        .collect();
    let mut hist = LatencyHist::new();
    let mut ops = 0;
    let mut hits = 0;
    let mut misses = 0;
    for h in handles {
        let (h_ops, h_hist, h_hits, h_misses) = h.join().expect("client thread");
        ops += h_ops;
        hits += h_hits;
        misses += h_misses;
        hist.merge(&h_hist);
    }
    LoadStats { ops, elapsed: start.elapsed(), hist, hits, misses }
}

fn run_one_connection(cfg: &LoadConfig, tid: u64) -> (u64, LatencyHist, u64, u64) {
    let mut rng = Rng::new(cfg.seed ^ (tid.wrapping_mul(0x9E37_79B9)));
    let dist = KeyDist::from_spec(&cfg.dist, cfg.keys);
    let mut stream = TcpStream::connect(cfg.addr).expect("connect");
    stream.set_nodelay(true).ok();
    stream.set_nonblocking(true).expect("nonblocking");

    let mut hist = LatencyHist::new();
    let mut sent = 0u64;
    let mut done = 0u64;
    let mut hits = 0u64;
    let mut misses = 0u64;
    let mut next_id = 0u64;
    let mut in_flight: HashMap<u64, Instant> = HashMap::new();
    let mut out = Vec::with_capacity(64 * 1024);
    let mut wcur = 0usize;
    let mut inbuf = Vec::with_capacity(64 * 1024);
    let mut cursor = FrameCursor::new();
    let val = vec![b'x'; cfg.val_len];

    while done < cfg.ops_per_thread {
        // Top up the pipeline.
        while sent < cfg.ops_per_thread && in_flight.len() < cfg.pipeline {
            let key = key_bytes(dist.sample(&mut rng));
            let id = next_id;
            next_id += 1;
            if rng.pct(cfg.write_pct) {
                proto::write_request(&mut out, id, proto::OP_PUT, &key, &val);
            } else {
                proto::write_request(&mut out, id, proto::OP_GET, &key, &[]);
            }
            in_flight.insert(id, Instant::now());
            sent += 1;
        }
        // Flush writes (partial ok).
        loop {
            if wcur >= out.len() {
                out.clear();
                wcur = 0;
                break;
            }
            match stream.write(&out[wcur..]) {
                Ok(0) => panic!("server closed"),
                Ok(n) => wcur += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => panic!("write: {e}"),
            }
        }
        // Drain responses.
        let mut chunk = [0u8; 32 * 1024];
        match stream.read(&mut chunk) {
            Ok(0) => panic!("server closed"),
            Ok(n) => inbuf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(e) => panic!("read: {e}"),
        }
        while let Some(resp) = cursor
            .next_response(&inbuf)
            .expect("malformed response from server")
        {
            let t0 = in_flight.remove(&resp.id).expect("unexpected response id");
            hist.record(t0.elapsed().as_nanos() as u64);
            if resp.status == proto::ST_OK {
                hits += 1;
            } else {
                misses += 1;
            }
            done += 1;
        }
        proto::compact(&mut inbuf, &mut cursor);
    }
    (done, hist, hits, misses)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::backend::BackendKind;
    use crate::kvstore::server::{KvServer, KvServerConfig};

    #[test]
    fn load_generator_end_to_end() {
        let server = KvServer::start(KvServerConfig {
            workers: 3,
            backend: BackendKind::Trust { shards: 3 },
            ..Default::default()
        });
        server.prefill(100, 16);
        let stats = run_load(&LoadConfig {
            addr: server.addr(),
            threads: 2,
            pipeline: 16,
            ops_per_thread: 500,
            keys: 100,
            dist: "uniform".into(),
            write_pct: 5,
            val_len: 16,
            seed: 42,
        });
        assert_eq!(stats.ops, 1000);
        // Table was prefilled: reads must hit.
        assert_eq!(stats.misses, 0, "prefilled keys must not miss");
        assert!(stats.throughput() > 0.0);
        assert!(stats.hist.quantile(0.999) >= stats.hist.quantile(0.5));
        server.stop();
    }

    #[test]
    fn zipf_load_against_lock_backend() {
        let server = KvServer::start(KvServerConfig {
            workers: 2,
            backend: BackendKind::Swift,
            ..Default::default()
        });
        server.prefill(1000, 16);
        let stats = run_load(&LoadConfig {
            addr: server.addr(),
            threads: 2,
            pipeline: 8,
            ops_per_thread: 300,
            keys: 1000,
            dist: "zipf".into(),
            write_pct: 50,
            val_len: 16,
            seed: 7,
        });
        assert_eq!(stats.ops, 600);
        assert_eq!(stats.misses, 0);
        server.stop();
    }
}
