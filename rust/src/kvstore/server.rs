//! The multi-threaded TCP key-value server (§6.3), as a [`Protocol`]
//! front end on the shared delegated server core
//! ([`crate::server::engine`]).
//!
//! "Each worker-thread receives GET or PUT queries from one or more
//! connections, and applies these to the backend hashmap. Both reading
//! requests and sending results is done in batches ... the client accepts
//! responses out-of-order." The engine owns the connection loop (ingest,
//! backpressure, spooling, drain-on-stop); this module contributes only
//! the wire protocol: id-tagged binary frames parsed by
//! [`proto::FrameCursor`], dispatched to an [`AsyncKv`] backend, completed
//! **out of order** as their delegations finish
//! ([`ResponseOrder::OutOfOrder`]).

use super::backend::{AsyncKv, BackendKind};
use super::proto::{self, FrameCursor, ProtoError};
use crate::runtime::Runtime;
use crate::server::engine::{
    Completion, ConnMetrics, CoreConfig, Inbuf, Protocol, ResponseOrder, ServerCore, ServerTuning,
};
use crate::server::netfiber::{self, NetPolicy};
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct KvServerConfig {
    pub workers: usize,
    /// Dedicated trustee workers (shards live there; no socket fibers).
    pub dedicated: usize,
    pub backend: BackendKind,
    pub addr: String,
    /// How connection fibers wait for socket progress.
    pub net: NetPolicy,
    /// Overload-control and degradation knobs (shed watermarks, request
    /// deadline, stalled-connection reaping, stop-drain grace).
    pub tuning: ServerTuning,
}

impl Default for KvServerConfig {
    fn default() -> Self {
        KvServerConfig {
            workers: 4,
            dedicated: 0,
            backend: BackendKind::Trust { shards: 0 },
            addr: "127.0.0.1:0".into(),
            net: NetPolicy::default(),
            tuning: ServerTuning::default(),
        }
    }
}

impl KvServerConfig {
    /// Check the topology *before* any runtime is built: every
    /// misconfiguration that previously died on an internal assert after
    /// worker threads were already spawned reports here instead.
    pub fn validate(&self) -> Result<(), String> {
        netfiber::validate_topology(self.workers, self.dedicated)?;
        self.tuning.validate()
    }
}

/// Why a KV byte stream turned bad. Rendered by
/// [`KvProtocol::render_error`] as an [`proto::ST_BAD_REQUEST`] response
/// (so well-meaning-but-buggy clients see *why*) before the engine drains
/// and closes the connection.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KvFault {
    /// Framing is broken; there is no trustworthy request id to answer
    /// to, so the response carries id `u64::MAX` and the reason text.
    Frame(ProtoError),
    /// Syntactically valid frame with an op we do not speak.
    UnknownOp { id: u64 },
}

/// The binary KV wire protocol on the shared engine.
pub struct KvProtocol {
    backend: Arc<dyn AsyncKv>,
}

impl KvProtocol {
    pub fn new(backend: Arc<dyn AsyncKv>) -> KvProtocol {
        KvProtocol { backend }
    }
}

impl Protocol for KvProtocol {
    type Request = proto::Request;
    type Error = KvFault;

    /// Requests carry 64-bit ids; the client matches responses, so each
    /// one ships as soon as its delegation completes.
    const ORDER: ResponseOrder = ResponseOrder::OutOfOrder;

    fn parse(&mut self, inbuf: &mut Inbuf) -> Result<Option<proto::Request>, KvFault> {
        let mut cursor = FrameCursor::new();
        match cursor.next_request(inbuf.unparsed()) {
            Ok(Some(req)) => {
                inbuf.advance(cursor.consumed);
                if !matches!(req.op, proto::OP_GET | proto::OP_PUT | proto::OP_DEL) {
                    return Err(KvFault::UnknownOp { id: req.id });
                }
                Ok(Some(req))
            }
            Ok(None) => Ok(None),
            Err(e) => Err(KvFault::Frame(e)),
        }
    }

    fn render_overload(&mut self, req: &proto::Request, out: &mut Vec<u8>) -> bool {
        proto::write_response(out, req.id, proto::ST_OVERLOADED, &[]);
        true
    }

    fn render_error(&mut self, err: &KvFault, out: &mut Vec<u8>) {
        match err {
            KvFault::UnknownOp { id } => {
                proto::write_response(out, *id, proto::ST_BAD_REQUEST, &[]);
            }
            KvFault::Frame(e) => {
                let reason = e.to_string();
                proto::write_response(out, u64::MAX, proto::ST_BAD_REQUEST, reason.as_bytes());
            }
        }
    }

    fn dispatch(&mut self, req: proto::Request, done: Completion) {
        use crate::kvstore::backend::{AckCb, GetCb};
        let id = req.id;
        match req.op {
            proto::OP_GET => self.backend.get(
                // Key borrowed from the parsed request; the value arrives
                // borrowed from the backend and is copied exactly once,
                // straight into the pooled wire buffer (one-copy GET).
                &req.key,
                GetCb::new(move |v: Option<&[u8]>| {
                    let mut b = done.checkout();
                    match v {
                        Some(val) => proto::write_response(&mut b, id, proto::ST_OK, val),
                        None => proto::write_response(&mut b, id, proto::ST_NOT_FOUND, &[]),
                    }
                    done.complete(b);
                }),
            ),
            proto::OP_PUT => self.backend.put(
                &req.key,
                &req.val,
                AckCb::new(move |_| {
                    let mut b = done.checkout();
                    proto::write_response(&mut b, id, proto::ST_OK, &[]);
                    done.complete(b);
                }),
            ),
            _ => self.backend.del(
                &req.key,
                AckCb::new(move |existed| {
                    let st = if existed { proto::ST_OK } else { proto::ST_NOT_FOUND };
                    let mut b = done.checkout();
                    proto::write_response(&mut b, id, st, &[]);
                    done.complete(b);
                }),
            ),
        }
    }
}

/// A running KV server (owns its runtime and accept path via the shared
/// [`ServerCore`]).
pub struct KvServer {
    core: ServerCore,
    backend: Arc<dyn AsyncKv>,
    pub ops_served: Arc<AtomicU64>,
}

impl KvServer {
    /// Start a server, panicking on an invalid configuration (see
    /// [`KvServer::try_start`] for the fallible form).
    pub fn start(cfg: KvServerConfig) -> KvServer {
        Self::try_start(cfg).unwrap_or_else(|e| panic!("invalid KvServerConfig: {e}"))
    }

    /// Start a server, reporting configuration/bind problems as a
    /// descriptive error *before* any worker thread is spawned.
    pub fn try_start(cfg: KvServerConfig) -> Result<KvServer, String> {
        let mut backend_out: Option<Arc<dyn AsyncKv>> = None;
        let core = ServerCore::try_start(
            CoreConfig {
                workers: cfg.workers,
                dedicated: cfg.dedicated,
                addr: cfg.addr.clone(),
                net: cfg.net,
                tuning: cfg.tuning,
            },
            "kv-accept",
            |rt, trustees| {
                let backend = cfg.backend.build(rt, trustees);
                backend_out = Some(backend.clone());
                move || KvProtocol::new(backend.clone())
            },
        )?;
        let ops_served = core.ops_served().clone();
        Ok(KvServer { core, backend: backend_out.unwrap(), ops_served })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.core.addr()
    }

    pub fn backend(&self) -> &Arc<dyn AsyncKv> {
        &self.backend
    }

    pub fn runtime(&self) -> &Runtime {
        self.core.runtime()
    }

    /// Per-worker connection metrics (accepted/closed/requests/pool).
    pub fn metrics(&self) -> &Arc<ConnMetrics> {
        self.core.metrics()
    }

    /// Delegation-layer hot-path allocation/copy counters (diagnostic).
    pub fn hot_path_stats(&self) -> crate::runtime::HotPathStats {
        self.core.hot_path_stats()
    }

    /// io_uring submission/completion counters across all workers
    /// (zeros unless running under `NetPolicy::IoUring`; diagnostic).
    pub fn uring_stats(&self) -> crate::runtime::uring::UringStats {
        self.core.uring_stats()
    }

    /// The settled network plane (requested vs resolved policy, data-
    /// plane capability, fallback reason).
    pub fn net_info(&self) -> &crate::server::netfiber::NetInfo {
        self.core.net_info()
    }

    /// Item-store counters (items, bytes, evictions, expirations, plus
    /// the value-slab pool hit/miss and fragmentation gauges).
    pub fn store_stats(&self) -> crate::kvstore::store::StoreStats {
        self.backend.store_stats()
    }

    /// Pre-fill the table with `n` keys ("Prior to each run, we pre-fill
    /// the table"). Key format matches the load generator's.
    pub fn prefill(&self, n: u64, val_len: usize) {
        let backend = self.backend.clone();
        self.core.prefill(n, move |i, on_done| {
            backend.put(
                &super::client::key_bytes(i),
                &vec![b'x'; val_len],
                crate::kvstore::backend::AckCb::new(move |_| on_done()),
            );
        });
    }

    pub fn stop(mut self) {
        self.core.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::sync::atomic::Ordering;

    fn get(stream: &mut TcpStream, id: u64, key: &[u8]) -> proto::Response {
        let mut buf = Vec::new();
        proto::write_request(&mut buf, id, proto::OP_GET, key, &[]);
        stream.write_all(&buf).unwrap();
        read_one_response(stream)
    }

    fn put(stream: &mut TcpStream, id: u64, key: &[u8], val: &[u8]) -> proto::Response {
        let mut buf = Vec::new();
        proto::write_request(&mut buf, id, proto::OP_PUT, key, val);
        stream.write_all(&buf).unwrap();
        read_one_response(stream)
    }

    fn read_one_response(stream: &mut TcpStream) -> proto::Response {
        let mut buf = Vec::new();
        let mut cursor = FrameCursor::new();
        let mut chunk = [0u8; 4096];
        loop {
            if let Some(r) = cursor.next_response(&buf).unwrap() {
                return r;
            }
            let n = stream.read(&mut chunk).unwrap();
            assert!(n > 0, "server closed early");
            buf.extend_from_slice(&chunk[..n]);
        }
    }

    fn smoke(backend: BackendKind) {
        let server = KvServer::start(KvServerConfig {
            workers: 2,
            dedicated: 0,
            backend,
            addr: "127.0.0.1:0".into(),
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        // miss, put, hit, overwrite, delete
        assert_eq!(get(&mut c, 1, b"k").status, proto::ST_NOT_FOUND);
        assert_eq!(put(&mut c, 2, b"k", b"v1").status, proto::ST_OK);
        let r = get(&mut c, 3, b"k");
        assert_eq!((r.status, r.val.as_slice()), (proto::ST_OK, &b"v1"[..]));
        assert_eq!(put(&mut c, 4, b"k", b"v2").status, proto::ST_OK);
        let r = get(&mut c, 5, b"k");
        assert_eq!(r.val, b"v2");
        drop(c);
        assert_eq!(server.ops_served.load(Ordering::Relaxed), 5);
        server.stop();
    }

    #[test]
    fn trust_server_smoke() {
        smoke(BackendKind::Trust { shards: 2 });
    }

    #[test]
    fn mutex_server_smoke() {
        smoke(BackendKind::Mutex);
    }

    #[test]
    fn pipelined_out_of_order_ids_match() {
        let server = KvServer::start(KvServerConfig {
            workers: 2,
            backend: BackendKind::Trust { shards: 2 },
            ..Default::default()
        });
        server.prefill(100, 16);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        // Fire 50 pipelined GETs, then collect all 50 responses by id.
        let mut buf = Vec::new();
        for i in 0..50u64 {
            proto::write_request(
                &mut buf,
                1000 + i,
                proto::OP_GET,
                &super::super::client::key_bytes(i % 100),
                &[],
            );
        }
        c.write_all(&buf).unwrap();
        let mut seen = std::collections::HashSet::new();
        let mut rbuf = Vec::new();
        let mut cursor = FrameCursor::new();
        let mut chunk = [0u8; 8192];
        while seen.len() < 50 {
            if let Some(r) = cursor.next_response(&rbuf).unwrap() {
                assert_eq!(r.status, proto::ST_OK);
                assert!(seen.insert(r.id), "duplicate id {}", r.id);
                assert!((1000..1050).contains(&r.id));
                continue;
            }
            let n = c.read(&mut chunk).unwrap();
            assert!(n > 0);
            rbuf.extend_from_slice(&chunk[..n]);
        }
        drop(c);
        server.stop();
    }

    #[test]
    fn multiple_connections_concurrent() {
        let server = KvServer::start(KvServerConfig {
            workers: 3,
            backend: BackendKind::Trust { shards: 3 },
            ..Default::default()
        });
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut c = TcpStream::connect(addr).unwrap();
                    for i in 0..50u64 {
                        let key = format!("t{t}-k{i}").into_bytes();
                        assert_eq!(put(&mut c, i, &key, b"val").status, proto::ST_OK);
                        let r = get(&mut c, 1000 + i, &key);
                        assert_eq!(r.val, b"val");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.backend().len(), 200);
        server.stop();
    }

    #[test]
    fn dedicated_trustee_topology() {
        let server = KvServer::start(KvServerConfig {
            workers: 3,
            dedicated: 1,
            backend: BackendKind::Trust { shards: 2 },
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        assert_eq!(put(&mut c, 1, b"a", b"b").status, proto::ST_OK);
        assert_eq!(get(&mut c, 2, b"a").val, b"b");
        drop(c);
        server.stop();
    }

    #[test]
    fn invalid_topology_is_a_descriptive_error_not_a_late_assert() {
        // dedicated >= workers used to die on an internal assert after the
        // runtime was already built; now it is a validation error up front.
        let err = KvServer::try_start(KvServerConfig {
            workers: 2,
            dedicated: 2,
            ..Default::default()
        })
        .err()
        .expect("must be rejected");
        assert!(err.contains("socket worker"), "unhelpful error: {err}");

        let err = KvServer::try_start(KvServerConfig {
            workers: 0,
            ..Default::default()
        })
        .err()
        .expect("must be rejected");
        assert!(err.contains("workers"), "unhelpful error: {err}");
    }

    #[test]
    fn unknown_op_answers_bad_request_and_closes() {
        for net in [NetPolicy::BusyPoll, NetPolicy::Epoll, NetPolicy::IoUring] {
            let server = KvServer::start(KvServerConfig {
                workers: 2,
                backend: BackendKind::Trust { shards: 2 },
                net,
                ..Default::default()
            });
            let mut c = TcpStream::connect(server.addr()).unwrap();
            // A valid request first, then one with an unknown op.
            assert_eq!(put(&mut c, 1, b"k", b"v").status, proto::ST_OK);
            let mut buf = Vec::new();
            proto::write_request(&mut buf, 2, 0x7F, b"k", &[]);
            c.write_all(&buf).unwrap();
            let r = read_one_response(&mut c);
            assert_eq!((r.id, r.status), (2, proto::ST_BAD_REQUEST));
            // The server closes after answering; reads drain to EOF.
            let mut sink = Vec::new();
            c.read_to_end(&mut sink).unwrap();
            // A fresh connection still works: the worker survived.
            let mut c2 = TcpStream::connect(server.addr()).unwrap();
            assert_eq!(get(&mut c2, 3, b"k").val, b"v");
            drop(c2);
            server.stop();
        }
    }

    #[test]
    fn broken_framing_answers_bad_request_with_reason() {
        // A hostile frame_len used to close the connection silently; the
        // engine's render_error hook now answers ST_BAD_REQUEST (id MAX)
        // with the reason text before closing.
        let server = KvServer::start(KvServerConfig {
            workers: 2,
            backend: BackendKind::Trust { shards: 2 },
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        c.write_all(&u32::MAX.to_le_bytes()).unwrap();
        let r = read_one_response(&mut c);
        assert_eq!((r.id, r.status), (u64::MAX, proto::ST_BAD_REQUEST));
        assert!(
            String::from_utf8_lossy(&r.val).contains("frame_len"),
            "reason text missing: {:?}",
            r.val
        );
        let mut sink = Vec::new();
        c.read_to_end(&mut sink).unwrap();
        server.stop();
    }

    #[test]
    fn per_worker_metrics_count_connections_and_requests() {
        let server = KvServer::start(KvServerConfig {
            workers: 2,
            backend: BackendKind::Trust { shards: 2 },
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        assert_eq!(put(&mut c, 1, b"m", b"v").status, proto::ST_OK);
        assert_eq!(get(&mut c, 2, b"m").val, b"v");
        drop(c);
        // The connection fiber exits asynchronously after the drop.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            let t = server.metrics().totals();
            if t.closed >= 1 || std::time::Instant::now() >= deadline {
                assert_eq!(t.accepted, 1);
                assert_eq!(t.closed, 1, "connection fiber must record its exit");
                assert_eq!(t.requests, 2);
                assert_eq!(t.parse_errors, 0);
                break;
            }
            std::thread::yield_now();
        }
        server.stop();
    }
}
