//! The multi-threaded TCP key-value server (§6.3).
//!
//! "Each worker-thread receives GET or PUT queries from one or more
//! connections, and applies these to the backend hashmap. Both reading
//! requests and sending results is done in batches ... the client accepts
//! responses out-of-order." Each accepted connection becomes a fiber on a
//! socket worker; requests are dispatched to the backend via callbacks
//! that append responses (tagged with the request id) to the connection's
//! write buffer as they complete — hence naturally out of order.

use super::backend::{AsyncKv, BackendKind};
use super::netfiber::{read_available, write_pending, ReadOutcome};
use super::proto::{self, FrameCursor};
use crate::fiber;
use crate::runtime::Runtime;
use std::cell::RefCell;
use std::net::{TcpListener, TcpStream};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct KvServerConfig {
    pub workers: usize,
    /// Dedicated trustee workers (shards live there; no socket fibers).
    pub dedicated: usize,
    pub backend: BackendKind,
    pub addr: String,
}

impl Default for KvServerConfig {
    fn default() -> Self {
        KvServerConfig {
            workers: 4,
            dedicated: 0,
            backend: BackendKind::Trust { shards: 0 },
            addr: "127.0.0.1:0".into(),
        }
    }
}

/// A running KV server (owns its runtime and accept thread).
pub struct KvServer {
    rt: Option<Runtime>,
    backend: Arc<dyn AsyncKv>,
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    pub ops_served: Arc<AtomicU64>,
}

impl KvServer {
    pub fn start(cfg: KvServerConfig) -> KvServer {
        let rt = Runtime::builder()
            .workers(cfg.workers)
            .dedicated_trustees(cfg.dedicated)
            .build();
        // Shard trustees: the dedicated workers if any, else all workers.
        let trustees: Vec<usize> = if cfg.dedicated > 0 {
            (0..cfg.dedicated).collect()
        } else {
            (0..cfg.workers).collect()
        };
        let backend = cfg.backend.build(&rt, &trustees);
        let listener = TcpListener::bind(&cfg.addr).expect("bind kv server");
        let local_addr = listener.local_addr().unwrap();
        listener.set_nonblocking(true).expect("nonblocking listener");
        let stop = Arc::new(AtomicBool::new(false));
        let ops_served = Arc::new(AtomicU64::new(0));

        // Socket workers: the non-dedicated ones.
        let socket_workers: Vec<usize> = (cfg.dedicated..cfg.workers).collect();
        assert!(!socket_workers.is_empty(), "no socket workers left");

        let accept_handle = {
            let stop = stop.clone();
            let backend = backend.clone();
            let shared = rt.shared().clone();
            let ops = ops_served.clone();
            std::thread::Builder::new()
                .name("kv-accept".into())
                .spawn(move || {
                    let mut next = 0usize;
                    while !stop.load(Ordering::Acquire) {
                        match listener.accept() {
                            Ok((stream, _peer)) => {
                                let worker = socket_workers[next % socket_workers.len()];
                                next += 1;
                                let backend = backend.clone();
                                let ops = ops.clone();
                                let stop = stop.clone();
                                shared.inject(
                                    worker,
                                    Box::new(move || {
                                        fiber::with_executor(|e| {
                                            e.spawn(move || {
                                                connection_fiber(stream, backend, ops, stop)
                                            });
                                        });
                                    }),
                                );
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(std::time::Duration::from_micros(200));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .expect("spawn acceptor")
        };

        KvServer {
            rt: Some(rt),
            backend,
            local_addr,
            stop,
            accept_handle: Some(accept_handle),
            ops_served,
        }
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    pub fn backend(&self) -> &Arc<dyn AsyncKv> {
        &self.backend
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt.as_ref().unwrap()
    }

    /// Pre-fill the table with `n` keys ("Prior to each run, we pre-fill
    /// the table"). Key format matches the load generator's.
    pub fn prefill(&self, n: u64, val_len: usize) {
        let worker = self.runtime().workers() - 1;
        let backend = self.backend.clone();
        self.runtime().block_on(worker, move || {
            let done = Arc::new(AtomicU64::new(0));
            let mut issued = 0u64;
            while issued < n || done.load(Ordering::Relaxed) < n {
                // Keep a bounded window in flight so outboxes stay small.
                while issued < n && issued - done.load(Ordering::Relaxed) < 256 {
                    let d = done.clone();
                    backend.put(
                        super::client::key_bytes(issued),
                        vec![b'x'; val_len],
                        Box::new(move |_| {
                            d.fetch_add(1, Ordering::Relaxed);
                        }),
                    );
                    issued += 1;
                }
                fiber::yield_now();
            }
        });
    }

    pub fn stop(mut self) {
        self.stop_impl();
    }

    fn stop_impl(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(rt) = self.rt.take() {
            rt.shutdown();
        }
    }
}

impl Drop for KvServer {
    fn drop(&mut self) {
        self.stop_impl();
    }
}

/// Per-connection fiber: parse requests, dispatch to the backend, stream
/// responses back out of order as their callbacks fire. Exits when the
/// peer closes or the server stops.
fn connection_fiber(
    mut stream: TcpStream,
    backend: Arc<dyn AsyncKv>,
    ops: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
) {
    stream.set_nonblocking(true).expect("nonblocking conn");
    stream.set_nodelay(true).ok();
    let out = Rc::new(RefCell::new(Vec::<u8>::new()));
    let inflight = Rc::new(std::cell::Cell::new(0usize));
    let mut inbuf: Vec<u8> = Vec::with_capacity(32 * 1024);
    let mut cursor = FrameCursor::new();
    let mut wcursor = 0usize;
    let mut peer_gone = false;

    loop {
        // 1. Ingest.
        if !peer_gone {
            match read_available(&mut stream, &mut inbuf) {
                ReadOutcome::Closed => peer_gone = true,
                ReadOutcome::Data(_) | ReadOutcome::WouldBlock => {}
            }
        }
        // 2. Parse + dispatch every complete request ("reading requests is
        //    done in batches").
        while let Some(req) = cursor.next_request(&inbuf) {
            inflight.set(inflight.get() + 1);
            let out = out.clone();
            let infl = inflight.clone();
            let ops = ops.clone();
            let id = req.id;
            match req.op {
                proto::OP_GET => backend.get(
                    req.key,
                    Box::new(move |v| {
                        let mut o = out.borrow_mut();
                        match v {
                            Some(val) => proto::write_response(&mut o, id, proto::ST_OK, &val),
                            None => proto::write_response(&mut o, id, proto::ST_NOT_FOUND, &[]),
                        }
                        infl.set(infl.get() - 1);
                        ops.fetch_add(1, Ordering::Relaxed);
                    }),
                ),
                proto::OP_PUT => backend.put(
                    req.key,
                    req.val,
                    Box::new(move |_| {
                        proto::write_response(&mut out.borrow_mut(), id, proto::ST_OK, &[]);
                        infl.set(infl.get() - 1);
                        ops.fetch_add(1, Ordering::Relaxed);
                    }),
                ),
                proto::OP_DEL => backend.del(
                    req.key,
                    Box::new(move |existed| {
                        let st = if existed { proto::ST_OK } else { proto::ST_NOT_FOUND };
                        proto::write_response(&mut out.borrow_mut(), id, st, &[]);
                        infl.set(infl.get() - 1);
                        ops.fetch_add(1, Ordering::Relaxed);
                    }),
                ),
                other => panic!("unknown op {other}"),
            }
        }
        proto::compact(&mut inbuf, &mut cursor);
        // 3. Egress ("sending results is done in batches").
        {
            let mut o = out.borrow_mut();
            if !write_pending(&mut stream, &mut o, &mut wcursor) {
                break;
            }
        }
        if peer_gone && inflight.get() == 0 && out.borrow().is_empty() {
            break;
        }
        // Server shutdown: stop accepting new work and drain what's left.
        if stop.load(Ordering::Acquire) && inflight.get() == 0 {
            break;
        }
        // 4. Let the scheduler serve trustee work / other connections.
        fiber::yield_now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    fn get(stream: &mut TcpStream, id: u64, key: &[u8]) -> proto::Response {
        let mut buf = Vec::new();
        proto::write_request(&mut buf, id, proto::OP_GET, key, &[]);
        stream.write_all(&buf).unwrap();
        read_one_response(stream)
    }

    fn put(stream: &mut TcpStream, id: u64, key: &[u8], val: &[u8]) -> proto::Response {
        let mut buf = Vec::new();
        proto::write_request(&mut buf, id, proto::OP_PUT, key, val);
        stream.write_all(&buf).unwrap();
        read_one_response(stream)
    }

    fn read_one_response(stream: &mut TcpStream) -> proto::Response {
        let mut buf = Vec::new();
        let mut cursor = FrameCursor::new();
        let mut chunk = [0u8; 4096];
        loop {
            if let Some(r) = cursor.next_response(&buf) {
                return r;
            }
            let n = stream.read(&mut chunk).unwrap();
            assert!(n > 0, "server closed early");
            buf.extend_from_slice(&chunk[..n]);
        }
    }

    fn smoke(backend: BackendKind) {
        let server = KvServer::start(KvServerConfig {
            workers: 2,
            dedicated: 0,
            backend,
            addr: "127.0.0.1:0".into(),
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        // miss, put, hit, overwrite, delete
        assert_eq!(get(&mut c, 1, b"k").status, proto::ST_NOT_FOUND);
        assert_eq!(put(&mut c, 2, b"k", b"v1").status, proto::ST_OK);
        let r = get(&mut c, 3, b"k");
        assert_eq!((r.status, r.val.as_slice()), (proto::ST_OK, &b"v1"[..]));
        assert_eq!(put(&mut c, 4, b"k", b"v2").status, proto::ST_OK);
        let r = get(&mut c, 5, b"k");
        assert_eq!(r.val, b"v2");
        drop(c);
        assert_eq!(server.ops_served.load(Ordering::Relaxed), 5);
        server.stop();
    }

    #[test]
    fn trust_server_smoke() {
        smoke(BackendKind::Trust { shards: 2 });
    }

    #[test]
    fn mutex_server_smoke() {
        smoke(BackendKind::Mutex);
    }

    #[test]
    fn pipelined_out_of_order_ids_match() {
        let server = KvServer::start(KvServerConfig {
            workers: 2,
            backend: BackendKind::Trust { shards: 2 },
            ..Default::default()
        });
        server.prefill(100, 16);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        // Fire 50 pipelined GETs, then collect all 50 responses by id.
        let mut buf = Vec::new();
        for i in 0..50u64 {
            proto::write_request(
                &mut buf,
                1000 + i,
                proto::OP_GET,
                &super::super::client::key_bytes(i % 100),
                &[],
            );
        }
        c.write_all(&buf).unwrap();
        let mut seen = std::collections::HashSet::new();
        let mut rbuf = Vec::new();
        let mut cursor = FrameCursor::new();
        let mut chunk = [0u8; 8192];
        while seen.len() < 50 {
            if let Some(r) = cursor.next_response(&rbuf) {
                assert_eq!(r.status, proto::ST_OK);
                assert!(seen.insert(r.id), "duplicate id {}", r.id);
                assert!((1000..1050).contains(&r.id));
                continue;
            }
            let n = c.read(&mut chunk).unwrap();
            assert!(n > 0);
            rbuf.extend_from_slice(&chunk[..n]);
        }
        drop(c);
        server.stop();
    }

    #[test]
    fn multiple_connections_concurrent() {
        let server = KvServer::start(KvServerConfig {
            workers: 3,
            backend: BackendKind::Trust { shards: 3 },
            ..Default::default()
        });
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut c = TcpStream::connect(addr).unwrap();
                    for i in 0..50u64 {
                        let key = format!("t{t}-k{i}").into_bytes();
                        assert_eq!(put(&mut c, i, &key, b"val").status, proto::ST_OK);
                        let r = get(&mut c, 1000 + i, &key);
                        assert_eq!(r.val, b"val");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.backend().len(), 200);
        server.stop();
    }

    #[test]
    fn dedicated_trustee_topology() {
        let server = KvServer::start(KvServerConfig {
            workers: 3,
            dedicated: 1,
            backend: BackendKind::Trust { shards: 2 },
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        assert_eq!(put(&mut c, 1, b"a", b"b").status, proto::ST_OK);
        assert_eq!(get(&mut c, 2, b"a").val, b"b");
        drop(c);
        server.stop();
    }
}
