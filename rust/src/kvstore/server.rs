//! The multi-threaded TCP key-value server (§6.3).
//!
//! "Each worker-thread receives GET or PUT queries from one or more
//! connections, and applies these to the backend hashmap. Both reading
//! requests and sending results is done in batches ... the client accepts
//! responses out-of-order." Each accepted connection becomes a fiber on a
//! socket worker; requests are dispatched to the backend via callbacks
//! that append responses (tagged with the request id) to the connection's
//! write buffer as they complete — hence naturally out of order.

use super::backend::{AsyncKv, BackendKind};
use super::netfiber::{self, net_wait, read_burst, write_pending, NetPolicy, ReadOutcome};
use super::proto::{self, FrameCursor};
use crate::fiber;
use crate::runtime::Runtime;
use std::cell::RefCell;
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct KvServerConfig {
    pub workers: usize,
    /// Dedicated trustee workers (shards live there; no socket fibers).
    pub dedicated: usize,
    pub backend: BackendKind,
    pub addr: String,
    /// How connection fibers wait for socket progress.
    pub net: NetPolicy,
}

impl Default for KvServerConfig {
    fn default() -> Self {
        KvServerConfig {
            workers: 4,
            dedicated: 0,
            backend: BackendKind::Trust { shards: 0 },
            addr: "127.0.0.1:0".into(),
            net: NetPolicy::default(),
        }
    }
}

impl KvServerConfig {
    /// Check the topology *before* any runtime is built: every
    /// misconfiguration that previously died on an internal assert after
    /// worker threads were already spawned reports here instead.
    pub fn validate(&self) -> Result<(), String> {
        netfiber::validate_topology(self.workers, self.dedicated)
    }
}

/// A running KV server (owns its runtime and accept thread).
pub struct KvServer {
    rt: Option<Runtime>,
    backend: Arc<dyn AsyncKv>,
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    pub ops_served: Arc<AtomicU64>,
}

impl KvServer {
    /// Start a server, panicking on an invalid configuration (see
    /// [`KvServer::try_start`] for the fallible form).
    pub fn start(cfg: KvServerConfig) -> KvServer {
        Self::try_start(cfg).unwrap_or_else(|e| panic!("invalid KvServerConfig: {e}"))
    }

    /// Start a server, reporting configuration/bind problems as a
    /// descriptive error *before* any worker thread is spawned.
    pub fn try_start(cfg: KvServerConfig) -> Result<KvServer, String> {
        cfg.validate()?;
        let listener =
            TcpListener::bind(&cfg.addr).map_err(|e| format!("bind {}: {e}", cfg.addr))?;
        let local_addr = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("nonblocking listener: {e}"))?;

        let rt = Runtime::builder()
            .workers(cfg.workers)
            .dedicated_trustees(cfg.dedicated)
            .build();
        // Shard trustees: the dedicated workers if any, else all workers.
        let trustees: Vec<usize> = if cfg.dedicated > 0 {
            (0..cfg.dedicated).collect()
        } else {
            (0..cfg.workers).collect()
        };
        let backend = cfg.backend.build(&rt, &trustees);
        let stop = Arc::new(AtomicBool::new(false));
        let ops_served = Arc::new(AtomicU64::new(0));

        // Socket workers: the non-dedicated ones (validate() guarantees at
        // least one).
        let socket_workers: Vec<usize> = (cfg.dedicated..cfg.workers).collect();
        let policy = cfg.net;

        // Round-robin dispatch of accepted streams onto socket workers.
        let dispatch = {
            let backend = backend.clone();
            let ops = ops_served.clone();
            let stop = stop.clone();
            netfiber::round_robin_dispatch(
                rt.shared().clone(),
                socket_workers.clone(),
                move |stream| {
                    let backend = backend.clone();
                    let ops = ops.clone();
                    let stop = stop.clone();
                    Box::new(move || connection_fiber(stream, backend, ops, stop, policy))
                },
            )
        };

        // Epoll: the acceptor is a fiber parked on listener readability in
        // the first socket worker's reactor — no sleep-poll thread.
        // BusyPoll: the legacy 200 µs accept thread (A/B baseline).
        let accept_handle = netfiber::start_acceptor(
            policy,
            listener,
            stop.clone(),
            rt.shared(),
            socket_workers[0],
            dispatch,
            "kv-accept",
        )?;

        Ok(KvServer {
            rt: Some(rt),
            backend,
            local_addr,
            stop,
            accept_handle,
            ops_served,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    pub fn backend(&self) -> &Arc<dyn AsyncKv> {
        &self.backend
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt.as_ref().unwrap()
    }

    /// Pre-fill the table with `n` keys ("Prior to each run, we pre-fill
    /// the table"). Key format matches the load generator's.
    pub fn prefill(&self, n: u64, val_len: usize) {
        let worker = self.runtime().workers() - 1;
        let backend = self.backend.clone();
        self.runtime().block_on(worker, move || {
            let done = Arc::new(AtomicU64::new(0));
            let mut issued = 0u64;
            while issued < n || done.load(Ordering::Relaxed) < n {
                // Keep a bounded window in flight so outboxes stay small.
                while issued < n && issued - done.load(Ordering::Relaxed) < 256 {
                    let d = done.clone();
                    backend.put(
                        super::client::key_bytes(issued),
                        vec![b'x'; val_len],
                        Box::new(move |_| {
                            d.fetch_add(1, Ordering::Relaxed);
                        }),
                    );
                    issued += 1;
                }
                fiber::yield_now();
            }
        });
    }

    pub fn stop(mut self) {
        self.stop_impl();
    }

    fn stop_impl(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(rt) = self.rt.take() {
            rt.shutdown();
        }
    }
}

impl Drop for KvServer {
    fn drop(&mut self) {
        self.stop_impl();
    }
}

/// Per-connection fiber: parse requests, dispatch to the backend, stream
/// responses back out of order as their callbacks fire. Exits when the
/// peer closes, the stream turns malformed, or the server stops.
///
/// Hardened against arbitrary client bytes: parse errors and unknown ops
/// end the connection (unknown ops first answer [`proto::ST_BAD_REQUEST`]
/// so well-meaning-but-buggy clients see *why*) — they never panic the
/// worker, which would strand the whole runtime.
fn connection_fiber(
    mut stream: TcpStream,
    backend: Arc<dyn AsyncKv>,
    ops: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    policy: NetPolicy,
) {
    if stream.set_nonblocking(true).is_err() {
        return;
    }
    stream.set_nodelay(true).ok();
    let fd = stream.as_raw_fd();
    let out = Rc::new(RefCell::new(Vec::<u8>::new()));
    let inflight = Rc::new(std::cell::Cell::new(0usize));
    let mut inbuf: Vec<u8> = Vec::with_capacity(32 * 1024);
    let mut cursor = FrameCursor::new();
    let mut wcursor = 0usize;
    let mut peer_gone = false;
    // Malformed stream: stop reading/parsing, drain what's owed, close.
    let mut poisoned = false;
    // On server stop, drain buffered responses for a bounded grace period
    // (acked work should reach the wire) without letting a peer that
    // never reads hold shutdown hostage.
    let mut stop_deadline: Option<std::time::Instant> = None;

    loop {
        let mut progress = false;
        // 1. Ingest ("reading requests is done in batches"): drain the
        //    socket up to a fairness bound, and stop reading while the
        //    unparsed backlog is past MAX_INBUF (TCP backpressure instead
        //    of unbounded buffering).
        if !peer_gone && !poisoned && inbuf.len() < netfiber::MAX_INBUF {
            match read_burst(&mut stream, &mut inbuf, 64 * 1024) {
                ReadOutcome::Data(_) => progress = true,
                ReadOutcome::Closed => peer_gone = true,
                ReadOutcome::WouldBlock => {}
            }
        }
        // 2. Parse + dispatch every complete request.
        if !poisoned {
            loop {
                let req = match cursor.next_request(&inbuf) {
                    Ok(Some(req)) => req,
                    Ok(None) => break,
                    Err(_) => {
                        // Framing is broken; no request id to answer to.
                        poisoned = true;
                        break;
                    }
                };
                progress = true;
                let id = req.id;
                if !matches!(req.op, proto::OP_GET | proto::OP_PUT | proto::OP_DEL) {
                    // One bad client must not kill the fiber mid-batch and
                    // strand its inflight count: answer, then wind down.
                    proto::write_response(
                        &mut out.borrow_mut(),
                        id,
                        proto::ST_BAD_REQUEST,
                        &[],
                    );
                    poisoned = true;
                    break;
                }
                inflight.set(inflight.get() + 1);
                let out = out.clone();
                let infl = inflight.clone();
                let ops = ops.clone();
                match req.op {
                    proto::OP_GET => backend.get(
                        req.key,
                        Box::new(move |v| {
                            let mut o = out.borrow_mut();
                            match v {
                                Some(val) => {
                                    proto::write_response(&mut o, id, proto::ST_OK, &val)
                                }
                                None => {
                                    proto::write_response(&mut o, id, proto::ST_NOT_FOUND, &[])
                                }
                            }
                            infl.set(infl.get() - 1);
                            ops.fetch_add(1, Ordering::Relaxed);
                        }),
                    ),
                    proto::OP_PUT => backend.put(
                        req.key,
                        req.val,
                        Box::new(move |_| {
                            proto::write_response(&mut out.borrow_mut(), id, proto::ST_OK, &[]);
                            infl.set(infl.get() - 1);
                            ops.fetch_add(1, Ordering::Relaxed);
                        }),
                    ),
                    _ => backend.del(
                        req.key,
                        Box::new(move |existed| {
                            let st = if existed { proto::ST_OK } else { proto::ST_NOT_FOUND };
                            proto::write_response(&mut out.borrow_mut(), id, st, &[]);
                            infl.set(infl.get() - 1);
                            ops.fetch_add(1, Ordering::Relaxed);
                        }),
                    ),
                }
            }
            proto::compact(&mut inbuf, &mut cursor);
        }
        // 3. Egress ("sending results is done in batches").
        {
            let mut o = out.borrow_mut();
            let pending_before = o.len() - wcursor;
            if !write_pending(&mut stream, &mut o, &mut wcursor) {
                break;
            }
            let pending_after = o.len() - wcursor;
            if pending_after < pending_before {
                progress = true;
            }
        }
        // 4. Exit conditions.
        if (peer_gone || poisoned) && inflight.get() == 0 && out.borrow().is_empty() {
            break;
        }
        // Server shutdown: stop accepting new work, drain what's left (the
        // responses in `out` are acknowledged work), break regardless once
        // the grace period expires.
        if stop.load(Ordering::Acquire) && inflight.get() == 0 {
            if out.borrow().is_empty() {
                break;
            }
            let deadline = *stop_deadline.get_or_insert_with(|| {
                std::time::Instant::now() + std::time::Duration::from_millis(250)
            });
            if std::time::Instant::now() >= deadline {
                break;
            }
        }
        // 5. Wait for more work. With responses in flight the wake comes
        //    from the scheduler (backend completions), so yield; otherwise
        //    the only possible wake is the socket — park on it (Epoll)
        //    instead of re-polling every tick (BusyPoll).
        if progress || inflight.get() > 0 || stop.load(Ordering::Acquire) {
            fiber::yield_now();
        } else {
            let want_read = !peer_gone && !poisoned && inbuf.len() < netfiber::MAX_INBUF;
            let want_write = !out.borrow().is_empty();
            net_wait(policy, fd, want_read, want_write);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};

    fn get(stream: &mut TcpStream, id: u64, key: &[u8]) -> proto::Response {
        let mut buf = Vec::new();
        proto::write_request(&mut buf, id, proto::OP_GET, key, &[]);
        stream.write_all(&buf).unwrap();
        read_one_response(stream)
    }

    fn put(stream: &mut TcpStream, id: u64, key: &[u8], val: &[u8]) -> proto::Response {
        let mut buf = Vec::new();
        proto::write_request(&mut buf, id, proto::OP_PUT, key, val);
        stream.write_all(&buf).unwrap();
        read_one_response(stream)
    }

    fn read_one_response(stream: &mut TcpStream) -> proto::Response {
        let mut buf = Vec::new();
        let mut cursor = FrameCursor::new();
        let mut chunk = [0u8; 4096];
        loop {
            if let Some(r) = cursor.next_response(&buf).unwrap() {
                return r;
            }
            let n = stream.read(&mut chunk).unwrap();
            assert!(n > 0, "server closed early");
            buf.extend_from_slice(&chunk[..n]);
        }
    }

    fn smoke(backend: BackendKind) {
        let server = KvServer::start(KvServerConfig {
            workers: 2,
            dedicated: 0,
            backend,
            addr: "127.0.0.1:0".into(),
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        // miss, put, hit, overwrite, delete
        assert_eq!(get(&mut c, 1, b"k").status, proto::ST_NOT_FOUND);
        assert_eq!(put(&mut c, 2, b"k", b"v1").status, proto::ST_OK);
        let r = get(&mut c, 3, b"k");
        assert_eq!((r.status, r.val.as_slice()), (proto::ST_OK, &b"v1"[..]));
        assert_eq!(put(&mut c, 4, b"k", b"v2").status, proto::ST_OK);
        let r = get(&mut c, 5, b"k");
        assert_eq!(r.val, b"v2");
        drop(c);
        assert_eq!(server.ops_served.load(Ordering::Relaxed), 5);
        server.stop();
    }

    #[test]
    fn trust_server_smoke() {
        smoke(BackendKind::Trust { shards: 2 });
    }

    #[test]
    fn mutex_server_smoke() {
        smoke(BackendKind::Mutex);
    }

    #[test]
    fn pipelined_out_of_order_ids_match() {
        let server = KvServer::start(KvServerConfig {
            workers: 2,
            backend: BackendKind::Trust { shards: 2 },
            ..Default::default()
        });
        server.prefill(100, 16);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        // Fire 50 pipelined GETs, then collect all 50 responses by id.
        let mut buf = Vec::new();
        for i in 0..50u64 {
            proto::write_request(
                &mut buf,
                1000 + i,
                proto::OP_GET,
                &super::super::client::key_bytes(i % 100),
                &[],
            );
        }
        c.write_all(&buf).unwrap();
        let mut seen = std::collections::HashSet::new();
        let mut rbuf = Vec::new();
        let mut cursor = FrameCursor::new();
        let mut chunk = [0u8; 8192];
        while seen.len() < 50 {
            if let Some(r) = cursor.next_response(&rbuf).unwrap() {
                assert_eq!(r.status, proto::ST_OK);
                assert!(seen.insert(r.id), "duplicate id {}", r.id);
                assert!((1000..1050).contains(&r.id));
                continue;
            }
            let n = c.read(&mut chunk).unwrap();
            assert!(n > 0);
            rbuf.extend_from_slice(&chunk[..n]);
        }
        drop(c);
        server.stop();
    }

    #[test]
    fn multiple_connections_concurrent() {
        let server = KvServer::start(KvServerConfig {
            workers: 3,
            backend: BackendKind::Trust { shards: 3 },
            ..Default::default()
        });
        let addr = server.addr();
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let mut c = TcpStream::connect(addr).unwrap();
                    for i in 0..50u64 {
                        let key = format!("t{t}-k{i}").into_bytes();
                        assert_eq!(put(&mut c, i, &key, b"val").status, proto::ST_OK);
                        let r = get(&mut c, 1000 + i, &key);
                        assert_eq!(r.val, b"val");
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.backend().len(), 200);
        server.stop();
    }

    #[test]
    fn dedicated_trustee_topology() {
        let server = KvServer::start(KvServerConfig {
            workers: 3,
            dedicated: 1,
            backend: BackendKind::Trust { shards: 2 },
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        assert_eq!(put(&mut c, 1, b"a", b"b").status, proto::ST_OK);
        assert_eq!(get(&mut c, 2, b"a").val, b"b");
        drop(c);
        server.stop();
    }

    #[test]
    fn invalid_topology_is_a_descriptive_error_not_a_late_assert() {
        // dedicated >= workers used to die on an internal assert after the
        // runtime was already built; now it is a validation error up front.
        let err = KvServer::try_start(KvServerConfig {
            workers: 2,
            dedicated: 2,
            ..Default::default()
        })
        .err()
        .expect("must be rejected");
        assert!(err.contains("socket worker"), "unhelpful error: {err}");

        let err = KvServer::try_start(KvServerConfig {
            workers: 0,
            ..Default::default()
        })
        .err()
        .expect("must be rejected");
        assert!(err.contains("workers"), "unhelpful error: {err}");
    }

    #[test]
    fn unknown_op_answers_bad_request_and_closes() {
        for net in [NetPolicy::BusyPoll, NetPolicy::Epoll] {
            let server = KvServer::start(KvServerConfig {
                workers: 2,
                backend: BackendKind::Trust { shards: 2 },
                net,
                ..Default::default()
            });
            let mut c = TcpStream::connect(server.addr()).unwrap();
            // A valid request first, then one with an unknown op.
            assert_eq!(put(&mut c, 1, b"k", b"v").status, proto::ST_OK);
            let mut buf = Vec::new();
            proto::write_request(&mut buf, 2, 0x7F, b"k", &[]);
            c.write_all(&buf).unwrap();
            let r = read_one_response(&mut c);
            assert_eq!((r.id, r.status), (2, proto::ST_BAD_REQUEST));
            // The server closes after answering; reads drain to EOF.
            let mut sink = Vec::new();
            c.read_to_end(&mut sink).unwrap();
            // A fresh connection still works: the worker survived.
            let mut c2 = TcpStream::connect(server.addr()).unwrap();
            assert_eq!(get(&mut c2, 3, b"k").val, b"v");
            drop(c2);
            server.stop();
        }
    }
}
