//! `Trust<T>` — the paper's programming abstraction (§3, §4).
//!
//! A [`Trust<T>`] is a thread-safe reference-counting smart pointer to a
//! *property* of type `T` owned by a *trustee* worker thread. The property
//! is only accessible by applying closures through the trust:
//!
//! - [`Trust::apply`] — synchronous delegation (§4.1): suspends the calling
//!   fiber until the closure has been applied, returns its value.
//! - [`Trust::apply_then`] — non-blocking delegation (§4.2): returns
//!   immediately; the `then` closure runs on the caller's worker with the
//!   return value. Safe to call from delegated context.
//! - [`Trust::apply_with`] / [`Trust::apply_with_then`] — variable-size and
//!   heap-allocated arguments travel serialized over the channel (§4.3.3).
//! - [`Trust::launch`] (on `Trust<Latch<T>>`) — apply in a trustee-side
//!   fiber so the closure may block, including nested blocking delegation
//!   (§4.3, Fig. 4), guarded by the no-atomics [`Latch`] (§4.3.1).
//!
//! Reference counting is itself delegated (§3.1): `clone`/`drop` post
//! fire-and-forget refcount requests; the count is a plain non-atomic field
//! only the trustee mutates. When the last trust drops, the trustee drops
//! the property.
//!
//! ## Safety discipline (§4.3.2)
//! Delegated closures must own their captures: the bounds are
//! `C: FnOnce(&mut T) -> U + Send + 'static`, so captured borrows are
//! rejected at compile time by the Rust borrow checker, exactly the
//! property the paper leans on. (The paper additionally bans *owned*
//! pointer types like `Box<T>` in captures to encourage locality; we keep
//! the type-system-enforced part and document the convention.)

use crate::channel::{read_response, RequestBuilder, ResponseWriter};
use crate::codec::{to_bytes, Wire, WireReader};
use crate::fiber::{self, FiberId};
use crate::runtime::{in_delegated_context, try_worker_id, with_worker, Shared, Worker};
use std::cell::{Cell, RefCell, UnsafeCell};
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::ptr::NonNull;
use std::sync::{Arc, Condvar, Mutex};

/// Header shared by all entrusted properties; must be the first field of
/// [`PropBox`] so type-erased refcount thunks can operate on it.
#[repr(C)]
pub(crate) struct PropHeader {
    /// Mutated only by the trustee thread — no atomics (§2).
    refcount: Cell<u64>,
    /// Index in the trustee worker's property registry.
    reg_idx: Cell<usize>,
}

/// An entrusted property: header + value, allocated on the trustee thread
/// for locality.
#[repr(C)]
pub(crate) struct PropBox<T> {
    header: PropHeader,
    value: UnsafeCell<T>,
}

unsafe fn drop_propbox<T>(p: *mut u8) {
    // SAFETY: registry stored this pointer from Box::into_raw::<PropBox<T>>.
    unsafe { drop(Box::from_raw(p as *mut PropBox<T>)) };
}

/// Allocate + register a property on the current worker (must be the
/// trustee thread).
fn alloc_propbox<T: 'static>(w: &mut Worker, value: T) -> *mut PropBox<T> {
    let boxed = Box::new(PropBox {
        header: PropHeader { refcount: Cell::new(1), reg_idx: Cell::new(usize::MAX) },
        value: UnsafeCell::new(value),
    });
    let ptr = Box::into_raw(boxed);
    let idx = w.registry.register(ptr as *mut u8, drop_propbox::<T>);
    // SAFETY: just allocated, we own it.
    unsafe { (*ptr).header.reg_idx.set(idx) };
    ptr
}

// ---------------------------------------------------------------------
// Thunks (run on the trustee thread, in delegated context)
// ---------------------------------------------------------------------

/// apply(): take the closure env by value, run it on the property, respond.
unsafe fn apply_thunk<T, U, C>(env: *const u8, prop: *mut u8, _args: &[u8], out: &mut ResponseWriter)
where
    U: Wire,
    C: FnOnce(&mut T) -> U,
{
    // SAFETY: env holds a forgotten C by value; prop is a live PropBox<T>.
    unsafe {
        let c = env.cast::<C>().read_unaligned();
        let pb = prop as *mut PropBox<T>;
        let u = c(&mut *(*pb).value.get());
        out.write_value(&u);
    }
}

/// apply() variant without a response (fire-and-forget).
unsafe fn apply_noresp_thunk<T, C>(env: *const u8, prop: *mut u8, _args: &[u8], _out: &mut ResponseWriter)
where
    C: FnOnce(&mut T),
{
    unsafe {
        let c = env.cast::<C>().read_unaligned();
        let pb = prop as *mut PropBox<T>;
        c(&mut *(*pb).value.get());
    }
}

/// apply_with(): also decode serialized args.
unsafe fn apply_with_thunk<T, V, U, C>(
    env: *const u8,
    prop: *mut u8,
    args: &[u8],
    out: &mut ResponseWriter,
) where
    V: Wire,
    U: Wire,
    C: FnOnce(&mut T, V) -> U,
{
    unsafe {
        let c = env.cast::<C>().read_unaligned();
        let mut r = WireReader::new(args);
        let v = V::read(&mut r).expect("apply_with argument decode");
        let pb = prop as *mut PropBox<T>;
        let u = c(&mut *(*pb).value.get(), v);
        out.write_value(&u);
    }
}

/// Type-erased refcount adjustment; reclaims the property at zero.
unsafe fn rc_delta_thunk(env: *const u8, prop: *mut u8, _args: &[u8], _out: &mut ResponseWriter) {
    unsafe {
        let delta = env.cast::<i64>().read_unaligned();
        let h = &*(prop as *const PropHeader);
        let rc = (h.refcount.get() as i64 + delta) as u64;
        h.refcount.set(rc);
        if rc == 0 {
            let idx = h.reg_idx.get();
            with_worker(|w| w.registry.reclaim(idx));
        }
    }
}

/// entrust(): move the value in, allocate the PropBox here, respond with
/// its address.
unsafe fn entrust_thunk<T: 'static>(
    env: *const u8,
    _prop: *mut u8,
    _args: &[u8],
    out: &mut ResponseWriter,
) {
    unsafe {
        let v = env.cast::<T>().read_unaligned();
        let ptr = with_worker(|w| alloc_propbox(w, v));
        out.write_value(&(ptr as usize as u64));
    }
}

/// launch(): spawn a trustee-side fiber running the closure under the
/// latch; deliver the result via a second delegation call (Fig. 4).
unsafe fn launch_thunk<T, U, C>(env: *const u8, prop: *mut u8, _args: &[u8], _out: &mut ResponseWriter)
where
    T: 'static,
    U: Send + 'static,
    C: FnOnce(&mut T) -> U + Send + 'static,
{
    #[repr(C)]
    struct LaunchEnv<C> {
        c: C,
        client: usize,
        cell_addr: usize,
    }
    unsafe {
        let LaunchEnv { c, client, cell_addr } = env.cast::<LaunchEnv<C>>().read_unaligned();
        let latch_prop = prop as *mut PropBox<Latch<T>>;
        // Creating the fiber is non-blocking — legal in delegated context.
        with_worker(move |w| {
            w.exec.spawn(move || {
                // SAFETY: the client's Trust handle is borrowed for the whole
                // launch, keeping the property alive.
                let latch = unsafe { &*(*latch_prop).value.get() };
                let u = latch.with_lock(|t| c(t));
                // Second delegation call: fire-and-forget completion back to
                // the client worker (we are a client of `client` here).
                deliver_launch_result::<U>(client, cell_addr, u);
            });
        });
    }
}

/// Cell the launching fiber sleeps on.
struct LaunchCell<U> {
    result: Option<U>,
    fiber: FiberId,
}

fn deliver_launch_result<U: Send + 'static>(client: usize, cell_addr: usize, u: U) {
    // Local fast path: the launch came from a fiber on this same worker.
    if try_worker_id() == Some(client) {
        // SAFETY: cell lives on the (parked) launching fiber's stack.
        unsafe {
            let cell = &mut *(cell_addr as *mut LaunchCell<U>);
            cell.result = Some(u);
            let fid = cell.fiber;
            fiber::with_executor(|e| e.resume(fid));
        }
        return;
    }
    #[repr(C)]
    struct DoneEnv<U> {
        u: U,
        cell_addr: usize,
    }
    unsafe fn launch_done_thunk<U: Send + 'static>(
        env: *const u8,
        _prop: *mut u8,
        _args: &[u8],
        _out: &mut ResponseWriter,
    ) {
        // Runs on the *client's* worker, in delegated context.
        unsafe {
            let DoneEnv { u, cell_addr } = env.cast::<DoneEnv<U>>().read_unaligned();
            let cell = &mut *(cell_addr as *mut LaunchCell<U>);
            cell.result = Some(u);
            let fid = cell.fiber;
            fiber::with_executor(|e| e.resume(fid));
        }
    }
    let done = DoneEnv { u, cell_addr };
    let env_bytes = unsafe {
        std::slice::from_raw_parts(&done as *const DoneEnv<U> as *const u8, size_of::<DoneEnv<U>>())
    };
    with_worker(|w| {
        let buf = w.client_mut(client).take_buf();
        let req = RequestBuilder::build(
            buf,
            launch_done_thunk::<U>,
            std::ptr::null_mut(),
            env_bytes,
            &[],
            true,
        );
        std::mem::forget(done);
        w.client_mut(client).enqueue(req, None);
        w.kick(client);
    });
}

// ---------------------------------------------------------------------
// Client-side plumbing
// ---------------------------------------------------------------------

/// Panic unless a *blocking* delegation call is legal right now (§3.4).
#[track_caller]
fn check_blocking_allowed(what: &str) {
    assert!(
        !in_delegated_context(),
        "Trust<T>: blocking {what} in delegated context — \
         use apply_then() or launch() instead (paper §4.3)"
    );
    assert!(
        fiber::in_fiber(),
        "Trust<T>: blocking {what} requires fiber context \
         (call from a runtime fiber, or use Runtime::block_on)"
    );
}

/// Enqueue a framed request on the current worker toward `trustee` and
/// eagerly flush.
fn enqueue_on_worker(trustee: usize, frame: impl FnOnce(Vec<u8>) -> crate::channel::PendingReq, completion: crate::channel::Completion) {
    with_worker(|w| {
        let buf = w.client_mut(trustee).take_buf();
        let req = frame(buf);
        w.client_mut(trustee).enqueue(req, completion);
        w.kick(trustee);
    });
}

/// Blocking wait for a response value: enqueue, suspend, decode.
fn delegate_blocking<U: Wire + 'static>(
    trustee: usize,
    frame: impl FnOnce(Vec<u8>) -> crate::channel::PendingReq,
) -> U {
    struct WaitCell<U> {
        result: Option<U>,
        fiber: FiberId,
    }
    let mut cell = WaitCell::<U> { result: None, fiber: fiber::current_fiber().expect("fiber") };
    let cell_ptr: *mut WaitCell<U> = &mut cell;
    let completion: crate::channel::Completion = Some(Box::new(move |r| {
        let u = read_response::<U>(r);
        // SAFETY: the cell lives on the parked fiber's stack until resume.
        unsafe {
            (*cell_ptr).result = Some(u);
            let fid = (*cell_ptr).fiber;
            fiber::with_executor(|e| e.resume(fid));
        }
    }));
    enqueue_on_worker(trustee, frame, completion);
    fiber::suspend(|_| {});
    cell.result.take().expect("resumed without response")
}

/// env bytes of a value to be moved through the channel. Caller must
/// `mem::forget` the value after framing.
unsafe fn env_bytes_of<C>(c: &C) -> &[u8] {
    unsafe { std::slice::from_raw_parts(c as *const C as *const u8, size_of::<C>()) }
}

// ---------------------------------------------------------------------
// TrusteeRef
// ---------------------------------------------------------------------

/// A reference to a trustee worker — the manual property-placement API
/// (§3.2): `entrust()` moves a value to that trustee and returns a
/// [`Trust<T>`].
#[derive(Clone)]
pub struct TrusteeRef {
    shared: Arc<Shared>,
    worker: usize,
}

impl TrusteeRef {
    pub(crate) fn new(shared: Arc<Shared>, worker: usize) -> TrusteeRef {
        TrusteeRef { shared, worker }
    }

    /// The worker id this trustee runs on.
    pub fn worker_id(&self) -> usize {
        self.worker
    }

    /// Move `value` into the care of this trustee.
    ///
    /// Callable from: the trustee's own thread (direct), another worker's
    /// fiber (delegated), or a non-runtime thread (injected).
    pub fn entrust<T: Send + 'static>(&self, value: T) -> Trust<T> {
        let ptr: *mut PropBox<T> = match try_worker_id() {
            Some(id) if id == self.worker => with_worker(|w| alloc_propbox(w, value)),
            Some(_) => {
                check_blocking_allowed("entrust()");
                let addr: u64 = delegate_blocking(self.worker, |buf| {
                    let req = RequestBuilder::build(
                        buf,
                        entrust_thunk::<T>,
                        std::ptr::null_mut(),
                        unsafe { env_bytes_of(&value) },
                        &[],
                        false,
                    );
                    std::mem::forget(value);
                    req
                });
                addr as usize as *mut PropBox<T>
            }
            None => {
                // Injected job + condvar (start-up path).
                let done = Arc::new((Mutex::new(None::<usize>), Condvar::new()));
                let done2 = done.clone();
                self.shared.inject(
                    self.worker,
                    Box::new(move |w| {
                        let p = alloc_propbox(w, value) as usize;
                        let (m, cv) = &*done2;
                        *m.lock().unwrap() = Some(p);
                        cv.notify_all();
                    }),
                );
                let (m, cv) = &*done;
                let mut g = m.lock().unwrap();
                while g.is_none() {
                    g = cv.wait(g).unwrap();
                }
                g.take().unwrap() as *mut PropBox<T>
            }
        };
        Trust {
            prop: NonNull::new(ptr).unwrap(),
            trustee: self.worker,
            shared: self.shared.clone(),
            _t: PhantomData,
        }
    }
}

/// The trustee running on the current worker thread (§3.1's
/// `local_trustee()`); panics off runtime threads.
pub fn local_trustee() -> TrusteeRef {
    with_worker(|w| TrusteeRef { shared: w.shared.clone(), worker: w.id })
}

// ---------------------------------------------------------------------
// Trust<T>
// ---------------------------------------------------------------------

/// A thread-safe reference-counted handle to an entrusted property of type
/// `T` (§3.1). See the module docs for the API tour.
pub struct Trust<T: 'static> {
    prop: NonNull<PropBox<T>>,
    trustee: usize,
    shared: Arc<Shared>,
    _t: PhantomData<PropBox<T>>,
}

// SAFETY: the property itself is only ever touched by its trustee thread;
// the handle merely routes requests. T: Send because entrust moved T to
// another thread and drop may run it there.
unsafe impl<T: Send + 'static> Send for Trust<T> {}
unsafe impl<T: Send + 'static> Sync for Trust<T> {}

impl<T: 'static> Trust<T> {
    /// Worker id of this property's trustee.
    pub fn trustee_id(&self) -> usize {
        self.trustee
    }

    /// Is the current thread this property's trustee?
    pub fn is_local(&self) -> bool {
        try_worker_id() == Some(self.trustee)
    }

    #[inline]
    fn prop_u8(&self) -> *mut u8 {
        self.prop.as_ptr() as *mut u8
    }

    /// Apply `c` to the property synchronously and return its result
    /// (§4.1). Suspends the calling fiber while the request is in flight.
    ///
    /// # Panics
    /// In delegated context (blocking there would sleep the trustee —
    /// §4.3), or outside fiber context on a runtime thread.
    pub fn apply<U, C>(&self, c: C) -> U
    where
        U: Wire + Send + 'static,
        C: FnOnce(&mut T) -> U + Send + 'static,
    {
        // Local-trustee shortcut (§5.2.1): applying directly is just as
        // safe, because delegated closures cannot suspend this thread.
        if self.is_local() {
            return self.run_local(c);
        }
        match try_worker_id() {
            Some(_) => {
                check_blocking_allowed("apply()");
                let prop = self.prop_u8();
                delegate_blocking(self.trustee, move |buf| {
                    let req = RequestBuilder::build(
                        buf,
                        apply_thunk::<T, U, C>,
                        prop,
                        unsafe { env_bytes_of(&c) },
                        &[],
                        false,
                    );
                    std::mem::forget(c);
                    req
                })
            }
            None => self.apply_injected(c),
        }
    }

    /// Direct application on the trustee thread, with the delegated flag
    /// set so nested blocking calls are caught.
    fn run_local<U, C: FnOnce(&mut T) -> U>(&self, c: C) -> U {
        with_worker(|w| {
            let prev = w.set_delegated(true);
            // SAFETY: we are the trustee thread; no other closure runs
            // concurrently on this property.
            let u = c(unsafe { &mut *(*self.prop.as_ptr()).value.get() });
            w.set_delegated(prev);
            u
        })
    }

    /// Slow path for non-runtime threads: inject the closure to the
    /// trustee and wait on a condvar. Keeps examples/tests ergonomic; the
    /// hot path never goes here.
    fn apply_injected<U, C>(&self, c: C) -> U
    where
        U: Send + 'static,
        C: FnOnce(&mut T) -> U + Send + 'static,
    {
        let done = Arc::new((Mutex::new(None::<U>), Condvar::new()));
        let done2 = done.clone();
        let prop_addr = self.prop.as_ptr() as usize;
        self.shared.inject(
            self.trustee,
            Box::new(move |w| {
                let pb = prop_addr as *mut PropBox<T>;
                let prev = w.set_delegated(true);
                // SAFETY: trustee thread; property alive (we hold a ref).
                let u = c(unsafe { &mut *(*pb).value.get() });
                w.set_delegated(prev);
                let (m, cv) = &*done2;
                *m.lock().unwrap() = Some(u);
                cv.notify_all();
            }),
        );
        let (m, cv) = &*done;
        let mut g = m.lock().unwrap();
        while g.is_none() {
            g = cv.wait(g).unwrap();
        }
        g.take().unwrap()
    }

    /// Non-blocking delegation (§4.2): returns immediately; `then` runs on
    /// this worker with the closure's return value once the response
    /// arrives. Safe to call from delegated context.
    pub fn apply_then<U, C, F>(&self, c: C, then: F)
    where
        U: Wire + Send + 'static,
        C: FnOnce(&mut T) -> U + Send + 'static,
        F: FnOnce(U) + 'static,
    {
        if self.is_local() {
            let u = self.run_local(c);
            then(u);
            return;
        }
        assert!(
            try_worker_id().is_some(),
            "apply_then requires a runtime worker thread"
        );
        let prop = self.prop_u8();
        let completion: crate::channel::Completion = Some(Box::new(move |r| {
            let u = read_response::<U>(r);
            then(u);
        }));
        enqueue_on_worker(
            self.trustee,
            move |buf| {
                let req = RequestBuilder::build(
                    buf,
                    apply_thunk::<T, U, C>,
                    prop,
                    unsafe { env_bytes_of(&c) },
                    &[],
                    false,
                );
                std::mem::forget(c);
                req
            },
            completion,
        );
    }

    /// Fire-and-forget delegation: no return value, no response bytes.
    pub fn apply_forget<C>(&self, c: C)
    where
        C: FnOnce(&mut T) + Send + 'static,
    {
        if self.is_local() {
            self.run_local(|t| c(t));
            return;
        }
        assert!(
            try_worker_id().is_some(),
            "apply_forget requires a runtime worker thread"
        );
        let prop = self.prop_u8();
        enqueue_on_worker(
            self.trustee,
            move |buf| {
                let req = RequestBuilder::build(
                    buf,
                    apply_noresp_thunk::<T, C>,
                    prop,
                    unsafe { env_bytes_of(&c) },
                    &[],
                    true,
                );
                std::mem::forget(c);
                req
            },
            None,
        );
    }

    /// Synchronous delegation with serialized arguments (§4.3.3): `args`
    /// may be any `Wire` type (tuples for multiple values); variable-size
    /// payloads travel through the channel rather than the closure env.
    pub fn apply_with<V, U, C>(&self, c: C, args: V) -> U
    where
        V: Wire + Send + 'static,
        U: Wire + Send + 'static,
        C: FnOnce(&mut T, V) -> U + Send + 'static,
    {
        if self.is_local() {
            return self.run_local(move |t| c(t, args));
        }
        match try_worker_id() {
            Some(_) => {
                check_blocking_allowed("apply_with()");
                let prop = self.prop_u8();
                let ser = to_bytes(&args);
                drop(args);
                delegate_blocking(self.trustee, move |buf| {
                    let req = RequestBuilder::build(
                        buf,
                        apply_with_thunk::<T, V, U, C>,
                        prop,
                        unsafe { env_bytes_of(&c) },
                        &ser,
                        false,
                    );
                    std::mem::forget(c);
                    req
                })
            }
            None => self.apply_injected(move |t| c(t, args)),
        }
    }

    /// Non-blocking variant of [`Trust::apply_with`].
    pub fn apply_with_then<V, U, C, F>(&self, c: C, args: V, then: F)
    where
        V: Wire + Send + 'static,
        U: Wire + Send + 'static,
        C: FnOnce(&mut T, V) -> U + Send + 'static,
        F: FnOnce(U) + 'static,
    {
        if self.is_local() {
            let u = self.run_local(move |t| c(t, args));
            then(u);
            return;
        }
        assert!(
            try_worker_id().is_some(),
            "apply_with_then requires a runtime worker thread"
        );
        let prop = self.prop_u8();
        let ser = to_bytes(&args);
        drop(args);
        let completion: crate::channel::Completion = Some(Box::new(move |r| {
            let u = read_response::<U>(r);
            then(u);
        }));
        enqueue_on_worker(
            self.trustee,
            move |buf| {
                let req = RequestBuilder::build(
                    buf,
                    apply_with_thunk::<T, V, U, C>,
                    prop,
                    unsafe { env_bytes_of(&c) },
                    &ser,
                    false,
                );
                std::mem::forget(c);
                req
            },
            completion,
        );
    }

    /// Adjust the refcount from whatever context we're in.
    fn rc_delta(&self, delta: i64) {
        match try_worker_id() {
            Some(id) if id == self.trustee => {
                // Direct: we are the trustee thread.
                let h = unsafe { &(*self.prop.as_ptr()).header };
                let rc = (h.refcount.get() as i64 + delta) as u64;
                h.refcount.set(rc);
                if rc == 0 {
                    let idx = h.reg_idx.get();
                    with_worker(|w| unsafe { w.registry.reclaim(idx) });
                }
            }
            Some(_) => {
                // Fire-and-forget request; legal even in delegated context.
                let prop = self.prop_u8();
                enqueue_on_worker(
                    self.trustee,
                    move |buf| {
                        RequestBuilder::build(
                            buf,
                            rc_delta_thunk,
                            prop,
                            &delta.to_le_bytes(),
                            &[],
                            true,
                        )
                    },
                    None,
                );
            }
            None => {
                if self.shared.is_stopped() {
                    // Runtime already gone: property was reclaimed at
                    // worker shutdown; nothing to do.
                    return;
                }
                let prop_addr = self.prop.as_ptr() as usize;
                self.shared.inject(
                    self.trustee,
                    Box::new(move |w| {
                        let h = unsafe { &*(prop_addr as *const PropHeader) };
                        let rc = (h.refcount.get() as i64 + delta) as u64;
                        h.refcount.set(rc);
                        if rc == 0 {
                            let idx = h.reg_idx.get();
                            unsafe { w.registry.reclaim(idx) };
                        }
                    }),
                );
            }
        }
    }
}

impl<T: 'static> Trust<Latch<T>> {
    /// Apply `c` in a *trustee-side fiber* (§4.3, Fig. 4): unlike `apply`,
    /// the closure may block — including nested blocking delegation —
    /// because a suspension parks only the temporary fiber, not the
    /// trustee. Property access is serialized by the [`Latch`].
    ///
    /// Costs one extra delegation round-trip versus `apply`.
    pub fn launch<U, C>(&self, c: C) -> U
    where
        U: Send + 'static,
        C: FnOnce(&mut T) -> U + Send + 'static,
    {
        check_blocking_allowed("launch()");
        let client = try_worker_id().expect("launch requires a worker");
        let mut cell = LaunchCell::<U> {
            result: None,
            fiber: fiber::current_fiber().expect("fiber"),
        };
        let cell_addr = &mut cell as *mut LaunchCell<U> as usize;

        if self.is_local() {
            // Local: no delegation needed, but the closure still runs in a
            // *separate fiber* under the latch so it may block.
            let prop = self.prop.as_ptr();
            with_worker(|w| {
                w.exec.spawn(move || {
                    // SAFETY: our Trust handle keeps the property alive for
                    // the duration (we're suspended, not dropped).
                    let latch = unsafe { &*(*prop).value.get() };
                    let u = latch.with_lock(|t| c(t));
                    deliver_launch_result::<U>(client, cell_addr, u);
                });
            });
        } else {
            #[repr(C)]
            struct LaunchEnv<C> {
                c: C,
                client: usize,
                cell_addr: usize,
            }
            let env = LaunchEnv { c, client, cell_addr };
            let prop = self.prop_u8();
            enqueue_on_worker(
                self.trustee,
                move |buf| {
                    let req = RequestBuilder::build(
                        buf,
                        launch_thunk::<T, U, C>,
                        prop,
                        unsafe { env_bytes_of(&env) },
                        &[],
                        true,
                    );
                    std::mem::forget(env);
                    req
                },
                None,
            );
        }
        fiber::suspend(|_| {});
        cell.result.take().expect("launch resumed without result")
    }
}

impl<T: 'static> Clone for Trust<T> {
    fn clone(&self) -> Self {
        self.rc_delta(1);
        Trust {
            prop: self.prop,
            trustee: self.trustee,
            shared: self.shared.clone(),
            _t: PhantomData,
        }
    }
}

impl<T: 'static> Drop for Trust<T> {
    fn drop(&mut self) {
        self.rc_delta(-1);
    }
}

impl<T: 'static> std::fmt::Debug for Trust<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Trust")
            .field("trustee", &self.trustee)
            .field("prop", &self.prop.as_ptr())
            .finish()
    }
}

// ---------------------------------------------------------------------
// Latch<T>
// ---------------------------------------------------------------------

/// Single-thread mutual exclusion with **no atomic instructions** (§4.3.1):
/// analogous to `Mutex<T>`, except it may only be used by the fibers of one
/// thread (it is deliberately `!Sync`). Waiting fibers queue FIFO.
pub struct Latch<T> {
    locked: Cell<bool>,
    waiters: RefCell<VecDeque<FiberId>>,
    value: UnsafeCell<T>,
}

// Latch is Send (can be entrusted/moved between threads while unused) but
// intentionally NOT Sync — the compiler derives !Sync from Cell/RefCell,
// which is exactly the paper's footnote 4.
unsafe impl<T: Send> Send for Latch<T> {}

impl<T> Latch<T> {
    pub fn new(value: T) -> Latch<T> {
        Latch {
            locked: Cell::new(false),
            waiters: RefCell::new(VecDeque::new()),
            value: UnsafeCell::new(value),
        }
    }

    pub fn into_inner(self) -> T {
        self.value.into_inner()
    }

    /// Is the latch currently held?
    pub fn is_locked(&self) -> bool {
        self.locked.get()
    }

    /// Acquire the latch, suspending the current fiber while contended;
    /// run `f` on the value; release and wake the next waiter.
    pub fn with_lock<R>(&self, f: impl FnOnce(&mut T) -> R) -> R {
        while self.locked.get() {
            fiber::suspend(|id| self.waiters.borrow_mut().push_back(id));
        }
        self.locked.set(true);
        // SAFETY: single-thread + locked: unique access.
        let r = f(unsafe { &mut *self.value.get() });
        self.locked.set(false);
        if let Some(next) = self.waiters.borrow_mut().pop_front() {
            fiber::with_executor(|e| e.resume(next));
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn minimal_example_figure_1() {
        // Figure 1: entrust 17, increment, read back 18 (19 in Fig 2 after
        // two increments).
        let rt = Runtime::builder().workers(1).build();
        rt.block_on(0, || {
            let ct = local_trustee().entrust(17u64);
            ct.apply(|c| *c += 1);
            assert_eq!(ct.apply(|c| *c), 18);
        });
        rt.shutdown();
    }

    #[test]
    fn multi_thread_example_figure_2() {
        // Figure 2a: two workers increment the same entrusted counter.
        let rt = Runtime::builder().workers(2).build();
        let ct = rt.block_on(0, || local_trustee().entrust(17u64));
        let ct2 = ct.clone();
        let h = {
            let rt_ref = &rt;
            let done: u64 = rt_ref.block_on(1, move || {
                ct2.apply(|c| *c += 1);
                0u64
            });
            done
        };
        let _ = h;
        ct.apply(|c| *c += 1); // injected slow path from the main thread
        assert_eq!(ct.apply(|c| *c), 19);
        drop(ct);
        rt.shutdown();
    }

    #[test]
    fn cross_worker_delegation() {
        let rt = Runtime::builder().workers(3).build();
        // Property lives on worker 0; fibers on workers 1 and 2 hammer it.
        let ct = rt.block_on(0, || local_trustee().entrust(0u64));
        let mut handles = Vec::new();
        for w in 1..3 {
            let ct = ct.clone();
            let rt_shared = rt.shared().clone();
            let _ = rt_shared;
            handles.push(std::thread::spawn({
                let ct = ct.clone();
                move || ct // keep a clone alive across threads
            }));
            let ctw = ct.clone();
            rt.spawn_on(w, move || {
                for _ in 0..100 {
                    ctw.apply(|c| *c += 1);
                }
            });
        }
        for h in handles {
            let _ = h.join().unwrap();
        }
        // Wait for the spawned fibers by doing our own 100 increments from
        // each worker via block_on (runs after the spawned fibers finish
        // enqueueing... not guaranteed), so instead poll the value.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
        loop {
            let v = {
                let ct = ct.clone();
                rt.block_on(1, move || ct.apply(|c| *c))
            };
            if v == 200 {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "stuck at {v}/200");
            std::thread::yield_now();
        }
        drop(ct);
        rt.shutdown();
    }

    #[test]
    fn apply_then_async() {
        // Figure 3: asynchronous increment + then-callback.
        let rt = Runtime::builder().workers(2).build();
        let got = Arc::new(AtomicU64::new(0));
        let g = got.clone();
        let ct = rt.block_on(0, || local_trustee().entrust(17u64));
        let ct1 = ct.clone();
        rt.block_on(1, move || {
            let g2 = g.clone();
            ct1.apply_then(
                |c| {
                    *c += 1;
                    *c
                },
                move |v| g2.store(v, Ordering::Release),
            );
            // Wait for the callback by blocking on a second apply (in-order
            // per client-trustee pair: response 1 arrives first).
            let v = ct1.apply(|c| *c);
            assert_eq!(v, 18);
        });
        assert_eq!(got.load(Ordering::Acquire), 18);
        drop(ct);
        rt.shutdown();
    }

    #[test]
    fn apply_with_serialized_args() {
        let rt = Runtime::builder().workers(2).build();
        let table = rt.block_on(0, || {
            local_trustee().entrust(std::collections::HashMap::<String, String>::new())
        });
        let t1 = table.clone();
        let len = rt.block_on(1, move || {
            // Variable-size key/value travel serialized (§4.3.3).
            t1.apply_with(
                |table, (k, v): (String, String)| {
                    table.insert(k, v);
                    table.len() as u64
                },
                ("hello".to_string(), "world".to_string()),
            )
        });
        assert_eq!(len, 1);
        let t2 = table.clone();
        let v = rt.block_on(1, move || {
            t2.apply_with(|table, k: String| table.get(&k).cloned(), "hello".to_string())
        });
        assert_eq!(v.as_deref(), Some("world"));
        drop(table);
        rt.shutdown();
    }

    #[test]
    fn refcount_reclaims_property() {
        // Drop both trusts; the property must be reclaimed (registry empty).
        let rt = Runtime::builder().workers(2).build();
        let ct = rt.block_on(0, || local_trustee().entrust(vec![1u8, 2, 3]));
        let ct2 = ct.clone();
        let v = rt.block_on(1, move || ct2.apply(|v| v.len() as u64));
        assert_eq!(v, 3);
        drop(ct);
        // Give the refcount decs time to flow, then check via worker 0.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
        loop {
            let live = rt.block_on(0, || with_worker(|w| w.registry.live));
            if live == 0 {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "{live} props leaked");
        }
        rt.shutdown();
    }

    #[test]
    fn local_shortcut_applies_inline() {
        let rt = Runtime::builder().workers(1).build();
        let hits = rt.block_on(0, || {
            let ct = local_trustee().entrust(0u64);
            // All local: each apply runs inline via the shortcut (§5.2.1).
            for _ in 0..1000 {
                ct.apply(|c| *c += 1);
            }
            ct.apply(|c| *c)
        });
        assert_eq!(hits, 1000);
        rt.shutdown();
    }

    #[test]
    fn delegated_context_flag_visible() {
        let rt = Runtime::builder().workers(1).build();
        let (outside, inside) = rt.block_on(0, || {
            let ct = local_trustee().entrust(0u64);
            let outside = in_delegated_context();
            let inside = ct.apply(|_| in_delegated_context());
            (outside, inside)
        });
        assert!(!outside);
        assert!(inside, "closure must run in delegated context");
        rt.shutdown();
    }

    #[test]
    fn nested_blocking_apply_in_delegated_context_panics() {
        // The paper's runtime assertion (§3.4/§4.3): blocking delegation
        // inside a delegated closure must fail fast. We test the client-
        // side check through the local shortcut (same flag, same assert,
        // catchable because the panic fires on the caller's fiber).
        let rt = Runtime::builder().workers(1).build();
        let panicked = rt.block_on(0, || {
            let ct = local_trustee().entrust(0u64);
            let ct2 = ct.clone();
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                ct.apply(move |_| {
                    // Nested blocking apply to a *remote-looking* path:
                    // local shortcut still asserts via run_local's
                    // delegated flag when re-entering apply... the
                    // local shortcut IS legal (runs inline), so force the
                    // blocking check directly:
                    check_blocking_allowed("apply()");
                    let _ = ct2; // keep the clone captured
                    0u64
                })
            }))
            .is_err()
        });
        assert!(panicked, "blocking call in delegated context must assert");
        rt.shutdown();
    }

    #[test]
    fn apply_then_legal_in_delegated_context() {
        let rt = Runtime::builder().workers(2).build();
        let a = rt.block_on(0, || local_trustee().entrust(0u64));
        let b = rt.block_on(1, || local_trustee().entrust(0u64));
        let a2 = a.clone();
        let b2 = b.clone();
        // From worker 1's fiber, delegate to a (worker 0); inside that
        // delegated closure, issue a non-blocking apply_then to b (worker
        // 1) — legal per §4.2.
        let v = rt.block_on(1, move || {
            a2.apply(move |x| {
                *x += 1;
                b2.apply_then(|y| *y += 10, |_| {});
                *x
            })
        });
        assert_eq!(v, 1);
        // b eventually becomes 10.
        let b3 = b.clone();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
        loop {
            let bv = {
                let b3 = b3.clone();
                rt.block_on(1, move || b3.apply(|y| *y))
            };
            if bv == 10 {
                break;
            }
            assert!(std::time::Instant::now() < deadline);
        }
        drop((a, b, b3));
        rt.shutdown();
    }

    #[test]
    fn launch_allows_nested_blocking_delegation() {
        // §4.3 / Fig. 4: a launched closure may perform blocking delegation.
        let rt = Runtime::builder().workers(2).build();
        let inner = rt.block_on(0, || local_trustee().entrust(5u64));
        let outer = rt.block_on(0, || local_trustee().entrust(Latch::new(100u64)));
        let inner2 = inner.clone();
        let outer2 = outer.clone();
        let v = rt.block_on(1, move || {
            outer2.launch(move |x| {
                // Blocking apply from within launched (trustee-side) fiber.
                let add = inner2.apply(|i| *i);
                *x += add;
                *x
            })
        });
        assert_eq!(v, 105);
        drop((inner, outer));
        rt.shutdown();
    }

    #[test]
    fn launch_serializes_via_latch() {
        let rt = Runtime::builder().workers(3).build();
        let prop = rt.block_on(0, || local_trustee().entrust(Latch::new(Vec::<u64>::new())));
        // Two concurrent launches from different workers; each appends its
        // tag twice with a yield between — the latch must keep the pairs
        // contiguous (no interleaving on the shared Vec).
        let done = Arc::new(AtomicU64::new(0));
        for (w, tag) in [(1usize, 7u64), (2usize, 9u64)] {
            let p = prop.clone();
            let d = done.clone();
            rt.spawn_on(w, move || {
                p.launch(move |v| {
                    v.push(tag);
                    fiber::yield_now(); // suspend inside the critical section
                    v.push(tag);
                });
                d.fetch_add(1, Ordering::AcqRel);
            });
        }
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
        while done.load(Ordering::Acquire) != 2 {
            assert!(std::time::Instant::now() < deadline, "launches stuck");
            std::thread::yield_now();
        }
        let p = prop.clone();
        let v = rt.block_on(1, move || p.apply(|l| l.with_lock(|v| v.clone())));
        assert_eq!(v.len(), 4);
        assert_eq!(v[0], v[1], "latch must prevent interleaving");
        assert_eq!(v[2], v[3]);
        drop(prop);
        rt.shutdown();
    }

    #[test]
    fn trust_is_send_and_sync() {
        fn assert_send_sync<X: Send + Sync>() {}
        assert_send_sync::<Trust<u64>>();
        assert_send_sync::<Trust<Vec<String>>>();
    }

    #[test]
    fn latch_is_not_sync() {
        // Compile-time property (paper footnote 4); checked via trait
        // presence using autoref specialization trick at runtime is
        // overkill — static_assertions style negative impl test:
        fn requires_sync<X: Sync>() -> bool {
            true
        }
        let _ = requires_sync::<u64>;
        // Latch<T> must not satisfy Sync: enforced by the compiler; this
        // test documents it (uncommenting the next line fails to build).
        // let _ = requires_sync::<Latch<u64>>;
    }

    #[test]
    fn entrust_from_remote_worker_fiber() {
        let rt = Runtime::builder().workers(2).build();
        let shared = rt.shared().clone();
        let tr = TrusteeRef::new(shared, 0);
        let v = rt.block_on(1, move || {
            // entrust from worker 1 onto trustee 0 — delegated entrust.
            let ct = tr.entrust(vec![10u64, 20, 30]);
            assert_eq!(ct.trustee_id(), 0);
            ct.apply(|v| v.iter().sum::<u64>())
        });
        assert_eq!(v, 60);
        rt.shutdown();
    }

    #[test]
    fn string_property_roundtrip() {
        let rt = Runtime::builder().workers(2).build();
        let ct = rt.block_on(0, || local_trustee().entrust(String::from("abc")));
        let ct2 = ct.clone();
        let s = rt.block_on(1, move || {
            ct2.apply(|s| {
                s.push_str("def");
                s.clone()
            })
        });
        assert_eq!(s, "abcdef");
        drop(ct);
        rt.shutdown();
    }
}
