//! `Trust<T>` — the paper's programming abstraction (§3, §4).
//!
//! A [`Trust<T>`] is a thread-safe reference-counting smart pointer to a
//! *property* of type `T` owned by a *trustee* worker thread. The property
//! is only accessible by applying closures through the trust:
//!
//! - [`Trust::apply`] — synchronous delegation (§4.1): suspends the calling
//!   fiber until the closure has been applied, returns its value.
//! - [`Trust::apply_then`] — non-blocking delegation (§4.2): returns
//!   immediately; the `then` closure runs on the caller's worker with the
//!   return value. Safe to call from delegated context.
//! - [`Trust::apply_with`] / [`Trust::apply_with_then`] — variable-size and
//!   heap-allocated arguments travel serialized over the channel (§4.3.3).
//! - [`Trust::launch`] (on `Trust<Latch<T>>`) — apply in a trustee-side
//!   fiber so the closure may block, including nested blocking delegation
//!   (§4.3, Fig. 4), guarded by the no-atomics [`Latch`] (§4.3.1).
//!
//! Reference counting is itself delegated (§3.1): the count is a plain
//! non-atomic field only the trustee mutates. `drop` posts a
//! fire-and-forget decrement; `clone` is **acked** — it returns only once
//! the trustee has applied the `+1` — because an unacknowledged increment
//! and a remote holder's decrement travel on *different* client→trustee
//! slot pairs and the decrement could land first, hit zero, and reclaim
//! the property under a live handle (DESIGN.md, refcount ordering
//! contract). When the last trust drops, the trustee drops the property.
//!
//! ## Safety discipline (§4.3.2)
//! Delegated closures must own their captures: the bounds are
//! `C: FnOnce(&mut T) -> U + Send + 'static`, so captured borrows are
//! rejected at compile time by the Rust borrow checker, exactly the
//! property the paper leans on. (The paper additionally bans *owned*
//! pointer types like `Box<T>` in captures to encourage locality; we keep
//! the type-system-enforced part and document the convention.)

use crate::channel::{read_response, Completion, ResponseWriter, Thunk};
use crate::codec::{Wire, WireReader, WireWriter};
use crate::fiber::{self, FiberId};
use crate::runtime::{
    in_delegated_context, reclaim_on_current_worker, try_worker_id, with_worker, Shared, Worker,
};
use crate::util::cache::Backoff;
use std::cell::{Cell, RefCell, UnsafeCell};
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::mem::size_of;
use std::ptr::NonNull;
use std::sync::atomic::{AtomicBool, Ordering as AtomicOrdering};
use std::sync::{Arc, Condvar, Mutex};

/// Header shared by all entrusted properties; must be the first field of
/// [`PropBox`] so type-erased refcount thunks can operate on it.
#[repr(C)]
pub(crate) struct PropHeader {
    /// Mutated only by the trustee thread — no atomics (§2).
    refcount: Cell<u64>,
    /// Index in the trustee worker's property registry.
    reg_idx: Cell<usize>,
}

/// An entrusted property: header + value, allocated on the trustee thread
/// for locality.
#[repr(C)]
pub(crate) struct PropBox<T> {
    header: PropHeader,
    value: UnsafeCell<T>,
}

/// # Safety
/// `p` must be the pointer the registry stored from
/// `Box::into_raw::<PropBox<T>>` with this same `T`, not yet reclaimed.
unsafe fn drop_propbox<T>(p: *mut u8) {
    // SAFETY: registry stored this pointer from Box::into_raw::<PropBox<T>>.
    unsafe { drop(Box::from_raw(p as *mut PropBox<T>)) };
}

/// Allocate + register a property on the current worker (must be the
/// trustee thread).
fn alloc_propbox<T: 'static>(w: &mut Worker, value: T) -> *mut PropBox<T> {
    let boxed = Box::new(PropBox {
        header: PropHeader { refcount: Cell::new(1), reg_idx: Cell::new(usize::MAX) },
        value: UnsafeCell::new(value),
    });
    let ptr = Box::into_raw(boxed);
    let idx = w.registry.register(ptr as *mut u8, drop_propbox::<T>);
    // SAFETY: just allocated, we own it.
    unsafe { (*ptr).header.reg_idx.set(idx) };
    ptr
}

// ---------------------------------------------------------------------
// Thunks (run on the trustee thread, in delegated context)
// ---------------------------------------------------------------------

/// apply(): take the closure env by value, run it on the property, respond.
///
/// # Safety
/// Thunk contract: `env` holds a forgotten `C` (read exactly once);
/// `prop` points at the live `PropBox<T>` owned by this trustee.
unsafe fn apply_thunk<T, U, C>(env: *const u8, prop: *mut u8, _args: &[u8], out: &mut ResponseWriter)
where
    U: Wire,
    C: FnOnce(&mut T) -> U,
{
    // SAFETY: env holds a forgotten C by value; prop is a live PropBox<T>.
    unsafe {
        let c = env.cast::<C>().read_unaligned();
        let pb = prop as *mut PropBox<T>;
        let u = c(&mut *(*pb).value.get());
        out.write_value(&u);
    }
}

/// apply() variant without a response (fire-and-forget).
///
/// # Safety
/// Same thunk contract as [`apply_thunk`].
unsafe fn apply_noresp_thunk<T, C>(env: *const u8, prop: *mut u8, _args: &[u8], _out: &mut ResponseWriter)
where
    C: FnOnce(&mut T),
{
    // SAFETY: env holds a forgotten C by value; prop is a live PropBox<T>.
    unsafe {
        let c = env.cast::<C>().read_unaligned();
        let pb = prop as *mut PropBox<T>;
        c(&mut *(*pb).value.get());
    }
}

/// apply_with(): also decode serialized args.
///
/// # Safety
/// Same thunk contract as [`apply_thunk`]; `args` carry a wire-encoded `V`.
unsafe fn apply_with_thunk<T, V, U, C>(
    env: *const u8,
    prop: *mut u8,
    args: &[u8],
    out: &mut ResponseWriter,
) where
    V: Wire,
    U: Wire,
    C: FnOnce(&mut T, V) -> U,
{
    // SAFETY: env holds a forgotten C by value; prop is a live PropBox<T>.
    unsafe {
        let c = env.cast::<C>().read_unaligned();
        let mut r = WireReader::new(args);
        let v = V::read(&mut r).expect("apply_with argument decode");
        let pb = prop as *mut PropBox<T>;
        let u = c(&mut *(*pb).value.get(), v);
        out.write_value(&u);
    }
}

/// apply_raw(): the closure receives the framed argument bytes as a
/// borrowed slice (no decode allocation) and writes its response directly
/// into the channel's response writer — the allocation-free data path
/// behind the KV backends (one-copy GET).
///
/// # Safety
/// Same thunk contract as [`apply_thunk`]; `args` borrow the framed bytes.
unsafe fn apply_raw_thunk<T, C>(env: *const u8, prop: *mut u8, args: &[u8], out: &mut ResponseWriter)
where
    C: FnOnce(&mut T, &[u8], &mut ResponseWriter),
{
    // SAFETY: env holds a forgotten C by value; prop is a live PropBox<T>.
    unsafe {
        let c = env.cast::<C>().read_unaligned();
        let pb = prop as *mut PropBox<T>;
        c(&mut *(*pb).value.get(), args, out);
    }
}

/// Type-erased refcount adjustment; reclaims the property at zero.
///
/// # Safety
/// `env` holds a framed `i64` delta; `prop` points at the live property's
/// `PropHeader` (refcount touched only by this trustee).
unsafe fn rc_delta_thunk(env: *const u8, prop: *mut u8, _args: &[u8], _out: &mut ResponseWriter) {
    // SAFETY: per the contract above; reclaim consumes the registry slot once.
    unsafe {
        let delta = env.cast::<i64>().read_unaligned();
        let h = &*(prop as *const PropHeader);
        let rc = (h.refcount.get() as i64 + delta) as u64;
        h.refcount.set(rc);
        if rc == 0 {
            let idx = h.reg_idx.get();
            reclaim_on_current_worker(idx);
        }
    }
}

/// Acked refcount increment (`Trust::clone`): bump, then respond with the
/// new count so the cloning side can sequence the clone *behind* the
/// increment. Without the ack, the clone's `+1` and a remote holder's `-1`
/// travel on different client→trustee slot pairs, and the `-1` can land
/// first, hit zero, and reclaim the property under a live handle (see
/// DESIGN.md, "refcount ordering contract").
///
/// # Safety
/// `prop` points at the live property's `PropHeader`; only this trustee
/// mutates the refcount.
unsafe fn rc_inc_ack_thunk(
    _env: *const u8,
    prop: *mut u8,
    _args: &[u8],
    out: &mut ResponseWriter,
) {
    // SAFETY: prop is the live PropHeader; the refcount is trustee-private.
    unsafe {
        let h = &*(prop as *const PropHeader);
        let rc = h.refcount.get() + 1;
        h.refcount.set(rc);
        out.write_value(&rc);
    }
}

/// Spin-path variant of the acked increment, for cloners that cannot
/// suspend (delegated context / scheduler stack): fire-and-forget on the
/// response stream, acked through a side-channel flag on the cloner's
/// stack instead. The cloner spins on the flag *without dispatching any
/// completions* ([`Worker::poll_detach`]), so no foreign user code runs
/// re-entrantly under the in-progress delegated closure. The flag store
/// is a plain `mov` on x86-64 (Release store, no RMW), preserving the
/// paper's no-atomic-instructions property on the data path.
///
/// # Safety
/// `env` holds the address of the cloner's spin flag, which stays live
/// until the flag is set; `prop` points at the live `PropHeader`.
unsafe fn rc_inc_spin_ack_thunk(
    env: *const u8,
    prop: *mut u8,
    _args: &[u8],
    _out: &mut ResponseWriter,
) {
    // SAFETY: per the contract — flag_addr outlives the spin; prop is the
    // live PropHeader.
    unsafe {
        let flag_addr = env.cast::<usize>().read_unaligned();
        let h = &*(prop as *const PropHeader);
        h.refcount.set(h.refcount.get() + 1);
        // SAFETY: the cloner spins on this stack slot until the store.
        (*(flag_addr as *const AtomicBool)).store(true, AtomicOrdering::Release);
    }
}

/// Is `thunk_raw` (a framed record's thunk word) one of the refcount
/// *increment* thunks? Passed to the channel's admission pre-scan by the
/// clone-ack spin: increment thunks touch only the property header — no
/// user code, no reclamation, no runtime re-entry — so a batch made solely
/// of them is safe to serve while a delegated closure is still running.
/// (Decrements are deliberately excluded: a `-1` can reclaim the property,
/// which runs the value's `Drop` — foreign user code.)
pub(crate) fn is_rc_increment_thunk(thunk_raw: u64) -> bool {
    thunk_raw == (rc_inc_ack_thunk as crate::channel::Thunk) as usize as u64
        || thunk_raw == (rc_inc_spin_ack_thunk as crate::channel::Thunk) as usize as u64
}

/// entrust(): move the value in, allocate the PropBox here, respond with
/// its address.
///
/// # Safety
/// `env` holds a forgotten `T` moved in by `entrust` (read exactly once).
unsafe fn entrust_thunk<T: 'static>(
    env: *const u8,
    _prop: *mut u8,
    _args: &[u8],
    out: &mut ResponseWriter,
) {
    // SAFETY: env holds the forgotten T; read exactly once and boxed.
    unsafe {
        let v = env.cast::<T>().read_unaligned();
        let ptr = with_worker(|w| alloc_propbox(w, v));
        out.write_value(&(ptr as usize as u64));
    }
}

/// RAII delegated-context flag: set on enter, restored on drop, so the
/// flag survives panics and — crucially — no worker borrow is held while
/// the guarded user closure runs.
struct DelegatedGuard {
    prev: bool,
}

impl DelegatedGuard {
    fn enter() -> DelegatedGuard {
        DelegatedGuard { prev: with_worker(|w| w.set_delegated(true)) }
    }
}

impl Drop for DelegatedGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        with_worker(|w| {
            w.set_delegated(prev);
        });
    }
}

/// launch(): spawn a trustee-side fiber running the closure under the
/// latch; deliver the result via a second delegation call (Fig. 4).
///
/// # Safety
/// Thunk contract: `env` holds a forgotten `LaunchEnv<C>` (read once);
/// `prop` points at the live `PropBox<Latch<T>>`.
unsafe fn launch_thunk<T, U, C>(env: *const u8, prop: *mut u8, _args: &[u8], _out: &mut ResponseWriter)
where
    T: 'static,
    U: Send + 'static,
    C: FnOnce(&mut T) -> U + Send + 'static,
{
    #[repr(C)]
    struct LaunchEnv<C> {
        c: C,
        client: usize,
        cell_addr: usize,
    }
    // SAFETY: env holds the forgotten LaunchEnv<C>; prop is the live
    // PropBox<Latch<T>>.
    unsafe {
        let LaunchEnv { c, client, cell_addr } = env.cast::<LaunchEnv<C>>().read_unaligned();
        let latch_prop = prop as *mut PropBox<Latch<T>>;
        // Creating the fiber is non-blocking — legal in delegated context.
        // Spawn through the executor TLS (not a worker borrow): the fiber
        // body is foreign code that re-enters the runtime freely.
        fiber::with_executor(|e| {
            e.spawn(move || {
                // SAFETY: the client's Trust handle is borrowed for the whole
                // launch, keeping the property alive.
                let latch = unsafe { &*(*latch_prop).value.get() };
                let u = latch.with_lock(|t| c(t));
                // Second delegation call: fire-and-forget completion back to
                // the client worker (we are a client of `client` here).
                deliver_launch_result::<U>(client, cell_addr, u);
            });
        });
    }
}

/// Cell the launching fiber sleeps on.
struct LaunchCell<U> {
    result: Option<U>,
    fiber: FiberId,
}

fn deliver_launch_result<U: Send + 'static>(client: usize, cell_addr: usize, u: U) {
    // Local fast path: the launch came from a fiber on this same worker.
    if try_worker_id() == Some(client) {
        // SAFETY: cell lives on the (parked) launching fiber's stack.
        unsafe {
            let cell = &mut *(cell_addr as *mut LaunchCell<U>);
            cell.result = Some(u);
            let fid = cell.fiber;
            fiber::with_executor(|e| e.resume(fid));
        }
        return;
    }
    #[repr(C)]
    struct DoneEnv<U> {
        u: U,
        cell_addr: usize,
    }
    ///
    /// # Safety
    /// `env` holds a forgotten `DoneEnv<U>`; `cell_addr` points at the
    /// `LaunchCell` pinned on the client fiber's suspended stack.
    unsafe fn launch_done_thunk<U: Send + 'static>(
        env: *const u8,
        _prop: *mut u8,
        _args: &[u8],
        _out: &mut ResponseWriter,
    ) {
        // Runs on the *client's* worker, in delegated context.
        // SAFETY: env holds the forgotten DoneEnv<U>; the cell outlives the
        // suspended fiber that owns it.
        unsafe {
            let DoneEnv { u, cell_addr } = env.cast::<DoneEnv<U>>().read_unaligned();
            let cell = &mut *(cell_addr as *mut LaunchCell<U>);
            cell.result = Some(u);
            let fid = cell.fiber;
            fiber::with_executor(|e| e.resume(fid));
        }
    }
    let done = DoneEnv { u, cell_addr };
    // SAFETY: done is a live value on this frame; the bytes are copied by
    // the framing call and the original is forgotten below (a move).
    let env_bytes = unsafe {
        std::slice::from_raw_parts(&done as *const DoneEnv<U> as *const u8, size_of::<DoneEnv<U>>())
    };
    with_worker(|w| {
        // Urgent: the launching fiber is parked on this completion.
        w.enqueue_framed(
            client,
            launch_done_thunk::<U>,
            std::ptr::null_mut(),
            env_bytes,
            Completion::none(),
            true,
            |_| {},
        );
    });
    std::mem::forget(done);
}

// ---------------------------------------------------------------------
// Client-side plumbing
// ---------------------------------------------------------------------

/// Panic unless a *blocking* delegation call is legal right now (§3.4).
#[track_caller]
fn check_blocking_allowed(what: &str) {
    assert!(
        !in_delegated_context(),
        "Trust<T>: blocking {what} in delegated context — \
         use apply_then() or launch() instead (paper §4.3)"
    );
    assert!(
        fiber::in_fiber(),
        "Trust<T>: blocking {what} requires fiber context \
         (call from a runtime fiber, or use Runtime::block_on)"
    );
}

/// Frame a request directly into the current worker's outbox arena toward
/// `trustee` (reserve/commit — no temp framing buffer). `urgent` requests
/// flush immediately (a caller is about to suspend on the response); the
/// rest follow the worker's [`FlushPolicy`] — outbox watermarks or the
/// end-of-client-phase flush.
///
/// Callers pass the closure environment as raw bytes and `mem::forget`
/// the original **after** this returns (the bytes were copied by value
/// into the arena); `write_args` serializes `apply_with` arguments
/// straight into the arena.
///
/// [`FlushPolicy`]: crate::channel::FlushPolicy
fn enqueue_on_worker(
    trustee: usize,
    thunk: Thunk,
    prop: *mut u8,
    env: &[u8],
    completion: Completion,
    urgent: bool,
    write_args: impl FnOnce(&mut WireWriter),
) {
    with_worker(|w| w.enqueue_framed(trustee, thunk, prop, env, completion, urgent, write_args));
}

/// Blocking wait for a response value: enqueue (via `enqueue`, which
/// receives the completion to attach), suspend, decode. The completion
/// captures one raw pointer, so it always stores inline — a blocking
/// apply performs zero allocations at steady state.
fn delegate_blocking<U: Wire + 'static>(enqueue: impl FnOnce(Completion)) -> U {
    struct WaitCell<U> {
        result: Option<U>,
        fiber: FiberId,
    }
    let mut cell = WaitCell::<U> { result: None, fiber: fiber::current_fiber().expect("fiber") };
    let cell_ptr: *mut WaitCell<U> = &mut cell;
    let completion = Completion::new(move |r: &mut WireReader<'_>| {
        let u = read_response::<U>(r);
        // SAFETY: the cell lives on the parked fiber's stack until resume.
        unsafe {
            (*cell_ptr).result = Some(u);
            let fid = (*cell_ptr).fiber;
            fiber::with_executor(|e| e.resume(fid));
        }
    });
    // Urgent: we suspend on the response right away.
    enqueue(completion);
    fiber::suspend(|_| {});
    cell.result.take().expect("resumed without response")
}

/// env bytes of a value to be moved through the channel. Caller must
/// `mem::forget` the value after framing.
///
/// # Safety
/// The returned bytes are a *move* of `c`: the caller must copy them
/// exactly once and `mem::forget` the original.
unsafe fn env_bytes_of<C>(c: &C) -> &[u8] {
    // SAFETY: any live value is readable as size_of::<C>() bytes.
    unsafe { std::slice::from_raw_parts(c as *const C as *const u8, size_of::<C>()) }
}

thread_local! {
    /// Recycled scratch buffers for the trustee-local shortcut of
    /// [`Trust::apply_raw_then`]: the closure's response bytes bounce
    /// through one of these (same wire format as the remote path) without
    /// allocating per call. A small stack because the closure / `then`
    /// may re-enter nested local raw applies.
    static LOCAL_RAW_BUFS: RefCell<Vec<Vec<u8>>> = const { RefCell::new(Vec::new()) };
}

fn take_local_raw_buf() -> Vec<u8> {
    let mut b = LOCAL_RAW_BUFS.with(|p| p.borrow_mut().pop()).unwrap_or_default();
    b.clear();
    b
}

fn put_local_raw_buf(b: Vec<u8>) {
    LOCAL_RAW_BUFS.with(|p| {
        let mut pool = p.borrow_mut();
        if pool.len() < 8 && b.capacity() <= (1 << 20) {
            pool.push(b);
        }
    });
}

// ---------------------------------------------------------------------
// TrusteeRef
// ---------------------------------------------------------------------

/// A reference to a trustee worker — the manual property-placement API
/// (§3.2): `entrust()` moves a value to that trustee and returns a
/// [`Trust<T>`].
#[derive(Clone)]
pub struct TrusteeRef {
    shared: Arc<Shared>,
    worker: usize,
}

impl TrusteeRef {
    pub(crate) fn new(shared: Arc<Shared>, worker: usize) -> TrusteeRef {
        TrusteeRef { shared, worker }
    }

    /// The worker id this trustee runs on.
    pub fn worker_id(&self) -> usize {
        self.worker
    }

    /// Move `value` into the care of this trustee.
    ///
    /// Callable from: the trustee's own thread (direct), another worker's
    /// fiber (delegated), or a non-runtime thread (injected).
    pub fn entrust<T: Send + 'static>(&self, value: T) -> Trust<T> {
        let ptr: *mut PropBox<T> = match try_worker_id() {
            Some(id) if id == self.worker => with_worker(|w| alloc_propbox(w, value)),
            Some(_) => {
                check_blocking_allowed("entrust()");
                let worker = self.worker;
                let addr: u64 = delegate_blocking(move |completion| {
                    enqueue_on_worker(
                        worker,
                        entrust_thunk::<T>,
                        std::ptr::null_mut(),
                        // SAFETY: framing copies the bytes once; value is forgotten below.
                        unsafe { env_bytes_of(&value) },
                        completion,
                        true,
                        |_| {},
                    );
                    std::mem::forget(value);
                });
                addr as usize as *mut PropBox<T>
            }
            None => {
                // Injected job + condvar (start-up path).
                let done = Arc::new((Mutex::new(None::<usize>), Condvar::new()));
                let done2 = done.clone();
                self.shared.inject(
                    self.worker,
                    Box::new(move || {
                        let p = with_worker(|w| alloc_propbox(w, value)) as usize;
                        let (m, cv) = &*done2;
                        *m.lock().unwrap() = Some(p);
                        cv.notify_all();
                    }),
                );
                let (m, cv) = &*done;
                let mut g = m.lock().unwrap();
                while g.is_none() {
                    g = cv.wait(g).unwrap();
                }
                g.take().unwrap() as *mut PropBox<T>
            }
        };
        Trust {
            prop: NonNull::new(ptr).unwrap(),
            trustee: self.worker,
            shared: self.shared.clone(),
            _t: PhantomData,
        }
    }
}

/// The trustee running on the current worker thread (§3.1's
/// `local_trustee()`); panics off runtime threads.
pub fn local_trustee() -> TrusteeRef {
    with_worker(|w| TrusteeRef { shared: w.shared.clone(), worker: w.id })
}

// ---------------------------------------------------------------------
// Trust<T>
// ---------------------------------------------------------------------

/// A thread-safe reference-counted handle to an entrusted property of type
/// `T` (§3.1). See the module docs for the API tour.
pub struct Trust<T: 'static> {
    prop: NonNull<PropBox<T>>,
    trustee: usize,
    shared: Arc<Shared>,
    _t: PhantomData<PropBox<T>>,
}

// SAFETY: the property itself is only ever touched by its trustee thread;
// the handle merely routes requests. T: Send because entrust moved T to
// another thread and drop may run it there.
unsafe impl<T: Send + 'static> Send for Trust<T> {}
// SAFETY: same argument — &Trust only enqueues requests; T itself is
// never touched off-trustee.
unsafe impl<T: Send + 'static> Sync for Trust<T> {}

impl<T: 'static> Trust<T> {
    /// Worker id of this property's trustee.
    pub fn trustee_id(&self) -> usize {
        self.trustee
    }

    /// Is the current thread this property's trustee?
    pub fn is_local(&self) -> bool {
        try_worker_id() == Some(self.trustee)
    }

    #[inline]
    fn prop_u8(&self) -> *mut u8 {
        self.prop.as_ptr() as *mut u8
    }

    /// Apply `c` to the property synchronously and return its result
    /// (§4.1). Suspends the calling fiber while the request is in flight.
    ///
    /// # Panics
    /// In delegated context (blocking there would sleep the trustee —
    /// §4.3), or outside fiber context on a runtime thread.
    pub fn apply<U, C>(&self, c: C) -> U
    where
        U: Wire + Send + 'static,
        C: FnOnce(&mut T) -> U + Send + 'static,
    {
        // Local-trustee shortcut (§5.2.1): applying directly is just as
        // safe, because delegated closures cannot suspend this thread.
        if self.is_local() {
            return self.run_local(c);
        }
        match try_worker_id() {
            Some(_) => {
                check_blocking_allowed("apply()");
                let prop = self.prop_u8();
                let trustee = self.trustee;
                delegate_blocking(move |completion| {
                    enqueue_on_worker(
                        trustee,
                        apply_thunk::<T, U, C>,
                        prop,
                        // SAFETY: framing copies the bytes once; c is forgotten below.
                        unsafe { env_bytes_of(&c) },
                        completion,
                        true,
                        |_| {},
                    );
                    std::mem::forget(c);
                })
            }
            None => self.apply_injected(c),
        }
    }

    /// Direct application on the trustee thread, with the delegated flag
    /// set so nested blocking calls are caught.
    ///
    /// The user closure runs with **no worker borrow held** (the flag is
    /// toggled in short [`with_worker`] bursts via the guard): if `c`
    /// clones or drops a `Trust` whose trustee is this worker, the
    /// refcount path re-enters `with_worker`, which previously aliased a
    /// live `&mut Worker` taken here.
    fn run_local<U, C: FnOnce(&mut T) -> U>(&self, c: C) -> U {
        let _guard = DelegatedGuard::enter();
        // SAFETY: we are the trustee thread; no other closure runs
        // concurrently on this property.
        c(unsafe { &mut *(*self.prop.as_ptr()).value.get() })
    }

    /// Slow path for non-runtime threads: inject the closure to the
    /// trustee and wait on a condvar. Keeps examples/tests ergonomic; the
    /// hot path never goes here.
    fn apply_injected<U, C>(&self, c: C) -> U
    where
        U: Send + 'static,
        C: FnOnce(&mut T) -> U + Send + 'static,
    {
        let done = Arc::new((Mutex::new(None::<U>), Condvar::new()));
        let done2 = done.clone();
        let prop_addr = self.prop.as_ptr() as usize;
        self.shared.inject(
            self.trustee,
            Box::new(move || {
                let pb = prop_addr as *mut PropBox<T>;
                let u = {
                    let _guard = DelegatedGuard::enter();
                    // SAFETY: trustee thread; property alive (we hold a ref).
                    c(unsafe { &mut *(*pb).value.get() })
                };
                let (m, cv) = &*done2;
                *m.lock().unwrap() = Some(u);
                cv.notify_all();
            }),
        );
        let (m, cv) = &*done;
        let mut g = m.lock().unwrap();
        while g.is_none() {
            g = cv.wait(g).unwrap();
        }
        g.take().unwrap()
    }

    /// Non-blocking delegation (§4.2): returns immediately; `then` runs on
    /// this worker with the closure's return value once the response
    /// arrives. Safe to call from delegated context.
    pub fn apply_then<U, C, F>(&self, c: C, then: F)
    where
        U: Wire + Send + 'static,
        C: FnOnce(&mut T) -> U + Send + 'static,
        F: FnOnce(U) + 'static,
    {
        if self.is_local() {
            let u = self.run_local(c);
            then(u);
            return;
        }
        assert!(
            try_worker_id().is_some(),
            "apply_then requires a runtime worker thread"
        );
        let prop = self.prop_u8();
        // Inline-stored when `then`'s captures fit the completion budget
        // (the common case) — no per-request box.
        let completion = Completion::new(move |r: &mut WireReader<'_>| {
            let u = read_response::<U>(r);
            then(u);
        });
        enqueue_on_worker(
            self.trustee,
            apply_thunk::<T, U, C>,
            prop,
            // SAFETY: framing copies the bytes once; c is forgotten below.
            unsafe { env_bytes_of(&c) },
            completion,
            false,
            |_| {},
        );
        std::mem::forget(c);
    }

    /// Fire-and-forget delegation: no return value, no response bytes.
    pub fn apply_forget<C>(&self, c: C)
    where
        C: FnOnce(&mut T) + Send + 'static,
    {
        if self.is_local() {
            self.run_local(|t| c(t));
            return;
        }
        assert!(
            try_worker_id().is_some(),
            "apply_forget requires a runtime worker thread"
        );
        let prop = self.prop_u8();
        enqueue_on_worker(
            self.trustee,
            apply_noresp_thunk::<T, C>,
            prop,
            // SAFETY: framing copies the bytes once; c is forgotten below.
            unsafe { env_bytes_of(&c) },
            Completion::none(),
            false,
            |_| {},
        );
        std::mem::forget(c);
    }

    /// Synchronous delegation with serialized arguments (§4.3.3): `args`
    /// may be any `Wire` type (tuples for multiple values); variable-size
    /// payloads travel through the channel rather than the closure env.
    pub fn apply_with<V, U, C>(&self, c: C, args: V) -> U
    where
        V: Wire + Send + 'static,
        U: Wire + Send + 'static,
        C: FnOnce(&mut T, V) -> U + Send + 'static,
    {
        if self.is_local() {
            return self.run_local(move |t| c(t, args));
        }
        match try_worker_id() {
            Some(_) => {
                check_blocking_allowed("apply_with()");
                let prop = self.prop_u8();
                let trustee = self.trustee;
                delegate_blocking(move |completion| {
                    enqueue_on_worker(
                        trustee,
                        apply_with_thunk::<T, V, U, C>,
                        prop,
                        // SAFETY: framing copies the bytes once; c is forgotten below.
                        unsafe { env_bytes_of(&c) },
                        completion,
                        true,
                        // Serialized straight into the outbox arena — no
                        // temp `to_bytes` vector.
                        |w| args.write(w),
                    );
                    std::mem::forget(c);
                })
            }
            None => self.apply_injected(move |t| c(t, args)),
        }
    }

    /// Non-blocking variant of [`Trust::apply_with`].
    pub fn apply_with_then<V, U, C, F>(&self, c: C, args: V, then: F)
    where
        V: Wire + Send + 'static,
        U: Wire + Send + 'static,
        C: FnOnce(&mut T, V) -> U + Send + 'static,
        F: FnOnce(U) + 'static,
    {
        if self.is_local() {
            let u = self.run_local(move |t| c(t, args));
            then(u);
            return;
        }
        assert!(
            try_worker_id().is_some(),
            "apply_with_then requires a runtime worker thread"
        );
        let prop = self.prop_u8();
        let completion = Completion::new(move |r: &mut WireReader<'_>| {
            let u = read_response::<U>(r);
            then(u);
        });
        enqueue_on_worker(
            self.trustee,
            apply_with_thunk::<T, V, U, C>,
            prop,
            // SAFETY: framing copies the bytes once; c is forgotten below.
            unsafe { env_bytes_of(&c) },
            completion,
            false,
            // Serialized straight into the outbox arena — no temp
            // `to_bytes` vector.
            |w| args.write(w),
        );
        std::mem::forget(c);
    }

    /// Non-blocking delegation with **raw argument bytes and a raw
    /// response stream** — the allocation-free data path behind the KV
    /// backends (DESIGN.md, "Allocation discipline"). `args` is copied
    /// exactly once, caller → delegation slot; the closure receives it as
    /// a borrowed slice on the trustee (no decode, no key allocation) and
    /// writes its response directly into the channel's [`ResponseWriter`]
    /// (e.g. [`ResponseWriter::write_opt_bytes`] to send a borrowed value
    /// — the one-copy GET). `then` runs on this worker with the raw
    /// [`WireReader`] positioned at this request's response and must
    /// consume exactly what the closure wrote (pair it with
    /// [`crate::channel::read_opt_bytes`] / [`read_response`]).
    pub fn apply_raw_then<C, F>(&self, c: C, args: &[u8], then: F)
    where
        C: FnOnce(&mut T, &[u8], &mut ResponseWriter) + Send + 'static,
        F: FnOnce(&mut WireReader<'_>) + 'static,
    {
        self.apply_raw_parts_then(c, &[args], then);
    }

    /// [`Trust::apply_raw_then`] over several argument slices: the parts
    /// are serialized back to back into the delegation slot (still one
    /// copy total, no temp concatenation buffer) and the closure receives
    /// the concatenation. Callers that need the part boundaries capture
    /// the lengths in the closure (e.g. the KV PUT captures `key.len()`
    /// and splits). This is how multi-part payloads (key + value) travel
    /// without an owned scratch vector.
    pub fn apply_raw_parts_then<C, F>(&self, c: C, parts: &[&[u8]], then: F)
    where
        C: FnOnce(&mut T, &[u8], &mut ResponseWriter) + Send + 'static,
        F: FnOnce(&mut WireReader<'_>) + 'static,
    {
        if self.is_local() {
            // Local shortcut: run under the delegated flag, bouncing args
            // and response through recycled scratch buffers so the
            // closure and `then` see the same shapes as the remote path.
            let mut argbuf = take_local_raw_buf();
            for p in parts {
                argbuf.extend_from_slice(p);
            }
            let mut rw = ResponseWriter::reuse(take_local_raw_buf());
            {
                let _guard = DelegatedGuard::enter();
                // SAFETY: we are the trustee thread; no other closure runs
                // concurrently on this property.
                c(unsafe { &mut *(*self.prop.as_ptr()).value.get() }, &argbuf, &mut rw);
            }
            let bytes = rw.into_inner();
            {
                let mut reader = WireReader::new(&bytes);
                then(&mut reader);
                debug_assert!(
                    reader.is_empty(),
                    "apply_raw response not fully consumed"
                );
            }
            put_local_raw_buf(bytes);
            put_local_raw_buf(argbuf);
            return;
        }
        assert!(
            try_worker_id().is_some(),
            "apply_raw_parts_then requires a runtime worker thread"
        );
        let prop = self.prop_u8();
        let completion = Completion::new(then);
        enqueue_on_worker(
            self.trustee,
            apply_raw_thunk::<T, C>,
            prop,
            // SAFETY: framing copies the bytes once; c is forgotten below.
            unsafe { env_bytes_of(&c) },
            completion,
            false,
            |w| {
                for p in parts {
                    w.put_bytes(p);
                }
            },
        );
        std::mem::forget(c);
    }

    /// Apply a refcount *decrement* (or a trustee-local adjustment) from
    /// whatever context we're in. Decrements may travel fire-and-forget:
    /// the acked-increment protocol ([`Trust::clone`] /
    /// [`Trust::rc_inc_acked`]) guarantees every handle's `+1` was applied
    /// before the handle could reach another thread, so a `-1` can never
    /// drive the count to zero while a live handle exists, no matter how
    /// slot pairs interleave.
    fn rc_delta(&self, delta: i64) {
        match try_worker_id() {
            Some(id) if id == self.trustee => {
                // Direct: we are the trustee thread.
                // SAFETY: prop outlives every handle and only the trustee — us, here —
                // touches the header.
                let h = unsafe { &(*self.prop.as_ptr()).header };
                let rc = (h.refcount.get() as i64 + delta) as u64;
                h.refcount.set(rc);
                if rc == 0 {
                    let idx = h.reg_idx.get();
                    // SAFETY: count reached zero — no live handle remains.
                    unsafe { reclaim_on_current_worker(idx) };
                }
            }
            Some(_) => {
                // Fire-and-forget request; legal even in delegated context.
                // Not urgent: nothing waits on it, so it rides the next
                // batch (watermark or phase-end flush).
                let prop = self.prop_u8();
                enqueue_on_worker(
                    self.trustee,
                    rc_delta_thunk,
                    prop,
                    &delta.to_le_bytes(),
                    Completion::none(),
                    false,
                    |_| {},
                );
            }
            None => {
                if self.shared.is_stopped() {
                    // Runtime already gone: property was reclaimed at
                    // worker shutdown; nothing to do.
                    return;
                }
                let prop_addr = self.prop.as_ptr() as usize;
                self.shared.inject(
                    self.trustee,
                    Box::new(move || {
                        // SAFETY: the injected closure runs on the trustee thread; prop stays
                        // live until the refcount it guards reaches zero there.
                        let h = unsafe { &*(prop_addr as *const PropHeader) };
                        let rc = (h.refcount.get() as i64 + delta) as u64;
                        h.refcount.set(rc);
                        if rc == 0 {
                            let idx = h.reg_idx.get();
                            // SAFETY: running on the owning worker; idx is the live registry slot
                            // recorded when the property was allocated.
                            unsafe { reclaim_on_current_worker(idx) };
                        }
                    }),
                );
            }
        }
    }

    /// Refcount *increment* for [`Trust::clone`], sequenced so the new
    /// handle cannot outrun it: `clone` returns only after the trustee has
    /// applied the `+1` (or, on the trustee itself, after a direct bump).
    ///
    /// Why acked: any legal hand-off of the new handle to another thread
    /// establishes a happens-before edge, so once the `+1` is applied
    /// *before the hand-off*, every subsequent `-1` — on whatever slot
    /// pair — is served after it. The old fire-and-forget `+1` could be
    /// overtaken by a remote holder's `-1` on a different pair, hit zero,
    /// and reclaim the property under a live handle.
    fn rc_inc_acked(&self) {
        match try_worker_id() {
            Some(id) if id == self.trustee => {
                // Direct: trustee-thread clones are already ordered with
                // every served decrement.
                // SAFETY: prop outlives every handle and only the trustee — us, here —
                // touches the header.
                let h = unsafe { &(*self.prop.as_ptr()).header };
                h.refcount.set(h.refcount.get() + 1);
            }
            Some(_) => {
                let prop = self.prop_u8();
                if fiber::in_fiber() && !in_delegated_context() {
                    // Blocking ack: park the fiber until the trustee
                    // responded with the post-increment count.
                    let trustee = self.trustee;
                    let _count: u64 = delegate_blocking(move |completion| {
                        enqueue_on_worker(
                            trustee,
                            rc_inc_ack_thunk,
                            prop,
                            &[],
                            completion,
                            true,
                            |_| {},
                        );
                    });
                } else {
                    // Scheduler stack or delegated context: suspension is
                    // impossible, so publish urgently and spin until the
                    // trustee sets the side-channel flag. Progress on the
                    // edge comes from poll_detach, which consumes/publishes
                    // batches but dispatches NO completions — foreign user
                    // code (then-callbacks) must not run re-entrantly
                    // under an in-progress delegated closure. While
                    // spinning we also serve incoming refcount-increment
                    // batches addressed to *us*: two trustees cloning each
                    // other's properties inside delegated closures at the
                    // same instant otherwise wait on each other forever
                    // (DESIGN.md's former known caveat; regression test
                    // tests/clone_cycle.rs).
                    // Publish any queued records toward this trustee first
                    // (slot permitting): the peer's rc-only spin serve can
                    // admit the +1 only if it is not batched together with
                    // foreign records, so give it its own batch whenever
                    // the edge allows.
                    with_worker(|w| w.kick(self.trustee));
                    let acked = AtomicBool::new(false);
                    let flag_addr = &acked as *const AtomicBool as usize;
                    enqueue_on_worker(
                        self.trustee,
                        rc_inc_spin_ack_thunk,
                        prop,
                        &flag_addr.to_le_bytes(),
                        Completion::none(),
                        true,
                        |_| {},
                    );
                    let mut backoff = Backoff::new();
                    while !acked.load(AtomicOrdering::Acquire) {
                        let progressed = with_worker(|w| w.poll_detach(self.trustee));
                        let served =
                            crate::runtime::serve_rc_increment_batches(is_rc_increment_thunk);
                        if !progressed && served == 0 {
                            backoff.snooze();
                        }
                    }
                }
            }
            None => {
                if self.shared.is_stopped() {
                    // Handles outliving the runtime are inert.
                    return;
                }
                // Non-runtime thread: injected bump + condvar ack, so the
                // clone cannot cross threads before the count is applied.
                let prop_addr = self.prop.as_ptr() as usize;
                let done = Arc::new((Mutex::new(false), Condvar::new()));
                let done2 = done.clone();
                self.shared.inject(
                    self.trustee,
                    Box::new(move || {
                        // SAFETY: the injected closure runs on the trustee thread; prop stays
                        // live while a handle (ours) still exists.
                        let h = unsafe { &*(prop_addr as *const PropHeader) };
                        h.refcount.set(h.refcount.get() + 1);
                        let (m, cv) = &*done2;
                        *m.lock().unwrap() = true;
                        cv.notify_all();
                    }),
                );
                let (m, cv) = &*done;
                let mut g = m.lock().unwrap();
                while !*g {
                    g = cv.wait(g).unwrap();
                }
            }
        }
    }
}

impl<T: 'static> Trust<Latch<T>> {
    /// Apply `c` in a *trustee-side fiber* (§4.3, Fig. 4): unlike `apply`,
    /// the closure may block — including nested blocking delegation —
    /// because a suspension parks only the temporary fiber, not the
    /// trustee. Property access is serialized by the [`Latch`].
    ///
    /// Costs one extra delegation round-trip versus `apply`.
    pub fn launch<U, C>(&self, c: C) -> U
    where
        U: Send + 'static,
        C: FnOnce(&mut T) -> U + Send + 'static,
    {
        check_blocking_allowed("launch()");
        let client = try_worker_id().expect("launch requires a worker");
        let mut cell = LaunchCell::<U> {
            result: None,
            fiber: fiber::current_fiber().expect("fiber"),
        };
        let cell_addr = &mut cell as *mut LaunchCell<U> as usize;

        if self.is_local() {
            // Local: no delegation needed, but the closure still runs in a
            // *separate fiber* under the latch so it may block. Spawn via
            // the executor TLS — we are inside a fiber slice here, so a
            // worker borrow must not be held across the spawn.
            let prop = self.prop.as_ptr();
            fiber::with_executor(|e| {
                e.spawn(move || {
                    // SAFETY: our Trust handle keeps the property alive for
                    // the duration (we're suspended, not dropped).
                    let latch = unsafe { &*(*prop).value.get() };
                    let u = latch.with_lock(|t| c(t));
                    deliver_launch_result::<U>(client, cell_addr, u);
                });
            });
        } else {
            #[repr(C)]
            struct LaunchEnv<C> {
                c: C,
                client: usize,
                cell_addr: usize,
            }
            let env = LaunchEnv { c, client, cell_addr };
            let prop = self.prop_u8();
            // Urgent: we suspend on the launch result immediately below.
            enqueue_on_worker(
                self.trustee,
                launch_thunk::<T, U, C>,
                prop,
                // SAFETY: framing copies the bytes once; env is forgotten below.
                unsafe { env_bytes_of(&env) },
                Completion::none(),
                true,
                |_| {},
            );
            std::mem::forget(env);
        }
        fiber::suspend(|_| {});
        cell.result.take().expect("launch resumed without result")
    }
}

impl<T: 'static> Clone for Trust<T> {
    /// Cloning is *acked* (§3.1 refined): the `+1` is applied by the
    /// trustee before `clone` returns, so the new handle can never be
    /// outrun by a decrement on another slot pair. See
    /// [`Trust::rc_inc_acked`] and DESIGN.md's refcount ordering contract.
    fn clone(&self) -> Self {
        self.rc_inc_acked();
        Trust {
            prop: self.prop,
            trustee: self.trustee,
            shared: self.shared.clone(),
            _t: PhantomData,
        }
    }
}

impl<T: 'static> Drop for Trust<T> {
    fn drop(&mut self) {
        self.rc_delta(-1);
    }
}

impl<T: 'static> std::fmt::Debug for Trust<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Trust")
            .field("trustee", &self.trustee)
            .field("prop", &self.prop.as_ptr())
            .finish()
    }
}

// ---------------------------------------------------------------------
// Latch<T>
// ---------------------------------------------------------------------

/// Single-thread mutual exclusion with **no atomic instructions** (§4.3.1):
/// analogous to `Mutex<T>`, except it may only be used by the fibers of one
/// thread (it is deliberately `!Sync`). Waiting fibers queue FIFO.
pub struct Latch<T> {
    locked: Cell<bool>,
    waiters: RefCell<VecDeque<FiberId>>,
    value: UnsafeCell<T>,
}

// Latch is Send (can be entrusted/moved between threads while unused) but
// intentionally NOT Sync — the compiler derives !Sync from Cell/RefCell,
// which is exactly the paper's footnote 4.
// SAFETY: T: Send moves with the latch; all interior mutability is
// used by one thread at a time (handoff via entrust/launch).
unsafe impl<T: Send> Send for Latch<T> {}

impl<T> Latch<T> {
    pub fn new(value: T) -> Latch<T> {
        Latch {
            locked: Cell::new(false),
            waiters: RefCell::new(VecDeque::new()),
            value: UnsafeCell::new(value),
        }
    }

    pub fn into_inner(self) -> T {
        self.value.into_inner()
    }

    /// Is the latch currently held?
    pub fn is_locked(&self) -> bool {
        self.locked.get()
    }

    /// Acquire the latch, suspending the current fiber while contended;
    /// run `f` on the value; release and wake the next waiter.
    pub fn with_lock<R>(&self, f: impl FnOnce(&mut T) -> R) -> R {
        while self.locked.get() {
            fiber::suspend(|id| self.waiters.borrow_mut().push_back(id));
        }
        self.locked.set(true);
        // SAFETY: single-thread + locked: unique access.
        let r = f(unsafe { &mut *self.value.get() });
        self.locked.set(false);
        if let Some(next) = self.waiters.borrow_mut().pop_front() {
            fiber::with_executor(|e| e.resume(next));
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use std::rc::Rc;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn minimal_example_figure_1() {
        // Figure 1: entrust 17, increment, read back 18 (19 in Fig 2 after
        // two increments).
        let rt = Runtime::builder().workers(1).build();
        rt.block_on(0, || {
            let ct = local_trustee().entrust(17u64);
            ct.apply(|c| *c += 1);
            assert_eq!(ct.apply(|c| *c), 18);
        });
        rt.shutdown();
    }

    #[test]
    fn multi_thread_example_figure_2() {
        // Figure 2a: two workers increment the same entrusted counter.
        let rt = Runtime::builder().workers(2).build();
        let ct = rt.block_on(0, || local_trustee().entrust(17u64));
        let ct2 = ct.clone();
        let h = {
            let rt_ref = &rt;
            let done: u64 = rt_ref.block_on(1, move || {
                ct2.apply(|c| *c += 1);
                0u64
            });
            done
        };
        let _ = h;
        ct.apply(|c| *c += 1); // injected slow path from the main thread
        assert_eq!(ct.apply(|c| *c), 19);
        drop(ct);
        rt.shutdown();
    }

    #[test]
    fn cross_worker_delegation() {
        let rt = Runtime::builder().workers(3).build();
        // Property lives on worker 0; fibers on workers 1 and 2 hammer it.
        let ct = rt.block_on(0, || local_trustee().entrust(0u64));
        let mut threads = Vec::new();
        let mut fibers = Vec::new();
        for w in 1..3 {
            threads.push(std::thread::spawn({
                let ct = ct.clone();
                move || ct // keep a clone alive across threads
            }));
            let ctw = ct.clone();
            // spawn_on_handle is the completion signal: join() returns
            // only after the fiber ran its last blocking apply, so the
            // final read below is deterministic (no poll loop).
            fibers.push(rt.spawn_on_handle(w, move || {
                for _ in 0..100 {
                    ctw.apply(|c| *c += 1);
                }
            }));
        }
        for h in threads {
            let _ = h.join().unwrap();
        }
        for h in fibers {
            h.join();
        }
        let v = {
            let ct = ct.clone();
            rt.block_on(1, move || ct.apply(|c| *c))
        };
        assert_eq!(v, 200);
        drop(ct);
        rt.shutdown();
    }

    #[test]
    fn apply_then_async() {
        // Figure 3: asynchronous increment + then-callback.
        let rt = Runtime::builder().workers(2).build();
        let got = Arc::new(AtomicU64::new(0));
        let g = got.clone();
        let ct = rt.block_on(0, || local_trustee().entrust(17u64));
        let ct1 = ct.clone();
        rt.block_on(1, move || {
            let g2 = g.clone();
            ct1.apply_then(
                |c| {
                    *c += 1;
                    *c
                },
                move |v| g2.store(v, Ordering::Release),
            );
            // Wait for the callback by blocking on a second apply (in-order
            // per client-trustee pair: response 1 arrives first).
            let v = ct1.apply(|c| *c);
            assert_eq!(v, 18);
        });
        assert_eq!(got.load(Ordering::Acquire), 18);
        drop(ct);
        rt.shutdown();
    }

    #[test]
    fn apply_with_serialized_args() {
        let rt = Runtime::builder().workers(2).build();
        let table = rt.block_on(0, || {
            local_trustee().entrust(std::collections::HashMap::<String, String>::new())
        });
        let t1 = table.clone();
        let len = rt.block_on(1, move || {
            // Variable-size key/value travel serialized (§4.3.3).
            t1.apply_with(
                |table, (k, v): (String, String)| {
                    table.insert(k, v);
                    table.len() as u64
                },
                ("hello".to_string(), "world".to_string()),
            )
        });
        assert_eq!(len, 1);
        let t2 = table.clone();
        let v = rt.block_on(1, move || {
            t2.apply_with(|table, k: String| table.get(&k).cloned(), "hello".to_string())
        });
        assert_eq!(v.as_deref(), Some("world"));
        drop(table);
        rt.shutdown();
    }

    #[test]
    fn refcount_reclaims_property() {
        // Drop both trusts; the property must be reclaimed (registry empty).
        let rt = Runtime::builder().workers(2).build();
        let ct = rt.block_on(0, || local_trustee().entrust(vec![1u8, 2, 3]));
        let ct2 = ct.clone();
        let v = rt.block_on(1, move || ct2.apply(|v| v.len() as u64));
        assert_eq!(v, 3);
        drop(ct);
        // Give the refcount decs time to flow, then check via worker 0.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
        loop {
            let live = rt.block_on(0, || with_worker(|w| w.registry.live));
            if live == 0 {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "{live} props leaked");
        }
        rt.shutdown();
    }

    #[test]
    fn local_shortcut_applies_inline() {
        let rt = Runtime::builder().workers(1).build();
        let hits = rt.block_on(0, || {
            let ct = local_trustee().entrust(0u64);
            // All local: each apply runs inline via the shortcut (§5.2.1).
            for _ in 0..1000 {
                ct.apply(|c| *c += 1);
            }
            ct.apply(|c| *c)
        });
        assert_eq!(hits, 1000);
        rt.shutdown();
    }

    #[test]
    fn delegated_context_flag_visible() {
        let rt = Runtime::builder().workers(1).build();
        let (outside, inside) = rt.block_on(0, || {
            let ct = local_trustee().entrust(0u64);
            let outside = in_delegated_context();
            let inside = ct.apply(|_| in_delegated_context());
            (outside, inside)
        });
        assert!(!outside);
        assert!(inside, "closure must run in delegated context");
        rt.shutdown();
    }

    #[test]
    fn nested_blocking_apply_in_delegated_context_panics() {
        // The paper's runtime assertion (§3.4/§4.3): blocking delegation
        // inside a delegated closure must fail fast. We test the client-
        // side check through the local shortcut (same flag, same assert,
        // catchable because the panic fires on the caller's fiber).
        let rt = Runtime::builder().workers(1).build();
        let panicked = rt.block_on(0, || {
            let ct = local_trustee().entrust(0u64);
            let ct2 = ct.clone();
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                ct.apply(move |_| {
                    // Nested blocking apply to a *remote-looking* path:
                    // local shortcut still asserts via run_local's
                    // delegated flag when re-entering apply... the
                    // local shortcut IS legal (runs inline), so force the
                    // blocking check directly:
                    check_blocking_allowed("apply()");
                    let _ = ct2; // keep the clone captured
                    0u64
                })
            }))
            .is_err()
        });
        assert!(panicked, "blocking call in delegated context must assert");
        rt.shutdown();
    }

    #[test]
    fn apply_then_legal_in_delegated_context() {
        let rt = Runtime::builder().workers(2).build();
        let a = rt.block_on(0, || local_trustee().entrust(0u64));
        let b = rt.block_on(1, || local_trustee().entrust(0u64));
        let a2 = a.clone();
        let b2 = b.clone();
        // From worker 1's fiber, delegate to a (worker 0); inside that
        // delegated closure, issue a non-blocking apply_then to b (worker
        // 1) — legal per §4.2.
        let v = rt.block_on(1, move || {
            a2.apply(move |x| {
                *x += 1;
                b2.apply_then(|y| *y += 10, |_| {});
                *x
            })
        });
        assert_eq!(v, 1);
        // b eventually becomes 10.
        let b3 = b.clone();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(20);
        loop {
            let bv = {
                let b3 = b3.clone();
                rt.block_on(1, move || b3.apply(|y| *y))
            };
            if bv == 10 {
                break;
            }
            assert!(std::time::Instant::now() < deadline);
        }
        drop((a, b, b3));
        rt.shutdown();
    }

    #[test]
    fn launch_allows_nested_blocking_delegation() {
        // §4.3 / Fig. 4: a launched closure may perform blocking delegation.
        let rt = Runtime::builder().workers(2).build();
        let inner = rt.block_on(0, || local_trustee().entrust(5u64));
        let outer = rt.block_on(0, || local_trustee().entrust(Latch::new(100u64)));
        let inner2 = inner.clone();
        let outer2 = outer.clone();
        let v = rt.block_on(1, move || {
            outer2.launch(move |x| {
                // Blocking apply from within launched (trustee-side) fiber.
                let add = inner2.apply(|i| *i);
                *x += add;
                *x
            })
        });
        assert_eq!(v, 105);
        drop((inner, outer));
        rt.shutdown();
    }

    #[test]
    fn launch_serializes_via_latch() {
        let rt = Runtime::builder().workers(3).build();
        let prop = rt.block_on(0, || local_trustee().entrust(Latch::new(Vec::<u64>::new())));
        // Two concurrent launches from different workers; each appends its
        // tag twice with a yield between — the latch must keep the pairs
        // contiguous (no interleaving on the shared Vec). The join handles
        // are the completion signal (no poll loop / atomic counter).
        let handles: Vec<_> = [(1usize, 7u64), (2usize, 9u64)]
            .into_iter()
            .map(|(w, tag)| {
                let p = prop.clone();
                rt.spawn_on_handle(w, move || {
                    p.launch(move |v| {
                        v.push(tag);
                        fiber::yield_now(); // suspend inside the critical section
                        v.push(tag);
                    });
                })
            })
            .collect();
        for h in handles {
            h.join();
        }
        let p = prop.clone();
        let v = rt.block_on(1, move || p.apply(|l| l.with_lock(|v| v.clone())));
        assert_eq!(v.len(), 4);
        assert_eq!(v[0], v[1], "latch must prevent interleaving");
        assert_eq!(v[2], v[3]);
        drop(prop);
        rt.shutdown();
    }

    #[test]
    fn trust_is_send_and_sync() {
        fn assert_send_sync<X: Send + Sync>() {}
        assert_send_sync::<Trust<u64>>();
        assert_send_sync::<Trust<Vec<String>>>();
    }

    #[test]
    fn latch_is_not_sync() {
        // Compile-time property (paper footnote 4); checked via trait
        // presence using autoref specialization trick at runtime is
        // overkill — static_assertions style negative impl test:
        fn requires_sync<X: Sync>() -> bool {
            true
        }
        let _ = requires_sync::<u64>;
        // Latch<T> must not satisfy Sync: enforced by the compiler; this
        // test documents it (uncommenting the next line fails to build).
        // let _ = requires_sync::<Latch<u64>>;
    }

    #[test]
    fn entrust_from_remote_worker_fiber() {
        let rt = Runtime::builder().workers(2).build();
        let shared = rt.shared().clone();
        let tr = TrusteeRef::new(shared, 0);
        let v = rt.block_on(1, move || {
            // entrust from worker 1 onto trustee 0 — delegated entrust.
            let ct = tr.entrust(vec![10u64, 20, 30]);
            assert_eq!(ct.trustee_id(), 0);
            ct.apply(|v| v.iter().sum::<u64>())
        });
        assert_eq!(v, 60);
        rt.shutdown();
    }

    #[test]
    fn reentrant_runtime_use_inside_local_apply() {
        // Regression (re-entrant with_worker aliasing): run_local used to
        // hold &mut Worker across the user closure, so any closure that
        // re-entered the runtime — cloning/dropping a Trust of this very
        // worker, entrusting, nested local applies — created a second
        // &mut Worker. The restructure runs the closure with no worker
        // borrow held; this test exercises every re-entrant path.
        let rt = Runtime::builder().workers(1).build();
        let v = rt.block_on(0, || {
            let ct = local_trustee().entrust(10u64);
            let other = local_trustee().entrust(5u64);
            let ct2 = ct.clone();
            let r = ct.apply(move |c| {
                // clone + drop of a Trust trusteed by this worker, inside
                // the delegated closure (direct refcount path re-enters).
                let extra = ct2.clone();
                drop(extra);
                // entrust a fresh property from delegated context.
                let tmp = local_trustee().entrust(1u64);
                // nested local apply through the shortcut.
                let add = tmp.apply(|t| *t) + other.apply(|o| *o);
                drop(tmp); // refcount hits zero -> reclaim re-enters
                drop(other);
                *c += add;
                *c
            });
            assert_eq!(r, 16);
            let live = with_worker(|w| w.registry.live);
            assert_eq!(live, 1, "temporaries reclaimed, ct remains");
            ct.apply(|c| *c)
        });
        assert_eq!(v, 16);
        rt.shutdown();
    }

    #[test]
    fn clone_in_delegated_context_spins_for_ack() {
        // A delegated closure on trustee 0 clones a Trust whose trustee is
        // worker 1: suspension is illegal there, so the clone must
        // spin-poll the (0,1) edge until the +1 ack round-trips.
        let rt = Runtime::builder().workers(2).build();
        let a = rt.block_on(0, || local_trustee().entrust(0u64));
        let b = rt.block_on(1, || local_trustee().entrust(100u64));
        let a2 = a.clone();
        let b2 = b.clone();
        let got = rt.block_on(1, move || {
            a2.apply(move |x| {
                let b3 = b2.clone(); // acked via spin-poll (delegated ctx)
                drop(b2); // fire-and-forget -1 rides a later batch
                drop(b3);
                *x += 1;
                *x
            })
        });
        assert_eq!(got, 1);
        // b must still be alive and reachable (the acked +1 kept the count
        // from ever touching zero).
        let b4 = b.clone();
        let v = rt.block_on(0, move || b4.apply(|y| *y));
        assert_eq!(v, 100);
        drop((a, b));
        rt.shutdown();
    }

    /// Raw-apply test property: a tiny byte-keyed table.
    type RawTbl = crate::cmap::OaTable<Vec<u8>, Vec<u8>>;

    #[test]
    fn apply_raw_then_remote_borrows_args_and_response() {
        use crate::channel::read_opt_bytes;
        // Raw path end to end: args arrive on the trustee as a borrowed
        // slice, the response is written with write_opt_bytes, and the
        // completion reads it borrowed (one-copy GET shape).
        let rt = Runtime::builder().workers(2).build();
        let table = rt.block_on(0, || local_trustee().entrust(RawTbl::with_capacity(16)));
        let t1 = table.clone();
        rt.block_on(1, move || {
            t1.apply_raw_then(
                |t: &mut RawTbl, k: &[u8], out: &mut ResponseWriter| {
                    t.insert(k.to_vec(), b"world".to_vec());
                    out.write_value(&0u8);
                },
                b"hello",
                |r| {
                    read_response::<u8>(r);
                },
            );
            let hit = Rc::new(std::cell::RefCell::new(Vec::new()));
            let h = hit.clone();
            t1.apply_raw_then(
                |t: &mut RawTbl, k: &[u8], out: &mut ResponseWriter| {
                    out.write_opt_bytes(t.get(k).map(|v| &v[..]))
                },
                b"hello",
                move |r| {
                    if let Some(v) = read_opt_bytes(r) {
                        h.borrow_mut().extend_from_slice(v);
                    }
                },
            );
            let missed = Rc::new(Cell::new(false));
            let m = missed.clone();
            t1.apply_raw_then(
                |t: &mut RawTbl, k: &[u8], out: &mut ResponseWriter| {
                    out.write_opt_bytes(t.get(k).map(|v| &v[..]))
                },
                b"nope",
                move |r| m.set(read_opt_bytes(r).is_none()),
            );
            // Multi-part args: key and value as adjacent slices, split at
            // the captured key length (the PUT shape).
            let klen = 3usize;
            t1.apply_raw_parts_then(
                move |t: &mut RawTbl, args: &[u8], out: &mut ResponseWriter| {
                    let (k, v) = args.split_at(klen);
                    t.insert(k.to_vec(), v.to_vec());
                    out.write_value(&0u8);
                },
                &[&b"abc"[..], &b"defgh"[..]],
                |r| {
                    read_response::<u8>(r);
                },
            );
            // A blocking apply flushes and sequences behind the raw ops.
            let len = t1.apply(|t| t.len() as u64);
            assert_eq!(len, 2);
            assert_eq!(&*hit.borrow(), b"world");
            assert!(missed.get());
            let v = t1.apply(|t| t.get(&b"abc"[..]).cloned());
            assert_eq!(v.as_deref(), Some(&b"defgh"[..]));
        });
        drop(table);
        rt.shutdown();
    }

    #[test]
    fn apply_raw_then_local_shortcut() {
        use crate::channel::read_opt_bytes;
        // On the trustee's own worker the raw path runs inline through the
        // recycled scratch writer — same wire format, no delegation.
        let rt = Runtime::builder().workers(1).build();
        rt.block_on(0, || {
            let t = local_trustee().entrust(RawTbl::with_capacity(16));
            t.apply_raw_then(
                |t: &mut RawTbl, k: &[u8], out: &mut ResponseWriter| {
                    t.insert(k.to_vec(), b"local".to_vec());
                    out.write_value(&0u8);
                },
                b"k",
                |r| {
                    read_response::<u8>(r);
                },
            );
            let got = Rc::new(std::cell::RefCell::new(Vec::new()));
            let g = got.clone();
            t.apply_raw_then(
                |t: &mut RawTbl, k: &[u8], out: &mut ResponseWriter| {
                    out.write_opt_bytes(t.get(k).map(|v| &v[..]))
                },
                b"k",
                move |r| {
                    if let Some(v) = read_opt_bytes(r) {
                        g.borrow_mut().extend_from_slice(v);
                    }
                },
            );
            assert_eq!(&*got.borrow(), b"local");
            // Delegated-context flag must cover the local raw closure.
            let flagged = Rc::new(Cell::new(false));
            let f = flagged.clone();
            t.apply_raw_then(
                move |_t: &mut RawTbl, _k: &[u8], out: &mut ResponseWriter| {
                    out.write_value(&in_delegated_context());
                },
                &[],
                move |r| f.set(read_response::<bool>(r)),
            );
            assert!(flagged.get());
        });
        rt.shutdown();
    }

    #[test]
    fn string_property_roundtrip() {
        let rt = Runtime::builder().workers(2).build();
        let ct = rt.block_on(0, || local_trustee().entrust(String::from("abc")));
        let ct2 = ct.clone();
        let s = rt.block_on(1, move || {
            ct2.apply(|s| {
                s.push_str("def");
                s.clone()
            })
        });
        assert_eq!(s, "abcdef");
        drop(ct);
        rt.shutdown();
    }
}
