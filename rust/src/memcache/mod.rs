//! Mini-memcached (paper §7): a faithful miniature of the memcached port —
//! text protocol with real `exptime` support, served from the **unified
//! item store** ([`crate::kvstore::store`]) over all four backends
//! (`trust`/`mutex`/`rwlock`/`swift`), plus a memtier-benchmark-style
//! load generator.
//!
//! The old parallel `memcache::engine` (boxed-callback `McdEngine` with
//! its own `StockEngine`/`TrustEngine` tables) is gone: [`McdProtocol`]
//! dispatches onto [`crate::kvstore::AsyncKv`]'s item-aware ops, so the
//! memcached front end inherits the allocation-free delegation hot path,
//! TTL expiry, and per-shard LRU eviction the KV/RESP front ends share.
//!
//! Substitution note (DESIGN.md #3): we cannot link the C memcached here;
//! this Rust miniature reproduces the *structural* change of the paper's
//! port — critical sections become delegated closures on sharded state,
//! socket workers use asynchronous delegation and reorder responses.
//! The lock backends keep the lock-based synchronization *class* (every
//! GET takes a shard's exclusive lock for its LRU bump and lazy expiry),
//! but per shard rather than behind stock memcached's global LRU/slab
//! mutexes — a stronger baseline, so measured speedups are conservative
//! (DESIGN.md, "Unified item store").

pub mod memtier;
pub mod server;

pub use memtier::{run_memtier, MemtierConfig, MemtierStats};
pub use server::{McdParseError, McdProtocol, McdServer, McdServerConfig};
