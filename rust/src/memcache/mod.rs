//! Mini-memcached (paper §7): a faithful miniature of the memcached port —
//! text protocol, the stock lock-based engine vs. delegated Trust<T>
//! shards, and a memtier-benchmark-style load generator.
//!
//! Substitution note (DESIGN.md #3): we cannot link the C memcached here;
//! this Rust miniature reproduces the *structural* change of the paper's
//! port — critical sections become delegated closures on sharded state,
//! socket workers use asynchronous delegation and reorder responses — and
//! the synchronization profile of stock memcached (per-item locks, global
//! LRU + slab locks).

pub mod engine;
pub mod memtier;
pub mod server;

pub use engine::{Item, McdEngine, McdShard, StockEngine, TrustEngine};
pub use memtier::{run_memtier, MemtierConfig, MemtierStats};
pub use server::{EngineKind, McdParseError, McdProtocol, McdServer, McdServerConfig};
