//! memtier-benchmark stand-in (§7.1): multi-threaded text-protocol load
//! generator with per-thread connections, configurable pipelining, key
//! distribution, and write percentage — reporting aggregate throughput the
//! way `memtier_benchmark` does.

use crate::util::{KeyDist, Rng};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Instant;

/// Key encoding shared by prefill and load ("memtier-<n>" style).
pub fn key_bytes(k: u64) -> Vec<u8> {
    format!("memtier-{k}").into_bytes()
}

#[derive(Clone, Debug)]
pub struct MemtierConfig {
    pub addr: std::net::SocketAddr,
    pub threads: usize,
    /// Pipelining depth (paper: 48).
    pub pipeline: usize,
    pub ops_per_thread: u64,
    pub keys: u64,
    pub dist: String,
    pub write_pct: u32,
    pub val_len: usize,
    pub seed: u64,
}

pub struct MemtierStats {
    pub ops: u64,
    pub elapsed: std::time::Duration,
    pub hits: u64,
    pub misses: u64,
}

impl MemtierStats {
    pub fn throughput(&self) -> f64 {
        self.ops as f64 / self.elapsed.as_secs_f64()
    }
}

pub fn run_memtier(cfg: &MemtierConfig) -> MemtierStats {
    let start = Instant::now();
    let handles: Vec<_> = (0..cfg.threads)
        .map(|t| {
            let cfg = cfg.clone();
            std::thread::spawn(move || run_connection(&cfg, t as u64))
        })
        .collect();
    let mut ops = 0;
    let mut hits = 0;
    let mut misses = 0;
    for h in handles {
        let (o, hi, mi) = h.join().expect("memtier thread");
        ops += o;
        hits += hi;
        misses += mi;
    }
    MemtierStats { ops, elapsed: start.elapsed(), hits, misses }
}

/// What we expect back for each sent command (text protocol is in-order).
enum Expect {
    Stored,
    Value,
}

fn run_connection(cfg: &MemtierConfig, tid: u64) -> (u64, u64, u64) {
    let mut rng = Rng::new(cfg.seed ^ (tid.wrapping_mul(0xA24B_AED4)));
    let dist = KeyDist::from_spec(&cfg.dist, cfg.keys);
    let mut stream = TcpStream::connect(cfg.addr).expect("connect memtier");
    stream.set_nodelay(true).ok();
    stream.set_nonblocking(true).unwrap();

    let val: Vec<u8> = vec![b'm'; cfg.val_len];
    let mut expect: std::collections::VecDeque<Expect> =
        std::collections::VecDeque::with_capacity(cfg.pipeline);
    let mut out: Vec<u8> = Vec::with_capacity(64 * 1024);
    let mut wcur = 0usize;
    let mut inbuf: Vec<u8> = Vec::with_capacity(64 * 1024);
    let mut parsed = 0usize; // consumed prefix of inbuf
    let (mut sent, mut done, mut hits, mut misses) = (0u64, 0u64, 0u64, 0u64);

    while done < cfg.ops_per_thread {
        while sent < cfg.ops_per_thread && expect.len() < cfg.pipeline {
            let key = key_bytes(dist.sample(&mut rng));
            if rng.pct(cfg.write_pct) {
                out.extend_from_slice(
                    format!("set {} 0 0 {}\r\n", String::from_utf8_lossy(&key), val.len())
                        .as_bytes(),
                );
                out.extend_from_slice(&val);
                out.extend_from_slice(b"\r\n");
                expect.push_back(Expect::Stored);
            } else {
                out.extend_from_slice(
                    format!("get {}\r\n", String::from_utf8_lossy(&key)).as_bytes(),
                );
                expect.push_back(Expect::Value);
            }
            sent += 1;
        }
        // Flush.
        loop {
            if wcur >= out.len() {
                out.clear();
                wcur = 0;
                break;
            }
            match stream.write(&out[wcur..]) {
                Ok(0) => panic!("server closed"),
                Ok(n) => wcur += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => panic!("write: {e}"),
            }
        }
        // Read.
        let mut chunk = [0u8; 32 * 1024];
        match stream.read(&mut chunk) {
            Ok(0) => panic!("server closed"),
            Ok(n) => inbuf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(e) => panic!("read: {e}"),
        }
        // Parse responses in order.
        loop {
            let Some(front) = expect.front() else { break };
            match front {
                Expect::Stored => {
                    let Some(end) = find_crlf(&inbuf[parsed..]) else { break };
                    debug_assert_eq!(&inbuf[parsed..parsed + end], b"STORED");
                    parsed += end + 2;
                    expect.pop_front();
                    done += 1;
                    hits += 1;
                }
                Expect::Value => {
                    // Either "END\r\n" (miss) or VALUE header + data + END.
                    match try_parse_get(&inbuf[parsed..]) {
                        Some((used, hit)) => {
                            parsed += used;
                            expect.pop_front();
                            done += 1;
                            if hit {
                                hits += 1;
                            } else {
                                misses += 1;
                            }
                        }
                        None => break,
                    }
                }
            }
        }
        if parsed > 0 {
            inbuf.drain(..parsed);
            parsed = 0;
        }
    }
    (done, hits, misses)
}

fn find_crlf(buf: &[u8]) -> Option<usize> {
    buf.windows(2).position(|w| w == b"\r\n")
}

/// Parse a full GET response; returns (bytes_used, was_hit).
fn try_parse_get(buf: &[u8]) -> Option<(usize, bool)> {
    let line_end = find_crlf(buf)?;
    let line = &buf[..line_end];
    if line == b"END" {
        return Some((line_end + 2, false));
    }
    assert!(line.starts_with(b"VALUE "), "unexpected reply {:?}", String::from_utf8_lossy(line));
    // VALUE <key> <flags> <bytes>
    let bytes: usize = std::str::from_utf8(line.rsplit(|&b| b == b' ').next()?)
        .ok()?
        .parse()
        .ok()?;
    let data_start = line_end + 2;
    let end_start = data_start + bytes + 2;
    if buf.len() < end_start + 5 {
        return None;
    }
    debug_assert_eq!(&buf[end_start..end_start + 5], b"END\r\n");
    Some((end_start + 5, true))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memcache::server::{EngineKind, McdServer, McdServerConfig};

    fn smoke(engine: EngineKind) -> MemtierStats {
        let server = McdServer::start(McdServerConfig {
            workers: 3,
            engine,
            ..Default::default()
        });
        server.prefill(200, 16);
        let stats = run_memtier(&MemtierConfig {
            addr: server.addr(),
            threads: 2,
            pipeline: 12,
            ops_per_thread: 400,
            keys: 200,
            dist: "uniform".into(),
            write_pct: 10,
            val_len: 16,
            seed: 99,
        });
        server.stop();
        stats
    }

    #[test]
    fn memtier_against_trust_engine() {
        let stats = smoke(EngineKind::Trust { shards: 4 });
        assert_eq!(stats.ops, 800);
        assert_eq!(stats.misses, 0, "prefilled keys must hit");
    }

    #[test]
    fn memtier_against_stock_engine() {
        let stats = smoke(EngineKind::Stock);
        assert_eq!(stats.ops, 800);
        assert_eq!(stats.misses, 0);
    }

    #[test]
    fn get_parser_handles_partials() {
        let full = b"VALUE k 0 5\r\nhello\r\nEND\r\n";
        for cut in 0..full.len() {
            assert!(try_parse_get(&full[..cut]).is_none(), "cut={cut}");
        }
        assert_eq!(try_parse_get(full), Some((full.len(), true)));
        assert_eq!(try_parse_get(b"END\r\nmore"), Some((5, false)));
    }
}
