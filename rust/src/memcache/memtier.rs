//! memtier-benchmark stand-in (§7.1): multi-threaded text-protocol load
//! generator with per-thread connections, configurable pipelining, key
//! distribution, and write percentage — reporting aggregate throughput the
//! way `memtier_benchmark` does.
//!
//! The connection loop is the shared [`crate::loadgen`] skeleton; this
//! module contributes only the memcached-text [`LoadDriver`] (in-order
//! replies matched against an expectation queue). I/O failures and
//! protocol desyncs are surfaced in [`MemtierStats::errors`] (a server
//! dropping a connection mid-run fails the run descriptively) instead of
//! panicking the client thread.

use crate::loadgen::{run_pipelined_loader_opts, LoadDriver, Reply};
use crate::util::{KeyDist, Rng};
use std::collections::VecDeque;
use std::time::Instant;

/// Key encoding shared by prefill and load ("memtier-<n>" style).
pub fn key_bytes(k: u64) -> Vec<u8> {
    format!("memtier-{k}").into_bytes()
}

/// TTL the loader attaches to its TTL-carrying sets (seconds). Small on
/// purpose: a run longer than a second starts taking real misses, which
/// is the point of the expiry workload.
pub const LOAD_TTL_SECS: u64 = 1;

#[derive(Clone, Debug)]
pub struct MemtierConfig {
    pub addr: std::net::SocketAddr,
    pub threads: usize,
    /// Pipelining depth (paper: 48).
    pub pipeline: usize,
    pub ops_per_thread: u64,
    pub keys: u64,
    pub dist: String,
    pub write_pct: u32,
    /// Percentage of sets that carry `exptime` [`LOAD_TTL_SECS`] (the
    /// rest store without expiry) — the TTL-mix knob that drives the
    /// store's expiry/sweep machinery end to end. GETs of expired keys
    /// then count as misses.
    pub ttl_pct: u32,
    pub val_len: usize,
    pub seed: u64,
    /// Re-issue requests the server shed with `SERVER_ERROR busy`
    /// (bounded; off = count them as valueless completions).
    pub retry_shed: bool,
}

/// Aggregated results. `errors` holds one descriptive entry per client
/// thread that failed; completed operations still count toward `ops`.
pub struct MemtierStats {
    pub ops: u64,
    pub elapsed: std::time::Duration,
    pub hits: u64,
    pub misses: u64,
    /// Requests the server answered with `SERVER_ERROR busy`.
    pub shed: u64,
    pub errors: Vec<String>,
}

impl MemtierStats {
    pub fn throughput(&self) -> f64 {
        self.ops as f64 / self.elapsed.as_secs_f64()
    }

    /// True when every client thread ran to completion.
    pub fn ok(&self) -> bool {
        self.errors.is_empty()
    }
}

pub fn run_memtier(cfg: &MemtierConfig) -> MemtierStats {
    let start = Instant::now();
    let handles: Vec<_> = (0..cfg.threads)
        .map(|t| {
            let cfg = cfg.clone();
            std::thread::spawn(move || run_connection(&cfg, t as u64))
        })
        .collect();
    let mut ops = 0;
    let mut hits = 0;
    let mut misses = 0;
    let mut shed = 0;
    let mut errors = Vec::new();
    for (t, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok((o, hi, mi, sh, err)) => {
                ops += o;
                hits += hi;
                misses += mi;
                shed += sh;
                if let Some(e) = err {
                    errors.push(format!("client thread {t}: {e}"));
                }
            }
            Err(_) => errors.push(format!("client thread {t} panicked")),
        }
    }
    MemtierStats { ops, elapsed: start.elapsed(), hits, misses, shed, errors }
}

/// The overload-shed line [`crate::memcache::server::McdProtocol`]
/// renders (without its CRLF).
const SHED_LINE: &[u8] = b"SERVER_ERROR busy";

/// What we expect back for each sent command (text protocol is in-order).
enum Expect {
    Stored,
    Value,
}

/// The memcached text protocol plugged into the shared loader skeleton:
/// replies arrive strictly in request order, matched against `expect`.
struct McdDriver {
    rng: Rng,
    dist: KeyDist,
    write_pct: u32,
    ttl_pct: u32,
    val: Vec<u8>,
    expect: VecDeque<Expect>,
}

impl LoadDriver for McdDriver {
    fn encode_next(&mut self, out: &mut Vec<u8>) {
        let key = key_bytes(self.dist.sample(&mut self.rng));
        if self.rng.pct(self.write_pct) {
            let exptime = if self.ttl_pct > 0 && self.rng.pct(self.ttl_pct) {
                LOAD_TTL_SECS
            } else {
                0
            };
            out.extend_from_slice(
                format!(
                    "set {} 0 {exptime} {}\r\n",
                    String::from_utf8_lossy(&key),
                    self.val.len()
                )
                .as_bytes(),
            );
            out.extend_from_slice(&self.val);
            out.extend_from_slice(b"\r\n");
            self.expect.push_back(Expect::Stored);
        } else {
            out.extend_from_slice(
                format!("get {}\r\n", String::from_utf8_lossy(&key)).as_bytes(),
            );
            self.expect.push_back(Expect::Value);
        }
    }

    fn parse_reply(&mut self, buf: &[u8]) -> Result<Option<Reply>, String> {
        let Some(front) = self.expect.front() else {
            return Ok(None);
        };
        match front {
            Expect::Stored => {
                let Some(end) = find_crlf(buf) else { return Ok(None) };
                let line = &buf[..end];
                if line == SHED_LINE {
                    self.expect.pop_front();
                    return Ok(Some(Reply::shed(end + 2)));
                }
                if line != b"STORED" {
                    return Err(format!(
                        "expected STORED, got {:?}",
                        String::from_utf8_lossy(line)
                    ));
                }
                self.expect.pop_front();
                Ok(Some(Reply::ok(end + 2, true)))
            }
            Expect::Value => {
                // A shed GET answers the busy line instead of VALUE/END.
                if let Some(end) = find_crlf(buf) {
                    if &buf[..end] == SHED_LINE {
                        self.expect.pop_front();
                        return Ok(Some(Reply::shed(end + 2)));
                    }
                }
                // Either "END\r\n" (miss) or VALUE header + data + END.
                match try_parse_get(buf)? {
                    Some((used, hit)) => {
                        self.expect.pop_front();
                        Ok(Some(Reply::ok(used, hit)))
                    }
                    None => Ok(None),
                }
            }
        }
    }
}

fn run_connection(cfg: &MemtierConfig, tid: u64) -> (u64, u64, u64, u64, Option<String>) {
    let mut driver = McdDriver {
        rng: Rng::new(cfg.seed ^ (tid.wrapping_mul(0xA24B_AED4))),
        dist: KeyDist::from_spec(&cfg.dist, cfg.keys),
        write_pct: cfg.write_pct,
        ttl_pct: cfg.ttl_pct,
        val: vec![b'm'; cfg.val_len],
        expect: VecDeque::with_capacity(cfg.pipeline),
    };
    let r = run_pipelined_loader_opts(
        cfg.addr,
        cfg.pipeline,
        cfg.ops_per_thread,
        &mut driver,
        cfg.retry_shed,
    );
    (r.done, r.hits, r.misses, r.shed, r.error)
}

fn find_crlf(buf: &[u8]) -> Option<usize> {
    buf.windows(2).position(|w| w == b"\r\n")
}

/// Parse a full GET response: `Ok(Some((bytes_used, was_hit)))`,
/// `Ok(None)` to wait for more bytes, `Err` when the server answered
/// something that is not a GET response (protocol desync).
fn try_parse_get(buf: &[u8]) -> Result<Option<(usize, bool)>, String> {
    let Some(line_end) = find_crlf(buf) else { return Ok(None) };
    let line = &buf[..line_end];
    if line == b"END" {
        return Ok(Some((line_end + 2, false)));
    }
    if !line.starts_with(b"VALUE ") {
        return Err(format!(
            "unexpected reply {:?}",
            String::from_utf8_lossy(line)
        ));
    }
    // VALUE <key> <flags> <bytes>
    let bytes: usize = line
        .rsplit(|&b| b == b' ')
        .next()
        .and_then(|f| std::str::from_utf8(f).ok())
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| format!("bad VALUE header {:?}", String::from_utf8_lossy(line)))?;
    // A size past the server's own data cap means the stream is desynced:
    // fail descriptively instead of waiting forever for bytes that will
    // never come.
    if bytes > crate::memcache::server::MAX_DATA {
        return Err(format!("VALUE size {bytes} exceeds MAX_DATA (desync?)"));
    }
    let data_start = line_end + 2;
    let end_start = data_start + bytes + 2;
    if buf.len() < end_start + 5 {
        return Ok(None);
    }
    if &buf[end_start..end_start + 5] != b"END\r\n" {
        return Err("data block not END-terminated".into());
    }
    Ok(Some((end_start + 5, true)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::backend::BackendKind;
    use crate::memcache::server::{McdServer, McdServerConfig};

    fn smoke(backend: BackendKind, ttl_pct: u32) -> MemtierStats {
        let server = McdServer::start(McdServerConfig {
            workers: 3,
            backend,
            ..Default::default()
        });
        server.prefill(200, 16);
        let stats = run_memtier(&MemtierConfig {
            addr: server.addr(),
            threads: 2,
            pipeline: 12,
            ops_per_thread: 400,
            keys: 200,
            dist: "uniform".into(),
            write_pct: 10,
            ttl_pct,
            val_len: 16,
            seed: 99,
            retry_shed: false,
        });
        server.stop();
        stats
    }

    #[test]
    fn memtier_against_trust_backend() {
        let stats = smoke(BackendKind::Trust { shards: 4 }, 0);
        assert!(stats.ok(), "client errors: {:?}", stats.errors);
        assert_eq!(stats.ops, 800);
        assert_eq!(stats.misses, 0, "prefilled keys must hit");
    }

    #[test]
    fn memtier_against_lock_backend() {
        let stats = smoke(BackendKind::Mutex, 0);
        assert!(stats.ok(), "client errors: {:?}", stats.errors);
        assert_eq!(stats.ops, 800);
        assert_eq!(stats.misses, 0);
    }

    #[test]
    fn memtier_ttl_mix_speaks_exptime() {
        // Every set carries exptime LOAD_TTL_SECS: the run must still
        // complete (STOREDs all parse); misses are legal once keys
        // start expiring under the run.
        let stats = smoke(BackendKind::Trust { shards: 4 }, 100);
        assert!(stats.ok(), "client errors: {:?}", stats.errors);
        assert_eq!(stats.ops, 800);
    }

    #[test]
    fn get_parser_handles_partials() {
        let full = b"VALUE k 0 5\r\nhello\r\nEND\r\n";
        for cut in 0..full.len() {
            assert!(try_parse_get(&full[..cut]).unwrap().is_none(), "cut={cut}");
        }
        assert_eq!(try_parse_get(full).unwrap(), Some((full.len(), true)));
        assert_eq!(try_parse_get(b"END\r\nmore").unwrap(), Some((5, false)));
        assert!(try_parse_get(b"CLIENT_ERROR nope\r\n").is_err());
        // Desync guard: absurd declared sizes error instead of hanging.
        assert!(try_parse_get(b"VALUE k 0 99999999\r\n").is_err());
    }

    #[test]
    fn memtier_connect_failure_is_an_error_not_a_panic() {
        let stats = run_memtier(&MemtierConfig {
            addr: "127.0.0.1:1".parse().unwrap(),
            threads: 1,
            pipeline: 4,
            ops_per_thread: 10,
            keys: 10,
            dist: "uniform".into(),
            write_pct: 0,
            ttl_pct: 0,
            val_len: 8,
            seed: 5,
            retry_shed: false,
        });
        assert_eq!(stats.ops, 0);
        assert_eq!(stats.errors.len(), 1);
        assert!(stats.errors[0].contains("connect"), "unhelpful: {:?}", stats.errors);
    }
}
