//! Mini-memcached TCP server speaking the memcached **text protocol**
//! (get/set subset), structured like the paper's port (§7):
//!
//! - Socket worker fibers follow the original state-machine order:
//!   receive → parse → process → enqueue result → transmit.
//! - With the [`TrustEngine`](super::engine::TrustEngine), each request is
//!   dispatched with asynchronous delegation (`apply_then`) and the worker
//!   "moves on to the next request without waiting".
//! - The memcached protocol has no request ids, so responses to one
//!   connection must be transmitted **in order** even though shard
//!   responses may complete out of order — exactly the reordering buffer
//!   the paper describes ("the memcached socket worker thread must order
//!   the responses before they are transmitted").

use super::engine::McdEngine;
use crate::kvstore::netfiber::{read_available, write_pending, ReadOutcome};
use crate::fiber;
use crate::runtime::Runtime;
use std::cell::RefCell;
use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// One parsed text-protocol command.
#[derive(Debug, PartialEq, Eq)]
pub enum Command {
    Get { key: Vec<u8> },
    Set { key: Vec<u8>, flags: u32, data: Vec<u8> },
}

/// Incremental text-protocol parser. Returns (command, bytes_consumed).
pub fn parse_command(buf: &[u8]) -> Option<(Command, usize)> {
    let line_end = find_crlf(buf)?;
    let line = &buf[..line_end];
    let mut parts = line.split(|&b| b == b' ').filter(|p| !p.is_empty());
    match parts.next()? {
        b"get" => {
            let key = parts.next()?.to_vec();
            Some((Command::Get { key }, line_end + 2))
        }
        b"set" => {
            let key = parts.next()?.to_vec();
            let flags: u32 = parse_num(parts.next()?)?;
            let _exptime: u64 = parse_num(parts.next()?)?;
            let bytes: usize = parse_num(parts.next()?)?;
            let data_start = line_end + 2;
            if buf.len() < data_start + bytes + 2 {
                return None; // waiting for the data block
            }
            let data = buf[data_start..data_start + bytes].to_vec();
            Some((Command::Set { key, flags, data }, data_start + bytes + 2))
        }
        other => panic!(
            "mini-memcached: unsupported command {:?}",
            String::from_utf8_lossy(other)
        ),
    }
}

fn find_crlf(buf: &[u8]) -> Option<usize> {
    buf.windows(2).position(|w| w == b"\r\n")
}

fn parse_num<N: std::str::FromStr>(b: &[u8]) -> Option<N> {
    std::str::from_utf8(b).ok()?.parse().ok()
}

/// Engine selector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Stock,
    Trust { shards: usize },
}

impl EngineKind {
    pub fn label(&self) -> String {
        match self {
            EngineKind::Stock => "S (stock)".into(),
            EngineKind::Trust { shards } => format!("Trust{shards}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct McdServerConfig {
    pub workers: usize,
    pub dedicated: usize,
    pub engine: EngineKind,
    pub addr: String,
}

impl Default for McdServerConfig {
    fn default() -> Self {
        McdServerConfig {
            workers: 4,
            dedicated: 0,
            engine: EngineKind::Trust { shards: 4 },
            addr: "127.0.0.1:0".into(),
        }
    }
}

/// A running mini-memcached instance.
pub struct McdServer {
    rt: Option<Runtime>,
    engine: Arc<dyn McdEngine>,
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    pub ops_served: Arc<AtomicU64>,
}

impl McdServer {
    pub fn start(cfg: McdServerConfig) -> McdServer {
        let rt = Runtime::builder()
            .workers(cfg.workers)
            .dedicated_trustees(cfg.dedicated)
            .build();
        let trustees: Vec<usize> = if cfg.dedicated > 0 {
            (0..cfg.dedicated).collect()
        } else {
            (0..cfg.workers).collect()
        };
        let engine: Arc<dyn McdEngine> = match &cfg.engine {
            EngineKind::Stock => super::engine::StockEngine::new(1 << 16),
            EngineKind::Trust { shards } => {
                super::engine::TrustEngine::new(&rt, &trustees, (*shards).max(1))
            }
        };
        let listener = TcpListener::bind(&cfg.addr).expect("bind memcached");
        let local_addr = listener.local_addr().unwrap();
        listener.set_nonblocking(true).unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let ops_served = Arc::new(AtomicU64::new(0));
        let socket_workers: Vec<usize> = (cfg.dedicated..cfg.workers).collect();
        assert!(!socket_workers.is_empty());

        let accept_handle = {
            let stop = stop.clone();
            let engine = engine.clone();
            let shared = rt.shared().clone();
            let ops = ops_served.clone();
            std::thread::Builder::new()
                .name("mcd-accept".into())
                .spawn(move || {
                    let mut next = 0usize;
                    while !stop.load(Ordering::Acquire) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                let worker = socket_workers[next % socket_workers.len()];
                                next += 1;
                                let engine = engine.clone();
                                let ops = ops.clone();
                                let stop = stop.clone();
                                shared.inject(
                                    worker,
                                    Box::new(move || {
                                        fiber::with_executor(|e| {
                                            e.spawn(move || {
                                                connection_fiber(stream, engine, ops, stop)
                                            });
                                        });
                                    }),
                                );
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(std::time::Duration::from_micros(200));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .unwrap()
        };

        McdServer {
            rt: Some(rt),
            engine,
            local_addr,
            stop,
            accept_handle: Some(accept_handle),
            ops_served,
        }
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    pub fn engine(&self) -> &Arc<dyn McdEngine> {
        &self.engine
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt.as_ref().unwrap()
    }

    /// Populate the table with `n` items of `val_len` bytes.
    pub fn prefill(&self, n: u64, val_len: usize) {
        let worker = self.runtime().workers() - 1;
        let engine = self.engine.clone();
        self.runtime().block_on(worker, move || {
            let done = Arc::new(AtomicU64::new(0));
            let mut issued = 0u64;
            while issued < n || done.load(Ordering::Relaxed) < n {
                while issued < n && issued - done.load(Ordering::Relaxed) < 256 {
                    let d = done.clone();
                    engine.set(
                        super::memtier::key_bytes(issued),
                        0,
                        vec![b'v'; val_len],
                        Box::new(move |_| {
                            d.fetch_add(1, Ordering::Relaxed);
                        }),
                    );
                    issued += 1;
                }
                fiber::yield_now();
            }
        });
    }

    pub fn stop(mut self) {
        self.stop_impl();
    }

    fn stop_impl(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(rt) = self.rt.take() {
            rt.shutdown();
        }
    }
}

impl Drop for McdServer {
    fn drop(&mut self) {
        self.stop_impl();
    }
}

/// Ordered response buffer: completions arrive out of order from the
/// shards; the wire needs them in request order.
struct Reorder {
    next_seq: u64,
    next_emit: u64,
    pending: HashMap<u64, Vec<u8>>,
}

fn connection_fiber(
    mut stream: TcpStream,
    engine: Arc<dyn McdEngine>,
    ops: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
) {
    stream.set_nonblocking(true).unwrap();
    stream.set_nodelay(true).ok();
    let reorder = Rc::new(RefCell::new(Reorder {
        next_seq: 0,
        next_emit: 0,
        pending: HashMap::new(),
    }));
    let mut inbuf: Vec<u8> = Vec::with_capacity(32 * 1024);
    let mut out: Vec<u8> = Vec::with_capacity(32 * 1024);
    let mut wcur = 0usize;
    let mut peer_gone = false;

    loop {
        if !peer_gone {
            match read_available(&mut stream, &mut inbuf) {
                ReadOutcome::Closed => peer_gone = true,
                _ => {}
            }
        }
        // Parse + dispatch (state machine: receive → parse → process).
        let mut consumed = 0usize;
        while let Some((cmd, used)) = parse_command(&inbuf[consumed..]) {
            consumed += used;
            let seq = {
                let mut r = reorder.borrow_mut();
                let s = r.next_seq;
                r.next_seq += 1;
                s
            };
            let ro = reorder.clone();
            let ops = ops.clone();
            match cmd {
                Command::Get { key } => {
                    let echo_key = key.clone();
                    engine.get(
                        key,
                        Box::new(move |item| {
                            let mut resp = Vec::new();
                            if let Some(item) = item {
                                resp.extend_from_slice(
                                    format!(
                                        "VALUE {} {} {}\r\n",
                                        String::from_utf8_lossy(&echo_key),
                                        item.flags,
                                        item.data.len()
                                    )
                                    .as_bytes(),
                                );
                                resp.extend_from_slice(&item.data);
                                resp.extend_from_slice(b"\r\n");
                            }
                            resp.extend_from_slice(b"END\r\n");
                            ro.borrow_mut().pending.insert(seq, resp);
                            ops.fetch_add(1, Ordering::Relaxed);
                        }),
                    );
                }
                Command::Set { key, flags, data } => {
                    engine.set(
                        key,
                        flags,
                        data,
                        Box::new(move |_| {
                            ro.borrow_mut().pending.insert(seq, b"STORED\r\n".to_vec());
                            ops.fetch_add(1, Ordering::Relaxed);
                        }),
                    );
                }
            }
        }
        if consumed > 0 {
            inbuf.drain(..consumed);
        }
        // Emit the contiguous prefix of completed responses, in order.
        {
            let mut r = reorder.borrow_mut();
            loop {
                let seq = r.next_emit;
                let Some(resp) = r.pending.remove(&seq) else { break };
                out.extend_from_slice(&resp);
                r.next_emit += 1;
            }
        }
        if !write_pending(&mut stream, &mut out, &mut wcur) {
            break;
        }
        {
            let r = reorder.borrow();
            let drained = r.next_emit == r.next_seq && out.is_empty();
            if drained && (peer_gone || stop.load(Ordering::Acquire)) {
                break;
            }
        }
        fiber::yield_now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Read, Write};

    #[test]
    fn parse_get_and_set() {
        let (cmd, used) = parse_command(b"get foo\r\n").unwrap();
        assert_eq!(cmd, Command::Get { key: b"foo".to_vec() });
        assert_eq!(used, 9);
        let (cmd, used) = parse_command(b"set foo 7 0 5\r\nhello\r\nget x\r\n").unwrap();
        assert_eq!(
            cmd,
            Command::Set { key: b"foo".to_vec(), flags: 7, data: b"hello".to_vec() }
        );
        assert_eq!(used, 22);
    }

    #[test]
    fn parse_waits_for_data_block() {
        assert!(parse_command(b"set foo 0 0 5\r\nhel").is_none());
        assert!(parse_command(b"set foo 0 0 5\r\n").is_none());
        assert!(parse_command(b"get fo").is_none());
    }

    fn mcd_roundtrip(engine: EngineKind) {
        let server = McdServer::start(McdServerConfig {
            workers: 2,
            engine,
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        c.write_all(b"set greeting 5 0 5\r\nhello\r\n").unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "STORED\r\n");

        c.write_all(b"get greeting\r\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "VALUE greeting 5 5\r\n");
        let mut data = vec![0u8; 7];
        reader.read_exact(&mut data).unwrap();
        assert_eq!(&data, b"hello\r\n");
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "END\r\n");

        c.write_all(b"get missing\r\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "END\r\n");
        drop((c, reader));
        server.stop();
    }

    #[test]
    fn stock_server_roundtrip() {
        mcd_roundtrip(EngineKind::Stock);
    }

    #[test]
    fn trust_server_roundtrip() {
        mcd_roundtrip(EngineKind::Trust { shards: 2 });
    }

    #[test]
    fn pipelined_responses_stay_ordered() {
        // The delegated engine completes out of order across shards; the
        // text protocol demands in-order responses. Hammer with a
        // pipelined mix and verify strict ordering by echoing keys.
        let server = McdServer::start(McdServerConfig {
            workers: 3,
            engine: EngineKind::Trust { shards: 8 },
            ..Default::default()
        });
        server.prefill(64, 8);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        let mut sent_keys = Vec::new();
        let mut req = Vec::new();
        for i in 0..64u64 {
            let key = super::super::memtier::key_bytes(i);
            req.extend_from_slice(format!("get {}\r\n", String::from_utf8_lossy(&key)).as_bytes());
            sent_keys.push(key);
        }
        c.write_all(&req).unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        for want in &sent_keys {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(
                line.starts_with(&format!("VALUE {} ", String::from_utf8_lossy(want))),
                "out-of-order response: got {line:?} want key {}",
                String::from_utf8_lossy(want)
            );
            let mut data_line = String::new();
            reader.read_line(&mut data_line).unwrap(); // data
            let mut end = String::new();
            reader.read_line(&mut end).unwrap();
            assert_eq!(end, "END\r\n");
        }
        drop((c, reader));
        server.stop();
    }
}
