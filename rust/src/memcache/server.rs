//! Mini-memcached TCP server speaking the memcached **text protocol**
//! (get/set subset), structured like the paper's port (§7):
//!
//! - Socket worker fibers follow the original state-machine order:
//!   receive → parse → process → enqueue result → transmit.
//! - With the [`TrustEngine`](super::engine::TrustEngine), each request is
//!   dispatched with asynchronous delegation (`apply_then`) and the worker
//!   "moves on to the next request without waiting".
//! - The memcached protocol has no request ids, so responses to one
//!   connection must be transmitted **in order** even though shard
//!   responses may complete out of order — exactly the reordering buffer
//!   the paper describes ("the memcached socket worker thread must order
//!   the responses before they are transmitted").

use super::engine::McdEngine;
use crate::kvstore::netfiber::{
    self, net_wait, read_burst, write_pending, NetPolicy, ReadOutcome,
};
use crate::fiber;
use crate::runtime::Runtime;
use std::cell::RefCell;
use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// One parsed text-protocol command.
#[derive(Debug, PartialEq, Eq)]
pub enum Command {
    Get { key: Vec<u8> },
    Set { key: Vec<u8>, flags: u32, data: Vec<u8> },
}

/// Longest command line the parser will buffer before declaring the
/// stream hostile (real memcached uses 2048; be a little generous).
pub const MAX_LINE: usize = 8192;

/// Largest `set` data block accepted (memcached's classic 1 MiB default).
pub const MAX_DATA: usize = 1 << 20;

/// Longest key accepted (real memcached's limit).
pub const MAX_KEY: usize = 250;

/// memcached key rules: 1..=[`MAX_KEY`] bytes, nothing at or below ASCII
/// space and no DEL. A key is echoed verbatim into the line-oriented
/// response stream (`VALUE <key> ...`), so a stray `\r`/`\n` smuggled
/// inside one would inject protocol lines into the response and
/// desynchronize line-based clients — reject it at parse time.
fn valid_key(key: &[u8]) -> bool {
    !key.is_empty() && key.len() <= MAX_KEY && key.iter().all(|&b| b > 0x20 && b != 0x7F)
}

/// Why a byte stream failed to parse. The server answers with a protocol
/// error line and closes — it must never panic on client bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum McdParseError {
    /// First token is not a command we speak.
    UnknownCommand,
    /// Wrong arity, non-numeric field, oversized or misterminated data.
    BadArguments,
    /// No CRLF within [`MAX_LINE`] bytes.
    LineTooLong,
}

impl McdParseError {
    /// The memcached-style error line the server sends back.
    pub fn wire_line(&self) -> &'static [u8] {
        match self {
            McdParseError::UnknownCommand => b"ERROR\r\n",
            McdParseError::BadArguments => b"CLIENT_ERROR bad command line format\r\n",
            McdParseError::LineTooLong => b"CLIENT_ERROR line too long\r\n",
        }
    }
}

/// Incremental text-protocol parser: `Ok(Some((command, bytes_consumed)))`
/// for a complete command, `Ok(None)` to wait for more bytes, `Err` for a
/// stream that can never become valid (total — no panic on any input).
pub fn parse_command(buf: &[u8]) -> Result<Option<(Command, usize)>, McdParseError> {
    let Some(line_end) = find_crlf(buf) else {
        // +1: a maximal legal line may momentarily sit in the buffer with
        // its '\r' but not yet its '\n'.
        return if buf.len() > MAX_LINE + 1 {
            Err(McdParseError::LineTooLong)
        } else {
            Ok(None)
        };
    };
    if line_end > MAX_LINE {
        return Err(McdParseError::LineTooLong);
    }
    let line = &buf[..line_end];
    let mut parts = line.split(|&b| b == b' ').filter(|p| !p.is_empty());
    match parts.next() {
        Some(b"get") => {
            let key = parts.next().ok_or(McdParseError::BadArguments)?.to_vec();
            if !valid_key(&key) {
                return Err(McdParseError::BadArguments);
            }
            Ok(Some((Command::Get { key }, line_end + 2)))
        }
        Some(b"set") => {
            let key = parts.next().ok_or(McdParseError::BadArguments)?.to_vec();
            if !valid_key(&key) {
                return Err(McdParseError::BadArguments);
            }
            let flags: u32 = parse_num(parts.next().ok_or(McdParseError::BadArguments)?)
                .ok_or(McdParseError::BadArguments)?;
            let _exptime: u64 = parse_num(parts.next().ok_or(McdParseError::BadArguments)?)
                .ok_or(McdParseError::BadArguments)?;
            let bytes: usize = parse_num(parts.next().ok_or(McdParseError::BadArguments)?)
                .ok_or(McdParseError::BadArguments)?;
            if bytes > MAX_DATA {
                return Err(McdParseError::BadArguments);
            }
            let data_start = line_end + 2;
            if buf.len() < data_start + bytes + 2 {
                return Ok(None); // waiting for the data block
            }
            if &buf[data_start + bytes..data_start + bytes + 2] != b"\r\n" {
                return Err(McdParseError::BadArguments);
            }
            let data = buf[data_start..data_start + bytes].to_vec();
            Ok(Some((Command::Set { key, flags, data }, data_start + bytes + 2)))
        }
        // Blank lines and unknown verbs alike: the stream is not speaking
        // our protocol.
        _ => Err(McdParseError::UnknownCommand),
    }
}

fn find_crlf(buf: &[u8]) -> Option<usize> {
    // Bound the scan: beyond MAX_LINE (+1 for a CR split across reads) the
    // stream is hostile regardless of what follows.
    let window = buf.len().min(MAX_LINE + 2);
    buf[..window].windows(2).position(|w| w == b"\r\n")
}

fn parse_num<N: std::str::FromStr>(b: &[u8]) -> Option<N> {
    std::str::from_utf8(b).ok()?.parse().ok()
}

/// Engine selector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Stock,
    Trust { shards: usize },
}

impl EngineKind {
    pub fn label(&self) -> String {
        match self {
            EngineKind::Stock => "S (stock)".into(),
            EngineKind::Trust { shards } => format!("Trust{shards}"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct McdServerConfig {
    pub workers: usize,
    pub dedicated: usize,
    pub engine: EngineKind,
    pub addr: String,
    /// How connection fibers wait for socket progress.
    pub net: NetPolicy,
}

impl Default for McdServerConfig {
    fn default() -> Self {
        McdServerConfig {
            workers: 4,
            dedicated: 0,
            engine: EngineKind::Trust { shards: 4 },
            addr: "127.0.0.1:0".into(),
            net: NetPolicy::default(),
        }
    }
}

impl McdServerConfig {
    /// Topology checks, before any runtime is built (mirrors
    /// [`crate::kvstore::KvServerConfig::validate`]).
    pub fn validate(&self) -> Result<(), String> {
        netfiber::validate_topology(self.workers, self.dedicated)
    }
}

/// A running mini-memcached instance.
pub struct McdServer {
    rt: Option<Runtime>,
    engine: Arc<dyn McdEngine>,
    local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    pub ops_served: Arc<AtomicU64>,
}

impl McdServer {
    /// Start a server, panicking on an invalid configuration (see
    /// [`McdServer::try_start`] for the fallible form).
    pub fn start(cfg: McdServerConfig) -> McdServer {
        Self::try_start(cfg).unwrap_or_else(|e| panic!("invalid McdServerConfig: {e}"))
    }

    /// Start a server, reporting configuration/bind problems as a
    /// descriptive error *before* any worker thread is spawned.
    pub fn try_start(cfg: McdServerConfig) -> Result<McdServer, String> {
        cfg.validate()?;
        let listener =
            TcpListener::bind(&cfg.addr).map_err(|e| format!("bind {}: {e}", cfg.addr))?;
        let local_addr = listener.local_addr().map_err(|e| format!("local_addr: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("nonblocking listener: {e}"))?;

        let rt = Runtime::builder()
            .workers(cfg.workers)
            .dedicated_trustees(cfg.dedicated)
            .build();
        let trustees: Vec<usize> = if cfg.dedicated > 0 {
            (0..cfg.dedicated).collect()
        } else {
            (0..cfg.workers).collect()
        };
        let engine: Arc<dyn McdEngine> = match &cfg.engine {
            EngineKind::Stock => super::engine::StockEngine::new(1 << 16),
            EngineKind::Trust { shards } => {
                super::engine::TrustEngine::new(&rt, &trustees, (*shards).max(1))
            }
        };
        let stop = Arc::new(AtomicBool::new(false));
        let ops_served = Arc::new(AtomicU64::new(0));
        let socket_workers: Vec<usize> = (cfg.dedicated..cfg.workers).collect();
        let policy = cfg.net;

        let dispatch = {
            let engine = engine.clone();
            let ops = ops_served.clone();
            let stop = stop.clone();
            netfiber::round_robin_dispatch(
                rt.shared().clone(),
                socket_workers.clone(),
                move |stream| {
                    let engine = engine.clone();
                    let ops = ops.clone();
                    let stop = stop.clone();
                    Box::new(move || connection_fiber(stream, engine, ops, stop, policy))
                },
            )
        };

        let accept_handle = netfiber::start_acceptor(
            policy,
            listener,
            stop.clone(),
            rt.shared(),
            socket_workers[0],
            dispatch,
            "mcd-accept",
        )?;

        Ok(McdServer {
            rt: Some(rt),
            engine,
            local_addr,
            stop,
            accept_handle,
            ops_served,
        })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    pub fn engine(&self) -> &Arc<dyn McdEngine> {
        &self.engine
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt.as_ref().unwrap()
    }

    /// Populate the table with `n` items of `val_len` bytes.
    pub fn prefill(&self, n: u64, val_len: usize) {
        let worker = self.runtime().workers() - 1;
        let engine = self.engine.clone();
        self.runtime().block_on(worker, move || {
            let done = Arc::new(AtomicU64::new(0));
            let mut issued = 0u64;
            while issued < n || done.load(Ordering::Relaxed) < n {
                while issued < n && issued - done.load(Ordering::Relaxed) < 256 {
                    let d = done.clone();
                    engine.set(
                        super::memtier::key_bytes(issued),
                        0,
                        vec![b'v'; val_len],
                        Box::new(move |_| {
                            d.fetch_add(1, Ordering::Relaxed);
                        }),
                    );
                    issued += 1;
                }
                fiber::yield_now();
            }
        });
    }

    pub fn stop(mut self) {
        self.stop_impl();
    }

    fn stop_impl(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        if let Some(rt) = self.rt.take() {
            rt.shutdown();
        }
    }
}

impl Drop for McdServer {
    fn drop(&mut self) {
        self.stop_impl();
    }
}

/// Ordered response buffer: completions arrive out of order from the
/// shards; the wire needs them in request order.
struct Reorder {
    next_seq: u64,
    next_emit: u64,
    pending: HashMap<u64, Vec<u8>>,
}

fn connection_fiber(
    mut stream: TcpStream,
    engine: Arc<dyn McdEngine>,
    ops: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    policy: NetPolicy,
) {
    if stream.set_nonblocking(true).is_err() {
        return;
    }
    stream.set_nodelay(true).ok();
    let fd = stream.as_raw_fd();
    let reorder = Rc::new(RefCell::new(Reorder {
        next_seq: 0,
        next_emit: 0,
        pending: HashMap::new(),
    }));
    let mut inbuf: Vec<u8> = Vec::with_capacity(32 * 1024);
    let mut out: Vec<u8> = Vec::with_capacity(32 * 1024);
    let mut wcur = 0usize;
    let mut peer_gone = false;
    // Unparseable stream: answer with a protocol error line (in order,
    // through the reorder buffer), drain, close — never panic the worker.
    let mut poisoned = false;
    // Bounded stop-drain, mirroring the KV server: flush acked responses
    // on shutdown without letting a never-reading peer hold it hostage.
    let mut stop_deadline: Option<std::time::Instant> = None;

    loop {
        let mut progress = false;
        if !peer_gone && !poisoned && inbuf.len() < netfiber::MAX_INBUF {
            match read_burst(&mut stream, &mut inbuf, 64 * 1024) {
                ReadOutcome::Data(_) => progress = true,
                ReadOutcome::Closed => peer_gone = true,
                ReadOutcome::WouldBlock => {}
            }
        }
        // Parse + dispatch (state machine: receive → parse → process).
        let mut consumed = 0usize;
        while !poisoned {
            let (cmd, used) = match parse_command(&inbuf[consumed..]) {
                Ok(Some(hit)) => hit,
                Ok(None) => break,
                Err(e) => {
                    // Sequence the error line behind every completed
                    // command, like any other response.
                    let mut r = reorder.borrow_mut();
                    let seq = r.next_seq;
                    r.next_seq += 1;
                    r.pending.insert(seq, e.wire_line().to_vec());
                    poisoned = true;
                    break;
                }
            };
            consumed += used;
            progress = true;
            let seq = {
                let mut r = reorder.borrow_mut();
                let s = r.next_seq;
                r.next_seq += 1;
                s
            };
            let ro = reorder.clone();
            let ops = ops.clone();
            match cmd {
                Command::Get { key } => {
                    let echo_key = key.clone();
                    engine.get(
                        key,
                        Box::new(move |item| {
                            let mut resp = Vec::new();
                            if let Some(item) = item {
                                resp.extend_from_slice(
                                    format!(
                                        "VALUE {} {} {}\r\n",
                                        String::from_utf8_lossy(&echo_key),
                                        item.flags,
                                        item.data.len()
                                    )
                                    .as_bytes(),
                                );
                                resp.extend_from_slice(&item.data);
                                resp.extend_from_slice(b"\r\n");
                            }
                            resp.extend_from_slice(b"END\r\n");
                            ro.borrow_mut().pending.insert(seq, resp);
                            ops.fetch_add(1, Ordering::Relaxed);
                        }),
                    );
                }
                Command::Set { key, flags, data } => {
                    engine.set(
                        key,
                        flags,
                        data,
                        Box::new(move |_| {
                            ro.borrow_mut().pending.insert(seq, b"STORED\r\n".to_vec());
                            ops.fetch_add(1, Ordering::Relaxed);
                        }),
                    );
                }
            }
        }
        if consumed > 0 {
            inbuf.drain(..consumed);
        }
        // Emit the contiguous prefix of completed responses, in order.
        {
            let mut r = reorder.borrow_mut();
            loop {
                let seq = r.next_emit;
                let Some(resp) = r.pending.remove(&seq) else { break };
                out.extend_from_slice(&resp);
                r.next_emit += 1;
            }
        }
        {
            let before = out.len() - wcur;
            if !write_pending(&mut stream, &mut out, &mut wcur) {
                break;
            }
            let after = if out.is_empty() { 0 } else { out.len() - wcur };
            if after < before {
                progress = true;
            }
        }
        let awaiting = {
            let r = reorder.borrow();
            r.next_emit != r.next_seq
        };
        if !awaiting && out.is_empty() && (peer_gone || poisoned || stop.load(Ordering::Acquire))
        {
            break;
        }
        if !awaiting && stop.load(Ordering::Acquire) {
            let deadline = *stop_deadline.get_or_insert_with(|| {
                std::time::Instant::now() + std::time::Duration::from_millis(250)
            });
            if std::time::Instant::now() >= deadline {
                break;
            }
        }
        if progress || awaiting || stop.load(Ordering::Acquire) {
            fiber::yield_now();
        } else {
            let want_read = !peer_gone && !poisoned && inbuf.len() < netfiber::MAX_INBUF;
            let want_write = !out.is_empty();
            net_wait(policy, fd, want_read, want_write);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Read, Write};

    #[test]
    fn parse_get_and_set() {
        let (cmd, used) = parse_command(b"get foo\r\n").unwrap().unwrap();
        assert_eq!(cmd, Command::Get { key: b"foo".to_vec() });
        assert_eq!(used, 9);
        let (cmd, used) = parse_command(b"set foo 7 0 5\r\nhello\r\nget x\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(
            cmd,
            Command::Set { key: b"foo".to_vec(), flags: 7, data: b"hello".to_vec() }
        );
        assert_eq!(used, 22);
    }

    #[test]
    fn parse_waits_for_data_block() {
        assert!(parse_command(b"set foo 0 0 5\r\nhel").unwrap().is_none());
        assert!(parse_command(b"set foo 0 0 5\r\n").unwrap().is_none());
        assert!(parse_command(b"get fo").unwrap().is_none());
    }

    #[test]
    fn parse_is_total_on_hostile_input() {
        // Unknown verb: an error, not a panic (this used to panic!).
        assert_eq!(
            parse_command(b"flush_all\r\n"),
            Err(McdParseError::UnknownCommand)
        );
        assert_eq!(parse_command(b"\r\n"), Err(McdParseError::UnknownCommand));
        // Bad arity / non-numeric fields: previously stuck forever (None).
        assert_eq!(parse_command(b"get\r\n"), Err(McdParseError::BadArguments));
        assert_eq!(
            parse_command(b"set k x 0 5\r\nhello\r\n"),
            Err(McdParseError::BadArguments)
        );
        // Data block not CRLF-terminated where it should be.
        assert_eq!(
            parse_command(b"set k 0 0 2\r\nabXY\r\n"),
            Err(McdParseError::BadArguments)
        );
        // Oversized declared data block.
        assert_eq!(
            parse_command(format!("set k 0 0 {}\r\n", MAX_DATA + 1).as_bytes()),
            Err(McdParseError::BadArguments)
        );
        // Keys that would inject lines into the echoed response stream
        // (lone LF/CR survive the space-split and the CRLF scan).
        assert_eq!(
            parse_command(b"get k\niEND\r\n"),
            Err(McdParseError::BadArguments)
        );
        assert_eq!(
            parse_command(b"set k\rx 0 0 1\r\na\r\n"),
            Err(McdParseError::BadArguments)
        );
        // Oversized key.
        let mut cmd = b"get ".to_vec();
        cmd.extend_from_slice(&vec![b'k'; MAX_KEY + 1]);
        cmd.extend_from_slice(b"\r\n");
        assert_eq!(parse_command(&cmd), Err(McdParseError::BadArguments));
        // Endless line without CRLF.
        let long = vec![b'a'; MAX_LINE + 16];
        assert_eq!(parse_command(&long), Err(McdParseError::LineTooLong));
        // Random bytes never panic.
        crate::util::quickcheck::check::<Vec<u8>>("mcd-parse-garbage", 200, |bytes| {
            let _ = parse_command(bytes);
            true
        });
    }

    #[test]
    fn unknown_command_answers_error_line_and_closes() {
        let server = McdServer::start(McdServerConfig {
            workers: 2,
            engine: EngineKind::Trust { shards: 2 },
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        // A valid set, then garbage: the error line must arrive *after*
        // the STORED (in order), then the server closes.
        c.write_all(b"set k 0 0 1\r\nv\r\nflush_all\r\n").unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "STORED\r\n");
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "ERROR\r\n");
        let mut rest = Vec::new();
        reader.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "connection must close after ERROR");
        // The worker survived: a new connection works.
        let mut c2 = TcpStream::connect(server.addr()).unwrap();
        c2.write_all(b"get k\r\n").unwrap();
        let mut reader2 = BufReader::new(c2.try_clone().unwrap());
        let mut l = String::new();
        reader2.read_line(&mut l).unwrap();
        assert_eq!(l, "VALUE k 0 1\r\n");
        drop((c, reader, c2, reader2));
        server.stop();
    }

    fn mcd_roundtrip(engine: EngineKind) {
        let server = McdServer::start(McdServerConfig {
            workers: 2,
            engine,
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        c.write_all(b"set greeting 5 0 5\r\nhello\r\n").unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "STORED\r\n");

        c.write_all(b"get greeting\r\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "VALUE greeting 5 5\r\n");
        let mut data = vec![0u8; 7];
        reader.read_exact(&mut data).unwrap();
        assert_eq!(&data, b"hello\r\n");
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "END\r\n");

        c.write_all(b"get missing\r\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "END\r\n");
        drop((c, reader));
        server.stop();
    }

    #[test]
    fn stock_server_roundtrip() {
        mcd_roundtrip(EngineKind::Stock);
    }

    #[test]
    fn trust_server_roundtrip() {
        mcd_roundtrip(EngineKind::Trust { shards: 2 });
    }

    #[test]
    fn pipelined_responses_stay_ordered() {
        // The delegated engine completes out of order across shards; the
        // text protocol demands in-order responses. Hammer with a
        // pipelined mix and verify strict ordering by echoing keys.
        let server = McdServer::start(McdServerConfig {
            workers: 3,
            engine: EngineKind::Trust { shards: 8 },
            ..Default::default()
        });
        server.prefill(64, 8);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        let mut sent_keys = Vec::new();
        let mut req = Vec::new();
        for i in 0..64u64 {
            let key = super::super::memtier::key_bytes(i);
            req.extend_from_slice(format!("get {}\r\n", String::from_utf8_lossy(&key)).as_bytes());
            sent_keys.push(key);
        }
        c.write_all(&req).unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        for want in &sent_keys {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(
                line.starts_with(&format!("VALUE {} ", String::from_utf8_lossy(want))),
                "out-of-order response: got {line:?} want key {}",
                String::from_utf8_lossy(want)
            );
            let mut data_line = String::new();
            reader.read_line(&mut data_line).unwrap(); // data
            let mut end = String::new();
            reader.read_line(&mut end).unwrap();
            assert_eq!(end, "END\r\n");
        }
        drop((c, reader));
        server.stop();
    }
}
