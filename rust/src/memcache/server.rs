//! Mini-memcached TCP server speaking the memcached **text protocol**
//! (get/set subset, with real `exptime` support), structured like the
//! paper's port (§7), as a [`Protocol`] front end on the shared
//! delegated server core ([`crate::server::engine`]) over the unified
//! item store:
//!
//! - The engine's connection fibers follow the original state-machine
//!   order: receive → parse → process → enqueue result → transmit.
//! - [`McdProtocol`] dispatches onto [`AsyncKv`]'s item-aware ops
//!   (`get_item`/`set_item`), so all four backends
//!   (`trust`/`mutex`/`rwlock`/`swift`) serve memcached traffic with
//!   flags, TTL expiry and per-shard LRU eviction — the boxed-callback
//!   `McdEngine` duplicate this module used to carry is gone.
//! - With the Trust backend each request is dispatched with asynchronous
//!   delegation and the worker "moves on to the next request without
//!   waiting"; the GET completion receives key, flags and value
//!   **borrowed** (key echoed through the delegation slot), so the
//!   steady-state store path allocates nothing.
//! - The memcached protocol has no request ids, so responses to one
//!   connection must be transmitted **in order** even though shard
//!   responses may complete out of order — exactly the reordering buffer
//!   the paper describes. That buffer is the engine's
//!   [`ResponseOrder::InOrder`] spool.
//!
//! `exptime` simplifications (both client-visible, both deliberate):
//! memcached treats values > 30 days as absolute unix timestamps — the
//! store clock starts at server boot, so we treat every positive
//! `exptime` as relative seconds (0 = never); and a **negative**
//! `exptime` (memcached's "expire immediately") stores the item with a
//! 1 ms deadline — any real client observes the same immediate miss,
//! minus the sub-millisecond window.

use crate::kvstore::backend::{AckCb, AsyncKv, BackendKind, GetItemCb};
use crate::kvstore::store::{StoreConfig, StoreStats};
use crate::runtime::Runtime;
use crate::server::engine::{
    Completion, ConnMetrics, CoreConfig, Inbuf, Protocol, ResponseOrder, ServerCore, ServerTuning,
};
use crate::server::netfiber::{self, NetPolicy};
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

/// One parsed text-protocol command.
#[derive(Debug, PartialEq, Eq)]
pub enum Command {
    Get { key: Vec<u8> },
    /// `exptime` keeps memcached's sign convention: 0 = never, positive
    /// = relative seconds, negative = expire immediately.
    Set { key: Vec<u8>, flags: u32, exptime: i64, data: Vec<u8> },
}

/// Longest command line the parser will buffer before declaring the
/// stream hostile (real memcached uses 2048; be a little generous).
pub const MAX_LINE: usize = 8192;

/// Largest `set` data block accepted (memcached's classic 1 MiB default).
pub const MAX_DATA: usize = 1 << 20;

/// Longest key accepted (real memcached's limit).
pub const MAX_KEY: usize = 250;

/// memcached key rules: 1..=[`MAX_KEY`] bytes, nothing at or below ASCII
/// space and no DEL. A key is echoed verbatim into the line-oriented
/// response stream (`VALUE <key> ...`), so a stray `\r`/`\n` smuggled
/// inside one would inject protocol lines into the response and
/// desynchronize line-based clients — reject it at parse time.
fn valid_key(key: &[u8]) -> bool {
    !key.is_empty() && key.len() <= MAX_KEY && key.iter().all(|&b| b > 0x20 && b != 0x7F)
}

/// Why a byte stream failed to parse. The server answers with a protocol
/// error line and closes — it must never panic on client bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum McdParseError {
    /// First token is not a command we speak.
    UnknownCommand,
    /// Wrong arity, non-numeric field, oversized or misterminated data.
    BadArguments,
    /// No CRLF within [`MAX_LINE`] bytes.
    LineTooLong,
}

impl McdParseError {
    /// The memcached-style error line the server sends back.
    pub fn wire_line(&self) -> &'static [u8] {
        match self {
            McdParseError::UnknownCommand => b"ERROR\r\n",
            McdParseError::BadArguments => b"CLIENT_ERROR bad command line format\r\n",
            McdParseError::LineTooLong => b"CLIENT_ERROR line too long\r\n",
        }
    }
}

/// Incremental text-protocol parser: `Ok(Some((command, bytes_consumed)))`
/// for a complete command, `Ok(None)` to wait for more bytes, `Err` for a
/// stream that can never become valid (total — no panic on any input).
pub fn parse_command(buf: &[u8]) -> Result<Option<(Command, usize)>, McdParseError> {
    let Some(line_end) = find_crlf(buf) else {
        // +1: a maximal legal line may momentarily sit in the buffer with
        // its '\r' but not yet its '\n'.
        return if buf.len() > MAX_LINE + 1 {
            Err(McdParseError::LineTooLong)
        } else {
            Ok(None)
        };
    };
    if line_end > MAX_LINE {
        return Err(McdParseError::LineTooLong);
    }
    let line = &buf[..line_end];
    let mut parts = line.split(|&b| b == b' ').filter(|p| !p.is_empty());
    match parts.next() {
        Some(b"get") => {
            let key = parts.next().ok_or(McdParseError::BadArguments)?.to_vec();
            if !valid_key(&key) {
                return Err(McdParseError::BadArguments);
            }
            Ok(Some((Command::Get { key }, line_end + 2)))
        }
        Some(b"set") => {
            let key = parts.next().ok_or(McdParseError::BadArguments)?.to_vec();
            if !valid_key(&key) {
                return Err(McdParseError::BadArguments);
            }
            let flags: u32 = parse_num(parts.next().ok_or(McdParseError::BadArguments)?)
                .ok_or(McdParseError::BadArguments)?;
            // i64: a negative exptime is legal memcached ("expire
            // immediately", e.g. libmemcached's -1).
            let exptime: i64 = parse_num(parts.next().ok_or(McdParseError::BadArguments)?)
                .ok_or(McdParseError::BadArguments)?;
            let bytes: usize = parse_num(parts.next().ok_or(McdParseError::BadArguments)?)
                .ok_or(McdParseError::BadArguments)?;
            if bytes > MAX_DATA {
                return Err(McdParseError::BadArguments);
            }
            let data_start = line_end + 2;
            if buf.len() < data_start + bytes + 2 {
                return Ok(None); // waiting for the data block
            }
            if &buf[data_start + bytes..data_start + bytes + 2] != b"\r\n" {
                return Err(McdParseError::BadArguments);
            }
            let data = buf[data_start..data_start + bytes].to_vec();
            Ok(Some((
                Command::Set { key, flags, exptime, data },
                data_start + bytes + 2,
            )))
        }
        // Blank lines and unknown verbs alike: the stream is not speaking
        // our protocol.
        _ => Err(McdParseError::UnknownCommand),
    }
}

fn find_crlf(buf: &[u8]) -> Option<usize> {
    // Bound the scan: beyond MAX_LINE (+1 for a CR split across reads) the
    // stream is hostile regardless of what follows.
    let window = buf.len().min(MAX_LINE + 2);
    buf[..window].windows(2).position(|w| w == b"\r\n")
}

fn parse_num<N: std::str::FromStr>(b: &[u8]) -> Option<N> {
    std::str::from_utf8(b).ok()?.parse().ok()
}

#[derive(Clone, Debug)]
pub struct McdServerConfig {
    pub workers: usize,
    pub dedicated: usize,
    /// Storage backend (the same four the KV and RESP servers accept).
    pub backend: BackendKind,
    /// Total store byte budget (split per shard; 0 = unlimited). Going
    /// over evicts per-shard LRU victims.
    pub budget_bytes: u64,
    pub addr: String,
    /// How connection fibers wait for socket progress.
    pub net: NetPolicy,
    /// Overload-control and degradation knobs (shed watermarks, request
    /// deadline, stalled-connection reaping, stop-drain grace).
    pub tuning: ServerTuning,
}

impl Default for McdServerConfig {
    fn default() -> Self {
        McdServerConfig {
            workers: 4,
            dedicated: 0,
            backend: BackendKind::Trust { shards: 4 },
            budget_bytes: 0,
            addr: "127.0.0.1:0".into(),
            net: NetPolicy::default(),
            tuning: ServerTuning::default(),
        }
    }
}

impl McdServerConfig {
    /// Topology + budget sanity checks, before any runtime is built
    /// (mirrors [`crate::kvstore::KvServerConfig::validate`]).
    pub fn validate(&self) -> Result<(), String> {
        netfiber::validate_topology(self.workers, self.dedicated)?;
        self.backend.validate_budget(self.budget_bytes)?;
        self.tuning.validate()
    }
}

/// The memcached text protocol on the shared engine, over any
/// [`AsyncKv`] backend.
pub struct McdProtocol {
    kv: Arc<dyn AsyncKv>,
}

impl McdProtocol {
    pub fn new(kv: Arc<dyn AsyncKv>) -> McdProtocol {
        McdProtocol { kv }
    }
}

impl Protocol for McdProtocol {
    type Request = Command;
    type Error = McdParseError;

    /// No request ids on the wire: strict in-order responses via the
    /// engine's reorder spool.
    const ORDER: ResponseOrder = ResponseOrder::InOrder;

    fn parse(&mut self, inbuf: &mut Inbuf) -> Result<Option<Command>, McdParseError> {
        match parse_command(inbuf.unparsed())? {
            Some((cmd, used)) => {
                inbuf.advance(used);
                Ok(Some(cmd))
            }
            None => Ok(None),
        }
    }

    fn render_error(&mut self, err: &McdParseError, out: &mut Vec<u8>) {
        out.extend_from_slice(err.wire_line());
    }

    /// Shed replies are a `SERVER_ERROR` line — memcached's "server-side
    /// problem, command not executed" convention. The connection stays
    /// open and in-order, so pipelined clients keep their pairing.
    fn render_overload(&mut self, _req: &Command, out: &mut Vec<u8>) -> bool {
        out.extend_from_slice(b"SERVER_ERROR busy\r\n");
        true
    }

    fn dispatch(&mut self, cmd: Command, done: Completion) {
        match cmd {
            Command::Get { key } => {
                // The completion captures only the Completion ticket (32
                // bytes — stores inline); the key is echoed back borrowed
                // by the backend, so no owned key copy rides the
                // callback.
                self.kv.get_item(
                    &key,
                    GetItemCb::new(move |k: &[u8], item: Option<(u32, &[u8])>| {
                        use std::io::Write;
                        let mut b = done.checkout();
                        if let Some((flags, data)) = item {
                            b.extend_from_slice(b"VALUE ");
                            b.extend_from_slice(k);
                            let _ = write!(b, " {flags} {}\r\n", data.len());
                            b.extend_from_slice(data);
                            b.extend_from_slice(b"\r\n");
                        }
                        b.extend_from_slice(b"END\r\n");
                        done.complete(b);
                    }),
                );
            }
            Command::Set { key, flags, exptime, data } => {
                // Negative exptime = memcached "expire immediately":
                // stored with a 1 ms deadline (module docs).
                let ttl_ms = if exptime < 0 {
                    1
                } else {
                    (exptime as u64).saturating_mul(1000)
                };
                self.kv.set_item(
                    &key,
                    &data,
                    flags,
                    ttl_ms,
                    AckCb::new(move |_| {
                        let mut b = done.checkout();
                        b.extend_from_slice(b"STORED\r\n");
                        done.complete(b);
                    }),
                );
            }
        }
    }
}

/// A running mini-memcached instance.
pub struct McdServer {
    core: ServerCore,
    backend: Arc<dyn AsyncKv>,
    pub ops_served: Arc<AtomicU64>,
}

impl McdServer {
    /// Start a server, panicking on an invalid configuration (see
    /// [`McdServer::try_start`] for the fallible form).
    pub fn start(cfg: McdServerConfig) -> McdServer {
        Self::try_start(cfg).unwrap_or_else(|e| panic!("invalid McdServerConfig: {e}"))
    }

    /// Start a server, reporting configuration/bind problems as a
    /// descriptive error *before* any worker thread is spawned.
    pub fn try_start(cfg: McdServerConfig) -> Result<McdServer, String> {
        cfg.backend.validate_budget(cfg.budget_bytes)?;
        let mut backend_out: Option<Arc<dyn AsyncKv>> = None;
        let store_cfg = StoreConfig::with_budget(cfg.budget_bytes);
        let core = ServerCore::try_start(
            CoreConfig {
                workers: cfg.workers,
                dedicated: cfg.dedicated,
                addr: cfg.addr.clone(),
                net: cfg.net,
                tuning: cfg.tuning,
            },
            "mcd-accept",
            |rt, trustees| {
                let kv = cfg.backend.build_with(rt, trustees, &store_cfg);
                backend_out = Some(kv.clone());
                move || McdProtocol::new(kv.clone())
            },
        )?;
        let ops_served = core.ops_served().clone();
        Ok(McdServer { core, backend: backend_out.unwrap(), ops_served })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.core.addr()
    }

    pub fn backend(&self) -> &Arc<dyn AsyncKv> {
        &self.backend
    }

    pub fn runtime(&self) -> &Runtime {
        self.core.runtime()
    }

    /// Per-worker connection metrics (accepted/closed/requests/pool).
    pub fn metrics(&self) -> &Arc<ConnMetrics> {
        self.core.metrics()
    }

    /// Item-store counters (items, bytes, evictions, expirations, plus
    /// the value-slab pool hit/miss and fragmentation gauges).
    pub fn store_stats(&self) -> StoreStats {
        self.backend.store_stats()
    }

    /// Delegation-layer hot-path allocation/copy counters (diagnostic).
    pub fn hot_path_stats(&self) -> crate::runtime::HotPathStats {
        self.core.hot_path_stats()
    }

    /// io_uring submission/completion counters across all workers
    /// (zeros unless running under `NetPolicy::IoUring`; diagnostic).
    pub fn uring_stats(&self) -> crate::runtime::uring::UringStats {
        self.core.uring_stats()
    }

    /// The settled network plane (requested vs resolved policy, data-
    /// plane capability, fallback reason).
    pub fn net_info(&self) -> &crate::server::netfiber::NetInfo {
        self.core.net_info()
    }

    /// Populate the table with `n` items of `val_len` bytes.
    pub fn prefill(&self, n: u64, val_len: usize) {
        let kv = self.backend.clone();
        self.core.prefill(n, move |i, on_done| {
            kv.set_item(
                &super::memtier::key_bytes(i),
                &vec![b'v'; val_len],
                0,
                0,
                AckCb::new(move |_| on_done()),
            );
        });
    }

    pub fn stop(mut self) {
        self.core.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;

    #[test]
    fn parse_get_and_set() {
        let (cmd, used) = parse_command(b"get foo\r\n").unwrap().unwrap();
        assert_eq!(cmd, Command::Get { key: b"foo".to_vec() });
        assert_eq!(used, 9);
        let (cmd, used) = parse_command(b"set foo 7 0 5\r\nhello\r\nget x\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(
            cmd,
            Command::Set {
                key: b"foo".to_vec(),
                flags: 7,
                exptime: 0,
                data: b"hello".to_vec()
            }
        );
        assert_eq!(used, 22);
        // exptime is parsed, not elided.
        let (cmd, _) = parse_command(b"set k 1 300 2\r\nhi\r\n").unwrap().unwrap();
        assert_eq!(
            cmd,
            Command::Set { key: b"k".to_vec(), flags: 1, exptime: 300, data: b"hi".to_vec() }
        );
        // Negative exptime (memcached "expire immediately") is legal.
        let (cmd, _) = parse_command(b"set k 0 -1 2\r\nhi\r\n").unwrap().unwrap();
        assert_eq!(
            cmd,
            Command::Set { key: b"k".to_vec(), flags: 0, exptime: -1, data: b"hi".to_vec() }
        );
    }

    #[test]
    fn parse_waits_for_data_block() {
        assert!(parse_command(b"set foo 0 0 5\r\nhel").unwrap().is_none());
        assert!(parse_command(b"set foo 0 0 5\r\n").unwrap().is_none());
        assert!(parse_command(b"get fo").unwrap().is_none());
    }

    #[test]
    fn parse_is_total_on_hostile_input() {
        // Unknown verb: an error, not a panic (this used to panic!).
        assert_eq!(
            parse_command(b"flush_all\r\n"),
            Err(McdParseError::UnknownCommand)
        );
        assert_eq!(parse_command(b"\r\n"), Err(McdParseError::UnknownCommand));
        // Bad arity / non-numeric fields: previously stuck forever (None).
        assert_eq!(parse_command(b"get\r\n"), Err(McdParseError::BadArguments));
        assert_eq!(
            parse_command(b"set k x 0 5\r\nhello\r\n"),
            Err(McdParseError::BadArguments)
        );
        // Non-numeric exptime.
        assert_eq!(
            parse_command(b"set k 0 never 5\r\nhello\r\n"),
            Err(McdParseError::BadArguments)
        );
        // Data block not CRLF-terminated where it should be.
        assert_eq!(
            parse_command(b"set k 0 0 2\r\nabXY\r\n"),
            Err(McdParseError::BadArguments)
        );
        // Oversized declared data block.
        assert_eq!(
            parse_command(format!("set k 0 0 {}\r\n", MAX_DATA + 1).as_bytes()),
            Err(McdParseError::BadArguments)
        );
        // Keys that would inject lines into the echoed response stream
        // (lone LF/CR survive the space-split and the CRLF scan).
        assert_eq!(
            parse_command(b"get k\niEND\r\n"),
            Err(McdParseError::BadArguments)
        );
        assert_eq!(
            parse_command(b"set k\rx 0 0 1\r\na\r\n"),
            Err(McdParseError::BadArguments)
        );
        // Oversized key.
        let mut cmd = b"get ".to_vec();
        cmd.extend_from_slice(&vec![b'k'; MAX_KEY + 1]);
        cmd.extend_from_slice(b"\r\n");
        assert_eq!(parse_command(&cmd), Err(McdParseError::BadArguments));
        // Endless line without CRLF.
        let long = vec![b'a'; MAX_LINE + 16];
        assert_eq!(parse_command(&long), Err(McdParseError::LineTooLong));
        // Random bytes never panic.
        crate::util::quickcheck::check::<Vec<u8>>("mcd-parse-garbage", 200, |bytes| {
            let _ = parse_command(bytes);
            true
        });
    }

    #[test]
    fn unknown_command_answers_error_line_and_closes() {
        let server = McdServer::start(McdServerConfig {
            workers: 2,
            backend: BackendKind::Trust { shards: 2 },
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        // A valid set, then garbage: the error line must arrive *after*
        // the STORED (in order), then the server closes.
        c.write_all(b"set k 0 0 1\r\nv\r\nbogus_verb\r\n").unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "STORED\r\n");
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "ERROR\r\n");
        let mut rest = Vec::new();
        reader.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "connection must close after ERROR");
        // The worker survived: a new connection works.
        let mut c2 = TcpStream::connect(server.addr()).unwrap();
        c2.write_all(b"get k\r\n").unwrap();
        let mut reader2 = BufReader::new(c2.try_clone().unwrap());
        let mut l = String::new();
        reader2.read_line(&mut l).unwrap();
        assert_eq!(l, "VALUE k 0 1\r\n");
        drop((c, reader, c2, reader2));
        server.stop();
    }

    fn mcd_roundtrip(backend: BackendKind) {
        let server = McdServer::start(McdServerConfig {
            workers: 2,
            backend,
            ..Default::default()
        });
        let mut c = TcpStream::connect(server.addr()).unwrap();
        c.write_all(b"set greeting 5 0 5\r\nhello\r\n").unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "STORED\r\n");

        c.write_all(b"get greeting\r\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "VALUE greeting 5 5\r\n");
        let mut data = vec![0u8; 7];
        reader.read_exact(&mut data).unwrap();
        assert_eq!(&data, b"hello\r\n");
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "END\r\n");

        c.write_all(b"get missing\r\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line, "END\r\n");
        drop((c, reader));
        server.stop();
    }

    #[test]
    fn trust_server_roundtrip() {
        mcd_roundtrip(BackendKind::Trust { shards: 2 });
    }

    #[test]
    fn lock_server_roundtrips() {
        // The unified path serves memcached over every lock baseline too.
        mcd_roundtrip(BackendKind::Mutex);
        mcd_roundtrip(BackendKind::RwLock);
        mcd_roundtrip(BackendKind::Swift);
    }

    #[test]
    fn pipelined_responses_stay_ordered() {
        // The delegated backend completes out of order across shards; the
        // text protocol demands in-order responses. Hammer with a
        // pipelined mix and verify strict ordering by echoing keys.
        let server = McdServer::start(McdServerConfig {
            workers: 3,
            backend: BackendKind::Trust { shards: 8 },
            ..Default::default()
        });
        server.prefill(64, 8);
        let mut c = TcpStream::connect(server.addr()).unwrap();
        let mut sent_keys = Vec::new();
        let mut req = Vec::new();
        for i in 0..64u64 {
            let key = super::super::memtier::key_bytes(i);
            req.extend_from_slice(format!("get {}\r\n", String::from_utf8_lossy(&key)).as_bytes());
            sent_keys.push(key);
        }
        c.write_all(&req).unwrap();
        let mut reader = BufReader::new(c.try_clone().unwrap());
        for want in &sent_keys {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(
                line.starts_with(&format!("VALUE {} ", String::from_utf8_lossy(want))),
                "out-of-order response: got {line:?} want key {}",
                String::from_utf8_lossy(want)
            );
            let mut data_line = String::new();
            reader.read_line(&mut data_line).unwrap(); // data
            let mut end = String::new();
            reader.read_line(&mut end).unwrap();
            assert_eq!(end, "END\r\n");
        }
        drop((c, reader));
        server.stop();
    }
}
