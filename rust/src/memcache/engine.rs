//! Storage engines for mini-memcached (§7).
//!
//! [`StockEngine`] models stock memcached's synchronization profile:
//! bucket-chained hash table with striped locks, a **global** LRU list
//! behind its own mutex, and a slab-allocator byte counter behind another —
//! "memory allocation, LRU updates as well as table writes, all of which
//! involve synchronization in a lock-based design".
//!
//! [`TrustEngine`] is the delegated port: the table and supporting
//! structures are divided into shards, each entrusted to a trustee with a
//! **per-shard LRU** ("we use the traditional eviction scheme, maintaining
//! one LRU per shard"); all operations on a shard are local to its trustee
//! and require no synchronization.

use crate::cmap::{fxhash, OaTable};
use crate::runtime::Runtime;
use crate::trust::Trust;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A stored item: flags + payload (expiry elided — the paper disables
/// eviction/expiry for the evaluation).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Item {
    pub flags: u32,
    pub data: Vec<u8>,
}

pub type GetCb = Box<dyn FnOnce(Option<Item>) + 'static>;
pub type SetCb = Box<dyn FnOnce(()) + 'static>;

/// Callback-style engine interface (same shape as the KV backend so the
/// server loop is engine-agnostic).
pub trait McdEngine: Send + Sync + 'static {
    fn get(&self, key: Vec<u8>, cb: GetCb);
    fn set(&self, key: Vec<u8>, flags: u32, data: Vec<u8>, cb: SetCb);
    fn item_count(&self) -> usize;
    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------
// Stock engine (lock-based)
// ---------------------------------------------------------------------

const LRU_BUMP_EVERY: u64 = 8; // memcached bumps lazily; model that

pub struct StockEngine {
    buckets: Vec<Mutex<HashMap<Vec<u8>, Item>>>,
    /// Global LRU — the contended structure writes (and periodic read
    /// bumps) must take.
    lru: Mutex<VecDeque<Vec<u8>>>,
    /// Slab allocator stand-in: a byte budget behind a mutex.
    slab_bytes: Mutex<u64>,
    accesses: AtomicU64,
}

impl StockEngine {
    pub fn new(n_buckets: usize) -> Arc<StockEngine> {
        let n = n_buckets.next_power_of_two().max(16);
        let mut buckets = Vec::with_capacity(n);
        buckets.resize_with(n, || Mutex::new(HashMap::new()));
        Arc::new(StockEngine {
            buckets,
            lru: Mutex::new(VecDeque::new()),
            slab_bytes: Mutex::new(0),
            accesses: AtomicU64::new(0),
        })
    }

    #[inline]
    fn bucket(&self, key: &[u8]) -> &Mutex<HashMap<Vec<u8>, Item>> {
        &self.buckets[(fxhash(key) as usize >> 6) & (self.buckets.len() - 1)]
    }
}

impl McdEngine for StockEngine {
    fn get(&self, key: Vec<u8>, cb: GetCb) {
        let item = self.bucket(&key).lock().unwrap().get(&key).cloned();
        // Periodic LRU bump: even reads synchronize on the global list
        // every so often (stock memcached's lazy bump).
        if item.is_some() && self.accesses.fetch_add(1, Ordering::Relaxed) % LRU_BUMP_EVERY == 0 {
            let mut lru = self.lru.lock().unwrap();
            lru.push_back(key);
            if lru.len() > 1 << 20 {
                lru.pop_front();
            }
        }
        cb(item);
    }

    fn set(&self, key: Vec<u8>, flags: u32, data: Vec<u8>, cb: SetCb) {
        // Slab allocation (global mutex) ...
        {
            let mut bytes = self.slab_bytes.lock().unwrap();
            *bytes += (key.len() + data.len()) as u64;
        }
        // ... table write (bucket lock) ...
        let prev = self
            .bucket(&key)
            .lock()
            .unwrap()
            .insert(key.clone(), Item { flags, data });
        // ... and LRU insertion (global mutex).
        {
            let mut lru = self.lru.lock().unwrap();
            lru.push_back(key);
            if lru.len() > 1 << 20 {
                lru.pop_front();
            }
        }
        if let Some(old) = prev {
            let mut bytes = self.slab_bytes.lock().unwrap();
            *bytes = bytes.saturating_sub(old.data.len() as u64);
        }
        cb(());
    }

    fn item_count(&self) -> usize {
        self.buckets.iter().map(|b| b.lock().unwrap().len()).sum()
    }

    fn name(&self) -> &'static str {
        "stock"
    }
}

// ---------------------------------------------------------------------
// Delegated engine (Trust<T>)
// ---------------------------------------------------------------------

/// One delegated shard: table + its own LRU + byte accounting, all
/// trustee-local (zero synchronization).
pub struct McdShard {
    table: OaTable<Vec<u8>, Item>,
    lru: VecDeque<Vec<u8>>,
    bytes: u64,
    accesses: u64,
}

impl Default for McdShard {
    fn default() -> Self {
        McdShard {
            table: OaTable::with_capacity(1024),
            lru: VecDeque::new(),
            bytes: 0,
            accesses: 0,
        }
    }
}

impl McdShard {
    fn get(&mut self, key: &[u8]) -> Option<Item> {
        let item = self.table.get(key).cloned();
        if item.is_some() {
            self.accesses += 1;
            if self.accesses % LRU_BUMP_EVERY == 0 {
                self.lru.push_back(key.to_vec());
                if self.lru.len() > 1 << 18 {
                    self.lru.pop_front();
                }
            }
        }
        item
    }

    fn set(&mut self, key: Vec<u8>, flags: u32, data: Vec<u8>) {
        self.bytes += (key.len() + data.len()) as u64;
        if let Some(old) = self.table.insert(key.clone(), Item { flags, data }) {
            self.bytes = self.bytes.saturating_sub(old.data.len() as u64);
        }
        self.lru.push_back(key);
        if self.lru.len() > 1 << 18 {
            self.lru.pop_front();
        }
    }
}

pub struct TrustEngine {
    shards: Vec<Trust<McdShard>>,
}

impl TrustEngine {
    pub fn new(rt: &Runtime, trustees: &[usize], n_shards: usize) -> Arc<TrustEngine> {
        let mut shards = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let tr = rt.trustee(trustees[s % trustees.len()]);
            shards.push(tr.entrust(McdShard::default()));
        }
        Arc::new(TrustEngine { shards })
    }

    #[inline]
    fn shard(&self, key: &[u8]) -> &Trust<McdShard> {
        &self.shards[(fxhash(key) as usize >> 8) % self.shards.len()]
    }
}

impl McdEngine for TrustEngine {
    fn get(&self, key: Vec<u8>, cb: GetCb) {
        self.shard(&key).apply_with_then(
            |s, k: Vec<u8>| s.get(&k).map(|i| (i.flags, i.data)),
            key,
            move |r| cb(r.map(|(flags, data)| Item { flags, data })),
        );
    }

    fn set(&self, key: Vec<u8>, flags: u32, data: Vec<u8>, cb: SetCb) {
        self.shard(&key).apply_with_then(
            move |s, (k, f, d): (Vec<u8>, u32, Vec<u8>)| {
                s.set(k, f, d);
                0u8 // fixed-size ack
            },
            (key, flags, data),
            move |_| cb(()),
        );
    }

    fn item_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.apply(|sh| sh.table.len() as u64) as usize)
            .sum()
    }

    fn name(&self) -> &'static str {
        "trust"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stock_engine_basics() {
        let e = StockEngine::new(64);
        let got = Arc::new(Mutex::new(None));
        let g = got.clone();
        e.set(b"k".to_vec(), 3, b"hello".to_vec(), Box::new(|_| {}));
        e.get(
            b"k".to_vec(),
            Box::new(move |i| {
                *g.lock().unwrap() = i;
            }),
        );
        let item = got.lock().unwrap().clone().unwrap();
        assert_eq!(item.flags, 3);
        assert_eq!(item.data, b"hello");
        assert_eq!(e.item_count(), 1);
    }

    #[test]
    fn stock_engine_concurrent_sets() {
        let e = StockEngine::new(64);
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let e = e.clone();
                std::thread::spawn(move || {
                    for i in 0..200u64 {
                        e.set(
                            format!("t{t}-{i}").into_bytes(),
                            0,
                            vec![0u8; 16],
                            Box::new(|_| {}),
                        );
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(e.item_count(), 800);
    }

    #[test]
    fn trust_engine_roundtrip() {
        let rt = Runtime::builder().workers(2).build();
        let e = TrustEngine::new(&rt, &[0], 2);
        let e2 = e.clone();
        rt.block_on(1, move || {
            let done = Arc::new(AtomicU64::new(0));
            let d = done.clone();
            e2.set(b"alpha".to_vec(), 7, b"beta".to_vec(), Box::new(move |_| {
                d.fetch_add(1, Ordering::Relaxed);
            }));
            while done.load(Ordering::Relaxed) == 0 {
                crate::fiber::yield_now();
            }
            let got = Arc::new(Mutex::new(None));
            let g = got.clone();
            e2.get(
                b"alpha".to_vec(),
                Box::new(move |i| {
                    *g.lock().unwrap() = i;
                }),
            );
            loop {
                if let Some(item) = got.lock().unwrap().clone() {
                    assert_eq!(item.flags, 7);
                    assert_eq!(item.data, b"beta");
                    break;
                }
                crate::fiber::yield_now();
            }
        });
        assert_eq!(e.item_count(), 1);
        rt.shutdown();
    }
}
