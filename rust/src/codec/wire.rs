//! The [`Wire`] trait and its reader/writer, plus implementations for the
//! standard types the delegation channel carries.

use std::fmt;

/// Decoding errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Input ended before the value was complete (needed, available).
    Truncated { needed: usize, available: usize },
    /// An enum/bool tag byte had an invalid value.
    BadTag(u8),
    /// A string was not valid UTF-8.
    BadUtf8,
    /// A length prefix exceeded the remaining input (element-count sanity).
    BadLength(u64),
    /// A varint ran past 10 bytes.
    BadVarint,
    /// Bytes were left over after a full-value decode.
    TrailingBytes(usize),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Truncated { needed, available } => {
                write!(f, "truncated input: needed {needed}, had {available}")
            }
            WireError::BadTag(t) => write!(f, "invalid tag byte {t}"),
            WireError::BadUtf8 => write!(f, "invalid utf-8 in string"),
            WireError::BadLength(l) => write!(f, "implausible length {l}"),
            WireError::BadVarint => write!(f, "varint longer than 10 bytes"),
            WireError::TrailingBytes(n) => write!(f, "{n} trailing bytes"),
        }
    }
}

impl std::error::Error for WireError {}

/// Byte sink for encoding. Grows a `Vec<u8>`; the channel writes the
/// resulting bytes into slot memory (or encodes directly into a scratch
/// buffer reused per worker).
pub struct WireWriter {
    buf: Vec<u8>,
}

impl Default for WireWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl WireWriter {
    pub fn new() -> Self {
        WireWriter { buf: Vec::new() }
    }

    pub fn with_capacity(cap: usize) -> Self {
        WireWriter { buf: Vec::with_capacity(cap) }
    }

    /// Reuse an existing buffer (cleared) to avoid allocation on hot paths.
    pub fn reuse(mut buf: Vec<u8>) -> Self {
        buf.clear();
        WireWriter { buf }
    }

    /// Continue appending to an existing buffer **without clearing it** —
    /// the channel's reserve/commit framing serializes `apply_with`
    /// arguments directly into the outbox arena this way.
    pub fn append(buf: Vec<u8>) -> Self {
        WireWriter { buf }
    }

    #[inline]
    pub fn put_bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    #[inline]
    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// LEB128-style varint (used for lengths).
    pub fn put_varint(&mut self, mut v: u64) {
        loop {
            let byte = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(byte);
                return;
            }
            self.buf.push(byte | 0x80);
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    pub fn clear(&mut self) {
        self.buf.clear();
    }
}

/// Encoded size of `v` as a LEB128 varint (1–10 bytes).
pub fn varint_len(mut v: u64) -> usize {
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

/// Byte source for decoding.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        WireReader { buf, pos: 0 }
    }

    #[inline]
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    #[inline]
    pub fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { needed: n, available: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    #[inline]
    pub fn get_u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn get_varint(&mut self) -> Result<u64, WireError> {
        let mut v = 0u64;
        for i in 0..10 {
            let b = self.get_u8()?;
            v |= ((b & 0x7f) as u64) << (7 * i);
            if b & 0x80 == 0 {
                return Ok(v);
            }
        }
        Err(WireError::BadVarint)
    }

    /// Read a varint length and sanity-check it against remaining input,
    /// assuming elements occupy at least `min_elem_size` bytes each. This
    /// blocks hostile/corrupt length prefixes from causing huge allocations.
    pub fn get_len(&mut self, min_elem_size: usize) -> Result<usize, WireError> {
        let l = self.get_varint()?;
        let floor = min_elem_size.max(1) as u64;
        if l > (self.remaining() as u64) / floor + 1 {
            // +1 tolerates zero-size-element edge cases
            if l.saturating_mul(floor) > self.remaining() as u64 {
                return Err(WireError::BadLength(l));
            }
        }
        Ok(l as usize)
    }
}

/// A value that can traverse the delegation channel in serialized form.
pub trait Wire: Sized {
    /// `Some(n)` iff every value of this type encodes to exactly `n` bytes.
    const FIXED_SIZE: Option<usize>;

    fn write(&self, w: &mut WireWriter);
    fn read(r: &mut WireReader<'_>) -> Result<Self, WireError>;

    /// Exact encoded size of this particular value.
    fn encoded_size(&self) -> usize {
        match Self::FIXED_SIZE {
            Some(n) => n,
            None => {
                let mut w = WireWriter::new();
                self.write(&mut w);
                w.len()
            }
        }
    }
}

impl Wire for () {
    const FIXED_SIZE: Option<usize> = Some(0);
    fn write(&self, _w: &mut WireWriter) {}
    fn read(_r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(())
    }
}

impl Wire for bool {
    const FIXED_SIZE: Option<usize> = Some(1);
    fn write(&self, w: &mut WireWriter) {
        w.put_u8(*self as u8);
    }
    fn read(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        match r.get_u8()? {
            0 => Ok(false),
            1 => Ok(true),
            t => Err(WireError::BadTag(t)),
        }
    }
}

macro_rules! wire_num {
    ($($t:ty),*) => {$(
        impl Wire for $t {
            const FIXED_SIZE: Option<usize> = Some(std::mem::size_of::<$t>());
            #[inline]
            fn write(&self, w: &mut WireWriter) {
                w.put_bytes(&self.to_le_bytes());
            }
            #[inline]
            fn read(r: &mut WireReader<'_>) -> Result<Self, WireError> {
                let b = r.take(std::mem::size_of::<$t>())?;
                Ok(<$t>::from_le_bytes(b.try_into().unwrap()))
            }
        }
    )*};
}
wire_num!(u8, u16, u32, u64, u128, i8, i16, i32, i64, i128, f32, f64);

// usize always encodes as u64 for cross-platform stability.
impl Wire for usize {
    const FIXED_SIZE: Option<usize> = Some(8);
    fn write(&self, w: &mut WireWriter) {
        (*self as u64).write(w);
    }
    fn read(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(u64::read(r)? as usize)
    }
}

impl Wire for char {
    const FIXED_SIZE: Option<usize> = Some(4);
    fn write(&self, w: &mut WireWriter) {
        (*self as u32).write(w);
    }
    fn read(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        char::from_u32(u32::read(r)?).ok_or(WireError::BadTag(0))
    }
}

impl Wire for String {
    const FIXED_SIZE: Option<usize> = None;
    fn write(&self, w: &mut WireWriter) {
        w.put_varint(self.len() as u64);
        w.put_bytes(self.as_bytes());
    }
    fn read(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        let len = r.get_len(1)?;
        let bytes = r.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::BadUtf8)
    }
}

impl<T: Wire> Wire for Vec<T> {
    const FIXED_SIZE: Option<usize> = None;
    fn write(&self, w: &mut WireWriter) {
        w.put_varint(self.len() as u64);
        for x in self {
            x.write(w);
        }
    }
    fn read(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        let min = T::FIXED_SIZE.unwrap_or(1).max(1);
        let len = r.get_len(min)?;
        let mut v = Vec::with_capacity(len.min(r.remaining() / min + 1));
        for _ in 0..len {
            v.push(T::read(r)?);
        }
        Ok(v)
    }
}

impl<T: Wire> Wire for Option<T> {
    const FIXED_SIZE: Option<usize> = None;
    fn write(&self, w: &mut WireWriter) {
        match self {
            None => w.put_u8(0),
            Some(x) => {
                w.put_u8(1);
                x.write(w);
            }
        }
    }
    fn read(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        match r.get_u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::read(r)?)),
            t => Err(WireError::BadTag(t)),
        }
    }
}

impl<T: Wire, E: Wire> Wire for Result<T, E> {
    const FIXED_SIZE: Option<usize> = None;
    fn write(&self, w: &mut WireWriter) {
        match self {
            Ok(x) => {
                w.put_u8(0);
                x.write(w);
            }
            Err(e) => {
                w.put_u8(1);
                e.write(w);
            }
        }
    }
    fn read(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        match r.get_u8()? {
            0 => Ok(Ok(T::read(r)?)),
            1 => Ok(Err(E::read(r)?)),
            t => Err(WireError::BadTag(t)),
        }
    }
}

impl<T: Wire, const N: usize> Wire for [T; N] {
    const FIXED_SIZE: Option<usize> = match T::FIXED_SIZE {
        Some(n) => Some(n * N),
        None => None,
    };
    fn write(&self, w: &mut WireWriter) {
        for x in self {
            x.write(w);
        }
    }
    fn read(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        // Build via Vec to avoid MaybeUninit gymnastics; N is small in
        // practice (channel payloads).
        let mut v = Vec::with_capacity(N);
        for _ in 0..N {
            v.push(T::read(r)?);
        }
        v.try_into().map_err(|_| WireError::BadLength(N as u64))
    }
}

const fn sum_fixed(sizes: &[Option<usize>]) -> Option<usize> {
    let mut total = 0;
    let mut i = 0;
    while i < sizes.len() {
        match sizes[i] {
            Some(n) => total += n,
            None => return None,
        }
        i += 1;
    }
    Some(total)
}

macro_rules! wire_tuple {
    ($($t:ident . $idx:tt),+) => {
        impl<$($t: Wire),+> Wire for ($($t,)+) {
            const FIXED_SIZE: Option<usize> = sum_fixed(&[$($t::FIXED_SIZE),+]);
            fn write(&self, w: &mut WireWriter) {
                $(self.$idx.write(w);)+
            }
            fn read(r: &mut WireReader<'_>) -> Result<Self, WireError> {
                Ok(($($t::read(r)?,)+))
            }
        }
    };
}
wire_tuple!(A.0);
wire_tuple!(A.0, B.1);
wire_tuple!(A.0, B.1, C.2);
wire_tuple!(A.0, B.1, C.2, D.3);
wire_tuple!(A.0, B.1, C.2, D.3, E.4);
wire_tuple!(A.0, B.1, C.2, D.3, E.4, F.5);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_sizes() {
        for (v, len) in [(0u64, 1), (127, 1), (128, 2), (16383, 2), (16384, 3), (u64::MAX, 10)] {
            let mut w = WireWriter::new();
            w.put_varint(v);
            assert_eq!(w.len(), len, "varint({v})");
            assert_eq!(varint_len(v), len, "varint_len({v})");
        }
    }

    #[test]
    fn reader_take_bounds() {
        let mut r = WireReader::new(&[1, 2, 3]);
        assert_eq!(r.take(2).unwrap(), &[1, 2]);
        assert_eq!(r.remaining(), 1);
        assert!(r.take(2).is_err());
        assert_eq!(r.take(1).unwrap(), &[3]);
        assert!(r.is_empty());
    }

    #[test]
    fn encoded_size_matches_actual() {
        let vals: Vec<Box<dyn Fn() -> (usize, usize)>> = vec![
            Box::new(|| {
                let v = 42u64;
                (v.encoded_size(), crate::codec::to_bytes(&v).len())
            }),
            Box::new(|| {
                let v = "hello".to_string();
                (v.encoded_size(), crate::codec::to_bytes(&v).len())
            }),
            Box::new(|| {
                let v = vec![1u16, 2, 3];
                (v.encoded_size(), crate::codec::to_bytes(&v).len())
            }),
        ];
        for f in vals {
            let (hint, actual) = f();
            assert_eq!(hint, actual);
        }
    }

    #[test]
    fn writer_reuse_clears() {
        let mut w = WireWriter::new();
        w.put_bytes(&[1, 2, 3]);
        let w2 = WireWriter::reuse(w.into_vec());
        assert!(w2.is_empty());
    }
}
