//! Wire codec — the serde/bincode stand-in used by the delegation channel.
//!
//! The paper serializes `apply_with` arguments and all closure return
//! values with serde + bincode (§4.3.3, §5.1): *"any type that can be
//! serialized and deserialized may pass over the delegation channel in
//! serialized form"*. Neither crate is available offline, so this module
//! provides [`Wire`], a compact little-endian binary codec with the two
//! properties the channel design depends on:
//!
//! 1. **Statically-sized types advertise their size** ([`Wire::FIXED_SIZE`])
//!    so fixed-size responses are not length-prefixed in the response slot
//!    (§5.3: "The size of each response is often statically known, in which
//!    case it is not encoded in the channel").
//! 2. **Variable-size values are preceded by their size** (varint), exactly
//!    like the paper's variable responses.
//!
//! Implementations cover the primitive types, tuples, `Option`, `Result`,
//! `String`, `Vec<T>`, fixed arrays, and `()`; user types implement `Wire`
//! by composing fields (see `kvstore::proto` for a realistic example).

mod wire;

pub use wire::{varint_len, Wire, WireError, WireReader, WireWriter};

/// Serialize a value to a fresh byte vector.
pub fn to_bytes<T: Wire>(v: &T) -> Vec<u8> {
    let mut w = WireWriter::new();
    v.write(&mut w);
    w.into_vec()
}

/// Deserialize a value from bytes, requiring full consumption.
pub fn from_bytes<T: Wire>(bytes: &[u8]) -> Result<T, WireError> {
    let mut r = WireReader::new(bytes);
    let v = T::read(&mut r)?;
    if !r.is_empty() {
        return Err(WireError::TrailingBytes(r.remaining()));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::check;

    fn roundtrip<T: Wire + PartialEq + std::fmt::Debug>(v: T) {
        let bytes = to_bytes(&v);
        let back: T = from_bytes(&bytes).expect("decode");
        assert_eq!(back, v);
        if let Some(fixed) = T::FIXED_SIZE {
            assert_eq!(bytes.len(), fixed, "FIXED_SIZE mismatch for {v:?}");
        }
    }

    #[test]
    fn primitives_roundtrip() {
        roundtrip(());
        roundtrip(true);
        roundtrip(false);
        roundtrip(0u8);
        roundtrip(255u8);
        roundtrip(u16::MAX);
        roundtrip(u32::MAX);
        roundtrip(u64::MAX);
        roundtrip(i8::MIN);
        roundtrip(i32::MIN);
        roundtrip(i64::MIN);
        roundtrip(-0.0f32);
        roundtrip(std::f64::consts::PI);
        roundtrip(usize::MAX as u64);
    }

    #[test]
    fn compound_roundtrip() {
        roundtrip((1u32, 2u64, 3i8));
        roundtrip(Some(42u16));
        roundtrip(None::<u16>);
        roundtrip(Ok::<u8, String>(7));
        roundtrip(Err::<u8, String>("nope".into()));
        roundtrip("hello world".to_string());
        roundtrip(String::new());
        roundtrip(vec![1u64, 2, 3]);
        roundtrip(Vec::<u8>::new());
        roundtrip([1u32, 2, 3, 4]);
        roundtrip((vec!["a".to_string(), "b".to_string()], Some((1u8, 2u8))));
    }

    #[test]
    fn fixed_size_advertised_correctly() {
        assert_eq!(<()>::FIXED_SIZE, Some(0));
        assert_eq!(u8::FIXED_SIZE, Some(1));
        assert_eq!(u64::FIXED_SIZE, Some(8));
        assert_eq!(<(u32, u16)>::FIXED_SIZE, Some(6));
        assert_eq!(<[u16; 4]>::FIXED_SIZE, Some(8));
        assert_eq!(String::FIXED_SIZE, None);
        assert_eq!(Vec::<u8>::FIXED_SIZE, None);
        assert_eq!(Option::<u8>::FIXED_SIZE, None);
    }

    #[test]
    fn truncated_input_errors() {
        let bytes = to_bytes(&12345u64);
        for cut in 0..bytes.len() {
            assert!(from_bytes::<u64>(&bytes[..cut]).is_err());
        }
    }

    #[test]
    fn trailing_bytes_error() {
        let mut bytes = to_bytes(&1u8);
        bytes.push(0);
        assert!(matches!(
            from_bytes::<u8>(&bytes),
            Err(WireError::TrailingBytes(1))
        ));
    }

    #[test]
    fn bad_utf8_rejected() {
        // length-1 string with invalid byte
        let bytes = vec![1u8, 0xFF];
        assert!(from_bytes::<String>(&bytes).is_err());
    }

    #[test]
    fn bad_enum_tag_rejected() {
        let bytes = vec![7u8];
        assert!(from_bytes::<Option<u8>>(&bytes).is_err());
        assert!(from_bytes::<bool>(&bytes).is_err());
    }

    #[test]
    fn huge_length_prefix_rejected_without_alloc() {
        // varint length claiming ~u64::MAX elements must not OOM.
        let mut w = WireWriter::new();
        w.put_varint(u64::MAX);
        let bytes = w.into_vec();
        assert!(from_bytes::<Vec<u64>>(&bytes).is_err());
    }

    // ---- property tests ----

    #[test]
    fn prop_u64_roundtrip() {
        check::<u64>("wire-u64", 300, |&x| from_bytes::<u64>(&to_bytes(&x)) == Ok(x));
    }

    #[test]
    fn prop_vec_u8_roundtrip() {
        check::<Vec<u8>>("wire-vec-u8", 300, |v| {
            from_bytes::<Vec<u8>>(&to_bytes(v)).as_ref() == Ok(v)
        });
    }

    #[test]
    fn prop_string_roundtrip() {
        check::<String>("wire-string", 300, |s| {
            from_bytes::<String>(&to_bytes(s)).as_ref() == Ok(s)
        });
    }

    #[test]
    fn prop_tuple_roundtrip() {
        check::<(u32, String, Vec<u16>)>("wire-tuple", 200, |t| {
            from_bytes::<(u32, String, Vec<u16>)>(&to_bytes(t)).as_ref() == Ok(t)
        });
    }

    #[test]
    fn prop_varint_roundtrip() {
        check::<u64>("wire-varint", 500, |&x| {
            let mut w = WireWriter::new();
            w.put_varint(x);
            let v = w.into_vec();
            let mut r = WireReader::new(&v);
            r.get_varint() == Ok(x) && r.is_empty()
        });
    }
}
