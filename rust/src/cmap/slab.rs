//! Slab arena with stable `u32` handles and LIFO free-list reuse — the
//! storage substrate that makes intrusive links legal in the item store.
//!
//! The open-addressing table ([`OaTable`](crate::cmap::OaTable))
//! relocates entries on insert (robin hood) and remove (backward shift),
//! so a pointer or index into a table slot goes stale under churn. A
//! [`Slab`] decouples *where an entry lives* from *how it is found*: the
//! table maps key → slab handle, the slab owns the entry at a slot that
//! never moves until the entry is removed, and freed slots are recycled
//! LIFO so sustained insert/remove churn reaches a fixed footprint with
//! no per-op allocation. Because handles are stable, entries may carry
//! intrusive prev/next links naming *other* slab handles — the basis of
//! the item store's O(1) LRU eviction
//! ([`ItemShard`](crate::kvstore::store::ItemShard)).
//!
//! Entirely safe Rust: vacancy is an enum discriminant, not a
//! mem-uninitialized hole, so the whole module runs under Miri as part
//! of the OS-free layer suite.

/// The null handle: never returned by [`Slab::insert`], usable as a
/// list-terminator sentinel in intrusive links.
pub const NIL: u32 = u32::MAX;

enum Slot<T> {
    Occupied(T),
    /// Next slot in the free list ([`NIL`] = end).
    Vacant { next_free: u32 },
}

/// A slab arena: values live at stable `u32` handles, freed slots are
/// reused LIFO before the backing vector grows.
pub struct Slab<T> {
    slots: Vec<Slot<T>>,
    free_head: u32,
    len: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Slab<T> {
        Slab { slots: Vec::new(), free_head: NIL, len: 0 }
    }

    pub fn with_capacity(cap: usize) -> Slab<T> {
        Slab { slots: Vec::with_capacity(cap), free_head: NIL, len: 0 }
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Slots ever allocated (occupied + free-listed). Handles are always
    /// `< slot_count()`, so this bounds a cursor walking the slab by
    /// index — slots never relocate, unlike table slots.
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Store `value`, reusing the most recently freed slot if one
    /// exists. The returned handle stays valid (and the value stays at
    /// it) until `remove(handle)`.
    pub fn insert(&mut self, value: T) -> u32 {
        self.len += 1;
        if self.free_head != NIL {
            let idx = self.free_head;
            let slot = &mut self.slots[idx as usize];
            match *slot {
                Slot::Vacant { next_free } => {
                    self.free_head = next_free;
                    *slot = Slot::Occupied(value);
                    idx
                }
                Slot::Occupied(_) => unreachable!("free list points at an occupied slot"),
            }
        } else {
            assert!(self.slots.len() < NIL as usize, "slab full: 2^32-1 slots");
            self.slots.push(Slot::Occupied(value));
            (self.slots.len() - 1) as u32
        }
    }

    /// Remove and return the value at `idx`, pushing the slot onto the
    /// free list. `None` if the slot is vacant or out of range.
    pub fn remove(&mut self, idx: u32) -> Option<T> {
        let slot = self.slots.get_mut(idx as usize)?;
        if matches!(slot, Slot::Vacant { .. }) {
            return None;
        }
        let prev = std::mem::replace(slot, Slot::Vacant { next_free: self.free_head });
        self.free_head = idx;
        self.len -= 1;
        match prev {
            Slot::Occupied(v) => Some(v),
            Slot::Vacant { .. } => unreachable!("checked occupied above"),
        }
    }

    pub fn get(&self, idx: u32) -> Option<&T> {
        match self.slots.get(idx as usize) {
            Some(Slot::Occupied(v)) => Some(v),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, idx: u32) -> Option<&mut T> {
        match self.slots.get_mut(idx as usize) {
            Some(Slot::Occupied(v)) => Some(v),
            _ => None,
        }
    }

    pub fn contains(&self, idx: u32) -> bool {
        matches!(self.slots.get(idx as usize), Some(Slot::Occupied(_)))
    }

    /// Drop every entry and reset the free list, keeping the backing
    /// vector's allocation.
    pub fn clear(&mut self) {
        self.slots.clear();
        self.free_head = NIL;
        self.len = 0;
    }

    /// Occupied `(handle, value)` pairs in slot order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, &T)> {
        self.slots.iter().enumerate().filter_map(|(i, s)| match s {
            Slot::Occupied(v) => Some((i as u32, v)),
            Slot::Vacant { .. } => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::check;
    use std::collections::HashMap;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        assert_ne!(a, b);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a), Some(&"a"));
        assert_eq!(s.get(b), Some(&"b"));
        *s.get_mut(a).unwrap() = "a2";
        assert_eq!(s.remove(a), Some("a2"));
        assert_eq!(s.get(a), None);
        assert_eq!(s.remove(a), None, "double remove is a no-op");
        assert_eq!(s.len(), 1);
        assert!(s.contains(b));
        assert!(!s.contains(a));
        assert_eq!(s.get(NIL), None);
    }

    #[test]
    fn handles_stay_stable_across_unrelated_churn() {
        let mut s = Slab::new();
        let handles: Vec<u32> = (0..100u64).map(|i| s.insert(i)).collect();
        // Remove every third entry, then insert replacements; the
        // survivors' handles must still resolve to their values.
        for h in handles.iter().step_by(3) {
            s.remove(*h);
        }
        for i in 100..134u64 {
            s.insert(i);
        }
        for (i, h) in handles.iter().enumerate() {
            if i % 3 != 0 {
                assert_eq!(s.get(*h), Some(&(i as u64)), "handle {h} moved");
            }
        }
    }

    #[test]
    fn freed_slots_are_reused_lifo_before_growth() {
        let mut s = Slab::new();
        let h: Vec<u32> = (0..8u32).map(|i| s.insert(i)).collect();
        let before = s.slot_count();
        s.remove(h[2]);
        s.remove(h[5]);
        // LIFO: the most recently freed slot comes back first.
        assert_eq!(s.insert(50), h[5]);
        assert_eq!(s.insert(20), h[2]);
        assert_eq!(s.slot_count(), before, "reuse must not grow the slab");
        let fresh = s.insert(99);
        assert_eq!(fresh as usize, before, "exhausted free list grows");
    }

    #[test]
    fn clear_resets_everything() {
        let mut s = Slab::new();
        for i in 0..10u32 {
            s.insert(i);
        }
        s.remove(3);
        s.clear();
        assert_eq!(s.len(), 0);
        assert!(s.is_empty());
        assert_eq!(s.slot_count(), 0);
        assert_eq!(s.get(0), None);
        let h = s.insert(7u32);
        assert_eq!(h, 0, "fresh slab allocates from slot 0 again");
    }

    #[test]
    fn iter_sees_exactly_the_occupied_slots() {
        let mut s = Slab::new();
        let h: Vec<u32> = (0..20u32).map(|i| s.insert(i * 10)).collect();
        for h in h.iter().step_by(2) {
            s.remove(*h);
        }
        let got: Vec<(u32, u32)> = s.iter().map(|(h, v)| (h, *v)).collect();
        let want: Vec<(u32, u32)> = (0..20u32)
            .filter(|i| i % 2 == 1)
            .map(|i| (h[i as usize], i * 10))
            .collect();
        assert_eq!(got, want);
    }

    #[test]
    fn prop_model_equivalence_under_churn() {
        // Random insert/remove sequences agree with a HashMap keyed by
        // the returned handles; len and membership always match.
        check::<Vec<(u16, bool)>>("slab-model", 120, |ops| {
            let mut s = Slab::new();
            let mut m: HashMap<u32, u16> = HashMap::new();
            let mut handles: Vec<u32> = Vec::new();
            for &(v, del) in ops {
                if del && !handles.is_empty() {
                    let h = handles.remove(v as usize % handles.len());
                    assert_eq!(s.remove(h), m.remove(&h));
                } else {
                    let h = s.insert(v);
                    assert!(m.insert(h, v).is_none(), "handle {h} double-issued");
                    handles.push(h);
                }
                if s.len() != m.len() {
                    return false;
                }
            }
            m.iter().all(|(h, v)| s.get(*h) == Some(v))
                && s.iter().count() == m.len()
        });
    }
}
