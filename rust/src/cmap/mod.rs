//! Map substrate for the storage layer: the from-scratch open-addressing
//! robin-hood table ([`OaTable`]) and its FxHash hasher.
//!
//! The storage unification (PR 5) collapsed the former zoo here — a
//! generic `ConcurrentMap` trait with sharded `Mutex`/`RwLock` `HashMap`s
//! and a Dashmap stand-in — into one shard type built on [`OaTable`]:
//! [`crate::kvstore::store::ItemShard`]. The lock baselines now wrap that
//! shard directly (`kvstore::backend::LockedItemKv`), so the generic
//! concurrent-map machinery had no remaining users and was deleted
//! rather than kept as unreachable pub API.
//!
//! [`OaTable`] exposes slot-addressed entry points
//! ([`OaTable::index_of`]/[`OaTable::entry_at`]/[`OaTable::remove_at`])
//! so LRU victim scans and the incremental expiry sweep can address
//! entries without building owned keys.

pub mod oatable;

pub use oatable::{fxhash, FxHasher, OaTable};
