//! Map substrate for the storage layer: the from-scratch open-addressing
//! robin-hood table ([`OaTable`]), its FxHash hasher, and the stable-
//! handle slab arena ([`Slab`]) the item store threads intrusive LRU
//! links through.
//!
//! The storage unification (PR 5) collapsed the former zoo here — a
//! generic `ConcurrentMap` trait with sharded `Mutex`/`RwLock` `HashMap`s
//! and a Dashmap stand-in — into one shard type built on [`OaTable`]:
//! [`crate::kvstore::store::ItemShard`]. The lock baselines now wrap that
//! shard directly (`kvstore::backend::LockedItemKv`), so the generic
//! concurrent-map machinery had no remaining users and was deleted
//! rather than kept as unreachable pub API.
//!
//! The slab refactor split *finding* an entry from *storing* it: the
//! table maps key → `u32` slab handle (a [`Slab`] index that never moves
//! under robin-hood/backward-shift relocation), and
//! [`OaTable::find_slot_by_hash`] walks a stored hash back to its table
//! slot in expected O(1) — how the store's LRU tail victim finds its own
//! table entry without a scan.

pub mod oatable;
pub mod slab;

pub use oatable::{fxhash, FxHasher, OaTable};
pub use slab::{Slab, NIL};
